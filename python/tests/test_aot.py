# AOT contract tests: the manifest + HLO-text artifacts the Rust runtime
# consumes.  Lowers a subset into a temp dir and checks structure; also
# validates an existing artifacts/ dir when present (fast path in CI).
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import model
from compile.shapes import SHAPES as S

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
ARTIFACTS = os.path.join(REPO, "artifacts")


@pytest.fixture(scope="module")
def artifacts_dir(tmp_path_factory):
    """Use the checked-out artifacts dir if complete, else lower fresh."""
    manifest = os.path.join(ARTIFACTS, "manifest.json")
    if os.path.exists(manifest):
        with open(manifest) as f:
            m = json.load(f)
        if set(m["entries"]) == {"prefill", "decode_step", "logprob",
                                 "train_step"}:
            return ARTIFACTS
    out = str(tmp_path_factory.mktemp("artifacts"))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out],
        check=True, cwd=os.path.join(REPO, "python"))
    return out


def _manifest(artifacts_dir):
    with open(os.path.join(artifacts_dir, "manifest.json")) as f:
        return json.load(f)


def test_manifest_entries_complete(artifacts_dir):
    m = _manifest(artifacts_dir)
    assert set(m["entries"]) == {
        "prefill", "decode_step", "logprob", "train_step"}
    for name, e in m["entries"].items():
        path = os.path.join(artifacts_dir, e["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert text.startswith("HloModule"), name
        assert e["inputs"] and e["outputs"]


def test_manifest_model_matches_shapes(artifacts_dir):
    m = _manifest(artifacts_dir)["model"]
    assert m["vocab"] == S.vocab
    assert m["n_layers"] == S.n_layers
    assert m["batch"] == S.batch
    assert m["max_seq"] == S.max_seq
    assert m["param_count"] == S.param_count()


def test_param_layout_round_trip(artifacts_dir):
    m = _manifest(artifacts_dir)
    layout = model.param_layout()
    assert len(m["param_layout"]) == len(layout)
    for entry, (name, shape) in zip(m["param_layout"], layout):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == shape


def test_params_bin_size_and_loadability(artifacts_dir):
    path = os.path.join(artifacts_dir, "params.init.bin")
    raw = np.fromfile(path, "<f4")
    assert raw.size == S.param_count()
    # reconstruct and compare against init_params(0)
    params = model.init_params(0)
    off = 0
    for p in params:
        n = int(np.prod(p.shape))
        np.testing.assert_array_equal(
            raw[off:off + n].reshape(p.shape), np.asarray(p))
        off += n
    assert off == raw.size


def test_train_step_flat_arg_order(artifacts_dir):
    """The Rust runtime feeds literals positionally; the manifest input
    list must be params, m, v, then the six data args."""
    e = _manifest(artifacts_dir)["entries"]["train_step"]
    names = [i["name"] for i in e["inputs"]]
    n = len(model.param_layout())
    assert names[:n] == [x for x, _ in model.param_layout()]
    assert names[n:2 * n] == [f"m.{x}" for x, _ in model.param_layout()]
    assert names[2 * n:3 * n] == [f"v.{x}" for x, _ in model.param_layout()]
    assert names[3 * n:] == ["step", "lr", "tokens", "old_logp", "adv",
                             "mask"]
    outs = [o["name"] for o in e["outputs"]]
    assert outs[-3:] == ["loss", "entropy", "grad_norm"]
    assert len(outs) == 3 * n + 3


def test_decode_entry_shapes(artifacts_dir):
    e = _manifest(artifacts_dir)["entries"]["decode_step"]
    by_name = {i["name"]: i for i in e["inputs"]}
    assert by_name["cache_k"]["shape"] == [
        S.n_layers, S.batch, S.n_heads, S.max_seq, S.head_dim]
    assert by_name["tokens"]["shape"] == [S.batch]
    assert by_name["tokens"]["dtype"] == "int32"
    outs = [o["name"] for o in e["outputs"]]
    assert outs == ["logits", "cache_k", "cache_v", "lengths"]
