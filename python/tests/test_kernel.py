# pytest: kernel vs ref allclose — the CORE correctness signal.
# hypothesis sweeps shapes/dtypes; every Pallas kernel is compared
# against its pure-jnp oracle in compile/kernels/ref.py.
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    flash_attention,
    decode_attention,
    grpo_loss,
    grpo_loss_terms,
)
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=8)
settings.load_profile("ci")


def _rand(rng, shape, dtype):
    x = rng.normal(0.0, 1.0, shape)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref(b, h, s_blocks, d, seed):
    s = 32 * s_blocks
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, s, d), jnp.float32)
    k = _rand(rng, (b, h, s, d), jnp.float32)
    v = _rand(rng, (b, h, s, d), jnp.float32)
    out = flash_attention(q, k, v)
    exp = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


@given(
    bq=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_block_size_invariance(bq, bk, seed):
    """Output must not depend on the tiling."""
    s = 64
    rng = np.random.default_rng(seed)
    q = _rand(rng, (2, 2, s, 32), jnp.float32)
    k = _rand(rng, (2, 2, s, 32), jnp.float32)
    v = _rand(rng, (2, 2, s, 32), jnp.float32)
    out = flash_attention(q, k, v, bq, bk)
    exp = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 2, 64, 32), jnp.bfloat16)
    k = _rand(rng, (2, 2, 64, 32), jnp.bfloat16)
    v = _rand(rng, (2, 2, 64, 32), jnp.bfloat16)
    out = flash_attention(q, k, v).astype(jnp.float32)
    exp = ref.causal_attention(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(out, exp, rtol=5e-2, atol=5e-2)


def test_flash_attention_causality():
    """Perturbing future K/V rows must not change earlier outputs."""
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 1, 64, 32), jnp.float32)
    k = _rand(rng, (1, 1, 64, 32), jnp.float32)
    v = _rand(rng, (1, 1, 64, 32), jnp.float32)
    out1 = flash_attention(q, k, v)
    k2 = k.at[:, :, 40:].add(100.0)
    v2 = v.at[:, :, 40:].add(-7.0)
    out2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(out1[:, :, :40], out2[:, :, :40],
                               rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, :, 41:], out2[:, :, 41:])


def test_flash_attention_grad_matches_ref():
    rng = np.random.default_rng(4)
    q = _rand(rng, (2, 2, 64, 32), jnp.float32)
    k = _rand(rng, (2, 2, 64, 32), jnp.float32)
    v = _rand(rng, (2, 2, 64, 32), jnp.float32)
    g1 = jax.grad(lambda a, b, c: flash_attention(a, b, c).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda a, b, c: ref.causal_attention(a, b, c).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_flash_attention_first_row_attends_self_only():
    """Row 0 can only attend itself → output row 0 == v row 0."""
    rng = np.random.default_rng(5)
    q = _rand(rng, (1, 2, 32, 16), jnp.float32)
    k = _rand(rng, (1, 2, 32, 16), jnp.float32)
    v = _rand(rng, (1, 2, 32, 16), jnp.float32)
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(out[:, :, 0], v[:, :, 0], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 5),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s_blocks, d, seed):
    s = 32 * s_blocks
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, d), jnp.float32)
    ck = _rand(rng, (b, h, s, d), jnp.float32)
    cv = _rand(rng, (b, h, s, d), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, s + 1, size=(b,)), jnp.int32)
    out = decode_attention(q, ck, cv, lengths)
    exp = ref.decode_attention(q, ck, cv, lengths)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_decode_attention_ignores_stale_cache():
    """Rows beyond `lengths` must not affect the result (the engine
    reuses cache slots across trajectories — stale data is expected)."""
    rng = np.random.default_rng(6)
    b, h, s, d = 2, 2, 64, 32
    q = _rand(rng, (b, h, d), jnp.float32)
    ck = _rand(rng, (b, h, s, d), jnp.float32)
    cv = _rand(rng, (b, h, s, d), jnp.float32)
    lengths = jnp.asarray([10, 20], jnp.int32)
    out1 = decode_attention(q, ck, cv, lengths)
    ck2 = ck.at[:, :, 30:].set(999.0)
    cv2 = cv.at[:, :, 30:].set(-999.0)
    out2 = decode_attention(q, ck2, cv2, lengths)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_attention_length_one():
    """With length 1 the output is exactly cache row 0's V."""
    rng = np.random.default_rng(7)
    b, h, s, d = 1, 2, 32, 16
    q = _rand(rng, (b, h, d), jnp.float32)
    ck = _rand(rng, (b, h, s, d), jnp.float32)
    cv = _rand(rng, (b, h, s, d), jnp.float32)
    out = decode_attention(q, ck, cv, jnp.asarray([1], jnp.int32))
    np.testing.assert_allclose(out[0], cv[0, :, 0], rtol=1e-6, atol=1e-6)


def test_decode_matches_last_row_of_flash():
    """Decoding position t must equal flash attention's row t."""
    rng = np.random.default_rng(8)
    b, h, s, d = 2, 2, 64, 32
    q = _rand(rng, (b, h, s, d), jnp.float32)
    k = _rand(rng, (b, h, s, d), jnp.float32)
    v = _rand(rng, (b, h, s, d), jnp.float32)
    full = flash_attention(q, k, v)
    t = 37
    dec = decode_attention(q[:, :, t], k, v,
                           jnp.full((b,), t + 1, jnp.int32))
    np.testing.assert_allclose(dec, full[:, :, t], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grpo_loss
# ---------------------------------------------------------------------------

@given(
    b_blocks=st.integers(1, 3),
    s_blocks=st.integers(1, 4),
    clip=st.sampled_from([0.1, 0.2, 0.3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_grpo_terms_match_ref(b_blocks, s_blocks, clip, seed):
    b, s = 4 * b_blocks, 32 * s_blocks
    rng = np.random.default_rng(seed)
    lp_new = _rand(rng, (b, s), jnp.float32)
    lp_old = _rand(rng, (b, s), jnp.float32)
    adv = _rand(rng, (b, s), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, (b, s)), jnp.float32)
    out = grpo_loss_terms(lp_new, lp_old, adv, mask, clip)
    exp = ref.grpo_loss_terms(lp_new, lp_old, adv, mask, clip)
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)


def test_grpo_scalar_matches_ref():
    rng = np.random.default_rng(9)
    lp_new = _rand(rng, (8, 64), jnp.float32)
    lp_old = _rand(rng, (8, 64), jnp.float32)
    adv = _rand(rng, (8, 64), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, (8, 64)), jnp.float32)
    np.testing.assert_allclose(
        grpo_loss(lp_new, lp_old, adv, mask),
        ref.grpo_loss(lp_new, lp_old, adv, mask),
        rtol=1e-6, atol=1e-6)


def test_grpo_grad_matches_ref():
    rng = np.random.default_rng(10)
    lp_new = _rand(rng, (4, 32), jnp.float32)
    lp_old = _rand(rng, (4, 32), jnp.float32)
    adv = _rand(rng, (4, 32), jnp.float32)
    mask = jnp.ones((4, 32), jnp.float32)
    g1 = jax.grad(lambda x: grpo_loss(x, lp_old, adv, mask))(lp_new)
    g2 = jax.grad(lambda x: ref.grpo_loss(x, lp_old, adv, mask))(lp_new)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)


def test_grpo_identical_policy_is_plain_pg():
    """ratio == 1 everywhere → loss == -mean(adv * mask)."""
    rng = np.random.default_rng(11)
    lp = _rand(rng, (4, 32), jnp.float32)
    adv = _rand(rng, (4, 32), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, (4, 32)), jnp.float32)
    loss = grpo_loss(lp, lp, adv, mask)
    exp = -(adv * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    np.testing.assert_allclose(loss, exp, rtol=1e-6, atol=1e-6)


def test_grpo_masked_tokens_contribute_nothing():
    rng = np.random.default_rng(12)
    lp_new = _rand(rng, (4, 32), jnp.float32)
    lp_old = _rand(rng, (4, 32), jnp.float32)
    adv = _rand(rng, (4, 32), jnp.float32)
    mask = jnp.zeros((4, 32), jnp.float32).at[:, :8].set(1.0)
    l1 = grpo_loss(lp_new, lp_old, adv, mask)
    # wildly perturb masked region
    l2 = grpo_loss(lp_new.at[:, 8:].add(50.0), lp_old, adv, mask)
    np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-6)


def test_grpo_clip_bounds_positive_adv():
    """For adv>0 and huge ratio, loss per token is -(1+eps)*adv."""
    lp_old = jnp.zeros((4, 32), jnp.float32)
    lp_new = jnp.full((4, 32), 5.0, jnp.float32)     # ratio = e^5
    adv = jnp.ones((4, 32), jnp.float32)
    mask = jnp.ones((4, 32), jnp.float32)
    terms = grpo_loss_terms(lp_new, lp_old, adv, mask, 0.2)
    np.testing.assert_allclose(terms, -1.2 * jnp.ones_like(terms),
                               rtol=1e-6, atol=1e-6)


def test_grpo_no_clip_negative_direction():
    """For adv<0 the unclipped branch dominates (pessimistic min)."""
    lp_old = jnp.zeros((4, 32), jnp.float32)
    lp_new = jnp.full((4, 32), 1.0, jnp.float32)     # ratio = e
    adv = -jnp.ones((4, 32), jnp.float32)
    mask = jnp.ones((4, 32), jnp.float32)
    terms = grpo_loss_terms(lp_new, lp_old, adv, mask, 0.2)
    np.testing.assert_allclose(terms, np.e * jnp.ones_like(terms),
                               rtol=1e-6, atol=1e-6)
