# L2 model tests: shape contracts, prefill/decode consistency, RoPE,
# logprob semantics, and a tiny end-to-end "loss goes down" check for
# the fused GRPO train step.
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.shapes import SHAPES as S


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=0)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(42)
    return jnp.asarray(
        rng.integers(0, S.vocab, size=(S.batch, S.max_seq)), jnp.int32)


def test_param_layout_matches_count(params):
    total = sum(int(np.prod(p.shape)) for p in params)
    assert total == S.param_count()
    assert len(params) == len(model.param_layout())
    for p, (name, shape) in zip(params, model.param_layout()):
        assert p.shape == shape, name


def test_prefill_shapes(params, tokens):
    lengths = jnp.full((S.batch,), 7, jnp.int32)
    last, ck, cv = model.prefill(params, tokens, lengths)
    assert last.shape == (S.batch, S.vocab)
    assert ck.shape == (S.n_layers, S.batch, S.n_heads, S.max_seq, S.head_dim)
    assert cv.shape == ck.shape
    assert bool(jnp.all(jnp.isfinite(last)))


def test_prefill_last_logits_position(params, tokens):
    """last_logits must equal the full forward at position len-1."""
    lengths = jnp.asarray([3, 5, 7, 9, 2, 4, 6, 8][: S.batch], jnp.int32)
    last, _, _ = model.prefill(params, tokens, lengths)
    full, _, _ = model._forward_full(params, tokens)
    for b in range(S.batch):
        np.testing.assert_allclose(
            last[b], full[b, int(lengths[b]) - 1], rtol=1e-5, atol=1e-5)


def test_decode_step_matches_full_forward(params, tokens):
    """Teacher-forced decode after prefill == full-sequence forward."""
    plen = 5
    lengths = jnp.full((S.batch,), plen, jnp.int32)
    _, ck, cv = model.prefill(params, tokens, lengths)
    lens = lengths
    for t in range(plen, plen + 3):
        nxt = tokens[:, t]
        logits, ck, cv, lens = model.decode_step(params, ck, cv, nxt, lens)
        full, _, _ = model._forward_full(params, tokens)
        np.testing.assert_allclose(logits, full[:, t], rtol=2e-4, atol=2e-4)
    assert int(lens[0]) == plen + 3


def test_decode_step_heterogeneous_lengths(params, tokens):
    """Slots at different positions decode independently & correctly."""
    lengths = jnp.asarray(
        [3, 8, 5, 12, 4, 9, 6, 10][: S.batch], jnp.int32)
    _, ck, cv = model.prefill(params, tokens, lengths)
    nxt = jnp.asarray(
        [int(tokens[b, int(lengths[b])]) for b in range(S.batch)], jnp.int32)
    logits, _, _, _ = model.decode_step(params, ck, cv, nxt, lengths)
    full, _, _ = model._forward_full(params, tokens)
    for b in range(S.batch):
        np.testing.assert_allclose(
            logits[b], full[b, int(lengths[b])], rtol=2e-4, atol=2e-4)


def test_logprob_is_log_softmax_of_forward(params):
    rng = np.random.default_rng(1)
    toks = jnp.asarray(
        rng.integers(0, S.vocab, (S.train_batch, S.train_seq)), jnp.int32)
    lp = model.logprob(params, toks)
    assert lp.shape == (S.train_batch, S.train_seq)
    np.testing.assert_allclose(lp[:, 0], 0.0)
    assert bool(jnp.all(lp[:, 1:] <= 0.0))
    full, _, _ = model._forward_full(params, toks)
    ls = jax.nn.log_softmax(full.astype(jnp.float32), -1)
    exp = jnp.take_along_axis(ls[:, :-1], toks[:, 1:, None], -1)[..., 0]
    np.testing.assert_allclose(lp[:, 1:], exp, rtol=1e-5, atol=1e-5)


def test_rope_position_dependence(params):
    """Same token at different positions must produce different K."""
    toks = jnp.zeros((S.batch, S.max_seq), jnp.int32).at[:, :].set(17)
    full, ck, _ = model._forward_full(params, toks)
    # K at position 0 vs position 1 for identical input tokens differ
    assert not np.allclose(ck[0, 0, :, 0], ck[0, 0, :, 1])


def test_train_step_shapes_and_finiteness(params):
    rng = np.random.default_rng(2)
    B, T = S.train_batch, S.train_seq
    toks = jnp.asarray(rng.integers(0, S.vocab, (B, T)), jnp.int32)
    mask = jnp.zeros((B, T), jnp.float32).at[:, 4:40].set(1.0)
    old = model.logprob(params, toks)
    adv = jnp.asarray(rng.normal(size=(B, T)), jnp.float32)
    zeros = tuple(jnp.zeros_like(p) for p in params)
    new_p, new_m, new_v, loss, ent, gn = model.train_step(
        params, zeros, zeros, jnp.float32(1.0), jnp.float32(1e-4),
        toks, old, adv, mask)
    assert len(new_p) == len(params)
    for a, b in zip(new_p, params):
        assert a.shape == b.shape
    assert np.isfinite(float(loss))
    assert float(ent) > 0.0
    assert float(gn) > 0.0


def test_train_step_zero_adv_is_noop_loss(params):
    """adv == 0 → loss == 0 and (clip-free) zero policy gradient."""
    rng = np.random.default_rng(3)
    B, T = S.train_batch, S.train_seq
    toks = jnp.asarray(rng.integers(0, S.vocab, (B, T)), jnp.int32)
    mask = jnp.ones((B, T), jnp.float32)
    old = model.logprob(params, toks)
    zeros = tuple(jnp.zeros_like(p) for p in params)
    _, _, _, loss, _, gn = model.train_step(
        params, zeros, zeros, jnp.float32(1.0), jnp.float32(1e-4),
        toks, old, jnp.zeros((B, T), jnp.float32), mask)
    assert abs(float(loss)) < 1e-8
    assert float(gn) < 1e-6


def test_train_step_improves_objective(params):
    """A few GRPO steps on a fixed batch must raise the (masked) mean
    logprob of positively-advantaged tokens — the 'loss goes down'
    smoke check for the full fused fwd+bwd+Adam artifact."""
    rng = np.random.default_rng(4)
    B, T = S.train_batch, S.train_seq
    toks = jnp.asarray(rng.integers(0, S.vocab, (B, T)), jnp.int32)
    mask = jnp.zeros((B, T), jnp.float32).at[:, 2:30].set(1.0)
    adv = jnp.ones((B, T), jnp.float32)          # reinforce everything
    old = model.logprob(params, toks)

    p = params
    m = tuple(jnp.zeros_like(x) for x in p)
    v = tuple(jnp.zeros_like(x) for x in p)
    step_fn = jax.jit(model.train_step)
    lp0 = float((model.logprob(p, toks) * mask).sum() / mask.sum())
    for i in range(3):
        p, m, v, loss, ent, gn = step_fn(
            p, m, v, jnp.float32(i + 1), jnp.float32(3e-4),
            toks, old, adv, mask)
    lp1 = float((model.logprob(p, toks) * mask).sum() / mask.sum())
    assert lp1 > lp0, (lp0, lp1)


def test_greedy_generate_deterministic(params):
    out1 = model.greedy_generate(params, [1, 2, 3], steps=4)
    out2 = model.greedy_generate(params, [1, 2, 3], steps=4)
    assert out1 == out2
    assert len(out1) == 4
    assert all(0 <= t < S.vocab for t in out1)
