"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this
package is checked against the matching function here by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes/dtypes and
asserts allclose).  They are also used as the *backward* implementations
for the kernels' ``custom_vjp`` rules — Pallas has no general autodiff,
so gradients recompute through these (mathematically identical)
definitions.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def causal_attention(q, k, v, scale=None):
    """Masked softmax attention.

    q, k, v: (B, H, S, D).  Returns (B, H, S, D) in q's dtype.
    """
    b, h, s, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vf).astype(q.dtype)


def decode_attention(q, cache_k, cache_v, lengths, scale=None):
    """Single-position attention against a KV cache.

    q: (B, H, D) — the query for the token being decoded.
    cache_k/cache_v: (B, H, S, D).
    lengths: (B,) int32 — number of *valid* cache positions per slot
             (the current token's K/V must already be written, so
             position ``lengths[b]-1`` is the newest).
    Returns (B, H, D).
    """
    b, h, s, d = cache_k.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qf = q.astype(jnp.float32)
    kf = cache_k.astype(jnp.float32)
    vf = cache_v.astype(jnp.float32)
    scores = jnp.einsum("bhd,bhkd->bhk", qf, kf) * scale
    pos = jnp.arange(s)[None, :]                      # (1, S)
    valid = pos < lengths[:, None]                    # (B, S)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", probs, vf).astype(q.dtype)


def grpo_loss_terms(logp_new, logp_old, adv, mask, clip_eps=0.2):
    """Per-token clipped GRPO policy-gradient objective.

    logp_new, logp_old, adv, mask: (B, S) float32.
    Returns per-token loss contributions (B, S); caller masks/averages.
    loss_t = -min(r_t * A_t, clip(r_t, 1-eps, 1+eps) * A_t) * mask_t
    """
    ratio = jnp.exp(logp_new - logp_old)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    return -jnp.minimum(unclipped, clipped) * mask


def grpo_loss(logp_new, logp_old, adv, mask, clip_eps=0.2):
    """Scalar masked-mean GRPO loss."""
    terms = grpo_loss_terms(logp_new, logp_old, adv, mask, clip_eps)
    denom = jnp.maximum(mask.sum(), 1.0)
    return terms.sum() / denom


def rmsnorm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def swiglu(x, w1, w2, w3):
    """SwiGLU MLP: (silu(x @ w1) * (x @ w3)) @ w2."""
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2
