"""Pallas fused GRPO-loss kernel (L1 hot spot of the training objective).

Computes the per-token clipped policy-gradient objective

    loss_t = -min(r_t · A_t, clip(r_t, 1±eps) · A_t) · mask_t,
    r_t    = exp(logp_new_t − logp_old_t)

fused in one VMEM pass (exp, clip, min, mask — all VPU element-wise ops)
instead of the five materialized (B,S) intermediates the naive jnp
version creates.  Tiled over (B-blocks × S-blocks); each tile is a
(block_b, block_s) panel resident in VMEM.

Autodiff: ``custom_vjp`` recomputing through ``ref.grpo_loss_terms``
(same math; Pallas has no transpose rules — see ref.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _grpo_kernel(new_ref, old_ref, adv_ref, mask_ref, o_ref, *, clip_eps):
    lp_new = new_ref[...]
    lp_old = old_ref[...]
    adv = adv_ref[...]
    mask = mask_ref[...]

    ratio = jnp.exp(lp_new - lp_old)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    o_ref[...] = -jnp.minimum(unclipped, clipped) * mask


def _grpo_pallas(logp_new, logp_old, adv, mask, clip_eps, block_b, block_s):
    b, s = logp_new.shape
    assert b % block_b == 0 and s % block_s == 0, (b, s, block_b, block_s)
    kernel = functools.partial(_grpo_kernel, clip_eps=clip_eps)
    spec = pl.BlockSpec((block_b, block_s), lambda i, j: (i, j))
    return pl.pallas_call(
        kernel,
        grid=(b // block_b, s // block_s),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, s), jnp.float32),
        interpret=True,
    )(logp_new, logp_old, adv, mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def grpo_loss_terms(logp_new, logp_old, adv, mask,
                    clip_eps=0.2, block_b=4, block_s=32):
    """Per-token GRPO objective, (B,S) float32 inputs → (B,S) float32."""
    return _grpo_pallas(logp_new, logp_old, adv, mask,
                        clip_eps, block_b, block_s)


def _fwd(logp_new, logp_old, adv, mask, clip_eps, block_b, block_s):
    out = _grpo_pallas(logp_new, logp_old, adv, mask,
                       clip_eps, block_b, block_s)
    return out, (logp_new, logp_old, adv, mask)


def _bwd(clip_eps, block_b, block_s, res, g):
    logp_new, logp_old, adv, mask = res
    f = functools.partial(ref.grpo_loss_terms, clip_eps=clip_eps)
    _, vjp = jax.vjp(f, logp_new, logp_old, adv, mask)
    return vjp(g)


grpo_loss_terms.defvjp(_fwd, _bwd)


def grpo_loss(logp_new, logp_old, adv, mask,
              clip_eps=0.2, block_b=4, block_s=32):
    """Scalar masked-mean GRPO loss over the fused per-token kernel."""
    terms = grpo_loss_terms(logp_new, logp_old, adv, mask,
                            clip_eps, block_b, block_s)
    denom = jnp.maximum(mask.sum(), 1.0)
    return terms.sum() / denom
