"""Pallas decode-attention kernel (L1 hot spot for the decode phase).

Single-token attention against a KV cache: the bandwidth-bound phase
whose roofline (≈1 FLOP/byte over the whole cache) is the quantitative
basis of the paper's H20-affinity claim (§3, Fig 4b) — mirrored in the
Rust ``hw`` cost model.

Grid: one program per (batch · head).  Each program streams the cache
rows for its head through VMEM in ``block_k`` tiles and computes a
masked online softmax against the per-slot valid length, so slots in a
continuous batch can sit at different positions (the LLMProxy packs
heterogeneous trajectories into one engine batch; see rust/src/proxy).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, seq, scale):
    """One (batch·head) program.

    len_ref: (1,) int32 in SMEM-like memory — valid cache length for this
        slot (same value for every head of a batch row).
    q_ref: (d,) query; k_ref/v_ref: (seq, d) cache rows; o_ref: (d,).
    """
    q = q_ref[...].astype(jnp.float32) * scale        # (d,)
    d = q.shape[-1]
    length = len_ref[0]

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.ds(ki * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.ds(ki * block_k, block_k), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

        s = jnp.sum(k * q[None, :], axis=-1)          # (bk,) VPU reduce
        pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        valid = pos < length
        s = jnp.where(valid, s, NEG_INF)

        m_cur = jnp.max(s)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p)
        acc = acc * alpha + jnp.sum(p[:, None] * v, axis=0)
        return m_new, l_new, acc

    num_kb = seq // block_k
    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))

    l = jnp.maximum(l, 1e-30)
    o_ref[...] = (acc / l).astype(o_ref.dtype)


def decode_attention(q, cache_k, cache_v, lengths, block_k=32):
    """q: (B,H,D); cache_k/v: (B,H,S,D); lengths: (B,) int32 → (B,H,D).

    No custom_vjp: decode runs only on the inference path (no gradients).
    """
    b, h, s, d = cache_k.shape
    assert s % block_k == 0, (s, block_k)
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * h, d)
    kr = cache_k.reshape(b * h, s, d)
    vr = cache_v.reshape(b * h, s, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), h)   # (B*H,)

    kernel = functools.partial(
        _dec_kernel, block_k=block_k, seq=s, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1,), lambda bh: (bh,)),
            pl.BlockSpec((None, d), lambda bh: (bh, 0)),
            pl.BlockSpec((None, s, d), lambda bh: (bh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda bh: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, d), lambda bh: (bh, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, d), q.dtype),
        interpret=True,
    )(lens, qr, kr, vr)
    return out.reshape(b, h, d)
