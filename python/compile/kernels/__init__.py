# L1: Pallas kernels for the paper's compute hot-spots, checked against
# the pure-jnp oracles in ref.py.
from .flash_attention import flash_attention  # noqa: F401
from .decode_attention import decode_attention  # noqa: F401
from .grpo_loss import grpo_loss, grpo_loss_terms  # noqa: F401
