"""Pallas flash-attention kernel (L1 hot spot for prefill & training).

Tiled online-softmax causal attention.  The grid iterates over
(batch*heads, q-blocks); inside each program a ``fori_loop`` streams K/V
blocks through VMEM and maintains the running (max, normalizer, acc)
triple of the flash-attention recurrence.

TPU adaptation of the paper's CUDA hot spot (DESIGN.md
§Hardware-Adaptation): threadblock tiling becomes the BlockSpec grid +
in-kernel K/V block loop, WMMA becomes MXU-friendly ``jnp.dot`` with f32
accumulation, and warp shuffles become whole-tile VPU reductions.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the AOT
artifacts ship (see /opt/xla-example/README.md).

Autodiff: Pallas has no transpose rules, so ``flash_attention`` carries a
``custom_vjp`` whose backward recomputes through the pure-jnp oracle in
``ref.py`` (identical math; see ref.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq, scale):
    """One (batch*head, q-block) program of the flash-attention grid.

    q_ref: (block_q, d) — this program's query tile (VMEM).
    k_ref, v_ref: (seq, d) — the full K/V rows for this head; the kernel
        streams them block_k rows at a time (on real TPU each ``pl.load``
        below is an HBM→VMEM copy of one tile; double-buffering is the
        compiler's job once block sizes are VMEM-sized).
    o_ref: (block_q, d) — output tile.
    """
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale        # (bq, d)
    d = q.shape[-1]

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)  # (bq,)

    # Causal: query row t only attends keys <= t, so K blocks past this
    # q-block contribute nothing — bound the loop at the diagonal.
    num_kb = (qi * block_q + block_q + block_k - 1) // block_k
    num_kb = jnp.minimum(num_kb, seq // block_k)

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.ds(ki * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.ds(ki * block_k, block_k), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

        s = jax.lax.dot_general(                      # (bq, bk) on the MXU
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, NEG_INF)

        m_cur = jnp.max(s, axis=-1)                   # (bq,)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be 1.
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(causal, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))

    l = jnp.maximum(l, 1e-30)                          # fully-masked rows
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _fa_pallas(q, k, v, block_q, block_k):
    """Raw pallas_call wrapper: q,k,v (B,H,S,D) → (B,H,S,D)."""
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)

    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * h, s, d)
    vr = v.reshape(b * h, s, d)

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, seq=s, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, block_q=32, block_k=32):
    """Causal flash attention. q,k,v: (B,H,S,D); S divisible by blocks."""
    return _fa_pallas(q, k, v, block_q, block_k)


def _fa_fwd(q, k, v, block_q, block_k):
    return _fa_pallas(q, k, v, block_q, block_k), (q, k, v)


def _fa_bwd(block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(ref.causal_attention, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
