"""Static shape configuration for AOT lowering.

Every artifact is lowered at the fixed shapes declared here; the Rust
runtime reads the same values from ``artifacts/manifest.json`` and pads
its batches accordingly.  Keep this file tiny and dependency-free — it is
imported by the kernels, the model, the AOT driver and the tests.

The e2e model is a ~7M-parameter Qwen-style decoder.  The paper trains
Qwen3-8B..32B on GPU clusters; on the CPU-PJRT substrate we scale the
model down so a few hundred *real* GRPO steps complete in the session
budget (see DESIGN.md §2 Substitutions) while exercising identical code
paths (prefill / decode-with-KV-cache / fused-loss train step).
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelShapes:
    """Architecture + AOT batch/sequence shapes for the agent LLM."""

    vocab: int = 512          # byte-level tokenizer: 256 bytes + specials
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 64        # n_heads * head_dim == d_model
    d_ffn: int = 1024
    rope_theta: float = 10_000.0

    # AOT execution shapes (fixed at lowering time).
    batch: int = 8            # engine batch width (proxy pads to this)
    max_seq: int = 160        # KV-cache capacity / prefill width
    train_seq: int = 160      # token width of one training sample
    train_batch: int = 8      # samples per train_step micro-batch

    # Pallas kernel tile sizes (see DESIGN.md §6 for VMEM/MXU estimates).
    block_q: int = 32
    block_k: int = 32

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ffn, self.vocab
        per_layer = 4 * d * d + 3 * d * f + 2 * d  # attn + swiglu + norms
        return v * d + self.n_layers * per_layer + d + d * v

    def to_dict(self):
        out = asdict(self)
        out["param_count"] = self.param_count()
        return out


SHAPES = ModelShapes()

# Adam hyper-parameters baked into the train_step artifact.
ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8

# GRPO clipping range (PPO-style ratio clip).
CLIP_EPS = 0.2
