"""L2: the agent LLM — a Qwen-style decoder-only transformer in JAX.

Four jittable entry points are AOT-lowered to HLO text by ``aot.py`` and
executed from the Rust runtime (Python never runs on the request path):

- ``prefill``      — prompt ingestion: builds the KV cache, returns the
                     next-token logits at each slot's last prompt token.
- ``decode_step``  — one continuous-batching decode step against the KV
                     cache (per-slot positions; calls the Pallas decode
                     kernel).
- ``logprob``      — per-token log-probabilities of a realized sequence
                     (old-logprob recompute after weight sync §6.2, and
                     the LLM-judge reward path).
- ``train_step``   — fused GRPO loss (Pallas kernel) + full backward via
                     ``jax.grad`` + Adam update.

Parameters are a *flat tuple* of arrays in the order given by
``param_layout()``; the same ordering is recorded in
``artifacts/manifest.json`` and consumed by ``rust/src/runtime``.

Attention uses the Pallas kernels from ``kernels/`` (flash attention for
prefill/training, decode attention for generation), so the paper's
compute hot spots lower into the same HLO the Rust side loads.
"""

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .kernels import ref
from .shapes import SHAPES, ADAM_B1, ADAM_B2, ADAM_EPS, CLIP_EPS

S = SHAPES


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

def param_layout(cfg=S) -> List[Tuple[str, Tuple[int, ...]]]:
    """Flat (name, shape) list defining the cross-language param order."""
    d, f, v, hd, h = cfg.d_model, cfg.d_ffn, cfg.vocab, cfg.head_dim, cfg.n_heads
    layout = [("embed", (v, d))]
    for i in range(cfg.n_layers):
        layout += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.wq", (d, h * hd)),
            (f"l{i}.wk", (d, h * hd)),
            (f"l{i}.wv", (d, h * hd)),
            (f"l{i}.wo", (h * hd, d)),
            (f"l{i}.ln2", (d,)),
            (f"l{i}.w1", (d, f)),
            (f"l{i}.w2", (f, d)),
            (f"l{i}.w3", (d, f)),
        ]
    layout += [("lnf", (d,)), ("head", (d, v))]
    return layout


def init_params(seed: int = 0, cfg=S):
    """Scaled-normal init; returns the flat tuple in layout order."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in param_layout(cfg):
        if name.endswith(("ln1", "ln2", "lnf")):
            arr = np.ones(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) == 2 else cfg.d_model
            arr = rng.normal(0.0, fan_in ** -0.5, shape).astype(np.float32)
        params.append(jnp.asarray(arr))
    return tuple(params)


def _split(params, cfg=S):
    """Flat tuple → (embed, [per-layer dicts], lnf, head)."""
    embed = params[0]
    layers = []
    idx = 1
    names = ["ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2", "w3"]
    for _ in range(cfg.n_layers):
        layers.append(dict(zip(names, params[idx:idx + 9])))
        idx += 9
    lnf, head = params[idx], params[idx + 1]
    return embed, layers, lnf, head


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def _rope_freqs(positions, cfg=S):
    """positions: (...,) int32 → cos/sin of shape (..., head_dim//2)."""
    half = cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def _apply_rope(x, cos, sin):
    """x: (..., head_dim); cos/sin broadcastable to (..., head_dim//2)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


# --------------------------------------------------------------------------
# Transformer blocks
# --------------------------------------------------------------------------

def _qkv(layer, x, cfg=S):
    b = x.shape[0]
    t = x.shape[1] if x.ndim == 3 else 1
    def proj(w):
        y = x @ w
        return y.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    return proj(layer["wq"]), proj(layer["wk"]), proj(layer["wv"])


def _forward_full(params, tokens, cfg=S):
    """Full-sequence forward: tokens (B,T) → (logits (B,T,V), k/v stacks).

    k/v stacks: (L, B, H, T, Dh) — the prefill KV cache.
    """
    embed, layers, lnf, head = _split(params, cfg)
    b, t = tokens.shape
    x = embed[tokens]                                   # (B,T,D)
    pos = jnp.arange(t, dtype=jnp.int32)
    cos, sin = _rope_freqs(pos, cfg)                    # (T, Dh/2)
    ks, vs = [], []
    for layer in layers:
        h_in = ref.rmsnorm(x, layer["ln1"])
        q, k, v = _qkv(layer, h_in, cfg)                # (B,H,T,Dh)
        q = _apply_rope(q, cos[None, None], sin[None, None])
        k = _apply_rope(k, cos[None, None], sin[None, None])
        att = kernels.flash_attention(q, k, v, cfg.block_q, cfg.block_k)
        att = att.transpose(0, 2, 1, 3).reshape(b, t, -1)
        x = x + att @ layer["wo"]
        h2 = ref.rmsnorm(x, layer["ln2"])
        x = x + ref.swiglu(h2, layer["w1"], layer["w2"], layer["w3"])
        ks.append(k)
        vs.append(v)
    x = ref.rmsnorm(x, lnf)
    logits = x @ head                                   # (B,T,V)
    return logits, jnp.stack(ks), jnp.stack(vs)


def prefill(params, tokens, lengths, cfg=S):
    """tokens (B,S) int32, lengths (B,) int32.

    Returns (last_logits (B,V), cache_k, cache_v) where ``last_logits``
    are the next-token logits at each slot's final prompt position
    (``lengths[b]-1``) and the caches are (L,B,H,S,Dh).
    """
    logits, ck, cv = _forward_full(params, tokens, cfg)
    idx = jnp.maximum(lengths - 1, 0)
    last = jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1
    )[:, 0]                                             # (B,V)
    return last, ck, cv


def decode_step(params, cache_k, cache_v, tokens, lengths, cfg=S):
    """One decode step for a continuous batch.

    tokens: (B,) int32 — the token generated at position ``lengths[b]-1``'s
        successor slot, i.e. the model input at position ``lengths[b]``.
    lengths: (B,) int32 — current valid cache length per slot; the new
        K/V is written at index ``lengths[b]`` and attention spans
        ``lengths[b]+1`` entries.
    Returns (logits (B,V), new_cache_k, new_cache_v, new_lengths).
    """
    embed, layers, lnf, head = _split(params, cfg)
    b = tokens.shape[0]
    x = embed[tokens][:, None, :]                       # (B,1,D)
    cos, sin = _rope_freqs(lengths, cfg)                # (B, Dh/2)
    cos_b = cos[:, None, None, :]                       # (B,1,1,Dh/2)
    sin_b = sin[:, None, None, :]

    new_ck, new_cv = [], []
    for li, layer in enumerate(layers):
        h_in = ref.rmsnorm(x, layer["ln1"])
        q, k, v = _qkv(layer, h_in, cfg)                # (B,H,1,Dh)
        q = _apply_rope(q, cos_b, sin_b)[:, :, 0]       # (B,H,Dh)
        k = _apply_rope(k, cos_b, sin_b)[:, :, 0]
        v = v[:, :, 0]

        # Scatter the new K/V into each slot's ``lengths[b]`` row.
        def put(cache, new):
            def one(c, n, l):                           # c:(H,S,Dh) n:(H,Dh)
                return jax.lax.dynamic_update_slice(
                    c, n[:, None, :], (0, l, 0))
            return jax.vmap(one)(cache, new, lengths)
        ck = put(cache_k[li], k)
        cv = put(cache_v[li], v)
        new_ck.append(ck)
        new_cv.append(cv)

        att = kernels.decode_attention(q, ck, cv, lengths + 1, cfg.block_k)
        att = att.reshape(b, 1, -1)                     # (B,1,H*Dh)
        x = x + att @ layer["wo"]
        h2 = ref.rmsnorm(x, layer["ln2"])
        x = x + ref.swiglu(h2, layer["w1"], layer["w2"], layer["w3"])

    x = ref.rmsnorm(x, lnf)[:, 0]                       # (B,D)
    logits = x @ head                                   # (B,V)
    return logits, jnp.stack(new_ck), jnp.stack(new_cv), lengths + 1


def logprob(params, tokens, cfg=S):
    """Per-token log-probabilities: lp[b,t] = log P(tokens[t] | tokens[<t]).

    lp[:, 0] is defined as 0 (no conditioning context in-artifact; the
    Rust side masks position 0 anyway because it is always a prompt
    token).
    """
    logits, _, _ = _forward_full(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(
        logp[:, :-1], tokens[:, 1:, None].astype(jnp.int32), axis=-1
    )[..., 0]                                           # (B,S-1)
    return jnp.concatenate(
        [jnp.zeros((tokens.shape[0], 1), jnp.float32), tgt], axis=1
    )


# --------------------------------------------------------------------------
# GRPO training step (loss → grad → Adam) — one fused artifact
# --------------------------------------------------------------------------

def _loss_fn(params, tokens, old_logp, adv, mask, cfg=S):
    # Single forward pass shared by the policy-gradient term and the
    # entropy diagnostic (computing them from separate forwards doubled
    # the train-step cost; see EXPERIMENTS.md §Perf L2-1).
    logits, _, _ = _forward_full(params, tokens, cfg)
    logp_all = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(
        logp_all[:, :-1], tokens[:, 1:, None].astype(jnp.int32), axis=-1
    )[..., 0]
    lp = jnp.concatenate(
        [jnp.zeros((tokens.shape[0], 1), jnp.float32), tgt], axis=1)
    pg = kernels.grpo_loss(lp, old_logp, adv, mask, CLIP_EPS)
    ent_tok = -jnp.sum(jnp.exp(logp_all) * logp_all, -1)          # (B,S)
    ent = jax.lax.stop_gradient(
        (ent_tok * mask).sum() / jnp.maximum(mask.sum(), 1.0))
    return pg, ent


def train_step(params, m_state, v_state, step, lr,
               tokens, old_logp, adv, mask, cfg=S):
    """One GRPO update.

    params/m_state/v_state: flat tuples in ``param_layout`` order.
    step: float32 scalar Adam timestep (1-based); lr: float32 scalar.
    tokens (B,S) int32; old_logp/adv/mask (B,S) float32.
    Returns (new_params, new_m, new_v, loss, entropy, grad_norm).
    """
    (loss, ent), grads = jax.value_and_grad(
        lambda p: _loss_fn(p, tokens, old_logp, adv, mask, cfg),
        has_aux=True,
    )(params)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
    # Global-norm clip at 1.0 for stability.
    scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-12))
    b1t = 1.0 - ADAM_B1 ** step
    b2t = 1.0 - ADAM_B2 ** step

    new_p, new_m, new_v = [], [], []
    for p, m, v, g in zip(params, m_state, v_state, grads):
        g = g * scale
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * jnp.square(g)
        upd = (m2 / b1t) / (jnp.sqrt(v2 / b2t) + ADAM_EPS)
        new_p.append(p - lr * upd)
        new_m.append(m2)
        new_v.append(v2)
    return (tuple(new_p), tuple(new_m), tuple(new_v), loss, ent, gnorm)


# --------------------------------------------------------------------------
# Reference generation loop (used by python tests only — the production
# loop lives in rust/src/exec; this mirrors it for cross-validation).
# --------------------------------------------------------------------------

def greedy_generate(params, prompt: List[int], steps: int, cfg=S):
    b, s = cfg.batch, cfg.max_seq
    tokens = np.zeros((b, s), np.int32)
    tokens[0, :len(prompt)] = prompt
    lengths = np.zeros((b,), np.int32)
    lengths[0] = len(prompt)
    last, ck, cv = prefill(params, jnp.asarray(tokens), jnp.asarray(lengths), cfg)
    out = []
    lens = jnp.asarray(lengths)
    for _ in range(steps):
        nxt = jnp.argmax(last, -1).astype(jnp.int32)    # (B,)
        out.append(int(nxt[0]))
        last, ck, cv, lens = decode_step(params, ck, cv, nxt, lens, cfg)
    return out
