"""AOT driver: lower the L2 entry points to HLO *text* artifacts.

HLO text (NOT ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects;
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Outputs (under --out-dir, default ``artifacts/``):
  prefill.hlo.txt      decode_step.hlo.txt
  logprob.hlo.txt      train_step.hlo.txt
  params.init.bin      — initial parameters, raw little-endian f32,
                         concatenated in ``param_layout`` order
  manifest.json        — shapes/dtypes/flat arg order for the Rust side

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .shapes import SHAPES

S = SHAPES
F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs():
    return [_spec(shape) for _, shape in model.param_layout(S)]


def _cache_spec():
    return _spec((S.n_layers, S.batch, S.n_heads, S.max_seq, S.head_dim))


def _entry_defs():
    """name → (flat_fn, input specs (flat), input names, output names)."""
    n_p = len(model.param_layout(S))
    p_names = [n for n, _ in model.param_layout(S)]

    def prefill_flat(*args):
        params, tokens, lengths = args[:n_p], args[n_p], args[n_p + 1]
        return model.prefill(params, tokens, lengths, S)

    def decode_flat(*args):
        params = args[:n_p]
        ck, cv, tokens, lengths = args[n_p:n_p + 4]
        return model.decode_step(params, ck, cv, tokens, lengths, S)

    def logprob_flat(*args):
        params, tokens = args[:n_p], args[n_p]
        return (model.logprob(params, tokens, S),)

    def train_flat(*args):
        i = 0
        params = args[i:i + n_p]; i += n_p
        m = args[i:i + n_p]; i += n_p
        v = args[i:i + n_p]; i += n_p
        step, lr, tokens, old_logp, adv, mask = args[i:i + 6]
        new_p, new_m, new_v, loss, ent, gnorm = model.train_step(
            params, m, v, step, lr, tokens, old_logp, adv, mask, S)
        return (*new_p, *new_m, *new_v, loss, ent, gnorm)

    bt = (S.train_batch, S.train_seq)
    return {
        "prefill": (
            prefill_flat,
            _param_specs() + [_spec((S.batch, S.max_seq), I32), _spec((S.batch,), I32)],
            p_names + ["tokens", "lengths"],
            ["last_logits", "cache_k", "cache_v"],
        ),
        "decode_step": (
            decode_flat,
            _param_specs() + [_cache_spec(), _cache_spec(),
                              _spec((S.batch,), I32), _spec((S.batch,), I32)],
            p_names + ["cache_k", "cache_v", "tokens", "lengths"],
            ["logits", "cache_k", "cache_v", "lengths"],
        ),
        "logprob": (
            logprob_flat,
            _param_specs() + [_spec(bt, I32)],
            p_names + ["tokens"],
            ["logprobs"],
        ),
        "train_step": (
            train_flat,
            _param_specs() * 3
            + [_spec((), F32), _spec((), F32), _spec(bt, I32),
               _spec(bt), _spec(bt), _spec(bt)],
            p_names + [f"m.{n}" for n in p_names] + [f"v.{n}" for n in p_names]
            + ["step", "lr", "tokens", "old_logp", "adv", "mask"],
            [f"p.{n}" for n in p_names] + [f"m.{n}" for n in p_names]
            + [f"v.{n}" for n in p_names] + ["loss", "entropy", "grad_norm"],
        ),
    }


def _describe(specs, names):
    return [
        {"name": n, "shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}
        for n, s in zip(names, specs)
    ]


def _out_specs(fn, in_specs):
    return jax.eval_shape(fn, *in_specs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: <repo>/artifacts)")
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of entries to lower")
    args = ap.parse_args()

    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(os.path.abspath(args.out))
    if out_dir is None:
        out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    out_dir = os.path.abspath(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    entries = {}
    for name, (fn, in_specs, in_names, out_names) in _entry_defs().items():
        if only and name not in only:
            continue
        print(f"[aot] lowering {name} ({len(in_specs)} inputs)...", flush=True)
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.tree_util.tree_leaves(_out_specs(fn, in_specs))
        entries[name] = {
            "file": fname,
            "inputs": _describe(in_specs, in_names),
            "outputs": _describe(out_specs, out_names),
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"[aot]   wrote {fname}: {len(text)} chars", flush=True)

    # Initial parameters for the Rust side (raw f32 little-endian concat).
    params = model.init_params(seed=0)
    with open(os.path.join(out_dir, "params.init.bin"), "wb") as f:
        for p in params:
            f.write(np.asarray(p, "<f4").tobytes())

    manifest = {
        "model": S.to_dict(),
        "param_layout": [
            {"name": n, "shape": list(shape)} for n, shape in model.param_layout(S)
        ],
        "entries": entries,
    }
    # Merge with an existing manifest when lowering a subset.
    mpath = os.path.join(out_dir, "manifest.json")
    if only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["entries"].update(entries)
        manifest["entries"] = old["entries"]
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest + params written to {out_dir}", flush=True)


if __name__ == "__main__":
    sys.exit(main())
