//! Fault plane: cluster-level failure injection (§3.1, §8).
//!
//! The paper's headline production claim is *robustness* — a week-long
//! >3,000-GPU run riding through node failures, inference-engine
//! crashes, env-worker deaths and serverless stragglers.  This module
//! models those cluster-level faults as first-class simulation inputs:
//!
//! * **stochastic engine failures** — each inference engine fails with
//!   an exponential MTBF ([`FaultProfile::engine_mtbf_s`]) and comes
//!   back after [`FaultProfile::engine_recovery_s`] (node reboot +
//!   engine relaunch + weight reload);
//! * **env-worker crashes** — a container dies mid-trajectory with
//!   probability [`FaultProfile::env_crash_p`] per `env.step`, detected
//!   after [`FaultProfile::env_crash_detect_s`];
//! * **serverless stragglers** — a reward invocation lands on a slow
//!   sandbox with probability [`FaultProfile::straggler_p`] and runs
//!   [`FaultProfile::straggler_factor`]× longer;
//! * **scheduled faults** ([`ScheduledFault`]) — deterministic chaos
//!   events (kill one engine, take out a fraction of a GPU-class pool,
//!   restore it) for reproducible chaos experiments such as
//!   `examples/chaos_train.rs`.
//!
//! All stochastic draws come from dedicated [`SimRng`] streams salted
//! with [`FaultProfile::seed_salt`] (the salted-stream convention —
//! see `docs/DETERMINISM.md` for the full seeding contract), so
//! enabling injection never perturbs the draws of any other component
//! — and with the profile inactive no fault stream is ever sampled,
//! making injection *zero-cost when off*.
//!
//! The drivers surface the outcome in a [`FaultReport`]: failure
//! counts, trajectory-level recoveries (re-queued requests, relaunched
//! group members) and recovery latency.  Together with
//! [`crate::sim::ScenarioResult::goodput`] these are the §8 robustness
//! metrics.

use crate::hw::GpuClass;
use crate::simkit::SimRng;

/// One deterministic chaos event.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// Kill one engine by index; it auto-recovers after
    /// `engine_recovery_s`.
    EngineCrash { engine: usize },
    /// Take out `fraction` of the currently-live engines of `class`.
    /// They stay down until a [`FaultEvent::PoolRestore`] (or, with an
    /// elastic controller, until replacement capacity is provisioned).
    PoolOutage { class: GpuClass, fraction: f64 },
    /// Bring every downed engine of `class` back up.
    PoolRestore { class: GpuClass },
}

/// A chaos event pinned to a simulation time.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduledFault {
    pub at_s: f64,
    pub event: FaultEvent,
}

/// Cluster-level failure model for one scenario.
///
/// [`FaultProfile::none`] (the [`Default`]) disables every mechanism;
/// drivers skip all fault sampling in that case so results are
/// bit-identical to a build without the fault plane.
///
/// # Writing your own chaos profile
///
/// Compose the stochastic knobs with a deterministic schedule.  A
/// profile that crashes engines every ~10 simulated minutes, kills 2%
/// of env steps, and takes half the H20 pool out for one minute at
/// t = 300 s:
///
/// ```
/// use rollart::fault::{FaultEvent, FaultProfile, ScheduledFault};
/// use rollart::hw::GpuClass;
/// use rollart::simkit::SimRng;
///
/// let profile = FaultProfile {
///     env_crash_p: 0.02,
///     scheduled: vec![
///         ScheduledFault {
///             at_s: 300.0,
///             event: FaultEvent::PoolOutage { class: GpuClass::H20, fraction: 0.5 },
///         },
///         ScheduledFault {
///             at_s: 360.0,
///             event: FaultEvent::PoolRestore { class: GpuClass::H20 },
///         },
///     ],
///     ..FaultProfile::mtbf(600.0)
/// };
/// assert!(profile.is_active());
///
/// // Failure draws are pure functions of (root seed, salt, entity,
/// // occurrence): the same schedule replays exactly, run after run.
/// let root = SimRng::new(17);
/// let a = profile.next_engine_failure(&root, 0, 0).unwrap();
/// let b = profile.next_engine_failure(&root, 0, 0).unwrap();
/// assert_eq!(a, b);
///
/// // A different salt replays an *independent* failure pattern on
/// // the same scenario seed (A/B chaos ablations).
/// let salted = FaultProfile { seed_salt: 1, ..profile.clone() };
/// assert_ne!(a, salted.next_engine_failure(&root, 0, 0).unwrap());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct FaultProfile {
    /// Per-engine exponential mean time between failures, seconds.
    /// `None` disables stochastic engine failures.
    pub engine_mtbf_s: Option<f64>,
    /// Downtime of a crashed engine before it rejoins the fleet.
    pub engine_recovery_s: f64,
    /// Probability one `env.step` kills its environment worker.
    pub env_crash_p: f64,
    /// Latency until a dead env worker is detected (health-check
    /// interval + grace period).
    pub env_crash_detect_s: f64,
    /// Probability a serverless reward invocation straggles.
    pub straggler_p: f64,
    /// Execution-time multiplier of a straggling invocation.
    pub straggler_factor: f64,
    /// Deterministic chaos schedule.
    pub scheduled: Vec<ScheduledFault>,
    /// Salt mixed into every fault stream index, so two profiles on the
    /// same scenario seed draw independent failure patterns (see the
    /// seeding convention in [`crate::simkit`]).
    pub seed_salt: u64,
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile::none()
    }
}

impl FaultProfile {
    /// No faults; injection paths are never sampled.
    pub fn none() -> Self {
        FaultProfile {
            engine_mtbf_s: None,
            engine_recovery_s: 120.0,
            env_crash_p: 0.0,
            env_crash_detect_s: 10.0,
            straggler_p: 0.0,
            straggler_factor: 10.0,
            scheduled: Vec::new(),
            seed_salt: 0,
        }
    }

    /// Stochastic engine failures at the given MTBF, defaults elsewhere
    /// (the knob the MTBF-sweep bench turns).
    pub fn mtbf(engine_mtbf_s: f64) -> Self {
        assert!(engine_mtbf_s > 0.0);
        FaultProfile {
            engine_mtbf_s: Some(engine_mtbf_s),
            ..FaultProfile::none()
        }
    }

    /// Is any injection mechanism enabled?
    pub fn is_active(&self) -> bool {
        self.engine_mtbf_s.is_some()
            || self.env_crash_p > 0.0
            || self.straggler_p > 0.0
            || !self.scheduled.is_empty()
    }

    /// Derive the fault stream for `(label, index)` from the scenario
    /// root RNG, salted by this profile.
    pub fn stream(&self, root: &SimRng, label: &str, index: u64) -> SimRng {
        root.stream(label, index ^ self.seed_salt)
    }

    /// Seconds until the `nth` failure of `engine` (exponential
    /// interarrival), or `None` when stochastic engine failures are
    /// disabled.  A pure function of (root seed, salt, engine, nth) so
    /// failure patterns replay exactly.
    pub fn next_engine_failure(&self, root: &SimRng, engine: usize, nth: u64) -> Option<f64> {
        let mtbf = self.engine_mtbf_s?;
        // A non-positive MTBF would fire zero-delay crashes forever
        // without advancing the sim clock: fail loudly instead.
        assert!(
            mtbf > 0.0 && mtbf.is_finite(),
            "engine_mtbf_s must be positive and finite, got {mtbf}"
        );
        let idx = (engine as u64).wrapping_mul(1_000_003).wrapping_add(nth);
        let mut r = self.stream(root, "fault/engine", idx);
        Some(exp_sample(mtbf, &mut r))
    }

    /// Does the `turn`-th `env.step` of manager `mgr` crash its worker?
    pub fn env_step_crashes(&self, root: &SimRng, mgr: usize, turn: usize) -> bool {
        if self.env_crash_p <= 0.0 {
            return false;
        }
        let idx = (mgr as u64).wrapping_mul(1_000_003).wrapping_add(turn as u64);
        let mut r = self.stream(root, "fault/envstep", idx);
        r.chance(self.env_crash_p)
    }

    /// Does reward invocation `index` straggle?  Returns the execution
    /// multiplier (1.0 = no straggle).
    pub fn reward_multiplier(&self, root: &SimRng, index: u64) -> f64 {
        if self.straggler_p <= 0.0 {
            return 1.0;
        }
        let mut r = self.stream(root, "fault/straggler", index);
        if r.chance(self.straggler_p) {
            self.straggler_factor
        } else {
            1.0
        }
    }
}

/// Exponential sample with the given mean.
pub fn exp_sample(mean: f64, rng: &mut SimRng) -> f64 {
    let u = (1.0 - rng.f64()).max(1e-12); // (0, 1]
    -mean * u.ln()
}

/// What the fault plane did to one scenario run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultReport {
    /// Engine crashes (stochastic + scheduled, incl. pool outages).
    pub engine_failures: u64,
    /// Env workers that died mid-trajectory.
    pub env_crashes: u64,
    /// Reward invocations that straggled.
    pub reward_stragglers: u64,
    /// Re-queue *operations*: in-flight generation requests drained
    /// off dead engines and re-dispatched (trajectory-level recovery:
    /// work replayed, trajectory kept).  A request that bounces across
    /// cascading failures — re-dispatched onto an engine a later fault
    /// kills — counts once per bounce, so this can exceed the number
    /// of distinct requests recovered.
    pub requeued_requests: u64,
    /// Trajectories relaunched into their GRPO group after an env
    /// crash (§6.3 backfill).
    pub trajectories_relaunched: u64,
    /// Completed engine recoveries (auto-recovery or pool restore).
    pub recoveries: u64,
    /// Total downtime over completed recoveries.
    pub recovery_latency_s: f64,
}

impl FaultReport {
    /// Mean engine downtime per completed recovery.
    pub fn mean_recovery_latency_s(&self) -> f64 {
        if self.recoveries == 0 {
            0.0
        } else {
            self.recovery_latency_s / self.recoveries as f64
        }
    }

    pub fn merge(&mut self, other: &FaultReport) {
        self.engine_failures += other.engine_failures;
        self.env_crashes += other.env_crashes;
        self.reward_stragglers += other.reward_stragglers;
        self.requeued_requests += other.requeued_requests;
        self.trajectories_relaunched += other.trajectories_relaunched;
        self.recoveries += other.recoveries;
        self.recovery_latency_s += other.recovery_latency_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_is_inactive() {
        let p = FaultProfile::none();
        assert!(!p.is_active());
        assert_eq!(p, FaultProfile::default());
        let root = SimRng::new(7);
        assert_eq!(p.next_engine_failure(&root, 0, 0), None);
        assert!(!p.env_step_crashes(&root, 0, 0));
        assert_eq!(p.reward_multiplier(&root, 0), 1.0);
    }

    #[test]
    fn mtbf_profile_is_active_and_deterministic() {
        let p = FaultProfile::mtbf(600.0);
        assert!(p.is_active());
        let root = SimRng::new(7);
        let a = p.next_engine_failure(&root, 3, 0).unwrap();
        let b = p.next_engine_failure(&root, 3, 0).unwrap();
        assert_eq!(a, b, "same (engine, nth) replays exactly");
        let c = p.next_engine_failure(&root, 3, 1).unwrap();
        assert_ne!(a, c, "successive failures draw fresh interarrivals");
        assert!(a > 0.0);
    }

    #[test]
    fn seed_salt_changes_failure_pattern_only() {
        let a = FaultProfile::mtbf(600.0);
        let mut b = FaultProfile::mtbf(600.0);
        b.seed_salt = 99;
        let root = SimRng::new(7);
        assert_ne!(
            a.next_engine_failure(&root, 0, 0),
            b.next_engine_failure(&root, 0, 0)
        );
    }

    #[test]
    fn exp_sample_mean_roughly_matches() {
        let mut rng = SimRng::new(11);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| exp_sample(50.0, &mut rng)).sum::<f64>() / n as f64;
        assert!((m - 50.0).abs() < 2.5, "{m}");
    }

    #[test]
    fn env_crash_rate_roughly_matches() {
        let mut p = FaultProfile::none();
        p.env_crash_p = 0.1;
        let root = SimRng::new(3);
        let hits = (0..10_000)
            .filter(|&i| p.env_step_crashes(&root, i, 0))
            .count();
        assert!((800..1200).contains(&hits), "{hits}");
    }

    #[test]
    fn scheduled_faults_activate_profile() {
        let mut p = FaultProfile::none();
        p.scheduled.push(ScheduledFault {
            at_s: 100.0,
            event: FaultEvent::PoolOutage {
                class: GpuClass::H20,
                fraction: 0.25,
            },
        });
        assert!(p.is_active());
    }

    #[test]
    fn report_merge_and_mean_latency() {
        let mut a = FaultReport {
            engine_failures: 2,
            recoveries: 2,
            recovery_latency_s: 60.0,
            ..FaultReport::default()
        };
        let b = FaultReport {
            engine_failures: 1,
            recoveries: 1,
            recovery_latency_s: 30.0,
            ..FaultReport::default()
        };
        a.merge(&b);
        assert_eq!(a.engine_failures, 3);
        assert!((a.mean_recovery_latency_s() - 30.0).abs() < 1e-12);
        assert_eq!(FaultReport::default().mean_recovery_latency_s(), 0.0);
    }
}
