//! Production workload trace plane (§8, Fig 15): generator, streaming
//! source, open-loop arrival processes, and multi-tenant SLO types.
//!
//! The paper reports a week-long >3,000-GPU MoE deployment; the trace
//! generator reproduces its published statistics so Fig 15 can be
//! regenerated: prompts to 12k tokens, responses to 46k, 1–48 mean
//! turns per task family, per-step max response > 5× mean (peak 9×),
//! max turns > 40× mean, 1:5 train:generation GPU ratio, blocking
//! `get_batch` up to 62% of iteration time, longest iteration 1.5 h.
//!
//! Beyond offline analysis, the trace is a first-class *scenario
//! source*: a [`TraceSource`] streams records one at a time (constant
//! memory — no materialized `Vec`), an [`ArrivalProcess`] turns them
//! into open-loop arrival times, and [`Scenario::trace`] feeds them
//! into the DES driver via `Ev::TraceArrival`.  Per-domain latency
//! targets ([`SloPolicy`]) produce an [`SloReport`] on
//! [`ScenarioResult`](crate::sim::ScenarioResult).
//!
//! [`Scenario::trace`]: crate::sim::Scenario

use crate::env::profile::TrajectoryShape;
use crate::env::TaskDomain;
use crate::metrics::Histogram;
use crate::simkit::dist::Dist;
use crate::simkit::SimRng;

/// One production task family's shape (anonymized, after §8).
#[derive(Clone, Debug)]
pub struct FamilyProfile {
    pub name: &'static str,
    pub turns: Dist,
    pub prompt_tokens: Dist,
    pub response_tokens: Dist,
    /// Fraction of the job's trajectories from this family.
    pub weight: f64,
    /// Nearest Table-1 task domain — the tenant this family bills to
    /// in multi-tenant SLO reports and PD/affinity routing.
    pub domain: TaskDomain,
}

/// The §8 mix: in-house mathematical + software-engineering agentic
/// tasks on a hundreds-of-billions-parameter MoE.
pub fn prod_families() -> Vec<FamilyProfile> {
    vec![
        FamilyProfile {
            name: "math-short",
            turns: Dist::Uniform { lo: 1.0, hi: 3.0 },
            prompt_tokens: Dist::lognormal_median(900.0, 0.5),
            // long chains of thought; tail controlled below 46k
            response_tokens: Dist::lognormal_median(4000.0, 0.8),
            weight: 0.45,
            domain: TaskDomain::GameSingle,
        },
        FamilyProfile {
            name: "math-tool",
            turns: Dist::Uniform { lo: 2.0, hi: 8.0 },
            prompt_tokens: Dist::lognormal_median(1500.0, 0.5),
            response_tokens: Dist::lognormal_median(2500.0, 0.7),
            weight: 0.25,
            domain: TaskDomain::MathTool,
        },
        FamilyProfile {
            name: "swe-agent",
            turns: Dist::Uniform { lo: 12.0, hi: 48.0 },
            prompt_tokens: Dist::lognormal_median(6000.0, 0.5),
            response_tokens: Dist::lognormal_median(1200.0, 0.6),
            weight: 0.30,
            domain: TaskDomain::Swe,
        },
    ]
}

/// One sampled trajectory record.
#[derive(Clone, Copy, Debug)]
pub struct TraceRecord {
    pub family: usize,
    pub turns: usize,
    pub prompt_tokens: f64,
    pub response_tokens: f64,
}

/// Weighted family pick.  `pick` is uniform in `[0, total_w)`; float
/// roundoff in the decrement chain can let it survive every comparison
/// (e.g. when `pick` rounds to `total_w` itself, or the partial sums
/// round upward), in which case the leftover probability mass belongs
/// to the *last* family, not the first.
fn pick_family(families: &[FamilyProfile], mut pick: f64) -> usize {
    let mut fi = families.len() - 1;
    for (i, f) in families.iter().enumerate() {
        if pick < f.weight {
            fi = i;
            break;
        }
        pick -= f.weight;
    }
    fi
}

/// Sample one record.  Draw order (family pick, turns, prompt,
/// response) is part of the determinism contract: [`generate`] and
/// [`TraceSource`] share this function, which is what pins the
/// streamed replay bit-identical to the materialized one.
fn sample_record(families: &[FamilyProfile], total_w: f64, rng: &mut SimRng) -> TraceRecord {
    let fi = pick_family(families, rng.f64() * total_w);
    let f = &families[fi];
    TraceRecord {
        family: fi,
        turns: f.turns.sample(rng).round().max(1.0) as usize,
        prompt_tokens: f.prompt_tokens.sample(rng).min(12_000.0),
        response_tokens: f.response_tokens.sample(rng).min(46_000.0),
    }
}

/// A streaming trace: an *infinite* iterator of [`TraceRecord`]s drawn
/// from the family mix, one at a time, in constant memory.  The n-th
/// record equals `generate(families, m, seed)[n]` for any `m > n` —
/// the two share [`sample_record`] — so a driver fed by `take(n)` is
/// bit-identical to one fed the materialized `Vec`.
#[derive(Clone, Debug)]
pub struct TraceSource {
    families: Vec<FamilyProfile>,
    total_w: f64,
    rng: SimRng,
}

impl TraceSource {
    pub fn new(families: &[FamilyProfile], seed: u64) -> TraceSource {
        assert!(!families.is_empty(), "trace needs at least one family");
        TraceSource {
            families: families.to_vec(),
            total_w: families.iter().map(|f| f.weight).sum(),
            rng: SimRng::new(seed),
        }
    }
}

impl Iterator for TraceSource {
    type Item = TraceRecord;

    fn next(&mut self) -> Option<TraceRecord> {
        Some(sample_record(&self.families, self.total_w, &mut self.rng))
    }
}

/// Generate `n` trajectory records from the family mix (materialized
/// form of [`TraceSource`]).
pub fn generate(families: &[FamilyProfile], n: usize, seed: u64) -> Vec<TraceRecord> {
    TraceSource::new(families, seed).take(n).collect()
}

/// Fig 15a-style statistics of a trace.
#[derive(Clone, Debug)]
pub struct TraceStats {
    pub mean_turns: f64,
    pub max_turns: usize,
    pub mean_response: f64,
    pub max_response: f64,
    pub max_prompt: f64,
    /// max/mean straggler ratios (§8: response >5×, turns >40×).
    pub response_tail_ratio: f64,
    pub turns_tail_ratio: f64,
}

pub fn analyze(trace: &[TraceRecord]) -> TraceStats {
    assert!(!trace.is_empty());
    let n = trace.len() as f64;
    let mean_turns = trace.iter().map(|t| t.turns as f64).sum::<f64>() / n;
    let max_turns = trace.iter().map(|t| t.turns).max().unwrap();
    let mean_response = trace.iter().map(|t| t.response_tokens).sum::<f64>() / n;
    let max_response = trace
        .iter()
        .map(|t| t.response_tokens)
        .fold(0.0, f64::max);
    let max_prompt = trace.iter().map(|t| t.prompt_tokens).fold(0.0, f64::max);
    TraceStats {
        mean_turns,
        max_turns,
        mean_response,
        max_response,
        max_prompt,
        response_tail_ratio: max_response / mean_response,
        turns_tail_ratio: max_turns as f64 / mean_turns,
    }
}

/// Per-step straggler ratios over steps of `step_size` trajectories
/// (the §8 "in each step, max response exceeds 5× the mean" claim).
///
/// The trailing partial step is included — a trace shorter than one
/// step still yields one ratio (over however many records it has), so
/// callers averaging the result never divide by zero.
pub fn per_step_tail_ratios(trace: &[TraceRecord], step_size: usize) -> Vec<f64> {
    trace
        .chunks(step_size)
        .map(|c| {
            let mean = c.iter().map(|t| t.response_tokens).sum::<f64>() / c.len() as f64;
            let max = c.iter().map(|t| t.response_tokens).fold(0.0, f64::max);
            max / mean
        })
        .collect()
}

/// Distribution of response lengths (Fig 15a histogram input).
pub fn response_histogram(trace: &[TraceRecord]) -> Histogram {
    let mut h = Histogram::new();
    for t in trace {
        h.record(t.response_tokens);
    }
    h
}

/// Convert a trace record into the driver's trajectory shape: the
/// prompt prefills on turn 0, the response decodes evenly across the
/// record's turns.  Purely arithmetic — no RNG — so a record maps to
/// the same shape on every path (streamed or materialized).
pub fn record_shape(r: &TraceRecord, domain: TaskDomain) -> TrajectoryShape {
    let turns = r.turns.max(1);
    let act = (r.response_tokens / turns as f64).max(1.0);
    TrajectoryShape {
        domain,
        initial_prompt_tokens: r.prompt_tokens,
        per_turn: vec![(0.0, act); turns],
    }
}

/// Open-loop arrival process over a trace (StreamRL-style evaluation:
/// arrivals do not wait for completions the way closed-loop admission
/// does).
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at `rate` requests/s.
    Poisson { rate: f64 },
    /// Non-homogeneous Poisson with a sinusoidal day/night cycle:
    /// instantaneous rate `base_rate · (1 + amplitude·sin(2πt/period))`
    /// (amplitude clamped to [0, 0.999]); sampled by thinning.
    Diurnal {
        base_rate: f64,
        amplitude: f64,
        period_s: f64,
    },
    /// On/off bursts: exponential on-periods of mean `mean_on_s` with
    /// Poisson arrivals at `on_rate`, separated by exponential silences
    /// of mean `mean_off_s`.  The process starts in an on-period.
    Bursty {
        on_rate: f64,
        mean_on_s: f64,
        mean_off_s: f64,
    },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate (requests/s) — sizing diagnostic.
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            // The sinusoid integrates to zero over a period.
            ArrivalProcess::Diurnal { base_rate, .. } => base_rate,
            ArrivalProcess::Bursty {
                on_rate,
                mean_on_s,
                mean_off_s,
            } => on_rate * mean_on_s / (mean_on_s + mean_off_s),
        }
    }
}

/// Runtime state of an [`ArrivalProcess`]: owns a dedicated RNG stream
/// (see `docs/DETERMINISM.md`) plus the bursty on/off phase machine.
#[derive(Clone, Debug)]
pub struct Arrivals {
    process: ArrivalProcess,
    rng: SimRng,
    /// Bursty state: inside an on-period, and when the phase flips.
    on: bool,
    phase_until: f64,
}

impl Arrivals {
    pub fn new(process: ArrivalProcess, rng: SimRng) -> Arrivals {
        Arrivals {
            process,
            rng,
            on: false,
            phase_until: 0.0,
        }
    }

    fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.rng.f64()).ln() / rate
    }

    /// Seconds from `now` until the next arrival.
    pub fn next_gap(&mut self, now: f64) -> f64 {
        match self.process {
            ArrivalProcess::Poisson { rate } => self.exp(rate),
            ArrivalProcess::Diurnal {
                base_rate,
                amplitude,
                period_s,
            } => {
                let amp = amplitude.clamp(0.0, 0.999);
                let max_rate = base_rate * (1.0 + amp);
                let mut t = now;
                // Thinning: candidate arrivals at the peak rate, kept
                // with probability rate(t)/max_rate.  Acceptance is at
                // least (1-amp)/(1+amp) > 0, so the loop terminates.
                loop {
                    t += self.exp(max_rate);
                    let rate = base_rate
                        * (1.0 + amp * (std::f64::consts::TAU * t / period_s).sin());
                    if self.rng.f64() * max_rate <= rate {
                        return t - now;
                    }
                }
            }
            ArrivalProcess::Bursty {
                on_rate,
                mean_on_s,
                mean_off_s,
            } => {
                let mut t = now;
                loop {
                    if !self.on {
                        t = self.phase_until.max(t);
                        self.on = true;
                        self.phase_until = t + self.exp(1.0 / mean_on_s);
                    }
                    let gap = self.exp(on_rate);
                    if t + gap <= self.phase_until {
                        return t + gap - now;
                    }
                    t = self.phase_until;
                    self.on = false;
                    self.phase_until = t + self.exp(1.0 / mean_off_s);
                }
            }
        }
    }
}

/// How the driver pulls trace records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFeed {
    /// Pull one record per arrival from a [`TraceSource`] — constant
    /// memory, the production path.
    Streamed,
    /// Materialize the whole trace up front ([`generate`]) — the
    /// reference path the streamed one is pinned bit-identical to.
    Materialized,
}

/// Trace-replay scenario source (`Scenario::trace`): when set, the
/// driver replaces closed-loop admission with open-loop arrivals drawn
/// from this trace.
#[derive(Clone, Debug)]
pub struct TraceScenario {
    pub families: Vec<FamilyProfile>,
    /// Requests to replay (the run drains after the last arrival).
    pub requests: u64,
    pub arrivals: ArrivalProcess,
    pub feed: TraceFeed,
    /// Seed of the trace's own RNG (separate from `Scenario::seed`, so
    /// the same trace can be replayed under different system seeds).
    pub trace_seed: u64,
}

impl TraceScenario {
    /// The §8 production mix, streamed, Poisson arrivals at `rate`/s.
    pub fn section8(requests: u64, rate: f64) -> TraceScenario {
        TraceScenario {
            families: prod_families(),
            requests,
            arrivals: ArrivalProcess::Poisson { rate },
            feed: TraceFeed::Streamed,
            trace_seed: 8,
        }
    }
}

/// Per-domain latency targets and the admission backstop
/// (`Scenario::slo`).
#[derive(Clone, Debug)]
pub struct SloPolicy {
    /// Target for domains without an explicit entry (default ∞: report
    /// latencies, count no violations).
    pub default_target_s: f64,
    /// (domain, end-to-end trajectory latency target in seconds).
    pub targets: Vec<(TaskDomain, f64)>,
    /// Load shedding: reject arrivals while this many trajectories are
    /// already in flight (None = admit everything).
    pub shed_above: Option<usize>,
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy {
            default_target_s: f64::INFINITY,
            targets: Vec::new(),
            shed_above: None,
        }
    }
}

impl SloPolicy {
    pub fn target_for(&self, d: TaskDomain) -> f64 {
        self.targets
            .iter()
            .find(|(td, _)| *td == d)
            .map(|(_, t)| *t)
            .unwrap_or(self.default_target_s)
    }
}

/// One tenant's row in the [`SloReport`].
#[derive(Clone, Debug, PartialEq)]
pub struct DomainSlo {
    pub domain: TaskDomain,
    /// Trajectories deposited into training batches.
    pub completed: u64,
    pub target_s: f64,
    /// End-to-end trajectory latency (arrival → deposit) quantiles.
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
    /// Sum of completed-trajectory latencies — reconciles with the
    /// lifecycle tracker's residency totals (the phase dwells of a
    /// deposited trajectory telescope to exactly its latency).
    pub total_latency_s: f64,
    /// Completions slower than `target_s`.
    pub violations: u64,
}

/// Multi-tenant SLO outcome of a trace replay, attached to
/// [`ScenarioResult::slo`](crate::sim::ScenarioResult::slo).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SloReport {
    /// Per-domain rows, ordered by [`TaskDomain::ALL`].
    pub domains: Vec<DomainSlo>,
    /// Arrivals offered by the trace.
    pub offered: u64,
    /// Arrivals admitted (offered − shed).
    pub admitted: u64,
    /// Arrivals rejected by the `shed_above` backstop.
    pub shed: u64,
    /// Trajectories deposited into training batches.
    pub completed: u64,
    /// Admitted trajectories aborted before deposit (stale/crash).
    pub aborted: u64,
    /// Sum of aborted-trajectory latencies (arrival → abort) — the
    /// non-completed share of lifecycle residency, kept so residency
    /// reconciliation also holds under chaos.
    pub aborted_latency_s: f64,
    /// Completed trajectories per wall-clock second — goodput under
    /// load shedding (shed and aborted requests don't count).
    pub goodput_rps: f64,
    pub total_violations: u64,
}

/// Feed-side replay statistics, returned by
/// [`run_trace_replay`](crate::sim::driver::core::run_trace_replay)
/// next to the scenario result.  `peak_records_buffered` is the
/// constant-memory proof the `fig_trace` bench gates on: a streamed
/// replay holds at most one record in hand regardless of trace length,
/// while a materialized replay buffers the whole trace.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceReplayStats {
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
    pub peak_records_buffered: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> Vec<TraceRecord> {
        generate(&prod_families(), 20_000, 8)
    }

    #[test]
    fn token_bounds_match_section8() {
        let s = analyze(&trace());
        assert!(s.max_prompt <= 12_000.0);
        assert!(s.max_response <= 46_000.0);
        assert!(s.max_response > 30_000.0, "{}", s.max_response);
    }

    #[test]
    fn turn_range_1_to_48() {
        let t = trace();
        assert!(t.iter().all(|r| (1..=48).contains(&r.turns)));
        let s = analyze(&t);
        assert!(s.max_turns >= 40, "{}", s.max_turns);
    }

    #[test]
    fn straggler_ratios_match_section8() {
        // §8: per-step max response > 5× mean, peaking ~9×.
        let ratios = per_step_tail_ratios(&trace(), 512);
        let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;
        let peak = ratios.iter().cloned().fold(0.0, f64::max);
        assert!(mean_ratio > 3.0, "mean tail ratio {mean_ratio}");
        assert!(peak > 6.0, "peak tail ratio {peak}");
        assert!(peak < 20.0, "peak tail ratio {peak}");
    }

    #[test]
    fn family_mix_respected() {
        let t = trace();
        let swe = t.iter().filter(|r| r.family == 2).count() as f64 / t.len() as f64;
        assert!((swe - 0.30).abs() < 0.02, "{swe}");
    }

    #[test]
    fn deterministic() {
        let a = generate(&prod_families(), 100, 1);
        let b = generate(&prod_families(), 100, 1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.turns, y.turns);
            assert_eq!(x.response_tokens, y.response_tokens);
        }
    }

    #[test]
    fn histogram_works() {
        let mut h = response_histogram(&trace());
        assert!(h.p99() > h.p50());
    }

    // Regression (bugfix 1): when float roundoff lets the pick survive
    // every decrement, the leftover mass must land on the LAST family,
    // not fall through to index 0.
    #[test]
    fn weighted_pick_boundary_lands_on_last_family() {
        let fams = prod_families();
        let total_w: f64 = fams.iter().map(|f| f.weight).sum();
        // The epsilon case: rng.f64() close enough to 1 that
        // `rng.f64() * total_w` rounds to total_w itself, surviving
        // every decrement.  The old code returned 0 here.
        assert_eq!(pick_family(&fams, total_w), fams.len() - 1);
        assert_eq!(pick_family(&fams, total_w * (1.0 - 1e-17)), fams.len() - 1);
        // Interior picks still map to their own families.
        assert_eq!(pick_family(&fams, 0.0), 0);
        assert_eq!(pick_family(&fams, 0.44), 0);
        assert_eq!(pick_family(&fams, 0.46), 1);
        assert_eq!(pick_family(&fams, 0.71), 2);
    }

    // Regression (bugfix 1), seeded flavor: a crafted mix whose float
    // weight sum exceeds the last cumulative boundary, so seeds that
    // draw near 1.0 land in the final epsilon.  Every record must
    // carry a valid family index and the last family must receive its
    // share (the old code silently re-billed that mass to family 0).
    #[test]
    fn weighted_pick_seeded_epsilon_mass_reaches_last_family() {
        let base = prod_families();
        // 10×0.1 sums to 0.9999999999999999 ≠ 1.0: the cumulative
        // decrement chain and the float total disagree in the last ulp.
        let fams: Vec<FamilyProfile> = (0..10)
            .map(|i| {
                let mut f = base[i % base.len()].clone();
                f.weight = 0.1;
                f
            })
            .collect();
        for seed in 0..32 {
            let t = generate(&fams, 2_000, seed);
            assert!(t.iter().all(|r| r.family < fams.len()));
            let last = t.iter().filter(|r| r.family == fams.len() - 1).count();
            assert!(last > 0, "seed {seed}: last family starved");
        }
    }

    // Regression (bugfix 2): a trace shorter than one step must yield
    // one finite ratio, not an empty vec (the NaN the example hit).
    #[test]
    fn tail_ratios_include_trailing_partial_step() {
        let t = trace();
        let short = &t[..100];
        let ratios = per_step_tail_ratios(short, 512);
        assert_eq!(ratios.len(), 1);
        assert!(ratios[0].is_finite() && ratios[0] >= 1.0, "{}", ratios[0]);
        // 20_000 = 39×512 + 32: the partial step is a 40th ratio.
        let full = per_step_tail_ratios(&t, 512);
        assert_eq!(full.len(), t.len().div_ceil(512));
        assert!(full.iter().all(|r| r.is_finite() && *r >= 1.0));
    }

    #[test]
    fn streamed_source_matches_materialized_generate() {
        let streamed: Vec<TraceRecord> =
            TraceSource::new(&prod_families(), 8).take(5_000).collect();
        let materialized = generate(&prod_families(), 5_000, 8);
        for (s, m) in streamed.iter().zip(&materialized) {
            assert_eq!(s.family, m.family);
            assert_eq!(s.turns, m.turns);
            assert_eq!(s.prompt_tokens, m.prompt_tokens);
            assert_eq!(s.response_tokens, m.response_tokens);
        }
    }

    #[test]
    fn record_shape_conserves_tokens() {
        for r in trace().iter().take(500) {
            let shape = record_shape(r, TaskDomain::Swe);
            assert_eq!(shape.turns(), r.turns);
            assert_eq!(shape.initial_prompt_tokens, r.prompt_tokens);
            let decode = shape.decode_tokens();
            assert!(
                (decode - r.response_tokens).abs() <= r.turns as f64,
                "decode {decode} vs response {}",
                r.response_tokens
            );
        }
    }

    #[test]
    fn poisson_gaps_have_the_right_mean() {
        let mut a = Arrivals::new(ArrivalProcess::Poisson { rate: 4.0 }, SimRng::new(7));
        let n = 20_000;
        let total: f64 = (0..n).map(|_| a.next_gap(0.0)).sum();
        let mean = total / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean gap {mean}");
    }

    #[test]
    fn diurnal_rate_tracks_the_sinusoid() {
        let p = ArrivalProcess::Diurnal {
            base_rate: 10.0,
            amplitude: 0.8,
            period_s: 1_000.0,
        };
        let mut a = Arrivals::new(p, SimRng::new(9));
        let (mut t, mut peak_half, mut trough_half) = (0.0, 0u64, 0u64);
        while t < 10_000.0 {
            t += a.next_gap(t);
            // sin > 0 on the first half of each period.
            if (t % 1_000.0) < 500.0 {
                peak_half += 1;
            } else {
                trough_half += 1;
            }
        }
        assert!(
            peak_half as f64 > 1.5 * trough_half as f64,
            "peak {peak_half} trough {trough_half}"
        );
    }

    #[test]
    fn bursty_arrivals_cluster() {
        let p = ArrivalProcess::Bursty {
            on_rate: 50.0,
            mean_on_s: 1.0,
            mean_off_s: 9.0,
        };
        let mut a = Arrivals::new(p, SimRng::new(3));
        let (mut t, mut gaps) = (0.0, Vec::new());
        for _ in 0..5_000 {
            let g = a.next_gap(t);
            gaps.push(g);
            t += g;
        }
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let long = gaps.iter().filter(|g| **g > 5.0 * mean).count();
        // Off-periods show up as rare gaps far above the on-rate gap.
        assert!(long > 10, "only {long} long gaps");
        let expected = 1.0 / p.mean_rate();
        assert!((mean - expected).abs() / expected < 0.25, "mean gap {mean}");
    }

    #[test]
    fn arrivals_are_deterministic() {
        for p in [
            ArrivalProcess::Poisson { rate: 2.0 },
            ArrivalProcess::Diurnal {
                base_rate: 2.0,
                amplitude: 0.5,
                period_s: 100.0,
            },
            ArrivalProcess::Bursty {
                on_rate: 10.0,
                mean_on_s: 2.0,
                mean_off_s: 5.0,
            },
        ] {
            let mut a = Arrivals::new(p.clone(), SimRng::new(11));
            let mut b = Arrivals::new(p, SimRng::new(11));
            let (mut ta, mut tb) = (0.0, 0.0);
            for _ in 0..1_000 {
                ta += a.next_gap(ta);
                tb += b.next_gap(tb);
                assert_eq!(ta.to_bits(), tb.to_bits());
            }
        }
    }

    #[test]
    fn slo_policy_targets_resolve_per_domain() {
        let slo = SloPolicy {
            default_target_s: 600.0,
            targets: vec![(TaskDomain::Swe, 1_800.0), (TaskDomain::MathTool, 300.0)],
            shed_above: Some(4_096),
        };
        assert_eq!(slo.target_for(TaskDomain::Swe), 1_800.0);
        assert_eq!(slo.target_for(TaskDomain::MathTool), 300.0);
        assert_eq!(slo.target_for(TaskDomain::Web), 600.0);
        let d = SloPolicy::default();
        assert!(d.target_for(TaskDomain::Game).is_infinite());
        assert!(d.shed_above.is_none());
    }
}
