//! Production workload trace generator + analyzer (§8, Fig 15).
//!
//! The paper reports a week-long >3,000-GPU MoE deployment; the trace
//! generator reproduces its published statistics so Fig 15 can be
//! regenerated: prompts to 12k tokens, responses to 46k, 1–48 mean
//! turns per task family, per-step max response > 5× mean (peak 9×),
//! max turns > 40× mean, 1:5 train:generation GPU ratio, blocking
//! `get_batch` up to 62% of iteration time, longest iteration 1.5 h.

use crate::metrics::Histogram;
use crate::simkit::dist::Dist;
use crate::simkit::SimRng;

/// One production task family's shape (anonymized, after §8).
#[derive(Clone, Debug)]
pub struct FamilyProfile {
    pub name: &'static str,
    pub turns: Dist,
    pub prompt_tokens: Dist,
    pub response_tokens: Dist,
    /// Fraction of the job's trajectories from this family.
    pub weight: f64,
}

/// The §8 mix: in-house mathematical + software-engineering agentic
/// tasks on a hundreds-of-billions-parameter MoE.
pub fn prod_families() -> Vec<FamilyProfile> {
    vec![
        FamilyProfile {
            name: "math-short",
            turns: Dist::Uniform { lo: 1.0, hi: 3.0 },
            prompt_tokens: Dist::lognormal_median(900.0, 0.5),
            // long chains of thought; tail controlled below 46k
            response_tokens: Dist::lognormal_median(4000.0, 0.8),
            weight: 0.45,
        },
        FamilyProfile {
            name: "math-tool",
            turns: Dist::Uniform { lo: 2.0, hi: 8.0 },
            prompt_tokens: Dist::lognormal_median(1500.0, 0.5),
            response_tokens: Dist::lognormal_median(2500.0, 0.7),
            weight: 0.25,
        },
        FamilyProfile {
            name: "swe-agent",
            turns: Dist::Uniform { lo: 12.0, hi: 48.0 },
            prompt_tokens: Dist::lognormal_median(6000.0, 0.5),
            response_tokens: Dist::lognormal_median(1200.0, 0.6),
            weight: 0.30,
        },
    ]
}

/// One sampled trajectory record.
#[derive(Clone, Copy, Debug)]
pub struct TraceRecord {
    pub family: usize,
    pub turns: usize,
    pub prompt_tokens: f64,
    pub response_tokens: f64,
}

/// Generate `n` trajectory records from the family mix.
pub fn generate(families: &[FamilyProfile], n: usize, seed: u64) -> Vec<TraceRecord> {
    let mut rng = SimRng::new(seed);
    let total_w: f64 = families.iter().map(|f| f.weight).sum();
    (0..n)
        .map(|_| {
            let mut pick = rng.f64() * total_w;
            let mut fi = 0;
            for (i, f) in families.iter().enumerate() {
                if pick < f.weight {
                    fi = i;
                    break;
                }
                pick -= f.weight;
            }
            let f = &families[fi];
            TraceRecord {
                family: fi,
                turns: f.turns.sample(&mut rng).round().max(1.0) as usize,
                prompt_tokens: f.prompt_tokens.sample(&mut rng).min(12_000.0),
                response_tokens: f.response_tokens.sample(&mut rng).min(46_000.0),
            }
        })
        .collect()
}

/// Fig 15a-style statistics of a trace.
#[derive(Clone, Debug)]
pub struct TraceStats {
    pub mean_turns: f64,
    pub max_turns: usize,
    pub mean_response: f64,
    pub max_response: f64,
    pub max_prompt: f64,
    /// max/mean straggler ratios (§8: response >5×, turns >40×).
    pub response_tail_ratio: f64,
    pub turns_tail_ratio: f64,
}

pub fn analyze(trace: &[TraceRecord]) -> TraceStats {
    assert!(!trace.is_empty());
    let n = trace.len() as f64;
    let mean_turns = trace.iter().map(|t| t.turns as f64).sum::<f64>() / n;
    let max_turns = trace.iter().map(|t| t.turns).max().unwrap();
    let mean_response = trace.iter().map(|t| t.response_tokens).sum::<f64>() / n;
    let max_response = trace
        .iter()
        .map(|t| t.response_tokens)
        .fold(0.0, f64::max);
    let max_prompt = trace.iter().map(|t| t.prompt_tokens).fold(0.0, f64::max);
    TraceStats {
        mean_turns,
        max_turns,
        mean_response,
        max_response,
        max_prompt,
        response_tail_ratio: max_response / mean_response,
        turns_tail_ratio: max_turns as f64 / mean_turns,
    }
}

/// Per-step straggler ratios over steps of `step_size` trajectories
/// (the §8 "in each step, max response exceeds 5× the mean" claim).
pub fn per_step_tail_ratios(trace: &[TraceRecord], step_size: usize) -> Vec<f64> {
    trace
        .chunks(step_size)
        .filter(|c| c.len() == step_size)
        .map(|c| {
            let mean = c.iter().map(|t| t.response_tokens).sum::<f64>() / c.len() as f64;
            let max = c.iter().map(|t| t.response_tokens).fold(0.0, f64::max);
            max / mean
        })
        .collect()
}

/// Distribution of response lengths (Fig 15a histogram input).
pub fn response_histogram(trace: &[TraceRecord]) -> Histogram {
    let mut h = Histogram::new();
    for t in trace {
        h.record(t.response_tokens);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace() -> Vec<TraceRecord> {
        generate(&prod_families(), 20_000, 8)
    }

    #[test]
    fn token_bounds_match_section8() {
        let s = analyze(&trace());
        assert!(s.max_prompt <= 12_000.0);
        assert!(s.max_response <= 46_000.0);
        assert!(s.max_response > 30_000.0, "{}", s.max_response);
    }

    #[test]
    fn turn_range_1_to_48() {
        let t = trace();
        assert!(t.iter().all(|r| (1..=48).contains(&r.turns)));
        let s = analyze(&t);
        assert!(s.max_turns >= 40, "{}", s.max_turns);
    }

    #[test]
    fn straggler_ratios_match_section8() {
        // §8: per-step max response > 5× mean, peaking ~9×.
        let ratios = per_step_tail_ratios(&trace(), 512);
        let mean_ratio = ratios.iter().sum::<f64>() / ratios.len() as f64;
        let peak = ratios.iter().cloned().fold(0.0, f64::max);
        assert!(mean_ratio > 3.0, "mean tail ratio {mean_ratio}");
        assert!(peak > 6.0, "peak tail ratio {peak}");
        assert!(peak < 20.0, "peak tail ratio {peak}");
    }

    #[test]
    fn family_mix_respected() {
        let t = trace();
        let swe = t.iter().filter(|r| r.family == 2).count() as f64 / t.len() as f64;
        assert!((swe - 0.30).abs() < 0.02, "{swe}");
    }

    #[test]
    fn deterministic() {
        let a = generate(&prod_families(), 100, 1);
        let b = generate(&prod_families(), 100, 1);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.turns, y.turns);
            assert_eq!(x.response_tokens, y.response_tokens);
        }
    }

    #[test]
    fn histogram_works() {
        let mut h = response_histogram(&trace());
        assert!(h.p99() > h.p50());
    }
}
