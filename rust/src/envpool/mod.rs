//! Kubernetes-like containerized environment pool (simulated).
//!
//! Models the paper's CPU-cluster environment substrate (§2.2, §3.1):
//! `env.reset` = image pull + container launch under host contention,
//! `env.step` = action execution.  Both are heavy-tailed (Fig 5a);
//! reset tails reach hundreds of seconds from concurrent image pulls
//! saturating network links and CPU/disk contention when launching
//! containers.  Failures (timeouts) occur ~once every ten iterations
//! (§3.1) and are injected per-reset here.
//!
//! The §8 production mitigation — a multi-tier image cache (registry
//! mirror + distributed node cache) — is modeled by [`CacheTier`] and
//! raises reset success above 99.99% with sub-minute initialization,
//! reproducing the reported effect.

use crate::env::TaskDomain;
use crate::simkit::dist::Dist;
use crate::simkit::SimRng;

/// Image-distribution configuration (§8 "Optimizing Environment
/// Stability").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheTier {
    /// Direct pulls from an external registry: slow, contended,
    /// failure-prone (the paper's pre-optimization state).
    RegistryOnly,
    /// Internal mirror + distributed load-balanced cache between nodes
    /// (the paper's production fix).
    MultiTier,
}

/// One environment pool's latency/failure model.
#[derive(Clone, Debug)]
pub struct EnvPoolConfig {
    pub cache: CacheTier,
    /// Probability one `env.reset` fails (timeout) and must be retried
    /// by the coordinator. Calibrated so env failures appear roughly
    /// once every ten iterations at batch 128 under RegistryOnly.
    pub reset_failure_p: f64,
    /// Timeout before a failed reset is detected, seconds.
    pub reset_timeout_s: f64,
    /// Host-contention multiplier applied when many resets are in
    /// flight simultaneously (concurrent docker pulls saturate links).
    pub contention_per_inflight: f64,
    /// When set, reset *failure* draws come from a dedicated stream
    /// seeded here (via [`ResetSampler`]) instead of the caller's
    /// latency stream, so fault-related tests can pin the failure
    /// pattern independently of latency draws.  `None` (the default)
    /// keeps the historical single-stream behaviour bit-for-bit.
    /// Seeding convention: `docs/DETERMINISM.md` (see also [`crate::simkit`]).
    pub fault_seed: Option<u64>,
}

impl EnvPoolConfig {
    pub fn registry_only() -> Self {
        EnvPoolConfig {
            cache: CacheTier::RegistryOnly,
            reset_failure_p: 0.0008,
            reset_timeout_s: 300.0,
            contention_per_inflight: 0.004,
            fault_seed: None,
        }
    }

    pub fn multi_tier() -> Self {
        EnvPoolConfig {
            cache: CacheTier::MultiTier,
            // §8: >99.99% success, >99.99% of inits under one minute.
            reset_failure_p: 0.00003,
            reset_timeout_s: 120.0,
            contention_per_inflight: 0.0005,
            fault_seed: None,
        }
    }

    /// Latency distribution of a *successful* `env.reset` (Fig 5a):
    /// bimodal — warm container cache vs cold image pull.
    pub fn reset_dist(&self) -> Dist {
        match self.cache {
            CacheTier::RegistryOnly => Dist::Mix {
                p_tail: 0.06,
                // warm path: seconds (container launch only)
                body: Box::new(Dist::lognormal_median(6.0, 0.5)),
                // cold path: image pull, tens to hundreds of seconds
                tail: Box::new(Dist::lognormal_median(30.0, 0.7)),
            },
            CacheTier::MultiTier => Dist::Mix {
                p_tail: 0.02,
                body: Box::new(Dist::lognormal_median(4.0, 0.4)),
                tail: Box::new(Dist::lognormal_median(20.0, 0.5)),
            },
        }
    }

    /// Latency distribution of one `env.step` (Fig 5a): sub-second
    /// median with a long tail into tens of seconds (sandboxed
    /// execution, host contention).
    pub fn step_dist(&self, domain: TaskDomain) -> Dist {
        let (median, sigma) = match domain {
            // running tests / builds inside the sandbox
            TaskDomain::Swe => (0.5, 0.6),
            TaskDomain::Web => (0.5, 0.5),
            TaskDomain::Game => (0.08, 0.4),
            TaskDomain::MathTool => (0.3, 0.5),
            TaskDomain::GameSingle => (0.2, 0.5),
        };
        Dist::lognormal_median(median, sigma)
    }

    /// Sample a reset outcome under `inflight` concurrent resets.
    pub fn sample_reset(&self, inflight: usize, rng: &mut SimRng) -> ResetOutcome {
        if rng.chance(self.reset_failure_p) {
            return ResetOutcome {
                latency_s: self.reset_timeout_s,
                failed: true,
            };
        }
        let base = self.reset_dist().sample(rng);
        let contention = 1.0 + self.contention_per_inflight * inflight as f64;
        ResetOutcome {
            latency_s: base * contention,
            failed: false,
        }
    }

    /// Sample one `env.step` latency.
    pub fn sample_step(&self, domain: TaskDomain, rng: &mut SimRng) -> f64 {
        self.step_dist(domain).sample(rng)
    }
}

/// Result of one simulated `env.reset`.
#[derive(Clone, Copy, Debug)]
pub struct ResetOutcome {
    pub latency_s: f64,
    pub failed: bool,
}

/// Stateful reset sampler used by the drivers: owns the optional
/// seeded failure stream declared by [`EnvPoolConfig::fault_seed`].
///
/// With `fault_seed = None` every draw (failure Bernoulli, then
/// latency) comes from the caller's stream in the historical order —
/// results are bit-identical to calling
/// [`EnvPoolConfig::sample_reset`] directly.  With a seed set, failure
/// draws come from the dedicated stream `root("envpool/fault")` so
/// sweeping latency parameters (or seeds) replays the exact same
/// failure pattern.
#[derive(Clone, Debug)]
pub struct ResetSampler {
    cfg: EnvPoolConfig,
    fault_rng: Option<SimRng>,
}

impl ResetSampler {
    pub fn new(cfg: &EnvPoolConfig) -> Self {
        ResetSampler {
            cfg: cfg.clone(),
            fault_rng: cfg
                .fault_seed
                .map(|s| SimRng::new(s).stream("envpool/fault", 0)),
        }
    }

    /// Sample one reset outcome under `inflight` concurrent resets;
    /// `rng` supplies the latency (and, unseeded, the failure) draws.
    pub fn sample(&mut self, inflight: usize, rng: &mut SimRng) -> ResetOutcome {
        let failed = match &mut self.fault_rng {
            Some(fr) => fr.chance(self.cfg.reset_failure_p),
            None => return self.cfg.sample_reset(inflight, rng),
        };
        if failed {
            return ResetOutcome {
                latency_s: self.cfg.reset_timeout_s,
                failed: true,
            };
        }
        let base = self.cfg.reset_dist().sample(rng);
        let contention = 1.0 + self.cfg.contention_per_inflight * inflight as f64;
        ResetOutcome {
            latency_s: base * contention,
            failed: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Histogram;

    #[test]
    fn reset_tail_reaches_hundreds_of_seconds() {
        // Fig 5a: env.reset long-tail delay "can reach hundreds of
        // seconds in production" under registry-only pulls.
        let cfg = EnvPoolConfig::registry_only();
        let mut rng = SimRng::new(0);
        let mut h = Histogram::new();
        for _ in 0..20_000 {
            h.record(cfg.sample_reset(0, &mut rng).latency_s);
        }
        assert!(h.quantile(0.999) > 60.0, "p99.9 {}", h.quantile(0.999));
        assert!(h.p50() < 10.0, "median {}", h.p50());
    }

    #[test]
    fn multi_tier_cache_keeps_inits_under_a_minute() {
        // §8: after the cache fix, >99.99% of inits complete < 1 min.
        let cfg = EnvPoolConfig::multi_tier();
        let mut rng = SimRng::new(1);
        let mut under = 0;
        let n = 50_000;
        for _ in 0..n {
            let o = cfg.sample_reset(0, &mut rng);
            if !o.failed && o.latency_s < 60.0 {
                under += 1;
            }
        }
        assert!(under as f64 / n as f64 > 0.9995, "{under}/{n}");
    }

    #[test]
    fn failure_rate_once_per_ten_iterations_at_batch_128() {
        // §3.1: failures ≈ every 10 iterations with 128 envs/iter.
        let cfg = EnvPoolConfig::registry_only();
        let p_iter_clean = (1.0 - cfg.reset_failure_p).powi(128);
        let p_iter_fail = 1.0 - p_iter_clean;
        assert!((0.05..0.2).contains(&p_iter_fail), "{p_iter_fail}");
    }

    #[test]
    fn contention_scales_with_inflight() {
        let cfg = EnvPoolConfig::registry_only();
        // expected latency grows with concurrent resets
        let mut rng1 = SimRng::new(2);
        let mut rng2 = SimRng::new(2);
        let mut sum0 = 0.0;
        let mut sum500 = 0.0;
        for _ in 0..5_000 {
            sum0 += cfg.sample_reset(0, &mut rng1).latency_s;
            sum500 += cfg.sample_reset(500, &mut rng2).latency_s;
        }
        assert!(sum500 > sum0 * 1.5, "{sum500} vs {sum0}");
    }

    #[test]
    fn step_tails_by_domain() {
        let cfg = EnvPoolConfig::registry_only();
        let mut rng = SimRng::new(3);
        let mut swe = Histogram::new();
        let mut game = Histogram::new();
        for _ in 0..10_000 {
            swe.record(cfg.sample_step(TaskDomain::Swe, &mut rng));
            game.record(cfg.sample_step(TaskDomain::Game, &mut rng));
        }
        // SWE steps are much slower than game steps; both heavy-tailed.
        assert!(swe.p50() > 3.0 * game.p50());
        assert!(swe.p99() > 3.0 * swe.p50(), "heavy tail expected");
    }

    #[test]
    fn unseeded_sampler_matches_direct_sampling_bit_for_bit() {
        let cfg = EnvPoolConfig::registry_only();
        let mut direct = SimRng::new(9);
        let mut via = SimRng::new(9);
        let mut sampler = ResetSampler::new(&cfg);
        for i in 0..2_000 {
            let a = cfg.sample_reset(i % 64, &mut direct);
            let b = sampler.sample(i % 64, &mut via);
            assert_eq!(a.latency_s, b.latency_s, "draw {i}");
            assert_eq!(a.failed, b.failed, "draw {i}");
        }
    }

    #[test]
    fn seeded_failure_pattern_is_independent_of_latency_stream() {
        let cfg = EnvPoolConfig {
            reset_failure_p: 0.2,
            fault_seed: Some(42),
            ..EnvPoolConfig::registry_only()
        };
        let pattern = |latency_seed: u64| -> Vec<bool> {
            let mut rng = SimRng::new(latency_seed);
            let mut s = ResetSampler::new(&cfg);
            (0..500).map(|_| s.sample(0, &mut rng).failed).collect()
        };
        let a = pattern(1);
        let b = pattern(777);
        assert_eq!(a, b, "same fault_seed ⇒ same failures, any latency seed");
        assert!(a.iter().any(|&f| f), "p=0.2 over 500 draws must fail some");
        let mut other = cfg.clone();
        other.fault_seed = Some(43);
        let mut rng = SimRng::new(1);
        let mut s = ResetSampler::new(&other);
        let c: Vec<bool> = (0..500).map(|_| s.sample(0, &mut rng).failed).collect();
        assert_ne!(a, c, "different fault_seed ⇒ different failure pattern");
    }

    #[test]
    fn failed_reset_costs_full_timeout() {
        let cfg = EnvPoolConfig {
            reset_failure_p: 1.0,
            ..EnvPoolConfig::registry_only()
        };
        let mut rng = SimRng::new(4);
        let o = cfg.sample_reset(0, &mut rng);
        assert!(o.failed);
        assert_eq!(o.latency_s, cfg.reset_timeout_s);
    }
}
