//! PJRT runtime: load the AOT artifacts and execute them on the
//! request path (Python never runs here).
//!
//! `python/compile/aot.py` lowers the L2 model (with its L1 Pallas
//! kernels) to HLO *text*; this module parses each module, compiles it
//! on the PJRT CPU client once at startup, and exposes typed wrappers:
//! [`Runtime::prefill`], [`Runtime::decode_step`], [`Runtime::logprob`]
//! and [`Runtime::train_step`].  Parameter order follows
//! `manifest.json`'s flat layout (see `runtime::manifest`).
//!
//! HLO text — not serialized protos — is the interchange format: jax ≥
//! 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns them (see /opt/xla-example/README.md).

mod manifest;

pub use manifest::{default_artifacts_dir, EntrySpec, Manifest, ModelShapes, TensorSpec};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

/// Flat model parameters (layout order), shared by all entries.
pub struct Params(pub Vec<Literal>);

impl Params {
    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Total byte size (for weight-transfer accounting).
    pub fn byte_size(&self) -> usize {
        self.0.iter().map(|l| l.size_bytes()).sum()
    }
}

/// Adam training state: params + first/second moments + step counter.
pub struct TrainState {
    pub params: Params,
    pub m: Vec<Literal>,
    pub v: Vec<Literal>,
    pub step: f32,
}

/// Scalar diagnostics of one train step.
#[derive(Clone, Copy, Debug)]
pub struct TrainMetrics {
    pub loss: f32,
    pub entropy: f32,
    pub grad_norm: f32,
}

/// KV cache pair (cache_k, cache_v), shape (L,B,H,S,Dh) each.
pub struct KvCache {
    pub k: Literal,
    pub v: Literal,
}

/// Parameters resident on the PJRT device (§Perf L3-1).
///
/// The naive path re-uploads all ~17.8 MB of parameter literals on
/// *every* executable call; uploading once and executing with
/// `execute_b` removes that host→device traffic from the decode loop
/// (see `rust/benches/bench_runtime.rs` for the before/after).
pub struct DeviceParams {
    bufs: Vec<PjRtBuffer>,
}

impl DeviceParams {
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// The compiled runtime.
pub struct Runtime {
    pub manifest: Manifest,
    client: PjRtClient,
    exes: BTreeMap<String, PjRtLoadedExecutable>,
}

fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if data.len() != n {
        bail!("literal size mismatch: {} vs {:?}", data.len(), dims);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

fn i32_literal(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if data.len() != n {
        bail!("literal size mismatch: {} vs {:?}", data.len(), dims);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims_i64)?)
}

impl Runtime {
    /// Load the manifest, parse + compile every entry.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = BTreeMap::new();
        for entry in &manifest.entries {
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| anyhow!("parsing {}: {e:?}", entry.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
            exes.insert(entry.name.clone(), exe);
        }
        Ok(Runtime {
            manifest,
            client,
            exes,
        })
    }

    pub fn load_default() -> Result<Runtime> {
        Self::load(default_artifacts_dir())
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Initial parameters from `params.init.bin` (raw LE f32 concat in
    /// layout order).
    pub fn init_params(&self) -> Result<Params> {
        let path = self.manifest.dir.join("params.init.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != self.manifest.param_elements() * 4 {
            bail!(
                "params.init.bin has {} bytes, expected {}",
                bytes.len(),
                self.manifest.param_elements() * 4
            );
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut out = Vec::with_capacity(self.manifest.param_layout.len());
        let mut off = 0;
        for (_, shape) in &self.manifest.param_layout {
            let n: usize = shape.iter().product();
            out.push(f32_literal(&floats[off..off + n], shape)?);
            off += n;
        }
        Ok(Params(out))
    }

    /// Zero-initialized Adam state.
    pub fn init_train_state(&self) -> Result<TrainState> {
        let params = self.init_params()?;
        let zeros = |shape: &[usize]| -> Result<Literal> {
            f32_literal(&vec![0.0; shape.iter().product()], shape)
        };
        let mut m = Vec::new();
        let mut v = Vec::new();
        for (_, shape) in &self.manifest.param_layout {
            m.push(zeros(shape)?);
            v.push(zeros(shape)?);
        }
        Ok(TrainState {
            params,
            m,
            v,
            step: 0.0,
        })
    }

    fn run_entry(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("entry {name} not loaded"))?;
        let spec = self.manifest.entry(name)?;
        if args.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                args.len()
            );
        }
        let result = exe
            .execute::<&Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "{name}: expected {} outputs, got {}",
                spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Prompt ingestion for a padded batch.
    ///
    /// `tokens`: (B, S) row-major; `lengths`: (B,) valid prompt widths.
    /// Returns (next-token logits (B,V) row-major, KV cache).
    pub fn prefill(
        &self,
        params: &Params,
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<(Vec<f32>, KvCache)> {
        let m = &self.manifest.model;
        let tok = i32_literal(tokens, &[m.batch, m.max_seq])?;
        let len = i32_literal(lengths, &[m.batch])?;
        let mut args: Vec<&Literal> = params.0.iter().collect();
        args.push(&tok);
        args.push(&len);
        let mut outs = self.run_entry("prefill", &args)?;
        let v = outs.remove(2);
        let k = outs.remove(1);
        let logits = outs.remove(0).to_vec::<f32>()?;
        Ok((logits, KvCache { k, v }))
    }

    /// One continuous-batching decode step.
    ///
    /// `tokens`: (B,) next input token per slot; `lengths`: (B,) valid
    /// cache length per slot.  Returns logits (B,V) and advances the
    /// cache + lengths in place.
    pub fn decode_step(
        &self,
        params: &Params,
        cache: &mut KvCache,
        tokens: &[i32],
        lengths: &mut [i32],
    ) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let tok = i32_literal(tokens, &[m.batch])?;
        let len = i32_literal(lengths, &[m.batch])?;
        let mut args: Vec<&Literal> = params.0.iter().collect();
        args.push(&cache.k);
        args.push(&cache.v);
        args.push(&tok);
        args.push(&len);
        let mut outs = self.run_entry("decode_step", &args)?;
        let new_len = outs.remove(3).to_vec::<i32>()?;
        cache.v = outs.remove(2);
        cache.k = outs.remove(1);
        let logits = outs.remove(0).to_vec::<f32>()?;
        lengths.copy_from_slice(&new_len);
        Ok(logits)
    }

    /// Upload parameters to the device once (fast generation path).
    pub fn upload_params(&self, params: &Params) -> Result<DeviceParams> {
        let bufs = params
            .0
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("uploading param: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceParams { bufs })
    }

    fn run_entry_b(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("entry {name} not loaded"))?;
        let spec = self.manifest.entry(name)?;
        if args.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                args.len()
            );
        }
        let result = exe
            .execute_b::<&PjRtBuffer>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        if outs.len() != spec.outputs.len() {
            bail!("{name}: wrong output arity {}", outs.len());
        }
        Ok(outs)
    }

    /// Decode step against device-resident parameters (§Perf L3-1).
    ///
    /// Per call this uploads only the KV cache + 2 tiny int vectors
    /// instead of the full parameter set; numerics are identical to
    /// [`Runtime::decode_step`].
    pub fn decode_step_device(
        &self,
        params: &DeviceParams,
        cache: &mut KvCache,
        tokens: &[i32],
        lengths: &mut [i32],
    ) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let up = |l: &Literal| {
            self.client
                .buffer_from_host_literal(None, l)
                .map_err(|e| anyhow!("upload: {e:?}"))
        };
        let ck = up(&cache.k)?;
        let cv = up(&cache.v)?;
        let tok = self
            .client
            .buffer_from_host_buffer(tokens, &[m.batch], None)
            .map_err(|e| anyhow!("upload tokens: {e:?}"))?;
        let len = self
            .client
            .buffer_from_host_buffer(&*lengths, &[m.batch], None)
            .map_err(|e| anyhow!("upload lengths: {e:?}"))?;
        let mut args: Vec<&PjRtBuffer> = params.bufs.iter().collect();
        args.push(&ck);
        args.push(&cv);
        args.push(&tok);
        args.push(&len);
        let mut outs = self.run_entry_b("decode_step", &args)?;
        let new_len = outs.remove(3).to_vec::<i32>()?;
        cache.v = outs.remove(2);
        cache.k = outs.remove(1);
        let logits = outs.remove(0).to_vec::<f32>()?;
        lengths.copy_from_slice(&new_len);
        Ok(logits)
    }

    /// Per-token log-probabilities of realized sequences (B, S_train).
    pub fn logprob(&self, params: &Params, tokens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let tok = i32_literal(tokens, &[m.train_batch, m.train_seq])?;
        let mut args: Vec<&Literal> = params.0.iter().collect();
        args.push(&tok);
        let mut outs = self.run_entry("logprob", &args)?;
        Ok(outs.remove(0).to_vec::<f32>()?)
    }

    /// One fused GRPO train step (fwd + bwd + Adam), updating `state`
    /// in place and returning the scalar diagnostics.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        lr: f32,
        tokens: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        mask: &[f32],
    ) -> Result<TrainMetrics> {
        let m = &self.manifest.model;
        let bt = [m.train_batch, m.train_seq];
        state.step += 1.0;
        let step_l = Literal::scalar(state.step);
        let lr_l = Literal::scalar(lr);
        let tok = i32_literal(tokens, &bt)?;
        let old = f32_literal(old_logp, &bt)?;
        let adv_l = f32_literal(adv, &bt)?;
        let mask_l = f32_literal(mask, &bt)?;

        let mut args: Vec<&Literal> = Vec::with_capacity(3 * state.params.len() + 6);
        args.extend(state.params.0.iter());
        args.extend(state.m.iter());
        args.extend(state.v.iter());
        args.push(&step_l);
        args.push(&lr_l);
        args.push(&tok);
        args.push(&old);
        args.push(&adv_l);
        args.push(&mask_l);

        let mut outs = self.run_entry("train_step", &args)?;
        let n = state.params.len();
        let grad_norm = outs.pop().unwrap().get_first_element::<f32>()?;
        let entropy = outs.pop().unwrap().get_first_element::<f32>()?;
        let loss = outs.pop().unwrap().get_first_element::<f32>()?;
        let v: Vec<Literal> = outs.drain(2 * n..).collect();
        let mm: Vec<Literal> = outs.drain(n..).collect();
        let p: Vec<Literal> = outs.drain(..).collect();
        state.params = Params(p);
        state.m = mm;
        state.v = v;
        Ok(TrainMetrics {
            loss,
            entropy,
            grad_norm,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Heavier integration coverage lives in rust/tests/e2e_runtime.rs;
    // here only cheap contract checks that run without artifacts.

    #[test]
    fn literal_helpers_validate_shape() {
        assert!(f32_literal(&[1.0, 2.0], &[3]).is_err());
        let l = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(i32_literal(&[1], &[2]).is_err());
    }

    #[test]
    fn default_dir_is_stable() {
        let d = default_artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }
}
