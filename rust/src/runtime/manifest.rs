//! `artifacts/manifest.json` parsing: the cross-language contract
//! between `python/compile/aot.py` and the Rust runtime.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Model shape constants (python/compile/shapes.py).
#[derive(Clone, Debug)]
pub struct ModelShapes {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub batch: usize,
    pub max_seq: usize,
    pub train_batch: usize,
    pub train_seq: usize,
    pub param_count: usize,
}

/// One tensor in an entry's flat signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry (prefill / decode_step / logprob / train_step).
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelShapes,
    /// (name, shape) in flat parameter order.
    pub param_layout: Vec<(String, Vec<usize>)>,
    pub entries: Vec<EntrySpec>,
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("manifest missing numeric field {key}"))
}

fn tensor_list(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensors"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("tensor missing name"))?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("tensor missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<usize>>>()?,
                dtype: t
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or("float32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;

        let m = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let model = ModelShapes {
            vocab: usize_field(m, "vocab")?,
            d_model: usize_field(m, "d_model")?,
            n_layers: usize_field(m, "n_layers")?,
            n_heads: usize_field(m, "n_heads")?,
            head_dim: usize_field(m, "head_dim")?,
            batch: usize_field(m, "batch")?,
            max_seq: usize_field(m, "max_seq")?,
            train_batch: usize_field(m, "train_batch")?,
            train_seq: usize_field(m, "train_seq")?,
            param_count: usize_field(m, "param_count")?,
        };

        let param_layout = j
            .get("param_layout")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing param_layout"))?
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow!("param missing name"))?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<usize>>>()?;
                Ok((name, shape))
            })
            .collect::<Result<Vec<_>>>()?;

        let mut entries = Vec::new();
        let entries_obj = j
            .get("entries")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("missing entries"))?;
        for (name, e) in entries_obj {
            let file = e
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("entry {name} missing file"))?;
            entries.push(EntrySpec {
                name: name.clone(),
                file: dir.join(file),
                inputs: tensor_list(e.get("inputs").ok_or_else(|| anyhow!("no inputs"))?)?,
                outputs: tensor_list(e.get("outputs").ok_or_else(|| anyhow!("no outputs"))?)?,
            });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest {
            dir,
            model,
            param_layout,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("no entry {name} in manifest"))
    }

    /// Total f32 elements across the parameter layout.
    pub fn param_elements(&self) -> usize {
        self.param_layout
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }
}

/// Locate the artifacts directory: `$ROLLART_ARTIFACTS`, else walk up
/// from the crate/workspace root.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("ROLLART_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for base in [
        Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts"),
        PathBuf::from("artifacts"),
        PathBuf::from("../artifacts"),
    ] {
        if base.join("manifest.json").exists() {
            return base;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(default_artifacts_dir()).unwrap();
        assert_eq!(m.entries.len(), 4);
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.param_elements(), m.model.param_count);
        let train = m.entry("train_step").unwrap();
        let n = m.param_layout.len();
        assert_eq!(train.inputs.len(), 3 * n + 6);
        assert_eq!(train.outputs.len(), 3 * n + 3);
        assert_eq!(train.outputs[3 * n].name, "loss");
        // params come first, in layout order
        for (i, (name, shape)) in m.param_layout.iter().enumerate() {
            assert_eq!(&train.inputs[i].name, name);
            assert_eq!(&train.inputs[i].shape, shape);
        }
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::tempdir::TempDir::new("man").unwrap();
        std::fs::write(dir.path().join("manifest.json"), "{}").unwrap();
        assert!(Manifest::load(dir.path()).is_err());
    }
}
