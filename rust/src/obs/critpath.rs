//! Critical-path plane: causal blame for the iteration makespan, and a
//! re-simulation-validated what-if estimator.
//!
//! [`crate::obs::BubbleReport`] says *where engines idle*; this module
//! says *which dependency chain actually bounds the iteration* — idle
//! time off the critical path is free, idle time on it is the whole
//! ballgame.  The inputs come from the event queue's causal provenance
//! ([`crate::simkit::EventQueue::enable_provenance`]): every scheduled
//! event records its parent (the event whose handler scheduled it), its
//! schedule/fire times, a driver-assigned [`EdgeKind`], and the share
//! of its delay spent queueing on a shared link.
//!
//! # Why the chain is exact
//!
//! A handler schedules its children at the simulation clock of the
//! event it is handling, so a child's `sched_s` is *bitwise equal* to
//! its parent's `due_s` — every ancestor chain covers a contiguous time
//! interval ending at the final event's fire time.  The ancestor chain
//! of iteration `i`'s `TrainDone`, clipped at iteration `i-1`'s
//! `TrainDone`, therefore has length *exactly* equal to the iteration
//! makespan ([`IterPath::len_s`] is computed as `end - start` directly;
//! the per-kind decomposition sums to it within float addition).  This
//! is the invariant `tests/critpath_plane.rs` pins under every mode ×
//! PD × chaos composition.
//!
//! # What-if estimation (causal profiling)
//!
//! [`what_if`] applies a virtual speedup to every on-path edge of a
//! kind (service part only — queueing is left untouched) and re-sums
//! the chains, à la causal profiling (Coz): "what would the run take if
//! decode were 2× faster?".  The prediction deliberately ignores
//! second-order effects (a shorter decode changes queueing and may move
//! the critical path onto another chain), so it is an *estimate*; the
//! test suite validates it against actual re-simulation with the
//! corresponding scenario knob changed, within the tolerance stated in
//! `docs/OBSERVABILITY.md` (and it is an upper bound on the achievable
//! new makespan in the common case, since the true path can only be
//! bound by *other* chains getting relatively longer).

use crate::simkit::{ProvEntry, NO_CAUSE};

/// Causal classification of one scheduled event — what kind of work the
/// delay between its scheduling and its firing represents.  Stored as a
/// `u8` tag on [`ProvEntry`] (the queue is event-type-agnostic); the
/// driver classifies each event at pop time.
#[repr(u8)]
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EdgeKind {
    /// Unclassified (an event scheduled but never popped, or a driver
    /// that does not classify).
    #[default]
    Other = 0,
    /// PD prefill-pool engine step.
    Prefill = 1,
    /// PD decode-pool engine step.
    Decode = 2,
    /// Colocated (non-PD) engine step.
    Generation = 3,
    /// KV-cache hop over the shared PD link.
    KvHop = 4,
    /// Environment reset (cold start, retries).
    EnvReset = 5,
    /// Environment step execution.
    EnvStep = 6,
    /// Reward computation.
    Reward = 7,
    /// Training step.
    Train = 8,
    /// Blocking weight-sync barrier (fleet drain + analytic store sync).
    Barrier = 9,
    /// Bucketized background weight stream (event-driven strategies).
    WeightStream = 10,
    /// Engine cutover (GPU load + per-bucket coordination + recompute).
    Cutover = 11,
    /// Fault plane: crashes, recovery, chaos events.
    Fault = 12,
    /// Elastic plane: provisioning, warm-up pulls, repurposing.
    Elastic = 13,
    /// Trace-replay plane: open-loop trace arrival ticks.
    Arrival = 14,
}

impl EdgeKind {
    /// Every classifiable kind, in tag order.
    pub const ALL: [EdgeKind; 15] = [
        EdgeKind::Other,
        EdgeKind::Prefill,
        EdgeKind::Decode,
        EdgeKind::Generation,
        EdgeKind::KvHop,
        EdgeKind::EnvReset,
        EdgeKind::EnvStep,
        EdgeKind::Reward,
        EdgeKind::Train,
        EdgeKind::Barrier,
        EdgeKind::WeightStream,
        EdgeKind::Cutover,
        EdgeKind::Fault,
        EdgeKind::Elastic,
        EdgeKind::Arrival,
    ];

    pub fn from_u8(k: u8) -> EdgeKind {
        *Self::ALL.get(k as usize).unwrap_or(&EdgeKind::Other)
    }

    pub fn name(self) -> &'static str {
        match self {
            EdgeKind::Other => "other",
            EdgeKind::Prefill => "prefill",
            EdgeKind::Decode => "decode",
            EdgeKind::Generation => "generation",
            EdgeKind::KvHop => "kv-hop",
            EdgeKind::EnvReset => "env-reset",
            EdgeKind::EnvStep => "env-step",
            EdgeKind::Reward => "reward",
            EdgeKind::Train => "train",
            EdgeKind::Barrier => "barrier",
            EdgeKind::WeightStream => "weight-stream",
            EdgeKind::Cutover => "cutover",
            EdgeKind::Fault => "fault",
            EdgeKind::Elastic => "elastic",
            EdgeKind::Arrival => "arrival",
        }
    }
}

/// Seconds on the critical path, decomposed by [`EdgeKind`] service
/// plus one shared queueing row (link-slot waits tagged by the driver,
/// booked here instead of under their edge's kind so contention is
/// blamed as contention).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PathBreakdown {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub generation_s: f64,
    pub kv_hop_s: f64,
    pub env_reset_s: f64,
    pub env_step_s: f64,
    pub reward_s: f64,
    pub train_s: f64,
    pub barrier_s: f64,
    pub weight_stream_s: f64,
    pub cutover_s: f64,
    pub fault_s: f64,
    pub elastic_s: f64,
    /// Open-loop trace arrival ticks (trace-replay runs only).
    pub arrival_s: f64,
    pub other_s: f64,
    /// Link-slot queueing across all on-path edges.
    pub queue_s: f64,
}

impl PathBreakdown {
    fn slot(&mut self, kind: EdgeKind) -> &mut f64 {
        match kind {
            EdgeKind::Prefill => &mut self.prefill_s,
            EdgeKind::Decode => &mut self.decode_s,
            EdgeKind::Generation => &mut self.generation_s,
            EdgeKind::KvHop => &mut self.kv_hop_s,
            EdgeKind::EnvReset => &mut self.env_reset_s,
            EdgeKind::EnvStep => &mut self.env_step_s,
            EdgeKind::Reward => &mut self.reward_s,
            EdgeKind::Train => &mut self.train_s,
            EdgeKind::Barrier => &mut self.barrier_s,
            EdgeKind::WeightStream => &mut self.weight_stream_s,
            EdgeKind::Cutover => &mut self.cutover_s,
            EdgeKind::Fault => &mut self.fault_s,
            EdgeKind::Elastic => &mut self.elastic_s,
            EdgeKind::Arrival => &mut self.arrival_s,
            EdgeKind::Other => &mut self.other_s,
        }
    }

    fn book(&mut self, kind: EdgeKind, service_s: f64, queue_s: f64) {
        *self.slot(kind) += service_s;
        self.queue_s += queue_s;
    }

    fn merge(&mut self, other: &PathBreakdown) {
        for k in EdgeKind::ALL {
            *self.slot(k) += other.row(k);
        }
        self.queue_s += other.queue_s;
    }

    /// Service seconds booked under one kind.
    pub fn row(&self, kind: EdgeKind) -> f64 {
        match kind {
            EdgeKind::Prefill => self.prefill_s,
            EdgeKind::Decode => self.decode_s,
            EdgeKind::Generation => self.generation_s,
            EdgeKind::KvHop => self.kv_hop_s,
            EdgeKind::EnvReset => self.env_reset_s,
            EdgeKind::EnvStep => self.env_step_s,
            EdgeKind::Reward => self.reward_s,
            EdgeKind::Train => self.train_s,
            EdgeKind::Barrier => self.barrier_s,
            EdgeKind::WeightStream => self.weight_stream_s,
            EdgeKind::Cutover => self.cutover_s,
            EdgeKind::Fault => self.fault_s,
            EdgeKind::Elastic => self.elastic_s,
            EdgeKind::Arrival => self.arrival_s,
            EdgeKind::Other => self.other_s,
        }
    }

    /// All rows, in tag order, plus the queueing row — the blame table.
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        let mut out: Vec<(&'static str, f64)> =
            EdgeKind::ALL.iter().map(|&k| (k.name(), self.row(k))).collect();
        out.push(("queueing", self.queue_s));
        out
    }

    /// Sum of every row (equals the path length within float addition).
    pub fn total(&self) -> f64 {
        EdgeKind::ALL.iter().map(|&k| self.row(k)).sum::<f64>() + self.queue_s
    }

    /// The largest non-train service row — "what to aim at next".
    /// Train is excluded because it is the payload, not overhead.
    pub fn dominant(&self) -> (EdgeKind, f64) {
        let mut best = (EdgeKind::Other, f64::NEG_INFINITY);
        for k in EdgeKind::ALL {
            if k == EdgeKind::Train {
                continue;
            }
            let v = self.row(k);
            if v > best.1 {
                best = (k, v);
            }
        }
        best
    }
}

/// One on-path edge: the causal delay of one event, clipped to its
/// iteration window and split into service + queueing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PathNode {
    pub kind: EdgeKind,
    /// Engine id for engine edges, trajectory slot for env/KV/reward
    /// edges, `u32::MAX` when not applicable.
    pub actor: u32,
    pub service_s: f64,
    pub queue_s: f64,
}

/// The critical path of one training iteration: the unique causal
/// ancestor chain of its `TrainDone`, clipped at the previous one.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IterPath {
    pub iter: usize,
    pub start_s: f64,
    pub end_s: f64,
    /// Iteration makespan, `end_s - start_s` — *exactly* the sum of the
    /// chain's delays (the telescoping invariant; see module docs).
    pub len_s: f64,
    pub breakdown: PathBreakdown,
    /// On-path edges in chronological order.
    pub nodes: Vec<PathNode>,
}

/// One recurring `(kind, actor)` edge aggregated across the run's
/// critical paths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EdgeBlame {
    pub kind: EdgeKind,
    pub actor: u32,
    pub on_path_s: f64,
    pub count: u64,
}

/// One trajectory's total on-path seconds (env/KV/reward edges carry
/// the trajectory slot as actor) — the per-trajectory critical-path
/// blame: which rollouts actually gated training.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrajBlame {
    pub traj: u32,
    pub on_path_s: f64,
}

/// Critical-path decomposition of one run, attached to
/// [`crate::sim::ScenarioResult::critpath`] by the provenance-enabled
/// entry points.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CritPathReport {
    /// Per-iteration critical paths, in iteration order.
    pub iters: Vec<IterPath>,
    /// Run-total blame table (sum of the per-iteration breakdowns).
    pub total: PathBreakdown,
    /// Top recurring on-path `(kind, actor)` edges, worst first.
    pub top_edges: Vec<EdgeBlame>,
    /// Top trajectories by on-path seconds, worst first.
    pub top_trajectories: Vec<TrajBlame>,
    /// Fire time of the final `TrainDone` (== the run makespan the
    /// iteration windows tile).
    pub makespan_s: f64,
}

/// How many recurring edges / trajectories the report keeps.
const TOP_K: usize = 8;

/// Shared accumulator state for the blame tables while paths are built.
#[derive(Default)]
struct BlameAcc {
    edges: std::collections::BTreeMap<(u8, u32), (f64, u64)>,
    trajs: std::collections::BTreeMap<u32, f64>,
}

impl BlameAcc {
    fn book(&mut self, kind: EdgeKind, actor: u32, span: f64) {
        let b = self.edges.entry((kind as u8, actor)).or_insert((0.0, 0));
        b.0 += span;
        b.1 += 1;
        if matches!(
            kind,
            EdgeKind::KvHop | EdgeKind::EnvReset | EdgeKind::EnvStep | EdgeKind::Reward
        ) && actor != u32::MAX
        {
            *self.trajs.entry(actor).or_insert(0.0) += span;
        }
    }

    fn finish(self, report: &mut CritPathReport) {
        let mut blames: Vec<EdgeBlame> = self
            .edges
            .into_iter()
            .map(|((kind, actor), (on_path_s, count))| EdgeBlame {
                kind: EdgeKind::from_u8(kind),
                actor,
                on_path_s,
                count,
            })
            .collect();
        blames.sort_by(|a, b| {
            b.on_path_s
                .total_cmp(&a.on_path_s)
                .then(a.kind.cmp(&b.kind))
                .then(a.actor.cmp(&b.actor))
        });
        blames.truncate(TOP_K);
        report.top_edges = blames;

        let mut tb: Vec<TrajBlame> = self
            .trajs
            .into_iter()
            .map(|(traj, on_path_s)| TrajBlame { traj, on_path_s })
            .collect();
        tb.sort_by(|a, b| b.on_path_s.total_cmp(&a.on_path_s).then(a.traj.cmp(&b.traj)));
        tb.truncate(TOP_K);
        report.top_trajectories = tb;
    }
}

/// Extract per-iteration critical paths from a provenance log.
///
/// Iteration windows are defined by [`EdgeKind::Train`] fire times
/// (window `i` spans from `TrainDone`<sub>i-1</sub>, or 0, to
/// `TrainDone`<sub>i</sub>); each window's path is the train event's
/// causal ancestor chain clipped at the window start.
pub fn extract(log: &[ProvEntry]) -> CritPathReport {
    let mut trains: Vec<usize> = (0..log.len())
        .filter(|&i| log[i].kind == EdgeKind::Train as u8)
        .collect();
    trains.sort_by(|&a, &b| log[a].due_s.total_cmp(&log[b].due_s).then(a.cmp(&b)));

    let mut report = CritPathReport::default();
    let mut acc = BlameAcc::default();

    let mut start = 0.0f64;
    for (iter, &ti) in trains.iter().enumerate() {
        let end = log[ti].due_s;
        let mut path = IterPath {
            iter,
            start_s: start,
            end_s: end,
            len_s: end - start,
            breakdown: PathBreakdown::default(),
            nodes: Vec::new(),
        };
        // Walk the unique causal ancestor chain train-ward → root-ward.
        let mut idx = ti as u64;
        while idx != NO_CAUSE {
            let e = &log[idx as usize];
            if e.due_s <= start {
                break; // fully before this window: prior iterations' work
            }
            let kind = EdgeKind::from_u8(e.kind);
            // Clip the boundary edge at the window start.
            let span = (e.due_s - e.sched_s.max(start)).max(0.0);
            let queue = e.queue_s.clamp(0.0, span);
            let service = span - queue;
            path.breakdown.book(kind, service, queue);
            path.nodes.push(PathNode {
                kind,
                actor: e.actor,
                service_s: service,
                queue_s: queue,
            });
            acc.book(kind, e.actor, span);
            idx = e.parent;
        }
        path.nodes.reverse();
        report.total.merge(&path.breakdown);
        report.iters.push(path);
        start = end;
    }
    report.makespan_s = start;
    acc.finish(&mut report);
    report
}

/// Build a report from already-linear per-iteration chains.
///
/// The analytic Sync driver has no event queue to record provenance
/// from — but a barrier pipeline *is* one causal chain by construction,
/// so its committed per-iteration phase breakdown maps directly onto
/// path nodes.  Windows tile from 0; each iteration's length is the sum
/// of its nodes (the same telescoping identity [`extract`] gets from
/// the event clock).
pub fn synthesize(iters: &[Vec<PathNode>]) -> CritPathReport {
    let mut report = CritPathReport::default();
    let mut acc = BlameAcc::default();
    let mut start = 0.0f64;
    for (iter, nodes) in iters.iter().enumerate() {
        let mut path = IterPath {
            iter,
            start_s: start,
            end_s: start,
            len_s: 0.0,
            breakdown: PathBreakdown::default(),
            nodes: Vec::new(),
        };
        for n in nodes {
            let span = n.service_s + n.queue_s;
            path.breakdown.book(n.kind, n.service_s, n.queue_s);
            acc.book(n.kind, n.actor, span);
            path.end_s += span;
            path.nodes.push(*n);
        }
        path.len_s = path.end_s - path.start_s;
        report.total.merge(&path.breakdown);
        start = path.end_s;
        report.iters.push(path);
    }
    report.makespan_s = start;
    acc.finish(&mut report);
    report
}

/// A virtual speedup to evaluate over the recorded critical paths:
/// "what if this stage were `f`× faster?".  `f > 1.0` speeds the stage
/// up; `f < 1.0` models a slowdown.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Speedup {
    Prefill(f64),
    Decode(f64),
    Generation(f64),
    KvHop(f64),
    EnvReset(f64),
    EnvStep(f64),
    Reward(f64),
    Train(f64),
    /// The blocking fleet-drain barrier (analytic store sync).
    Barrier(f64),
    /// The bucketized background weight stream (link bandwidth).
    Weights(f64),
}

impl Speedup {
    pub fn kind(self) -> EdgeKind {
        match self {
            Speedup::Prefill(_) => EdgeKind::Prefill,
            Speedup::Decode(_) => EdgeKind::Decode,
            Speedup::Generation(_) => EdgeKind::Generation,
            Speedup::KvHop(_) => EdgeKind::KvHop,
            Speedup::EnvReset(_) => EdgeKind::EnvReset,
            Speedup::EnvStep(_) => EdgeKind::EnvStep,
            Speedup::Reward(_) => EdgeKind::Reward,
            Speedup::Train(_) => EdgeKind::Train,
            Speedup::Barrier(_) => EdgeKind::Barrier,
            Speedup::Weights(_) => EdgeKind::WeightStream,
        }
    }

    pub fn factor(self) -> f64 {
        match self {
            Speedup::Prefill(f)
            | Speedup::Decode(f)
            | Speedup::Generation(f)
            | Speedup::KvHop(f)
            | Speedup::EnvReset(f)
            | Speedup::EnvStep(f)
            | Speedup::Reward(f)
            | Speedup::Train(f)
            | Speedup::Barrier(f)
            | Speedup::Weights(f) => f,
        }
    }
}

/// One what-if evaluation: predicted makespan under a virtual speedup.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WhatIf {
    pub speedup: Speedup,
    /// The recorded makespan the prediction starts from.
    pub baseline_s: f64,
    /// Predicted makespan with the stage virtually sped up.
    pub predicted_s: f64,
}

impl WhatIf {
    /// Predicted end-to-end speedup, `baseline / predicted`.
    pub fn predicted_speedup(&self) -> f64 {
        self.baseline_s / self.predicted_s.max(1e-12)
    }

    /// Seconds the speedup is predicted to shave off the run.
    pub fn saved_s(&self) -> f64 {
        self.baseline_s - self.predicted_s
    }
}

/// Virtually speed up one stage over the recorded critical paths
/// (service scaled by `1/f`, queueing untouched) and re-sum the chains.
/// See the module docs for what the estimate does and does not capture.
pub fn what_if(report: &CritPathReport, s: Speedup) -> WhatIf {
    let kind = s.kind();
    let f = s.factor().max(1e-9);
    let mut predicted = 0.0f64;
    for iter in &report.iters {
        for n in &iter.nodes {
            let service = if n.kind == kind { n.service_s / f } else { n.service_s };
            predicted += service + n.queue_s;
        }
    }
    WhatIf {
        speedup: s,
        baseline_s: report.makespan_s,
        predicted_s: predicted,
    }
}

/// Evaluate the standard what-if panel (every stage `factor`× faster)
/// and rank by predicted saving — the "where to aim" table the
/// `fig_critpath` bench prints.
pub fn rank_what_if(report: &CritPathReport, factor: f64) -> Vec<WhatIf> {
    let panel = [
        Speedup::Prefill(factor),
        Speedup::Decode(factor),
        Speedup::Generation(factor),
        Speedup::KvHop(factor),
        Speedup::EnvReset(factor),
        Speedup::EnvStep(factor),
        Speedup::Reward(factor),
        Speedup::Train(factor),
        Speedup::Barrier(factor),
        Speedup::Weights(factor),
    ];
    let mut out: Vec<WhatIf> = panel.iter().map(|&s| what_if(report, s)).collect();
    out.sort_by(|a, b| {
        a.predicted_s
            .total_cmp(&b.predicted_s)
            .then(a.speedup.kind().cmp(&b.speedup.kind()))
    });
    out
}

impl CritPathReport {
    /// Deterministic JSON export of the blame table (the CI artifact):
    /// per-iteration lengths, the run-total breakdown, and the top
    /// recurring edges.  Hand-rolled like
    /// [`crate::obs::TraceRecorder::to_chrome_json`] — no serde.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"makespan_s\": {:.9},\n", self.makespan_s));
        s.push_str("  \"iterations\": [");
        for (i, it) in self.iters.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"iter\": {}, \"start_s\": {:.9}, \"end_s\": {:.9}, \"len_s\": {:.9}, \"nodes\": {}}}",
                it.iter,
                it.start_s,
                it.end_s,
                it.len_s,
                it.nodes.len()
            ));
        }
        s.push_str("],\n  \"total\": {");
        for (i, (name, secs)) in self.total.rows().iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{name}\": {secs:.9}"));
        }
        s.push_str("},\n  \"top_edges\": [");
        for (i, e) in self.top_edges.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"kind\": \"{}\", \"actor\": {}, \"on_path_s\": {:.9}, \"count\": {}}}",
                e.kind.name(),
                e.actor,
                e.on_path_s,
                e.count
            ));
        }
        s.push_str("],\n  \"top_trajectories\": [");
        for (i, t) in self.top_trajectories.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"traj\": {}, \"on_path_s\": {:.9}}}",
                t.traj, t.on_path_s
            ));
        }
        s.push_str("]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built provenance log: a 2-iteration linear chain
    ///   root(gen, 0→2) → kv(2→3, 0.5 queued) → train(3→4)
    ///   → env(4→7) → train(7→9)
    fn demo_log() -> Vec<ProvEntry> {
        let e = |parent: u64, sched: f64, due: f64, kind: EdgeKind, queue: f64, actor: u32| {
            ProvEntry {
                parent,
                sched_s: sched,
                due_s: due,
                kind: kind as u8,
                queue_s: queue,
                actor,
            }
        };
        vec![
            e(NO_CAUSE, 0.0, 2.0, EdgeKind::Generation, 0.0, 3),
            e(0, 2.0, 3.0, EdgeKind::KvHop, 0.5, 7),
            e(1, 3.0, 4.0, EdgeKind::Train, 0.0, u32::MAX),
            e(2, 4.0, 7.0, EdgeKind::EnvStep, 0.0, 7),
            e(3, 7.0, 9.0, EdgeKind::Train, 0.0, u32::MAX),
        ]
    }

    #[test]
    fn extracts_exact_iteration_paths() {
        let r = extract(&demo_log());
        assert_eq!(r.iters.len(), 2);
        assert_eq!(r.makespan_s, 9.0);
        // Window 0: [0, 4] — gen 2s, kv 0.5s service + 0.5s queue,
        // train 1s.
        let i0 = &r.iters[0];
        assert_eq!(i0.len_s, 4.0);
        assert_eq!(i0.breakdown.generation_s, 2.0);
        assert_eq!(i0.breakdown.kv_hop_s, 0.5);
        assert_eq!(i0.breakdown.queue_s, 0.5);
        assert_eq!(i0.breakdown.train_s, 1.0);
        assert!((i0.breakdown.total() - i0.len_s).abs() < 1e-12);
        // Window 1: [4, 9] — env 3s, train 2s.
        let i1 = &r.iters[1];
        assert_eq!(i1.len_s, 5.0);
        assert_eq!(i1.breakdown.env_step_s, 3.0);
        assert_eq!(i1.breakdown.train_s, 2.0);
        // Total sums both windows and equals the makespan.
        assert!((r.total.total() - r.makespan_s).abs() < 1e-12);
        // Trajectory 7 carried the kv hop and the env step.
        assert_eq!(r.top_trajectories[0].traj, 7);
        assert!((r.top_trajectories[0].on_path_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn boundary_edges_clip_at_the_window_start() {
        // An edge spanning a train boundary books only its post-boundary
        // share into the later window.
        let e = |parent: u64, sched: f64, due: f64, kind: EdgeKind| ProvEntry {
            parent,
            sched_s: sched,
            due_s: due,
            kind: kind as u8,
            queue_s: 0.0,
            actor: u32::MAX,
        };
        let log = vec![
            e(NO_CAUSE, 0.0, 1.0, EdgeKind::Train),
            // Spans the boundary at t=1: scheduled before, due after.
            e(NO_CAUSE, 0.5, 3.0, EdgeKind::EnvStep),
            e(1, 3.0, 4.0, EdgeKind::Train),
        ];
        let r = extract(&log);
        assert_eq!(r.iters.len(), 2);
        let i1 = &r.iters[1];
        assert_eq!(i1.len_s, 3.0);
        assert_eq!(i1.breakdown.env_step_s, 2.5, "clipped at the boundary");
        assert_eq!(i1.breakdown.train_s, 1.0);
        assert!((i1.breakdown.total() - i1.len_s).abs() < 1e-12);
    }

    #[test]
    fn what_if_scales_service_not_queueing() {
        let r = extract(&demo_log());
        let w = what_if(&r, Speedup::Generation(2.0));
        // gen 2s → 1s; everything else (incl. the 0.5s kv queue) stays.
        assert!((w.predicted_s - 8.0).abs() < 1e-12, "{w:?}");
        assert!((w.predicted_speedup() - 9.0 / 8.0).abs() < 1e-12);
        assert!((w.saved_s() - 1.0).abs() < 1e-12);
        // Queueing is never scaled.
        let wk = what_if(&r, Speedup::KvHop(1e9));
        assert!((wk.predicted_s - 8.5).abs() < 1e-9, "{wk:?}");
        // A kind absent from the path predicts no change.
        let wp = what_if(&r, Speedup::Prefill(2.0));
        assert_eq!(wp.predicted_s, wp.baseline_s);
    }

    #[test]
    fn rank_orders_by_predicted_makespan() {
        let r = extract(&demo_log());
        let ranked = rank_what_if(&r, 2.0);
        assert_eq!(ranked[0].speedup.kind(), EdgeKind::Train, "3s on path");
        assert!(ranked.windows(2).all(|w| w[0].predicted_s <= w[1].predicted_s));
    }

    #[test]
    fn report_json_is_well_formed_enough() {
        let r = extract(&demo_log());
        let j = r.to_json();
        assert!(j.contains("\"makespan_s\""));
        assert!(j.contains("\"kv-hop\""));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn empty_log_yields_empty_report() {
        let r = extract(&[]);
        assert_eq!(r, CritPathReport::default());
        assert_eq!(what_if(&r, Speedup::Decode(2.0)).predicted_s, 0.0);
    }
}
