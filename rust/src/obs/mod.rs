//! Telemetry plane: event tracing, counter sampling, and bubble
//! attribution for the DES.
//!
//! The paper's central claim is that naive disaggregation loses
//! throughput to *resource bubbles*; until this plane existed the DES
//! could only report end-of-run aggregates — nobody could see *where*
//! an engine's idle seconds went.  Three pieces fix that:
//!
//! * [`TraceRecorder`] — a zero-cost-when-disabled span/counter
//!   recorder the drivers thread their phase changes, engine windows,
//!   link transfers and weight buckets through, exported as
//!   deterministic Chrome-trace JSON ([`TraceRecorder::to_chrome_json`])
//!   openable in `chrome://tracing` or Perfetto (pid = pool/engine,
//!   tid = trajectory).
//! * the **counter catalog** ([`CTR_ENGINES_BUSY`] and friends) — a
//!   sim-time-sampled gauge registry the driver emits at every
//!   iteration boundary (engine utilization, queue depths, link
//!   occupancy, version lag).
//! * [`BubbleReport`] — an always-on decomposition of each engine's
//!   idle time into named causes ([`BubbleCause`]), surfaced on
//!   [`ScenarioResult`](crate::sim::ScenarioResult) and cross-checked
//!   against [`WeightSyncReport`](crate::weights::WeightSyncReport)
//!   and KV-link totals (see `tests/obs_plane.rs`).
//! * the **critical-path plane** ([`critpath`]) — causal event
//!   provenance over the DES ([`crate::simkit::EventQueue::enable_provenance`])
//!   turned into per-iteration blame tables ([`CritPathReport`]) and a
//!   re-simulation-validated [`what_if`] estimator: which dependency
//!   chain bounds the iteration, and what a stage speedup would buy.
//!
//! The disabled recorder is a no-op: a determinism test pins traced
//! and untraced runs to bit-identical `ScenarioResult`s.  See
//! `docs/OBSERVABILITY.md` for the guided tour.

mod bubble;
pub mod critpath;
mod trace;

pub use bubble::{BubbleCause, BubbleReport};
pub use critpath::{
    extract as extract_critpath, rank_what_if, synthesize as synthesize_critpath, what_if,
    CritPathReport, EdgeBlame, EdgeKind, IterPath, PathBreakdown, PathNode, Speedup, TrajBlame,
    WhatIf,
};
pub use trace::{TraceEvent, TraceRecorder};

// ---- trace-process layout (pid scheme) ------------------------------

/// Driver/trainer process: train spans, fleet-drain spans, counters.
pub const PID_DRIVER: u64 = 0;
/// Trajectory process: one tid per trajectory, spans per lifecycle
/// phase visit.
pub const PID_TRAJ: u64 = 1;
/// The PD KV link: one tid per transfer slot (forward), slots + s for
/// reverse slot s.
pub const PID_KV_LINK: u64 = 2;
/// The weight fan-out link: bucketized pull transfers, per slot.
pub const PID_WEIGHT_LINK: u64 = 3;
/// Engines: engine `e` traces under pid `PID_ENGINE_BASE + e`.
pub const PID_ENGINE_BASE: u64 = 100;

// ---- counter catalog (documented in docs/OBSERVABILITY.md) ----------

/// Engines currently mid-step (gauge, sampled at iteration boundaries).
pub const CTR_ENGINES_BUSY: &str = "engines_busy";
/// Live (not down/retired) engines.
pub const CTR_ENGINES_LIVE: &str = "engines_live";
/// Non-terminal trajectories in flight.
pub const CTR_ACTIVE_TRAJ: &str = "active_trajectories";
/// Requests parked by a suspended proxy / dead pool.
pub const CTR_PENDING_REQS: &str = "pending_requests";
/// Events waiting in the simulation queue.
pub const CTR_QUEUE_DEPTH: &str = "event_queue_depth";
/// Worst live-engine weight-version lag behind the trainer.
pub const CTR_VERSION_LAG_MAX: &str = "version_lag_max";
/// Cumulative KV-link queue delay (occupancy proxy), seconds.
pub const CTR_KV_QUEUE_DELAY: &str = "kv_link_queue_delay_s";
/// Cumulative weight fan-out link queue delay, seconds.
pub const CTR_WLINK_QUEUE_DELAY: &str = "weight_link_queue_delay_s";
/// Trace-replay plane: requests offered by the arrival process so far.
pub const CTR_TRACE_OFFERED: &str = "trace_offered";
/// Trace-replay plane: offered requests shed by the admission cap.
pub const CTR_TRACE_SHED: &str = "trace_shed";

// Per-GPU-class rows (heterogeneous fleet plane): one gauge per class
// present in the fleet, named `<prefix><class>` (e.g.
// `class_live_H20`).  Classes appear and disappear as the elastic
// controller repurposes engines, so the rows are emitted from the
// live fleet scan, not a fixed catalog.

/// Live engines of one GPU class (prefix; suffixed with the class name).
pub const CTR_CLASS_LIVE_PREFIX: &str = "class_live_";
/// Engines of one class currently mid-step.
pub const CTR_CLASS_BUSY_PREFIX: &str = "class_busy_";
/// Outstanding prefill+decode tokens queued on one class's engines.
pub const CTR_CLASS_BACKLOG_PREFIX: &str = "class_backlog_tokens_";
