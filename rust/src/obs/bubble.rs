//! Idle-bubble attribution: where did each engine's idle seconds go?

use std::fmt;

/// Why an engine sat idle during a bubble window.
///
/// A window opens when an engine finishes a step (or comes up) with no
/// admissible work and closes when work lands on it.  Windows that
/// overlap a weight cutover/drain are bracketed as `AwaitingWeights`
/// exactly; the generic windows are attributed by what *ended* them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BubbleCause {
    /// Engine suspended for a weight cutover, or parked behind a
    /// blocking fleet drain + broadcast.
    AwaitingWeights,
    /// The work that ended the bubble arrived off the PD KV link
    /// (prefill→decode handoff in flight).
    KvQueue,
    /// Default: waiting on environment steps / resets / rewards to
    /// produce the next admissible turn.
    #[default]
    EnvWait,
    /// The work that ended the bubble was parked in the admission
    /// buffer (suspended proxy or dead pool) rather than in flight.
    StarvedAdmission,
}

impl BubbleCause {
    /// Stable label used in trace span names and CSV columns.
    pub fn label(self) -> &'static str {
        match self {
            BubbleCause::AwaitingWeights => "awaiting-weights",
            BubbleCause::KvQueue => "kv-queue",
            BubbleCause::EnvWait => "env-wait",
            BubbleCause::StarvedAdmission => "starved-admission",
        }
    }
}

impl fmt::Display for BubbleCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Decomposition of fleet idle time into named causes.
///
/// Attribution is *always on* (it costs a couple of vector reads per
/// engine kick) so traced and untraced runs stay bit-identical.  The
/// four cause fields partition [`BubbleReport::engine_idle_s`]; the
/// `*_booked_s` mirror is accumulated at grant-admission time and
/// cross-checks the window accounting against the link's own stats
/// (see `tests/obs_plane.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BubbleReport {
    /// Total engine idle seconds observed via bubble windows
    /// (summed over engines; excludes downtime).
    pub engine_idle_s: f64,
    /// Idle under a weight cutover / blocking sync drain.
    pub awaiting_weights_s: f64,
    /// Idle ended by work arriving off the KV link.
    pub kv_queue_s: f64,
    /// Idle waiting on env/reward progress (the default cause).
    pub env_wait_s: f64,
    /// Idle ended by previously-parked (inadmissible) work.
    pub starved_admission_s: f64,
    /// Number of non-zero-length bubble windows closed.
    pub windows: u64,
    /// KV-link queue delay booked per forward grant at admission time;
    /// mirrors `KvLinkReport::queue_delay_total_s` when the link is
    /// not shared with the weight plane or reverse traffic.
    pub kv_queue_booked_s: f64,
}

impl BubbleReport {
    /// Book a closed window.
    pub fn book(&mut self, cause: BubbleCause, dur_s: f64) {
        if dur_s <= 0.0 {
            return;
        }
        self.engine_idle_s += dur_s;
        self.windows += 1;
        match cause {
            BubbleCause::AwaitingWeights => self.awaiting_weights_s += dur_s,
            BubbleCause::KvQueue => self.kv_queue_s += dur_s,
            BubbleCause::EnvWait => self.env_wait_s += dur_s,
            BubbleCause::StarvedAdmission => self.starved_admission_s += dur_s,
        }
    }

    /// Sum of the four cause fields; equals
    /// [`BubbleReport::engine_idle_s`] up to fp rounding.
    pub fn attributed_s(&self) -> f64 {
        self.awaiting_weights_s + self.kv_queue_s + self.env_wait_s + self.starved_admission_s
    }

    /// Fraction of idle time attributed to `cause` (0 when no idle).
    pub fn fraction(&self, cause: BubbleCause) -> f64 {
        if self.engine_idle_s <= 0.0 {
            return 0.0;
        }
        let part = match cause {
            BubbleCause::AwaitingWeights => self.awaiting_weights_s,
            BubbleCause::KvQueue => self.kv_queue_s,
            BubbleCause::EnvWait => self.env_wait_s,
            BubbleCause::StarvedAdmission => self.starved_admission_s,
        };
        part / self.engine_idle_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn causes_partition_idle() {
        let mut b = BubbleReport::default();
        b.book(BubbleCause::AwaitingWeights, 2.0);
        b.book(BubbleCause::KvQueue, 1.0);
        b.book(BubbleCause::EnvWait, 3.0);
        b.book(BubbleCause::StarvedAdmission, 0.5);
        b.book(BubbleCause::EnvWait, 0.0); // zero-length: ignored
        assert_eq!(b.windows, 4);
        assert!((b.attributed_s() - b.engine_idle_s).abs() < 1e-12);
        assert!((b.engine_idle_s - 6.5).abs() < 1e-12);
        assert!((b.fraction(BubbleCause::AwaitingWeights) - 2.0 / 6.5).abs() < 1e-12);
    }

    #[test]
    fn empty_report_fractions_are_zero() {
        let b = BubbleReport::default();
        assert_eq!(b.fraction(BubbleCause::KvQueue), 0.0);
        assert_eq!(b.attributed_s(), 0.0);
    }
}
