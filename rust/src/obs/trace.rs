//! Span/counter recorder with deterministic Chrome-trace JSON export.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// One recorded trace event.
///
/// Timestamps are kept in simulation **seconds** (f64) so in-process
/// consumers (e.g. the `fig_phases` bench rebuilding phase residency)
/// see exactly the values the driver computed; conversion to integer
/// microseconds happens only at JSON export.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Chrome-trace phase: `'X'` complete span, `'C'` counter,
    /// `'i'` instant, `'M'` process-name metadata.
    pub ph: char,
    pub pid: u64,
    pub tid: u64,
    pub name: String,
    pub cat: &'static str,
    /// Span/instant/counter timestamp, simulation seconds.
    pub start_s: f64,
    /// Span duration, simulation seconds (`'X'` only).
    pub dur_s: f64,
    /// Counter value (`'C'` only).
    pub value: f64,
}

/// Records simulation spans and counters; exports Chrome-trace JSON.
///
/// The recorder is the single hook the drivers thread their telemetry
/// through.  A [`TraceRecorder::disabled`] recorder ignores every call
/// (one branch per call site), so instrumentation is always compiled
/// in but free when unused.
///
/// # Worked example
///
/// Record a tiny timeline by hand and export it:
///
/// ```
/// use rollart::obs::{TraceRecorder, PID_ENGINE_BASE};
///
/// let mut rec = TraceRecorder::enabled();
/// rec.process_name(PID_ENGINE_BASE, "engine-0 (H800)");
/// // engine busy from t=1.0s for 2.5s, then an idle bubble
/// rec.span(PID_ENGINE_BASE, 0, "step", "engine", 1.0, 2.5);
/// rec.span(PID_ENGINE_BASE, 0, "idle:env-wait", "bubble", 3.5, 0.5);
/// rec.counter(0, "engines_busy", 1.0, 1.0);
///
/// let json = rec.to_chrome_json();
/// // valid JSON (checked with the in-tree parser), openable in
/// // chrome://tracing or https://ui.perfetto.dev
/// let doc = rollart::util::json::Json::parse(&json).unwrap();
/// let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
/// assert_eq!(events.len(), 4);
/// // spans carry integer-microsecond timestamps
/// assert_eq!(doc.at("traceEvents.1.ts").unwrap().as_f64(), Some(1_000_000.0));
/// assert_eq!(doc.at("traceEvents.1.dur").unwrap().as_f64(), Some(2_500_000.0));
/// ```
///
/// In the simulator you never build spans by hand: pass an enabled
/// recorder to `sim::driver::run_with_trace` (or
/// `sim::sync_driver::run_with_trace`) and write the result with
/// [`TraceRecorder::write_json`].
#[derive(Debug, Default)]
pub struct TraceRecorder {
    on: bool,
    events: Vec<TraceEvent>,
}

impl TraceRecorder {
    /// A recorder that drops every event (the zero-cost default).
    pub fn disabled() -> Self {
        TraceRecorder {
            on: false,
            events: Vec::new(),
        }
    }

    /// A recorder that keeps everything for export.
    pub fn enabled() -> Self {
        TraceRecorder {
            on: true,
            events: Vec::new(),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.on
    }

    /// Record a complete span `[start_s, start_s + dur_s]`.
    ///
    /// Negative durations are clamped to zero (a span must not end
    /// before it starts; clamping keeps fp jitter out of the export).
    pub fn span(
        &mut self,
        pid: u64,
        tid: u64,
        name: &str,
        cat: &'static str,
        start_s: f64,
        dur_s: f64,
    ) {
        if !self.on {
            return;
        }
        self.events.push(TraceEvent {
            ph: 'X',
            pid,
            tid,
            name: name.to_string(),
            cat,
            start_s,
            dur_s: dur_s.max(0.0),
            value: 0.0,
        });
    }

    /// Record an instant marker.
    pub fn instant(&mut self, pid: u64, tid: u64, name: &str, cat: &'static str, t_s: f64) {
        if !self.on {
            return;
        }
        self.events.push(TraceEvent {
            ph: 'i',
            pid,
            tid,
            name: name.to_string(),
            cat,
            start_s: t_s,
            dur_s: 0.0,
            value: 0.0,
        });
    }

    /// Record a counter sample (rendered as a track in chrome://tracing).
    pub fn counter(&mut self, pid: u64, name: &str, t_s: f64, value: f64) {
        if !self.on {
            return;
        }
        self.events.push(TraceEvent {
            ph: 'C',
            pid,
            tid: 0,
            name: name.to_string(),
            cat: "counter",
            start_s: t_s,
            dur_s: 0.0,
            value,
        });
    }

    /// Name a trace process (`pid`) for the viewer's sidebar.
    pub fn process_name(&mut self, pid: u64, name: &str) {
        if !self.on {
            return;
        }
        self.events.push(TraceEvent {
            ph: 'M',
            pid,
            tid: 0,
            name: name.to_string(),
            cat: "__metadata",
            start_s: 0.0,
            dur_s: 0.0,
            value: 0.0,
        });
    }

    /// Name one thread (`pid`, `tid`) for the viewer's sidebar — the
    /// per-track label inside a process (trajectory rows, link slots,
    /// the trainer lane).  Distinguished from [`TraceRecorder::process_name`]
    /// by category at export time, where it becomes a Perfetto
    /// `thread_name` metadata event carrying the tid.
    pub fn thread_name(&mut self, pid: u64, tid: u64, name: &str) {
        if !self.on {
            return;
        }
        self.events.push(TraceEvent {
            ph: 'M',
            pid,
            tid,
            name: name.to_string(),
            cat: "__metadata_thread",
            start_s: 0.0,
            dur_s: 0.0,
            value: 0.0,
        });
    }

    /// All recorded events, in emission order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Serialize to Chrome-trace JSON (the `{"traceEvents": [...]}`
    /// form).  Timestamps are integer microseconds; output is fully
    /// deterministic for a deterministic simulation run, so repeated
    /// seeded runs export byte-identical files.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.events.len() * 96);
        out.push_str("{\"traceEvents\":[");
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            match e.ph {
                'M' => {
                    let meta = if e.cat == "__metadata_thread" {
                        "thread_name"
                    } else {
                        "process_name"
                    };
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\
                         \"args\":{{\"name\":\"{}\"}}}}",
                        meta,
                        e.pid,
                        e.tid,
                        escape(&e.name)
                    );
                }
                'C' => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"C\",\"pid\":{},\"tid\":0,\
                         \"ts\":{},\"args\":{{\"value\":{}}}}}",
                        escape(&e.name),
                        escape(e.cat),
                        e.pid,
                        micros(e.start_s),
                        num(e.value)
                    );
                }
                'i' => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\
                         \"pid\":{},\"tid\":{},\"ts\":{}}}",
                        escape(&e.name),
                        escape(e.cat),
                        e.pid,
                        e.tid,
                        micros(e.start_s)
                    );
                }
                _ => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\
                         \"ts\":{},\"dur\":{}}}",
                        escape(&e.name),
                        escape(e.cat),
                        e.pid,
                        e.tid,
                        micros(e.start_s),
                        micros(e.dur_s)
                    );
                }
            }
        }
        out.push_str("],\"displayTimeUnit\":\"ms\"}");
        out
    }

    /// Write the Chrome-trace JSON to `path` (creating parent dirs).
    pub fn write_json(&self, path: &Path) -> io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_chrome_json())
    }
}

/// Simulation seconds → integer microseconds (Chrome-trace `ts`/`dur`).
fn micros(s: f64) -> i64 {
    (s * 1e6).round() as i64
}

/// Deterministic numeric formatting for counter values.
fn num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn disabled_recorder_is_a_no_op() {
        let mut rec = TraceRecorder::disabled();
        rec.span(1, 2, "x", "c", 0.0, 1.0);
        rec.counter(0, "n", 0.0, 3.0);
        rec.instant(0, 0, "i", "c", 0.5);
        rec.process_name(0, "p");
        rec.thread_name(0, 1, "t");
        assert!(rec.is_empty());
        assert_eq!(
            rec.to_chrome_json(),
            "{\"traceEvents\":[],\"displayTimeUnit\":\"ms\"}"
        );
    }

    #[test]
    fn export_is_valid_json_with_microsecond_timestamps() {
        let mut rec = TraceRecorder::enabled();
        rec.process_name(100, "engine \"zero\"");
        rec.span(100, 7, "step", "engine", 1.5, 0.25);
        rec.counter(0, "depth", 2.0, 5.0);
        rec.instant(0, 0, "publish", "weights", 2.5);
        let json = rec.to_chrome_json();
        let doc = Json::parse(&json).expect("export parses");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 4);
        assert_eq!(
            doc.at("traceEvents.0.args.name").unwrap().as_str(),
            Some("engine \"zero\"")
        );
        assert_eq!(doc.at("traceEvents.1.ts").unwrap().as_f64(), Some(1_500_000.0));
        assert_eq!(doc.at("traceEvents.1.dur").unwrap().as_f64(), Some(250_000.0));
        assert_eq!(doc.at("traceEvents.1.tid").unwrap().as_usize(), Some(7));
        assert_eq!(doc.at("traceEvents.2.args.value").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn thread_name_metadata_carries_the_tid() {
        let mut rec = TraceRecorder::enabled();
        rec.process_name(2, "kv-link");
        rec.thread_name(2, 5, "slot 2 (reverse)");
        let json = rec.to_chrome_json();
        let doc = Json::parse(&json).expect("export parses");
        assert_eq!(
            doc.at("traceEvents.0.name").unwrap().as_str(),
            Some("process_name")
        );
        assert_eq!(
            doc.at("traceEvents.1.name").unwrap().as_str(),
            Some("thread_name")
        );
        assert_eq!(doc.at("traceEvents.1.tid").unwrap().as_usize(), Some(5));
        assert_eq!(
            doc.at("traceEvents.1.args.name").unwrap().as_str(),
            Some("slot 2 (reverse)")
        );
    }

    #[test]
    fn negative_durations_clamped() {
        let mut rec = TraceRecorder::enabled();
        rec.span(0, 0, "x", "c", 1.0, -0.5);
        assert_eq!(rec.events()[0].dur_s, 0.0);
    }

    #[test]
    fn export_is_deterministic() {
        let build = || {
            let mut rec = TraceRecorder::enabled();
            for i in 0..10 {
                rec.span(1, i, "phase", "traj", i as f64 * 0.1, 0.05);
                rec.counter(0, "g", i as f64, (i as f64) / 3.0);
            }
            rec.to_chrome_json()
        };
        assert_eq!(build(), build());
    }
}
