//! `rollart` — launcher CLI for the RollArt coordinator.
//!
//! Subcommands:
//!   simulate   run a scenario on the DES harness (default)
//!   train      real training through the PJRT runtime (needs artifacts)
//!   trace      production workload characterization (§8)
//!
//! Examples:
//!   rollart simulate --model qwen3-8b --mode rollart --alpha 1
//!   rollart simulate --config scenario.json
//!   rollart train --steps 50 --env echo
//!   rollart trace --trajectories 20000

use rollart::baselines;
use rollart::config::{mode_by_name, model_by_name, scenario_from_json};
use rollart::sim::Scenario;
use rollart::trace;
use rollart::util::cli::Args;

fn simulate(args: &Args) {
    let scenario = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).expect("read --config file");
        scenario_from_json(&text).expect("parse config")
    } else {
        let model = model_by_name(args.get_or("model", "qwen3-8b")).expect("unknown --model");
        let mode = mode_by_name(args.get_or("mode", "rollart")).expect("unknown --mode");
        let mut s = Scenario::rollart_default(model, args.get_f64("scale", 0.25));
        s = baselines::configure(&s, mode);
        s.alpha = args.get_usize("alpha", 1) as u64;
        s.iterations = args.get_usize("iterations", 5);
        s.seed = args.get_usize("seed", 17) as u64;
        s
    };
    println!(
        "simulating {} on {} ({} iterations, alpha {})",
        scenario.mode.name(),
        scenario.model.name,
        scenario.iterations,
        scenario.alpha
    );
    let r = baselines::run(&scenario);
    for (i, s) in r.steps.iter().enumerate() {
        println!(
            "  iter {i}: {:>8.1}s  (train {:.1}s, sync {:.1}s, wait {:.1}s, stale {}, tokens {:.0})",
            s.step_time_s,
            s.breakdown.train_s,
            s.breakdown.weight_sync_s,
            s.breakdown.get_batch_wait_s,
            s.stale_aborts,
            s.batch_tokens
        );
    }
    println!(
        "mean step {:.1}s  throughput {:.0} tok/s  gen util {:.0}%  reward util {:.0}%",
        r.mean_step_time(),
        r.throughput(),
        100.0 * r.gen_util,
        100.0 * r.reward_util
    );
}

fn real_train(args: &Args) {
    use rollart::env::{EchoEnv, Environment, FrozenLake, GemMath};
    use rollart::exec::{train, TrainConfig};
    let rt = rollart::runtime::Runtime::load_default()
        .expect("artifacts missing — run `make artifacts`");
    let env = args.get_or("env", "echo").to_string();
    let make_env: Box<dyn Fn() -> Box<dyn Environment>> = match env.as_str() {
        "echo" => Box::new(|| Box::new(EchoEnv::new()) as _),
        "math" => Box::new(|| Box::new(GemMath::single_turn()) as _),
        "frozenlake" => Box::new(|| Box::new(FrozenLake::new(4, false)) as _),
        other => panic!("--env {other}: use echo | math | frozenlake"),
    };
    let cfg = TrainConfig {
        steps: args.get_usize("steps", 20),
        groups_per_step: args.get_usize("groups", 1),
        lr: args.get_f64("lr", 2e-3) as f32,
        ..TrainConfig::default()
    };
    let (logs, _) = train(&rt, &cfg, make_env.as_ref()).expect("training");
    for l in &logs {
        println!(
            "step {:>4}: loss {:>8.4} entropy {:.3} reward {:.3}",
            l.step, l.loss, l.entropy, l.mean_reward
        );
    }
}

fn run_trace(args: &Args) {
    let n = args.get_usize("trajectories", 20_000);
    let records = trace::generate(&trace::prod_families(), n, 15);
    let s = trace::analyze(&records);
    println!("{n} trajectories:");
    println!("  turns 1..{} (mean {:.1})", s.max_turns, s.mean_turns);
    println!(
        "  responses mean {:.0} max {:.0} (tail ratio {:.1}x)",
        s.mean_response, s.max_response, s.response_tail_ratio
    );
}

fn main() {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        None | Some("simulate") => simulate(&args),
        Some("train") => real_train(&args),
        Some("trace") => run_trace(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'; use simulate | train | trace");
            std::process::exit(2);
        }
    }
}
