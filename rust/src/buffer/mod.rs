//! SampleBuffer: scored-trajectory buffering with bounded staleness.
//!
//! The control-plane component behind protocol step ① (`get_batch`) and
//! the asynchronous bound α (§6.2):
//!
//! * scored trajectories are deposited as they finish (trajectory-level
//!   rollout, R2);
//! * before a batch is formed, trajectories outside the α-window are
//!   *eagerly evicted* (aborted), so out-of-order completion cannot
//!   grow the buffer beyond O(α · E) with E concurrent environments;
//! * eviction policy is selectable: RollArt checks every turn's version
//!   (footnote 1), AReaL-style only the start version.

use crate::rl::{Trajectory, Version};

/// Which staleness test evicts (RollArt vs AReaL semantics, §7.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalenessPolicy {
    /// Every turn's version must be within the window (RollArt).
    PerTurn,
    /// Only the start version is bounded (AReaL re-implementation).
    AtStart,
}

/// Buffer statistics (reported by benches and the production trace).
#[derive(Clone, Copy, Debug, Default)]
pub struct BufferStats {
    pub deposited: u64,
    pub evicted_stale: u64,
    pub consumed: u64,
    pub peak_len: usize,
}

/// The scored-trajectory buffer.
#[derive(Debug)]
pub struct SampleBuffer {
    items: Vec<Trajectory>,
    alpha: u64,
    policy: StalenessPolicy,
    stats: BufferStats,
    /// Evict whole GRPO groups together (see [`SampleBuffer::set_group_aware`]).
    group_aware: bool,
}

impl SampleBuffer {
    pub fn new(alpha: u64, policy: StalenessPolicy) -> Self {
        SampleBuffer {
            items: Vec::new(),
            alpha,
            policy,
            stats: BufferStats::default(),
            group_aware: false,
        }
    }

    /// GRPO's advantage baseline is the *group* mean/std, so a batch
    /// containing a partial group is statistically wrong.  With
    /// group-aware eviction on, a stale member drags its whole group
    /// out of the buffer rather than leaving group-mates behind to
    /// form a partial group (the lost prompt is made up by the
    /// driver's normal concurrency refill).  Off by default for
    /// ungrouped uses (all-zero group ids would collapse into one
    /// giant group); the async driver enables it for Mode::RollArt.
    pub fn set_group_aware(&mut self, on: bool) -> &mut Self {
        self.group_aware = on;
        self
    }

    pub fn alpha(&self) -> u64 {
        self.alpha
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn stats(&self) -> BufferStats {
        self.stats
    }

    fn fresh(&self, t: &Trajectory, current: Version) -> bool {
        match self.policy {
            StalenessPolicy::PerTurn => t.fresh_rollart(current, self.alpha),
            StalenessPolicy::AtStart => t.fresh_areal(current, self.alpha),
        }
    }

    /// Deposit a scored trajectory.  A trajectory already outside the
    /// window at deposit time is dropped immediately (counted as
    /// evicted) — the paper aborts such trajectories at the source.
    pub fn deposit(&mut self, traj: Trajectory, current: Version) -> bool {
        assert!(traj.is_scored(), "only scored trajectories enter the buffer");
        self.stats.deposited += 1;
        if !self.fresh(&traj, current) {
            self.stats.evicted_stale += 1;
            return false;
        }
        self.items.push(traj);
        self.stats.peak_len = self.stats.peak_len.max(self.items.len());
        true
    }

    /// Deposit a filled GRPO group *atomically*: either every member
    /// enters the buffer or none does (counted as evicted).  Without
    /// this, one member going stale between scoring and deposit leaves
    /// a partial group in the buffer — a batch formed from it would
    /// compute group advantages against an incomplete baseline.
    pub fn deposit_group(&mut self, trajs: Vec<Trajectory>, current: Version) -> bool {
        for t in &trajs {
            assert!(t.is_scored(), "only scored trajectories enter the buffer");
        }
        self.stats.deposited += trajs.len() as u64;
        if !trajs.iter().all(|t| self.fresh(t, current)) {
            self.stats.evicted_stale += trajs.len() as u64;
            return false;
        }
        self.items.extend(trajs);
        self.stats.peak_len = self.stats.peak_len.max(self.items.len());
        true
    }

    /// Eagerly evict stale trajectories at the current version (called
    /// by `get_batch` before forming a batch, §6.2).  In group-aware
    /// mode a stale member evicts its whole group.
    pub fn evict_stale(&mut self, current: Version) -> usize {
        let before = self.items.len();
        let alpha = self.alpha;
        let policy = self.policy;
        let fresh = |t: &Trajectory| match policy {
            StalenessPolicy::PerTurn => t.fresh_rollart(current, alpha),
            StalenessPolicy::AtStart => t.fresh_areal(current, alpha),
        };
        if self.group_aware {
            let stale_groups: std::collections::BTreeSet<u64> = self
                .items
                .iter()
                .filter(|&t| !fresh(t))
                .map(|t| t.group)
                .collect();
            self.items
                .retain(|t| fresh(t) && !stale_groups.contains(&t.group));
        } else {
            self.items.retain(fresh);
        }
        let evicted = before - self.items.len();
        self.stats.evicted_stale += evicted as u64;
        evicted
    }

    /// Protocol step ①: take `n` trajectories if available after stale
    /// eviction; oldest-first (FIFO) to bound trajectory latency.
    /// Returns `None` when fewer than `n` fresh trajectories are ready
    /// (the caller blocks / keeps rolling out).
    pub fn get_batch(&mut self, n: usize, current: Version) -> Option<Vec<Trajectory>> {
        self.evict_stale(current);
        if self.items.len() < n {
            return None;
        }
        let batch: Vec<Trajectory> = self.items.drain(..n).collect();
        self.stats.consumed += n as u64;
        Some(batch)
    }

    /// Upper bound on pending trajectories: O(α · E) (§6.2).
    pub fn capacity_bound(&self, concurrent_envs: usize) -> usize {
        ((self.alpha + 1) as usize) * concurrent_envs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::TaskDomain;
    use crate::rl::{TrajectoryId, Turn};

    fn scored(id: u64, start: u64, turn_versions: &[u64]) -> Trajectory {
        let mut t =
            Trajectory::new(TrajectoryId(id), TaskDomain::MathTool, Version(start));
        for &v in turn_versions {
            t.turns.push(Turn {
                obs_tokens: vec![0],
                action_tokens: vec![1],
                version: Version(v),
            });
        }
        t.reward = Some(1.0);
        t
    }

    #[test]
    fn get_batch_blocks_until_enough() {
        let mut b = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        b.deposit(scored(0, 5, &[5]), Version(5));
        assert!(b.get_batch(2, Version(5)).is_none());
        b.deposit(scored(1, 5, &[5]), Version(5));
        let batch = b.get_batch(2, Version(5)).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn fifo_order() {
        let mut b = SampleBuffer::new(4, StalenessPolicy::PerTurn);
        for i in 0..4 {
            b.deposit(scored(i, 1, &[1]), Version(1));
        }
        let batch = b.get_batch(2, Version(1)).unwrap();
        assert_eq!(batch[0].id.0, 0);
        assert_eq!(batch[1].id.0, 1);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn eager_eviction_on_get_batch() {
        let mut b = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        b.deposit(scored(0, 3, &[3]), Version(3)); // stale at v5 (α=1)
        b.deposit(scored(1, 4, &[4]), Version(4)); // fresh at v5
        b.deposit(scored(2, 5, &[5]), Version(5));
        assert_eq!(b.len(), 3);
        let batch = b.get_batch(2, Version(5)).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].id.0, 1);
        assert_eq!(b.stats().evicted_stale, 1);
    }

    #[test]
    fn deposit_rejects_already_stale() {
        let mut b = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        assert!(!b.deposit(scored(0, 1, &[1]), Version(5)));
        assert!(b.is_empty());
        assert_eq!(b.stats().evicted_stale, 1);
    }

    #[test]
    fn per_turn_vs_at_start_policies_differ() {
        // Trajectory started fresh (v4) but carries a v3 turn.
        let t = scored(0, 4, &[3, 4]);
        let mut rollart = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        let mut areal = SampleBuffer::new(1, StalenessPolicy::AtStart);
        assert!(!rollart.deposit(t.clone(), Version(5)));
        assert!(areal.deposit(t, Version(5)));
    }

    #[test]
    fn capacity_bound_formula() {
        let b = SampleBuffer::new(2, StalenessPolicy::PerTurn);
        assert_eq!(b.capacity_bound(128), 384);
    }

    #[test]
    fn buffer_growth_is_bounded_under_version_advance() {
        // Property: with eviction at every version bump, the buffer
        // never exceeds the O(α·E) bound even with adversarial deposit
        // timing across E simulated envs.
        let e = 16;
        let alpha = 2;
        let mut b = SampleBuffer::new(alpha, StalenessPolicy::PerTurn);
        let mut id = 0;
        for v in 0..50u64 {
            let current = Version(v);
            b.evict_stale(current);
            // each env deposits one trajectory started up to α back
            for env in 0..e {
                let start = v.saturating_sub((env as u64) % (alpha + 1));
                b.deposit(scored(id, start, &[start]), current);
                id += 1;
            }
            assert!(
                b.len() <= b.capacity_bound(e),
                "v{v}: {} > bound {}",
                b.len(),
                b.capacity_bound(e)
            );
            // trainer consumes what it can
            let _ = b.get_batch(e, current);
        }
    }

    #[test]
    #[should_panic(expected = "scored")]
    fn unscored_deposit_panics() {
        let mut b = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        let t = Trajectory::new(TrajectoryId(9), TaskDomain::Web, Version(0));
        b.deposit(t, Version(0));
    }

    fn scored_in_group(id: u64, group: u64, start: u64, turn_versions: &[u64]) -> Trajectory {
        let mut t = scored(id, start, turn_versions);
        t.group = group;
        t
    }

    #[test]
    fn alpha_zero_admits_only_current_version() {
        // α = 0: the fully-synchronous corner — anything not generated
        // at the current version is already stale.
        let mut b = SampleBuffer::new(0, StalenessPolicy::PerTurn);
        assert!(b.deposit(scored(0, 5, &[5]), Version(5)));
        assert!(!b.deposit(scored(1, 4, &[4]), Version(5)));
        assert_eq!(b.len(), 1);
        // The survivor dies as soon as the version advances.
        assert!(b.get_batch(1, Version(6)).is_none());
        assert!(b.is_empty());
        assert_eq!(b.stats().evicted_stale, 2);
    }

    #[test]
    fn batch_larger_than_buffer_blocks_without_draining() {
        let mut b = SampleBuffer::new(2, StalenessPolicy::PerTurn);
        for i in 0..3 {
            b.deposit(scored(i, 1, &[1]), Version(1));
        }
        assert!(b.get_batch(4, Version(1)).is_none());
        assert_eq!(b.len(), 3, "a blocked get_batch must not consume items");
        assert_eq!(b.stats().consumed, 0);
    }

    #[test]
    fn group_aware_eviction_takes_the_whole_group() {
        // Group 0 has one member with a stale turn; group 1 is fully
        // fresh.  Group-aware eviction removes *both* members of group
        // 0 — a partial group would corrupt the GRPO baseline.
        let mut b = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        b.set_group_aware(true);
        b.deposit(scored_in_group(0, 0, 3, &[3]), Version(4)); // stale at v5
        b.deposit(scored_in_group(1, 0, 4, &[4]), Version(4)); // fresh at v5
        b.deposit(scored_in_group(2, 1, 4, &[4]), Version(4));
        b.deposit(scored_in_group(3, 1, 5, &[5]), Version(5));
        assert_eq!(b.evict_stale(Version(5)), 2, "group 0 evicted whole");
        let batch = b.get_batch(2, Version(5)).unwrap();
        assert!(batch.iter().all(|t| t.group == 1));
    }

    #[test]
    fn group_deposit_is_atomic() {
        let mut b = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        let stale_group = vec![
            scored_in_group(0, 7, 5, &[5]),
            scored_in_group(1, 7, 3, &[3]), // stale at v5
        ];
        assert!(!b.deposit_group(stale_group, Version(5)));
        assert!(b.is_empty(), "no partial group may enter");
        assert_eq!(b.stats().evicted_stale, 2);
        let fresh_group = vec![
            scored_in_group(2, 8, 5, &[5]),
            scored_in_group(3, 8, 4, &[4, 5]),
        ];
        assert!(b.deposit_group(fresh_group, Version(5)));
        assert_eq!(b.len(), 2);
        assert_eq!(b.stats().deposited, 4);
    }

    #[test]
    fn is_empty_tracks_lifecycle() {
        let mut b = SampleBuffer::new(1, StalenessPolicy::PerTurn);
        assert!(b.is_empty());
        b.deposit(scored(0, 1, &[1]), Version(1));
        assert!(!b.is_empty());
        b.get_batch(1, Version(1)).unwrap();
        assert!(b.is_empty());
    }
}
