//! Point-to-point link model.

use crate::simkit::dist::Dist;

/// A network path with an effective-bandwidth + setup-cost model.
///
/// `effective_bytes_per_s` is the *achieved* single-transfer goodput
/// (protocol stacks on these fabrics reach only a fraction of the raw
/// signalling rate for large sequential transfers; the constants below
/// are fit to the paper's Table 3 measurements).
#[derive(Clone, Debug)]
pub struct Link {
    pub name: &'static str,
    /// Raw signalling rate, Gbit/s (documentation only).
    pub raw_gbps: f64,
    /// Achieved goodput for bulk transfers, bytes/s.
    pub effective_bytes_per_s: f64,
    /// Per-transfer session setup cost, seconds.
    pub setup_s: f64,
    /// One-way base latency, seconds.
    pub latency_s: f64,
}

impl Link {
    /// Time to move `bytes` in one logical transfer.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        self.setup_s + self.latency_s + bytes / self.effective_bytes_per_s
    }

    /// Time to move `bytes` split into `streams` parallel streams that
    /// share the link fairly (setup paid once; bandwidth unchanged).
    pub fn transfer_time_streams(&self, bytes: f64, streams: usize) -> f64 {
        assert!(streams > 0);
        self.setup_s + self.latency_s + bytes / self.effective_bytes_per_s
            + (streams as f64 - 1.0) * 1e-4 // per-stream bookkeeping
    }
}

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Cross-cluster Ethernet (paper: 200 Gbps TCP).  Effective goodput fit
/// to Table 3: ≈2.06 GB/s single-stream.
pub static TCP_200GBE: Link = Link {
    name: "tcp-200gbe",
    raw_gbps: 200.0,
    effective_bytes_per_s: 2.06 * GB,
    setup_s: 0.10,
    latency_s: 0.002,
};

/// Cross-cluster InfiniBand (paper: 400 Gbps RDMA via Mooncake).
/// Higher goodput but heavier session establishment (QP setup +
/// registration), which is why small models see less speedup (Table 3).
pub static RDMA_400IB: Link = Link {
    name: "rdma-400ib",
    raw_gbps: 400.0,
    effective_bytes_per_s: 10.0 * GB,
    setup_s: 3.60,
    latency_s: 0.0005,
};

/// Intra-cluster NVLink/NVSwitch path for weight broadcast (NCCL).
pub static NVLINK_INTRA: Link = Link {
    name: "nvlink-intra",
    raw_gbps: 3600.0,
    effective_bytes_per_s: 250.0 * GB,
    setup_s: 0.005,
    latency_s: 0.00001,
};

/// Latency distribution for a *small-packet* control-path call
/// (trajectory transfer, serverless reward I/O): a tight body with a
/// rare heavy tail, calibrated to §7.5's (mean, max) pairs.
///
/// `mean_s` ≈ observed mean per-call overhead; `max_s` ≈ observed max.
pub fn jittered_small_transfer(mean_s: f64, max_s: f64) -> Dist {
    // Body: exponential around ~0.8·mean. Tail: uniform stretch toward
    // max, hit rarely enough to keep the mean at ~mean_s.
    let tail_lo = max_s * 0.25;
    let tail_mean = (tail_lo + max_s) / 2.0;
    let p_tail = (0.2 * mean_s / tail_mean).min(0.05);
    let body_mean = (mean_s - p_tail * tail_mean).max(mean_s * 0.1) / (1.0 - p_tail);
    Dist::Mix {
        p_tail,
        body: Box::new(Dist::Exp { mean: body_mean }),
        tail: Box::new(Dist::Uniform {
            lo: tail_lo,
            hi: max_s,
        }),
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::{QWEN3_14B, QWEN3_32B, QWEN3_8B};

    #[test]
    fn table3_shape_tcp_vs_rdma() {
        // The *shape* check: RDMA wins, and its advantage grows with
        // model size (paper: 1.264x -> 2.482x -> 3.140x).
        let mut last = 0.0;
        for spec in [&QWEN3_8B, &QWEN3_14B, &QWEN3_32B] {
            let tcp = TCP_200GBE.transfer_time(spec.weight_bytes());
            let rdma = RDMA_400IB.transfer_time(spec.weight_bytes());
            let speedup = tcp / rdma;
            assert!(speedup > 1.0, "{}: {speedup}", spec.name);
            assert!(speedup > last, "speedup must grow with size");
            last = speedup;
        }
        // 32B speedup is close to the paper's 3.14x
        let tcp = TCP_200GBE.transfer_time(QWEN3_32B.weight_bytes());
        let rdma = RDMA_400IB.transfer_time(QWEN3_32B.weight_bytes());
        assert!((tcp / rdma - 3.14).abs() < 0.5, "{}", tcp / rdma);
    }

    #[test]
    fn table3_absolute_times_are_in_range() {
        // Within ~25% of the paper's measured seconds.
        let cases = [
            (&QWEN3_8B, 6.911, 5.466),
            (&QWEN3_14B, 14.437, 5.817),
            (&QWEN3_32B, 29.649, 9.442),
        ];
        for (spec, tcp_paper, rdma_paper) in cases {
            let tcp = TCP_200GBE.transfer_time(spec.weight_bytes());
            let rdma = RDMA_400IB.transfer_time(spec.weight_bytes());
            assert!(
                (tcp - tcp_paper).abs() / tcp_paper < 0.25,
                "{} tcp {tcp} vs {tcp_paper}",
                spec.name
            );
            assert!(
                (rdma - rdma_paper).abs() / rdma_paper < 0.35,
                "{} rdma {rdma} vs {rdma_paper}",
                spec.name
            );
        }
    }

    #[test]
    fn nvlink_much_faster_than_cross_cluster() {
        let bytes = QWEN3_8B.weight_bytes();
        assert!(NVLINK_INTRA.transfer_time(bytes) < 0.1 * RDMA_400IB.transfer_time(bytes));
    }

    #[test]
    fn small_transfer_jitter_calibration() {
        // §7.5 env-interaction I/O: mean 0.02s, max 1.4s.
        let d = jittered_small_transfer(0.02, 1.4);
        let mut rng = crate::simkit::SimRng::new(11);
        let samples: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let max = samples.iter().cloned().fold(0.0, f64::max);
        assert!((mean - 0.02).abs() < 0.01, "mean {mean}");
        assert!(max <= 1.4 + 1e-9, "max {max}");
        assert!(max > 0.3, "tail should be visible, max {max}");
    }

    #[test]
    fn streams_share_setup() {
        let t1 = RDMA_400IB.transfer_time(1e9);
        let t16 = RDMA_400IB.transfer_time_streams(1e9, 16);
        assert!((t16 - t1) < 0.01);
    }
}
