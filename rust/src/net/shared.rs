//! Shared-bandwidth queueing over a point-to-point [`Link`].
//!
//! [`Link::transfer_time`] answers "how long does *one* transfer take
//! on an idle link"; it has no state, so two concurrent transfers
//! overlap for free.  That is the wrong model for the PD KV hop: at
//! high batch a prefill engine completes a whole admission wave at
//! once and every request's KV cache hits the inter-pool link in the
//! same instant.  [`SharedLink`] makes the link a *contended* resource:
//! a fixed number of transfer slots (NIC queues / NVLink channels),
//! each serving transfers FIFO at the link's effective bandwidth.  A
//! burst of `k` transfers over `s` slots therefore queues — the
//! sharpening of Table 5 at high batch the ROADMAP predicted — and
//! every transfer's queue delay is recorded in [`SharedLinkStats`].
//!
//! The model is deliberately simple (earliest-free-slot FIFO, no
//! preemption, full per-slot bandwidth): for equal-size bursts it
//! coincides with the balanced fair-share bound
//! [`balanced_makespan`], which is also the analytic term the
//! synchronous baseline's PD path uses.
//!
//! # Bucket-level priorities (KV preempts queued weight buckets)
//!
//! When weight dissemination shares the KV link
//! (`weights.share_kv_link`), the plain FIFO model makes a latency-
//! critical KV hop queue behind a multi-gigabyte background weight
//! bucket that merely *arrived* earlier.  [`SharedLink::enable_preemption`]
//! adds two traffic classes on the forward direction:
//!
//! * [`SharedLink::acquire_prio`] (KV hops) — admitted against the
//!   *committed* tail of each slot only, jumping ahead of any queued
//!   low-priority segment that has not started moving bytes yet;
//! * [`SharedLink::acquire_low`] (weight buckets) — queue as before,
//!   but every still-unstarted segment is pushed back when a priority
//!   transfer lands in front of it (a segment that has started is
//!   committed and never preempted — no mid-transfer abort modeling).
//!
//! Displaced pulls' completion times are tracked per pull id
//! ([`SharedLink::low_pull_done`]) so the driver can re-check a
//! stream's delivery event against the post-preemption reality.  With
//! preemption disabled (the default) both class methods delegate to
//! the plain FIFO [`SharedLink::acquire`], bit-identically.

use super::Link;
use crate::metrics::Histogram;
use std::collections::BTreeMap;

/// Admission of one transfer onto a [`SharedLink`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Grant {
    /// When the transfer starts moving bytes (≥ the request time).
    pub start_s: f64,
    /// When the last byte lands on the far side.
    pub done_s: f64,
    /// Time spent waiting for a free transfer slot.
    pub queue_delay_s: f64,
    /// The FIFO slot that served the transfer (per direction).
    /// Transfers on one slot are serialized, which is exactly what the
    /// telemetry plane needs to lay them out as non-overlapping trace
    /// tracks (one tid per slot).
    pub slot: usize,
}

/// One admitted transfer, kept when [`SharedLink::enable_trace`] is on
/// (the telemetry plane drains these into link-occupancy trace spans).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferRecord {
    pub start_s: f64,
    pub done_s: f64,
    pub queue_delay_s: f64,
    pub bytes: f64,
    pub slot: usize,
    pub reverse: bool,
}

/// Per-transfer contention statistics of one [`SharedLink`].
/// Both directions accumulate here; the `reverse_*` counters break the
/// reverse direction out.
#[derive(Clone, Debug, Default)]
pub struct SharedLinkStats {
    /// Transfers admitted (both directions).
    pub transfers: u64,
    /// Transfers that had to wait for a slot.
    pub queued_transfers: u64,
    /// Total queue delay across transfers.
    pub queue_delay_total_s: f64,
    /// Worst single-transfer queue delay.
    pub queue_delay_max_s: f64,
    /// Bytes moved.
    pub bytes_total: f64,
    /// Reverse-direction transfers ([`SharedLink::acquire_reverse`]).
    pub reverse_transfers: u64,
    /// Reverse-direction transfers that queued (behind other *reverse*
    /// traffic — the fabric is full duplex).
    pub reverse_queued: u64,
    /// Priority transfers that jumped ahead of at least one queued
    /// low-priority segment ([`SharedLink::acquire_prio`]).
    pub preemptions: u64,
    /// Low-priority segments pushed back by priority traffic (one
    /// preemption can displace several queued buckets).
    pub preempted_segments: u64,
    /// Total seconds low-priority segments were pushed back by.
    pub preempted_delay_s: f64,
    /// Per-transfer queue-delay samples (percentiles for the benches).
    pub queue_delay: Histogram,
}

impl SharedLinkStats {
    /// Mean per-transfer queue delay.
    pub fn mean_queue_delay_s(&self) -> f64 {
        if self.transfers == 0 {
            return 0.0;
        }
        self.queue_delay_total_s / self.transfers as f64
    }

    /// Compact copyable summary for [`crate::sim::ScenarioResult`].
    pub fn report(&self) -> KvLinkReport {
        KvLinkReport {
            transfers: self.transfers,
            queued_transfers: self.queued_transfers,
            queue_delay_total_s: self.queue_delay_total_s,
            queue_delay_max_s: self.queue_delay_max_s,
            reverse_transfers: self.reverse_transfers,
            reverse_queued: self.reverse_queued,
            preemptions: self.preemptions,
            preempted_segments: self.preempted_segments,
            preempted_delay_s: self.preempted_delay_s,
        }
    }
}

/// Copyable summary of a run's KV-link contention (the histogram stays
/// on the [`SharedLink`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KvLinkReport {
    pub transfers: u64,
    pub queued_transfers: u64,
    pub queue_delay_total_s: f64,
    pub queue_delay_max_s: f64,
    /// Reverse-direction (decode→prefill prefix reuse) transfers.
    pub reverse_transfers: u64,
    pub reverse_queued: u64,
    /// KV hops that preempted queued weight buckets
    /// ([`SharedLink::acquire_prio`]; zero unless the scenario shares
    /// the KV link with weight traffic and preemption is enabled).
    pub preemptions: u64,
    /// Weight buckets pushed back by those preemptions.
    pub preempted_segments: u64,
    /// Total pushback those buckets absorbed, seconds.
    pub preempted_delay_s: f64,
}

/// A [`Link`] with `slots` FIFO transfer slots per direction.
///
/// Each slot serves one transfer at a time at the link's full
/// single-transfer goodput (`setup + bytes/bw`); an arriving transfer
/// takes the earliest-free slot and queues behind its current work.
/// The one-way base latency is paid after the bytes finish moving.
///
/// The fabric is modeled full duplex: the forward direction
/// ([`SharedLink::acquire`], e.g. prefill→decode KV hops) and the
/// reverse direction ([`SharedLink::acquire_reverse`], e.g.
/// decode→prefill prefix reuse) each own a slot pool, so traffic queues
/// only against its own direction while both directions share the
/// statistics.
#[derive(Clone, Debug)]
pub struct SharedLink {
    link: Link,
    /// Per-slot busy-until time, seconds (forward direction).  With
    /// preemption enabled this is the *committed* tail only — started
    /// or non-preemptible work; queued low-priority segments live in
    /// `low_q` until their start time passes.
    slots: Vec<f64>,
    /// Reverse-direction slot pool (same width; full duplex).
    rev_slots: Vec<f64>,
    pub stats: SharedLinkStats,
    /// Opt-in transfer log ([`SharedLink::enable_trace`]); `None` keeps
    /// the admission path allocation-free when telemetry is off.
    trace_log: Option<Vec<TransferRecord>>,
    /// Bucket-level priorities on ([`SharedLink::enable_preemption`]).
    preempt: bool,
    /// Queued, not-yet-started low-priority segments per forward slot,
    /// in start order (empty unless preemption is enabled).
    low_q: Vec<Vec<LowSeg>>,
    /// Next low-priority pull id ([`SharedLink::begin_low_pull`]).
    next_pull: u64,
    /// Latest completion (incl. delivery latency) per low-priority
    /// pull, updated when preemptions push its segments back.
    pull_done: BTreeMap<u64, f64>,
}

/// One queued low-priority segment (a weight bucket) that has not
/// started moving bytes yet — the preemptible unit.
#[derive(Clone, Copy, Debug)]
struct LowSeg {
    start_s: f64,
    end_s: f64,
    pull: u64,
}

/// Earliest-free-slot FIFO admission over one direction's slot pool.
/// `service_s` comes from [`SharedLink::service_time`] so both
/// directions and the public accessor share one service model.
fn grant_on(slots: &mut [f64], service_s: f64, latency_s: f64, now: f64) -> Grant {
    let slot = (0..slots.len())
        .min_by(|&a, &b| slots[a].total_cmp(&slots[b]))
        .expect("slots is non-empty");
    let start = slots[slot].max(now);
    let queue_delay = start - now;
    let free_at = start + service_s;
    slots[slot] = free_at;
    Grant {
        start_s: start,
        done_s: free_at + latency_s,
        queue_delay_s: queue_delay,
        slot,
    }
}

impl SharedLink {
    pub fn new(link: Link, slots: usize) -> Self {
        assert!(slots > 0, "a link needs at least one transfer slot");
        SharedLink {
            link,
            slots: vec![0.0; slots],
            rev_slots: vec![0.0; slots],
            stats: SharedLinkStats::default(),
            trace_log: None,
            preempt: false,
            low_q: Vec::new(),
            next_pull: 0,
            pull_done: BTreeMap::new(),
        }
    }

    /// Turn on bucket-level priorities on the forward direction: KV
    /// hops admitted via [`SharedLink::acquire_prio`] jump ahead of
    /// queued weight buckets admitted via [`SharedLink::acquire_low`].
    /// While off (the default) both class methods delegate to the plain
    /// FIFO [`SharedLink::acquire`] bit-identically.
    pub fn enable_preemption(&mut self) {
        if !self.preempt {
            self.preempt = true;
            self.low_q = vec![Vec::new(); self.slots.len()];
        }
    }

    pub fn preemption_enabled(&self) -> bool {
        self.preempt
    }

    /// Commit every queued low-priority segment whose start time has
    /// passed: once bytes are moving the segment is non-preemptible and
    /// folds into the slot's committed tail.
    fn commit_started(&mut self, now: f64) {
        for i in 0..self.low_q.len() {
            while let Some(&seg) = self.low_q[i].first() {
                if seg.start_s > now {
                    break;
                }
                self.slots[i] = self.slots[i].max(seg.end_s);
                self.low_q[i].remove(0);
            }
        }
    }

    /// Freeze every still-queued low segment into its slot's committed
    /// tail.  Neutral-class arrivals on a preemption-enabled link admit
    /// behind *all* pending work (they were granted a completion time a
    /// driver event now depends on, so nothing scheduled before them
    /// may be displaced afterwards — no stale-event hazard).
    fn freeze_low(&mut self) {
        for i in 0..self.low_q.len() {
            if let Some(last) = self.low_q[i].last() {
                self.slots[i] = self.slots[i].max(last.end_s);
            }
            self.low_q[i].clear();
        }
    }

    /// Start keeping a [`TransferRecord`] per admitted transfer.
    /// Purely additive: grants and stats are byte-identical with the
    /// log on or off.
    pub fn enable_trace(&mut self) {
        if self.trace_log.is_none() {
            self.trace_log = Some(Vec::new());
        }
    }

    /// Take the transfer log accumulated since [`SharedLink::enable_trace`]
    /// (empty when tracing was never enabled).  Tracing stays enabled.
    pub fn drain_trace(&mut self) -> Vec<TransferRecord> {
        match self.trace_log.as_mut() {
            Some(log) => std::mem::take(log),
            None => Vec::new(),
        }
    }

    pub fn link(&self) -> &Link {
        &self.link
    }

    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Service time of one transfer once it holds a slot (setup +
    /// bytes at effective bandwidth; excludes queueing and latency).
    pub fn service_time(&self, bytes: f64) -> f64 {
        self.link.setup_s + bytes / self.link.effective_bytes_per_s
    }

    /// Total end-to-end wall-clock the link's transfers have taken:
    /// queueing + setup + bytes at bandwidth + delivery latency,
    /// summed over all transfers *admitted* so far (an in-flight
    /// transfer counts in full — for per-delivery accounting the PD
    /// driver books each hop at its completion event instead).
    pub fn total_transfer_time_s(&self) -> f64 {
        self.stats.queue_delay_total_s
            + self.stats.transfers as f64 * (self.link.setup_s + self.link.latency_s)
            + self.stats.bytes_total / self.link.effective_bytes_per_s
    }

    /// A zero-byte transfer is a no-op: it must neither occupy a slot
    /// nor book session setup or delivery latency (the empty
    /// provisioned-pull edge — see [`crate::mooncake`]'s bucket model,
    /// whose `bucket_count(0) == 0` is the other half of the guard).
    fn empty_grant(now: f64) -> Grant {
        Grant {
            start_s: now,
            done_s: now,
            queue_delay_s: 0.0,
            slot: 0,
        }
    }

    /// Admit one forward-direction transfer of `bytes` at time `now`:
    /// it occupies the earliest-free slot FIFO and completes at
    /// `done_s`.  Zero-byte transfers are free (no slot, no setup).
    pub fn acquire(&mut self, now: f64, bytes: f64) -> Grant {
        if bytes <= 0.0 {
            return Self::empty_grant(now);
        }
        if self.preempt {
            self.commit_started(now);
            self.freeze_low();
        }
        let service = self.service_time(bytes);
        let grant = grant_on(&mut self.slots, service, self.link.latency_s, now);
        self.record(grant, bytes, false);
        grant
    }

    /// Admit one **priority** forward transfer (a KV hop): it queues
    /// against each slot's *committed* tail only, jumping ahead of any
    /// low-priority segment that has not started moving bytes yet;
    /// displaced segments are pushed back and their pulls' completion
    /// times updated ([`SharedLink::low_pull_done`]).  Delegates to the
    /// FIFO [`SharedLink::acquire`] while preemption is off.
    pub fn acquire_prio(&mut self, now: f64, bytes: f64) -> Grant {
        if !self.preempt {
            return self.acquire(now, bytes);
        }
        if bytes <= 0.0 {
            return Self::empty_grant(now);
        }
        self.commit_started(now);
        let service = self.service_time(bytes);
        let latency = self.link.latency_s;
        let slot = (0..self.slots.len())
            .min_by(|&a, &b| self.slots[a].total_cmp(&self.slots[b]))
            .expect("slots is non-empty");
        let start = self.slots[slot].max(now);
        let end = start + service;
        self.slots[slot] = end;
        let grant = Grant {
            start_s: start,
            done_s: end + latency,
            queue_delay_s: start - now,
            slot,
        };
        // Push back every still-queued low segment the priority
        // transfer displaced, preserving their relative order.  An
        // already-planned pull's cross-slot bucket sequencing is not
        // re-derived: its delivery is the max of its segments'
        // completions, which this keeps current.
        let mut displaced = 0u64;
        let mut pushback = 0.0f64;
        let mut tail = end;
        for seg in self.low_q[slot].iter_mut() {
            if seg.start_s < tail {
                let d = tail - seg.start_s;
                seg.start_s += d;
                seg.end_s += d;
                displaced += 1;
                pushback += d;
                let done = seg.end_s + latency;
                let e = self.pull_done.entry(seg.pull).or_insert(done);
                if done > *e {
                    *e = done;
                }
            }
            tail = seg.end_s;
        }
        if displaced > 0 {
            self.stats.preemptions += 1;
            self.stats.preempted_segments += displaced;
            self.stats.preempted_delay_s += pushback;
        }
        self.record(grant, bytes, false);
        grant
    }

    /// Start one low-priority pull (a bucketized weight pull): returns
    /// the pull id its buckets pass to [`SharedLink::acquire_low`] and
    /// the driver uses to re-check delivery via
    /// [`SharedLink::low_pull_done`].
    pub fn begin_low_pull(&mut self) -> u64 {
        let id = self.next_pull;
        self.next_pull += 1;
        id
    }

    /// Admit one **low-priority** forward transfer (one weight bucket
    /// of pull `pull`): queues behind both committed work and earlier
    /// low segments, and remains preemptible by
    /// [`SharedLink::acquire_prio`] until its start time passes.
    /// Delegates to the FIFO [`SharedLink::acquire`] while preemption
    /// is off.
    pub fn acquire_low(&mut self, now: f64, bytes: f64, pull: u64) -> Grant {
        if !self.preempt {
            return self.acquire(now, bytes);
        }
        if bytes <= 0.0 {
            return Self::empty_grant(now);
        }
        self.commit_started(now);
        let service = self.service_time(bytes);
        let latency = self.link.latency_s;
        let avail = |link: &Self, i: usize| -> f64 {
            link.low_q[i]
                .last()
                .map(|s| s.end_s)
                .unwrap_or(f64::NEG_INFINITY)
                .max(link.slots[i])
        };
        let slot = (0..self.slots.len())
            .min_by(|&a, &b| avail(self, a).total_cmp(&avail(self, b)))
            .expect("slots is non-empty");
        let start = avail(self, slot).max(now);
        let end = start + service;
        self.low_q[slot].push(LowSeg {
            start_s: start,
            end_s: end,
            pull,
        });
        let done = end + latency;
        let e = self.pull_done.entry(pull).or_insert(done);
        if done > *e {
            *e = done;
        }
        let grant = Grant {
            start_s: start,
            done_s: done,
            queue_delay_s: start - now,
            slot,
        };
        self.record(grant, bytes, false);
        grant
    }

    /// Latest known completion of low-priority pull `pull`, including
    /// any pushback preemptions inflicted after its buckets were
    /// granted.  `None` for unknown pulls (or with preemption off,
    /// where grants are final).
    pub fn low_pull_done(&self, pull: u64) -> Option<f64> {
        self.pull_done.get(&pull).copied()
    }

    /// Admit one *reverse-direction* transfer (decode→prefill prefix
    /// reuse): queues only against other reverse traffic — the fabric
    /// is full duplex — but shares the link's statistics.  Zero-byte
    /// transfers are free (no slot, no setup).
    pub fn acquire_reverse(&mut self, now: f64, bytes: f64) -> Grant {
        if bytes <= 0.0 {
            return Self::empty_grant(now);
        }
        let service = self.service_time(bytes);
        let grant = grant_on(&mut self.rev_slots, service, self.link.latency_s, now);
        self.record(grant, bytes, true);
        grant
    }

    fn record(&mut self, grant: Grant, bytes: f64, reverse: bool) {
        let queued = grant.queue_delay_s > 1e-12;
        self.stats.transfers += 1;
        if queued {
            self.stats.queued_transfers += 1;
        }
        if reverse {
            self.stats.reverse_transfers += 1;
            if queued {
                self.stats.reverse_queued += 1;
            }
        }
        self.stats.queue_delay_total_s += grant.queue_delay_s;
        self.stats.queue_delay_max_s = self.stats.queue_delay_max_s.max(grant.queue_delay_s);
        self.stats.bytes_total += bytes;
        self.stats.queue_delay.record(grant.queue_delay_s);
        if let Some(log) = self.trace_log.as_mut() {
            log.push(TransferRecord {
                start_s: grant.start_s,
                done_s: grant.done_s,
                queue_delay_s: grant.queue_delay_s,
                bytes,
                slot: grant.slot,
                reverse,
            });
        }
    }
}

/// Balanced fair-share makespan of a burst of transfers that all
/// arrive at once on an idle link with `slots` transfer slots:
///
/// ```text
/// latency + Σᵢ (setup + bytesᵢ / bandwidth) / slots
/// ```
///
/// This is the analytic counterpart of [`SharedLink`]'s FIFO model —
/// for equal-size transfers whose count divides `slots` the two agree
/// exactly — and the transfer term the synchronous baseline's PD path
/// uses (see [`crate::sim::sync_driver`]).
pub fn balanced_makespan(link: &Link, slots: usize, transfer_bytes: &[f64]) -> f64 {
    assert!(slots > 0);
    if transfer_bytes.is_empty() {
        return 0.0;
    }
    let service: f64 = transfer_bytes
        .iter()
        .map(|&b| link.setup_s + b / link.effective_bytes_per_s)
        .sum();
    link.latency_s + service / slots as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NVLINK_INTRA;

    fn shared(slots: usize) -> SharedLink {
        SharedLink::new(NVLINK_INTRA.clone(), slots)
    }

    #[test]
    fn lone_transfer_pays_no_queue_delay() {
        let mut l = shared(1);
        let g = l.acquire(5.0, 1e9);
        assert_eq!(g.queue_delay_s, 0.0);
        assert_eq!(g.start_s, 5.0);
        let expect = 5.0 + l.service_time(1e9) + NVLINK_INTRA.latency_s;
        assert!((g.done_s - expect).abs() < 1e-12);
        assert_eq!(l.stats.transfers, 1);
        assert_eq!(l.stats.queued_transfers, 0);
    }

    #[test]
    fn concurrent_transfers_contend_on_one_slot() {
        let mut l = shared(1);
        let a = l.acquire(0.0, 1e9);
        let b = l.acquire(0.0, 1e9);
        let service = l.service_time(1e9);
        assert!((b.queue_delay_s - service).abs() < 1e-12, "{b:?}");
        assert!(b.done_s > a.done_s);
        assert_eq!(l.stats.queued_transfers, 1);
        assert!((l.stats.queue_delay_max_s - service).abs() < 1e-12);
    }

    #[test]
    fn extra_slots_absorb_the_burst() {
        let mut l = shared(2);
        let a = l.acquire(0.0, 1e9);
        let b = l.acquire(0.0, 1e9);
        assert_eq!(a.queue_delay_s, 0.0);
        assert_eq!(b.queue_delay_s, 0.0);
        let c = l.acquire(0.0, 1e9);
        assert!(c.queue_delay_s > 0.0, "third transfer queues");
    }

    #[test]
    fn later_arrival_can_start_immediately() {
        let mut l = shared(1);
        let a = l.acquire(0.0, 1e9);
        // Arrives after the slot frees: no queueing.
        let b = l.acquire(a.done_s + 1.0, 1e9);
        assert_eq!(b.queue_delay_s, 0.0);
        assert_eq!(b.start_s, a.done_s + 1.0);
    }

    #[test]
    fn fifo_burst_matches_the_balanced_bound() {
        // 8 equal transfers over 2 slots: last completion equals the
        // balanced fair-share makespan (the analytic formula is exact
        // when the count divides the slot count).
        let bytes = vec![2e9; 8];
        let mut l = shared(2);
        let mut last = 0.0f64;
        for &b in &bytes {
            last = last.max(l.acquire(0.0, b).done_s);
        }
        let bound = balanced_makespan(&NVLINK_INTRA, 2, &bytes);
        assert!((last - bound).abs() < 1e-9, "{last} vs {bound}");
    }

    #[test]
    fn balanced_makespan_formula_is_pinned() {
        let link = &NVLINK_INTRA;
        let bytes = [1e9, 3e9, 5e9];
        let expect = link.latency_s
            + bytes
                .iter()
                .map(|b| link.setup_s + b / link.effective_bytes_per_s)
                .sum::<f64>()
                / 4.0;
        assert!((balanced_makespan(link, 4, &bytes) - expect).abs() < 1e-12);
        assert_eq!(balanced_makespan(link, 4, &[]), 0.0);
    }

    #[test]
    fn cross_direction_queueing_is_independent() {
        // Saturate the single forward slot: a reverse transfer admitted
        // at the same instant starts immediately (full duplex), while a
        // second reverse transfer queues behind the first — reverse
        // traffic contends only with itself.
        let mut l = shared(1);
        let f1 = l.acquire(0.0, 1e9);
        let f2 = l.acquire(0.0, 1e9);
        assert!(f2.queue_delay_s > 0.0, "forward saturated");
        let r1 = l.acquire_reverse(0.0, 1e9);
        assert_eq!(
            r1.queue_delay_s, 0.0,
            "reverse must not queue behind forward traffic"
        );
        assert_eq!(r1.start_s, 0.0);
        let r2 = l.acquire_reverse(0.0, 1e9);
        assert!(
            (r2.queue_delay_s - l.service_time(1e9)).abs() < 1e-12,
            "second reverse queues behind the first: {r2:?}"
        );
        // And a forward arrival is untouched by the reverse backlog
        // (beyond its own queue): it waits on the forward slot only.
        let f3 = l.acquire(0.0, 1e9);
        assert!((f3.start_s - f2.done_s + NVLINK_INTRA.latency_s).abs() < 1e-9);
        // Direction-split accounting.
        assert_eq!(l.stats.transfers, 5);
        assert_eq!(l.stats.reverse_transfers, 2);
        assert_eq!(l.stats.reverse_queued, 1);
        assert_eq!(l.stats.queued_transfers, 3, "f2, r2, f3");
        let r = l.stats.report();
        assert_eq!(r.reverse_transfers, 2);
        assert_eq!(r.reverse_queued, 1);
        assert_eq!((f1.queue_delay_s, r1.queue_delay_s), (0.0, 0.0));
    }

    #[test]
    fn zero_byte_transfer_is_free_and_books_nothing() {
        // Regression for the empty-pull edge: a zero-byte transfer must
        // not occupy a slot, pay setup/latency, or perturb the stats —
        // a later real transfer sees an untouched link.
        let mut l = shared(1);
        let z = l.acquire(3.0, 0.0);
        assert_eq!((z.start_s, z.done_s, z.queue_delay_s), (3.0, 3.0, 0.0));
        let zr = l.acquire_reverse(3.0, -1.0);
        assert_eq!((zr.start_s, zr.done_s), (3.0, 3.0));
        assert_eq!(l.stats.transfers, 0, "nothing admitted");
        assert_eq!(l.stats.bytes_total, 0.0);
        let real = l.acquire(3.0, 1e9);
        assert_eq!(real.queue_delay_s, 0.0, "slot untouched by the no-ops");
        assert_eq!(real.start_s, 3.0);
    }

    #[test]
    fn trace_log_records_admitted_transfers_only() {
        let mut l = shared(2);
        // Before enable_trace the log stays empty and drain is a no-op.
        l.acquire(0.0, 1e9);
        assert!(l.drain_trace().is_empty());
        l.enable_trace();
        let g1 = l.acquire(1.0, 1e9);
        let g2 = l.acquire_reverse(1.0, 2e9);
        l.acquire(1.0, 0.0); // zero-byte: never admitted, never logged
        let log = l.drain_trace();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].start_s, g1.start_s);
        assert_eq!(log[0].done_s, g1.done_s);
        assert_eq!(log[0].slot, g1.slot);
        assert_eq!(log[0].bytes, 1e9);
        assert!(!log[0].reverse);
        assert_eq!(log[1].slot, g2.slot);
        assert!(log[1].reverse);
        // drain resets but keeps tracing on
        assert!(l.drain_trace().is_empty());
        l.acquire(2.0, 1e9);
        assert_eq!(l.drain_trace().len(), 1);
    }

    #[test]
    fn preemption_off_class_methods_are_plain_fifo() {
        // Bit-compatibility guard: without enable_preemption the class
        // methods must produce exactly the legacy FIFO grants.
        let mut a = shared(2);
        let mut b = shared(2);
        let g1 = a.acquire(0.0, 1e9);
        let pull = b.begin_low_pull();
        let g2 = b.acquire_low(0.0, 1e9, pull);
        assert_eq!(g1, g2);
        let g3 = a.acquire(0.0, 2e9);
        let g4 = b.acquire_prio(0.0, 2e9);
        assert_eq!(g3, g4);
        assert!(b.low_pull_done(pull).is_none(), "grants are final");
        assert_eq!(b.stats.preemptions, 0);
        assert!(!b.preemption_enabled());
    }

    #[test]
    fn kv_preempts_queued_weight_buckets() {
        let mut l = shared(1);
        l.enable_preemption();
        let svc = l.service_time(1e9);
        let pull = l.begin_low_pull();
        // First bucket starts immediately → committed; second queues.
        let b1 = l.acquire_low(0.0, 1e9, pull);
        assert_eq!(b1.start_s, 0.0);
        let b2 = l.acquire_low(0.0, 1e9, pull);
        assert!((b2.start_s - svc).abs() < 1e-12);
        let done_before = l.low_pull_done(pull).unwrap();
        assert!((done_before - b2.done_s).abs() < 1e-12);
        // A KV hop lands mid-first-bucket: it must wait only for the
        // *started* bucket (no mid-transfer abort), then jump ahead of
        // the queued one.
        let kv = l.acquire_prio(0.5 * svc, 1e9);
        assert!((kv.start_s - svc).abs() < 1e-12, "{kv:?}");
        // The queued bucket is pushed back behind the KV hop, and the
        // pull's tracked completion moves with it.
        let done_after = l.low_pull_done(pull).unwrap();
        assert!((done_after - (done_before + svc)).abs() < 1e-9);
        assert_eq!(l.stats.preemptions, 1);
        assert_eq!(l.stats.preempted_segments, 1);
        assert!((l.stats.preempted_delay_s - svc).abs() < 1e-9);
        let r = l.stats.report();
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.preempted_segments, 1);
        assert!(r.preempted_delay_s > 0.0);
    }

    #[test]
    fn neutral_arrival_freezes_the_low_queue() {
        // A neutral-class transfer's grant is final (a driver event
        // depends on it), so everything queued before it freezes: a
        // later KV hop cannot displace those buckets any more.
        let mut l = shared(1);
        l.enable_preemption();
        let svc = l.service_time(1e9);
        let pull = l.begin_low_pull();
        l.acquire_low(0.0, 1e9, pull);
        let b2 = l.acquire_low(0.0, 1e9, pull);
        let n = l.acquire(0.0, 1e9);
        assert!((n.start_s - 2.0 * svc).abs() < 1e-12, "{n:?}");
        let kv = l.acquire_prio(0.0, 1e9);
        assert!((kv.start_s - 3.0 * svc).abs() < 1e-12, "{kv:?}");
        assert_eq!(l.stats.preemptions, 0, "nothing left to displace");
        assert!((l.low_pull_done(pull).unwrap() - b2.done_s).abs() < 1e-12);
    }

    #[test]
    fn prio_on_idle_link_pays_no_queue_delay() {
        let mut l = shared(2);
        l.enable_preemption();
        let g = l.acquire_prio(1.0, 1e9);
        assert_eq!(g.queue_delay_s, 0.0);
        assert_eq!(g.start_s, 1.0);
        assert_eq!(l.stats.preemptions, 0);
    }

    #[test]
    fn grants_carry_the_serving_slot() {
        let mut l = shared(2);
        let a = l.acquire(0.0, 1e9);
        let b = l.acquire(0.0, 1e9);
        let c = l.acquire(0.0, 1e9);
        // two slots: first two transfers land on distinct slots, the
        // third queues behind the earlier-free one
        assert_ne!(a.slot, b.slot);
        assert!(c.queue_delay_s > 0.0);
        assert!(c.slot == a.slot || c.slot == b.slot);
    }

    #[test]
    fn stats_accumulate_and_summarize() {
        let mut l = shared(1);
        for _ in 0..4 {
            l.acquire(0.0, 1e9);
        }
        assert_eq!(l.stats.transfers, 4);
        assert_eq!(l.stats.queued_transfers, 3);
        assert_eq!(l.stats.bytes_total, 4e9);
        assert!(l.stats.mean_queue_delay_s() > 0.0);
        assert_eq!(l.stats.queue_delay.len(), 4);
        let r = l.stats.report();
        assert_eq!(r.transfers, 4);
        assert_eq!(r.queued_transfers, 3);
        assert!((r.queue_delay_total_s - l.stats.queue_delay_total_s).abs() < 1e-12);
        // End-to-end occupancy: queueing + per-transfer (setup +
        // latency) + total bytes at bandwidth.
        let link = &NVLINK_INTRA;
        let expect = l.stats.queue_delay_total_s
            + 4.0 * (link.setup_s + link.latency_s)
            + 4e9 / link.effective_bytes_per_s;
        assert!((l.total_transfer_time_s() - expect).abs() < 1e-12);
    }
}
