//! Network fabric model: links, transfer times, shared-bandwidth
//! queueing, and the small-packet jitter path used by trajectory/env
//! I/O (§3.2).
//!
//! Calibration anchors from the paper:
//! * Table 3 — Mooncake weight transfer, training→inference cluster:
//!   TCP (200 GbE) vs RDMA (400 Gb IB); speedup grows with model size
//!   because RDMA's fixed session setup amortizes (1.26×→3.14×).
//! * §7.5 — env-interaction I/O ≤2.7 MB/call, overhead mean 0.02 s /
//!   max 1.4 s; serverless reward I/O ≤5.2 MB, mean 0.01 s / max 2.1 s.
//!
//! [`Link`] is the stateless single-transfer model; [`SharedLink`]
//! wraps it in FIFO transfer slots so concurrent transfers *contend*
//! (the PD KV hop uses this — see [`crate::sim::driver::pd`]).

mod link;
mod shared;

pub use link::{jittered_small_transfer, Link, NVLINK_INTRA, RDMA_400IB, TCP_200GBE};
pub use shared::{balanced_makespan, Grant, KvLinkReport, SharedLink, SharedLinkStats};
