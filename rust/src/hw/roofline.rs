//! Roofline cost model: (FLOPs, bytes) → seconds on a GPU class.
//!
//! `time = max(flops / eff_flops, bytes / eff_bw) + launch_overhead`.
//!
//! This is the quantitative engine behind the paper's R1 story: a
//! prefill-heavy phase has high arithmetic intensity and lands on the
//! FLOPs roof (H800 wins); a decode phase streams the whole weight +
//! KV-cache working set per token and lands on the bandwidth roof
//! (H20 wins at equal cost).  Fig 4 / Fig 11a / Table 5 all reduce to
//! this function applied per phase.

use super::GpuSpec;

/// The resource demand of one executed phase (one prefill of `n`
/// tokens, one decode step of a batch, one optimizer step, ...).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseCost {
    pub flops: f64,
    pub bytes: f64,
}

impl PhaseCost {
    pub fn new(flops: f64, bytes: f64) -> Self {
        PhaseCost { flops, bytes }
    }

    /// Arithmetic intensity, FLOP/byte.
    pub fn intensity(&self) -> f64 {
        if self.bytes == 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }

    pub fn add(&self, other: &PhaseCost) -> PhaseCost {
        PhaseCost {
            flops: self.flops + other.flops,
            bytes: self.bytes + other.bytes,
        }
    }

    pub fn scale(&self, k: f64) -> PhaseCost {
        PhaseCost {
            flops: self.flops * k,
            bytes: self.bytes * k,
        }
    }
}

/// Fixed per-launch overhead (kernel launches, scheduler ticks).
pub const LAUNCH_OVERHEAD_S: f64 = 25e-6;

/// Time for `cost` spread over `n_gpus` of class `spec` (ideal data
/// parallel split; parallelism inefficiency is applied by callers that
/// know their sharding).
pub fn phase_time(cost: &PhaseCost, spec: &GpuSpec, n_gpus: usize) -> f64 {
    assert!(n_gpus > 0);
    let n = n_gpus as f64;
    let t_flops = cost.flops / (spec.eff_flops() * n);
    let t_bytes = cost.bytes / (spec.eff_bw() * n);
    t_flops.max(t_bytes) + LAUNCH_OVERHEAD_S
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{H20, H800};

    #[test]
    fn compute_bound_phase_favors_h800() {
        // High arithmetic intensity: 1 PFLOP over 1 GB.
        let c = PhaseCost::new(1e15, 1e9);
        let t20 = phase_time(&c, &H20, 1);
        let t800 = phase_time(&c, &H800, 1);
        assert!(t800 < t20 * 0.25, "{t800} vs {t20}");
    }

    #[test]
    fn bandwidth_bound_phase_favors_h20() {
        // ~1 FLOP/byte: decode-like.
        let c = PhaseCost::new(1e12, 1e12);
        let t20 = phase_time(&c, &H20, 1);
        let t800 = phase_time(&c, &H800, 1);
        assert!(t20 < t800, "{t20} vs {t800}");
        // and per-cost H20 wins by ~3x (4/3.35 * 2.85 cost ratio)
        let per_cost_20 = t20 * H20.cost;
        let per_cost_800 = t800 * H800.cost;
        assert!(per_cost_20 < 0.5 * per_cost_800);
    }

    #[test]
    fn scaling_with_gpus() {
        let c = PhaseCost::new(1e15, 1e9);
        let t1 = phase_time(&c, &H800, 1);
        let t4 = phase_time(&c, &H800, 4);
        assert!((t1 / t4 - 4.0).abs() < 0.01, "{}", t1 / t4);
    }

    #[test]
    fn intensity_and_roofs() {
        let c = PhaseCost::new(1e12, 1e9);
        assert!((c.intensity() - 1000.0).abs() < 1e-9);
        // above both ridge points -> compute bound on both
        assert!(c.intensity() > H20.ridge_point());
        assert!(c.intensity() > H800.ridge_point());
    }

    #[test]
    fn overhead_floor() {
        let c = PhaseCost::new(0.0, 0.0);
        assert_eq!(phase_time(&c, &H20, 8), LAUNCH_OVERHEAD_S);
    }

    #[test]
    fn add_scale() {
        let a = PhaseCost::new(1.0, 2.0);
        let b = a.add(&PhaseCost::new(3.0, 4.0)).scale(2.0);
        assert_eq!(b, PhaseCost::new(8.0, 12.0));
    }
}
