//! Hardware model: GPU classes and the roofline cost model.
//!
//! Table 2 of the paper is the source of truth for the two GPU classes;
//! the roofline translates an LLM phase's (FLOPs, bytes moved) into
//! seconds on a class, which is what makes the R1 affinity claims
//! (Fig 4, Fig 11a, Table 5) *ratio-reproducible* without the physical
//! testbed (DESIGN.md §2).

mod gpu;
mod roofline;

pub use gpu::{GpuClass, GpuSpec, H20, H800};
pub use roofline::{phase_time, PhaseCost};
