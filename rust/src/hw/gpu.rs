//! GPU specifications (paper Table 2).


/// The two GPU classes of the paper's disaggregated fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum GpuClass {
    /// Bandwidth-optimized (NVIDIA H20): 148 TFLOPS, 4 TB/s HBM.
    H20,
    /// Compute-optimized (NVIDIA H800): 989.5 TFLOPS, 3.35 TB/s HBM.
    H800,
}

impl GpuClass {
    pub fn spec(self) -> &'static GpuSpec {
        match self {
            GpuClass::H20 => &H20,
            GpuClass::H800 => &H800,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GpuClass::H20 => "H20",
            GpuClass::H800 => "H800",
        }
    }
}

impl std::fmt::Display for GpuClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One GPU class's capabilities (paper Table 2).
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense bf16 throughput, TFLOPS.
    pub tflops: f64,
    /// HBM capacity, GB.
    pub hbm_gb: f64,
    /// HBM bandwidth, TB/s.
    pub hbm_tbps: f64,
    /// NVLink bandwidth, GB/s.
    pub nvlink_gbps: f64,
    /// Normalized cost (H20 = 1.00; paper cites [69]).
    pub cost: f64,
    /// Achievable fraction of peak FLOPs for large GEMMs (MFU ceiling).
    pub flops_eff: f64,
    /// Achievable fraction of peak HBM bandwidth.
    pub bw_eff: f64,
}

pub static H20: GpuSpec = GpuSpec {
    name: "H20",
    tflops: 148.0,
    hbm_gb: 96.0,
    hbm_tbps: 4.0,
    nvlink_gbps: 900.0,
    cost: 1.00,
    flops_eff: 0.45,
    bw_eff: 0.65,
};

pub static H800: GpuSpec = GpuSpec {
    name: "H800",
    tflops: 989.5,
    hbm_gb: 80.0,
    hbm_tbps: 3.35,
    nvlink_gbps: 400.0,
    cost: 2.85,
    flops_eff: 0.45,
    bw_eff: 0.65,
};

impl GpuSpec {
    /// Effective compute throughput, FLOP/s.
    pub fn eff_flops(&self) -> f64 {
        self.tflops * 1e12 * self.flops_eff
    }

    /// Effective HBM bandwidth, bytes/s.
    pub fn eff_bw(&self) -> f64 {
        self.hbm_tbps * 1e12 * self.bw_eff
    }

    /// FLOP/byte at which this class transitions compute-bound.
    pub fn ridge_point(&self) -> f64 {
        self.eff_flops() / self.eff_bw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values() {
        assert_eq!(H20.tflops, 148.0);
        assert_eq!(H800.tflops, 989.5);
        assert_eq!(H20.hbm_tbps, 4.0);
        assert_eq!(H800.hbm_tbps, 3.35);
        assert_eq!(H20.cost, 1.00);
        assert_eq!(H800.cost, 2.85);
    }

    #[test]
    fn h20_is_bandwidth_optimized() {
        // Lower ridge point == becomes compute-bound sooner == favors
        // bandwidth-bound decoding.
        assert!(H20.ridge_point() < H800.ridge_point());
        // H20 has more HBM bandwidth despite ~6.7x less compute.
        assert!(H20.hbm_tbps > H800.hbm_tbps);
        assert!(H800.tflops / H20.tflops > 6.0);
    }

    #[test]
    fn cost_equivalence_of_paper_setups() {
        // §3: six H20s vs two H800s is the paper's cost-equivalent pair.
        let h20x6 = 6.0 * H20.cost;
        let h800x2 = 2.0 * H800.cost;
        assert!((h20x6 - h800x2).abs() / h800x2 < 0.06, "{h20x6} vs {h800x2}");
    }

    #[test]
    fn class_round_trip() {
        assert_eq!(GpuClass::H20.spec().name, "H20");
        assert_eq!(GpuClass::H800.spec().name, "H800");
        assert_eq!(GpuClass::H800.to_string(), "H800");
    }
}
