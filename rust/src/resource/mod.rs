//! Resource plane: heterogeneous pools, affinity binding, fallback.
//!
//! Implements the paper's *resource manager* (§4.1, §5.2): it keeps a
//! global real-time view of the disaggregated pools (compute-optimized
//! GPUs, bandwidth-optimized GPUs, CPU slots, serverless endpoints),
//! interprets worker-level hardware-affinity declarations, binds
//! Workers to concrete resources, and *opportunistically falls back*
//! to compatible pools instead of stalling deployment when the
//! preferred hardware is unavailable.

use crate::env::TaskDomain;
use crate::hw::GpuClass;
use std::collections::BTreeMap;

/// The resource classes of the disaggregated fabric (Fig 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ResourceClass {
    Gpu(GpuClass),
    CpuSlot,
    Serverless,
}

impl std::fmt::Display for ResourceClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResourceClass::Gpu(g) => write!(f, "gpu:{g}"),
            ResourceClass::CpuSlot => write!(f, "cpu"),
            ResourceClass::Serverless => write!(f, "serverless"),
        }
    }
}

/// Worker roles (the four Clusters of §5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Role {
    ActorTrain,
    ActorGen,
    Reward,
    Environment,
}

impl Role {
    /// Default affinity order (§5.2): training → compute-optimized
    /// GPUs, generation → bandwidth-optimized GPUs, environments →
    /// CPU servers, reward → serverless (falling back to local GPUs).
    pub fn default_affinity(self) -> &'static [ResourceClass] {
        match self {
            Role::ActorTrain => &[
                ResourceClass::Gpu(GpuClass::H800),
                ResourceClass::Gpu(GpuClass::H20),
            ],
            Role::ActorGen => &[
                ResourceClass::Gpu(GpuClass::H20),
                ResourceClass::Gpu(GpuClass::H800),
            ],
            Role::Reward => &[
                ResourceClass::Serverless,
                ResourceClass::Gpu(GpuClass::H20),
                ResourceClass::Gpu(GpuClass::H800),
            ],
            Role::Environment => &[ResourceClass::CpuSlot],
        }
    }
}

/// A successful binding: `count` units of `class` held by a worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Binding {
    pub id: u64,
    pub role: Role,
    pub class: ResourceClass,
    pub count: usize,
    /// True when the preferred class was unavailable and a fallback
    /// was used (surfaced to metrics; the paper logs these).
    pub fallback: bool,
}

#[derive(Clone, Copy, Debug, Default)]
struct Pool {
    total: usize,
    free: usize,
}

/// Binding failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BindError {
    pub role: Role,
    pub wanted: Vec<ResourceClass>,
    pub count: usize,
}

impl std::fmt::Display for BindError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "no capacity for {:?} x{} in any of {:?}",
            self.role, self.count, self.wanted
        )
    }
}

impl std::error::Error for BindError {}

/// The resource manager: pool accounting + the binding registry
/// (the paper uses a shared Redis; a BTreeMap plays that role here —
/// same semantics, single-process).
#[derive(Debug, Default)]
pub struct ResourceManager {
    pools: BTreeMap<ResourceClass, Pool>,
    bindings: BTreeMap<u64, Binding>,
    next_id: u64,
    /// Task-domain → GPU class routing table (R1, `hw_mapping`).
    hw_mapping: BTreeMap<TaskDomain, GpuClass>,
}

impl ResourceManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `count` units of a resource class.
    pub fn add_pool(&mut self, class: ResourceClass, count: usize) -> &mut Self {
        let p = self.pools.entry(class).or_default();
        p.total += count;
        p.free += count;
        self
    }

    pub fn free(&self, class: ResourceClass) -> usize {
        self.pools.get(&class).map(|p| p.free).unwrap_or(0)
    }

    pub fn total(&self, class: ResourceClass) -> usize {
        self.pools.get(&class).map(|p| p.total).unwrap_or(0)
    }

    /// Declare a task-domain affinity (the `hw_mapping` decorator,
    /// Listing 1).  Domains without an entry use the role default.
    pub fn set_hw_mapping(&mut self, domain: TaskDomain, class: GpuClass) -> &mut Self {
        self.hw_mapping.insert(domain, class);
        self
    }

    /// R1 routing: which GPU class should serve `domain`'s generation?
    pub fn route_domain(&self, domain: TaskDomain) -> Option<GpuClass> {
        self.hw_mapping.get(&domain).copied()
    }

    /// Bind `count` units for `role`, trying `affinity` in order and
    /// falling back to later entries when earlier pools lack capacity.
    pub fn bind(
        &mut self,
        role: Role,
        affinity: &[ResourceClass],
        count: usize,
    ) -> Result<Binding, BindError> {
        assert!(count > 0);
        for (i, &class) in affinity.iter().enumerate() {
            if self.free(class) >= count {
                let p = self.pools.get_mut(&class).unwrap();
                p.free -= count;
                let id = self.next_id;
                self.next_id += 1;
                let b = Binding {
                    id,
                    role,
                    class,
                    count,
                    fallback: i > 0,
                };
                self.bindings.insert(id, b.clone());
                return Ok(b);
            }
        }
        Err(BindError {
            role,
            wanted: affinity.to_vec(),
            count,
        })
    }

    /// Bind with the role's default affinity chain.
    pub fn bind_default(&mut self, role: Role, count: usize) -> Result<Binding, BindError> {
        self.bind(role, role.default_affinity(), count)
    }

    /// Release a binding back to its pool.  Idempotent per id.
    pub fn release(&mut self, binding_id: u64) -> bool {
        match self.bindings.remove(&binding_id) {
            Some(b) => {
                self.pools.get_mut(&b.class).unwrap().free += b.count;
                true
            }
            None => false,
        }
    }

    pub fn active_bindings(&self) -> impl Iterator<Item = &Binding> {
        self.bindings.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manager() -> ResourceManager {
        let mut rm = ResourceManager::new();
        rm.add_pool(ResourceClass::Gpu(GpuClass::H800), 96)
            .add_pool(ResourceClass::Gpu(GpuClass::H20), 32)
            .add_pool(ResourceClass::CpuSlot, 1024)
            .add_pool(ResourceClass::Serverless, usize::MAX / 2);
        rm
    }

    #[test]
    fn default_bindings_follow_paper_defaults() {
        let mut rm = manager();
        let train = rm.bind_default(Role::ActorTrain, 32).unwrap();
        assert_eq!(train.class, ResourceClass::Gpu(GpuClass::H800));
        assert!(!train.fallback);
        let gen = rm.bind_default(Role::ActorGen, 32).unwrap();
        assert_eq!(gen.class, ResourceClass::Gpu(GpuClass::H20));
        let env = rm.bind_default(Role::Environment, 512).unwrap();
        assert_eq!(env.class, ResourceClass::CpuSlot);
        let rew = rm.bind_default(Role::Reward, 8).unwrap();
        assert_eq!(rew.class, ResourceClass::Serverless);
        assert_eq!(rm.free(ResourceClass::Gpu(GpuClass::H800)), 64);
    }

    #[test]
    fn fallback_when_preferred_exhausted() {
        let mut rm = manager();
        rm.bind_default(Role::ActorGen, 32).unwrap(); // drains H20
        let gen2 = rm.bind_default(Role::ActorGen, 16).unwrap();
        assert_eq!(gen2.class, ResourceClass::Gpu(GpuClass::H800));
        assert!(gen2.fallback);
    }

    #[test]
    fn reward_affinity_order_is_serverless_then_h20_then_h800() {
        // §5.2: reward prefers the elastic pool and falls back through
        // bandwidth-optimized to compute-optimized GPUs — the exact
        // chain the paper's reward workers declare.
        assert_eq!(
            Role::Reward.default_affinity(),
            &[
                ResourceClass::Serverless,
                ResourceClass::Gpu(GpuClass::H20),
                ResourceClass::Gpu(GpuClass::H800),
            ]
        );
    }

    #[test]
    fn reward_falls_back_through_the_whole_chain_without_stalling() {
        // Finite pools so each tier can actually be exhausted.
        let mut rm = ResourceManager::new();
        rm.add_pool(ResourceClass::Serverless, 4)
            .add_pool(ResourceClass::Gpu(GpuClass::H20), 4)
            .add_pool(ResourceClass::Gpu(GpuClass::H800), 4);

        // Preferred tier has capacity: no fallback.
        let a = rm.bind_default(Role::Reward, 4).unwrap();
        assert_eq!(a.class, ResourceClass::Serverless);
        assert!(!a.fallback);

        // Serverless exhausted: binding lands on H20 immediately —
        // opportunistic fallback, not a stall on the preferred pool.
        let b = rm.bind_default(Role::Reward, 4).unwrap();
        assert_eq!(b.class, ResourceClass::Gpu(GpuClass::H20));
        assert!(b.fallback);

        // H20 exhausted too: last resort is H800.
        let c = rm.bind_default(Role::Reward, 4).unwrap();
        assert_eq!(c.class, ResourceClass::Gpu(GpuClass::H800));
        assert!(c.fallback);

        // Everything exhausted: an explicit error, never a hang.
        let err = rm.bind_default(Role::Reward, 4).unwrap_err();
        assert_eq!(err.role, Role::Reward);
        assert_eq!(err.wanted, Role::Reward.default_affinity().to_vec());

        // Releasing the preferred tier restores the original order.
        rm.release(a.id);
        let d = rm.bind_default(Role::Reward, 4).unwrap();
        assert_eq!(d.class, ResourceClass::Serverless);
        assert!(!d.fallback);
    }

    #[test]
    fn partial_preferred_capacity_still_falls_back_whole() {
        // 3 free serverless slots cannot host a 4-wide request: the
        // whole request falls back to H20 rather than splitting or
        // waiting for the preferred pool.
        let mut rm = ResourceManager::new();
        rm.add_pool(ResourceClass::Serverless, 3)
            .add_pool(ResourceClass::Gpu(GpuClass::H20), 8);
        let b = rm.bind_default(Role::Reward, 4).unwrap();
        assert_eq!(b.class, ResourceClass::Gpu(GpuClass::H20));
        assert!(b.fallback);
        assert_eq!(rm.free(ResourceClass::Serverless), 3, "untouched");
    }

    #[test]
    fn bind_error_when_nothing_fits() {
        let mut rm = manager();
        let err = rm
            .bind(
                Role::ActorTrain,
                &[ResourceClass::Gpu(GpuClass::H800)],
                200,
            )
            .unwrap_err();
        assert_eq!(err.count, 200);
        assert!(err.to_string().contains("ActorTrain"));
    }

    #[test]
    fn release_returns_capacity() {
        let mut rm = manager();
        let b = rm.bind_default(Role::ActorTrain, 96).unwrap();
        assert_eq!(rm.free(ResourceClass::Gpu(GpuClass::H800)), 0);
        assert!(rm.release(b.id));
        assert_eq!(rm.free(ResourceClass::Gpu(GpuClass::H800)), 96);
        // idempotent
        assert!(!rm.release(b.id));
        assert_eq!(rm.free(ResourceClass::Gpu(GpuClass::H800)), 96);
    }

    #[test]
    fn hw_mapping_routes_domains() {
        // Listing 1: FrozenLake → H800, default → H20.
        let mut rm = manager();
        rm.set_hw_mapping(TaskDomain::Game, GpuClass::H800);
        assert_eq!(rm.route_domain(TaskDomain::Game), Some(GpuClass::H800));
        assert_eq!(rm.route_domain(TaskDomain::MathTool), None);
    }

    #[test]
    fn partial_capacity_prefers_fallback_over_split() {
        // The manager binds whole requests to a single class (the
        // paper's Worker groups are homogeneous); a request larger
        // than the preferred pool's free space falls back entirely.
        let mut rm = ResourceManager::new();
        rm.add_pool(ResourceClass::Gpu(GpuClass::H20), 4)
            .add_pool(ResourceClass::Gpu(GpuClass::H800), 64);
        let b = rm.bind_default(Role::ActorGen, 8).unwrap();
        assert_eq!(b.class, ResourceClass::Gpu(GpuClass::H800));
        assert_eq!(rm.free(ResourceClass::Gpu(GpuClass::H20)), 4);
    }

    #[test]
    fn registry_tracks_active_bindings() {
        let mut rm = manager();
        let a = rm.bind_default(Role::ActorTrain, 8).unwrap();
        let _b = rm.bind_default(Role::ActorGen, 8).unwrap();
        assert_eq!(rm.active_bindings().count(), 2);
        rm.release(a.id);
        assert_eq!(rm.active_bindings().count(), 1);
    }
}
