//! GEM-math: the paper's decode-heavy math + tool-use environment [3].
//!
//! Few turns (<5), long chains of thought per action (§2.1) — the
//! decode-heavy pole of the bimodal task distribution.  Tasks are
//! integer arithmetic expressions; the optional `calc:` tool lets the
//! agent evaluate a sub-expression (tool use), and `answer:` submits.
//! The single-turn variant models GEM-game (Table 1: 1 turn).

use super::{Environment, Observation, TaskDomain};
use crate::simkit::SimRng;

pub struct GemMath {
    single_turn: bool,
    answer: i64,
    turns: usize,
    max_turns: usize,
    done: bool,
}

impl GemMath {
    pub fn new() -> Self {
        GemMath {
            single_turn: false,
            answer: 0,
            turns: 0,
            max_turns: 5,
            done: true,
        }
    }

    /// GEM-game: exactly one turn, answer immediately.
    pub fn single_turn() -> Self {
        GemMath {
            single_turn: true,
            answer: 0,
            turns: 0,
            max_turns: 1,
            done: true,
        }
    }

    /// Evaluate `a op b` with op ∈ {+, -, *}; used by the `calc:` tool.
    fn eval_tool(expr: &str) -> Option<i64> {
        let expr = expr.trim();
        for (sym, f) in [
            ("+", (|a: i64, b: i64| a.checked_add(b)) as fn(i64, i64) -> Option<i64>),
            ("*", |a, b| a.checked_mul(b)),
            ("-", |a, b| a.checked_sub(b)),
        ] {
            // split on the operator, allowing negative first operand
            if let Some(idx) = expr[1..].find(sym).map(|i| i + 1) {
                let (l, r) = expr.split_at(idx);
                let r = &r[1..];
                if let (Ok(a), Ok(b)) = (l.trim().parse::<i64>(), r.trim().parse::<i64>()) {
                    return f(a, b);
                }
            }
        }
        expr.parse::<i64>().ok()
    }

    /// Extract the submitted answer from free-form output: prefer an
    /// `answer:` marker, else the last integer in the text.
    fn parse_answer(text: &str) -> Option<i64> {
        let lower = text.to_lowercase();
        if let Some(idx) = lower.rfind("answer:") {
            let tail = &text[idx + 7..];
            let num: String = tail
                .chars()
                .skip_while(|c| c.is_whitespace())
                .take_while(|c| c.is_ascii_digit() || *c == '-')
                .collect();
            if let Ok(v) = num.parse() {
                return Some(v);
            }
        }
        // fallback: last integer token
        let mut last = None;
        let mut cur = String::new();
        for c in text.chars() {
            if c.is_ascii_digit() || (c == '-' && cur.is_empty()) {
                cur.push(c);
            } else if !cur.is_empty() {
                if let Ok(v) = cur.parse() {
                    last = Some(v);
                }
                cur.clear();
            }
        }
        if let Ok(v) = cur.parse() {
            last = Some(v);
        }
        last
    }
}

impl Default for GemMath {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for GemMath {
    fn domain(&self) -> TaskDomain {
        if self.single_turn {
            TaskDomain::GameSingle
        } else {
            TaskDomain::MathTool
        }
    }

    fn reset(&mut self, seed: u64) -> Observation {
        let mut rng = SimRng::new(seed);
        let a = rng.below(90) as i64 + 10;
        let b = rng.below(90) as i64 + 10;
        let c = rng.below(9) as i64 + 1;
        self.answer = a + b * c;
        self.turns = 0;
        self.done = false;
        Observation::ongoing(format!(
            "compute {a} + {b} * {c}. tools: 'calc: <x> <op> <y>'. \
             submit with 'answer: <n>'."
        ))
    }

    fn step(&mut self, action: &str) -> Observation {
        assert!(!self.done, "step after episode end");
        self.turns += 1;
        let lower = action.to_lowercase();

        // Tool call path (not available in single-turn mode).
        if !self.single_turn {
            if let Some(idx) = lower.find("calc:") {
                if !lower.contains("answer:") {
                    let expr = &action[idx + 5..];
                    let msg = match Self::eval_tool(expr) {
                        Some(v) => format!("calc result: {v}"),
                        None => "calc error: could not parse".to_string(),
                    };
                    if self.turns >= self.max_turns {
                        self.done = true;
                        return Observation::terminal("out of turns.", 0.0);
                    }
                    return Observation::ongoing(msg);
                }
            }
        }

        match Self::parse_answer(action) {
            Some(v) if v == self.answer => {
                self.done = true;
                Observation::terminal("correct!", 1.0)
            }
            _ if self.turns >= self.max_turns => {
                self.done = true;
                Observation::terminal("out of turns.", 0.0)
            }
            Some(_) => {
                self.done = true;
                Observation::terminal("wrong answer.", 0.0)
            }
            None => Observation::ongoing("no answer found; use 'answer: <n>'."),
        }
    }

    fn max_turns(&self) -> usize {
        self.max_turns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn answer_of(seed: u64) -> (GemMath, i64) {
        let mut env = GemMath::new();
        env.reset(seed);
        let ans = env.answer;
        (env, ans)
    }

    #[test]
    fn correct_answer_rewarded() {
        let (mut env, ans) = answer_of(5);
        let obs = env.step(&format!("thinking... answer: {ans}"));
        assert!(obs.done);
        assert_eq!(obs.reward, 1.0);
    }

    #[test]
    fn wrong_answer_terminal_zero() {
        let (mut env, ans) = answer_of(6);
        let obs = env.step(&format!("answer: {}", ans + 1));
        assert!(obs.done);
        assert_eq!(obs.reward, 0.0);
    }

    #[test]
    fn tool_use_then_answer() {
        let mut env = GemMath::new();
        let obs = env.reset(7);
        // extract operands from the prompt: "compute A + B * C."
        let nums: Vec<i64> = obs
            .text
            .split(|c: char| !c.is_ascii_digit())
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().unwrap())
            .collect();
        let (a, b, c) = (nums[0], nums[1], nums[2]);
        let t = env.step(&format!("calc: {b} * {c}"));
        assert!(!t.done);
        let prod: i64 = t.text.split(": ").nth(1).unwrap().parse().unwrap();
        assert_eq!(prod, b * c);
        let fin = env.step(&format!("answer: {}", a + prod));
        assert_eq!(fin.reward, 1.0);
    }

    #[test]
    fn single_turn_has_one_shot() {
        let mut env = GemMath::single_turn();
        env.reset(8);
        assert_eq!(env.max_turns(), 1);
        let obs = env.step("calc: 1 + 1"); // tools unavailable
        assert!(obs.done);
        assert_eq!(obs.reward, 0.0);
    }

    #[test]
    fn last_integer_fallback_parsing() {
        assert_eq!(GemMath::parse_answer("maybe 5 or 7? I'll say 42"), Some(42));
        assert_eq!(GemMath::parse_answer("answer: -13"), Some(-13));
        assert_eq!(GemMath::parse_answer("no numbers here"), None);
    }

    #[test]
    fn eval_tool_ops() {
        assert_eq!(GemMath::eval_tool("3 + 4"), Some(7));
        assert_eq!(GemMath::eval_tool("3 * 4"), Some(12));
        assert_eq!(GemMath::eval_tool("10 - 4"), Some(6));
        assert_eq!(GemMath::eval_tool("-5 + 2"), Some(-3));
        assert_eq!(GemMath::eval_tool("7"), Some(7));
        assert_eq!(GemMath::eval_tool("nope"), None);
    }

    #[test]
    fn unanswered_runs_out_of_turns() {
        let mut env = GemMath::new();
        env.reset(9);
        let mut obs = Observation::ongoing("");
        for _ in 0..env.max_turns() {
            obs = env.step("still thinking");
            if obs.done {
                break;
            }
        }
        assert!(obs.done);
        assert_eq!(obs.reward, 0.0);
    }
}
