//! Agentic environments (paper Table 1).
//!
//! Real, fully-implemented Rust environments used by both harnesses:
//! the e2e example drives them against the AOT transformer through the
//! coordinator; the DES uses their [`profile::DomainProfile`]s (turn
//! counts, token footprints) as workload generators.
//!
//! | env | paper counterpart | domain | turns |
//! |---|---|---|---|
//! | [`FrozenLake`] | FrozenLake [9] | Game (prefill-heavy) | 20–100 |
//! | [`GemMath`] | GEM-math [3] | Math+Tool (decode-heavy) | <5 |
//! | [`WebShop`] | WebShop [61] | Web | 5–30 |
//! | [`SweSim`] | SWE-bench [23] | SWE | 30–50 |
//!
//! SWE-bench and WebShop run in containers the paper's K8s cluster
//! provides; here they are deterministic in-process simulations that
//! preserve the interaction *pattern* (observation sizes, turn counts,
//! success conditions) — see DESIGN.md §2 Substitutions.

mod echo;
mod frozen_lake;
mod gem_math;
pub mod profile;
mod swe;
pub mod tokenizer;
mod webshop;

pub use echo::EchoEnv;
pub use frozen_lake::FrozenLake;
pub use gem_math::GemMath;
pub use swe::SweSim;
pub use webshop::WebShop;


/// Task domains, the unit of hardware-affinity annotation (R1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TaskDomain {
    /// SWE-bench-like software engineering (30–50 turns, prefill-heavy).
    Swe,
    /// WebShop-like web navigation (5–30 turns).
    Web,
    /// FrozenLake-like games (20–100 turns, prefill-heavy).
    Game,
    /// GEM-math-like math + tool use (<5 turns, decode-heavy).
    MathTool,
    /// GEM-game single-turn tasks.
    GameSingle,
}

impl TaskDomain {
    pub fn name(self) -> &'static str {
        match self {
            TaskDomain::Swe => "swe",
            TaskDomain::Web => "web",
            TaskDomain::Game => "game",
            TaskDomain::MathTool => "math_tool",
            TaskDomain::GameSingle => "game_single",
        }
    }

    pub const ALL: [TaskDomain; 5] = [
        TaskDomain::Swe,
        TaskDomain::Web,
        TaskDomain::Game,
        TaskDomain::MathTool,
        TaskDomain::GameSingle,
    ];
}

impl std::fmt::Display for TaskDomain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What an environment returns to the agent after reset/step.
#[derive(Clone, Debug, PartialEq)]
pub struct Observation {
    pub text: String,
    pub done: bool,
    /// Final scalar reward; only meaningful when `done`.
    pub reward: f64,
}

impl Observation {
    pub fn ongoing(text: impl Into<String>) -> Self {
        Observation {
            text: text.into(),
            done: false,
            reward: 0.0,
        }
    }

    pub fn terminal(text: impl Into<String>, reward: f64) -> Self {
        Observation {
            text: text.into(),
            done: true,
            reward,
        }
    }
}

/// A stateful, multi-turn agentic environment (paper §2.1).
///
/// The lifecycle mirrors the paper's `env.reset` / `env.step` API: a
/// reset instantiates a task (seeded → reproducible), then the agent
/// alternates generation and `step` until `done`.
pub trait Environment: Send {
    fn domain(&self) -> TaskDomain;

    /// Start a new task instance. Deterministic in `seed`.
    fn reset(&mut self, seed: u64) -> Observation;

    /// Apply one agent action (raw generated text).
    fn step(&mut self, action: &str) -> Observation;

    /// Hard turn budget after which the episode is failed.
    fn max_turns(&self) -> usize;
}

/// Construct the environment for a domain (uniform factory used by the
/// coordinator's task mix).
pub fn make_env(domain: TaskDomain) -> Box<dyn Environment> {
    match domain {
        TaskDomain::Game => Box::new(FrozenLake::new(4, false)),
        TaskDomain::MathTool => Box::new(GemMath::new()),
        TaskDomain::GameSingle => Box::new(GemMath::single_turn()),
        TaskDomain::Web => Box::new(WebShop::new()),
        TaskDomain::Swe => Box::new(SweSim::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_produces_matching_domain() {
        for d in TaskDomain::ALL {
            let env = make_env(d);
            assert_eq!(env.domain(), d);
            assert!(env.max_turns() >= 1);
        }
    }

    #[test]
    fn every_env_resets_deterministically() {
        for d in TaskDomain::ALL {
            let mut a = make_env(d);
            let mut b = make_env(d);
            assert_eq!(a.reset(42).text, b.reset(42).text, "{d}");
            // different seeds give different tasks for multi-instance envs
            let mut c = make_env(d);
            let o1 = c.reset(1);
            let mut e = make_env(d);
            let o2 = e.reset(2);
            // not required to differ for all, but text must be non-empty
            assert!(!o1.text.is_empty() && !o2.text.is_empty());
        }
    }

    #[test]
    fn episodes_terminate_within_budget() {
        // Feeding garbage actions must still terminate by max_turns.
        for d in TaskDomain::ALL {
            let mut env = make_env(d);
            let mut obs = env.reset(7);
            let mut turns = 0;
            while !obs.done {
                obs = env.step("garbage action text");
                turns += 1;
                assert!(
                    turns <= env.max_turns() + 1,
                    "{d} exceeded turn budget"
                );
            }
        }
    }
}
