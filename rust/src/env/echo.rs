//! Echo: a single-turn environment with a *smooth* reward, used by the
//! end-to-end example to demonstrate real learning with the AOT
//! transformer within a CPU-session budget.
//!
//! The instruction asks the agent to repeat a short byte string; the
//! reward is the per-byte match fraction (partial credit), which gives
//! GRPO a dense signal the ~4.5M-param byte-level model can climb in a
//! few hundred steps.  Pattern-wise it is a GEM-game-like single-turn
//! task (Table 1).

use super::{Environment, Observation, TaskDomain};
use crate::simkit::SimRng;

pub struct EchoEnv {
    target: Vec<u8>,
    done: bool,
    /// Alphabet to draw targets from (small: learnable quickly).
    alphabet: &'static [u8],
    len: usize,
}

impl EchoEnv {
    pub fn new() -> Self {
        EchoEnv {
            target: Vec::new(),
            done: true,
            alphabet: b"ab",
            len: 4,
        }
    }

    pub fn with_difficulty(alphabet: &'static [u8], len: usize) -> Self {
        assert!(!alphabet.is_empty() && len > 0);
        EchoEnv {
            target: Vec::new(),
            done: true,
            alphabet,
            len,
        }
    }

    /// Per-byte overlap score in [0, 1].
    fn score(target: &[u8], reply: &[u8]) -> f64 {
        if target.is_empty() {
            return 0.0;
        }
        let hits = target
            .iter()
            .zip(reply.iter())
            .filter(|(a, b)| a == b)
            .count();
        // length penalty: overlong replies dilute the score
        let extra = reply.len().saturating_sub(target.len());
        (hits as f64 - 0.25 * extra as f64).max(0.0) / target.len() as f64
    }
}

impl Default for EchoEnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for EchoEnv {
    fn domain(&self) -> TaskDomain {
        TaskDomain::GameSingle
    }

    fn reset(&mut self, seed: u64) -> Observation {
        let mut rng = SimRng::new(seed);
        self.target = (0..self.len)
            .map(|_| *rng.choose(self.alphabet))
            .collect();
        self.done = false;
        Observation::ongoing(format!(
            "say:{}",
            String::from_utf8_lossy(&self.target)
        ))
    }

    fn step(&mut self, action: &str) -> Observation {
        assert!(!self.done, "step after episode end");
        self.done = true;
        let reward = Self::score(&self.target, action.trim().as_bytes());
        Observation::terminal("done", reward)
    }

    fn max_turns(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_echo_scores_one() {
        let mut env = EchoEnv::new();
        let obs = env.reset(3);
        let target = obs.text.strip_prefix("say:").unwrap().to_string();
        let fin = env.step(&target);
        assert!(fin.done);
        assert_eq!(fin.reward, 1.0);
    }

    #[test]
    fn partial_credit() {
        let mut env = EchoEnv::with_difficulty(b"ab", 4);
        let obs = env.reset(4);
        let target = obs.text.strip_prefix("say:").unwrap().as_bytes().to_vec();
        let mut half = target.clone();
        half[0] = if half[0] == b'a' { b'b' } else { b'a' };
        half[1] = if half[1] == b'a' { b'b' } else { b'a' };
        let fin = env.step(std::str::from_utf8(&half).unwrap());
        assert!((fin.reward - 0.5).abs() < 1e-9);
    }

    #[test]
    fn garbage_scores_low() {
        let mut env = EchoEnv::new();
        env.reset(5);
        let fin = env.step("zzzzzzzzzzzz");
        assert!(fin.reward < 0.3, "{}", fin.reward);
    }

    #[test]
    fn deterministic_target_per_seed() {
        let mut a = EchoEnv::new();
        let mut b = EchoEnv::new();
        assert_eq!(a.reset(9).text, b.reset(9).text);
        assert_ne!(a.target.is_empty(), true);
    }

    #[test]
    fn overlong_reply_penalized() {
        let mut env = EchoEnv::new();
        let obs = env.reset(6);
        let target = obs.text.strip_prefix("say:").unwrap().to_string();
        let fin = env.step(&format!("{target}{target}{target}"));
        assert!(fin.reward < 1.0);
    }
}
