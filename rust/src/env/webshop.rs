//! WebShop: the paper's web-navigation environment [61], simulated.
//!
//! A deterministic in-process shop: a seeded catalog of items with
//! (category, color, price) attributes and an instruction like
//! "buy a red shirt under $40".  Actions: `search <keywords>`,
//! `click <item-id>`, `buy`.  Preserves WebShop's interaction pattern
//! (multi-turn browsing, 5–30 turns, medium observations) without the
//! real website container (DESIGN.md §2 Substitutions).

use super::{Environment, Observation, TaskDomain};
use crate::simkit::SimRng;

const CATEGORIES: [&str; 6] = ["shirt", "shoes", "lamp", "mug", "chair", "hat"];
const COLORS: [&str; 6] = ["red", "blue", "green", "black", "white", "yellow"];

#[derive(Clone, Debug)]
struct Item {
    id: usize,
    category: &'static str,
    color: &'static str,
    price: u32,
}

impl Item {
    fn describe(&self) -> String {
        format!("[{}] {} {} - ${}", self.id, self.color, self.category, self.price)
    }
}

pub struct WebShop {
    catalog: Vec<Item>,
    want_cat: &'static str,
    want_color: &'static str,
    max_price: u32,
    selected: Option<usize>,
    turns: usize,
    done: bool,
}

impl WebShop {
    pub fn new() -> Self {
        WebShop {
            catalog: Vec::new(),
            want_cat: "",
            want_color: "",
            max_price: 0,
            selected: None,
            turns: 0,
            done: true,
        }
    }

    fn matches_goal(&self, item: &Item) -> bool {
        item.category == self.want_cat
            && item.color == self.want_color
            && item.price <= self.max_price
    }

    fn search(&self, query: &str) -> Vec<&Item> {
        let q = query.to_lowercase();
        let terms: Vec<&str> = q
            .split(|c: char| !c.is_alphanumeric())
            .filter(|s| !s.is_empty())
            .collect();
        let mut hits: Vec<&Item> = self
            .catalog
            .iter()
            .filter(|it| {
                terms
                    .iter()
                    .any(|t| it.category.contains(t) || it.color.contains(t))
            })
            .collect();
        hits.truncate(5);
        hits
    }
}

impl Default for WebShop {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for WebShop {
    fn domain(&self) -> TaskDomain {
        TaskDomain::Web
    }

    fn reset(&mut self, seed: u64) -> Observation {
        let mut rng = SimRng::new(seed);
        self.catalog = (0..40)
            .map(|id| Item {
                id,
                category: CATEGORIES[rng.below(CATEGORIES.len())],
                color: COLORS[rng.below(COLORS.len())],
                price: 5 + rng.below(95) as u32,
            })
            .collect();
        // Pick a goal that exists in the catalog so every task is
        // solvable (mirrors WebShop's attainable instructions).
        let goal_idx = rng.below(self.catalog.len());
        let goal = self.catalog[goal_idx].clone();
        self.want_cat = goal.category;
        self.want_color = goal.color;
        self.max_price = goal.price + rng.below(20) as u32;
        self.selected = None;
        self.turns = 0;
        self.done = false;
        Observation::ongoing(format!(
            "instruction: buy a {} {} under ${}. actions: 'search <kw>', \
             'click <id>', 'buy'.",
            self.want_color, self.want_cat, self.max_price
        ))
    }

    fn step(&mut self, action: &str) -> Observation {
        assert!(!self.done, "step after episode end");
        self.turns += 1;
        let lower = action.to_lowercase();
        let out_of_turns = self.turns >= self.max_turns();

        let obs = if let Some(idx) = lower.find("search") {
            let query = &lower[idx + 6..];
            let hits = self.search(query);
            if hits.is_empty() {
                Observation::ongoing("no results.".to_string())
            } else {
                let list: Vec<String> = hits.iter().map(|i| i.describe()).collect();
                Observation::ongoing(format!("results:\n{}", list.join("\n")))
            }
        } else if let Some(idx) = lower.find("click") {
            let id: Option<usize> = lower[idx + 5..]
                .split(|c: char| !c.is_ascii_digit())
                .find(|s| !s.is_empty())
                .and_then(|s| s.parse().ok());
            match id.and_then(|i| self.catalog.iter().find(|it| it.id == i)) {
                Some(item) => {
                    self.selected = Some(item.id);
                    Observation::ongoing(format!(
                        "viewing {}. 'buy' to purchase.",
                        item.describe()
                    ))
                }
                None => Observation::ongoing("item not found.".to_string()),
            }
        } else if lower.contains("buy") {
            self.done = true;
            let reward = match self.selected {
                Some(id) => {
                    let item = self.catalog.iter().find(|it| it.id == id).unwrap();
                    if self.matches_goal(item) {
                        1.0
                    } else {
                        0.0
                    }
                }
                None => 0.0,
            };
            return Observation::terminal(
                if reward > 0.0 { "purchase complete!" } else { "wrong item." },
                reward,
            );
        } else {
            Observation::ongoing("unknown action. use search/click/buy.".to_string())
        };

        if out_of_turns {
            self.done = true;
            return Observation::terminal("session expired.", 0.0);
        }
        obs
    }

    fn max_turns(&self) -> usize {
        30
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goal_is_always_attainable() {
        for seed in 0..30 {
            let mut env = WebShop::new();
            env.reset(seed);
            assert!(
                env.catalog.iter().any(|it| env.matches_goal(it)),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn oracle_agent_succeeds() {
        let mut env = WebShop::new();
        env.reset(11);
        let (cat, color) = (env.want_cat, env.want_color);
        let obs = env.step(&format!("search {color} {cat}"));
        assert!(obs.text.contains("results"));
        // pick the first listed id that matches the goal
        let target = env
            .catalog
            .iter()
            .find(|it| env.matches_goal(it))
            .unwrap()
            .id;
        env.step(&format!("click {target}"));
        let fin = env.step("buy");
        assert!(fin.done);
        assert_eq!(fin.reward, 1.0);
    }

    #[test]
    fn buying_without_selection_fails() {
        let mut env = WebShop::new();
        env.reset(12);
        let fin = env.step("buy");
        assert!(fin.done);
        assert_eq!(fin.reward, 0.0);
    }

    #[test]
    fn buying_wrong_item_fails() {
        let mut env = WebShop::new();
        env.reset(13);
        let wrong = env
            .catalog
            .iter()
            .find(|it| !env.matches_goal(it))
            .unwrap()
            .id;
        env.step(&format!("click {wrong}"));
        let fin = env.step("buy");
        assert_eq!(fin.reward, 0.0);
    }

    #[test]
    fn search_limits_results() {
        let mut env = WebShop::new();
        env.reset(14);
        let obs = env.step("search red blue green black white yellow");
        let lines = obs.text.lines().count();
        assert!(lines <= 6, "{}", obs.text); // header + ≤5 items
    }

    #[test]
    fn session_expires_at_turn_budget() {
        let mut env = WebShop::new();
        env.reset(15);
        let mut obs = Observation::ongoing("");
        for _ in 0..env.max_turns() {
            obs = env.step("search nothingmatches");
            if obs.done {
                break;
            }
        }
        assert!(obs.done);
        assert_eq!(obs.reward, 0.0);
    }
}
