//! Byte-level tokenizer shared by the real execution path.
//!
//! Vocab = 512 (matching `python/compile/shapes.py`): ids 0–255 are raw
//! bytes; 256+ are special tokens.  Byte-level keeps the tokenizer
//! trivially correct and reversible — the right trade for a ~4.5M-param
//! e2e model whose job is to prove the stack composes.

/// Special token ids (must stay below the 512 vocab of shapes.py).
pub const PAD: i32 = 256;
pub const BOS: i32 = 257;
pub const EOS: i32 = 258;
/// Separator between conversation turns (observation ↔ action).
pub const SEP: i32 = 259;
/// Marks the start of an agent action (tokens after this are trained).
pub const ACT: i32 = 260;

pub const VOCAB: usize = 512;

/// Encode text as raw bytes.
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

/// Decode token ids back to text; specials and out-of-range ids are
/// dropped, invalid UTF-8 is replaced.
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Build a prompt: BOS, obs bytes, SEP, ... , ACT.
/// `history` is the alternating (observation, action) transcript.
pub fn build_prompt(history: &[(String, String)], latest_obs: &str, budget: usize) -> Vec<i32> {
    let mut toks = vec![BOS];
    for (obs, act) in history {
        toks.extend(encode(obs));
        toks.push(ACT);
        toks.extend(encode(act));
        toks.push(SEP);
    }
    toks.extend(encode(latest_obs));
    toks.push(ACT);
    // Keep the most recent `budget` tokens (sliding window), always
    // starting with BOS so position 0 is stable.
    if toks.len() > budget {
        let tail = toks.split_off(toks.len() - (budget - 1));
        toks = vec![BOS];
        toks.extend(tail);
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let s = "move right, then up!";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let mut t = encode("ab");
        t.push(EOS);
        t.insert(0, BOS);
        assert_eq!(decode(&t), "ab");
    }

    #[test]
    fn specials_below_vocab() {
        for t in [PAD, BOS, EOS, SEP, ACT] {
            assert!((t as usize) < VOCAB);
        }
    }

    #[test]
    fn prompt_structure() {
        let hist = vec![("you are at S".to_string(), "right".to_string())];
        let p = build_prompt(&hist, "you moved", 4096);
        assert_eq!(p[0], BOS);
        assert_eq!(*p.last().unwrap(), ACT);
        // contains exactly two ACT markers (one per action slot)
        assert_eq!(p.iter().filter(|&&t| t == ACT).count(), 2);
        assert_eq!(p.iter().filter(|&&t| t == SEP).count(), 1);
    }

    #[test]
    fn prompt_truncates_to_budget() {
        let hist: Vec<(String, String)> = (0..50)
            .map(|i| (format!("obs {i} {}", "x".repeat(40)), "act".to_string()))
            .collect();
        let p = build_prompt(&hist, "final", 128);
        assert_eq!(p.len(), 128);
        assert_eq!(p[0], BOS);
        assert_eq!(*p.last().unwrap(), ACT);
    }

    #[test]
    fn utf8_lossy_is_safe() {
        // Splitting a multi-byte char across the window must not panic.
        let s = "héllo";
        let toks = encode(s);
        let _ = decode(&toks[1..]);
    }
}
