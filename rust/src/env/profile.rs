//! Per-domain workload profiles for the DES harness.
//!
//! The paper's insight (§3, §8, §9) is that computation profiles are
//! *stable per task domain*: turn counts and prefill/decode ratios vary
//! wildly across domains but stay bounded within one.  These profiles
//! parameterize the simulated rollout generator; the numbers follow
//! Table 1's turn ranges and §2.1's chain-of-thought observations.

use super::TaskDomain;
use crate::simkit::dist::Dist;
use crate::simkit::SimRng;

/// Workload statistics of one task domain.
#[derive(Clone, Debug)]
pub struct DomainProfile {
    pub domain: TaskDomain,
    /// Interaction turns per trajectory.
    pub turns: Dist,
    /// Prompt/system tokens at trajectory start.
    pub initial_prompt_tokens: f64,
    /// Observation tokens appended per turn (drives prefill growth).
    pub obs_tokens_per_turn: Dist,
    /// Generated (decoded) tokens per action.
    pub action_tokens: Dist,
    /// Whether the domain is prefill-heavy (many turns, growing
    /// context) or decode-heavy (few turns, long chains of thought).
    pub prefill_heavy: bool,
}

impl DomainProfile {
    pub fn of(domain: TaskDomain) -> DomainProfile {
        match domain {
            // SWE-bench: 30–50 turns, file-listing observations,
            // moderate actions. Strongly prefill-heavy.
            TaskDomain::Swe => DomainProfile {
                domain,
                turns: Dist::Uniform { lo: 30.0, hi: 50.0 },
                initial_prompt_tokens: 2000.0,
                obs_tokens_per_turn: Dist::LogNormal {
                    mu: 6.4,
                    sigma: 0.5,
                }, // median ~600 (file listings, diffs)
                action_tokens: Dist::LogNormal { mu: 5.5, sigma: 0.4 }, // ~250 CoT
                prefill_heavy: true,
            },
            // WebShop: 5–30 turns, medium pages.
            TaskDomain::Web => DomainProfile {
                domain,
                turns: Dist::Uniform { lo: 5.0, hi: 30.0 },
                initial_prompt_tokens: 800.0,
                obs_tokens_per_turn: Dist::LogNormal {
                    mu: 5.7,
                    sigma: 0.4,
                }, // ~300 (page contents)
                action_tokens: Dist::LogNormal { mu: 4.8, sigma: 0.4 }, // ~120
                prefill_heavy: true,
            },
            // FrozenLake: 20–100 turns, small board renders, short
            // actions — prefill dominates through sheer turn count.
            TaskDomain::Game => DomainProfile {
                domain,
                turns: Dist::Uniform { lo: 20.0, hi: 100.0 },
                initial_prompt_tokens: 400.0,
                obs_tokens_per_turn: Dist::LogNormal {
                    mu: 4.8,
                    sigma: 0.3,
                }, // ~120 (board render + status)
                action_tokens: Dist::LogNormal { mu: 3.7, sigma: 0.5 }, // ~40
                prefill_heavy: true,
            },
            // GEM-math: <5 turns, long chains of thought → decode-heavy.
            TaskDomain::MathTool => DomainProfile {
                domain,
                turns: Dist::Uniform { lo: 1.0, hi: 5.0 },
                initial_prompt_tokens: 400.0,
                obs_tokens_per_turn: Dist::LogNormal {
                    mu: 3.4,
                    sigma: 0.3,
                }, // ~30
                action_tokens: Dist::LogNormal { mu: 7.6, sigma: 0.5 }, // ~2000
                prefill_heavy: false,
            },
            // GEM-game: single turn, very long response.
            TaskDomain::GameSingle => DomainProfile {
                domain,
                turns: Dist::Constant(1.0),
                initial_prompt_tokens: 350.0,
                obs_tokens_per_turn: Dist::Constant(0.0),
                action_tokens: Dist::LogNormal { mu: 7.6, sigma: 0.5 }, // ~2000
                prefill_heavy: false,
            },
        }
    }

    /// Sample one trajectory's shape: per-turn (obs tokens, action
    /// tokens) plus the initial prompt.
    pub fn sample_trajectory(&self, rng: &mut SimRng) -> TrajectoryShape {
        let turns = self.turns.sample(rng).round().max(1.0) as usize;
        let mut per_turn = Vec::with_capacity(turns);
        for _ in 0..turns {
            let obs = self.obs_tokens_per_turn.sample(rng).round().max(0.0);
            let act = self.action_tokens.sample(rng).round().max(1.0);
            per_turn.push((obs, act));
        }
        TrajectoryShape {
            domain: self.domain,
            initial_prompt_tokens: self.initial_prompt_tokens,
            per_turn,
        }
    }

    /// Expected decode-to-prefill token ratio under prefix caching
    /// (diagnostic; validates the prefill/decode-heavy labels).  With
    /// prefix caching — which the paper's rollouts enable (§7.1) — each
    /// turn only prefills the *new* observation tokens; previously
    /// generated actions are already cached.
    pub fn decode_prefill_ratio(&self) -> f64 {
        let turns = self.turns.mean();
        let decoded = turns * self.action_tokens.mean();
        let prefilled =
            self.initial_prompt_tokens + turns * self.obs_tokens_per_turn.mean();
        decoded / prefilled.max(1.0)
    }
}

/// A sampled trajectory's token structure.
#[derive(Clone, Debug)]
pub struct TrajectoryShape {
    pub domain: TaskDomain,
    pub initial_prompt_tokens: f64,
    /// (observation tokens, action tokens) per turn.
    pub per_turn: Vec<(f64, f64)>,
}

impl TrajectoryShape {
    pub fn turns(&self) -> usize {
        self.per_turn.len()
    }

    /// Total tokens decoded by the LLM.
    pub fn decode_tokens(&self) -> f64 {
        self.per_turn.iter().map(|(_, a)| a).sum()
    }

    /// Total tokens prefilled across all turns assuming prefix caching
    /// (only *new* tokens are prefilled each turn: the previous turn's
    /// observation; the generated action is already cached).
    pub fn prefill_tokens_cached(&self) -> f64 {
        self.initial_prompt_tokens + self.per_turn.iter().map(|(o, _)| o).sum::<f64>()
    }

    /// Final context length.
    pub fn final_context(&self) -> f64 {
        self.initial_prompt_tokens
            + self
                .per_turn
                .iter()
                .map(|(o, a)| o + a)
                .sum::<f64>()
    }

    /// Total tokens in the finished trajectory (prompt + response), the
    /// §7.1 throughput numerator.
    pub fn total_tokens(&self) -> f64 {
        self.final_context()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_match_ratios() {
        // Decode-heavy domains decode more tokens than they prefill;
        // prefill-heavy domains the opposite, by a wide margin.
        for d in TaskDomain::ALL {
            let p = DomainProfile::of(d);
            let r = p.decode_prefill_ratio();
            if p.prefill_heavy {
                assert!(r < 0.5, "{d}: ratio {r}");
            } else {
                assert!(r > 1.0, "{d}: ratio {r}");
            }
        }
    }

    #[test]
    fn turn_ranges_match_table1() {
        let mut rng = SimRng::new(0);
        let swe = DomainProfile::of(TaskDomain::Swe);
        for _ in 0..100 {
            let t = swe.sample_trajectory(&mut rng).turns();
            assert!((30..=50).contains(&t), "{t}");
        }
        let math = DomainProfile::of(TaskDomain::MathTool);
        for _ in 0..100 {
            let t = math.sample_trajectory(&mut rng).turns();
            assert!(t <= 5, "{t}");
        }
        let single = DomainProfile::of(TaskDomain::GameSingle);
        assert_eq!(single.sample_trajectory(&mut rng).turns(), 1);
    }

    #[test]
    fn trajectory_accounting_consistent() {
        let mut rng = SimRng::new(1);
        let p = DomainProfile::of(TaskDomain::Web);
        let t = p.sample_trajectory(&mut rng);
        assert!(t.final_context() >= t.prefill_tokens_cached());
        assert!(
            (t.final_context() - t.prefill_tokens_cached() - t.decode_tokens()).abs() < 1e-9
        );
    }

    #[test]
    fn bimodal_turn_distribution() {
        // §3: production tasks are bimodal — <5 or >10 turns.
        let math = DomainProfile::of(TaskDomain::MathTool).turns.mean();
        let swe = DomainProfile::of(TaskDomain::Swe).turns.mean();
        assert!(math < 5.0);
        assert!(swe > 10.0);
    }
}
