//! SWE-bench-like software-engineering environment [23], simulated.
//!
//! The paper runs real repository containers; here a deterministic
//! mini-codebase (a handful of files, one seeded bug) preserves the
//! interaction pattern that matters to the system: long horizons
//! (30–50 turns), large observations (file listings), and a verifiable
//! terminal condition (`test` passes only when the bug is fixed).
//! See DESIGN.md §2 Substitutions.

use super::{Environment, Observation, TaskDomain};
use crate::simkit::SimRng;
use std::collections::BTreeMap;

/// One injectable bug: (file, line, buggy text, fixed text, test name).
struct BugTemplate {
    file: &'static str,
    line: usize,
    buggy: &'static str,
    fixed: &'static str,
    test: &'static str,
}

const BUGS: [BugTemplate; 4] = [
    BugTemplate {
        file: "calc.rs",
        line: 2,
        buggy: "    a - b",
        fixed: "    a + b",
        test: "test_add",
    },
    BugTemplate {
        file: "calc.rs",
        line: 6,
        buggy: "    a * a",
        fixed: "    a * b",
        test: "test_mul",
    },
    BugTemplate {
        file: "text.rs",
        line: 2,
        buggy: "    s.to_uppercase()",
        fixed: "    s.to_lowercase()",
        test: "test_lower",
    },
    BugTemplate {
        file: "list.rs",
        line: 2,
        buggy: "    v.first()",
        fixed: "    v.last()",
        test: "test_last",
    },
];

fn base_codebase() -> BTreeMap<String, Vec<String>> {
    let mut files = BTreeMap::new();
    files.insert(
        "calc.rs".to_string(),
        vec![
            "fn add(a: i64, b: i64) -> i64 {".into(),
            "    a + b".into(),
            "}".into(),
            "".into(),
            "fn mul(a: i64, b: i64) -> i64 {".into(),
            "    a * b".into(),
            "}".into(),
        ],
    );
    files.insert(
        "text.rs".to_string(),
        vec![
            "fn lower(s: &str) -> String {".into(),
            "    s.to_lowercase()".into(),
            "}".into(),
        ],
    );
    files.insert(
        "list.rs".to_string(),
        vec![
            "fn last(v: &[i64]) -> Option<&i64> {".into(),
            "    v.last()".into(),
            "}".into(),
        ],
    );
    files
}

pub struct SweSim {
    files: BTreeMap<String, Vec<String>>,
    bug: usize,
    turns: usize,
    done: bool,
}

impl SweSim {
    pub fn new() -> Self {
        SweSim {
            files: BTreeMap::new(),
            bug: 0,
            turns: 0,
            done: true,
        }
    }

    fn bug_fixed(&self) -> bool {
        let b = &BUGS[self.bug];
        self.files
            .get(b.file)
            .and_then(|lines| lines.get(b.line))
            .map(|l| l.trim() == b.fixed.trim())
            .unwrap_or(false)
    }

    fn run_tests(&self) -> String {
        let b = &BUGS[self.bug];
        if self.bug_fixed() {
            "all tests passed.".to_string()
        } else {
            format!("FAILED {}: expected fixed behaviour in {}", b.test, b.file)
        }
    }
}

impl Default for SweSim {
    fn default() -> Self {
        Self::new()
    }
}

impl Environment for SweSim {
    fn domain(&self) -> TaskDomain {
        TaskDomain::Swe
    }

    fn reset(&mut self, seed: u64) -> Observation {
        let mut rng = SimRng::new(seed);
        self.files = base_codebase();
        self.bug = rng.below(BUGS.len());
        let b = &BUGS[self.bug];
        self.files.get_mut(b.file).unwrap()[b.line] = b.buggy.to_string();
        self.turns = 0;
        self.done = false;
        let listing: Vec<&str> = self.files.keys().map(|s| s.as_str()).collect();
        Observation::ongoing(format!(
            "issue: {} fails. files: {}. actions: 'open <file>', \
             'edit <file> <line> <code>', 'test'.",
            b.test,
            listing.join(", ")
        ))
    }

    fn step(&mut self, action: &str) -> Observation {
        assert!(!self.done, "step after episode end");
        self.turns += 1;
        let lower = action.to_lowercase();
        let out_of_turns = self.turns >= self.max_turns();

        let obs = if let Some(idx) = lower.find("open") {
            let name = action[idx + 4..]
                .split_whitespace()
                .next()
                .unwrap_or("")
                .to_string();
            match self.files.get(&name) {
                Some(lines) => {
                    let numbered: Vec<String> = lines
                        .iter()
                        .enumerate()
                        .map(|(i, l)| format!("{i}: {l}"))
                        .collect();
                    Observation::ongoing(format!("{name}:\n{}", numbered.join("\n")))
                }
                None => Observation::ongoing("no such file.".to_string()),
            }
        } else if let Some(idx) = lower.find("edit") {
            let rest = &action[idx + 4..];
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("").to_string();
            let line: Option<usize> = it.next().and_then(|s| s.parse().ok());
            let code: String = {
                // remainder after the line number, preserving spacing-ish
                let consumed: usize = rest
                    .find(|c: char| c.is_ascii_digit())
                    .map(|p| {
                        p + rest[p..]
                            .find(char::is_whitespace)
                            .unwrap_or(rest.len() - p)
                    })
                    .unwrap_or(rest.len());
                rest[consumed.min(rest.len())..].trim().to_string()
            };
            match (self.files.get_mut(&name), line) {
                (Some(lines), Some(ln)) if ln < lines.len() => {
                    lines[ln] = format!("    {code}");
                    Observation::ongoing(format!("edited {name}:{ln}"))
                }
                _ => Observation::ongoing("edit failed: bad file or line.".to_string()),
            }
        } else if lower.contains("test") {
            let result = self.run_tests();
            if self.bug_fixed() {
                self.done = true;
                return Observation::terminal(result, 1.0);
            }
            Observation::ongoing(result)
        } else {
            Observation::ongoing("unknown action. use open/edit/test.".to_string())
        };

        if out_of_turns {
            self.done = true;
            return Observation::terminal("time limit reached.", 0.0);
        }
        obs
    }

    fn max_turns(&self) -> usize {
        50
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_agent_fixes_the_bug() {
        for seed in 0..8 {
            let mut env = SweSim::new();
            env.reset(seed);
            let b = &BUGS[env.bug];
            // open, edit the buggy line with the fix, run tests
            let obs = env.step(&format!("open {}", b.file));
            assert!(obs.text.contains(&format!("{}:", b.line)), "{}", obs.text);
            env.step(&format!("edit {} {} {}", b.file, b.line, b.fixed.trim()));
            let fin = env.step("test");
            assert!(fin.done, "seed {seed}");
            assert_eq!(fin.reward, 1.0);
        }
    }

    #[test]
    fn tests_fail_before_fix() {
        let mut env = SweSim::new();
        env.reset(3);
        let obs = env.step("test");
        assert!(!obs.done);
        assert!(obs.text.contains("FAILED"));
    }

    #[test]
    fn wrong_edit_does_not_pass() {
        let mut env = SweSim::new();
        env.reset(4);
        let b = &BUGS[env.bug];
        env.step(&format!("edit {} {} something_wrong()", b.file, b.line));
        let obs = env.step("test");
        assert!(!obs.done);
        assert!(obs.text.contains("FAILED"));
    }

    #[test]
    fn open_lists_numbered_lines() {
        let mut env = SweSim::new();
        env.reset(5);
        let obs = env.step("open calc.rs");
        assert!(obs.text.starts_with("calc.rs:"));
        assert!(obs.text.contains("0: fn add"));
    }

    #[test]
    fn edit_bad_line_rejected() {
        let mut env = SweSim::new();
        env.reset(6);
        let obs = env.step("edit calc.rs 999 nope");
        assert!(obs.text.contains("edit failed"));
    }

    #[test]
    fn time_limit_fails_episode() {
        let mut env = SweSim::new();
        env.reset(7);
        let mut obs = Observation::ongoing("");
        for _ in 0..env.max_turns() {
            obs = env.step("open calc.rs");
            if obs.done {
                break;
            }
        }
        assert!(obs.done);
        assert_eq!(obs.reward, 0.0);
    }
}
