//! FrozenLake: the paper's prefill-heavy game environment [9].
//!
//! A real grid-world implementation (not a stub): N×N board with start,
//! holes and a goal; optional slippery dynamics.  Observations render
//! the full board each turn, so context grows with every move — exactly
//! the many-turns / growing-history pattern that makes the domain
//! prefill-heavy (§2.1, Table 1: 20–100 turns).

use super::{Environment, Observation, TaskDomain};
use crate::simkit::SimRng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Cell {
    Frozen,
    Hole,
    Goal,
    Start,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    Left,
    Down,
    Right,
    Up,
}

impl Action {
    /// Parse an action from free-form model output: first direction
    /// keyword (or single-letter alias) wins; unparseable text is a
    /// no-op handled by the caller.
    pub fn parse(text: &str) -> Option<Action> {
        let lower = text.to_lowercase();
        for word in lower.split(|c: char| !c.is_alphanumeric()) {
            match word {
                "left" | "l" => return Some(Action::Left),
                "down" | "d" => return Some(Action::Down),
                "right" | "r" => return Some(Action::Right),
                "up" | "u" => return Some(Action::Up),
                _ => {}
            }
        }
        None
    }

    fn delta(self) -> (i32, i32) {
        match self {
            Action::Left => (0, -1),
            Action::Down => (1, 0),
            Action::Right => (0, 1),
            Action::Up => (-1, 0),
        }
    }
}

pub struct FrozenLake {
    n: usize,
    slippery: bool,
    grid: Vec<Cell>,
    pos: (i32, i32),
    turns: usize,
    done: bool,
    rng: SimRng,
}

impl FrozenLake {
    pub fn new(n: usize, slippery: bool) -> Self {
        assert!(n >= 3);
        FrozenLake {
            n,
            slippery,
            grid: vec![Cell::Frozen; n * n],
            pos: (0, 0),
            turns: 0,
            done: true,
            rng: SimRng::new(0),
        }
    }

    fn at(&self, r: i32, c: i32) -> Cell {
        self.grid[r as usize * self.n + c as usize]
    }

    /// Generate a solvable board: random holes, then verify a path
    /// exists with BFS; retry until solvable.
    fn gen_board(&mut self, seed: u64) {
        let n = self.n;
        let mut attempt = 0u64;
        loop {
            let mut rng = SimRng::new(seed.wrapping_add(attempt * 0x9e37));
            let mut grid = vec![Cell::Frozen; n * n];
            grid[0] = Cell::Start;
            grid[n * n - 1] = Cell::Goal;
            let holes = (n * n) / 5;
            let mut placed = 0;
            while placed < holes {
                let i = rng.below(n * n);
                if grid[i] == Cell::Frozen {
                    grid[i] = Cell::Hole;
                    placed += 1;
                }
            }
            if Self::solvable(&grid, n) {
                self.grid = grid;
                self.rng = rng;
                return;
            }
            attempt += 1;
        }
    }

    fn solvable(grid: &[Cell], n: usize) -> bool {
        let mut seen = vec![false; n * n];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(i) = stack.pop() {
            if grid[i] == Cell::Goal {
                return true;
            }
            let (r, c) = (i / n, i % n);
            let mut push = |r2: i32, c2: i32| {
                if r2 >= 0 && c2 >= 0 && (r2 as usize) < n && (c2 as usize) < n {
                    let j = r2 as usize * n + c2 as usize;
                    if !seen[j] && grid[j] != Cell::Hole {
                        seen[j] = true;
                        stack.push(j);
                    }
                }
            };
            push(r as i32 - 1, c as i32);
            push(r as i32 + 1, c as i32);
            push(r as i32, c as i32 - 1);
            push(r as i32, c as i32 + 1);
        }
        false
    }

    fn render(&self) -> String {
        let mut s = String::with_capacity(self.n * (self.n + 1));
        for r in 0..self.n {
            for c in 0..self.n {
                if (r as i32, c as i32) == self.pos {
                    s.push('A');
                } else {
                    s.push(match self.grid[r * self.n + c] {
                        Cell::Frozen => '.',
                        Cell::Hole => 'O',
                        Cell::Goal => 'G',
                        Cell::Start => 'S',
                    });
                }
            }
            s.push('\n');
        }
        s.push_str("move? (up/down/left/right)");
        s
    }
}

impl Environment for FrozenLake {
    fn domain(&self) -> TaskDomain {
        TaskDomain::Game
    }

    fn reset(&mut self, seed: u64) -> Observation {
        self.gen_board(seed);
        self.pos = (0, 0);
        self.turns = 0;
        self.done = false;
        Observation::ongoing(format!("frozen lake {0}x{0}\n{1}", self.n, self.render()))
    }

    fn step(&mut self, action: &str) -> Observation {
        assert!(!self.done, "step after episode end");
        self.turns += 1;
        let parsed = Action::parse(action);
        if let Some(mut act) = parsed {
            if self.slippery && self.rng.chance(1.0 / 3.0) {
                // Slip perpendicular, as in Gymnasium's dynamics.
                act = match (act, self.rng.chance(0.5)) {
                    (Action::Left | Action::Right, true) => Action::Up,
                    (Action::Left | Action::Right, false) => Action::Down,
                    (Action::Up | Action::Down, true) => Action::Left,
                    (Action::Up | Action::Down, false) => Action::Right,
                };
            }
            let (dr, dc) = act.delta();
            let r2 = (self.pos.0 + dr).clamp(0, self.n as i32 - 1);
            let c2 = (self.pos.1 + dc).clamp(0, self.n as i32 - 1);
            self.pos = (r2, c2);
        }
        match self.at(self.pos.0, self.pos.1) {
            Cell::Goal => {
                self.done = true;
                Observation::terminal("you reached the goal!", 1.0)
            }
            Cell::Hole => {
                self.done = true;
                Observation::terminal("you fell into a hole.", 0.0)
            }
            _ if self.turns >= self.max_turns() => {
                self.done = true;
                Observation::terminal("out of moves.", 0.0)
            }
            _ => Observation::ongoing(self.render()),
        }
    }

    fn max_turns(&self) -> usize {
        self.n * self.n * 4 // generous: up to 100 for 5x5
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_is_always_solvable() {
        for seed in 0..50 {
            let mut env = FrozenLake::new(4, false);
            env.reset(seed);
            assert!(FrozenLake::solvable(&env.grid, env.n), "seed {seed}");
        }
    }

    #[test]
    fn action_parsing() {
        assert_eq!(Action::parse("I should go right now"), Some(Action::Right));
        assert_eq!(Action::parse("UP!"), Some(Action::Up));
        assert_eq!(Action::parse("d"), Some(Action::Down));
        assert_eq!(Action::parse("nothing sensible"), None);
        // first keyword wins
        assert_eq!(Action::parse("left then right"), Some(Action::Left));
    }

    #[test]
    fn deterministic_solution_reaches_goal() {
        // On a solvable deterministic board, BFS-derived moves win.
        let mut env = FrozenLake::new(4, false);
        env.reset(3);
        // navigate greedily via BFS on the known grid
        let n = env.n;
        let grid = env.grid.clone();
        // BFS shortest path
        let mut prev = vec![usize::MAX; n * n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        let mut seen = vec![false; n * n];
        seen[0] = true;
        while let Some(i) = queue.pop_front() {
            let (r, c) = (i / n, i % n);
            for (dr, dc) in [(0i32, 1i32), (1, 0), (0, -1), (-1, 0)] {
                let (r2, c2) = (r as i32 + dr, c as i32 + dc);
                if r2 >= 0 && c2 >= 0 && (r2 as usize) < n && (c2 as usize) < n {
                    let j = r2 as usize * n + c2 as usize;
                    if !seen[j] && grid[j] != Cell::Hole {
                        seen[j] = true;
                        prev[j] = i;
                        queue.push_back(j);
                    }
                }
            }
        }
        let mut path = vec![n * n - 1];
        while *path.last().unwrap() != 0 {
            path.push(prev[*path.last().unwrap()]);
        }
        path.reverse();
        let mut obs = Observation::ongoing("");
        for w in path.windows(2) {
            let (a, b) = (w[0], w[1]);
            let act = if b == a + 1 {
                "right"
            } else if b + 1 == a {
                "left"
            } else if b == a + n {
                "down"
            } else {
                "up"
            };
            obs = env.step(act);
        }
        assert!(obs.done);
        assert_eq!(obs.reward, 1.0);
    }

    #[test]
    fn falling_into_hole_ends_episode() {
        let mut env = FrozenLake::new(4, false);
        env.reset(0);
        // walk until something terminal happens with garbage+right mix
        let mut obs = Observation::ongoing("");
        let mut i = 0;
        while !obs.done {
            obs = env.step(if i % 2 == 0 { "right" } else { "down" });
            i += 1;
        }
        assert!(obs.reward == 0.0 || obs.reward == 1.0);
    }

    #[test]
    fn unparseable_action_is_noop_but_consumes_turn() {
        let mut env = FrozenLake::new(4, false);
        let first = env.reset(3);
        let obs = env.step("hmm let me think");
        assert!(!obs.done);
        // agent did not move: rendering identical to reset board
        assert!(first.text.ends_with(&obs.text));
        assert_eq!(env.turns, 1);
    }

    #[test]
    fn observation_contains_agent_marker() {
        let mut env = FrozenLake::new(4, false);
        let obs = env.reset(9);
        assert!(obs.text.contains('A'));
        assert!(obs.text.contains('G'));
    }
}
