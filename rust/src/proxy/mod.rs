//! LLMProxy: trajectory-level generation dispatch (§6.1).
//!
//! The proxy sits between EnvManagers and inference workers.  It
//! dispatches *per-trajectory* requests (never batches of
//! environments), routes each request to the GPU class its task domain
//! prefers (R1), supports the weight-sync protocol's SUSPEND / RESUME
//! commands (§6.2 steps ②/④), ABORTs stale trajectories, and — in PD
//! mode (§6.3) — splits prefill and decode across engine pools.
//!
//! [`EngineSim`] models one inference worker's command-driven event
//! loop over the roofline cost model; the real harness substitutes the
//! PJRT-backed engine in [`crate::exec`] behind the same command set.

mod engine_sim;
pub mod pd;

pub use engine_sim::{EngineSim, EngineStats, SimRequest, StepOutcome};

use crate::env::TaskDomain;
use crate::hw::GpuClass;
use crate::rl::TrajectoryId;
use std::collections::BTreeMap;

/// Commands an inference worker's event loop processes between engine
/// steps (§6.1: ADD / ABORT; §6.2: SUSPEND / RESUME).
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Add(SimRequest),
    Abort(TrajectoryId),
    Suspend,
    Resume,
}

/// The proxy: engine registry + affinity routing + suspend state.
pub struct LlmProxy {
    engines: Vec<EngineSim>,
    affinity: BTreeMap<TaskDomain, GpuClass>,
    default_class: Option<GpuClass>,
    suspended: bool,
    /// Dispatch counters for fairness stats.
    dispatched: BTreeMap<TaskDomain, u64>,
}

impl LlmProxy {
    pub fn new(engines: Vec<EngineSim>) -> Self {
        LlmProxy {
            engines,
            affinity: BTreeMap::new(),
            default_class: None,
            suspended: false,
            dispatched: BTreeMap::new(),
        }
    }

    /// Declare `domain → class` routing (Listing 1's `hw_affinity`).
    pub fn set_affinity(&mut self, domain: TaskDomain, class: GpuClass) -> &mut Self {
        self.affinity.insert(domain, class);
        self
    }

    /// Class used for domains without an explicit declaration
    /// (Listing 1's `"default": "H20"`).
    pub fn set_default_class(&mut self, class: GpuClass) -> &mut Self {
        self.default_class = Some(class);
        self
    }

    pub fn engines(&self) -> &[EngineSim] {
        &self.engines
    }

    pub fn engines_mut(&mut self) -> &mut [EngineSim] {
        &mut self.engines
    }

    /// Register a freshly provisioned engine (elastic scale-up).
    /// Returns its index.  The engine inherits the proxy's suspend
    /// state so a scale-up landing mid-weight-sync cannot generate
    /// under stale weights.
    pub fn add_engine(&mut self, mut engine: EngineSim) -> usize {
        if self.suspended {
            engine.suspend();
        }
        self.engines.push(engine);
        self.engines.len() - 1
    }

    /// Live (not-down) engine count.
    pub fn live_engines(&self) -> usize {
        self.engines.iter().filter(|e| !e.is_down()).count()
    }

    pub fn is_suspended(&self) -> bool {
        self.suspended
    }

    fn preferred_class(&self, domain: TaskDomain) -> Option<GpuClass> {
        self.affinity.get(&domain).copied().or(self.default_class)
    }

    /// Route a request to the least-loaded engine of the preferred
    /// class, with two fallbacks (§5.3 "redirects execution to a
    /// compatible fallback... ensuring forward progress under transient
    /// contention"):
    /// * the class has no members → global least-loaded;
    /// * the class is *congested* (its best queue is much deeper than
    ///   the global best) → spill to the global least-loaded engine.
    pub fn route(&self, domain: TaskDomain) -> Option<usize> {
        // Dead engines (fault plane) never receive work; when the whole
        // fleet is down the caller re-queues (no engine returned).
        let live = |i: &usize| !self.engines[*i].is_down();
        let global = (0..self.engines.len())
            .filter(live)
            .min_by_key(|&i| self.engines[i].load())?;
        let Some(cls) = self.preferred_class(domain) else {
            return Some(global);
        };
        let preferred = (0..self.engines.len())
            .filter(live)
            .filter(|&i| self.engines[i].class == cls)
            .min_by_key(|&i| self.engines[i].load());
        // Spillover is asymmetric: decode-heavy work (preferring H20)
        // degrades gracefully on compute-optimized GPUs, but
        // prefill-heavy work must never spill onto bandwidth-optimized
        // GPUs (6.7x slower prefill, Table 2) — the resource manager
        // only offers *compatible* fallbacks (§5.3).
        let may_spill = cls == GpuClass::H20;
        match preferred {
            Some(p)
                if !may_spill
                    || self.engines[p].load() <= 2 * self.engines[global].load() + 4 =>
            {
                Some(p)
            }
            _ => Some(global),
        }
    }

    /// ADD: dispatch one trajectory-level generation request.
    /// Returns the engine it landed on, or None while suspended (the
    /// caller re-queues; the paper's suspend blocks new requests).
    pub fn add(&mut self, req: SimRequest) -> Option<usize> {
        if self.suspended {
            return None;
        }
        let idx = self.route(req.domain)?;
        *self.dispatched.entry(req.domain).or_insert(0) += 1;
        self.engines[idx].enqueue(req);
        Some(idx)
    }

    /// ABORT: cancel a trajectory on whichever engine holds it.
    pub fn abort(&mut self, traj: TrajectoryId) -> bool {
        self.engines.iter_mut().any(|e| e.abort(traj))
    }

    /// SUSPEND (protocol step ②): stop accepting and processing;
    /// in-flight state is preserved on the engines.
    pub fn suspend(&mut self) {
        self.suspended = true;
        for e in &mut self.engines {
            e.suspend();
        }
    }

    /// RESUME (protocol step ④): continue pending generation.
    pub fn resume(&mut self) {
        self.suspended = false;
        for e in &mut self.engines {
            e.resume();
        }
    }

    /// Total KV-recompute cost across engines (protocol step ⑤): after
    /// a weight update, in-flight trajectories rebuild their KV caches
    /// under the new weights.
    pub fn recompute_cost_s(&self) -> f64 {
        self.engines.iter().map(|e| e.recompute_cost_s()).sum()
    }

    pub fn dispatch_counts(&self) -> &BTreeMap<TaskDomain, u64> {
        &self.dispatched
    }

    /// In-flight request count across engines.
    pub fn inflight(&self) -> usize {
        self.engines.iter().map(|e| e.load()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn req(id: u64, domain: TaskDomain) -> SimRequest {
        SimRequest {
            traj: TrajectoryId(id),
            domain,
            new_tokens: 100.0,
            ctx_tokens: 0.0,
            decode_budget: 50.0,
        }
    }

    fn proxy() -> LlmProxy {
        let engines = vec![
            EngineSim::new(0, GpuClass::H800, 2, QWEN3_8B.clone(), 32),
            EngineSim::new(1, GpuClass::H20, 6, QWEN3_8B.clone(), 32),
            EngineSim::new(2, GpuClass::H20, 6, QWEN3_8B.clone(), 32),
        ];
        let mut p = LlmProxy::new(engines);
        p.set_affinity(TaskDomain::Game, GpuClass::H800)
            .set_default_class(GpuClass::H20);
        p
    }

    #[test]
    fn routes_declared_domain_to_declared_class() {
        let mut p = proxy();
        let idx = p.add(req(1, TaskDomain::Game)).unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H800);
    }

    #[test]
    fn default_class_for_undeclared_domains() {
        let mut p = proxy();
        let idx = p.add(req(2, TaskDomain::MathTool)).unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H20);
    }

    #[test]
    fn least_loaded_within_class() {
        let mut p = proxy();
        let a = p.add(req(1, TaskDomain::MathTool)).unwrap();
        let b = p.add(req(2, TaskDomain::MathTool)).unwrap();
        assert_ne!(a, b, "second request must go to the other H20 engine");
    }

    #[test]
    fn suspend_blocks_and_resume_unblocks() {
        let mut p = proxy();
        p.suspend();
        assert!(p.add(req(1, TaskDomain::Game)).is_none());
        p.resume();
        assert!(p.add(req(1, TaskDomain::Game)).is_some());
    }

    #[test]
    fn abort_reaches_the_right_engine() {
        let mut p = proxy();
        p.add(req(7, TaskDomain::Game)).unwrap();
        assert!(p.abort(TrajectoryId(7)));
        assert!(!p.abort(TrajectoryId(7)), "second abort finds nothing");
        assert_eq!(p.inflight(), 0);
    }

    #[test]
    fn missing_class_falls_back() {
        let engines = vec![EngineSim::new(0, GpuClass::H20, 1, QWEN3_8B.clone(), 8)];
        let mut p = LlmProxy::new(engines);
        p.set_affinity(TaskDomain::Game, GpuClass::H800);
        // No H800 engine exists; request still lands somewhere.
        assert!(p.add(req(1, TaskDomain::Game)).is_some());
    }

    #[test]
    fn routing_skips_down_engines() {
        let mut p = proxy();
        // Kill both H20 engines: default-class traffic must spill to
        // the H800 survivor instead of landing on a corpse.
        p.engines_mut()[1].set_down(true);
        p.engines_mut()[2].set_down(true);
        assert_eq!(p.live_engines(), 1);
        let idx = p.add(req(1, TaskDomain::MathTool)).unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H800);
        // Whole fleet down: no routing target at all.
        p.engines_mut()[0].set_down(true);
        assert!(p.route(TaskDomain::MathTool).is_none());
    }

    #[test]
    fn added_engine_inherits_suspend_state() {
        let mut p = proxy();
        p.suspend();
        let idx = p.add_engine(EngineSim::new(9, GpuClass::H20, 6, QWEN3_8B.clone(), 32));
        assert!(p.engines()[idx].is_suspended());
        p.resume();
        assert!(!p.engines()[idx].is_suspended());
        assert_eq!(p.engines().len(), 4);
    }

    #[test]
    fn dispatch_counts_accumulate() {
        let mut p = proxy();
        p.add(req(1, TaskDomain::Game));
        p.add(req(2, TaskDomain::Game));
        p.add(req(3, TaskDomain::Web));
        assert_eq!(p.dispatch_counts()[&TaskDomain::Game], 2);
        assert_eq!(p.dispatch_counts()[&TaskDomain::Web], 1);
    }
}
