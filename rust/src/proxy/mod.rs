//! LLMProxy: trajectory-level generation dispatch (§6.1).
//!
//! The proxy sits between EnvManagers and inference workers.  It
//! dispatches *per-trajectory* requests (never batches of
//! environments), routes each request through a pluggable
//! [`RoutePolicy`] (hardware affinity per R1 by default; see
//! [`route`]), supports the weight-sync protocol's SUSPEND / RESUME
//! commands (§6.2 steps ②/④), ABORTs stale trajectories, and — in PD
//! mode (§6.3) — pins prefill and decode dispatches to their pools via
//! [`LlmProxy::add_to_class`].
//!
//! [`EngineSim`] models one inference worker's command-driven event
//! loop over the roofline cost model; the real harness substitutes the
//! PJRT-backed engine in [`crate::exec`] behind the same command set.

mod engine_sim;
pub mod pd;
pub mod route;

pub use engine_sim::{
    EngineSim, EngineStats, SimRequest, StepOutcome, DECODE_STEP_FLOOR_S, PREFILL_STEP_FLOOR_S,
};
pub use route::{
    AffinityRoute, BestFitRoute, DomainFairRoute, LeastLoadedRoute, RouteCtx, RouteKind,
    RoutePolicy, TokenBacklogRoute,
};

use crate::env::TaskDomain;
use crate::hw::GpuClass;
use crate::rl::TrajectoryId;
use std::collections::BTreeMap;

/// Commands an inference worker's event loop processes between engine
/// steps (§6.1: ADD / ABORT; §6.2: SUSPEND / RESUME).
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Add(SimRequest),
    Abort(TrajectoryId),
    Suspend,
    Resume,
}

/// The proxy: engine registry + pluggable routing + suspend state.
pub struct LlmProxy {
    engines: Vec<EngineSim>,
    affinity: BTreeMap<TaskDomain, GpuClass>,
    default_class: Option<GpuClass>,
    suspended: bool,
    /// Dispatch counters for fairness stats.
    dispatched: BTreeMap<TaskDomain, u64>,
    /// The dispatch discipline (see [`route`]).
    policy: Box<dyn RoutePolicy>,
    /// Maintained live (not-down) count — [`LlmProxy::live_engines`] is
    /// read on every dispatch and must not scan the fleet.  Kept
    /// coherent by routing all up/down flips through
    /// [`LlmProxy::set_down`].
    live: usize,
    /// Engine indices per GPU class.  Engines are never removed from
    /// the fleet (only marked down/retired), but a *repurpose*
    /// ([`LlmProxy::reclass_engine`]) moves an index between class
    /// lists.  The PD class-pinned dispatch iterates one pool's
    /// members instead of the whole fleet.
    class_members: BTreeMap<GpuClass, Vec<usize>>,
}

impl LlmProxy {
    pub fn new(engines: Vec<EngineSim>) -> Self {
        let live = engines.iter().filter(|e| !e.is_down()).count();
        let mut class_members: BTreeMap<GpuClass, Vec<usize>> = BTreeMap::new();
        for (i, e) in engines.iter().enumerate() {
            class_members.entry(e.class).or_default().push(i);
        }
        LlmProxy {
            engines,
            affinity: BTreeMap::new(),
            default_class: None,
            suspended: false,
            dispatched: BTreeMap::new(),
            policy: RouteKind::Affinity.make(),
            live,
            class_members,
        }
    }

    /// Swap the dispatch discipline (default: [`AffinityRoute`]).
    pub fn set_route_policy(&mut self, policy: Box<dyn RoutePolicy>) -> &mut Self {
        self.policy = policy;
        self
    }

    pub fn route_policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Declare `domain → class` routing (Listing 1's `hw_affinity`).
    pub fn set_affinity(&mut self, domain: TaskDomain, class: GpuClass) -> &mut Self {
        self.affinity.insert(domain, class);
        self
    }

    /// Class used for domains without an explicit declaration
    /// (Listing 1's `"default": "H20"`).
    pub fn set_default_class(&mut self, class: GpuClass) -> &mut Self {
        self.default_class = Some(class);
        self
    }

    pub fn engines(&self) -> &[EngineSim] {
        &self.engines
    }

    pub fn engines_mut(&mut self) -> &mut [EngineSim] {
        &mut self.engines
    }

    /// Register a freshly provisioned engine (elastic scale-up).
    /// Returns its index.  The engine inherits the proxy's suspend
    /// state so a scale-up landing mid-weight-sync cannot generate
    /// under stale weights.
    pub fn add_engine(&mut self, mut engine: EngineSim) -> usize {
        if self.suspended {
            engine.suspend();
        }
        let idx = self.engines.len();
        self.class_members.entry(engine.class).or_default().push(idx);
        if !engine.is_down() {
            self.live += 1;
        }
        self.engines.push(engine);
        idx
    }

    /// Re-home engine `idx` onto a new GPU class (elastic repurpose):
    /// the engine keeps its fleet index but moves between the
    /// [`LlmProxy::add_to_class`] member lists, and its step times come
    /// from the new class's roofline ([`EngineSim::repurpose`]).  The
    /// caller is expected to have taken the engine down and drained it
    /// first — a repurpose pays the same warm-up pull as a fresh
    /// provision before the engine re-joins the live fleet.
    pub fn reclass_engine(&mut self, idx: usize, class: GpuClass, gpus: usize, max_batch: usize) {
        let old = self.engines[idx].class;
        if old != class {
            let members = self.class_members.get_mut(&old).expect("class list exists");
            let pos = members
                .iter()
                .position(|&i| i == idx)
                .expect("engine listed under its own class");
            members.remove(pos);
            self.class_members.entry(class).or_default().push(idx);
        }
        self.engines[idx].repurpose(class, gpus, max_batch);
        debug_assert!(
            self.class_members_coherent(),
            "class member lists drifted after reclass of engine {idx}"
        );
    }

    /// Full-coherence rescan of the class member lists: every engine
    /// appears exactly once, under exactly its own class.  Debug-assert
    /// material on the mutation paths; public so the invariants suite
    /// can promote it to an explicit property.
    pub fn class_members_coherent(&self) -> bool {
        let mut seen = vec![0usize; self.engines.len()];
        for (&class, members) in &self.class_members {
            for &i in members {
                if i >= self.engines.len() || self.engines[i].class != class {
                    return false;
                }
                seen[i] += 1;
            }
        }
        seen.iter().all(|&n| n == 1)
    }

    /// Live (not-down) engine count (maintained, not scanned).
    pub fn live_engines(&self) -> usize {
        debug_assert_eq!(
            self.live,
            self.engines.iter().filter(|e| !e.is_down()).count(),
            "live-engine count drifted: an up/down flip bypassed LlmProxy::set_down"
        );
        self.live
    }

    /// Flip engine `idx` up/down, keeping the live count coherent.
    /// All fault/elastic up-down transitions must come through here —
    /// flipping `EngineSim::set_down` directly through `engines_mut`
    /// would leave [`LlmProxy::live_engines`] stale.
    pub fn set_down(&mut self, idx: usize, down: bool) {
        let was_down = self.engines[idx].is_down();
        self.engines[idx].set_down(down);
        match (was_down, down) {
            (false, true) => self.live -= 1,
            (true, false) => self.live += 1,
            _ => {}
        }
    }

    pub fn is_suspended(&self) -> bool {
        self.suspended
    }

    /// Route a request through the active [`RoutePolicy`]: dead engines
    /// (fault plane) never receive work; when the whole fleet is down
    /// the caller re-queues (no engine returned).
    pub fn route(&mut self, domain: TaskDomain) -> Option<usize> {
        let ctx = RouteCtx {
            affinity: &self.affinity,
            default_class: self.default_class,
        };
        self.policy.pick(&self.engines, domain, &ctx)
    }

    /// ADD: dispatch one trajectory-level generation request.
    /// Returns the engine it landed on, or None while suspended (the
    /// caller re-queues; the paper's suspend blocks new requests).
    pub fn add(&mut self, req: SimRequest) -> Option<usize> {
        if self.suspended {
            return None;
        }
        let idx = self.route(req.domain)?;
        *self.dispatched.entry(req.domain).or_insert(0) += 1;
        self.engines[idx].enqueue(req);
        Some(idx)
    }

    /// ADD pinned to one GPU class, with *no* fallback: the least-loaded
    /// live engine of exactly `class`.  This is the PD-disaggregation
    /// dispatch path (§6.3): a prefill request must never land in the
    /// decode pool and vice versa — the phases run on different
    /// hardware with the KV cache shipped between them, so spilling
    /// would silently skip the transfer the mode exists to model.
    /// Returns `None` while suspended or when the class has no live
    /// engine (the caller holds the request).
    pub fn add_to_class(&mut self, req: SimRequest, class: GpuClass) -> Option<usize> {
        if self.suspended {
            return None;
        }
        // Per-engine suspend (weight plane): a pool member mid-swap is
        // skipped like a down one — the caller holds when the whole
        // pool is refreshing.  Only the class's own members are
        // scanned (maintained index list, not the whole fleet).
        let members = self.class_members.get(&class).map(Vec::as_slice).unwrap_or(&[]);
        let idx = members
            .iter()
            .copied()
            .filter(|&i| !self.engines[i].is_down() && !self.engines[i].is_suspended())
            .min_by_key(|&i| self.engines[i].load())?;
        *self.dispatched.entry(req.domain).or_insert(0) += 1;
        self.engines[idx].enqueue(req);
        Some(idx)
    }

    /// ABORT: cancel a trajectory on whichever engine holds it.
    pub fn abort(&mut self, traj: TrajectoryId) -> bool {
        self.engines.iter_mut().any(|e| e.abort(traj))
    }

    /// SUSPEND (protocol step ②): stop accepting and processing;
    /// in-flight state is preserved on the engines.
    pub fn suspend(&mut self) {
        self.suspended = true;
        for e in &mut self.engines {
            e.suspend();
        }
    }

    /// RESUME (protocol step ④): continue pending generation.
    pub fn resume(&mut self) {
        self.suspended = false;
        for e in &mut self.engines {
            e.resume();
        }
    }

    /// Total KV-recompute cost across engines (protocol step ⑤): after
    /// a weight update, in-flight trajectories rebuild their KV caches
    /// under the new weights.
    pub fn recompute_cost_s(&self) -> f64 {
        self.engines.iter().map(|e| e.recompute_cost_s()).sum()
    }

    pub fn dispatch_counts(&self) -> &BTreeMap<TaskDomain, u64> {
        &self.dispatched
    }

    /// In-flight request count across engines.
    pub fn inflight(&self) -> usize {
        self.engines.iter().map(|e| e.load()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn req(id: u64, domain: TaskDomain) -> SimRequest {
        SimRequest {
            traj: TrajectoryId(id),
            domain,
            new_tokens: 100.0,
            ctx_tokens: 0.0,
            decode_budget: 50.0,
        }
    }

    fn proxy() -> LlmProxy {
        let engines = vec![
            EngineSim::new(0, GpuClass::H800, 2, QWEN3_8B.clone(), 32),
            EngineSim::new(1, GpuClass::H20, 6, QWEN3_8B.clone(), 32),
            EngineSim::new(2, GpuClass::H20, 6, QWEN3_8B.clone(), 32),
        ];
        let mut p = LlmProxy::new(engines);
        p.set_affinity(TaskDomain::Game, GpuClass::H800)
            .set_default_class(GpuClass::H20);
        p
    }

    #[test]
    fn routes_declared_domain_to_declared_class() {
        let mut p = proxy();
        let idx = p.add(req(1, TaskDomain::Game)).unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H800);
    }

    #[test]
    fn default_class_for_undeclared_domains() {
        let mut p = proxy();
        let idx = p.add(req(2, TaskDomain::MathTool)).unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H20);
    }

    #[test]
    fn least_loaded_within_class() {
        let mut p = proxy();
        let a = p.add(req(1, TaskDomain::MathTool)).unwrap();
        let b = p.add(req(2, TaskDomain::MathTool)).unwrap();
        assert_ne!(a, b, "second request must go to the other H20 engine");
    }

    #[test]
    fn suspend_blocks_and_resume_unblocks() {
        let mut p = proxy();
        p.suspend();
        assert!(p.add(req(1, TaskDomain::Game)).is_none());
        p.resume();
        assert!(p.add(req(1, TaskDomain::Game)).is_some());
    }

    #[test]
    fn abort_reaches_the_right_engine() {
        let mut p = proxy();
        p.add(req(7, TaskDomain::Game)).unwrap();
        assert!(p.abort(TrajectoryId(7)));
        assert!(!p.abort(TrajectoryId(7)), "second abort finds nothing");
        assert_eq!(p.inflight(), 0);
    }

    #[test]
    fn missing_class_falls_back() {
        let engines = vec![EngineSim::new(0, GpuClass::H20, 1, QWEN3_8B.clone(), 8)];
        let mut p = LlmProxy::new(engines);
        p.set_affinity(TaskDomain::Game, GpuClass::H800);
        // No H800 engine exists; request still lands somewhere.
        assert!(p.add(req(1, TaskDomain::Game)).is_some());
    }

    #[test]
    fn routing_skips_down_engines() {
        let mut p = proxy();
        // Kill both H20 engines: default-class traffic must spill to
        // the H800 survivor instead of landing on a corpse.
        p.set_down(1, true);
        p.set_down(2, true);
        assert_eq!(p.live_engines(), 1);
        let idx = p.add(req(1, TaskDomain::MathTool)).unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H800);
        // Whole fleet down: no routing target at all.
        p.set_down(0, true);
        assert!(p.route(TaskDomain::MathTool).is_none());
    }

    #[test]
    fn added_engine_inherits_suspend_state() {
        let mut p = proxy();
        p.suspend();
        let idx = p.add_engine(EngineSim::new(9, GpuClass::H20, 6, QWEN3_8B.clone(), 32));
        assert!(p.engines()[idx].is_suspended());
        p.resume();
        assert!(!p.engines()[idx].is_suspended());
        assert_eq!(p.engines().len(), 4);
    }

    #[test]
    fn dispatch_counts_accumulate() {
        let mut p = proxy();
        p.add(req(1, TaskDomain::Game));
        p.add(req(2, TaskDomain::Game));
        p.add(req(3, TaskDomain::Web));
        assert_eq!(p.dispatch_counts()[&TaskDomain::Game], 2);
        assert_eq!(p.dispatch_counts()[&TaskDomain::Web], 1);
    }

    #[test]
    fn preferred_class_entirely_down_falls_back() {
        // Not merely *missing*: the declared class exists but every
        // member is dead.  Work must spill to a live survivor.
        let mut p = proxy();
        p.set_down(0, true); // the only H800
        let idx = p.add(req(1, TaskDomain::Game)).unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H20);
    }

    #[test]
    fn dispatch_while_suspended_holds_for_every_policy() {
        for kind in [
            RouteKind::Affinity,
            RouteKind::LeastLoaded,
            RouteKind::DomainFair,
            RouteKind::TokenBacklog,
            RouteKind::BestFit,
            RouteKind::Inverted,
        ] {
            let mut p = proxy();
            p.set_route_policy(kind.make());
            p.suspend();
            assert!(p.add(req(1, TaskDomain::Game)).is_none(), "{kind:?}");
            assert!(
                p.add_to_class(req(1, TaskDomain::Game), GpuClass::H800)
                    .is_none(),
                "{kind:?}: class-pinned dispatch must respect suspend too"
            );
            p.resume();
            assert!(p.add(req(1, TaskDomain::Game)).is_some(), "{kind:?}");
        }
    }

    #[test]
    fn abort_of_already_completed_trajectory_is_a_noop() {
        let mut p = proxy();
        let e = p.add(req(5, TaskDomain::Game)).unwrap();
        // Run the request to completion on its engine.
        let (_, done) = p.engines_mut()[e].run_to_idle();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, TrajectoryId(5));
        // The trajectory no longer exists anywhere: ABORT must find
        // nothing, touch nothing, and report false.
        let aborted_before = p.engines()[e].stats.aborted;
        assert!(!p.abort(TrajectoryId(5)));
        assert_eq!(p.engines()[e].stats.aborted, aborted_before);
        assert_eq!(p.inflight(), 0);
    }

    #[test]
    fn add_to_class_pins_and_never_spills() {
        let mut p = proxy();
        let idx = p
            .add_to_class(req(1, TaskDomain::MathTool), GpuClass::H800)
            .unwrap();
        assert_eq!(p.engines()[idx].class, GpuClass::H800);
        // Class fully down → no fallback, the caller must hold.
        p.set_down(idx, true);
        assert!(p
            .add_to_class(req(2, TaskDomain::MathTool), GpuClass::H800)
            .is_none());
        // The other class still works.
        let d = p
            .add_to_class(req(3, TaskDomain::MathTool), GpuClass::H20)
            .unwrap();
        assert_eq!(p.engines()[d].class, GpuClass::H20);
    }

    #[test]
    fn add_to_class_picks_least_loaded_member() {
        let mut p = proxy();
        let a = p
            .add_to_class(req(1, TaskDomain::Web), GpuClass::H20)
            .unwrap();
        let b = p
            .add_to_class(req(2, TaskDomain::Web), GpuClass::H20)
            .unwrap();
        assert_ne!(a, b, "second pinned request must go to the other H20");
    }

    #[test]
    fn live_count_tracks_flips_and_scaleups() {
        let mut p = proxy();
        assert_eq!(p.live_engines(), 3);
        p.set_down(1, true);
        p.set_down(1, true); // idempotent: no double-decrement
        assert_eq!(p.live_engines(), 2);
        p.set_down(1, false);
        assert_eq!(p.live_engines(), 3);
        // A scale-up joins live; its class list routes to it.
        let idx = p.add_engine(EngineSim::new(9, GpuClass::H800, 2, QWEN3_8B.clone(), 32));
        assert_eq!(p.live_engines(), 4);
        p.set_down(0, true); // the original H800
        let e = p
            .add_to_class(req(1, TaskDomain::Game), GpuClass::H800)
            .unwrap();
        assert_eq!(e, idx, "pinned dispatch must find the new class member");
    }

    #[test]
    fn reclass_engine_moves_between_class_lists() {
        let mut p = proxy();
        assert!(p.class_members_coherent());
        // Repurpose the H800 engine into the H20 pool (6-GPU layout).
        p.reclass_engine(0, GpuClass::H20, 6, 32);
        assert!(p.class_members_coherent());
        assert_eq!(p.engines()[0].class, GpuClass::H20);
        // Class-pinned dispatch finds it under its new class only.
        assert!(p
            .add_to_class(req(1, TaskDomain::Game), GpuClass::H800)
            .is_none());
        // … and the H20 pool now has three members: load them all.
        let mut hits = std::collections::BTreeSet::new();
        for i in 0..3 {
            hits.insert(
                p.add_to_class(req(10 + i, TaskDomain::Game), GpuClass::H20)
                    .unwrap(),
            );
        }
        assert!(hits.contains(&0), "repurposed engine takes H20 work");
        // Same-class reclass (gpus/max_batch resize) is a no-op on the
        // lists but still coherent.
        p.reclass_engine(2, GpuClass::H20, 8, 64);
        assert!(p.class_members_coherent());
    }

    #[test]
    fn swapped_route_policy_changes_dispatch() {
        // Under AffinityRoute, Game is pinned to the single H800 engine;
        // under LeastLoadedRoute the same request stream spreads over
        // the whole fleet.
        let mut p = proxy();
        p.set_route_policy(RouteKind::LeastLoaded.make());
        assert_eq!(p.route_policy_name(), "least_loaded");
        let mut classes = std::collections::BTreeSet::new();
        for i in 0..3 {
            let idx = p.add(req(i, TaskDomain::Game)).unwrap();
            classes.insert(p.engines()[idx].class);
        }
        assert!(
            classes.contains(&GpuClass::H20),
            "least-loaded must use the H20 engines affinity would shun"
        );
    }
}
