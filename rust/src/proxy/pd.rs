//! Prefill-decode disaggregation (§6.3, Table 5).
//!
//! Extends affinity routing from task level to *phase* level: prefill
//! runs on compute-optimized nodes, decode on bandwidth-optimized
//! nodes, with the KV cache shipped between them after prefill.  The
//! configuration is expressed as `xPyD` (x prefill nodes, y decode
//! nodes, 8 GPUs each in the paper's setup).

use crate::hw::{phase_time, GpuClass};
use crate::llm::LlmSpec;
use crate::net::Link;

/// Slowdown from interleaving prefill and decode on one engine.
///
/// Dense models pay ~8% (working-set eviction + scheduler alternation,
/// consistent with DistServe/Splitwise [37, 66]).  MoE models pay much
/// more: interleaved phases thrash the expert working set and the
/// expert all-to-all contends with prefill GEMMs (the MegaScale-Infer
/// [69] observation) — this is why the paper's Table 5 shows larger PD
/// gains for Qwen3-30B-A3B (1.11–1.21×) than for the dense 32B
/// (1.03–1.05×).
pub fn colocation_interference(model: &LlmSpec) -> f64 {
    if model.moe.is_some() {
        1.22
    } else {
        1.08
    }
}

/// One PD deployment: prefill pool + decode pool + interconnect.
#[derive(Clone, Debug)]
pub struct PdConfig {
    pub prefill_nodes: usize,
    pub decode_nodes: usize,
    pub gpus_per_node: usize,
    /// Link carrying KV from prefill to decode nodes.
    pub kv_link: Link,
}

impl PdConfig {
    pub fn new(prefill_nodes: usize, decode_nodes: usize, kv_link: Link) -> Self {
        PdConfig {
            prefill_nodes,
            decode_nodes,
            gpus_per_node: 8,
            kv_link,
        }
    }

    pub fn name(&self) -> String {
        format!("{}P{}D", self.prefill_nodes, self.decode_nodes)
    }

    /// Rollout time for a batch of identical requests under PD
    /// disaggregation: prefill pipeline + KV transfer + decode, with
    /// the phases overlapping across the batch (prefill of request
    /// i+1 overlaps decode of request i — the steady-state pipeline).
    ///
    /// `batch` requests, `prompt` prefill tokens each, `decode` tokens
    /// each.
    pub fn rollout_time(
        &self,
        model: &LlmSpec,
        batch: f64,
        prompt: f64,
        decode: f64,
    ) -> f64 {
        let p_gpus = self.prefill_nodes * self.gpus_per_node;
        let d_gpus = self.decode_nodes * self.gpus_per_node;
        assert!(p_gpus > 0 && d_gpus > 0);

        // Stage times over the whole batch.
        let prefill_cost = model.prefill_cost(batch * prompt, 0.0);
        let t_prefill = phase_time(&prefill_cost, GpuClass::H800.spec(), p_gpus);

        // KV shipped once per request.
        let kv_bytes = batch * prompt * model.kv_bytes_per_token();
        let t_kv = self.kv_link.transfer_time(kv_bytes);

        // Decode runs in max_batch-sized waves on the decode pool.
        let mean_ctx = prompt + decode / 2.0;
        let decode_cost = model.decode_cost(batch, mean_ctx).scale(decode);
        let t_decode = phase_time(&decode_cost, GpuClass::H20.spec(), d_gpus);

        // Pipeline: total ≈ max stage + (sum of the others amortized);
        // with many requests the bottleneck stage dominates and the
        // other stages overlap it.
        let stages = [t_prefill, t_kv, t_decode];
        let bottleneck = stages.iter().cloned().fold(0.0, f64::max);
        let sum: f64 = stages.iter().sum();
        // one pipeline fill + steady state at the bottleneck rate
        bottleneck + (sum - bottleneck) / batch.max(1.0) * 2.0
    }

    /// Colocated rollout on the same total GPU count (all phases share
    /// every GPU; prefill and decode interleave, so the engine
    /// alternates between compute-bound and bandwidth-bound phases on
    /// whichever hardware mix it was given — here H800-class as the
    /// paper's colocation baseline uses the training-grade nodes).
    pub fn colocated_time(model: &LlmSpec, total_gpus: usize, batch: f64, prompt: f64, decode: f64) -> f64 {
        let prefill_cost = model.prefill_cost(batch * prompt, 0.0);
        let mean_ctx = prompt + decode / 2.0;
        let decode_cost = model.decode_cost(batch, mean_ctx).scale(decode);
        (phase_time(&prefill_cost, GpuClass::H800.spec(), total_gpus)
            + phase_time(&decode_cost, GpuClass::H800.spec(), total_gpus))
            * colocation_interference(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::{QWEN3_30B_A3B, QWEN3_32B};
    use crate::net::NVLINK_INTRA;

    // Table 5 workload: SWE task, batch 128, 32k sequence.
    const BATCH: f64 = 128.0;
    const PROMPT: f64 = 12_000.0;
    const DECODE: f64 = 20_000.0;

    fn t5(model: &LlmSpec, p: usize, d: usize) -> (f64, f64) {
        let cfg = PdConfig::new(p, d, NVLINK_INTRA.clone());
        let pd = cfg.rollout_time(model, BATCH, PROMPT, DECODE);
        let colo =
            PdConfig::colocated_time(model, (p + d) * 8, BATCH, PROMPT, DECODE);
        (pd, colo)
    }

    #[test]
    fn dense_model_gets_modest_speedup() {
        // Paper Table 5 (Qwen3-32B): 1P3D 1.03x, 2P2D 1.05x.
        let (pd, colo) = t5(&QWEN3_32B, 2, 2);
        let speedup = colo / pd;
        assert!(speedup > 1.0, "2P2D dense speedup {speedup}");
        assert!(speedup < 1.4, "2P2D dense speedup {speedup}");
    }

    #[test]
    fn moe_model_gets_larger_speedup() {
        // Paper: MoE 1P3D 1.11x, 2P2D 1.21x — PD pays off more because
        // the active-parameter decode is cheap on bandwidth-optimized
        // nodes while prefill still needs compute.
        let (pd_moe, colo_moe) = t5(&QWEN3_30B_A3B, 2, 2);
        let (pd_dense, colo_dense) = t5(&QWEN3_32B, 2, 2);
        let s_moe = colo_moe / pd_moe;
        let s_dense = colo_dense / pd_dense;
        assert!(s_moe > s_dense, "moe {s_moe} vs dense {s_dense}");
    }

    #[test]
    fn p3d1_bottlenecked_by_single_decode_node() {
        // Paper footnote 2: 3P1D performed worst — one decode node
        // bottlenecks. Our model must reproduce the ordering.
        let t_1p3d = t5(&QWEN3_30B_A3B, 1, 3).0;
        let t_2p2d = t5(&QWEN3_30B_A3B, 2, 2).0;
        let t_3p1d = t5(&QWEN3_30B_A3B, 3, 1).0;
        assert!(t_3p1d > t_1p3d, "{t_3p1d} vs {t_1p3d}");
        assert!(t_3p1d > t_2p2d, "{t_3p1d} vs {t_2p2d}");
    }

    #[test]
    fn kv_transfer_counts() {
        let cheap = PdConfig::new(1, 3, NVLINK_INTRA.clone());
        let mut slow_link = NVLINK_INTRA.clone();
        slow_link.effective_bytes_per_s = 1e9; // badly undersized link
        let slow = PdConfig::new(1, 3, slow_link);
        let t_fast = cheap.rollout_time(&QWEN3_32B, BATCH, PROMPT, DECODE);
        let t_slow = slow.rollout_time(&QWEN3_32B, BATCH, PROMPT, DECODE);
        assert!(t_slow > t_fast, "{t_slow} vs {t_fast}");
    }

    #[test]
    fn names() {
        assert_eq!(PdConfig::new(1, 3, NVLINK_INTRA.clone()).name(), "1P3D");
        assert_eq!(PdConfig::new(2, 2, NVLINK_INTRA.clone()).name(), "2P2D");
    }
}
