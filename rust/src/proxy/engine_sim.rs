//! Simulated inference engine: the command-driven event loop of §6.1
//! over the roofline cost model.
//!
//! Mirrors a vLLM-style continuous-batching worker: a prefill queue and
//! an active decode batch; each `step()` either admits waiting prefills
//! or advances decoding for the whole batch, returning the simulated
//! elapsed time.  Commands (ADD/ABORT) are processed *between* steps,
//! so adding or aborting a trajectory never stalls ongoing generation —
//! exactly the paper's non-blocking loop.

use crate::env::TaskDomain;
use crate::hw::{phase_time, GpuClass};
use crate::llm::LlmSpec;
use crate::rl::TrajectoryId;
use std::collections::VecDeque;

/// One trajectory-level generation request (one turn's generation).
#[derive(Clone, Debug, PartialEq)]
pub struct SimRequest {
    pub traj: TrajectoryId,
    pub domain: TaskDomain,
    /// New tokens to prefill (observation under prefix caching).
    pub new_tokens: f64,
    /// Cached context length at arrival.
    pub ctx_tokens: f64,
    /// Tokens to decode before the turn's action is complete.
    pub decode_budget: f64,
}

#[derive(Clone, Debug)]
struct Active {
    req: SimRequest,
    decoded: f64,
    /// Current context (grows by 1 per decoded token).
    ctx: f64,
}

/// What one engine step did.
#[derive(Clone, Debug, PartialEq)]
pub enum StepOutcome {
    /// Nothing to do (empty engine or suspended).
    Idle,
    /// The engine ran for `elapsed` seconds; `completed` lists
    /// trajectories whose decode budget finished this step, with their
    /// final context length.
    Busy {
        elapsed: f64,
        completed: Vec<(TrajectoryId, f64)>,
        /// True when this step was a prefill (admission) step.
        was_prefill: bool,
    },
}

/// Aggregate engine statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub prefill_tokens: f64,
    pub decode_tokens: f64,
    pub busy_s: f64,
    pub completed: u64,
    pub aborted: u64,
}

/// A simulated inference worker.
#[derive(Clone, Debug)]
pub struct EngineSim {
    pub id: u64,
    pub class: GpuClass,
    pub gpus: usize,
    model: LlmSpec,
    max_batch: usize,
    waiting: VecDeque<SimRequest>,
    active: Vec<Active>,
    suspended: bool,
    /// Engine is dead (crashed node / retired by the elastic
    /// controller): no routing, no stepping, until revived.
    down: bool,
    /// Max decode tokens advanced per step when no commands are
    /// pending (event-count optimization; 1 = fully step-accurate).
    decode_chunk: f64,
    /// Phase-interleaving slowdown multiplier (1.0 = none).  The PD
    /// colocation baseline sets this to
    /// [`crate::proxy::pd::colocation_interference`]: an engine that
    /// alternates prefill and decode on the same GPUs thrashes the
    /// working set and (for MoE) contends on the expert all-to-all
    /// (DistServe / MegaScale-Infer; Table 5's mechanism).
    interference: f64,
    pub stats: EngineStats,
}

/// Per-decode-step engine overhead: scheduler tick + kernel launches +
/// sampling, with CUDA graphs enabled (the paper's vLLM config).  Real
/// decode steps cannot go below this regardless of roofline.
pub const DECODE_STEP_FLOOR_S: f64 = 0.004;
/// Per-admission (prefill) scheduling overhead.
pub const PREFILL_STEP_FLOOR_S: f64 = 0.02;

impl EngineSim {
    pub fn new(id: u64, class: GpuClass, gpus: usize, model: LlmSpec, max_batch: usize) -> Self {
        assert!(gpus > 0 && max_batch > 0);
        EngineSim {
            id,
            class,
            gpus,
            model,
            max_batch,
            waiting: VecDeque::new(),
            active: Vec::new(),
            suspended: false,
            down: false,
            decode_chunk: 16.0,
            interference: 1.0,
            stats: EngineStats::default(),
        }
    }

    /// Re-home the engine onto a different GPU class (the elastic
    /// repurpose path): the worker keeps its id, queues, and stats but
    /// all subsequent step times come from the new class's roofline.
    /// Callers are expected to have drained in-flight work first (a
    /// repurpose rides the same take-down/warm-up machinery as a
    /// retire) — the coordinator pays the weight re-pull, not this
    /// struct.
    pub fn repurpose(&mut self, class: GpuClass, gpus: usize, max_batch: usize) {
        assert!(gpus > 0 && max_batch > 0);
        self.class = class;
        self.gpus = gpus;
        self.max_batch = max_batch;
    }

    /// Analytic time of one prefill (admission) step over `new_tokens`
    /// fresh tokens at `ctx_sum` total cached context, on this engine's
    /// class/GPU count: exactly what [`EngineSim::step`] charges,
    /// including the scheduling floor and interference multiplier.
    /// Public so the conformance suite and best-fit routing score
    /// engines with the *same* expression the DES executes.
    pub fn prefill_step_s(&self, new_tokens: f64, ctx_sum: f64) -> f64 {
        let cost = self.model.prefill_cost(new_tokens, ctx_sum);
        phase_time(&cost, self.class.spec(), self.gpus).max(PREFILL_STEP_FLOOR_S)
            * self.interference
    }

    /// Analytic time of one decode step advancing a batch of `batch`
    /// requests at `mean_ctx` average context by `chunk` tokens each —
    /// the exact expression [`EngineSim::step`]'s decode branch charges
    /// (roofline, per-step floor, interference).
    pub fn decode_step_s(&self, batch: f64, mean_ctx: f64, chunk: f64) -> f64 {
        let cost = self.model.decode_cost(batch, mean_ctx).scale(chunk);
        phase_time(&cost, self.class.spec(), self.gpus).max(chunk * DECODE_STEP_FLOOR_S)
            * self.interference
    }

    /// Set decode chunking (events-per-token trade-off; see §Perf).
    pub fn set_decode_chunk(&mut self, chunk: f64) -> &mut Self {
        assert!(chunk >= 1.0);
        self.decode_chunk = chunk;
        self
    }

    /// Set the phase-interleaving slowdown (PD colocation baseline).
    pub fn set_interference(&mut self, factor: f64) -> &mut Self {
        assert!(factor >= 1.0);
        self.interference = factor;
        self
    }

    pub fn load(&self) -> usize {
        self.waiting.len() + self.active.len()
    }

    /// Outstanding *token* work: prefill tokens not yet admitted plus
    /// decode tokens not yet produced, across waiting and active
    /// requests.  This is what
    /// [`TokenBacklogRoute`](crate::proxy::route::TokenBacklogRoute)
    /// balances on — two engines with equal request counts can differ
    /// by orders of magnitude in token backlog when decode budgets are
    /// long.
    pub fn backlog_tokens(&self) -> f64 {
        let waiting: f64 = self
            .waiting
            .iter()
            .map(|r| r.new_tokens + r.decode_budget)
            .sum();
        let active: f64 = self
            .active
            .iter()
            .map(|a| (a.req.decode_budget - a.decoded).max(0.0))
            .sum();
        waiting + active
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn enqueue(&mut self, req: SimRequest) {
        self.waiting.push_back(req);
    }

    pub fn abort(&mut self, traj: TrajectoryId) -> bool {
        if let Some(i) = self.waiting.iter().position(|r| r.traj == traj) {
            self.waiting.remove(i);
            self.stats.aborted += 1;
            return true;
        }
        if let Some(i) = self.active.iter().position(|a| a.req.traj == traj) {
            self.active.remove(i);
            self.stats.aborted += 1;
            return true;
        }
        false
    }

    pub fn suspend(&mut self) {
        self.suspended = true;
    }

    pub fn resume(&mut self) {
        self.suspended = false;
    }

    pub fn is_suspended(&self) -> bool {
        self.suspended
    }

    /// Mark the engine dead (crash) or alive again (recovery).  State
    /// is *not* cleared here — the coordinator drains it first via
    /// [`EngineSim::drain_requests`] so in-flight work is re-queued,
    /// not lost.
    pub fn set_down(&mut self, down: bool) {
        self.down = down;
    }

    pub fn is_down(&self) -> bool {
        self.down
    }

    /// Take every pending request off the engine (waiting queue +
    /// active batch) for trajectory-level recovery after a crash.
    /// Active requests are returned in their original form: partially
    /// decoded work is lost and replayed on whichever engine the
    /// request lands on next — exactly the recovery cost the fault
    /// plane measures.
    pub fn drain_requests(&mut self) -> Vec<SimRequest> {
        let mut out: Vec<SimRequest> = self.waiting.drain(..).collect();
        out.extend(self.active.drain(..).map(|a| a.req));
        out
    }

    /// KV-recompute cost for in-flight trajectories after a weight
    /// update (protocol step ⑤): re-prefill every active context.
    pub fn recompute_cost_s(&self) -> f64 {
        if self.active.is_empty() {
            return 0.0;
        }
        let total_ctx: f64 = self.active.iter().map(|a| a.ctx).sum();
        let cost = self.model.prefill_cost(total_ctx, 0.0);
        phase_time(&cost, self.class.spec(), self.gpus)
    }

    /// Advance the engine by one step (§6.1's loop body).
    pub fn step(&mut self) -> StepOutcome {
        if self.suspended || self.down {
            return StepOutcome::Idle;
        }
        // Admission (prefill) has priority while batch slots are free —
        // vLLM-style scheduling.
        if !self.waiting.is_empty() && self.active.len() < self.max_batch {
            let mut new_tokens = 0.0;
            let mut ctx_sum = 0.0;
            while let Some(req) = self.waiting.front() {
                if self.active.len() >= self.max_batch {
                    break;
                }
                new_tokens += req.new_tokens;
                ctx_sum += req.ctx_tokens;
                let req = self.waiting.pop_front().unwrap();
                let ctx = req.ctx_tokens + req.new_tokens;
                self.active.push(Active {
                    req,
                    decoded: 0.0,
                    ctx,
                });
            }
            let elapsed = self.prefill_step_s(new_tokens, ctx_sum);
            self.stats.prefill_steps += 1;
            self.stats.prefill_tokens += new_tokens;
            self.stats.busy_s += elapsed;
            // A request with zero decode budget completes at prefill.
            let completed = self.harvest_completed();
            return StepOutcome::Busy {
                elapsed,
                completed,
                was_prefill: true,
            };
        }

        if self.active.is_empty() {
            return StepOutcome::Idle;
        }

        // Decode: advance every active request by up to `decode_chunk`
        // tokens (bounded by the smallest remaining budget so that
        // completions stay step-accurate).  Single pass over the batch
        // computes both the chunk bound and the context sum — this runs
        // once per decode event, the hottest loop in the DES.
        let mut min_remaining = f64::INFINITY;
        let mut ctx_sum = 0.0;
        for a in &self.active {
            min_remaining = min_remaining.min(a.req.decode_budget - a.decoded);
            ctx_sum += a.ctx;
        }
        let min_remaining = min_remaining.max(1.0);
        let chunk = min_remaining.min(self.decode_chunk).floor().max(1.0);

        let batch = self.active.len() as f64;
        let mean_ctx = ctx_sum / batch;
        let elapsed = self.decode_step_s(batch, mean_ctx, chunk);

        for a in &mut self.active {
            a.decoded += chunk;
            a.ctx += chunk;
        }
        self.stats.decode_steps += 1;
        self.stats.decode_tokens += chunk * batch;
        self.stats.busy_s += elapsed;

        let completed = self.harvest_completed();
        StepOutcome::Busy {
            elapsed,
            completed,
            was_prefill: false,
        }
    }

    fn harvest_completed(&mut self) -> Vec<(TrajectoryId, f64)> {
        let mut done = Vec::new();
        self.active.retain(|a| {
            if a.decoded >= a.req.decode_budget {
                done.push((a.req.traj, a.ctx));
                false
            } else {
                true
            }
        });
        self.stats.completed += done.len() as u64;
        done
    }

    /// Drain the engine to idle, returning total elapsed time (used by
    /// synchronous baselines that wait for a whole batch).
    pub fn run_to_idle(&mut self) -> (f64, Vec<(TrajectoryId, f64)>) {
        let mut total = 0.0;
        let mut all = Vec::new();
        loop {
            match self.step() {
                StepOutcome::Idle => return (total, all),
                StepOutcome::Busy {
                    elapsed, completed, ..
                } => {
                    total += elapsed;
                    all.extend(completed);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn engine(class: GpuClass, gpus: usize) -> EngineSim {
        EngineSim::new(0, class, gpus, QWEN3_8B.clone(), 16)
    }

    fn req(id: u64, new: f64, decode: f64) -> SimRequest {
        SimRequest {
            traj: TrajectoryId(id),
            domain: TaskDomain::MathTool,
            new_tokens: new,
            ctx_tokens: 0.0,
            decode_budget: decode,
        }
    }

    #[test]
    fn prefill_then_decode_then_complete() {
        let mut e = engine(GpuClass::H800, 1);
        e.enqueue(req(1, 100.0, 10.0));
        let s1 = e.step();
        match s1 {
            StepOutcome::Busy { was_prefill, .. } => assert!(was_prefill),
            _ => panic!("expected prefill step"),
        }
        let (t, done) = e.run_to_idle();
        assert!(t > 0.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].0, TrajectoryId(1));
        // final ctx = prompt + decoded
        assert_eq!(done[0].1, 110.0);
        assert_eq!(e.stats.completed, 1);
    }

    #[test]
    fn continuous_batching_admits_mid_decode() {
        let mut e = engine(GpuClass::H20, 1);
        e.set_decode_chunk(1.0);
        e.enqueue(req(1, 10.0, 100.0));
        e.step(); // prefill 1
        e.step(); // decode 1 token
        e.enqueue(req(2, 10.0, 5.0));
        let s = e.step(); // admission step for req 2 — decode continues after
        match s {
            StepOutcome::Busy { was_prefill, .. } => assert!(was_prefill),
            _ => panic!(),
        }
        assert_eq!(e.active_len(), 2);
        let (_, done) = e.run_to_idle();
        assert_eq!(done.len(), 2);
        // req 2 (budget 5) completes before req 1 (budget 100)
        assert_eq!(done[0].0, TrajectoryId(2));
    }

    #[test]
    fn abort_waiting_and_active() {
        let mut e = engine(GpuClass::H20, 1);
        e.enqueue(req(1, 10.0, 10.0));
        e.enqueue(req(2, 10.0, 10.0));
        assert!(e.abort(TrajectoryId(2)));
        e.step(); // prefill req1
        assert!(e.abort(TrajectoryId(1)));
        assert_eq!(e.load(), 0);
        assert_eq!(e.stats.aborted, 2);
        assert_eq!(e.step(), StepOutcome::Idle);
    }

    #[test]
    fn backlog_counts_waiting_and_remaining_decode() {
        let mut e = engine(GpuClass::H20, 1);
        e.set_decode_chunk(1.0);
        assert_eq!(e.backlog_tokens(), 0.0);
        e.enqueue(req(1, 100.0, 40.0));
        e.enqueue(req(2, 10.0, 5.0));
        // Waiting: prefill + full decode budgets.
        assert_eq!(e.backlog_tokens(), 155.0);
        e.step(); // admission: both active, prefill done
        assert_eq!(e.backlog_tokens(), 45.0, "prefill tokens retired");
        e.step(); // decode 1 token each
        assert_eq!(e.backlog_tokens(), 43.0);
        e.run_to_idle();
        assert_eq!(e.backlog_tokens(), 0.0);
    }

    #[test]
    fn suspend_preserves_state() {
        let mut e = engine(GpuClass::H20, 1);
        e.enqueue(req(1, 10.0, 50.0));
        e.step();
        e.suspend();
        assert_eq!(e.step(), StepOutcome::Idle);
        assert_eq!(e.active_len(), 1, "in-flight preserved");
        e.resume();
        let (_, done) = e.run_to_idle();
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn recompute_cost_scales_with_inflight_context() {
        let mut e = engine(GpuClass::H800, 1);
        assert_eq!(e.recompute_cost_s(), 0.0);
        e.enqueue(req(1, 1000.0, 50.0));
        e.step();
        let c1 = e.recompute_cost_s();
        e.enqueue(req(2, 4000.0, 50.0));
        e.step();
        let c2 = e.recompute_cost_s();
        assert!(c2 > c1 * 2.0, "{c1} vs {c2}");
    }

    #[test]
    fn h20_decodes_faster_than_h800_at_equal_cost() {
        // Fig 4b's mechanism at engine level: decode-heavy work on
        // 6×H20 vs 2×H800 (cost-equivalent).
        let mut h20 = EngineSim::new(0, GpuClass::H20, 6, QWEN3_8B.clone(), 64);
        let mut h800 = EngineSim::new(1, GpuClass::H800, 2, QWEN3_8B.clone(), 64);
        for i in 0..64 {
            let r = SimRequest {
                traj: TrajectoryId(i),
                domain: TaskDomain::MathTool,
                new_tokens: 400.0,
                ctx_tokens: 0.0,
                decode_budget: 1500.0,
            };
            h20.enqueue(r.clone());
            h800.enqueue(r);
        }
        let (t20, _) = h20.run_to_idle();
        let (t800, _) = h800.run_to_idle();
        let ratio = t20 / t800;
        // Paper: H20 cuts decode-heavy rollout to 0.49–0.79x of H800.
        assert!(ratio < 0.85, "H20/H800 = {ratio}");
        assert!(ratio > 0.2, "H20/H800 = {ratio}");
    }

    #[test]
    fn h800_prefills_faster_than_h20_at_equal_cost() {
        // Fig 4a: prefill-heavy work favors 2×H800 over 6×H20.
        let mut h20 = EngineSim::new(0, GpuClass::H20, 6, QWEN3_8B.clone(), 64);
        let mut h800 = EngineSim::new(1, GpuClass::H800, 2, QWEN3_8B.clone(), 64);
        for i in 0..64 {
            let r = SimRequest {
                traj: TrajectoryId(i),
                domain: TaskDomain::Game,
                new_tokens: 8000.0,
                ctx_tokens: 0.0,
                decode_budget: 40.0,
            };
            h20.enqueue(r.clone());
            h800.enqueue(r);
        }
        let (t20, _) = h20.run_to_idle();
        let (t800, _) = h800.run_to_idle();
        let ratio = t800 / t20;
        // Paper: H800 cuts prefill-heavy rollout to ~0.53x of H20.
        assert!(ratio < 0.8, "H800/H20 = {ratio}");
    }

    #[test]
    fn down_engine_idles_and_drain_recovers_requests() {
        let mut e = engine(GpuClass::H20, 1);
        e.enqueue(req(1, 10.0, 50.0));
        e.step(); // prefill: req 1 now active
        e.enqueue(req(2, 10.0, 50.0)); // still waiting
        e.set_down(true);
        assert_eq!(e.step(), StepOutcome::Idle);
        let drained = e.drain_requests();
        assert_eq!(drained.len(), 2);
        // Waiting requests come out first, then active ones.
        assert_eq!(drained[0].traj, TrajectoryId(2));
        assert_eq!(drained[1].traj, TrajectoryId(1));
        assert_eq!(e.load(), 0);
        e.set_down(false);
        assert!(!e.is_down());
        assert_eq!(e.step(), StepOutcome::Idle, "drained engine is empty");
    }

    #[test]
    fn interference_scales_elapsed_time_only() {
        let mk = |f: f64| {
            let mut e = engine(GpuClass::H800, 1);
            e.set_interference(f);
            e.enqueue(req(1, 500.0, 200.0));
            let (t, done) = e.run_to_idle();
            (t, done.len(), e.stats.decode_tokens)
        };
        let (t1, n1, tok1) = mk(1.0);
        let (t2, n2, tok2) = mk(1.22);
        assert_eq!(n1, n2);
        assert_eq!(tok1, tok2, "token accounting is unchanged");
        assert!((t2 / t1 - 1.22).abs() < 1e-6, "{t1} vs {t2}");
    }

    #[test]
    fn repurpose_changes_step_times_in_place() {
        let mut e = EngineSim::new(0, GpuClass::H800, 2, QWEN3_8B.clone(), 64);
        // Decode-heavy on H800 …
        let t800 = e.decode_step_s(32.0, 4000.0, 16.0);
        e.repurpose(GpuClass::H20, 6, 64);
        assert_eq!(e.class, GpuClass::H20);
        assert_eq!(e.gpus, 6);
        // … is slower than the same batch after repurposing onto 6×H20
        // (Fig 4b's cost-equivalent swap), with id/stats intact.
        let t20 = e.decode_step_s(32.0, 4000.0, 16.0);
        assert!(t20 < t800, "{t20} vs {t800}");
        assert_eq!(e.id, 0);
    }

    #[test]
    fn decode_chunking_preserves_totals() {
        let mk = |chunk: f64| {
            let mut e = engine(GpuClass::H20, 1);
            e.set_decode_chunk(chunk);
            e.enqueue(req(1, 10.0, 100.0));
            e.enqueue(req(2, 10.0, 37.0));
            let (t, done) = e.run_to_idle();
            (t, done.len(), e.stats.decode_tokens)
        };
        let (t1, n1, tok1) = mk(1.0);
        let (t16, n16, tok16) = mk(16.0);
        assert_eq!(n1, n16);
        assert_eq!(tok1, tok16);
        // chunked time within 25% of step-accurate (batch composition
        // at completion boundaries differs slightly)
        assert!((t1 - t16).abs() / t1 < 0.25, "{t1} vs {t16}");
    }
}
