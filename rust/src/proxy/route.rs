//! Pluggable routing policies for the [`LlmProxy`](super::LlmProxy).
//!
//! Routing used to be one hard-coded function inside the proxy; the
//! scheduler-plane refactor promotes it to a [`RoutePolicy`] trait so a
//! scenario can swap the dispatch discipline without touching the
//! proxy (ROADMAP: "as many scenarios as you can imagine").  Three
//! policies ship:
//!
//! * [`AffinityRoute`] — the paper's R1 hardware-affinity routing with
//!   asymmetric congestion spillover (§5.3, §6.1); the default.
//! * [`LeastLoadedRoute`] — classic least-outstanding-requests across
//!   the whole live fleet, affinity ignored (the ablation arm of
//!   Fig 10's affinity study, and a sane default for homogeneous
//!   fleets).
//! * [`DomainFairRoute`] — capacity-weighted fairness: each task
//!   domain spreads its requests across GPU classes in proportion to
//!   live class capacity, so no domain monopolizes the premium pool
//!   (the multi-tenant fairness discipline AgentRL argues for in
//!   multi-task asynchrony).
//! * [`TokenBacklogRoute`] — balances by outstanding prefill + decode
//!   *token* estimates instead of request count: long-decode domains
//!   (ProRL-style agentic rollouts) make request count a poor load
//!   proxy, because one 20k-token decode weighs as much as dozens of
//!   short tool calls.
//! * [`BestFitRoute`] — roofline-driven best fit (paper principle 1):
//!   scores every live engine by the *analytic service time* of the
//!   domain's expected per-turn work on that engine's GPU class
//!   ([`EngineSim::prefill_step_s`] / [`EngineSim::decode_step_s`]),
//!   scaled by queue depth.  Prefill-heavy domains land on
//!   compute-rich classes and decode-heavy domains on bandwidth-rich
//!   classes *emergently* — no affinity table, the roofline decides.
//!   Its `inverted` arm keys on the reciprocal fit, deliberately
//!   placing work on the worst-suited class (Fig 10's lower bound).
//!
//! Policies see only the live fleet and a [`RouteCtx`] snapshot of the
//! proxy's declarations, so they stay independently unit-testable.

use super::EngineSim;
use crate::env::TaskDomain;
use crate::hw::GpuClass;
use std::collections::BTreeMap;

/// Immutable proxy state handed to a policy on every pick.
pub struct RouteCtx<'a> {
    /// Declared `domain → class` affinities (Listing 1's `hw_affinity`).
    pub affinity: &'a BTreeMap<TaskDomain, GpuClass>,
    /// Class for domains without a declaration.
    pub default_class: Option<GpuClass>,
}

/// A dispatch discipline: pick the engine one request lands on.
///
/// # Writing your own routing policy
///
/// Implement `pick` over the live fleet and hand the policy to
/// [`LlmProxy::set_route_policy`](super::LlmProxy::set_route_policy).
/// A policy that pins every domain to the lowest-numbered live engine
/// (useful as a worst-case baseline in routing ablations):
///
/// ```
/// use rollart::env::TaskDomain;
/// use rollart::hw::GpuClass;
/// use rollart::llm::QWEN3_8B;
/// use rollart::proxy::{EngineSim, RouteCtx, RoutePolicy};
///
/// struct FirstLive;
/// impl RoutePolicy for FirstLive {
///     fn name(&self) -> &'static str {
///         "first_live"
///     }
///     fn pick(
///         &mut self,
///         engines: &[EngineSim],
///         _domain: TaskDomain,
///         _ctx: &RouteCtx,
///     ) -> Option<usize> {
///         (0..engines.len()).find(|&i| !engines[i].is_down())
///     }
/// }
///
/// let mut engines = vec![
///     EngineSim::new(0, GpuClass::H800, 1, QWEN3_8B.clone(), 8),
///     EngineSim::new(1, GpuClass::H20, 1, QWEN3_8B.clone(), 8),
/// ];
/// let affinity = std::collections::BTreeMap::new();
/// let ctx = RouteCtx { affinity: &affinity, default_class: None };
/// let mut p = FirstLive;
/// assert_eq!(p.pick(&engines, TaskDomain::Swe, &ctx), Some(0));
/// engines[0].set_down(true);
/// assert_eq!(p.pick(&engines, TaskDomain::Swe, &ctx), Some(1));
/// ```
pub trait RoutePolicy {
    fn name(&self) -> &'static str;

    /// Pick an engine index for `domain`, or `None` when no live engine
    /// can take work (whole fleet down — the caller re-queues).
    /// `&mut self` so stateful disciplines (fair-share counters) can
    /// record the decision.
    fn pick(&mut self, engines: &[EngineSim], domain: TaskDomain, ctx: &RouteCtx) -> Option<usize>;
}

/// Declarative routing selector carried by scenario configs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RouteKind {
    /// R1 hardware-affinity routing (paper default).
    #[default]
    Affinity,
    /// Global least-loaded, affinity ignored.
    LeastLoaded,
    /// Capacity-weighted per-domain fair share across GPU classes.
    DomainFair,
    /// Least outstanding prefill+decode *tokens*, affinity ignored.
    TokenBacklog,
    /// Roofline-driven best fit: minimize analytic per-turn service
    /// time × queue depth (paper principle 1 without an affinity
    /// table).
    BestFit,
    /// Adversarial worst fit (`BestFit` with the fit term inverted):
    /// the ablation floor for the affinity study.
    Inverted,
}

impl RouteKind {
    pub fn name(self) -> &'static str {
        match self {
            RouteKind::Affinity => "affinity",
            RouteKind::LeastLoaded => "least_loaded",
            RouteKind::DomainFair => "domain_fair",
            RouteKind::TokenBacklog => "token_backlog",
            RouteKind::BestFit => "best_fit",
            RouteKind::Inverted => "inverted",
        }
    }

    /// Instantiate the policy this selector names.
    pub fn make(self) -> Box<dyn RoutePolicy> {
        match self {
            RouteKind::Affinity => Box::new(AffinityRoute),
            RouteKind::LeastLoaded => Box::new(LeastLoadedRoute),
            RouteKind::DomainFair => Box::new(DomainFairRoute::new()),
            RouteKind::TokenBacklog => Box::new(TokenBacklogRoute),
            RouteKind::BestFit => Box::new(BestFitRoute::best()),
            RouteKind::Inverted => Box::new(BestFitRoute::inverted()),
        }
    }
}

/// Least-loaded live engine over an iterator of candidate indices.
/// Suspended engines are skipped too: the weight plane suspends
/// engines *individually* while they pull new weights (see
/// [`crate::weights`]), and routing fresh work onto a mid-swap engine
/// would queue it behind the whole transfer.
fn least_loaded(engines: &[EngineSim], idxs: impl Iterator<Item = usize>) -> Option<usize> {
    idxs.filter(|&i| !engines[i].is_down() && !engines[i].is_suspended())
        .min_by_key(|&i| engines[i].load())
}

/// The paper's R1 routing: preferred class by domain declaration, with
/// two fallbacks (§5.3 "redirects execution to a compatible
/// fallback... ensuring forward progress under transient contention"):
///
/// * the class has no live members → global least-loaded;
/// * the class is *congested* (its best queue is much deeper than the
///   global best) → spill to the global least-loaded engine.
///
/// Spillover is asymmetric: decode-heavy work (preferring H20) degrades
/// gracefully on compute-optimized GPUs, but prefill-heavy work must
/// never spill onto bandwidth-optimized GPUs (6.7x slower prefill,
/// Table 2) — the resource manager only offers *compatible* fallbacks.
#[derive(Clone, Copy, Debug, Default)]
pub struct AffinityRoute;

impl RoutePolicy for AffinityRoute {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn pick(&mut self, engines: &[EngineSim], domain: TaskDomain, ctx: &RouteCtx) -> Option<usize> {
        let global = least_loaded(engines, 0..engines.len())?;
        let Some(cls) = ctx.affinity.get(&domain).copied().or(ctx.default_class) else {
            return Some(global);
        };
        let preferred = least_loaded(
            engines,
            (0..engines.len()).filter(|&i| engines[i].class == cls),
        );
        let may_spill = cls == GpuClass::H20;
        match preferred {
            Some(p)
                if !may_spill || engines[p].load() <= 2 * engines[global].load() + 4 =>
            {
                Some(p)
            }
            _ => Some(global),
        }
    }
}

/// Classic least-outstanding-requests over the whole live fleet;
/// affinity declarations are ignored.
#[derive(Clone, Copy, Debug, Default)]
pub struct LeastLoadedRoute;

impl RoutePolicy for LeastLoadedRoute {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn pick(&mut self, engines: &[EngineSim], _domain: TaskDomain, _ctx: &RouteCtx) -> Option<usize> {
        least_loaded(engines, 0..engines.len())
    }
}

/// Capacity-weighted per-domain fair share: domain `d`'s requests are
/// spread across GPU classes in proportion to each class's live GPU
/// capacity, via a largest-deficit rule (weighted round-robin), then
/// least-loaded within the chosen class.  A domain can therefore never
/// monopolize the premium pool, and class shares track fleet churn
/// (crashes, elastic resizes) because capacity is re-read on every
/// pick.
#[derive(Clone, Debug, Default)]
pub struct DomainFairRoute {
    /// Dispatches so far per (domain, class).
    counts: BTreeMap<(TaskDomain, GpuClass), u64>,
}

impl DomainFairRoute {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RoutePolicy for DomainFairRoute {
    fn name(&self) -> &'static str {
        "domain_fair"
    }

    fn pick(&mut self, engines: &[EngineSim], domain: TaskDomain, _ctx: &RouteCtx) -> Option<usize> {
        // Live capacity per class (GPUs, not engines: a wide engine is
        // proportionally more of the fleet).  Mid-swap (suspended)
        // engines are no more dispatchable than down ones, so a class
        // whose members are all pulling weights holds zero capacity
        // and the pick falls to another class instead of returning
        // None while free engines exist.
        let mut cap: BTreeMap<GpuClass, f64> = BTreeMap::new();
        for e in engines.iter().filter(|e| !e.is_down() && !e.is_suspended()) {
            *cap.entry(e.class).or_insert(0.0) += e.gpus as f64;
        }
        let total: f64 = cap.values().sum();
        if total <= 0.0 {
            return None;
        }
        // Largest-deficit rule: the class whose share-per-dispatch is
        // most under-served by this domain goes next.  BTreeMap order +
        // strict inequality make ties deterministic.
        let mut best: Option<(GpuClass, f64)> = None;
        for (&class, &gpus) in &cap {
            let served = *self.counts.get(&(domain, class)).unwrap_or(&0) as f64;
            let score = (gpus / total) / (1.0 + served);
            match best {
                Some((_, s)) if s >= score => {}
                _ => best = Some((class, score)),
            }
        }
        let (class, _) = best?;
        let idx = least_loaded(
            engines,
            (0..engines.len()).filter(|&i| engines[i].class == class),
        )?;
        *self.counts.entry((domain, class)).or_insert(0) += 1;
        Some(idx)
    }
}

/// Least outstanding *token* work across the live fleet
/// ([`EngineSim::backlog_tokens`]: un-admitted prefill tokens plus
/// unfinished decode budgets).  Request count treats a 20k-token SWE
/// decode and a 40-token game action as equal load; in long-decode
/// domains that skews the balance badly — this policy weighs requests
/// by the work they still represent.  Ties break to the lowest engine
/// index, so dispatch stays deterministic.
#[derive(Clone, Copy, Debug, Default)]
pub struct TokenBacklogRoute;

impl RoutePolicy for TokenBacklogRoute {
    fn name(&self) -> &'static str {
        "token_backlog"
    }

    fn pick(&mut self, engines: &[EngineSim], _domain: TaskDomain, _ctx: &RouteCtx) -> Option<usize> {
        (0..engines.len())
            .filter(|&i| !engines[i].is_down() && !engines[i].is_suspended())
            .min_by(|&a, &b| engines[a].backlog_tokens().total_cmp(&engines[b].backlog_tokens()))
    }
}

/// Roofline-driven best fit (paper principle 1, no affinity table).
///
/// For every live engine the policy computes the *analytic service
/// time* of the domain's expected per-turn work — mean observation
/// tokens prefetched into the mean mid-rollout context, then the mean
/// action decoded in the engine's would-be batch — using the exact
/// step-time expressions the DES executes
/// ([`EngineSim::prefill_step_s`] / [`EngineSim::decode_step_s`]).
/// The pick minimizes `fit × (1 + load)`: service estimate scaled by
/// queue depth.  Compute-bound prefill work therefore scores best on
/// FLOPs-rich classes and bandwidth-bound decode work on HBM-rich
/// classes *because the roofline says so*, not because a table does —
/// a new GPU class joins the study by defining its [`crate::hw::GpuSpec`].
///
/// The `inverted` arm keys on `(1 + load) / fit` instead: still
/// queue-balanced (it never starves an engine), but deliberately
/// preferring the class *worst* suited to the domain.  This is the
/// affinity study's lower bound — placement value is the spread
/// between the two arms at equal total FLOPs.
#[derive(Clone, Copy, Debug)]
pub struct BestFitRoute {
    invert: bool,
}

impl BestFitRoute {
    pub fn best() -> Self {
        BestFitRoute { invert: false }
    }

    pub fn inverted() -> Self {
        BestFitRoute { invert: true }
    }

    /// Expected service seconds of one turn of `domain` on engine `e`,
    /// were it dispatched there now.
    fn fit_s(e: &EngineSim, domain: TaskDomain) -> f64 {
        let p = crate::env::profile::DomainProfile::of(domain);
        let turns = p.turns.mean().max(1.0);
        let obs = p.obs_tokens_per_turn.mean().max(1.0);
        let act = p.action_tokens.mean().max(1.0);
        // Mid-rollout context: prompt plus half the rollout's growth.
        let ctx = p.initial_prompt_tokens + 0.5 * turns * (obs + act);
        let batch = (e.active_len() + 1) as f64;
        e.prefill_step_s(obs, ctx) + e.decode_step_s(batch, ctx, act)
    }
}

impl RoutePolicy for BestFitRoute {
    fn name(&self) -> &'static str {
        if self.invert {
            "inverted"
        } else {
            "best_fit"
        }
    }

    fn pick(&mut self, engines: &[EngineSim], domain: TaskDomain, _ctx: &RouteCtx) -> Option<usize> {
        (0..engines.len())
            .filter(|&i| !engines[i].is_down() && !engines[i].is_suspended())
            .map(|i| {
                let fit = Self::fit_s(&engines[i], domain).max(1e-12);
                let queue = 1.0 + engines[i].load() as f64;
                let key = if self.invert { queue / fit } else { fit * queue };
                (key, i)
            })
            .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)))
            .map(|(_, i)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn fleet() -> Vec<EngineSim> {
        vec![
            EngineSim::new(0, GpuClass::H800, 2, QWEN3_8B.clone(), 32),
            EngineSim::new(1, GpuClass::H20, 2, QWEN3_8B.clone(), 32),
            EngineSim::new(2, GpuClass::H20, 2, QWEN3_8B.clone(), 32),
        ]
    }

    fn ctx<'a>(
        affinity: &'a BTreeMap<TaskDomain, GpuClass>,
        default_class: Option<GpuClass>,
    ) -> RouteCtx<'a> {
        RouteCtx {
            affinity,
            default_class,
        }
    }

    #[test]
    fn least_loaded_ignores_affinity() {
        // Load the declared-affinity engine; the policy must walk away
        // from it to an emptier engine of the "wrong" class.
        let mut engines = fleet();
        let mut affinity = BTreeMap::new();
        affinity.insert(TaskDomain::Game, GpuClass::H800);
        let mut p = LeastLoadedRoute;
        engines[0].enqueue(crate::proxy::SimRequest {
            traj: crate::rl::TrajectoryId(0),
            domain: TaskDomain::Game,
            new_tokens: 10.0,
            ctx_tokens: 0.0,
            decode_budget: 5.0,
        });
        let got = p
            .pick(&engines, TaskDomain::Game, &ctx(&affinity, None))
            .unwrap();
        assert_ne!(got, 0, "least-loaded must leave the loaded H800 engine");
    }

    #[test]
    fn least_loaded_none_when_fleet_down() {
        let mut engines = fleet();
        for e in &mut engines {
            e.set_down(true);
        }
        let affinity = BTreeMap::new();
        let mut p = LeastLoadedRoute;
        assert_eq!(p.pick(&engines, TaskDomain::Web, &ctx(&affinity, None)), None);
    }

    #[test]
    fn domain_fair_spreads_by_capacity() {
        // 2 GPUs of H800 vs 4 GPUs of H20 → a single domain's dispatches
        // should split ~1:2 across the classes.
        let engines = fleet();
        let affinity = BTreeMap::new();
        let mut p = DomainFairRoute::new();
        let mut h800 = 0;
        let mut h20 = 0;
        for _ in 0..30 {
            let i = p
                .pick(&engines, TaskDomain::MathTool, &ctx(&affinity, None))
                .unwrap();
            match engines[i].class {
                GpuClass::H800 => h800 += 1,
                GpuClass::H20 => h20 += 1,
            }
        }
        assert_eq!(h800 + h20, 30);
        assert_eq!(h800, 10, "H800 holds 1/3 of capacity: {h800} of 30");
        assert_eq!(h20, 20, "H20 holds 2/3 of capacity: {h20} of 30");
    }

    #[test]
    fn domain_fair_counters_are_per_domain() {
        let engines = fleet();
        let affinity = BTreeMap::new();
        let mut p = DomainFairRoute::new();
        let a = p
            .pick(&engines, TaskDomain::Swe, &ctx(&affinity, None))
            .unwrap();
        let b = p
            .pick(&engines, TaskDomain::Web, &ctx(&affinity, None))
            .unwrap();
        // A fresh domain starts its own deficit sequence: both domains'
        // first pick lands on the larger class, not wherever the other
        // domain left off.
        assert_eq!(engines[a].class, engines[b].class);
    }

    #[test]
    fn domain_fair_tracks_fleet_churn() {
        let mut engines = fleet();
        let affinity = BTreeMap::new();
        let mut p = DomainFairRoute::new();
        // Kill the whole H20 class: everything must land on H800.
        engines[1].set_down(true);
        engines[2].set_down(true);
        for _ in 0..5 {
            let i = p
                .pick(&engines, TaskDomain::Game, &ctx(&affinity, None))
                .unwrap();
            assert_eq!(engines[i].class, GpuClass::H800);
        }
        // Whole fleet down → no target.
        engines[0].set_down(true);
        assert_eq!(p.pick(&engines, TaskDomain::Game, &ctx(&affinity, None)), None);
    }

    #[test]
    fn token_backlog_outweighs_request_count() {
        // Engine 0: one huge-decode request.  Engine 1: three tiny
        // requests.  Least-loaded (request count) picks engine 0; the
        // token-backlog policy must pick engine 1.
        let mut engines = fleet();
        let affinity = BTreeMap::new();
        engines[0].enqueue(crate::proxy::SimRequest {
            traj: crate::rl::TrajectoryId(0),
            domain: TaskDomain::Swe,
            new_tokens: 12_000.0,
            ctx_tokens: 0.0,
            decode_budget: 20_000.0,
        });
        for i in 0..3 {
            engines[1].enqueue(crate::proxy::SimRequest {
                traj: crate::rl::TrajectoryId(1 + i),
                domain: TaskDomain::Game,
                new_tokens: 50.0,
                ctx_tokens: 0.0,
                decode_budget: 40.0,
            });
        }
        let mut ll = LeastLoadedRoute;
        let by_count = ll
            .pick(&engines, TaskDomain::Swe, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(by_count, 2, "least-loaded prefers the empty engine");
        engines[2].set_down(true);
        let by_count = ll
            .pick(&engines, TaskDomain::Swe, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(by_count, 0, "one request beats three");
        let mut tb = TokenBacklogRoute;
        let by_tokens = tb
            .pick(&engines, TaskDomain::Swe, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(by_tokens, 1, "270 outstanding tokens beat 32k");
    }

    #[test]
    fn token_backlog_skips_down_engines_and_breaks_ties_low() {
        let mut engines = fleet();
        let affinity = BTreeMap::new();
        let mut p = TokenBacklogRoute;
        // Empty fleet: all tie at 0 backlog → lowest index.
        assert_eq!(p.pick(&engines, TaskDomain::Web, &ctx(&affinity, None)), Some(0));
        engines[0].set_down(true);
        assert_eq!(p.pick(&engines, TaskDomain::Web, &ctx(&affinity, None)), Some(1));
        for e in &mut engines {
            e.set_down(true);
        }
        assert_eq!(p.pick(&engines, TaskDomain::Web, &ctx(&affinity, None)), None);
    }

    #[test]
    fn route_kind_round_trip() {
        for k in [
            RouteKind::Affinity,
            RouteKind::LeastLoaded,
            RouteKind::DomainFair,
            RouteKind::TokenBacklog,
            RouteKind::BestFit,
            RouteKind::Inverted,
        ] {
            assert_eq!(k.make().name(), k.name());
        }
        assert_eq!(RouteKind::default(), RouteKind::Affinity);
    }

    #[test]
    fn best_fit_places_by_phase_affinity() {
        // Equal-cost fleet (2×H800 vs 6×H20, Table 2): decode-heavy
        // MathTool must pick the H20 engine, prefill-heavy Swe the
        // H800 engine — with no affinity table at all.
        let engines = vec![
            EngineSim::new(0, GpuClass::H800, 2, QWEN3_8B.clone(), 32),
            EngineSim::new(1, GpuClass::H20, 6, QWEN3_8B.clone(), 32),
        ];
        let affinity = BTreeMap::new();
        let mut p = BestFitRoute::best();
        let decode_pick = p
            .pick(&engines, TaskDomain::MathTool, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(engines[decode_pick].class, GpuClass::H20);
        let prefill_pick = p
            .pick(&engines, TaskDomain::Swe, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(engines[prefill_pick].class, GpuClass::H800);
        // The inverted arm flips both placements.
        let mut inv = BestFitRoute::inverted();
        let decode_pick = inv
            .pick(&engines, TaskDomain::MathTool, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(engines[decode_pick].class, GpuClass::H800);
        let prefill_pick = inv
            .pick(&engines, TaskDomain::Swe, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(engines[prefill_pick].class, GpuClass::H20);
    }

    #[test]
    fn best_fit_spills_under_queue_pressure() {
        // One H20 and one H800; pile load onto the H20 engine until
        // the queue term overrides the class fit for decode work.
        let mut engines = vec![
            EngineSim::new(0, GpuClass::H800, 2, QWEN3_8B.clone(), 64),
            EngineSim::new(1, GpuClass::H20, 6, QWEN3_8B.clone(), 64),
        ];
        let affinity = BTreeMap::new();
        let mut p = BestFitRoute::best();
        for i in 0..64 {
            engines[1].enqueue(crate::proxy::SimRequest {
                traj: crate::rl::TrajectoryId(i),
                domain: TaskDomain::MathTool,
                new_tokens: 30.0,
                ctx_tokens: 0.0,
                decode_budget: 2000.0,
            });
        }
        let got = p
            .pick(&engines, TaskDomain::MathTool, &ctx(&affinity, None))
            .unwrap();
        assert_eq!(got, 0, "a 64-deep H20 queue must spill to the idle H800");
        // Whole fleet down → None, like every other policy.
        for e in &mut engines {
            e.set_down(true);
        }
        assert_eq!(
            p.pick(&engines, TaskDomain::MathTool, &ctx(&affinity, None)),
            None
        );
    }
}
