//! Weight-dissemination plane: event-driven, per-engine weight sync
//! over the contended network (§6.2/§6.3, Table 4).
//!
//! The pre-refactor driver modeled weight sync as a *fleet-wide stall*:
//! drain every engine, charge one analytic
//! [`MooncakeStore::sync`](crate::mooncake::MooncakeStore::sync)
//! scalar, bump a single global [`Version`].  Rolling updates, lazy
//! pulls and transfer/decode overlap — the regimes StreamRL's
//! disaggregated stream generation and rollout-as-a-service systems
//! exploit — were unrepresentable.  This module promotes dissemination
//! to a first-class subsystem:
//!
//! * every engine carries its **own** weight [`Version`]; the fleet is
//!   allowed to disagree, and the α-staleness window becomes a real
//!   scheduling trade-off instead of bookkeeping;
//! * a pluggable [`SyncStrategy`] decides *which engines refresh when*:
//!
//! | strategy | semantics | trainer stall | engine stall |
//! |---|---|---|---|
//! | [`BlockingBroadcast`] | the legacy fleet drain: suspend everything, one analytic store sync, global flip | exposed + KV recompute | whole fleet, whole window |
//! | [`RollingSubset`] | `k` engines stream their pull at a time; the rest stay at the old version | none | cutover only, `k` pulls in flight |
//! | [`LazyPull`] | each engine pulls at its next idle gap, forced once it would fall α behind | none | cutover only, deferred to idle |
//! | [`OverlappedBroadcast`] | everyone streams at once; the cutover itself is chunked so only the last chunk's GPU load is exposed | none | last-chunk cutover only |
//! | [`AdaptiveSync`] | closed loop: `k` tuned per iteration from the observed `get_batch` wait vs the fleet's version lag | none | cutover only, adapted `k` |
//!
//! * every per-engine pull is **bucketized** by the Mooncake model
//!   ([`MooncakeConfig::bucket_sizes`]): [`bucketized_pull`] admits the
//!   buckets as *sequenced* transfers on a trainer-side
//!   [`SharedLink`](crate::net::SharedLink) — never reordered within
//!   one engine's pull, conserving bytes exactly — each gated on the
//!   trainer→store push pipeline producing that bucket, so the DES
//!   reproduces Table 4's push/pull/exposed decomposition *per engine*
//!   ([`BucketBreakdown`], cross-checked against
//!   [`MooncakeStore::sync`](crate::mooncake::MooncakeStore::sync) by
//!   `rust/tests/weights_conformance.rs`).  The transfer streams
//!   *behind decode*; the engine suspends only for the cutover (chunked
//!   GPU load + per-bucket coordination + KV recompute).  Concurrent
//!   pulls contend for the fan-out slots (and, with
//!   [`WeightsScenario::share_kv_link`], with PD KV traffic on the same
//!   link);
//! * a [`WeightSyncReport`] surfaces the exposed stall, overlap ratio,
//!   per-engine version lag, link queue delay and the bucket
//!   decomposition on [`ScenarioResult`](crate::sim::ScenarioResult).
//!
//! The driver core (see [`crate::sim::driver::core`]) owns the event
//! loop; this module owns the *decisions* (strategy), the *transfer
//! pipeline* ([`bucketized_pull`]) and the *knobs* (scenario + report).
//! `BlockingBroadcast` keeps the exact pre-refactor code path so the
//! fleet-drain numbers are reproduced by construction (pinned by
//! `blocking_broadcast_is_the_legacy_fleet_drain` in the driver core's
//! tests).

use crate::llm::LlmSpec;
use crate::mooncake::MooncakeConfig;
use crate::net::{balanced_makespan, Grant, Link, SharedLink};
use crate::rl::Version;

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Store→engine fan-out path for per-engine weight pulls: the Mooncake
/// pull side of Table 4 (aggregate ≈2.1 GB/s across the inference
/// fleet), modeled as one contended link.  The per-transfer session
/// cost equals the bucket model's per-bucket coordination latency —
/// transfers on this link *are* buckets, so one serial bucketized pull
/// reproduces [`MooncakeStore::acc_pull_time`](crate::mooncake::MooncakeStore::acc_pull_time)
/// up to the per-bucket delivery latency.
pub static MOONCAKE_FANOUT: Link = Link {
    name: "mooncake-fanout",
    raw_gbps: 200.0,
    effective_bytes_per_s: 2.1 * GB,
    setup_s: 0.01,
    latency_s: 0.002,
};

/// Declarative strategy selector carried by scenario configs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SyncStrategyKind {
    /// Today's drain-everything semantics (the baseline): suspend the
    /// fleet, one analytic store sync, global version bump.
    #[default]
    BlockingBroadcast,
    /// Sync `k` engines at a time while the rest keep decoding.
    RollingSubset { k: usize },
    /// Each engine pulls at its next idle gap, bounded by α.
    LazyPull,
    /// Chunked push pipelined with decode; `chunks` pipeline stages,
    /// only the last chunk's GPU load is exposed per engine.
    OverlappedBroadcast { chunks: usize },
    /// Closed-loop rolling: the concurrency `k` is tuned per iteration
    /// from the observed `get_batch` wait vs the fleet's version lag
    /// (same controller shape as the elastic plane's
    /// [`AutoScaler`](crate::elastic::AutoScaler)).
    Adaptive,
}

impl SyncStrategyKind {
    pub fn name(self) -> &'static str {
        match self {
            SyncStrategyKind::BlockingBroadcast => "blocking",
            SyncStrategyKind::RollingSubset { .. } => "rolling",
            SyncStrategyKind::LazyPull => "lazy",
            SyncStrategyKind::OverlappedBroadcast { .. } => "overlapped",
            SyncStrategyKind::Adaptive => "adaptive",
        }
    }

    /// Instantiate the strategy this selector names.
    pub fn make(self) -> Box<dyn SyncStrategy> {
        match self {
            SyncStrategyKind::BlockingBroadcast => Box::new(BlockingBroadcast),
            SyncStrategyKind::RollingSubset { k } => Box::new(RollingSubset::new(k)),
            SyncStrategyKind::LazyPull => Box::new(LazyPull),
            SyncStrategyKind::OverlappedBroadcast { chunks } => {
                Box::new(OverlappedBroadcast::new(chunks))
            }
            SyncStrategyKind::Adaptive => Box::new(AdaptiveSync::new()),
        }
    }
}

/// The `weights` knob of a [`Scenario`](crate::sim::Scenario).
#[derive(Clone, Debug)]
pub struct WeightsScenario {
    pub strategy: SyncStrategyKind,
    /// Trainer-side fan-out link (store → engines) the per-engine
    /// pulls ride.  Its `setup_s` *and* `effective_bytes_per_s` are
    /// derived from the bucket model (transfers on this link are
    /// buckets — see [`WeightsScenario::fanout_link`]): tune delivery
    /// latency and identity here, bandwidth and coordination cost on
    /// `mooncake`.
    pub link: Link,
    /// Concurrent transfer slots on the fan-out link; pulls beyond
    /// this queue FIFO ([`SharedLink`](crate::net::SharedLink)).
    pub fanout_slots: usize,
    /// Route weight pulls over the PD deployment's KV link instead of
    /// the dedicated fan-out link, so weight and KV traffic contend for
    /// the same slots.  Ignored when the scenario has no disaggregated
    /// PD deployment.
    pub share_kv_link: bool,
    /// The Mooncake bucket model every weight transfer is priced with:
    /// per-engine pulls split into `bucket_count` sequenced bucket
    /// transfers, the trainer→store push paces them, and the cutover
    /// pays the per-bucket coordination residual (Table 4).
    pub mooncake: MooncakeConfig,
    /// Template for [`SyncStrategyKind::Adaptive`]: the controller the
    /// driver clones when the strategy is adaptive, carrying the tuned
    /// `rollout_bound_ratio` / `cooldown_steps` knobs
    /// ([`SyncStrategyKind`] itself is `Copy + Eq` and cannot hold the
    /// f64 ratio).  Ignored by every other strategy.
    pub adaptive: AdaptiveSync,
}

impl Default for WeightsScenario {
    fn default() -> Self {
        WeightsScenario {
            strategy: SyncStrategyKind::BlockingBroadcast,
            link: MOONCAKE_FANOUT.clone(),
            fanout_slots: 2,
            share_kv_link: false,
            mooncake: MooncakeConfig::default(),
            adaptive: AdaptiveSync::new(),
        }
    }
}

impl WeightsScenario {
    /// Convenience constructor: `strategy` over the default fan-out.
    pub fn with_strategy(strategy: SyncStrategyKind) -> Self {
        WeightsScenario {
            strategy,
            ..WeightsScenario::default()
        }
    }

    /// Instantiate the configured strategy.  Unlike
    /// [`SyncStrategyKind::make`] this honors the scenario's
    /// [`WeightsScenario::adaptive`] template, so tuned controller
    /// knobs survive into the driver.
    pub fn make_strategy(&self) -> Box<dyn SyncStrategy> {
        match self.strategy {
            SyncStrategyKind::Adaptive => Box::new(self.adaptive),
            other => other.make(),
        }
    }

    /// The fan-out link actually priced: `link` with its per-transfer
    /// session cost pinned to the bucket model's coordination latency
    /// and its bandwidth pinned to the bucket model's aggregate pull
    /// goodput.  Deriving both here (instead of trusting the duplicate
    /// knobs to stay equal) keeps the DES link pricing and the
    /// analytic store decomposition from silently desynchronizing when
    /// either side is re-calibrated — the ROADMAP's "drive the fan-out
    /// link bandwidth from the Mooncake bucket model", literally.
    pub fn fanout_link(&self) -> Link {
        Link {
            setup_s: self.mooncake.per_bucket_latency_s,
            effective_bytes_per_s: self.mooncake.pull_bytes_per_s,
            ..self.link.clone()
        }
    }

    /// Analytic fleet-blocking dissemination time: the balanced
    /// fair-share makespan of one full-weight *bucketized* pull per
    /// engine over the fan-out link (every bucket pays the link's
    /// per-transfer session cost — the bucket model's coordination
    /// RPC), plus the in-GPU weight load at the suspend point.  This is
    /// the term the *synchronous* baseline pays when a non-legacy
    /// weight plane is configured (a barrier pipeline cannot exploit
    /// rolling updates, but it must pay the same transfer cost model so
    /// sync-vs-async comparisons stay fair — see
    /// [`crate::sim::sync_driver`]).
    pub fn analytic_fleet_sync_s(&self, model: &LlmSpec, n_engines: usize) -> f64 {
        let bytes = model.weight_bytes();
        let per_engine = self.mooncake.bucket_sizes(bytes);
        let mut transfers: Vec<f64> = Vec::new();
        for _ in 0..n_engines.max(1) {
            transfers.extend_from_slice(&per_engine);
        }
        balanced_makespan(&self.fanout_link(), self.fanout_slots, &transfers)
            + bytes / self.mooncake.gpu_load_bytes_per_s
    }

    /// Basic sanity of the knob (mirrors the config-file validation).
    pub fn validate(&self) -> Result<(), String> {
        if self.fanout_slots == 0 {
            return Err("weights.fanout_slots must be ≥ 1".to_string());
        }
        if self.mooncake.bucket_bytes <= 0.0 || !self.mooncake.bucket_bytes.is_finite() {
            return Err("weights.mooncake.bucket_bytes must be positive".to_string());
        }
        match self.strategy {
            SyncStrategyKind::RollingSubset { k } if k == 0 => {
                Err("weights.rolling k must be ≥ 1".to_string())
            }
            SyncStrategyKind::OverlappedBroadcast { chunks } if chunks == 0 => {
                Err("weights.overlapped chunks must be ≥ 1".to_string())
            }
            _ => Ok(()),
        }
    }
}

/// Fleet snapshot handed to a strategy decision.  Indices are engine
/// indices in the driver's fleet order.
pub struct FleetView<'a> {
    /// The published version dissemination is converging to.
    pub target: Version,
    /// Each engine's current weight version.
    pub engine_version: &'a [Version],
    /// Down (crashed/retired) engines never sync; they reload current
    /// weights as part of recovery/provisioning instead.
    pub engine_down: &'a [bool],
    /// Engines already committed to an in-flight sync.
    pub syncing: &'a [bool],
    /// The scenario's α staleness bound.
    pub alpha: u64,
}

impl<'a> FleetView<'a> {
    /// Engines eligible to start a sync: live, idle (sync-wise) and
    /// behind the target, stalest first (ties break low index).
    pub fn behind(&self) -> Vec<usize> {
        let mut v: Vec<usize> = (0..self.engine_version.len())
            .filter(|&i| {
                !self.engine_down[i] && !self.syncing[i] && self.engine_version[i] < self.target
            })
            .collect();
        v.sort_by_key(|&i| (self.engine_version[i], i));
        v
    }

    /// Engines currently committed to a sync.
    pub fn syncing_count(&self) -> usize {
        self.syncing.iter().filter(|s| **s).count()
    }

    /// How many versions engine `i` lags the target.
    pub fn lag(&self, i: usize) -> u64 {
        self.target.0.saturating_sub(self.engine_version[i].0)
    }
}

/// What a closed-loop strategy did with its knob this iteration
/// (surfaced as [`WeightSyncReport::adapt_raises`] /
/// [`WeightSyncReport::adapt_drops`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdaptDecision {
    Hold,
    /// Sync more aggressively (the fleet's lag approached the α bound).
    Raise,
    /// Sync lazier (the iteration was rollout-bound; free the link and
    /// the cutover stalls for generation).
    Lower,
}

/// A weight-dissemination discipline: decides which engines refresh
/// when, over the driver core's event loop.
///
/// The core consults the strategy at four points: when a freshly
/// trained version begins disseminating, after every per-engine sync
/// completion (both via [`SyncStrategy::next_wave`]), for idle-pull
/// strategies whenever an engine finishes a step
/// ([`SyncStrategy::pull_on_idle`]), and once per training iteration
/// for closed-loop tuning ([`SyncStrategy::observe_iteration`]).
/// Strategies never touch the event queue themselves; they return
/// engine sets and the core turns them into bucketized transfer +
/// cutover events, which keeps every strategy composable with faults,
/// elasticity and PD dispatch.
pub trait SyncStrategy {
    fn name(&self) -> &'static str;

    /// The legacy barrier: drain the whole fleet, one analytic store
    /// sync, global version flip.  When true the core keeps the exact
    /// pre-refactor suspend/drain path and none of the event-driven
    /// hooks fire.
    fn blocking(&self) -> bool {
        false
    }

    /// Engines to start syncing now.  Called when dissemination of a
    /// new version begins and again after every per-engine completion;
    /// eager strategies return the next wave, lazy ones return only
    /// engines the α bound forces.
    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize>;

    /// Pull at each engine's next idle gap (the core offers every
    /// engine a sync opportunity at its step boundaries).
    fn pull_on_idle(&self) -> bool {
        false
    }

    /// Pipeline depth of the cutover's chunked GPU load (1 =
    /// whole-weights swap at the suspend point).
    fn chunks(&self) -> usize {
        1
    }

    /// Closed-loop hook, called once per completed training iteration
    /// with the iteration's `get_batch` wait and train time plus the
    /// fleet's worst version lag right after the publish.  The default
    /// is open-loop (no adaptation).  Decisions must be pure functions
    /// of these measured signals — no randomness — so seeded replays
    /// stay bit-identical (see `docs/DETERMINISM.md`).
    fn observe_iteration(
        &mut self,
        _wait_s: f64,
        _train_s: f64,
        _max_lag: u64,
        _alpha: u64,
    ) -> AdaptDecision {
        AdaptDecision::Hold
    }
}

/// The legacy fleet drain (pre-refactor semantics, kept as baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockingBroadcast;

impl SyncStrategy for BlockingBroadcast {
    fn name(&self) -> &'static str {
        "blocking"
    }

    fn blocking(&self) -> bool {
        true
    }

    fn next_wave(&mut self, _fleet: &FleetView) -> Vec<usize> {
        Vec::new() // the core's legacy drain path handles everything
    }
}

/// Sync `k` engines at a time while the rest keep decoding at the old
/// version: the production rolling-update discipline.
#[derive(Clone, Copy, Debug)]
pub struct RollingSubset {
    pub k: usize,
}

impl RollingSubset {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "rolling subset needs k ≥ 1");
        RollingSubset { k }
    }
}

impl SyncStrategy for RollingSubset {
    fn name(&self) -> &'static str {
        "rolling"
    }

    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize> {
        let in_flight = fleet.syncing_count();
        if in_flight >= self.k {
            return Vec::new();
        }
        fleet.behind().into_iter().take(self.k - in_flight).collect()
    }
}

/// Each engine pulls from the store at its next idle gap; an engine
/// that would fall α behind the published version is forced to pull at
/// its next step boundary instead (the α bound keeps lazy laziness from
/// generating turns the buffer would evict anyway).
#[derive(Clone, Copy, Debug, Default)]
pub struct LazyPull;

impl SyncStrategy for LazyPull {
    fn name(&self) -> &'static str {
        "lazy"
    }

    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize> {
        // Only α-forced engines; voluntary pulls happen at idle gaps
        // through the `pull_on_idle` hook.
        fleet
            .behind()
            .into_iter()
            .filter(|&i| fleet.lag(i) >= fleet.alpha.max(1))
            .collect()
    }

    fn pull_on_idle(&self) -> bool {
        true
    }
}

/// Chunked/layer-wise push pipelined with decode: the transfer streams
/// behind ongoing generation and only the cutover (last chunk's GPU
/// load + KV recompute) suspends the engine.
#[derive(Clone, Copy, Debug)]
pub struct OverlappedBroadcast {
    pub chunks: usize,
}

impl OverlappedBroadcast {
    pub fn new(chunks: usize) -> Self {
        assert!(chunks > 0, "overlapped broadcast needs ≥ 1 chunk");
        OverlappedBroadcast { chunks }
    }
}

impl SyncStrategy for OverlappedBroadcast {
    fn name(&self) -> &'static str {
        "overlapped"
    }

    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize> {
        fleet.behind() // everyone streams concurrently (and contends)
    }

    fn chunks(&self) -> usize {
        self.chunks
    }
}

/// Closed-loop rolling dissemination: the concurrency `k` — how many
/// engines may stream a refresh at once beyond the α-forced ones — is
/// tuned once per training iteration from the observed `get_batch`
/// wait vs the fleet's version lag, the same feedback shape the
/// elastic controllers use ([`crate::elastic::AutoScaler`]):
///
/// * the fleet's worst lag reached the α bound → staleness (and the
///   aborts it causes) is the binding constraint: raise `k`;
/// * the iteration was rollout-bound (`get_batch` wait above
///   [`AdaptiveSync::rollout_bound_ratio`] × train) with lag in hand →
///   dissemination is stealing link bandwidth and cutover time from a
///   starved rollout: lower `k`;
/// * a cooldown iteration follows every adjustment so the pipeline
///   re-reaches steady state before the next decision.
///
/// Engines at the α bound are *always* refreshed regardless of `k` (α
/// is a hard bound, not advice), and idle engines pull opportunistically
/// ([`SyncStrategy::pull_on_idle`]) — laziness never manufactures lag.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveSync {
    /// Current voluntary-refresh concurrency (the adapted knob).
    k: usize,
    /// `k`'s bounds.
    pub min_k: usize,
    pub max_k: usize,
    /// Rollout-bound when `get_batch` wait exceeds this multiple of the
    /// train time.
    pub rollout_bound_ratio: f64,
    /// Iterations to hold after an adjustment.
    pub cooldown_steps: usize,
    cooldown: usize,
}

impl AdaptiveSync {
    /// Calibrated defaults (see the `calib_wsync` bench, which sweeps
    /// `rollout_bound_ratio` × `cooldown_steps` over the PD + chaos +
    /// elastic stress scenario, mirroring how
    /// [`PdElasticPolicy`](crate::elastic::PdElasticPolicy)'s
    /// thresholds were chosen):
    ///
    /// * `rollout_bound_ratio = 1.0` — treat the iteration as
    ///   rollout-bound as soon as the trainer waits longer on
    ///   `get_batch` than it trains.  Laxer ratios (2.0) let
    ///   dissemination keep stealing bandwidth from an already-starved
    ///   rollout; tighter ratios (0.5) drop `k` on noise and re-raise
    ///   it a few iterations later, churning without winning goodput.
    /// * `cooldown_steps = 1` — one settle iteration after each
    ///   adjustment.  `0` double-adjusts before the pipeline re-reaches
    ///   steady state; `3` reacts a full staleness window late under
    ///   regime shifts.
    ///
    /// The sweep's table is written to `bench-results/calib_wsync.csv`
    /// and the chosen cell is pinned by
    /// `adaptive_defaults_match_calibration` below.
    pub fn new() -> Self {
        AdaptiveSync {
            k: 1,
            min_k: 1,
            max_k: 64,
            rollout_bound_ratio: 1.0,
            cooldown_steps: 1,
            cooldown: 0,
        }
    }

    /// The current concurrency the controller has settled on.
    pub fn k(&self) -> usize {
        self.k
    }
}

impl Default for AdaptiveSync {
    fn default() -> Self {
        Self::new()
    }
}

impl SyncStrategy for AdaptiveSync {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize> {
        let forced_lag = fleet.alpha.max(1);
        let mut voluntary = fleet.syncing_count();
        let mut wave = Vec::new();
        for i in fleet.behind() {
            if fleet.lag(i) >= forced_lag {
                // α is a hard bound: refresh regardless of k.
                wave.push(i);
            } else if voluntary < self.k {
                wave.push(i);
                voluntary += 1;
            }
        }
        wave
    }

    fn pull_on_idle(&self) -> bool {
        true
    }

    fn observe_iteration(
        &mut self,
        wait_s: f64,
        train_s: f64,
        max_lag: u64,
        alpha: u64,
    ) -> AdaptDecision {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return AdaptDecision::Hold;
        }
        let train = train_s.max(1e-9);
        if max_lag >= alpha.max(1) && self.k < self.max_k {
            self.k += 1;
            self.cooldown = self.cooldown_steps;
            AdaptDecision::Raise
        } else if wait_s > self.rollout_bound_ratio * train && self.k > self.min_k {
            self.k -= 1;
            self.cooldown = self.cooldown_steps;
            AdaptDecision::Lower
        } else {
            AdaptDecision::Hold
        }
    }
}

/// One bucket's admission inside a pipelined pull.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BucketGrant {
    /// Bytes this bucket moved (≤ the bucket granularity; the tail
    /// bucket carries the remainder).
    pub bytes: f64,
    /// The link's admission (start / done / queue delay).
    pub grant: Grant,
}

/// Outcome of one engine's bucketized pull ([`bucketized_pull`]).
#[derive(Clone, Debug)]
pub struct PullOutcome {
    /// When the final bucket lands (== the admission time for an empty
    /// payload).
    pub done_s: f64,
    /// Pure transfer cost: Σ per-bucket service + delivery latency,
    /// excluding queueing and push gating — the per-engine counterpart
    /// of Table 4's accumulated pull.
    pub transfer_s: f64,
    /// Queue delay the buckets accumulated on the link's slots.
    pub queue_delay_s: f64,
    /// Worst single-bucket queue delay.
    pub max_queue_delay_s: f64,
    /// Time bucket admissions spent gated on the trainer→store push
    /// pipeline (beyond what the pull itself was still busy with).
    pub push_gate_s: f64,
    /// Buckets that had to wait for a link slot.
    pub queued: u64,
    /// The sequenced per-bucket admissions, in pull order.
    pub buckets: Vec<BucketGrant>,
    /// Low-priority pull id on the link ([`SharedLink::begin_low_pull`])
    /// when the pull was admitted as preemptible background traffic;
    /// `None` for the legacy FIFO class.  The driver re-checks the
    /// pull's delivery against [`SharedLink::low_pull_done`] at its
    /// stream event, because KV preemptions can push the tail buckets
    /// back *after* this outcome was granted.
    pub pull: Option<u64>,
}

/// Admit one engine's weight pull as a **bucketized pipeline** on a
/// contended link: the payload splits into the Mooncake bucket model's
/// sequenced buckets ([`MooncakeConfig::bucket_sizes`]), bucket `i+1`
/// is admitted only after bucket `i` has fully landed (buckets never
/// reorder within one pull), and each bucket additionally waits for
/// `push_ready_at(i)` — the time the trainer→store push pipeline
/// produced it — so a pull launched right at publish trails the push
/// bucket-by-bucket exactly as
/// [`MooncakeStore::sync`](crate::mooncake::MooncakeStore::sync)'s
/// analytic pipeline does.  A zero-byte payload admits nothing and
/// completes immediately (see the [`SharedLink`] zero-byte guard).
pub fn bucketized_pull(
    link: &mut SharedLink,
    mc: &MooncakeConfig,
    now: f64,
    bytes: f64,
    push_ready_at: impl Fn(usize) -> f64,
) -> PullOutcome {
    bucketized_pull_classed(link, mc, now, bytes, push_ready_at, false)
}

/// [`bucketized_pull`] with a traffic class: `background` admits the
/// buckets as **low-priority, preemptible** segments
/// ([`SharedLink::acquire_low`]) that KV hops may push back on a
/// shared link — the event-driven strategies' behind-decode streams.
/// With `background = false`, or on a link without
/// [`SharedLink::enable_preemption`], this is exactly the legacy FIFO
/// pull.
pub fn bucketized_pull_classed(
    link: &mut SharedLink,
    mc: &MooncakeConfig,
    now: f64,
    bytes: f64,
    push_ready_at: impl Fn(usize) -> f64,
    background: bool,
) -> PullOutcome {
    let pull = if background && link.preemption_enabled() {
        Some(link.begin_low_pull())
    } else {
        None
    };
    let mut out = PullOutcome {
        done_s: now,
        transfer_s: 0.0,
        queue_delay_s: 0.0,
        max_queue_delay_s: 0.0,
        push_gate_s: 0.0,
        queued: 0,
        buckets: Vec::new(),
        pull,
    };
    let latency = link.link().latency_s;
    let mut t = now;
    for (i, bucket) in mc.bucket_sizes(bytes).into_iter().enumerate() {
        let gate = push_ready_at(i);
        out.push_gate_s += (gate - t).max(0.0);
        let admit = t.max(gate).max(now);
        let grant = match pull {
            Some(id) => link.acquire_low(admit, bucket, id),
            None => link.acquire(admit, bucket),
        };
        out.transfer_s += link.service_time(bucket) + latency;
        out.queue_delay_s += grant.queue_delay_s;
        out.max_queue_delay_s = out.max_queue_delay_s.max(grant.queue_delay_s);
        if grant.queue_delay_s > 1e-12 {
            out.queued += 1;
        }
        t = grant.done_s;
        out.buckets.push(BucketGrant { bytes: bucket, grant });
    }
    out.done_s = t;
    out
}

/// Per-run bucket decomposition of the weight plane — the DES
/// counterpart of Table 4's push / accumulated-pull / exposed / naive
/// rows, accumulated per publish (push, naive) and per engine pull /
/// cutover (pull, exposed).  `rust/tests/weights_conformance.rs` pins
/// the per-publish and per-engine means against
/// [`MooncakeStore::sync`](crate::mooncake::MooncakeStore::sync)'s
/// analytic decomposition.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BucketBreakdown {
    /// Trainer→store bucketized push time, accumulated per publish
    /// (hidden behind rollout; per-engine pulls gate on its schedule).
    pub push_s: f64,
    /// Σ per-engine pull transfer time (service + delivery, excluding
    /// queueing/gating) — divide by [`BucketBreakdown::engine_pulls`]
    /// for the per-engine accumulated pull.
    pub acc_pull_s: f64,
    /// Σ exposed weight-swap cost per cutover: the (chunked) GPU load
    /// plus the per-bucket coordination residual.  Excludes the KV
    /// recompute (which depends on in-flight contexts) so the mean per
    /// cutover stays cross-checkable against the analytic store.
    pub exposed_s: f64,
    /// What naive blocking (push + fleet pull, no overlap) would pay,
    /// accumulated per publish.
    pub naive_s: f64,
    /// Bucketized per-engine pulls admitted (including elastic warm-up
    /// pulls).
    pub engine_pulls: u64,
    /// Cutovers performed (an in-flight pull at run end has no
    /// cutover yet).
    pub cutovers: u64,
    /// Bucket transfers admitted on the fan-out / shared-KV link.
    pub bucket_transfers: u64,
    /// Σ bytes across bucket transfers (= `engine_pulls` × weight
    /// bytes: pipelining conserves bytes).
    pub bytes_pulled: f64,
    /// Queue delay the buckets accumulated on the link (contention
    /// between concurrent pulls, and with KV traffic when shared).
    pub queue_delay_s: f64,
    /// Worst single-bucket queue delay.
    pub max_queue_delay_s: f64,
    /// Time bucket admissions spent gated on the push pipeline.
    pub push_gate_s: f64,
}

impl BucketBreakdown {
    /// Mean per-engine pull transfer time (Table 4's accumulated pull,
    /// per engine).
    pub fn mean_pull_s(&self) -> f64 {
        if self.engine_pulls == 0 {
            return 0.0;
        }
        self.acc_pull_s / self.engine_pulls as f64
    }

    /// Mean exposed weight-swap cost per cutover.
    pub fn mean_exposed_s(&self) -> f64 {
        if self.cutovers == 0 {
            return 0.0;
        }
        self.exposed_s / self.cutovers as f64
    }

    /// Mean bucket queue delay per engine pull.
    pub fn mean_queue_delay_s(&self) -> f64 {
        if self.engine_pulls == 0 {
            return 0.0;
        }
        self.queue_delay_s / self.engine_pulls as f64
    }
}

/// Dissemination activity over one scenario run, surfaced as
/// [`ScenarioResult::weights`](crate::sim::ScenarioResult::weights).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WeightSyncReport {
    /// Trained versions whose dissemination began.
    pub publishes: u64,
    /// Per-engine sync completions (blocking: live fleet size per
    /// publish).
    pub engine_syncs: u64,
    /// Trainer-visible stall: wall-clock the training pipeline spent
    /// blocked on weight sync (blocking: exposed store sync + KV
    /// recompute per publish; event strategies: none — the fleet
    /// converges while training proceeds).
    pub exposed_stall_s: f64,
    /// Engine-seconds *committed* to weight-sync suspensions, charged
    /// when each suspension is scheduled (the capacity the fleet gave
    /// up to dissemination).  Event strategies suspend only for the
    /// cutover — the bucketized transfer streams behind decode — so
    /// this is cutover time there; the blocking drain charges the
    /// whole exposed window per engine.  A cutover voided by an engine
    /// crash stays counted — the fault plane books the downtime that
    /// replaced it — so under heavy chaos this can exceed the time
    /// engines actually sat suspended.
    pub engine_offline_s: f64,
    /// Dissemination wall-clock: publish begin → last live engine
    /// current, summed over publishes.
    pub dissemination_s: f64,
    /// Queue delay weight pulls accumulated on the fan-out (or shared
    /// KV) link.
    pub link_queue_delay_s: f64,
    /// Weight transfers admitted / of those, queued behind a busy slot.
    pub transfers: u64,
    pub queued_transfers: u64,
    /// Per-engine version lag sampled across live engines at every
    /// train start (versions behind the trainer).
    pub lag_samples: u64,
    pub lag_sum: u64,
    pub lag_max: u64,
    /// Elastic warm-up pulls routed over the contended link (one per
    /// provisioned engine; real bucketized traffic, not the analytic
    /// `provision_delay_s`).
    pub warmup_pulls: u64,
    /// Fault-recovery weight reloads routed over the contended link
    /// (one per auto-recovered engine crash): the analytic
    /// `engine_recovery_s` covers only the node reboot + engine
    /// relaunch; the reload itself is real bucketized traffic queueing
    /// against refreshes and warm-ups.  Booked into the generic
    /// transfer/bucket counters, never into `engine_offline_s` (that
    /// stays the cutover cost the bubble plane cross-checks against).
    pub recovery_pulls: u64,
    /// Closed-loop strategy adjustments ([`AdaptiveSync`]): iterations
    /// that raised / lowered the refresh concurrency.
    pub adapt_raises: u64,
    pub adapt_drops: u64,
    /// The Table 4 bucket decomposition (see [`BucketBreakdown`]).
    pub buckets: BucketBreakdown,
}

impl WeightSyncReport {
    /// Mean per-engine version lag at train starts.
    pub fn mean_lag(&self) -> f64 {
        if self.lag_samples == 0 {
            return 0.0;
        }
        self.lag_sum as f64 / self.lag_samples as f64
    }

    /// Fraction of dissemination wall-clock hidden from the trainer
    /// (0 = fully exposed fleet drain, 1 = fully overlapped).
    pub fn overlap_ratio(&self) -> f64 {
        if self.dissemination_s <= 0.0 {
            return 0.0;
        }
        (1.0 - self.exposed_stall_s / self.dissemination_s).clamp(0.0, 1.0)
    }

    /// Engine-seconds the weight plane *committed* to suspensions —
    /// the floor for the telemetry plane's
    /// [`BubbleReport::awaiting_weights_s`](crate::obs::BubbleReport)
    /// attribution.  Under event strategies in a fault-free run the two
    /// are equal (every suspension is a cutover bracketed by the bubble
    /// accountant); the blocking fleet drain books the exposed window
    /// here per engine while the measured bubble can only be larger if
    /// faults stretch a drain.
    pub fn min_awaiting_weights_s(&self) -> f64 {
        self.engine_offline_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn fleet<'a>(
        target: u64,
        versions: &'a [Version],
        down: &'a [bool],
        syncing: &'a [bool],
        alpha: u64,
    ) -> FleetView<'a> {
        FleetView {
            target: Version(target),
            engine_version: versions,
            engine_down: down,
            syncing,
            alpha,
        }
    }

    #[test]
    fn kind_round_trip_and_defaults() {
        for kind in [
            SyncStrategyKind::BlockingBroadcast,
            SyncStrategyKind::RollingSubset { k: 2 },
            SyncStrategyKind::LazyPull,
            SyncStrategyKind::OverlappedBroadcast { chunks: 8 },
            SyncStrategyKind::Adaptive,
        ] {
            assert_eq!(kind.make().name(), kind.name());
        }
        assert_eq!(SyncStrategyKind::default(), SyncStrategyKind::BlockingBroadcast);
        let w = WeightsScenario::default();
        assert!(w.validate().is_ok());
        assert!(w.strategy.make().blocking());
        assert!(!w.share_kv_link);
    }

    #[test]
    fn adaptive_defaults_match_calibration() {
        // Pinned to the `calib_wsync` sweep's chosen cell (see the doc
        // on `AdaptiveSync::new`).  Changing these is a re-calibration:
        // re-run the bench and update the rationale alongside.
        let s = AdaptiveSync::new();
        assert_eq!(s.rollout_bound_ratio, 1.0);
        assert_eq!(s.cooldown_steps, 1);
        assert_eq!((s.k(), s.min_k, s.max_k), (1, 1, 64));
    }

    #[test]
    fn make_strategy_honors_adaptive_template() {
        let mut w = WeightsScenario::with_strategy(SyncStrategyKind::Adaptive);
        w.adaptive.rollout_bound_ratio = 2.0;
        w.adaptive.cooldown_steps = 3;
        let mut s = w.make_strategy();
        assert_eq!(s.name(), "adaptive");
        // Push k above min via the α-bound raise, then the tuned
        // cooldown (3, not the default 1) holds the next three
        // iterations even under an absurd rollout-bound signal.
        assert_eq!(s.observe_iteration(0.0, 80.0, 4, 4), AdaptDecision::Raise);
        for _ in 0..3 {
            assert_eq!(s.observe_iteration(1e9, 80.0, 0, 4), AdaptDecision::Hold);
        }
        // Cooldown drained and k > min: wait 1.5× train is NOT
        // rollout-bound at the tuned ratio 2.0 (the default 1.0 would
        // answer Lower here).
        assert_eq!(s.observe_iteration(120.0, 80.0, 0, 4), AdaptDecision::Hold);
        // Non-adaptive strategies ignore the template.
        let w = WeightsScenario::with_strategy(SyncStrategyKind::RollingSubset { k: 2 });
        assert_eq!(w.make_strategy().name(), "rolling");
    }

    #[test]
    fn validation_rejects_degenerate_knobs() {
        let mut w = WeightsScenario::with_strategy(SyncStrategyKind::RollingSubset { k: 0 });
        assert!(w.validate().is_err());
        w = WeightsScenario::with_strategy(SyncStrategyKind::OverlappedBroadcast { chunks: 0 });
        assert!(w.validate().is_err());
        w = WeightsScenario::default();
        w.fanout_slots = 0;
        assert!(w.validate().is_err());
    }

    #[test]
    fn rolling_waves_respect_k_and_pick_stalest_first() {
        let versions = [Version(2), Version(0), Version(1), Version(2), Version(1)];
        let down = [false; 5];
        let syncing = [false; 5];
        let mut s = RollingSubset::new(2);
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![1, 2], "stalest engines first, k bounded");
        // One slot already in flight: only one more starts.
        let syncing = [false, true, false, false, false];
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![2]);
        // k saturated: nothing starts.
        let syncing = [false, true, true, false, false];
        assert!(s.next_wave(&fleet(2, &versions, &down, &syncing, 1)).is_empty());
    }

    #[test]
    fn rolling_skips_down_and_current_engines() {
        let versions = [Version(0), Version(0), Version(2)];
        let down = [false, true, false];
        let syncing = [false; 3];
        let mut s = RollingSubset::new(4);
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![0], "down engine 1 and current engine 2 skipped");
    }

    #[test]
    fn lazy_only_forces_alpha_violations() {
        // Target 3, α=2: engine at 0 (lag 3) and 1 (lag 2) are forced;
        // engine at 2 (lag 1) stays lazy.
        let versions = [Version(0), Version(1), Version(2)];
        let down = [false; 3];
        let syncing = [false; 3];
        let mut s = LazyPull;
        let wave = s.next_wave(&fleet(3, &versions, &down, &syncing, 2));
        assert_eq!(wave, vec![0, 1]);
        assert!(s.pull_on_idle());
        // α=0 is clamped to 1: any lag forces.
        let wave = s.next_wave(&fleet(3, &versions, &down, &syncing, 0));
        assert_eq!(wave, vec![0, 1, 2]);
    }

    #[test]
    fn overlapped_streams_everyone_at_once() {
        let versions = [Version(1), Version(1), Version(2)];
        let down = [false; 3];
        let syncing = [false; 3];
        let mut s = OverlappedBroadcast::new(8);
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![0, 1]);
        assert_eq!(s.chunks(), 8);
    }

    #[test]
    fn adaptive_forces_alpha_and_bounds_voluntary_concurrency() {
        // Target 3, α=2: engines at lag ≥ 2 are forced regardless of k;
        // with k=1 only one voluntary (lag-1) engine joins them.
        let versions = [Version(0), Version(1), Version(2), Version(2)];
        let down = [false; 4];
        let syncing = [false; 4];
        let mut s = AdaptiveSync::new();
        assert_eq!(s.k(), 1);
        let wave = s.next_wave(&fleet(3, &versions, &down, &syncing, 2));
        assert_eq!(wave, vec![0, 1, 2], "0 and 1 forced, one voluntary");
        // A sync already in flight uses up the voluntary budget: only
        // the forced engines start.
        let syncing = [false, false, true, false];
        let wave = s.next_wave(&fleet(3, &versions, &down, &syncing, 2));
        assert_eq!(wave, vec![0, 1], "forced only; k budget spent");
        assert!(s.pull_on_idle());
    }

    #[test]
    fn adaptive_observe_tunes_k_with_cooldown() {
        let mut s = AdaptiveSync::new();
        s.cooldown_steps = 1;
        // Lag at the α bound: raise.
        assert_eq!(s.observe_iteration(0.0, 80.0, 1, 1), AdaptDecision::Raise);
        assert_eq!(s.k(), 2);
        // Cooldown holds even under the same pressure.
        assert_eq!(s.observe_iteration(0.0, 80.0, 2, 1), AdaptDecision::Hold);
        assert_eq!(s.observe_iteration(0.0, 80.0, 2, 1), AdaptDecision::Raise);
        assert_eq!(s.k(), 3);
        // Rollout-bound with lag in hand: lower.
        assert_eq!(s.observe_iteration(300.0, 80.0, 0, 1), AdaptDecision::Hold);
        assert_eq!(s.observe_iteration(300.0, 80.0, 0, 1), AdaptDecision::Lower);
        assert_eq!(s.k(), 2);
        // Balanced: hold, and k never leaves [min_k, max_k].
        assert_eq!(s.observe_iteration(10.0, 80.0, 0, 1), AdaptDecision::Hold);
        let mut floor = AdaptiveSync::new();
        floor.cooldown_steps = 0;
        assert_eq!(floor.observe_iteration(300.0, 80.0, 0, 1), AdaptDecision::Hold);
        assert_eq!(floor.k(), 1, "never below min_k");
    }

    #[test]
    fn bucketized_pull_sequences_buckets_and_conserves_bytes() {
        let mc = MooncakeConfig::default();
        let mut link = SharedLink::new(MOONCAKE_FANOUT.clone(), 2);
        let bytes = 3.5 * GB;
        let out = bucketized_pull(&mut link, &mc, 10.0, bytes, |_| f64::NEG_INFINITY);
        assert_eq!(out.buckets.len(), 4, "3 full buckets + the tail");
        let sum: f64 = out.buckets.iter().map(|b| b.bytes).sum();
        assert!((sum - bytes).abs() < 1e-6, "bytes conserved: {sum}");
        // Sequenced: bucket i+1 starts only after bucket i landed, even
        // with two free slots.
        for w in out.buckets.windows(2) {
            assert!(w[1].grant.start_s >= w[0].grant.done_s - 1e-9);
        }
        // Pure transfer time matches the store's accumulated pull up to
        // the link's per-bucket delivery latency.
        let store = crate::mooncake::MooncakeStore::default();
        let extra = out.buckets.len() as f64 * MOONCAKE_FANOUT.latency_s;
        assert!(
            (out.transfer_s - store.acc_pull_time(bytes) - extra).abs() < 1e-9,
            "{} vs {}",
            out.transfer_s,
            store.acc_pull_time(bytes)
        );
        assert!((out.done_s - 10.0 - out.transfer_s).abs() < 1e-9, "uncontended serial pull");
        assert_eq!(out.queued, 0);
    }

    #[test]
    fn bucketized_pull_gates_on_the_push_pipeline() {
        let mc = MooncakeConfig::default();
        let mut link = SharedLink::new(MOONCAKE_FANOUT.clone(), 4);
        let bytes = 4.0 * GB;
        // Push slower than pull (the Table 4 regime): bucket i lands at
        // i+1 push intervals; the pull trails it bucket-by-bucket and
        // finishes ≈ one bucket-pull after the push.
        let per_bucket_push = mc.bucket_bytes / mc.push_bytes_per_s;
        let gated = bucketized_pull(&mut link, &mc, 0.0, bytes, |i| {
            (i + 1) as f64 * per_bucket_push
        });
        assert!(gated.push_gate_s > 0.0, "pull must trail the slower push");
        let n = mc.bucket_count(bytes) as f64;
        let last_push = n * per_bucket_push;
        assert!(gated.done_s > last_push, "{} vs {last_push}", gated.done_s);
        assert!(
            gated.done_s < last_push + 2.0 * gated.transfer_s / n + 1.0,
            "only the final bucket's pull sticks out: {} vs push end {last_push}",
            gated.done_s
        );
        // An ungated pull of the same bytes is strictly faster.
        let mut link2 = SharedLink::new(MOONCAKE_FANOUT.clone(), 4);
        let free = bucketized_pull(&mut link2, &mc, 0.0, bytes, |_| f64::NEG_INFINITY);
        assert!(free.done_s < gated.done_s);
        assert_eq!(free.push_gate_s, 0.0);
    }

    #[test]
    fn bucketized_pull_empty_payload_is_free() {
        let mc = MooncakeConfig::default();
        let mut link = SharedLink::new(MOONCAKE_FANOUT.clone(), 1);
        let out = bucketized_pull(&mut link, &mc, 5.0, 0.0, |_| 100.0);
        assert_eq!(out.done_s, 5.0);
        assert_eq!(out.transfer_s, 0.0);
        assert!(out.buckets.is_empty());
        assert_eq!(link.stats.transfers, 0, "nothing touched the link");
    }

    #[test]
    fn analytic_fleet_sync_scales_with_fleet_and_model() {
        let w = WeightsScenario::default();
        let small = w.analytic_fleet_sync_s(&QWEN3_8B, 2);
        let large = w.analytic_fleet_sync_s(&QWEN3_8B, 8);
        assert!(large > small, "{large} vs {small}");
        let mut wide = WeightsScenario::default();
        wide.fanout_slots = 8;
        assert!(
            wide.analytic_fleet_sync_s(&QWEN3_8B, 8) < large,
            "more fan-out slots must cut the balanced makespan"
        );
        // Bucket granularity feeds the analytic term too: finer buckets
        // mean more per-bucket session costs on the same bytes.
        let mut fine = WeightsScenario::default();
        fine.mooncake.bucket_bytes /= 4.0;
        assert!(
            fine.analytic_fleet_sync_s(&QWEN3_8B, 4) > w.analytic_fleet_sync_s(&QWEN3_8B, 4),
            "quartering the bucket must raise the bucketized makespan"
        );
    }

    #[test]
    fn fanout_link_pricing_tracks_the_bucket_model() {
        // Session cost and bandwidth on the fan-out link always come
        // from the bucket model: re-calibrating one side cannot
        // silently desynchronize the DES link from the analytic store.
        let mut w = WeightsScenario::default();
        assert_eq!(w.fanout_link().setup_s, w.mooncake.per_bucket_latency_s);
        assert_eq!(
            w.fanout_link().effective_bytes_per_s,
            w.mooncake.pull_bytes_per_s
        );
        w.mooncake.per_bucket_latency_s = 0.05;
        w.mooncake.pull_bytes_per_s = 3.0 * GB;
        let derived = w.fanout_link();
        assert_eq!(derived.setup_s, 0.05);
        assert_eq!(derived.effective_bytes_per_s, 3.0 * GB);
        // Delivery latency stays the configured link's.
        assert_eq!(derived.latency_s, w.link.latency_s);
    }

    #[test]
    fn validation_rejects_degenerate_bucket_model() {
        let mut w = WeightsScenario::default();
        w.mooncake.bucket_bytes = 0.0;
        assert!(w.validate().is_err());
        w.mooncake.bucket_bytes = f64::INFINITY;
        assert!(w.validate().is_err());
    }

    #[test]
    fn bucket_breakdown_means() {
        let mut b = BucketBreakdown::default();
        assert_eq!(b.mean_pull_s(), 0.0);
        assert_eq!(b.mean_exposed_s(), 0.0);
        assert_eq!(b.mean_queue_delay_s(), 0.0);
        b.engine_pulls = 4;
        b.acc_pull_s = 28.0;
        b.queue_delay_s = 2.0;
        b.cutovers = 2;
        b.exposed_s = 5.0;
        assert!((b.mean_pull_s() - 7.0).abs() < 1e-12);
        assert!((b.mean_exposed_s() - 2.5).abs() < 1e-12);
        assert!((b.mean_queue_delay_s() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn report_summaries() {
        let mut r = WeightSyncReport::default();
        assert_eq!(r.mean_lag(), 0.0);
        assert_eq!(r.overlap_ratio(), 0.0);
        r.lag_samples = 4;
        r.lag_sum = 6;
        r.lag_max = 3;
        assert!((r.mean_lag() - 1.5).abs() < 1e-12);
        r.dissemination_s = 10.0;
        r.exposed_stall_s = 2.5;
        assert!((r.overlap_ratio() - 0.75).abs() < 1e-12);
        // Fully exposed fleet drain: ratio 0.
        r.exposed_stall_s = 10.0;
        assert_eq!(r.overlap_ratio(), 0.0);
    }
}
