//! Weight-dissemination plane: event-driven, per-engine weight sync
//! over the contended network (§6.2/§6.3, Table 4).
//!
//! The pre-refactor driver modeled weight sync as a *fleet-wide stall*:
//! drain every engine, charge one analytic
//! [`MooncakeStore::sync`](crate::mooncake::MooncakeStore::sync)
//! scalar, bump a single global [`Version`].  Rolling updates, lazy
//! pulls and transfer/decode overlap — the regimes StreamRL's
//! disaggregated stream generation and rollout-as-a-service systems
//! exploit — were unrepresentable.  This module promotes dissemination
//! to a first-class subsystem:
//!
//! * every engine carries its **own** weight [`Version`]; the fleet is
//!   allowed to disagree, and the α-staleness window becomes a real
//!   scheduling trade-off instead of bookkeeping;
//! * a pluggable [`SyncStrategy`] decides *which engines refresh when*:
//!
//! | strategy | semantics | trainer stall | engine stall |
//! |---|---|---|---|
//! | [`BlockingBroadcast`] | the legacy fleet drain: suspend everything, one analytic store sync, global flip | exposed + KV recompute | whole fleet, whole window |
//! | [`RollingSubset`] | sync `k` engines at a time; the rest keep decoding at the old version | none | per-engine pull + cutover, `k` at a time |
//! | [`LazyPull`] | each engine pulls at its next idle gap, forced once it would fall α behind | none | per-engine, deferred to idle |
//! | [`OverlappedBroadcast`] | chunked push streams behind decode; only the last chunk's GPU load + KV recompute is exposed per engine | none | cutover only |
//!
//! * weight traffic flows over the [`net`](crate::net) plane: every
//!   per-engine pull is a transfer on a trainer-side
//!   [`SharedLink`](crate::net::SharedLink), so concurrent pulls
//!   *contend* for fan-out bandwidth (and, with
//!   [`WeightsScenario::share_kv_link`], with PD KV traffic on the same
//!   link);
//! * a [`WeightSyncReport`] surfaces the exposed stall, overlap ratio,
//!   per-engine version lag and link queue delay on
//!   [`ScenarioResult`](crate::sim::ScenarioResult).
//!
//! The driver core (see [`crate::sim::driver::core`]) owns the event
//! loop; this module owns the *decisions* (strategy) and the *knobs*
//! (scenario + report).  `BlockingBroadcast` keeps the exact
//! pre-refactor code path so the fleet-drain numbers are reproduced by
//! construction (pinned by `blocking_broadcast_is_the_legacy_fleet_drain`
//! in the driver core's tests).

use crate::llm::LlmSpec;
use crate::net::{balanced_makespan, Link};
use crate::rl::Version;

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Store→engine fan-out path for per-engine weight pulls: the Mooncake
/// pull side of Table 4 (aggregate ≈2.1 GB/s across the inference
/// fleet), modeled as one contended link with a small per-pull session
/// cost.
pub static MOONCAKE_FANOUT: Link = Link {
    name: "mooncake-fanout",
    raw_gbps: 200.0,
    effective_bytes_per_s: 2.1 * GB,
    setup_s: 0.05,
    latency_s: 0.002,
};

/// Declarative strategy selector carried by scenario configs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SyncStrategyKind {
    /// Today's drain-everything semantics (the baseline): suspend the
    /// fleet, one analytic store sync, global version bump.
    #[default]
    BlockingBroadcast,
    /// Sync `k` engines at a time while the rest keep decoding.
    RollingSubset { k: usize },
    /// Each engine pulls at its next idle gap, bounded by α.
    LazyPull,
    /// Chunked push pipelined with decode; `chunks` pipeline stages,
    /// only the last chunk's GPU load is exposed per engine.
    OverlappedBroadcast { chunks: usize },
}

impl SyncStrategyKind {
    pub fn name(self) -> &'static str {
        match self {
            SyncStrategyKind::BlockingBroadcast => "blocking",
            SyncStrategyKind::RollingSubset { .. } => "rolling",
            SyncStrategyKind::LazyPull => "lazy",
            SyncStrategyKind::OverlappedBroadcast { .. } => "overlapped",
        }
    }

    /// Instantiate the strategy this selector names.
    pub fn make(self) -> Box<dyn SyncStrategy> {
        match self {
            SyncStrategyKind::BlockingBroadcast => Box::new(BlockingBroadcast),
            SyncStrategyKind::RollingSubset { k } => Box::new(RollingSubset::new(k)),
            SyncStrategyKind::LazyPull => Box::new(LazyPull),
            SyncStrategyKind::OverlappedBroadcast { chunks } => {
                Box::new(OverlappedBroadcast::new(chunks))
            }
        }
    }
}

/// The `weights` knob of a [`Scenario`](crate::sim::Scenario).
#[derive(Clone, Debug)]
pub struct WeightsScenario {
    pub strategy: SyncStrategyKind,
    /// Trainer-side fan-out link (store → engines) the per-engine
    /// pulls ride.
    pub link: Link,
    /// Concurrent transfer slots on the fan-out link; pulls beyond
    /// this queue FIFO ([`SharedLink`](crate::net::SharedLink)).
    pub fanout_slots: usize,
    /// Route weight pulls over the PD deployment's KV link instead of
    /// the dedicated fan-out link, so weight and KV traffic contend for
    /// the same slots.  Ignored when the scenario has no disaggregated
    /// PD deployment.
    pub share_kv_link: bool,
}

impl Default for WeightsScenario {
    fn default() -> Self {
        WeightsScenario {
            strategy: SyncStrategyKind::BlockingBroadcast,
            link: MOONCAKE_FANOUT.clone(),
            fanout_slots: 2,
            share_kv_link: false,
        }
    }
}

impl WeightsScenario {
    /// Convenience constructor: `strategy` over the default fan-out.
    pub fn with_strategy(strategy: SyncStrategyKind) -> Self {
        WeightsScenario {
            strategy,
            ..WeightsScenario::default()
        }
    }

    /// Analytic fleet-blocking dissemination time: the balanced
    /// fair-share makespan of one full-weight pull per engine over the
    /// fan-out link, plus the in-GPU weight load at the suspend point.
    /// This is the term the *synchronous* baseline pays when a
    /// non-legacy weight plane is configured (a barrier pipeline cannot
    /// exploit rolling updates, but it must pay the same transfer cost
    /// model so sync-vs-async comparisons stay fair — see
    /// [`crate::sim::sync_driver`]).
    pub fn analytic_fleet_sync_s(&self, model: &LlmSpec, n_engines: usize) -> f64 {
        let bytes = model.weight_bytes();
        let per_engine: Vec<f64> = vec![bytes; n_engines.max(1)];
        balanced_makespan(&self.link, self.fanout_slots, &per_engine)
            + bytes / crate::mooncake::MooncakeConfig::default().gpu_load_bytes_per_s
    }

    /// Basic sanity of the knob (mirrors the config-file validation).
    pub fn validate(&self) -> Result<(), String> {
        if self.fanout_slots == 0 {
            return Err("weights.fanout_slots must be ≥ 1".to_string());
        }
        match self.strategy {
            SyncStrategyKind::RollingSubset { k } if k == 0 => {
                Err("weights.rolling k must be ≥ 1".to_string())
            }
            SyncStrategyKind::OverlappedBroadcast { chunks } if chunks == 0 => {
                Err("weights.overlapped chunks must be ≥ 1".to_string())
            }
            _ => Ok(()),
        }
    }
}

/// Fleet snapshot handed to a strategy decision.  Indices are engine
/// indices in the driver's fleet order.
pub struct FleetView<'a> {
    /// The published version dissemination is converging to.
    pub target: Version,
    /// Each engine's current weight version.
    pub engine_version: &'a [Version],
    /// Down (crashed/retired) engines never sync; they reload current
    /// weights as part of recovery/provisioning instead.
    pub engine_down: &'a [bool],
    /// Engines already committed to an in-flight sync.
    pub syncing: &'a [bool],
    /// The scenario's α staleness bound.
    pub alpha: u64,
}

impl<'a> FleetView<'a> {
    /// Engines eligible to start a sync: live, idle (sync-wise) and
    /// behind the target, stalest first (ties break low index).
    pub fn behind(&self) -> Vec<usize> {
        let mut v: Vec<usize> = (0..self.engine_version.len())
            .filter(|&i| {
                !self.engine_down[i] && !self.syncing[i] && self.engine_version[i] < self.target
            })
            .collect();
        v.sort_by_key(|&i| (self.engine_version[i], i));
        v
    }

    /// Engines currently committed to a sync.
    pub fn syncing_count(&self) -> usize {
        self.syncing.iter().filter(|s| **s).count()
    }

    /// How many versions engine `i` lags the target.
    pub fn lag(&self, i: usize) -> u64 {
        self.target.0.saturating_sub(self.engine_version[i].0)
    }
}

/// A weight-dissemination discipline: decides which engines refresh
/// when, over the driver core's event loop.
///
/// The core consults the strategy at three points: when a freshly
/// trained version begins disseminating, after every per-engine sync
/// completion (both via [`SyncStrategy::next_wave`]), and — for
/// idle-pull strategies — whenever an engine finishes a step
/// ([`SyncStrategy::pull_on_idle`]).  Strategies never touch the event
/// queue themselves; they return engine sets and the core turns them
/// into transfer + cutover events, which keeps every strategy
/// composable with faults, elasticity and PD dispatch.
pub trait SyncStrategy {
    fn name(&self) -> &'static str;

    /// The legacy barrier: drain the whole fleet, one analytic store
    /// sync, global version flip.  When true the core keeps the exact
    /// pre-refactor suspend/drain path and none of the event-driven
    /// hooks fire.
    fn blocking(&self) -> bool {
        false
    }

    /// Engines to start syncing now.  Called when dissemination of a
    /// new version begins and again after every per-engine completion;
    /// eager strategies return the next wave, lazy ones return only
    /// engines the α bound forces.
    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize>;

    /// Pull at each engine's next idle gap (the core offers every
    /// engine a sync opportunity at its step boundaries).
    fn pull_on_idle(&self) -> bool {
        false
    }

    /// Stream the transfer *behind* ongoing decode and suspend the
    /// engine only for the cutover (last chunk's GPU load + KV
    /// recompute).
    fn overlapped(&self) -> bool {
        false
    }

    /// Pipeline depth of a chunked push (1 = whole-weights swap).
    fn chunks(&self) -> usize {
        1
    }
}

/// The legacy fleet drain (pre-refactor semantics, kept as baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct BlockingBroadcast;

impl SyncStrategy for BlockingBroadcast {
    fn name(&self) -> &'static str {
        "blocking"
    }

    fn blocking(&self) -> bool {
        true
    }

    fn next_wave(&mut self, _fleet: &FleetView) -> Vec<usize> {
        Vec::new() // the core's legacy drain path handles everything
    }
}

/// Sync `k` engines at a time while the rest keep decoding at the old
/// version: the production rolling-update discipline.
#[derive(Clone, Copy, Debug)]
pub struct RollingSubset {
    pub k: usize,
}

impl RollingSubset {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "rolling subset needs k ≥ 1");
        RollingSubset { k }
    }
}

impl SyncStrategy for RollingSubset {
    fn name(&self) -> &'static str {
        "rolling"
    }

    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize> {
        let in_flight = fleet.syncing_count();
        if in_flight >= self.k {
            return Vec::new();
        }
        fleet.behind().into_iter().take(self.k - in_flight).collect()
    }
}

/// Each engine pulls from the store at its next idle gap; an engine
/// that would fall α behind the published version is forced to pull at
/// its next step boundary instead (the α bound keeps lazy laziness from
/// generating turns the buffer would evict anyway).
#[derive(Clone, Copy, Debug, Default)]
pub struct LazyPull;

impl SyncStrategy for LazyPull {
    fn name(&self) -> &'static str {
        "lazy"
    }

    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize> {
        // Only α-forced engines; voluntary pulls happen at idle gaps
        // through the `pull_on_idle` hook.
        fleet
            .behind()
            .into_iter()
            .filter(|&i| fleet.lag(i) >= fleet.alpha.max(1))
            .collect()
    }

    fn pull_on_idle(&self) -> bool {
        true
    }
}

/// Chunked/layer-wise push pipelined with decode: the transfer streams
/// behind ongoing generation and only the cutover (last chunk's GPU
/// load + KV recompute) suspends the engine.
#[derive(Clone, Copy, Debug)]
pub struct OverlappedBroadcast {
    pub chunks: usize,
}

impl OverlappedBroadcast {
    pub fn new(chunks: usize) -> Self {
        assert!(chunks > 0, "overlapped broadcast needs ≥ 1 chunk");
        OverlappedBroadcast { chunks }
    }
}

impl SyncStrategy for OverlappedBroadcast {
    fn name(&self) -> &'static str {
        "overlapped"
    }

    fn next_wave(&mut self, fleet: &FleetView) -> Vec<usize> {
        fleet.behind() // everyone streams concurrently (and contends)
    }

    fn overlapped(&self) -> bool {
        true
    }

    fn chunks(&self) -> usize {
        self.chunks
    }
}

/// Dissemination activity over one scenario run, surfaced as
/// [`ScenarioResult::weights`](crate::sim::ScenarioResult::weights).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WeightSyncReport {
    /// Trained versions whose dissemination began.
    pub publishes: u64,
    /// Per-engine sync completions (blocking: live fleet size per
    /// publish).
    pub engine_syncs: u64,
    /// Trainer-visible stall: wall-clock the training pipeline spent
    /// blocked on weight sync (blocking: exposed store sync + KV
    /// recompute per publish; event strategies: none — the fleet
    /// converges while training proceeds).
    pub exposed_stall_s: f64,
    /// Engine-seconds *committed* to weight transfer + cutover,
    /// charged when each sync is scheduled (the capacity the fleet
    /// gave up to dissemination).  A sync voided by an engine crash
    /// stays counted — the fault plane books the downtime that
    /// replaced it — so under heavy chaos this can exceed the time
    /// engines actually sat suspended.
    pub engine_offline_s: f64,
    /// Dissemination wall-clock: publish begin → last live engine
    /// current, summed over publishes.
    pub dissemination_s: f64,
    /// Queue delay weight pulls accumulated on the fan-out (or shared
    /// KV) link.
    pub link_queue_delay_s: f64,
    /// Weight transfers admitted / of those, queued behind a busy slot.
    pub transfers: u64,
    pub queued_transfers: u64,
    /// Per-engine version lag sampled across live engines at every
    /// train start (versions behind the trainer).
    pub lag_samples: u64,
    pub lag_sum: u64,
    pub lag_max: u64,
}

impl WeightSyncReport {
    /// Mean per-engine version lag at train starts.
    pub fn mean_lag(&self) -> f64 {
        if self.lag_samples == 0 {
            return 0.0;
        }
        self.lag_sum as f64 / self.lag_samples as f64
    }

    /// Fraction of dissemination wall-clock hidden from the trainer
    /// (0 = fully exposed fleet drain, 1 = fully overlapped).
    pub fn overlap_ratio(&self) -> f64 {
        if self.dissemination_s <= 0.0 {
            return 0.0;
        }
        (1.0 - self.exposed_stall_s / self.dissemination_s).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn fleet<'a>(
        target: u64,
        versions: &'a [Version],
        down: &'a [bool],
        syncing: &'a [bool],
        alpha: u64,
    ) -> FleetView<'a> {
        FleetView {
            target: Version(target),
            engine_version: versions,
            engine_down: down,
            syncing,
            alpha,
        }
    }

    #[test]
    fn kind_round_trip_and_defaults() {
        for kind in [
            SyncStrategyKind::BlockingBroadcast,
            SyncStrategyKind::RollingSubset { k: 2 },
            SyncStrategyKind::LazyPull,
            SyncStrategyKind::OverlappedBroadcast { chunks: 8 },
        ] {
            assert_eq!(kind.make().name(), kind.name());
        }
        assert_eq!(SyncStrategyKind::default(), SyncStrategyKind::BlockingBroadcast);
        let w = WeightsScenario::default();
        assert!(w.validate().is_ok());
        assert!(w.strategy.make().blocking());
        assert!(!w.share_kv_link);
    }

    #[test]
    fn validation_rejects_degenerate_knobs() {
        let mut w = WeightsScenario::with_strategy(SyncStrategyKind::RollingSubset { k: 0 });
        assert!(w.validate().is_err());
        w = WeightsScenario::with_strategy(SyncStrategyKind::OverlappedBroadcast { chunks: 0 });
        assert!(w.validate().is_err());
        w = WeightsScenario::default();
        w.fanout_slots = 0;
        assert!(w.validate().is_err());
    }

    #[test]
    fn rolling_waves_respect_k_and_pick_stalest_first() {
        let versions = [Version(2), Version(0), Version(1), Version(2), Version(1)];
        let down = [false; 5];
        let syncing = [false; 5];
        let mut s = RollingSubset::new(2);
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![1, 2], "stalest engines first, k bounded");
        // One slot already in flight: only one more starts.
        let syncing = [false, true, false, false, false];
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![2]);
        // k saturated: nothing starts.
        let syncing = [false, true, true, false, false];
        assert!(s.next_wave(&fleet(2, &versions, &down, &syncing, 1)).is_empty());
    }

    #[test]
    fn rolling_skips_down_and_current_engines() {
        let versions = [Version(0), Version(0), Version(2)];
        let down = [false, true, false];
        let syncing = [false; 3];
        let mut s = RollingSubset::new(4);
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![0], "down engine 1 and current engine 2 skipped");
    }

    #[test]
    fn lazy_only_forces_alpha_violations() {
        // Target 3, α=2: engine at 0 (lag 3) and 1 (lag 2) are forced;
        // engine at 2 (lag 1) stays lazy.
        let versions = [Version(0), Version(1), Version(2)];
        let down = [false; 3];
        let syncing = [false; 3];
        let mut s = LazyPull;
        let wave = s.next_wave(&fleet(3, &versions, &down, &syncing, 2));
        assert_eq!(wave, vec![0, 1]);
        assert!(s.pull_on_idle());
        // α=0 is clamped to 1: any lag forces.
        let wave = s.next_wave(&fleet(3, &versions, &down, &syncing, 0));
        assert_eq!(wave, vec![0, 1, 2]);
    }

    #[test]
    fn overlapped_streams_everyone_at_once() {
        let versions = [Version(1), Version(1), Version(2)];
        let down = [false; 3];
        let syncing = [false; 3];
        let mut s = OverlappedBroadcast::new(8);
        let wave = s.next_wave(&fleet(2, &versions, &down, &syncing, 1));
        assert_eq!(wave, vec![0, 1]);
        assert!(s.overlapped());
        assert_eq!(s.chunks(), 8);
    }

    #[test]
    fn analytic_fleet_sync_scales_with_fleet_and_model() {
        let w = WeightsScenario::default();
        let small = w.analytic_fleet_sync_s(&QWEN3_8B, 2);
        let large = w.analytic_fleet_sync_s(&QWEN3_8B, 8);
        assert!(large > small, "{large} vs {small}");
        let mut wide = WeightsScenario::default();
        wide.fanout_slots = 8;
        assert!(
            wide.analytic_fleet_sync_s(&QWEN3_8B, 8) < large,
            "more fan-out slots must cut the balanced makespan"
        );
    }

    #[test]
    fn report_summaries() {
        let mut r = WeightSyncReport::default();
        assert_eq!(r.mean_lag(), 0.0);
        assert_eq!(r.overlap_ratio(), 0.0);
        r.lag_samples = 4;
        r.lag_sum = 6;
        r.lag_max = 3;
        assert!((r.mean_lag() - 1.5).abs() < 1e-12);
        r.dissemination_s = 10.0;
        r.exposed_stall_s = 2.5;
        assert!((r.overlap_ratio() - 0.75).abs() < 1e-12);
        // Fully exposed fleet drain: ratio 0.
        r.exposed_stall_s = 10.0;
        assert_eq!(r.overlap_ratio(), 0.0);
    }
}
