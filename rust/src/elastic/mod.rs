//! Elasticity plane: autoscaling the generation pool (§5.2, §8).
//!
//! Disaggregation makes the ActorGen fleet *resizable*: StreamRL
//! (PAPERS.md) argues elasticity of the generation pool is a
//! first-class requirement for disaggregated RL, and the paper's
//! production run continuously rebalances pools as jobs come and go.
//! This module supplies the controller:
//!
//! * [`ElasticPolicy`] — declarative scaling rules for one GPU-class
//!   pool: bounds, step size, cooldown, and the warm-up cost model of a
//!   freshly provisioned engine (sandbox boot reusing the
//!   [`crate::serverless`] cold-start figure, plus the Mooncake weight
//!   pull from [`crate::mooncake`]);
//! * [`AutoScaler`] — watches the per-iteration
//!   [`IterationCost`](crate::coordinator::IterationCost) the drivers
//!   measure and decides: `get_batch` wait ≫ train time means the
//!   pipeline is rollout-bound (grow the pool); wait ≈ 0 means
//!   generation capacity is idle against the train step (shrink it).
//!
//! The DES drivers act on [`ScaleDecision`]s by binding/releasing
//! capacity through the [`crate::resource`] plane and provisioning
//! engines after the warm-up delay; `examples/chaos_train.rs` shows the
//! controller restoring throughput after a 25% generation-pool outage.
//! The environment pool scales in lock-step: its CpuSlot bindings track
//! the live generation fleet, so a scale-down returns real environment
//! capacity to the resource plane (see [`ElasticReport::env_slots_released`]).
//!
//! PD deployments get a *split* controller: [`PdAutoScaler`] watches
//! per-class bottleneck signals ([`PdSignals`]: prefill queue wait,
//! decode token backlog, KV-link queue delay) and resizes the prefill
//! and decode pools independently — a decode-bound run grows the
//! decode pool while the idle prefill pool shrinks, and a KV-bound
//! iteration holds both (no pool can fix a saturated link).

use crate::coordinator::IterationCost;
use crate::hw::GpuClass;
use crate::llm::LlmSpec;
use crate::mooncake::MooncakeStore;
use crate::serverless::ServerlessConfig;
use crate::sim::driver::pd::PdScenario;

/// Scaling rules for one generation pool.
///
/// # Writing your own scaling behaviour
///
/// The policy is declarative: tune the thresholds and hand it to an
/// [`AutoScaler`], which turns per-iteration costs into
/// [`ScaleDecision`]s.  A controller that grows aggressively but never
/// shrinks below four engines:
///
/// ```
/// use rollart::coordinator::IterationCost;
/// use rollart::elastic::{AutoScaler, ElasticPolicy, ScaleDecision};
/// use rollart::hw::GpuClass;
///
/// let mut policy = ElasticPolicy::new(GpuClass::H20, 2, 32);
/// policy.min_engines = 4;
/// policy.step_engines = 4;
/// policy.scale_up_wait_ratio = 0.5; // grow as soon as wait > train/2
/// policy.cooldown_steps = 0; // decide every iteration
/// let mut scaler = AutoScaler::new(policy);
///
/// // An iteration that waited 60 s on a 40 s train step is
/// // rollout-bound: the controller grows the pool.
/// let cost = IterationCost { get_batch_wait_s: 60.0, train_s: 40.0, ..Default::default() };
/// assert_eq!(scaler.observe(&cost, 8, 0), ScaleDecision::Up(4));
///
/// // An idle pipeline shrinks, but never below `min_engines`.
/// let idle = IterationCost { get_batch_wait_s: 0.0, train_s: 40.0, ..Default::default() };
/// assert_eq!(scaler.observe(&idle, 5, 0), ScaleDecision::Down(1));
/// assert_eq!(scaler.observe(&idle, 4, 0), ScaleDecision::Hold);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct ElasticPolicy {
    /// GPU class of the pool this policy resizes.
    pub class: GpuClass,
    /// Width of a provisioned engine (the model's rollout TP degree).
    pub gpus_per_engine: usize,
    /// Continuous-batching slot count of a provisioned engine.
    pub max_batch: usize,
    /// Never shrink the pool's live engines below this.
    pub min_engines: usize,
    /// Never grow the pool's live + provisioning engines above this.
    pub max_engines: usize,
    /// Engines added/retired per decision.
    pub step_engines: usize,
    /// Scale up when `get_batch` wait exceeds this multiple of the
    /// train time (rollout-bound).
    pub scale_up_wait_ratio: f64,
    /// Scale down when `get_batch` wait falls below this multiple of
    /// the train time (train-bound; generation capacity idles).
    pub scale_down_wait_ratio: f64,
    /// Iterations to hold after a decision before the next one (lets
    /// the pipeline re-reach steady state).
    pub cooldown_steps: usize,
    /// Engine boot time as a multiple of the serverless function
    /// cold start (an inference server boots a full runtime, not a
    /// sandboxed function).
    pub provision_boot_multiplier: f64,
}

impl ElasticPolicy {
    /// Sensible defaults for scaling a pool of `class` engines.
    pub fn new(class: GpuClass, gpus_per_engine: usize, max_batch: usize) -> Self {
        ElasticPolicy {
            class,
            gpus_per_engine,
            max_batch,
            min_engines: 1,
            max_engines: 64,
            step_engines: 2,
            scale_up_wait_ratio: 1.5,
            scale_down_wait_ratio: 0.25,
            cooldown_steps: 1,
            provision_boot_multiplier: 20.0,
        }
    }

    /// Runtime/sandbox boot portion of a provisioned engine's warm-up
    /// (serverless cold start × multiplier).  The event-driven drivers
    /// pay the *weight pull* separately, as real bucketized traffic on
    /// the contended fan-out link (see the driver core's
    /// `provision_engine`), so only the boot is analytic there.
    pub fn boot_delay_s(&self) -> f64 {
        ServerlessConfig::default().cold_start_s * self.provision_boot_multiplier
    }

    /// Fully analytic warm-up *floor* of one freshly provisioned
    /// engine: boot plus the default bucket model's accumulated weight
    /// pull for `model`.  Kept as a declarative reference only — the
    /// DES drivers route the pull over the real contended link with
    /// the *scenario's* bucket model and additionally pay the
    /// host→GPU load at the end, so their measured
    /// [`ElasticReport::provision_wait_s`] is strictly above
    /// `n × provision_delay_s`.
    pub fn provision_delay_s(&self, model: &LlmSpec) -> f64 {
        self.boot_delay_s() + MooncakeStore::default().acc_pull_time(model.weight_bytes())
    }
}

/// What the controller wants done to the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Provision this many engines (after the warm-up delay).
    Up(usize),
    /// Retire this many engines (drain + re-queue their work).
    Down(usize),
}

/// Accumulated controller activity over one scenario run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ElasticReport {
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Engines that finished provisioning and joined the fleet.
    pub engines_added: u64,
    /// Engines drained and retired by scale-down decisions.
    pub engines_retired: u64,
    /// Total warm-up time paid across provisioned engines.
    pub provision_wait_s: f64,
    /// Environment-pool CpuSlot bindings acquired through the resource
    /// plane (initial pool + elastic grows).
    pub env_slots_bound: u64,
    /// CpuSlot bindings released back on environment-pool scale-down.
    pub env_slots_released: u64,
    /// PD split controller: scale-up decisions on the *prefill* pool.
    pub prefill_scale_ups: u64,
    /// PD split controller: scale-down decisions on the prefill pool.
    pub prefill_scale_downs: u64,
    /// PD split controller: scale-up decisions on the *decode* pool.
    pub decode_scale_ups: u64,
    /// PD split controller: scale-down decisions on the decode pool.
    pub decode_scale_downs: u64,
    /// Iterations where the KV link — not either pool — was the
    /// bottleneck, so the split controller held both pools.
    pub kv_bound_holds: u64,
    /// Engines *repurposed* across GPU classes instead of a
    /// retire + provision pair: when one PD pool wants to grow while
    /// the other wants to shrink ([`PdAutoScaler::reconcile`]), the
    /// shrinking pool's engine is re-homed onto the growing pool's
    /// class, paying the warm-up weight pull but skipping the runtime
    /// boot a cold provision pays.
    pub repurposed: u64,
}

/// The feedback controller over [`IterationCost`] measurements.
#[derive(Clone, Debug)]
pub struct AutoScaler {
    pub policy: ElasticPolicy,
    cooldown: usize,
    pub report: ElasticReport,
}

impl AutoScaler {
    pub fn new(policy: ElasticPolicy) -> Self {
        assert!(policy.min_engines <= policy.max_engines);
        assert!(policy.step_engines > 0);
        assert!(policy.scale_down_wait_ratio < policy.scale_up_wait_ratio);
        AutoScaler {
            policy,
            cooldown: 0,
            report: ElasticReport::default(),
        }
    }

    /// Feed one iteration's measured cost; `live` is the pool's live
    /// engine count, `provisioning` the engines still warming up.
    pub fn observe(
        &mut self,
        cost: &IterationCost,
        live: usize,
        provisioning: usize,
    ) -> ScaleDecision {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return ScaleDecision::Hold;
        }
        let wait = cost.get_batch_wait_s;
        let train = cost.train_s.max(1e-9);
        if wait > self.policy.scale_up_wait_ratio * train {
            let headroom = self
                .policy
                .max_engines
                .saturating_sub(live + provisioning);
            let n = self.policy.step_engines.min(headroom);
            if n > 0 {
                self.cooldown = self.policy.cooldown_steps;
                self.report.scale_ups += 1;
                return ScaleDecision::Up(n);
            }
        } else if wait < self.policy.scale_down_wait_ratio * train && provisioning == 0 {
            let slack = live.saturating_sub(self.policy.min_engines);
            let n = self.policy.step_engines.min(slack);
            if n > 0 {
                self.cooldown = self.policy.cooldown_steps;
                self.report.scale_downs += 1;
                return ScaleDecision::Down(n);
            }
        }
        ScaleDecision::Hold
    }
}

// ---------------------------------------------------------------------
// Per-class PD elasticity
// ---------------------------------------------------------------------

/// Split-controller configuration for a PD deployment: one
/// [`ElasticPolicy`] per pool plus the bottleneck detectors that
/// decide *which* pool an iteration's rollout-boundness is charged to.
#[derive(Clone, Debug, PartialEq)]
pub struct PdElasticPolicy {
    /// Scaling rules for the prefill pool (compute-optimized class).
    pub prefill: ElasticPolicy,
    /// Scaling rules for the decode pool (bandwidth-optimized class).
    pub decode: ElasticPolicy,
    /// The prefill pool counts as the bottleneck when the iteration's
    /// summed Prefilling residency exceeds this many seconds per live
    /// prefill engine (trajectories queueing on prefill admission).
    pub prefill_wait_per_engine_s: f64,
    /// The decode pool counts as the bottleneck when outstanding
    /// decode tokens exceed this per live decode engine.
    pub decode_backlog_per_engine: f64,
    /// The KV *link* counts as the bottleneck when its accumulated
    /// queue delay this iteration exceeds this fraction of the train
    /// step — then neither pool is grown (a saturated link cannot be
    /// fixed by more engines on either side).
    pub kv_bound_ratio: f64,
}

impl PdElasticPolicy {
    /// Split controller sized to one [`PdScenario`]: each pool's
    /// policy provisions engines of that pool's class and node width.
    pub fn for_pd(pd: &PdScenario) -> Self {
        let mk = |class: GpuClass| {
            let mut p = ElasticPolicy::new(class, pd.gpus_per_node, pd.max_batch);
            p.min_engines = 1;
            p
        };
        PdElasticPolicy {
            prefill: mk(pd.prefill_class),
            decode: mk(pd.decode_class),
            // One engine's worth of queued prefill work per engine.
            // Calibrated by the `calib_pd` bench's threshold sweep
            // (10/30/90 s × 0.5/1/2× backlog on a 2P2D deployment):
            // 30 s sits in the stable middle — 10 s flaps the prefill
            // pool, 90 s never fires and leaves a starved pool unfixed.
            prefill_wait_per_engine_s: 30.0,
            // Roughly half an engine's continuous-batching capacity at
            // a long-decode working point (same sweep: 0.5× resizes on
            // ordinary bursts, 2× is effectively dead).
            decode_backlog_per_engine: pd.max_batch as f64 * 1024.0,
            kv_bound_ratio: 0.5,
        }
    }
}

/// Per-iteration bottleneck signals of a PD deployment, measured by
/// the driver core.
#[derive(Clone, Copy, Debug, Default)]
pub struct PdSignals {
    /// `get_batch` wait of the iteration (overall rollout-boundness).
    pub get_batch_wait_s: f64,
    /// Train time of the iteration (the wait ratios' denominator).
    pub train_s: f64,
    /// Summed Prefilling-phase residency this iteration (from
    /// [`LifecycleStats`](crate::sim::driver::LifecycleStats)), with
    /// the KV hop's end-to-end transfer time already subtracted by the
    /// measuring driver (the lifecycle books the hop under Prefilling;
    /// without the correction a congested link would masquerade as
    /// prefill-engine pressure): time trajectories spent queued or
    /// running in the prefill pool.
    pub prefill_wait_s: f64,
    /// Outstanding decode tokens on the decode pool's live engines at
    /// the iteration boundary (queued + unfinished decode budgets).
    pub decode_backlog_tokens: f64,
    /// KV-link queue delay accumulated this iteration (from
    /// [`SharedLinkStats`](crate::net::SharedLinkStats)).
    pub kv_queue_delay_s: f64,
}

/// The split feedback controller of a PD deployment: one
/// [`AutoScaler`] per pool (each with its own thresholds, cooldown
/// and bounds) over one [`PdSignals`] measurement.
///
/// Decision rule per iteration:
/// 1. rollout-bound **and** KV-bound → hold both pools
///    ([`ElasticReport::kv_bound_holds`]); both cooldowns also pause —
///    a KV-bound spell should not burn a pool's cooldown;
/// 2. rollout-bound but *neither* detector fired → hold both (the
///    bottleneck is outside the two pools; shrinking a starved
///    pipeline would make it worse);
/// 3. otherwise each pool is judged by its own [`AutoScaler`] fed a
///    gated cost: the iteration's `get_batch` wait *if its bottleneck
///    detector fired*, zero if not — so the bottleneck pool grows
///    while the idle pool is free to shrink in the same iteration,
///    and the threshold controller itself exists exactly once
///    ([`AutoScaler::observe`]).
#[derive(Clone, Debug)]
pub struct PdAutoScaler {
    pub policy: PdElasticPolicy,
    prefill: AutoScaler,
    decode: AutoScaler,
    pub report: ElasticReport,
}

impl PdAutoScaler {
    pub fn new(policy: PdElasticPolicy) -> Self {
        assert_ne!(
            policy.prefill.class, policy.decode.class,
            "PD pools are told apart by GPU class"
        );
        PdAutoScaler {
            prefill: AutoScaler::new(policy.prefill.clone()),
            decode: AutoScaler::new(policy.decode.clone()),
            policy,
            report: ElasticReport::default(),
        }
    }

    /// Feed one iteration's signals; returns the (prefill, decode)
    /// pool decisions and records them per class in the report.
    pub fn observe(
        &mut self,
        sig: &PdSignals,
        live_prefill: usize,
        live_decode: usize,
        provisioning_prefill: usize,
        provisioning_decode: usize,
    ) -> (ScaleDecision, ScaleDecision) {
        let train = sig.train_s.max(1e-9);
        let up_ratio = self
            .policy
            .prefill
            .scale_up_wait_ratio
            .min(self.policy.decode.scale_up_wait_ratio);
        let rollout_bound = sig.get_batch_wait_s > up_ratio * train;
        if rollout_bound && sig.kv_queue_delay_s > self.policy.kv_bound_ratio * train {
            self.report.kv_bound_holds += 1;
            return (ScaleDecision::Hold, ScaleDecision::Hold);
        }
        let prefill_bound = sig.prefill_wait_s
            > self.policy.prefill_wait_per_engine_s * live_prefill.max(1) as f64;
        let decode_bound = sig.decode_backlog_tokens
            > self.policy.decode_backlog_per_engine * live_decode.max(1) as f64;
        if rollout_bound && !prefill_bound && !decode_bound {
            // Rollout-bound but neither detector fired: the bottleneck
            // is elsewhere (env pool, reward path, mis-tuned
            // thresholds).  Zero-gating both pools here would shrink a
            // *starved* pipeline — hold instead.
            return (ScaleDecision::Hold, ScaleDecision::Hold);
        }
        // Gate the wait signal per class and let the single-pool
        // controller do the thresholding: the diagnosed bottleneck
        // pool sees the real wait (may grow), the other sees zero
        // (may shrink — intentional rebalancing toward the bottleneck).
        let gated = |bound: bool| IterationCost {
            get_batch_wait_s: if bound { sig.get_batch_wait_s } else { 0.0 },
            train_s: sig.train_s,
            ..IterationCost::default()
        };
        let dp = self
            .prefill
            .observe(&gated(prefill_bound), live_prefill, provisioning_prefill);
        let dd = self
            .decode
            .observe(&gated(decode_bound), live_decode, provisioning_decode);
        // The inner controllers already count their own decisions;
        // mirror them into the combined report (single counting
        // source) rather than tallying the decisions a second time.
        self.report.prefill_scale_ups = self.prefill.report.scale_ups;
        self.report.prefill_scale_downs = self.prefill.report.scale_downs;
        self.report.decode_scale_ups = self.decode.report.scale_ups;
        self.report.decode_scale_downs = self.decode.report.scale_downs;
        self.report.scale_ups = self.report.prefill_scale_ups + self.report.decode_scale_ups;
        self.report.scale_downs =
            self.report.prefill_scale_downs + self.report.decode_scale_downs;
        (dp, dd)
    }

    /// Reconcile one iteration's `(prefill, decode)` decisions into a
    /// rebalance plan: when one pool grows while the other shrinks (a
    /// *regime shift* — the workload's phase balance moved, not its
    /// total demand), matched Up/Down pairs become **repurposes**: the
    /// shrinking pool's engines are re-homed onto the growing pool's
    /// class instead of being retired while fresh nodes are bound.  A
    /// repurposed engine pays the warm-up weight pull (its weights are
    /// re-laid-out for the new class's parallelism) but skips the
    /// runtime boot — the engine process survives the move.  Unmatched
    /// remainders stay ordinary scale decisions.
    ///
    /// Kept separate from [`PdAutoScaler::observe`] so the detector →
    /// decision mapping stays independently testable; the driver calls
    /// `observe` then `reconcile` back-to-back.
    pub fn reconcile(&mut self, dp: ScaleDecision, dd: ScaleDecision) -> PdRebalance {
        use ScaleDecision::{Down, Up};
        let (mut plan_p, mut plan_d) = (dp, dd);
        let mut p_to_d = 0;
        let mut d_to_p = 0;
        match (dp, dd) {
            (Down(a), Up(b)) => {
                let m = a.min(b);
                p_to_d = m;
                plan_p = if a > m { Down(a - m) } else { ScaleDecision::Hold };
                plan_d = if b > m { Up(b - m) } else { ScaleDecision::Hold };
            }
            (Up(a), Down(b)) => {
                let m = a.min(b);
                d_to_p = m;
                plan_p = if a > m { Up(a - m) } else { ScaleDecision::Hold };
                plan_d = if b > m { Down(b - m) } else { ScaleDecision::Hold };
            }
            _ => {}
        }
        self.report.repurposed += (p_to_d + d_to_p) as u64;
        PdRebalance {
            prefill: plan_p,
            decode: plan_d,
            repurpose_prefill_to_decode: p_to_d,
            repurpose_decode_to_prefill: d_to_p,
        }
    }
}

/// One iteration's reconciled PD rebalance plan
/// ([`PdAutoScaler::reconcile`]): residual per-pool scale decisions
/// plus the cross-class repurpose counts carved out of matched
/// Up/Down pairs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PdRebalance {
    /// Residual decision for the prefill pool.
    pub prefill: ScaleDecision,
    /// Residual decision for the decode pool.
    pub decode: ScaleDecision,
    /// Engines to re-home from the prefill class to the decode class.
    pub repurpose_prefill_to_decode: usize,
    /// Engines to re-home from the decode class to the prefill class.
    pub repurpose_decode_to_prefill: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn cost(wait: f64, train: f64) -> IterationCost {
        IterationCost {
            get_batch_wait_s: wait,
            train_s: train,
            ..IterationCost::default()
        }
    }

    fn scaler() -> AutoScaler {
        let mut p = ElasticPolicy::new(GpuClass::H20, 2, 32);
        p.min_engines = 2;
        p.max_engines = 8;
        p.step_engines = 2;
        p.cooldown_steps = 0;
        AutoScaler::new(p)
    }

    #[test]
    fn rollout_bound_scales_up() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Up(2));
        assert_eq!(s.report.scale_ups, 1);
    }

    #[test]
    fn train_bound_scales_down() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(1.0, 80.0), 4, 0), ScaleDecision::Down(2));
        assert_eq!(s.report.scale_downs, 1);
    }

    #[test]
    fn balanced_holds() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(80.0, 80.0), 4, 0), ScaleDecision::Hold);
    }

    #[test]
    fn respects_max_with_provisioning_in_flight() {
        let mut s = scaler();
        // 6 live + 2 warming = max 8: no headroom.
        assert_eq!(s.observe(&cost(300.0, 80.0), 6, 2), ScaleDecision::Hold);
        // 7 live + 0 warming: only one slot left.
        assert_eq!(s.observe(&cost(300.0, 80.0), 7, 0), ScaleDecision::Up(1));
    }

    #[test]
    fn respects_min_engines() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(0.0, 80.0), 2, 0), ScaleDecision::Hold);
        assert_eq!(s.observe(&cost(0.0, 80.0), 3, 0), ScaleDecision::Down(1));
    }

    #[test]
    fn cooldown_suppresses_consecutive_decisions() {
        let mut s = scaler();
        s.policy.cooldown_steps = 2;
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Up(2));
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Hold);
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Hold);
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Up(2));
    }

    #[test]
    fn no_scale_down_while_provisioning() {
        // A warming engine means a recent scale-up; flapping down before
        // it lands would thrash.
        let mut s = scaler();
        assert_eq!(s.observe(&cost(0.0, 80.0), 4, 1), ScaleDecision::Hold);
    }

    fn pd_policy() -> PdElasticPolicy {
        let pd = PdScenario::xpyd(2, 2);
        let mut p = PdElasticPolicy::for_pd(&pd);
        p.prefill.cooldown_steps = 0;
        p.decode.cooldown_steps = 0;
        p
    }

    /// Rollout-bound signals with the bottleneck detectors set per
    /// class: prefill wait 100 s/engine, decode backlog per the given
    /// tokens, no KV queueing.
    fn sig(prefill_wait: f64, backlog: f64, kv: f64) -> PdSignals {
        PdSignals {
            get_batch_wait_s: 300.0,
            train_s: 80.0,
            prefill_wait_s: prefill_wait,
            decode_backlog_tokens: backlog,
            kv_queue_delay_s: kv,
        }
    }

    #[test]
    fn decode_bound_grows_decode_and_shrinks_prefill() {
        let mut s = PdAutoScaler::new(pd_policy());
        // Backlog far above threshold, prefill idle: the decode pool
        // grows while the prefill pool independently shrinks.
        let (dp, dd) = s.observe(&sig(0.0, 1e9, 0.0), 4, 4, 0, 0);
        assert_eq!(dd, ScaleDecision::Up(2));
        assert_eq!(dp, ScaleDecision::Down(2));
        assert_eq!(s.report.decode_scale_ups, 1);
        assert_eq!(s.report.prefill_scale_downs, 1);
        assert_eq!(s.report.decode_scale_downs, 0);
        assert_eq!(s.report.prefill_scale_ups, 0);
        assert_eq!(s.report.scale_ups, 1);
        assert_eq!(s.report.scale_downs, 1);
    }

    #[test]
    fn prefill_bound_grows_prefill_only() {
        let mut s = PdAutoScaler::new(pd_policy());
        // 1e6 s of prefill residency over 4 engines ≫ threshold; no
        // decode backlog.
        let (dp, dd) = s.observe(&sig(1e6, 0.0, 0.0), 4, 4, 0, 0);
        assert_eq!(dp, ScaleDecision::Up(2));
        assert_eq!(dd, ScaleDecision::Down(2), "idle decode pool shrinks");
        assert_eq!(s.report.prefill_scale_ups, 1);
        assert_eq!(s.report.decode_scale_ups, 0);
    }

    #[test]
    fn both_bound_grows_both_pools() {
        let mut s = PdAutoScaler::new(pd_policy());
        let (dp, dd) = s.observe(&sig(1e6, 1e9, 0.0), 4, 4, 0, 0);
        assert_eq!(dp, ScaleDecision::Up(2));
        assert_eq!(dd, ScaleDecision::Up(2));
    }

    #[test]
    fn undiagnosed_rollout_bound_holds_instead_of_shrinking() {
        // Rollout-bound (wait 300 ≫ train 80) but neither per-class
        // detector fires: the bottleneck is outside the pools, and a
        // starved pipeline must not lose capacity.
        let mut s = PdAutoScaler::new(pd_policy());
        let (dp, dd) = s.observe(&sig(0.0, 0.0, 0.0), 4, 4, 0, 0);
        assert_eq!(dp, ScaleDecision::Hold);
        assert_eq!(dd, ScaleDecision::Hold);
        assert_eq!(s.report.scale_downs, 0, "{:?}", s.report);
        assert_eq!(s.report.kv_bound_holds, 0, "not a KV hold");
    }

    #[test]
    fn kv_bound_iteration_holds_both_pools() {
        let mut s = PdAutoScaler::new(pd_policy());
        // Queue delay of 60 s on an 80 s train step > kv_bound_ratio:
        // more engines on either side cannot fix the link.
        let (dp, dd) = s.observe(&sig(1e6, 1e9, 60.0), 4, 4, 0, 0);
        assert_eq!(dp, ScaleDecision::Hold);
        assert_eq!(dd, ScaleDecision::Hold);
        assert_eq!(s.report.kv_bound_holds, 1);
        assert_eq!(s.report.scale_ups, 0);
    }

    #[test]
    fn pd_cooldowns_are_per_class() {
        let mut p = pd_policy();
        p.decode.cooldown_steps = 1;
        let mut s = PdAutoScaler::new(p);
        let (_, dd) = s.observe(&sig(0.0, 1e9, 0.0), 4, 4, 0, 0);
        assert_eq!(dd, ScaleDecision::Up(2));
        // Decode cools down; prefill keeps deciding independently.
        let (dp, dd) = s.observe(&sig(1e6, 1e9, 0.0), 4, 4, 0, 2);
        assert_eq!(dd, ScaleDecision::Hold);
        assert_eq!(dp, ScaleDecision::Up(2));
    }

    #[test]
    fn pd_respects_min_and_provisioning() {
        let mut s = PdAutoScaler::new(pd_policy());
        // Prefill already at min: no shrink below it.
        let (dp, _) = s.observe(&sig(0.0, 1e9, 0.0), 1, 4, 0, 0);
        assert_eq!(dp, ScaleDecision::Hold);
        // Decode warming engines block a second scale-up past max.
        let mut s = PdAutoScaler::new(pd_policy());
        let max = s.policy.decode.max_engines;
        let (_, dd) = s.observe(&sig(0.0, 1e9, 0.0), 4, max - 1, 0, 1);
        assert_eq!(dd, ScaleDecision::Hold, "live + warming at max");
    }

    #[test]
    fn reconcile_converts_opposed_decisions_into_repurposes() {
        use ScaleDecision::{Down, Hold, Up};
        let mut s = PdAutoScaler::new(pd_policy());
        // Decode-bound regime shift: (Down(2), Up(2)) → 2 repurposes,
        // no residual scaling.
        let plan = s.reconcile(Down(2), Up(2));
        assert_eq!(plan.prefill, Hold);
        assert_eq!(plan.decode, Hold);
        assert_eq!(plan.repurpose_prefill_to_decode, 2);
        assert_eq!(plan.repurpose_decode_to_prefill, 0);
        assert_eq!(s.report.repurposed, 2);
        // Unbalanced pair keeps the residual on the bigger side.
        let plan = s.reconcile(Up(3), Down(1));
        assert_eq!(plan.prefill, Up(2));
        assert_eq!(plan.decode, Hold);
        assert_eq!(plan.repurpose_decode_to_prefill, 1);
        assert_eq!(s.report.repurposed, 3);
        // Same-direction or Hold pairs pass through untouched.
        for (dp, dd) in [(Up(2), Up(2)), (Down(1), Down(1)), (Hold, Up(2)), (Hold, Hold)] {
            let plan = s.reconcile(dp, dd);
            assert_eq!(plan.prefill, dp);
            assert_eq!(plan.decode, dd);
            assert_eq!(plan.repurpose_prefill_to_decode, 0);
            assert_eq!(plan.repurpose_decode_to_prefill, 0);
        }
        assert_eq!(s.report.repurposed, 3, "pass-throughs count nothing");
    }

    #[test]
    fn observe_then_reconcile_repurposes_on_regime_shift() {
        use ScaleDecision::Hold;
        let mut s = PdAutoScaler::new(pd_policy());
        // The decode-bound signal from
        // `decode_bound_grows_decode_and_shrinks_prefill`, reconciled:
        // the opposed pair becomes pure repurposing.
        let (dp, dd) = s.observe(&sig(0.0, 1e9, 0.0), 4, 4, 0, 0);
        let plan = s.reconcile(dp, dd);
        assert_eq!(plan.repurpose_prefill_to_decode, 2);
        assert_eq!(plan.prefill, Hold);
        assert_eq!(plan.decode, Hold);
        assert_eq!(s.report.repurposed, 2);
    }

    #[test]
    fn for_pd_mirrors_the_deployment() {
        let pd = PdScenario::xpyd(3, 1);
        let p = PdElasticPolicy::for_pd(&pd);
        assert_eq!(p.prefill.class, pd.prefill_class);
        assert_eq!(p.decode.class, pd.decode_class);
        assert_eq!(p.prefill.gpus_per_engine, pd.gpus_per_node);
        assert_eq!(p.decode.max_batch, pd.max_batch);
    }

    #[test]
    fn provision_delay_includes_boot_and_weight_pull() {
        let p = ElasticPolicy::new(GpuClass::H800, 1, 32);
        let d = p.provision_delay_s(&QWEN3_8B);
        let boot = ServerlessConfig::default().cold_start_s * p.provision_boot_multiplier;
        assert_eq!(p.boot_delay_s(), boot);
        assert!(d > boot, "weight pull must add on top of boot: {d}");
        let store = MooncakeStore::default();
        let pull = store.acc_pull_time(QWEN3_8B.weight_bytes());
        assert!((d - (boot + pull)).abs() < 1e-9);
    }
}
