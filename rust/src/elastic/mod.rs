//! Elasticity plane: autoscaling the generation pool (§5.2, §8).
//!
//! Disaggregation makes the ActorGen fleet *resizable*: StreamRL
//! (PAPERS.md) argues elasticity of the generation pool is a
//! first-class requirement for disaggregated RL, and the paper's
//! production run continuously rebalances pools as jobs come and go.
//! This module supplies the controller:
//!
//! * [`ElasticPolicy`] — declarative scaling rules for one GPU-class
//!   pool: bounds, step size, cooldown, and the warm-up cost model of a
//!   freshly provisioned engine (sandbox boot reusing the
//!   [`crate::serverless`] cold-start figure, plus the Mooncake weight
//!   pull from [`crate::mooncake`]);
//! * [`AutoScaler`] — watches the per-iteration
//!   [`IterationCost`](crate::coordinator::IterationCost) the drivers
//!   measure and decides: `get_batch` wait ≫ train time means the
//!   pipeline is rollout-bound (grow the pool); wait ≈ 0 means
//!   generation capacity is idle against the train step (shrink it).
//!
//! The DES drivers act on [`ScaleDecision`]s by binding/releasing
//! capacity through the [`crate::resource`] plane and provisioning
//! engines after the warm-up delay; `examples/chaos_train.rs` shows the
//! controller restoring throughput after a 25% generation-pool outage.
//! The environment pool scales in lock-step: its CpuSlot bindings track
//! the live generation fleet, so a scale-down returns real environment
//! capacity to the resource plane (see [`ElasticReport::env_slots_released`]).

use crate::coordinator::IterationCost;
use crate::hw::GpuClass;
use crate::llm::LlmSpec;
use crate::mooncake::MooncakeStore;
use crate::serverless::ServerlessConfig;

/// Scaling rules for one generation pool.
#[derive(Clone, Debug, PartialEq)]
pub struct ElasticPolicy {
    /// GPU class of the pool this policy resizes.
    pub class: GpuClass,
    /// Width of a provisioned engine (the model's rollout TP degree).
    pub gpus_per_engine: usize,
    /// Continuous-batching slot count of a provisioned engine.
    pub max_batch: usize,
    /// Never shrink the pool's live engines below this.
    pub min_engines: usize,
    /// Never grow the pool's live + provisioning engines above this.
    pub max_engines: usize,
    /// Engines added/retired per decision.
    pub step_engines: usize,
    /// Scale up when `get_batch` wait exceeds this multiple of the
    /// train time (rollout-bound).
    pub scale_up_wait_ratio: f64,
    /// Scale down when `get_batch` wait falls below this multiple of
    /// the train time (train-bound; generation capacity idles).
    pub scale_down_wait_ratio: f64,
    /// Iterations to hold after a decision before the next one (lets
    /// the pipeline re-reach steady state).
    pub cooldown_steps: usize,
    /// Engine boot time as a multiple of the serverless function
    /// cold start (an inference server boots a full runtime, not a
    /// sandboxed function).
    pub provision_boot_multiplier: f64,
}

impl ElasticPolicy {
    /// Sensible defaults for scaling a pool of `class` engines.
    pub fn new(class: GpuClass, gpus_per_engine: usize, max_batch: usize) -> Self {
        ElasticPolicy {
            class,
            gpus_per_engine,
            max_batch,
            min_engines: 1,
            max_engines: 64,
            step_engines: 2,
            scale_up_wait_ratio: 1.5,
            scale_down_wait_ratio: 0.25,
            cooldown_steps: 1,
            provision_boot_multiplier: 20.0,
        }
    }

    /// Warm-up delay of one freshly provisioned engine: sandbox/runtime
    /// boot (serverless cold start × multiplier) plus the accumulated
    /// Mooncake weight pull for `model` — the same cost models the
    /// reward and weight-sync paths already use.
    pub fn provision_delay_s(&self, model: &LlmSpec) -> f64 {
        let boot = ServerlessConfig::default().cold_start_s * self.provision_boot_multiplier;
        let store = MooncakeStore::default();
        boot + store.acc_pull_time(model.weight_bytes())
    }
}

/// What the controller wants done to the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    Hold,
    /// Provision this many engines (after the warm-up delay).
    Up(usize),
    /// Retire this many engines (drain + re-queue their work).
    Down(usize),
}

/// Accumulated controller activity over one scenario run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ElasticReport {
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Engines that finished provisioning and joined the fleet.
    pub engines_added: u64,
    /// Engines drained and retired by scale-down decisions.
    pub engines_retired: u64,
    /// Total warm-up time paid across provisioned engines.
    pub provision_wait_s: f64,
    /// Environment-pool CpuSlot bindings acquired through the resource
    /// plane (initial pool + elastic grows).
    pub env_slots_bound: u64,
    /// CpuSlot bindings released back on environment-pool scale-down.
    pub env_slots_released: u64,
}

/// The feedback controller over [`IterationCost`] measurements.
#[derive(Clone, Debug)]
pub struct AutoScaler {
    pub policy: ElasticPolicy,
    cooldown: usize,
    pub report: ElasticReport,
}

impl AutoScaler {
    pub fn new(policy: ElasticPolicy) -> Self {
        assert!(policy.min_engines <= policy.max_engines);
        assert!(policy.step_engines > 0);
        assert!(policy.scale_down_wait_ratio < policy.scale_up_wait_ratio);
        AutoScaler {
            policy,
            cooldown: 0,
            report: ElasticReport::default(),
        }
    }

    /// Feed one iteration's measured cost; `live` is the pool's live
    /// engine count, `provisioning` the engines still warming up.
    pub fn observe(
        &mut self,
        cost: &IterationCost,
        live: usize,
        provisioning: usize,
    ) -> ScaleDecision {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return ScaleDecision::Hold;
        }
        let wait = cost.get_batch_wait_s;
        let train = cost.train_s.max(1e-9);
        if wait > self.policy.scale_up_wait_ratio * train {
            let headroom = self
                .policy
                .max_engines
                .saturating_sub(live + provisioning);
            let n = self.policy.step_engines.min(headroom);
            if n > 0 {
                self.cooldown = self.policy.cooldown_steps;
                self.report.scale_ups += 1;
                return ScaleDecision::Up(n);
            }
        } else if wait < self.policy.scale_down_wait_ratio * train && provisioning == 0 {
            let slack = live.saturating_sub(self.policy.min_engines);
            let n = self.policy.step_engines.min(slack);
            if n > 0 {
                self.cooldown = self.policy.cooldown_steps;
                self.report.scale_downs += 1;
                return ScaleDecision::Down(n);
            }
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn cost(wait: f64, train: f64) -> IterationCost {
        IterationCost {
            get_batch_wait_s: wait,
            train_s: train,
            ..IterationCost::default()
        }
    }

    fn scaler() -> AutoScaler {
        let mut p = ElasticPolicy::new(GpuClass::H20, 2, 32);
        p.min_engines = 2;
        p.max_engines = 8;
        p.step_engines = 2;
        p.cooldown_steps = 0;
        AutoScaler::new(p)
    }

    #[test]
    fn rollout_bound_scales_up() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Up(2));
        assert_eq!(s.report.scale_ups, 1);
    }

    #[test]
    fn train_bound_scales_down() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(1.0, 80.0), 4, 0), ScaleDecision::Down(2));
        assert_eq!(s.report.scale_downs, 1);
    }

    #[test]
    fn balanced_holds() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(80.0, 80.0), 4, 0), ScaleDecision::Hold);
    }

    #[test]
    fn respects_max_with_provisioning_in_flight() {
        let mut s = scaler();
        // 6 live + 2 warming = max 8: no headroom.
        assert_eq!(s.observe(&cost(300.0, 80.0), 6, 2), ScaleDecision::Hold);
        // 7 live + 0 warming: only one slot left.
        assert_eq!(s.observe(&cost(300.0, 80.0), 7, 0), ScaleDecision::Up(1));
    }

    #[test]
    fn respects_min_engines() {
        let mut s = scaler();
        assert_eq!(s.observe(&cost(0.0, 80.0), 2, 0), ScaleDecision::Hold);
        assert_eq!(s.observe(&cost(0.0, 80.0), 3, 0), ScaleDecision::Down(1));
    }

    #[test]
    fn cooldown_suppresses_consecutive_decisions() {
        let mut s = scaler();
        s.policy.cooldown_steps = 2;
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Up(2));
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Hold);
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Hold);
        assert_eq!(s.observe(&cost(300.0, 80.0), 4, 0), ScaleDecision::Up(2));
    }

    #[test]
    fn no_scale_down_while_provisioning() {
        // A warming engine means a recent scale-up; flapping down before
        // it lands would thrash.
        let mut s = scaler();
        assert_eq!(s.observe(&cost(0.0, 80.0), 4, 1), ScaleDecision::Hold);
    }

    #[test]
    fn provision_delay_includes_boot_and_weight_pull() {
        let p = ElasticPolicy::new(GpuClass::H800, 1, 32);
        let d = p.provision_delay_s(&QWEN3_8B);
        let boot = ServerlessConfig::default().cold_start_s * p.provision_boot_multiplier;
        assert!(d > boot, "weight pull must add on top of boot: {d}");
        let store = MooncakeStore::default();
        let pull = store.acc_pull_time(QWEN3_8B.weight_bytes());
        assert!((d - (boot + pull)).abs() < 1e-9);
    }
}
