//! Data plane: Worker and Cluster abstractions (§5.1–§5.3).
//!
//! A `Worker` is the basic execution unit; a `Cluster` is the proxy /
//! controller for a role-specific Worker group, realizing the paper's
//! decorator semantics (Listing 2) in Rust:
//!
//! * `execute_all` — the single-controller broadcast path (`register`
//!   with `execute_all` mode): invoke on every worker, aggregate
//!   results;
//! * `route_by_affinity` — the `hw_mapping` path: filter workers whose
//!   resource class matches the tag's preferred hardware, falling back
//!   to the whole group when none match (forward progress under
//!   transient contention, §5.3);
//! * `serverless_handler` — the `register_serverless` path: replace a
//!   method's executor with a callable that dispatches to the
//!   serverless platform.

use crate::env::TaskDomain;
use crate::hw::GpuClass;
use crate::resource::{ResourceClass, Role};
use std::collections::BTreeMap;

/// Metadata every Worker carries (resource binding of §5.2).
pub trait Worker {
    fn id(&self) -> u64;
    fn resource_class(&self) -> ResourceClass;
}

/// A plain worker record for roles whose state lives elsewhere.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorkerMeta {
    pub id: u64,
    pub class: ResourceClass,
}

impl Worker for WorkerMeta {
    fn id(&self) -> u64 {
        self.id
    }
    fn resource_class(&self) -> ResourceClass {
        self.class
    }
}

/// Role-specific worker group + invocation proxy.
pub struct Cluster<W: Worker> {
    pub role: Role,
    workers: Vec<W>,
    /// Task-domain → GPU class affinity table for this cluster
    /// (the `hw_mapping` declaration).
    hw_affinity: BTreeMap<TaskDomain, GpuClass>,
    /// Round-robin cursor per routing class for fair dispatch.
    cursors: BTreeMap<ResourceClass, usize>,
}

impl<W: Worker> Cluster<W> {
    pub fn new(role: Role, workers: Vec<W>) -> Self {
        Cluster {
            role,
            workers,
            hw_affinity: BTreeMap::new(),
            cursors: BTreeMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn workers(&self) -> &[W] {
        &self.workers
    }

    /// Declare a domain affinity (Listing 1, lines 17–19).
    pub fn declare_affinity(&mut self, domain: TaskDomain, class: GpuClass) -> &mut Self {
        self.hw_affinity.insert(domain, class);
        self
    }

    /// `execute_all`: call `f` on every worker and collect results
    /// (the runtime's broadcast + aggregate path).
    pub fn execute_all<R>(&mut self, mut f: impl FnMut(&mut W) -> R) -> Vec<R> {
        self.workers.iter_mut().map(|w| f(w)).collect()
    }

    /// Workers whose resource class serves `domain` under the declared
    /// affinity.  Falls back to *all* workers when the preferred class
    /// has no members (§5.3 forward-progress rule).
    pub fn route_by_affinity(&self, domain: TaskDomain) -> Vec<&W> {
        match self.hw_affinity.get(&domain) {
            Some(&cls) => {
                let want = ResourceClass::Gpu(cls);
                let hits: Vec<&W> = self
                    .workers
                    .iter()
                    .filter(|w| w.resource_class() == want)
                    .collect();
                if hits.is_empty() {
                    self.workers.iter().collect()
                } else {
                    hits
                }
            }
            None => self.workers.iter().collect(),
        }
    }

    /// Pick one worker for `domain`, round-robin within its affinity
    /// class (the LLMProxy's per-request dispatch uses this).
    pub fn dispatch(&mut self, domain: TaskDomain) -> Option<u64> {
        let candidates: Vec<u64> = self
            .route_by_affinity(domain)
            .iter()
            .map(|w| w.id())
            .collect();
        if candidates.is_empty() {
            return None;
        }
        let class_key = self
            .hw_affinity
            .get(&domain)
            .map(|&g| ResourceClass::Gpu(g))
            .unwrap_or(ResourceClass::CpuSlot);
        let cur = self.cursors.entry(class_key).or_insert(0);
        let chosen = candidates[*cur % candidates.len()];
        *cur += 1;
        Some(chosen)
    }

    pub fn worker_mut(&mut self, id: u64) -> Option<&mut W> {
        self.workers.iter_mut().find(|w| w.id() == id)
    }

    /// Remove a failed worker from the group (resilience path, §8):
    /// its work is reassigned by the caller; returns the worker.
    pub fn remove_worker(&mut self, id: u64) -> Option<W> {
        let idx = self.workers.iter().position(|w| w.id() == id)?;
        Some(self.workers.remove(idx))
    }

    pub fn add_worker(&mut self, w: W) {
        self.workers.push(w);
    }
}

/// The `register_serverless` realization: wraps a handler so calls are
/// executed by the serverless platform instead of a local worker.
/// (The DES uses [`crate::serverless::ServerlessPlatform`]; the real
/// harness uses an in-process executor with the same interface.)
pub struct ServerlessHandler<In, Out> {
    pub url: String,
    handler: Box<dyn FnMut(In) -> Out + Send>,
    pub calls: u64,
}

impl<In, Out> ServerlessHandler<In, Out> {
    pub fn new(url: impl Into<String>, handler: impl FnMut(In) -> Out + Send + 'static) -> Self {
        ServerlessHandler {
            url: url.into(),
            handler: Box::new(handler),
            calls: 0,
        }
    }

    pub fn invoke(&mut self, input: In) -> Out {
        self.calls += 1;
        (self.handler)(input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen_cluster() -> Cluster<WorkerMeta> {
        // 2 H800 + 4 H20 generation workers (Listing 1's heterogeneous
        // allocation, scaled down).
        let mut workers = Vec::new();
        for id in 0..2 {
            workers.push(WorkerMeta {
                id,
                class: ResourceClass::Gpu(GpuClass::H800),
            });
        }
        for id in 2..6 {
            workers.push(WorkerMeta {
                id,
                class: ResourceClass::Gpu(GpuClass::H20),
            });
        }
        Cluster::new(Role::ActorGen, workers)
    }

    #[test]
    fn execute_all_broadcasts() {
        let mut c = gen_cluster();
        let ids = c.execute_all(|w| w.id * 10);
        assert_eq!(ids, vec![0, 10, 20, 30, 40, 50]);
    }

    #[test]
    fn affinity_routes_to_declared_class() {
        let mut c = gen_cluster();
        c.declare_affinity(TaskDomain::Game, GpuClass::H800);
        let routed = c.route_by_affinity(TaskDomain::Game);
        assert_eq!(routed.len(), 2);
        assert!(routed
            .iter()
            .all(|w| w.resource_class() == ResourceClass::Gpu(GpuClass::H800)));
    }

    #[test]
    fn undeclared_domain_uses_all_workers() {
        let c = gen_cluster();
        assert_eq!(c.route_by_affinity(TaskDomain::MathTool).len(), 6);
    }

    #[test]
    fn missing_class_falls_back_to_all() {
        let mut c = gen_cluster();
        // declare affinity to a class with no members after removal
        c.declare_affinity(TaskDomain::Swe, GpuClass::H800);
        c.remove_worker(0);
        c.remove_worker(1);
        assert_eq!(c.route_by_affinity(TaskDomain::Swe).len(), 4);
    }

    #[test]
    fn dispatch_round_robins_within_class() {
        let mut c = gen_cluster();
        c.declare_affinity(TaskDomain::Game, GpuClass::H800);
        let picks: Vec<u64> = (0..4).map(|_| c.dispatch(TaskDomain::Game).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 0, 1]);
    }

    #[test]
    fn worker_failure_and_replacement() {
        let mut c = gen_cluster();
        let dead = c.remove_worker(3).unwrap();
        assert_eq!(c.len(), 5);
        assert!(c.worker_mut(3).is_none());
        c.add_worker(dead);
        assert_eq!(c.len(), 6);
    }

    #[test]
    fn serverless_handler_counts_calls() {
        let mut h = ServerlessHandler::new("fc://reward", |x: f64| x * 2.0);
        assert_eq!(h.invoke(2.0), 4.0);
        assert_eq!(h.invoke(3.0), 6.0);
        assert_eq!(h.calls, 2);
        assert_eq!(h.url, "fc://reward");
    }
}
