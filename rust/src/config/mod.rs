//! Launcher configuration: JSON config files → [`Scenario`].
//!
//! The `rollart` binary accepts `--config path.json` with the fields
//! below (all optional; defaults mirror the paper's §7.1 setup scaled
//! down).  This is the user-facing declarative surface of the resource
//! plane — model, pools, α, affinity, reward deployment.

use crate::buffer::StalenessPolicy;
use crate::env::TaskDomain;
use crate::envpool::EnvPoolConfig;
use crate::hw::GpuClass;
use crate::llm::{LlmSpec, QWEN3_14B, QWEN3_30B_A3B, QWEN3_32B, QWEN3_8B, TINY_E2E};
use crate::sim::{EnginePool, Mode, RewardDeploy, Scenario};
use crate::simkit::dist::Dist;
use crate::util::json::Json;

/// Look up a model by name.
pub fn model_by_name(name: &str) -> Option<LlmSpec> {
    match name.to_lowercase().as_str() {
        "qwen3-8b" | "8b" => Some(QWEN3_8B.clone()),
        "qwen3-14b" | "14b" => Some(QWEN3_14B.clone()),
        "qwen3-32b" | "32b" => Some(QWEN3_32B.clone()),
        "qwen3-30b-a3b" | "30b-a3b" | "moe" => Some(QWEN3_30B_A3B.clone()),
        "tiny" | "tiny-e2e" => Some(TINY_E2E.clone()),
        _ => None,
    }
}

pub fn mode_by_name(name: &str) -> Option<Mode> {
    match name.to_lowercase().as_str() {
        "sync" => Some(Mode::Sync),
        "sync+" | "syncplus" => Some(Mode::SyncPlus),
        "one-off" | "oneoff" => Some(Mode::OneOff),
        "areal" => Some(Mode::AReaL),
        "rollart" => Some(Mode::RollArt),
        _ => None,
    }
}

pub fn domain_by_name(name: &str) -> Option<TaskDomain> {
    TaskDomain::ALL.into_iter().find(|d| d.name() == name)
}

/// Parse a scenario from JSON text.  Unknown fields are ignored;
/// missing fields take the scaled default.
pub fn scenario_from_json(text: &str) -> Result<Scenario, String> {
    let j = Json::parse(text).map_err(|e| e.to_string())?;
    let model = j
        .get("model")
        .and_then(|m| m.as_str())
        .map(|n| model_by_name(n).ok_or(format!("unknown model {n}")))
        .transpose()?
        .unwrap_or_else(|| QWEN3_8B.clone());
    let scale = j.get("scale").and_then(|s| s.as_f64()).unwrap_or(0.25);
    let mut s = Scenario::rollart_default(model, scale);

    if let Some(m) = j.get("mode").and_then(|m| m.as_str()) {
        s.mode = mode_by_name(m).ok_or(format!("unknown mode {m}"))?;
    }
    if let Some(b) = j.get("batch_size").and_then(|v| v.as_usize()) {
        s.batch_size = b;
    }
    if let Some(g) = j.get("group_size").and_then(|v| v.as_usize()) {
        s.group_size = g;
    }
    if let Some(r) = j.get("redundancy").and_then(|v| v.as_usize()) {
        s.redundancy = r;
    }
    if let Some(a) = j.get("alpha").and_then(|v| v.as_usize()) {
        s.alpha = a as u64;
    }
    if let Some(p) = j.get("staleness").and_then(|v| v.as_str()) {
        s.staleness = match p {
            "per_turn" => StalenessPolicy::PerTurn,
            "at_start" => StalenessPolicy::AtStart,
            other => return Err(format!("unknown staleness {other}")),
        };
    }
    if let Some(t) = j.get("train_gpus").and_then(|v| v.as_usize()) {
        s.train_gpus = t;
    }
    if let Some(c) = j.get("train_class").and_then(|v| v.as_str()) {
        s.train_class = match c {
            "H800" | "h800" => GpuClass::H800,
            "H20" | "h20" => GpuClass::H20,
            other => return Err(format!("unknown train_class {other}")),
        };
    }
    if let Some(i) = j.get("iterations").and_then(|v| v.as_usize()) {
        s.iterations = i;
    }
    if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
        s.seed = v as u64;
    }
    if let Some(b) = j.get("affinity_routing").and_then(|v| v.as_bool()) {
        s.affinity_routing = b;
    }
    if let Some(b) = j.get("async_weight_sync").and_then(|v| v.as_bool()) {
        s.async_weight_sync = b;
    }
    if let Some(c) = j.get("envpool").and_then(|v| v.as_str()) {
        s.envpool = match c {
            "registry_only" => EnvPoolConfig::registry_only(),
            "multi_tier" => EnvPoolConfig::multi_tier(),
            other => return Err(format!("unknown envpool {other}")),
        };
    }
    if let Some(v) = j.get("env_fault_seed").and_then(|v| v.as_f64()) {
        s.envpool.fault_seed = Some(v as u64);
    }
    if let Some(m) = j.get("engine_mtbf_s").and_then(|v| v.as_f64()) {
        if m <= 0.0 || !m.is_finite() {
            return Err(format!("engine_mtbf_s must be positive, got {m}"));
        }
        s.fault = crate::fault::FaultProfile {
            engine_mtbf_s: Some(m),
            ..s.fault
        };
    }
    if let Some(p) = j.get("env_crash_p").and_then(|v| v.as_f64()) {
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("env_crash_p must be in [0, 1], got {p}"));
        }
        s.fault.env_crash_p = p;
    }
    if let Some(mix) = j.get("task_mix").and_then(|v| v.as_arr()) {
        let mut domains = Vec::new();
        for d in mix {
            let name = d.as_str().ok_or("task_mix entries must be strings")?;
            domains.push(domain_by_name(name).ok_or(format!("unknown domain {name}"))?);
        }
        if !domains.is_empty() {
            s.task_mix = domains;
        }
    }
    if let Some(pools) = j.get("gen_pools").and_then(|v| v.as_arr()) {
        let mut out = Vec::new();
        for p in pools {
            let class = match p.get("class").and_then(|c| c.as_str()) {
                Some("H800" | "h800") => GpuClass::H800,
                Some("H20" | "h20") => GpuClass::H20,
                other => return Err(format!("bad gpu class {other:?}")),
            };
            out.push(EnginePool {
                class,
                gpus_per_engine: p.get("gpus_per_engine").and_then(|v| v.as_usize()).unwrap_or(8),
                engines: p.get("engines").and_then(|v| v.as_usize()).unwrap_or(1),
                max_batch: p.get("max_batch").and_then(|v| v.as_usize()).unwrap_or(64),
            });
        }
        if !out.is_empty() {
            s.gen_pools = out;
        }
    }
    if let Some(r) = j.get("route").and_then(|v| v.as_str()) {
        s.route = match r {
            "affinity" => crate::proxy::RouteKind::Affinity,
            "least_loaded" => crate::proxy::RouteKind::LeastLoaded,
            "domain_fair" => crate::proxy::RouteKind::DomainFair,
            "token_backlog" => crate::proxy::RouteKind::TokenBacklog,
            "best_fit" => crate::proxy::RouteKind::BestFit,
            "inverted" => crate::proxy::RouteKind::Inverted,
            other => return Err(format!("unknown route policy {other}")),
        };
    }
    if let Some(p) = j.get("pd") {
        let x = p.get("prefill_nodes").and_then(|v| v.as_usize()).unwrap_or(1);
        let y = p.get("decode_nodes").and_then(|v| v.as_usize()).unwrap_or(1);
        if x == 0 || y == 0 {
            return Err(format!("pd needs ≥1 node per pool, got {x}P{y}D"));
        }
        let mut pd = crate::sim::driver::pd::PdScenario::xpyd(x, y);
        if let Some(g) = p.get("gpus_per_node").and_then(|v| v.as_usize()) {
            if g == 0 {
                return Err("pd.gpus_per_node must be ≥ 1".to_string());
            }
            pd.gpus_per_node = g;
        }
        if let Some(g) = p.get("decode_gpus_per_node").and_then(|v| v.as_usize()) {
            if g == 0 {
                return Err("pd.decode_gpus_per_node must be ≥ 1".to_string());
            }
            pd.decode_gpus_per_node = Some(g);
        }
        if let Some(m) = p.get("max_batch").and_then(|v| v.as_usize()) {
            if m == 0 {
                return Err("pd.max_batch must be ≥ 1".to_string());
            }
            pd.max_batch = m;
        }
        if let Some(k) = p.get("kv_slots").and_then(|v| v.as_usize()) {
            if k == 0 {
                return Err("pd.kv_slots must be ≥ 1".to_string());
            }
            pd.kv_slots = k;
        }
        if let Some(d) = p.get("disaggregated").and_then(|v| v.as_bool()) {
            pd.disaggregated = d;
        }
        if let Some(r) = p.get("prefix_reuse").and_then(|v| v.as_bool()) {
            pd.prefix_reuse = r;
        }
        s.pd = Some(pd);
    }
    if let Some(true) = j.get("pd_elastic").and_then(|v| v.as_bool()) {
        let pd = s
            .pd
            .as_ref()
            .ok_or("pd_elastic requires a pd deployment")?;
        if !pd.disaggregated {
            return Err("pd_elastic requires a disaggregated pd".to_string());
        }
        s.pd_elastic = Some(crate::elastic::PdElasticPolicy::for_pd(pd));
    }
    if let Some(w) = j.get("weights") {
        use crate::weights::{SyncStrategyKind, WeightsScenario};
        let mut ws = WeightsScenario::default();
        if let Some(st) = w.get("strategy").and_then(|v| v.as_str()) {
            ws.strategy = match st {
                "blocking" => SyncStrategyKind::BlockingBroadcast,
                "rolling" => SyncStrategyKind::RollingSubset {
                    k: w.get("k").and_then(|v| v.as_usize()).unwrap_or(2),
                },
                "lazy" => SyncStrategyKind::LazyPull,
                "overlapped" => SyncStrategyKind::OverlappedBroadcast {
                    chunks: w.get("chunks").and_then(|v| v.as_usize()).unwrap_or(8),
                },
                "adaptive" => SyncStrategyKind::Adaptive,
                other => return Err(format!("unknown weight strategy {other}")),
            };
        }
        if let Some(n) = w.get("fanout_slots").and_then(|v| v.as_usize()) {
            ws.fanout_slots = n;
        }
        if let Some(b) = w.get("share_kv_link").and_then(|v| v.as_bool()) {
            ws.share_kv_link = b;
        }
        // Adaptive-controller knobs (honored only by the adaptive
        // strategy; defaults come from the calib_wsync sweep).
        if let Some(r) = w.get("rollout_bound_ratio").and_then(|v| v.as_f64()) {
            if r <= 0.0 || !r.is_finite() {
                return Err(format!("weights.rollout_bound_ratio must be positive, got {r}"));
            }
            ws.adaptive.rollout_bound_ratio = r;
        }
        if let Some(c) = w.get("cooldown_steps").and_then(|v| v.as_usize()) {
            ws.adaptive.cooldown_steps = c;
        }
        if let Some(gb) = w.get("bucket_gb").and_then(|v| v.as_f64()) {
            // Bucket granularity of the Mooncake model every weight
            // transfer is priced with (validate() re-checks the
            // resulting bytes).
            if gb <= 0.0 || !gb.is_finite() {
                return Err(format!("weights.bucket_gb must be positive, got {gb}"));
            }
            ws.mooncake.bucket_bytes = gb * 1024.0 * 1024.0 * 1024.0;
        }
        ws.validate()?;
        // Mode legality mirrors the driver's assertion so a bad config
        // file errors instead of panicking mid-run (the monolithic Sync
        // driver accepts any strategy and pays the analytic term).
        if s.mode != Mode::Sync
            && !crate::sim::driver::policy_for(s.mode).strategy_legal(ws.strategy)
        {
            return Err(format!(
                "mode {:?} does not admit weight strategy {}",
                s.mode,
                ws.strategy.name()
            ));
        }
        s.weights = ws;
    }
    if let Some(r) = j.get("reward") {
        let kind = r.get("kind").and_then(|k| k.as_str()).unwrap_or("serverless");
        let exec = r.get("exec_s").and_then(|v| v.as_f64()).unwrap_or(1.0);
        s.reward = match kind {
            "serverless" => RewardDeploy::Serverless {
                exec_s: Dist::lognormal_median(exec, 0.6),
            },
            "dedicated" => RewardDeploy::DedicatedGpus {
                gpus: r.get("gpus").and_then(|v| v.as_usize()).unwrap_or(4),
                exec_s: Dist::lognormal_median(exec, 0.6),
            },
            other => return Err(format!("unknown reward kind {other}")),
        };
    }
    if let Some(t) = j.get("trace") {
        use crate::trace::{ArrivalProcess, TraceFeed, TraceScenario};
        let requests = t.get("requests").and_then(|v| v.as_usize()).unwrap_or(10_000) as u64;
        if requests == 0 {
            return Err("trace.requests must be ≥ 1".to_string());
        }
        let feed = match t.get("feed").and_then(|v| v.as_str()).unwrap_or("streamed") {
            "streamed" => TraceFeed::Streamed,
            "materialized" => TraceFeed::Materialized,
            other => return Err(format!("unknown trace feed {other}")),
        };
        let arrivals = match t.get("arrivals") {
            None => ArrivalProcess::Poisson { rate: 10.0 },
            Some(a) => {
                let rate_knob = |key: &str, default: f64| -> Result<f64, String> {
                    let v = a.get(key).and_then(|v| v.as_f64()).unwrap_or(default);
                    if v <= 0.0 || !v.is_finite() {
                        return Err(format!("trace.arrivals.{key} must be positive, got {v}"));
                    }
                    Ok(v)
                };
                match a.get("kind").and_then(|v| v.as_str()).unwrap_or("poisson") {
                    "poisson" => ArrivalProcess::Poisson {
                        rate: rate_knob("rate", 10.0)?,
                    },
                    "diurnal" => {
                        let amplitude =
                            a.get("amplitude").and_then(|v| v.as_f64()).unwrap_or(0.5);
                        if !(0.0..=1.0).contains(&amplitude) {
                            return Err(format!(
                                "trace.arrivals.amplitude must be in [0, 1], got {amplitude}"
                            ));
                        }
                        ArrivalProcess::Diurnal {
                            base_rate: rate_knob("base_rate", 10.0)?,
                            amplitude,
                            period_s: rate_knob("period_s", 86_400.0)?,
                        }
                    }
                    "bursty" => ArrivalProcess::Bursty {
                        on_rate: rate_knob("on_rate", 50.0)?,
                        mean_on_s: rate_knob("mean_on_s", 60.0)?,
                        mean_off_s: rate_knob("mean_off_s", 240.0)?,
                    },
                    other => return Err(format!("unknown arrival process {other}")),
                }
            }
        };
        // Open-loop arrivals cannot drive barrier iteration launches —
        // mirror the driver's assertion as a config error (the analytic
        // Sync driver ignores the trace entirely).
        if s.mode != Mode::Sync
            && !crate::sim::driver::policy_for(s.mode).continuous_rollout()
        {
            return Err(format!("mode {:?} does not admit a trace replay", s.mode));
        }
        s.trace = Some(TraceScenario {
            families: crate::trace::prod_families(),
            requests,
            arrivals,
            feed,
            trace_seed: t.get("seed").and_then(|v| v.as_f64()).unwrap_or(8.0) as u64,
        });
    }
    if let Some(o) = j.get("slo") {
        use crate::trace::SloPolicy;
        let mut slo = SloPolicy::default();
        if let Some(d) = o.get("default_target_s").and_then(|v| v.as_f64()) {
            if d <= 0.0 {
                return Err(format!("slo.default_target_s must be positive, got {d}"));
            }
            slo.default_target_s = d;
        }
        if let Some(cap) = o.get("shed_above").and_then(|v| v.as_usize()) {
            if cap == 0 {
                return Err("slo.shed_above must be ≥ 1 (0 would shed everything)".to_string());
            }
            slo.shed_above = Some(cap);
        }
        // Per-domain targets as an array of objects (the Json helper
        // has no key iteration).
        if let Some(targets) = o.get("targets").and_then(|v| v.as_arr()) {
            for entry in targets {
                let name = entry
                    .get("domain")
                    .and_then(|v| v.as_str())
                    .ok_or("slo.targets entries need a domain")?;
                let domain =
                    domain_by_name(name).ok_or(format!("unknown domain {name}"))?;
                let target = entry
                    .get("target_s")
                    .and_then(|v| v.as_f64())
                    .ok_or(format!("slo target for {name} needs target_s"))?;
                if target <= 0.0 {
                    return Err(format!("slo target for {name} must be positive"));
                }
                slo.targets.push((domain, target));
            }
        }
        s.slo = Some(slo);
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_config_defaults() {
        let s = scenario_from_json("{}").unwrap();
        assert_eq!(s.mode, Mode::RollArt);
        assert_eq!(s.model.name, "Qwen3-8B");
        assert!(s.batch_size > 0);
    }

    #[test]
    fn full_config_round_trip() {
        let text = r#"{
            "model": "qwen3-32b", "mode": "areal", "scale": 0.1,
            "batch_size": 64, "group_size": 8, "alpha": 2,
            "staleness": "at_start", "iterations": 4, "seed": 9,
            "affinity_routing": false, "envpool": "multi_tier",
            "task_mix": ["swe", "math_tool"],
            "gen_pools": [{"class": "H20", "engines": 2, "gpus_per_engine": 4}],
            "reward": {"kind": "dedicated", "gpus": 2, "exec_s": 3.0}
        }"#;
        let s = scenario_from_json(text).unwrap();
        assert_eq!(s.model.name, "Qwen3-32B");
        assert_eq!(s.mode, Mode::AReaL);
        assert_eq!(s.batch_size, 64);
        assert_eq!(s.alpha, 2);
        assert_eq!(s.task_mix, vec![TaskDomain::Swe, TaskDomain::MathTool]);
        assert_eq!(s.gen_pools.len(), 1);
        assert_eq!(s.gen_pools[0].class, GpuClass::H20);
        assert!(matches!(s.reward, RewardDeploy::DedicatedGpus { gpus: 2, .. }));
    }

    #[test]
    fn fault_knobs_parse() {
        let s = scenario_from_json(
            r#"{"engine_mtbf_s": 600.0, "env_crash_p": 0.01, "env_fault_seed": 7}"#,
        )
        .unwrap();
        assert_eq!(s.fault.engine_mtbf_s, Some(600.0));
        assert_eq!(s.fault.env_crash_p, 0.01);
        assert_eq!(s.envpool.fault_seed, Some(7));
        assert!(s.fault.is_active());
        let clean = scenario_from_json("{}").unwrap();
        assert!(!clean.fault.is_active());
        assert!(clean.elastic.is_none());
    }

    #[test]
    fn pd_and_route_knobs_parse() {
        let s = scenario_from_json(
            r#"{"pd": {"prefill_nodes": 2, "decode_nodes": 2, "gpus_per_node": 4,
                       "kv_slots": 2},
                "route": "domain_fair"}"#,
        )
        .unwrap();
        let pd = s.pd.expect("pd config");
        assert_eq!(pd.prefill_nodes, 2);
        assert_eq!(pd.decode_nodes, 2);
        assert_eq!(pd.gpus_per_node, 4);
        assert_eq!(pd.kv_slots, 2);
        assert!(pd.disaggregated);
        assert_eq!(pd.name(), "2P2D");
        assert_eq!(s.route, crate::proxy::RouteKind::DomainFair);
        let colo = scenario_from_json(r#"{"pd": {"disaggregated": false}}"#).unwrap();
        assert!(!colo.pd.unwrap().disaggregated);
        let clean = scenario_from_json("{}").unwrap();
        assert!(clean.pd.is_none());
        assert_eq!(clean.route, crate::proxy::RouteKind::Affinity);
        let tb = scenario_from_json(r#"{"route": "token_backlog"}"#).unwrap();
        assert_eq!(tb.route, crate::proxy::RouteKind::TokenBacklog);
        let bf = scenario_from_json(r#"{"route": "best_fit"}"#).unwrap();
        assert_eq!(bf.route, crate::proxy::RouteKind::BestFit);
        let inv = scenario_from_json(r#"{"route": "inverted"}"#).unwrap();
        assert_eq!(inv.route, crate::proxy::RouteKind::Inverted);
    }

    #[test]
    fn pd_elastic_knob_builds_the_split_controller() {
        let s = scenario_from_json(
            r#"{"pd": {"prefill_nodes": 1, "decode_nodes": 3}, "pd_elastic": true}"#,
        )
        .unwrap();
        let pe = s.pd_elastic.expect("split controller");
        let pd = s.pd.expect("pd config");
        assert_eq!(pe.prefill.class, pd.prefill_class);
        assert_eq!(pe.decode.class, pd.decode_class);
        assert!(s.elastic.is_none());
        // Validation: pd_elastic without pd, or on the colocated arm.
        assert!(scenario_from_json(r#"{"pd_elastic": true}"#).is_err());
        assert!(scenario_from_json(
            r#"{"pd": {"disaggregated": false}, "pd_elastic": true}"#
        )
        .is_err());
        // false is a no-op either way.
        let off = scenario_from_json(r#"{"pd_elastic": false}"#).unwrap();
        assert!(off.pd_elastic.is_none());
    }

    #[test]
    fn weights_and_train_class_knobs_parse() {
        use crate::weights::SyncStrategyKind;
        let s = scenario_from_json(
            r#"{"weights": {"strategy": "rolling", "k": 3, "fanout_slots": 4,
                            "share_kv_link": true},
                "train_class": "h20"}"#,
        )
        .unwrap();
        assert_eq!(s.weights.strategy, SyncStrategyKind::RollingSubset { k: 3 });
        assert_eq!(s.weights.fanout_slots, 4);
        assert!(s.weights.share_kv_link);
        assert_eq!(s.train_class, GpuClass::H20);
        let lazy = scenario_from_json(r#"{"weights": {"strategy": "lazy"}}"#).unwrap();
        assert_eq!(lazy.weights.strategy, SyncStrategyKind::LazyPull);
        let ov = scenario_from_json(r#"{"weights": {"strategy": "overlapped"}}"#).unwrap();
        assert_eq!(
            ov.weights.strategy,
            SyncStrategyKind::OverlappedBroadcast { chunks: 8 }
        );
        let ad = scenario_from_json(r#"{"weights": {"strategy": "adaptive"}}"#).unwrap();
        assert_eq!(ad.weights.strategy, SyncStrategyKind::Adaptive);
        // Adaptive-controller knobs land on the template the driver
        // clones (and leave the strategy selector untouched).
        let tuned = scenario_from_json(
            r#"{"weights": {"strategy": "adaptive", "rollout_bound_ratio": 2.0,
                            "cooldown_steps": 3}}"#,
        )
        .unwrap();
        assert_eq!(tuned.weights.adaptive.rollout_bound_ratio, 2.0);
        assert_eq!(tuned.weights.adaptive.cooldown_steps, 3);
        assert!(scenario_from_json(
            r#"{"weights": {"rollout_bound_ratio": -1.0}}"#
        )
        .is_err());
        // Bucket granularity of the Mooncake model.
        let bk =
            scenario_from_json(r#"{"weights": {"strategy": "rolling", "bucket_gb": 0.5}}"#)
                .unwrap();
        assert!((bk.weights.mooncake.bucket_bytes - 0.5 * 1024.0 * 1024.0 * 1024.0).abs() < 1.0);
        let clean = scenario_from_json("{}").unwrap();
        assert_eq!(clean.weights.strategy, SyncStrategyKind::BlockingBroadcast);
        assert_eq!(clean.train_class, GpuClass::H800);
        let pr = scenario_from_json(r#"{"pd": {"prefix_reuse": true}}"#).unwrap();
        assert!(pr.pd.unwrap().prefix_reuse);
    }

    #[test]
    fn weight_strategy_legality_is_config_checked() {
        // Sync+ trains behind a blocking barrier: only the fleet drain.
        assert!(scenario_from_json(
            r#"{"mode": "sync+", "weights": {"strategy": "rolling"}}"#
        )
        .is_err());
        assert!(scenario_from_json(
            r#"{"mode": "sync+", "weights": {"strategy": "blocking"}}"#
        )
        .is_ok());
        // The monolithic Sync driver pays the analytic term instead.
        assert!(scenario_from_json(
            r#"{"mode": "sync", "weights": {"strategy": "overlapped"}}"#
        )
        .is_ok());
        // Sync+ rejects the adaptive plane for the same reason.
        assert!(scenario_from_json(
            r#"{"mode": "sync+", "weights": {"strategy": "adaptive"}}"#
        )
        .is_err());
        // Degenerate knobs error.
        assert!(scenario_from_json(r#"{"weights": {"strategy": "telekinesis"}}"#).is_err());
        assert!(scenario_from_json(r#"{"weights": {"strategy": "rolling", "k": 0}}"#).is_err());
        assert!(scenario_from_json(r#"{"weights": {"fanout_slots": 0}}"#).is_err());
        assert!(scenario_from_json(r#"{"weights": {"bucket_gb": 0.0}}"#).is_err());
        assert!(scenario_from_json(r#"{"weights": {"bucket_gb": -2.0}}"#).is_err());
        assert!(scenario_from_json(r#"{"train_class": "TPU"}"#).is_err());
    }

    #[test]
    fn bad_values_error() {
        assert!(scenario_from_json(r#"{"model": "gpt-5"}"#).is_err());
        assert!(scenario_from_json(r#"{"mode": "warp"}"#).is_err());
        assert!(scenario_from_json("not json").is_err());
        assert!(scenario_from_json(r#"{"route": "telepathy"}"#).is_err());
        assert!(scenario_from_json(r#"{"pd": {"prefill_nodes": 0}}"#).is_err());
        assert!(scenario_from_json(r#"{"pd": {"gpus_per_node": 0}}"#).is_err());
        assert!(scenario_from_json(r#"{"pd": {"max_batch": 0}}"#).is_err());
        assert!(scenario_from_json(r#"{"pd": {"kv_slots": 0}}"#).is_err());
        // A zero/negative MTBF would make the failure process fire at
        // zero-delay forever (the sim clock never advances).
        assert!(scenario_from_json(r#"{"engine_mtbf_s": 0.0}"#).is_err());
        assert!(scenario_from_json(r#"{"engine_mtbf_s": -5.0}"#).is_err());
        assert!(scenario_from_json(r#"{"env_crash_p": 1.5}"#).is_err());
    }

    #[test]
    fn trace_and_slo_knobs_parse() {
        use crate::trace::{ArrivalProcess, TraceFeed};
        let s = scenario_from_json(
            r#"{"trace": {"requests": 5000, "seed": 21, "feed": "materialized",
                          "arrivals": {"kind": "diurnal", "base_rate": 4.0,
                                       "amplitude": 0.6, "period_s": 3600.0}},
                "slo": {"default_target_s": 900.0, "shed_above": 256,
                        "targets": [{"domain": "swe", "target_s": 1800.0},
                                    {"domain": "math_tool", "target_s": 300.0}]}}"#,
        )
        .unwrap();
        let t = s.trace.expect("trace config");
        assert_eq!(t.requests, 5_000);
        assert_eq!(t.trace_seed, 21);
        assert_eq!(t.feed, TraceFeed::Materialized);
        assert_eq!(
            t.arrivals,
            ArrivalProcess::Diurnal {
                base_rate: 4.0,
                amplitude: 0.6,
                period_s: 3_600.0
            }
        );
        let slo = s.slo.expect("slo config");
        assert_eq!(slo.default_target_s, 900.0);
        assert_eq!(slo.shed_above, Some(256));
        assert_eq!(slo.target_for(TaskDomain::Swe), 1_800.0);
        assert_eq!(slo.target_for(TaskDomain::MathTool), 300.0);
        assert_eq!(slo.target_for(TaskDomain::Web), 900.0);
        // Defaults: streamed Poisson §8 mix.
        let d = scenario_from_json(r#"{"trace": {}}"#).unwrap();
        let t = d.trace.expect("default trace");
        assert_eq!(t.feed, TraceFeed::Streamed);
        assert_eq!(t.requests, 10_000);
        assert!(matches!(t.arrivals, ArrivalProcess::Poisson { .. }));
        let bursty = scenario_from_json(
            r#"{"trace": {"arrivals": {"kind": "bursty", "on_rate": 20.0}}}"#,
        )
        .unwrap();
        assert!(matches!(
            bursty.trace.unwrap().arrivals,
            ArrivalProcess::Bursty { on_rate, .. } if on_rate == 20.0
        ));
        let clean = scenario_from_json("{}").unwrap();
        assert!(clean.trace.is_none() && clean.slo.is_none());
        // Validation: degenerate knobs and barrier modes error.
        assert!(scenario_from_json(r#"{"trace": {"requests": 0}}"#).is_err());
        assert!(scenario_from_json(r#"{"trace": {"feed": "psychic"}}"#).is_err());
        assert!(scenario_from_json(
            r#"{"trace": {"arrivals": {"kind": "poisson", "rate": 0.0}}}"#
        )
        .is_err());
        assert!(scenario_from_json(
            r#"{"trace": {"arrivals": {"kind": "diurnal", "amplitude": 1.5}}}"#
        )
        .is_err());
        assert!(scenario_from_json(r#"{"mode": "sync+", "trace": {}}"#).is_err());
        assert!(scenario_from_json(r#"{"slo": {"shed_above": 0}}"#).is_err());
        assert!(scenario_from_json(r#"{"slo": {"default_target_s": -1.0}}"#).is_err());
        assert!(scenario_from_json(
            r#"{"slo": {"targets": [{"domain": "swe"}]}}"#
        )
        .is_err());
        assert!(scenario_from_json(
            r#"{"slo": {"targets": [{"domain": "atlantis", "target_s": 60.0}]}}"#
        )
        .is_err());
    }

    #[test]
    fn lookups() {
        assert_eq!(model_by_name("8b").unwrap().name, "Qwen3-8B");
        assert_eq!(model_by_name("moe").unwrap().name, "Qwen3-30B-A3B");
        assert!(model_by_name("moe").unwrap().moe.is_some());
        assert_eq!(mode_by_name("RollArt"), Some(Mode::RollArt));
        assert_eq!(domain_by_name("game"), Some(TaskDomain::Game));
        assert!(domain_by_name("nope").is_none());
    }
}
