//! GRPO group tracking + redundant environment rollouts (§6.3).
//!
//! GRPO needs G completed trajectories per prompt group.  RollArt may
//! launch G + R environments per group ("redundant environment
//! rollouts"); once G trajectories finish, the remaining in-flight
//! members are aborted — slow or failed environments never hold a
//! group hostage (Fig 14b: up to 1.62× rollout speedup).

use crate::rl::TrajectoryId;
use std::collections::BTreeMap;

/// What a completion means for its group.
#[derive(Clone, Debug, PartialEq)]
pub enum GroupOutcome {
    /// Group still needs more completions.
    Pending,
    /// This completion filled the group: abort these in-flight members.
    Filled { abort: Vec<TrajectoryId> },
    /// Completion arrived after the group was already filled (racing
    /// abort); the trajectory is surplus and must be dropped.
    Surplus,
}

#[derive(Clone, Debug)]
struct Group {
    need: usize,
    done: Vec<TrajectoryId>,
    inflight: Vec<TrajectoryId>,
    filled: bool,
}

/// Tracks all groups of one training iteration.
#[derive(Clone, Debug, Default)]
pub struct GroupTracker {
    groups: BTreeMap<u64, Group>,
    /// trajectory → group reverse index.
    index: BTreeMap<TrajectoryId, u64>,
}

impl GroupTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a group needing `need` completions.
    pub fn add_group(&mut self, group: u64, need: usize) {
        assert!(need > 0);
        let prev = self.groups.insert(
            group,
            Group {
                need,
                done: Vec::new(),
                inflight: Vec::new(),
                filled: false,
            },
        );
        assert!(prev.is_none(), "group {group} declared twice");
    }

    /// Register a launched trajectory (including redundant ones).
    pub fn launch(&mut self, group: u64, traj: TrajectoryId) {
        let g = self.groups.get_mut(&group).expect("unknown group");
        g.inflight.push(traj);
        self.index.insert(traj, group);
    }

    /// Redundancy of a group: launched − needed.
    pub fn redundancy(&self, group: u64) -> usize {
        let g = &self.groups[&group];
        (g.inflight.len() + g.done.len()).saturating_sub(g.need)
    }

    /// A trajectory failed (env failure / stale abort): remove it from
    /// its group so redundancy accounting stays correct.  Returns true
    /// if it was tracked.
    pub fn fail(&mut self, traj: TrajectoryId) -> bool {
        let Some(group) = self.index.remove(&traj) else {
            return false;
        };
        let g = self.groups.get_mut(&group).unwrap();
        g.inflight.retain(|&t| t != traj);
        true
    }

    /// A trajectory completed.  Returns the group outcome.
    pub fn complete(&mut self, traj: TrajectoryId) -> GroupOutcome {
        let Some(&group) = self.index.get(&traj) else {
            return GroupOutcome::Surplus;
        };
        let g = self.groups.get_mut(&group).unwrap();
        if g.filled {
            g.inflight.retain(|&t| t != traj);
            self.index.remove(&traj);
            return GroupOutcome::Surplus;
        }
        g.inflight.retain(|&t| t != traj);
        g.done.push(traj);
        if g.done.len() >= g.need {
            g.filled = true;
            let abort = std::mem::take(&mut g.inflight);
            for t in &abort {
                self.index.remove(t);
            }
            GroupOutcome::Filled { abort }
        } else {
            GroupOutcome::Pending
        }
    }

    /// Ids of a filled group's kept members.
    pub fn members(&self, group: u64) -> &[TrajectoryId] {
        &self.groups[&group].done
    }

    pub fn is_filled(&self, group: u64) -> bool {
        self.groups[&group].filled
    }

    /// All groups filled?
    pub fn all_filled(&self) -> bool {
        self.groups.values().all(|g| g.filled)
    }

    /// Groups still missing completions (diagnostics).
    pub fn pending_groups(&self) -> usize {
        self.groups.values().filter(|g| !g.filled).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn id(n: u64) -> TrajectoryId {
        TrajectoryId(n)
    }

    #[test]
    fn group_fills_at_need_and_aborts_stragglers() {
        let mut t = GroupTracker::new();
        t.add_group(0, 2);
        for i in 0..4 {
            t.launch(0, id(i)); // redundancy 2
        }
        assert_eq!(t.redundancy(0), 2);
        assert_eq!(t.complete(id(1)), GroupOutcome::Pending);
        match t.complete(id(3)) {
            GroupOutcome::Filled { abort } => {
                assert_eq!(abort, vec![id(0), id(2)]);
            }
            o => panic!("{o:?}"),
        }
        assert!(t.is_filled(0));
        assert_eq!(t.members(0), &[id(1), id(3)]);
    }

    #[test]
    fn surplus_after_filled() {
        let mut t = GroupTracker::new();
        t.add_group(0, 1);
        t.launch(0, id(0));
        t.launch(0, id(1));
        assert!(matches!(t.complete(id(0)), GroupOutcome::Filled { .. }));
        // id(1) completes anyway (abort raced): surplus, dropped.
        assert_eq!(t.complete(id(1)), GroupOutcome::Surplus);
    }

    #[test]
    fn failure_removes_from_group() {
        let mut t = GroupTracker::new();
        t.add_group(0, 2);
        t.launch(0, id(0));
        t.launch(0, id(1));
        t.launch(0, id(2));
        assert!(t.fail(id(0)));
        assert!(!t.fail(id(0)), "double-fail is a no-op");
        assert_eq!(t.complete(id(1)), GroupOutcome::Pending);
        assert!(matches!(t.complete(id(2)), GroupOutcome::Filled { .. }));
    }

    #[test]
    fn group_can_starve_without_redundancy() {
        // Without redundancy, a failure leaves the group unfillable —
        // the scheduler must relaunch (this is what R2+redundancy buy).
        let mut t = GroupTracker::new();
        t.add_group(0, 2);
        t.launch(0, id(0));
        t.launch(0, id(1));
        t.fail(id(0));
        t.complete(id(1));
        assert!(!t.all_filled());
        assert_eq!(t.pending_groups(), 1);
        // relaunch path
        t.launch(0, id(7));
        assert!(matches!(t.complete(id(7)), GroupOutcome::Filled { .. }));
        assert!(t.all_filled());
    }

    #[test]
    fn multiple_groups_independent() {
        let mut t = GroupTracker::new();
        t.add_group(0, 1);
        t.add_group(1, 1);
        t.launch(0, id(0));
        t.launch(1, id(1));
        t.complete(id(0));
        assert!(t.is_filled(0));
        assert!(!t.is_filled(1));
        assert_eq!(t.pending_groups(), 1);
    }

    #[test]
    #[should_panic(expected = "declared twice")]
    fn duplicate_group_panics() {
        let mut t = GroupTracker::new();
        t.add_group(0, 1);
        t.add_group(0, 1);
    }
}
