//! EnvManager: per-trajectory environment lifecycle (§6.1).
//!
//! "Each EnvManager is a lightweight controller that manages the
//! lifecycle of a single environment to collect trajectories" — here as
//! a pure state machine over a sampled [`TrajectoryShape`], so the DES
//! driver owns all timing.  The real harness ([`crate::exec`]) runs the
//! same lifecycle against live environments and the PJRT engine.

use crate::env::profile::TrajectoryShape;
use crate::proxy::SimRequest;
use crate::rl::{Trajectory, TrajectoryId, Turn, Version};

/// Lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnvPhase {
    /// Waiting for `env.reset` (container init) to finish.
    Resetting,
    /// Generation request in flight at the LLMProxy.
    Generating,
    /// `env.step` executing on the CPU cluster.
    Stepping,
    /// Trajectory complete (awaiting reward / deposited).
    Done,
    /// Aborted (stale or redundant).
    Aborted,
}

/// What the driver must do next after an event.
#[derive(Clone, Debug, PartialEq)]
pub enum EnvAction {
    /// Send this generation request to the LLMProxy.
    Generate(SimRequest),
    /// Run `env.step` (driver samples its latency).
    StepEnv,
    /// Trajectory finished: dispatch to reward.
    Complete,
}

/// Per-trajectory controller over a pre-sampled workload shape.
#[derive(Clone, Debug)]
pub struct EnvManagerSim {
    pub id: TrajectoryId,
    pub traj: Trajectory,
    shape: TrajectoryShape,
    turn_idx: usize,
    pub phase: EnvPhase,
    /// Context tokens accumulated so far (prefix-cached).
    ctx: f64,
}

impl EnvManagerSim {
    pub fn new(
        id: TrajectoryId,
        shape: TrajectoryShape,
        version: Version,
        group: u64,
        now: f64,
    ) -> Self {
        let mut traj = Trajectory::new(id, shape.domain, version);
        traj.group = group;
        traj.started_at = now;
        EnvManagerSim {
            id,
            traj,
            shape,
            turn_idx: 0,
            phase: EnvPhase::Resetting,
            ctx: 0.0,
        }
    }

    pub fn domain(&self) -> crate::env::TaskDomain {
        self.shape.domain
    }

    pub fn turns_total(&self) -> usize {
        self.shape.turns()
    }

    pub fn turns_done(&self) -> usize {
        self.turn_idx
    }

    fn gen_request(&self, version: Version) -> SimRequest {
        let (obs, act) = self.shape.per_turn[self.turn_idx];
        let new_tokens = if self.turn_idx == 0 {
            self.shape.initial_prompt_tokens + obs
        } else {
            obs
        };
        let _ = version;
        SimRequest {
            traj: self.id,
            domain: self.shape.domain,
            new_tokens,
            ctx_tokens: self.ctx,
            decode_budget: act,
        }
    }

    /// `env.reset` finished: issue the first generation request.
    pub fn on_reset_done(&mut self, version: Version) -> EnvAction {
        assert_eq!(self.phase, EnvPhase::Resetting);
        self.phase = EnvPhase::Generating;
        EnvAction::Generate(self.gen_request(version))
    }

    /// Regenerate the current turn's request (crash recovery).  The
    /// manager is a pure state machine over a pre-sampled shape, so the
    /// regenerated request is deterministically identical to the one
    /// originally dispatched — the driver uses this to replay work
    /// whose completion was in flight on an engine when it died.
    pub fn regen_request(&self, version: Version) -> SimRequest {
        assert_eq!(self.phase, EnvPhase::Generating);
        self.gen_request(version)
    }

    /// Generation for the current turn finished under `version`:
    /// record the turn and run the environment.
    pub fn on_generation_done(&mut self, version: Version) -> EnvAction {
        assert_eq!(self.phase, EnvPhase::Generating);
        let (obs, act) = self.shape.per_turn[self.turn_idx];
        let new_tokens = if self.turn_idx == 0 {
            self.shape.initial_prompt_tokens + obs
        } else {
            obs
        };
        self.traj.turns.push(Turn {
            obs_tokens: vec![0; new_tokens as usize],
            action_tokens: vec![0; act as usize],
            version,
        });
        self.ctx += new_tokens + act;
        self.phase = EnvPhase::Stepping;
        EnvAction::StepEnv
    }

    /// `env.step` finished: next turn or complete.
    pub fn on_env_step_done(&mut self, version: Version, now: f64) -> EnvAction {
        assert_eq!(self.phase, EnvPhase::Stepping);
        self.turn_idx += 1;
        if self.turn_idx >= self.shape.turns() {
            self.phase = EnvPhase::Done;
            self.traj.finished_at = Some(now);
            EnvAction::Complete
        } else {
            self.phase = EnvPhase::Generating;
            EnvAction::Generate(self.gen_request(version))
        }
    }

    /// Abort (stale under α, or redundant after its group completed).
    pub fn abort(&mut self) {
        self.phase = EnvPhase::Aborted;
    }

    /// Drop the per-turn token storage of a *terminal* trajectory.
    /// Long trace replays (10⁶+ requests) keep every manager in the
    /// slab; releasing the token vectors once the trajectory is
    /// deposited (its clone lives in the sample buffer) or aborted
    /// bounds slab memory by the in-flight set, not the trace length.
    pub fn release(&mut self) {
        debug_assert!(self.is_terminal());
        self.traj.turns = Vec::new();
        self.shape.per_turn = Vec::new();
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self.phase, EnvPhase::Done | EnvPhase::Aborted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::profile::DomainProfile;
    use crate::env::TaskDomain;
    use crate::simkit::SimRng;

    fn mgr(domain: TaskDomain, seed: u64) -> EnvManagerSim {
        let mut rng = SimRng::new(seed);
        let shape = DomainProfile::of(domain).sample_trajectory(&mut rng);
        EnvManagerSim::new(TrajectoryId(1), shape, Version(3), 0, 0.0)
    }

    #[test]
    fn full_lifecycle_runs_all_turns() {
        let mut m = mgr(TaskDomain::Web, 0);
        let total = m.turns_total();
        let mut action = m.on_reset_done(Version(3));
        let mut gens = 0;
        loop {
            match action {
                EnvAction::Generate(req) => {
                    gens += 1;
                    assert_eq!(req.traj, TrajectoryId(1));
                    action = m.on_generation_done(Version(3));
                }
                EnvAction::StepEnv => {
                    action = m.on_env_step_done(Version(3), 1.0);
                }
                EnvAction::Complete => break,
            }
        }
        assert_eq!(gens, total);
        assert_eq!(m.phase, EnvPhase::Done);
        assert_eq!(m.traj.turns.len(), total);
        assert_eq!(m.traj.finished_at, Some(1.0));
    }

    #[test]
    fn regen_request_replays_the_dispatched_turn() {
        let mut m = mgr(TaskDomain::Web, 7);
        let EnvAction::Generate(orig) = m.on_reset_done(Version(2)) else {
            panic!()
        };
        assert_eq!(m.regen_request(Version(2)), orig);
        // Later turns replay identically too.
        m.on_generation_done(Version(2));
        if let EnvAction::Generate(r2) = m.on_env_step_done(Version(2), 0.5) {
            assert_eq!(m.regen_request(Version(2)), r2);
        } else {
            panic!("web trajectories have >1 turn at this seed");
        }
    }

    #[test]
    fn first_request_includes_initial_prompt() {
        let mut m = mgr(TaskDomain::Swe, 1);
        let EnvAction::Generate(req) = m.on_reset_done(Version(0)) else {
            panic!()
        };
        assert!(req.new_tokens >= 1200.0, "{}", req.new_tokens);
        assert_eq!(req.ctx_tokens, 0.0);
    }

    #[test]
    fn context_grows_across_turns() {
        let mut m = mgr(TaskDomain::Web, 2);
        let EnvAction::Generate(r1) = m.on_reset_done(Version(0)) else {
            panic!()
        };
        m.on_generation_done(Version(0));
        let EnvAction::Generate(r2) = m.on_env_step_done(Version(0), 0.5) else {
            panic!()
        };
        assert_eq!(r2.ctx_tokens, r1.new_tokens + r1.decode_budget);
        assert!(r2.new_tokens < r1.new_tokens, "no initial prompt on turn 2");
    }

    #[test]
    fn version_recorded_per_turn() {
        // Mid-trajectory weight update: turns carry distinct versions —
        // the input to RollArt's per-turn staleness test.
        let mut m = mgr(TaskDomain::Web, 3);
        m.on_reset_done(Version(0));
        m.on_generation_done(Version(0));
        if let EnvAction::Generate(_) = m.on_env_step_done(Version(1), 0.1) {
            m.on_generation_done(Version(1));
        }
        assert_eq!(m.traj.turns[0].version, Version(0));
        assert_eq!(m.traj.turns[1].version, Version(1));
        assert_eq!(m.traj.min_version(), Version(0));
        assert_eq!(m.traj.max_version(), Version(1));
    }

    #[test]
    fn abort_is_terminal() {
        let mut m = mgr(TaskDomain::Game, 4);
        m.on_reset_done(Version(0));
        m.abort();
        assert!(m.is_terminal());
        assert_eq!(m.phase, EnvPhase::Aborted);
    }
}
