//! The six-step weight-synchronization protocol (§6.2, Fig 9).
//!
//! Per iteration: ① `get_batch` (block until the SampleBuffer holds a
//! batch) → ② `suspend` the LLMProxy → ③ `update` inference weights →
//! ④ `resume` → ⑤ `recomp` in-flight KV caches → ⑥ `train_step`
//! overlapped with the resumed rollout.
//!
//! [`SyncProtocol::iteration`] computes one iteration's time accounting
//! from the component costs; the DES drivers feed it measured values,
//! and the Fig 10/13/14 benches compare the resulting schedules across
//! baselines.

/// Component costs of one iteration, as measured by a driver.
#[derive(Clone, Copy, Debug, Default)]
pub struct IterationCost {
    /// Time spent blocked in ① waiting for the batch (rollout-bound).
    pub get_batch_wait_s: f64,
    /// Exposed weight-update cost at ③ (Mooncake exposed pull + GPU
    /// load, or full transfer for synchronous schemes).
    pub weight_update_s: f64,
    /// KV recomputation for in-flight trajectories at ⑤.
    pub recompute_s: f64,
    /// The training step at ⑥.
    pub train_s: f64,
    /// Suspend/resume command round-trips (small).
    pub command_s: f64,
}

/// Scheduling policy: what overlaps what.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncProtocol {
    /// RollArt (Fig 9): training overlaps the resumed rollout; only
    /// suspend → update → resume → recomp is exposed to rollout, and
    /// the *next* get_batch wait absorbs the train step.
    AsyncOverlapped,
    /// Synchronous: every component serializes (Fig 2-Left).
    Synchronous,
}

impl SyncProtocol {
    /// Wall-clock the iteration adds to the pipeline's critical path.
    pub fn iteration(&self, c: &IterationCost) -> f64 {
        match self {
            SyncProtocol::Synchronous => {
                // rollout wait + transfer + recomp + training, serial.
                c.get_batch_wait_s
                    + c.command_s
                    + c.weight_update_s
                    + c.recompute_s
                    + c.train_s
            }
            SyncProtocol::AsyncOverlapped => {
                // Training overlaps the next rollout window; it only
                // extends the critical path when it outlasts that
                // window (rollout-bound vs train-bound regimes).
                let exposed_sync = c.command_s + c.weight_update_s + c.recompute_s;
                let rollout_window = c.get_batch_wait_s;
                exposed_sync + rollout_window.max(c.train_s)
            }
        }
    }

    /// GPU "dependency bubble" time per iteration (Fig 2): how long
    /// rollout GPUs sit idle.
    pub fn rollout_bubble(&self, c: &IterationCost) -> f64 {
        match self {
            SyncProtocol::Synchronous => {
                c.command_s + c.weight_update_s + c.recompute_s + c.train_s
            }
            SyncProtocol::AsyncOverlapped => {
                c.command_s + c.weight_update_s + c.recompute_s
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> IterationCost {
        IterationCost {
            get_batch_wait_s: 200.0,
            weight_update_s: 30.0,
            recompute_s: 5.0,
            train_s: 80.0,
            command_s: 0.5,
        }
    }

    #[test]
    fn async_hides_training_in_rollout_window() {
        let c = cost();
        let sync = SyncProtocol::Synchronous.iteration(&c);
        let asyn = SyncProtocol::AsyncOverlapped.iteration(&c);
        assert_eq!(sync, 315.5);
        // async: 35.5 exposed + max(200, 80) = 235.5
        assert!((asyn - 235.5).abs() < 1e-9, "{asyn}");
        assert!(asyn < sync);
    }

    #[test]
    fn train_bound_regime_exposes_training() {
        // When training outlasts the rollout window (small rollout
        // fleet), async degrades gracefully to train-bound.
        let c = IterationCost {
            get_batch_wait_s: 10.0,
            train_s: 100.0,
            ..cost()
        };
        let asyn = SyncProtocol::AsyncOverlapped.iteration(&c);
        assert!((asyn - (35.5 + 100.0)).abs() < 1e-9, "{asyn}");
    }

    #[test]
    fn bubbles_shrink_under_async() {
        let c = cost();
        assert!(
            SyncProtocol::AsyncOverlapped.rollout_bubble(&c)
                < SyncProtocol::Synchronous.rollout_bubble(&c)
        );
        // async bubble excludes exactly the training time
        assert!(
            (SyncProtocol::Synchronous.rollout_bubble(&c)
                - SyncProtocol::AsyncOverlapped.rollout_bubble(&c)
                - c.train_s)
                .abs()
                < 1e-12
        );
    }

    #[test]
    fn zero_cost_iteration_is_zero() {
        let c = IterationCost::default();
        assert_eq!(SyncProtocol::Synchronous.iteration(&c), 0.0);
        assert_eq!(SyncProtocol::AsyncOverlapped.iteration(&c), 0.0);
    }
}
