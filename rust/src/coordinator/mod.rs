//! Control plane: trajectory-level rollout orchestration and the
//! asynchronous training protocol (§6).
//!
//! The control plane is *system-managed*: users declare nothing here.
//! Three pieces:
//!
//! * [`EnvManagerSim`] — the per-trajectory lifecycle state machine of
//!   §6.1 (reset → {generate ↔ env.step}* → reward), expressed as a
//!   pure transition function the harnesses drive with events;
//! * [`GroupTracker`] — GRPO group accounting with *redundant
//!   environment rollouts* (§6.3): launch more environments than the
//!   group needs, keep the first finishers, abort the stragglers;
//! * [`SyncProtocol`] — the six-step weight-synchronization sequence of
//!   §6.2 (get_batch → suspend → update → resume → recomp → train),
//!   with the time accounting that decides what overlaps what.

mod envmgr;
mod groups;
mod sync;

pub use envmgr::{EnvAction, EnvManagerSim, EnvPhase};
pub use groups::{GroupOutcome, GroupTracker};
pub use sync::{IterationCost, SyncProtocol};
