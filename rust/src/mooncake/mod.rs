//! Mooncake-style asynchronous cross-cluster weight store (§6.3).
//!
//! After each training step, updated weights are bucketized (1 GB) and
//! *published* to a CPU-resident store over the low-bandwidth
//! cross-cluster link; inference workers then *pull* buckets on demand
//! over high-bandwidth intra-cluster links, pipelined behind the push.
//! Both stages overlap with ongoing rollout; the only unavoidable
//! *exposed* cost is the in-GPU weight (re)load at the suspend point of
//! the sync protocol plus whatever pull tail the overlap window did not
//! cover (paper Table 4: 1.4–9.6 s exposed vs 38.6–157 s naive).
//!
//! Constants are calibrated to Table 4's measurements: push goodput
//! ≈0.45 GB/s (cross-cluster TCP shared with rollout traffic),
//! aggregate pull ≈2.1 GB/s, GPU load ≈6.5 GB/s.


const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Configuration of the bucketized store.
#[derive(Clone, Debug)]
pub struct MooncakeConfig {
    /// Bucket granularity in bytes (paper: ~1 GB).
    pub bucket_bytes: f64,
    /// Achieved push goodput training-cluster → store (cross-cluster,
    /// shared with trajectory traffic).
    pub push_bytes_per_s: f64,
    /// Aggregate pull goodput store → inference workers (intra-cluster).
    pub pull_bytes_per_s: f64,
    /// Host→GPU weight load bandwidth at the suspend point.
    pub gpu_load_bytes_per_s: f64,
    /// Fixed per-bucket coordination latency (metadata RPC).
    pub per_bucket_latency_s: f64,
}

impl Default for MooncakeConfig {
    fn default() -> Self {
        MooncakeConfig {
            bucket_bytes: 1.0 * GB,
            push_bytes_per_s: 0.45 * GB,
            pull_bytes_per_s: 2.1 * GB,
            gpu_load_bytes_per_s: 6.5 * GB,
            per_bucket_latency_s: 0.01,
        }
    }
}

impl MooncakeConfig {
    /// Number of buckets a `bytes`-sized payload splits into.  An empty
    /// payload is zero buckets (it must cost nothing — the regression
    /// the old `.max(1.0)` clamp hid was an empty transfer booking one
    /// full per-bucket latency); a sub-bucket payload is exactly one
    /// *partial* bucket.
    pub fn bucket_count(&self, bytes: f64) -> usize {
        if bytes <= 0.0 {
            return 0;
        }
        (bytes / self.bucket_bytes).ceil().max(1.0) as usize
    }

    /// The sequenced bucket sizes of one `bytes`-sized transfer:
    /// `bucket_count - 1` full buckets followed by the remainder tail
    /// (which may be a full bucket when `bytes` divides evenly).
    /// Conservation holds by construction: the sizes sum to `bytes`.
    pub fn bucket_sizes(&self, bytes: f64) -> Vec<f64> {
        let n = self.bucket_count(bytes);
        if n == 0 {
            return Vec::new();
        }
        let mut sizes = vec![self.bucket_bytes; n - 1];
        sizes.push(bytes - self.bucket_bytes * (n - 1) as f64);
        sizes
    }
}

/// Cost decomposition of one weight synchronization (Table 4 rows).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SyncCost {
    /// Streaming updated weights to the store (hidden behind rollout).
    pub push_s: f64,
    /// Total pull cost across workers (mostly hidden).
    pub acc_pull_s: f64,
    /// Residual cost the rollout actually observes.
    pub exposed_s: f64,
    /// What a synchronous design (veRL-style push-to-workers) would
    /// block on: push + accumulated pull, no overlap.
    pub naive_s: f64,
}

/// The weight store: versions + cost model.
#[derive(Clone, Debug)]
pub struct MooncakeStore {
    cfg: MooncakeConfig,
    /// Latest fully-published weight version.
    version: u64,
    /// Bytes pushed across the lifetime (stats).
    pub bytes_pushed: f64,
    pub bytes_pulled: f64,
}

impl MooncakeStore {
    pub fn new(cfg: MooncakeConfig) -> Self {
        MooncakeStore {
            cfg,
            version: 0,
            bytes_pushed: 0.0,
            bytes_pulled: 0.0,
        }
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// The bucket model this store prices transfers with.
    pub fn config(&self) -> &MooncakeConfig {
        &self.cfg
    }

    fn buckets(&self, bytes: f64) -> usize {
        self.cfg.bucket_count(bytes)
    }

    /// Time to stream `bytes` of weights to the store.
    pub fn push_time(&self, bytes: f64) -> f64 {
        let n = self.buckets(bytes);
        bytes / self.cfg.push_bytes_per_s + n as f64 * self.cfg.per_bucket_latency_s
    }

    /// Total (accumulated) pull time across the inference fleet.
    pub fn acc_pull_time(&self, bytes: f64) -> f64 {
        let n = self.buckets(bytes);
        bytes / self.cfg.pull_bytes_per_s + n as f64 * self.cfg.per_bucket_latency_s
    }

    /// Host→GPU weight (re)load time at the suspend point — the one
    /// unavoidable exposed cost of any dissemination strategy.  The
    /// weight plane ([`crate::weights`]) charges this per engine at its
    /// cutover.
    pub fn gpu_load_time(&self, bytes: f64) -> f64 {
        bytes / self.cfg.gpu_load_bytes_per_s
    }

    /// Compute one synchronization's cost decomposition.
    ///
    /// `overlap_window_s` is how much ongoing-rollout time is available
    /// to hide the push+pull pipeline (the pipeline driver passes the
    /// real remaining-rollout estimate; `f64::INFINITY` = fully
    /// overlapped pulls, leaving only the GPU load exposed).
    pub fn sync(&mut self, bytes: f64, overlap_window_s: f64) -> SyncCost {
        self.version += 1;
        if bytes <= 0.0 {
            // Empty payload: zero buckets, zero cost everywhere (the
            // version still advances — a publish of nothing is a
            // no-op flip, not a stall).
            return SyncCost::default();
        }
        let push = self.push_time(bytes);
        let acc_pull = self.acc_pull_time(bytes);
        let n = self.buckets(bytes) as f64;

        // Pipelined completion: pulls trail the push bucket-by-bucket.
        let b_push = push / n;
        let b_pull = acc_pull / n;
        let pipeline_end = if b_push >= b_pull {
            push + b_pull
        } else {
            b_push + acc_pull
        };

        // Pull tail not covered by the rollout overlap window.
        let uncovered = (pipeline_end - overlap_window_s).max(0.0);
        // Unavoidable: (re)loading the new weights into GPU memory at
        // the suspend point.
        let gpu_load = bytes / self.cfg.gpu_load_bytes_per_s;
        let exposed = uncovered + gpu_load + n * self.cfg.per_bucket_latency_s;

        self.bytes_pushed += bytes;
        self.bytes_pulled += bytes;

        SyncCost {
            push_s: push,
            acc_pull_s: acc_pull,
            exposed_s: exposed,
            naive_s: push + acc_pull,
        }
    }
}

impl Default for MooncakeStore {
    fn default() -> Self {
        Self::new(MooncakeConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::{QWEN3_14B, QWEN3_32B, QWEN3_8B};

    fn sync_model(spec: &crate::llm::LlmSpec) -> SyncCost {
        let mut store = MooncakeStore::default();
        store.sync(spec.weight_bytes(), f64::INFINITY)
    }

    #[test]
    fn table4_push_times() {
        // Paper: 32.4 / 67.8 / 127.3 s push.
        let cases = [(&QWEN3_8B, 32.4), (&QWEN3_14B, 67.8), (&QWEN3_32B, 127.3)];
        for (spec, paper) in cases {
            let c = sync_model(spec);
            assert!(
                (c.push_s - paper).abs() / paper < 0.1,
                "{}: push {} vs paper {paper}",
                spec.name,
                c.push_s
            );
        }
    }

    #[test]
    fn table4_acc_pull_times() {
        // Paper: 6.2 / 16.3 / 29.7 s accumulated pull (±35%: aggregate
        // pull bandwidth varies with fleet size; shape is what matters).
        let cases = [(&QWEN3_8B, 6.2), (&QWEN3_14B, 16.3), (&QWEN3_32B, 29.7)];
        for (spec, paper) in cases {
            let c = sync_model(spec);
            assert!(
                (c.acc_pull_s - paper).abs() / paper < 0.35,
                "{}: pull {} vs paper {paper}",
                spec.name,
                c.acc_pull_s
            );
        }
    }

    #[test]
    fn exposed_cost_band_and_growth() {
        // Paper: exposed 1.4 / 5.1 / 9.6 s; grows with model size and
        // stays under 10% of naive.
        let mut last = 0.0;
        for spec in [&QWEN3_8B, &QWEN3_14B, &QWEN3_32B] {
            let c = sync_model(spec);
            assert!(c.exposed_s > last, "exposed must grow with size");
            assert!(
                c.exposed_s < 0.1 * c.naive_s,
                "{}: exposed {} vs naive {}",
                spec.name,
                c.exposed_s,
                c.naive_s
            );
            assert!(c.exposed_s < 12.0, "{}", c.exposed_s);
            last = c.exposed_s;
        }
    }

    #[test]
    fn overlap_hides_most_of_pull() {
        // Paper: "asynchronous overlap hides 67-78% of the pull cost".
        let c = sync_model(&QWEN3_32B);
        let hidden = 1.0 - c.exposed_s / (c.acc_pull_s + c.push_s * 0.0);
        assert!(hidden > 0.6, "hidden fraction {hidden}");
    }

    #[test]
    fn short_window_exposes_pull_tail() {
        let mut store = MooncakeStore::default();
        let full = store.sync(QWEN3_8B.weight_bytes(), f64::INFINITY);
        let mut store2 = MooncakeStore::default();
        let cut = store2.sync(QWEN3_8B.weight_bytes(), 5.0);
        assert!(cut.exposed_s > full.exposed_s + 10.0, "{cut:?} vs {full:?}");
    }

    #[test]
    fn version_advances_per_sync() {
        let mut store = MooncakeStore::default();
        assert_eq!(store.version(), 0);
        store.sync(1e9, f64::INFINITY);
        store.sync(1e9, f64::INFINITY);
        assert_eq!(store.version(), 2);
        assert!((store.bytes_pushed - 2e9).abs() < 1.0);
    }

    #[test]
    fn sub_bucket_payload_is_one_partial_bucket() {
        // The one-bucket edge: a payload smaller than the bucket
        // granularity is one *partial* bucket — it pays exactly one
        // per-bucket latency and moves exactly its own bytes, not a
        // full bucket's worth.
        let cfg = MooncakeConfig::default();
        let bytes = 0.3 * GB;
        assert_eq!(cfg.bucket_count(bytes), 1);
        let sizes = cfg.bucket_sizes(bytes);
        assert_eq!(sizes.len(), 1);
        assert!((sizes[0] - bytes).abs() < 1e-6, "{sizes:?}");
        let store = MooncakeStore::default();
        let expect = bytes / cfg.pull_bytes_per_s + cfg.per_bucket_latency_s;
        assert!((store.acc_pull_time(bytes) - expect).abs() < 1e-9);
    }

    #[test]
    fn zero_byte_payload_costs_nothing() {
        let cfg = MooncakeConfig::default();
        assert_eq!(cfg.bucket_count(0.0), 0);
        assert!(cfg.bucket_sizes(0.0).is_empty());
        assert!(cfg.bucket_sizes(-1.0).is_empty());
        let mut store = MooncakeStore::default();
        assert_eq!(store.push_time(0.0), 0.0);
        assert_eq!(store.acc_pull_time(0.0), 0.0);
        let c = store.sync(0.0, f64::INFINITY);
        assert_eq!(c, SyncCost::default(), "empty sync must be free");
        assert_eq!(store.version(), 1, "the version still flips");
        assert_eq!(store.bytes_pushed, 0.0);
    }

    #[test]
    fn bucket_sizes_conserve_bytes_and_order() {
        let cfg = MooncakeConfig::default();
        for bytes in [0.5 * GB, 1.0 * GB, 1.5 * GB, 15.26 * GB, 61.02 * GB] {
            let sizes = cfg.bucket_sizes(bytes);
            assert_eq!(sizes.len(), cfg.bucket_count(bytes));
            let sum: f64 = sizes.iter().sum();
            assert!((sum - bytes).abs() < 1e-6 * bytes.max(1.0), "{bytes}: {sum}");
            for (i, s) in sizes.iter().enumerate() {
                assert!(*s > 0.0, "bucket {i} of {bytes} is empty");
                assert!(*s <= cfg.bucket_bytes + 1e-6);
            }
        }
    }

    #[test]
    fn naive_matches_verl_style_blocking() {
        let c = sync_model(&QWEN3_32B);
        // Paper: naive 157.0 s for 32B.
        assert!((c.naive_s - 157.0).abs() / 157.0 < 0.15, "{}", c.naive_s);
    }
}
