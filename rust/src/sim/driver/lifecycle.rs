//! Trajectory-lifecycle state machine.
//!
//! Every trajectory the driver owns moves through one explicit phase
//! chain —
//!
//! ```text
//! Queued → Prefilling → Decoding → EnvStep ─┬→ Prefilling (next turn)
//!                                           └→ Reward → Deposited
//! ```
//!
//! — with three cross-cutting edges shared by every scenario:
//!
//! * **Suspended**: the request is parked (weight-sync suspend, or the
//!   target pool has no live engine); it re-enters Prefilling/Decoding
//!   on resume/recovery.
//! * **Recovering**: the request was drained off a crashed engine and
//!   is being re-queued (trajectory-level fault recovery).
//! * **Aborted**: terminal — stale under α, redundant after its group
//!   filled, surplus, or its env worker died.
//!
//! Colocated engines process prefill and decode in one continuous
//! batch, so the driver cannot observe the Prefilling→Decoding boundary
//! there and collapses it (Prefilling→EnvStep is a legal edge).  The PD
//! execution mode *does* observe it: the boundary is exactly the KV
//! transfer between pools.
//!
//! The [`LifecycleTracker`] is the driver's single funnel for phase
//! changes: it validates each edge against the table above, counts
//! edges, measures *phase residency* (time spent in each phase, per
//! visit), and records (rather than panics on) violations so a
//! modeling bug surfaces as a failed invariant check, not a poisoned
//! run.  The fault-recovery and autoscaler hooks that used to be
//! scattered through the monolithic driver hang off these edges in
//! [`super::core`]; the residency histograms feed the `fig_phases`
//! bench (a Fig 5-style per-mode breakdown) and the per-class PD
//! elastic controller ([`crate::elastic::PdAutoScaler`]).

use crate::metrics::Histogram;
use std::collections::BTreeMap;

/// Driver-visible phase of one trajectory.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TrajPhase {
    /// Launched; waiting for `env.reset` (or a reset retry).
    Queued,
    /// Generation request dispatched; prefill not yet known complete.
    /// In PD mode this also covers the KV transfer to the decode pool.
    Prefilling,
    /// Decode phase in flight (observable in PD mode; colocated engines
    /// collapse Prefilling→EnvStep).
    Decoding,
    /// `env.step` executing on the CPU cluster.
    EnvStep,
    /// Reward invocation in flight, or scored and staged awaiting its
    /// GRPO group to fill.
    Reward,
    /// Terminal: entered the sample buffer with its whole group.
    Deposited,
    /// Request parked while the proxy is suspended / target pool down.
    Suspended,
    /// Request drained off a crashed engine, being re-queued.
    Recovering,
    /// Terminal: stale, redundant, surplus, or env-worker death.
    Aborted,
}

impl TrajPhase {
    pub fn is_terminal(self) -> bool {
        matches!(self, TrajPhase::Deposited | TrajPhase::Aborted)
    }

    /// Stable lowercase label (trace span names; never reformatted, so
    /// committed trace files stay diffable).
    pub fn label(self) -> &'static str {
        match self {
            TrajPhase::Queued => "queued",
            TrajPhase::Prefilling => "prefilling",
            TrajPhase::Decoding => "decoding",
            TrajPhase::EnvStep => "env-step",
            TrajPhase::Reward => "reward",
            TrajPhase::Deposited => "deposited",
            TrajPhase::Suspended => "suspended",
            TrajPhase::Recovering => "recovering",
            TrajPhase::Aborted => "aborted",
        }
    }

    /// Is `self → to` a legal edge?  Self-loops on non-terminal phases
    /// are legal (e.g. a parked request re-parked because its pool is
    /// still down).
    pub fn can_transition(self, to: TrajPhase) -> bool {
        use TrajPhase::*;
        if self.is_terminal() {
            return false;
        }
        if self == to {
            return true;
        }
        match (self, to) {
            (Queued, Prefilling | Suspended | Aborted) => true,
            (Prefilling, Decoding | EnvStep | Recovering | Suspended | Aborted) => true,
            (Decoding, EnvStep | Recovering | Suspended | Aborted) => true,
            // EnvStep → Suspended: the step finished while the proxy
            // was suspended for weight sync (or the target pool was
            // down), so the next turn's request parks.
            (EnvStep, Prefilling | Reward | Suspended | Aborted) => true,
            (Reward, Deposited | Aborted) => true,
            (Suspended, Prefilling | Decoding | Recovering | Aborted) => true,
            (Recovering, Prefilling | Decoding | Suspended | Aborted) => true,
            _ => false,
        }
    }
}

/// One recorded transition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LifecycleEdge {
    pub from: TrajPhase,
    pub to: TrajPhase,
    /// False when the edge violated the transition table (recorded, not
    /// applied-around — the tracker still moves to `to` so the run
    /// continues deterministically).
    pub legal: bool,
    /// Simulation time the trajectory entered `from` — the start of the
    /// phase span this edge closes.  The telemetry plane emits each
    /// completed visit as the trace span `[since_s, now]`, computed
    /// with the same arithmetic as the residency booking so the span
    /// timeline and [`LifecycleStats`] cannot drift apart.
    pub since_s: f64,
}

/// Aggregate lifecycle activity of one run (exposed through
/// [`super::run_traced`] for invariant checks and diagnostics).
#[derive(Clone, Debug, Default)]
pub struct LifecycleStats {
    /// Trajectories ever spawned.
    pub spawned: u64,
    /// Edge → traversal count.
    pub edges: BTreeMap<(TrajPhase, TrajPhase), u64>,
    /// Transitions that violated the table (must be 0 in a correct
    /// driver; asserted by the driver's invariant tests).
    pub violations: u64,
    /// Per-visit phase-residency samples: every time a trajectory
    /// *leaves* a phase, the seconds it spent there are recorded under
    /// that phase (terminal phases are never left, so they have no
    /// residency).  Mutable access because [`Histogram`] quantiles
    /// sort lazily.
    pub residency: BTreeMap<TrajPhase, Histogram>,
    /// Total residency seconds per phase (cheap running sums; the
    /// per-iteration deltas drive the PD elastic controller's
    /// prefill-bound detector).
    pub residency_totals: BTreeMap<TrajPhase, f64>,
}

impl LifecycleStats {
    /// Traversals of one edge.
    pub fn edge(&self, from: TrajPhase, to: TrajPhase) -> u64 {
        self.edges.get(&(from, to)).copied().unwrap_or(0)
    }

    /// Total arrivals into `phase`.
    pub fn entered(&self, phase: TrajPhase) -> u64 {
        self.edges
            .iter()
            .filter(|((from, to), _)| *to == phase && *from != phase)
            .map(|(_, n)| n)
            .sum()
    }

    /// Total seconds trajectories spent in `phase` (completed visits).
    pub fn residency_s(&self, phase: TrajPhase) -> f64 {
        self.residency_totals.get(&phase).copied().unwrap_or(0.0)
    }

    /// Mean seconds per completed visit to `phase`.
    pub fn mean_residency_s(&self, phase: TrajPhase) -> f64 {
        match self.residency.get(&phase) {
            Some(h) if !h.is_empty() => h.mean(),
            _ => 0.0,
        }
    }
}

/// Phase registry for every trajectory of one run.
#[derive(Clone, Debug, Default)]
pub struct LifecycleTracker {
    phases: Vec<TrajPhase>,
    /// Simulation time each trajectory entered its current phase.
    entered_at: Vec<f64>,
    stats: LifecycleStats,
}

impl LifecycleTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a trajectory launched at simulation time `now` (starts
    /// Queued).  Returns its index, which the driver keeps equal to
    /// the mgr index.
    pub fn spawn_at(&mut self, now: f64) -> usize {
        self.phases.push(TrajPhase::Queued);
        self.entered_at.push(now);
        self.stats.spawned += 1;
        self.phases.len() - 1
    }

    pub fn phase(&self, idx: usize) -> TrajPhase {
        self.phases[idx]
    }

    pub fn len(&self) -> usize {
        self.phases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.phases.is_empty()
    }

    /// Move trajectory `idx` to `to` at simulation time `now`,
    /// validating the edge and recording the residency of the phase
    /// being left.  Self-loops are counted but legal (the segment
    /// still books under the phase); terminal-exit or table-violating
    /// edges increment `violations`.  The move is applied either way
    /// so the run stays deterministic.
    pub fn transition_at(&mut self, idx: usize, to: TrajPhase, now: f64) -> LifecycleEdge {
        let from = self.phases[idx];
        let legal = from.can_transition(to);
        if !legal {
            self.stats.violations += 1;
        }
        *self.stats.edges.entry((from, to)).or_insert(0) += 1;
        let since_s = self.entered_at[idx];
        let dwell = (now - since_s).max(0.0);
        self.stats
            .residency
            .entry(from)
            .or_default()
            .record(dwell);
        *self.stats.residency_totals.entry(from).or_insert(0.0) += dwell;
        self.phases[idx] = to;
        self.entered_at[idx] = now;
        LifecycleEdge {
            from,
            to,
            legal,
            since_s,
        }
    }

    pub fn stats(&self) -> &LifecycleStats {
        &self.stats
    }

    pub fn into_stats(self) -> LifecycleStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use TrajPhase::*;

    #[test]
    fn happy_path_is_legal() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(0.0);
        for to in [Prefilling, EnvStep, Prefilling, Decoding, EnvStep, Reward, Deposited] {
            assert!(t.transition_at(i, to, 0.0).legal, "{to:?}");
        }
        assert_eq!(t.stats().violations, 0);
        assert_eq!(t.phase(i), Deposited);
        assert_eq!(t.stats().edge(EnvStep, Prefilling), 1);
        assert_eq!(t.stats().entered(EnvStep), 2);
    }

    #[test]
    fn pd_path_observes_the_phase_boundary() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(0.0);
        for to in [Prefilling, Decoding, EnvStep, Reward, Deposited] {
            assert!(t.transition_at(i, to, 0.0).legal, "{to:?}");
        }
        assert_eq!(t.stats().violations, 0);
    }

    #[test]
    fn suspend_and_recovery_edges() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(0.0);
        assert!(t.transition_at(i, Suspended, 0.0).legal, "queued but proxy suspended");
        assert!(t.transition_at(i, Prefilling, 0.0).legal);
        assert!(t.transition_at(i, Recovering, 0.0).legal, "engine crashed");
        assert!(t.transition_at(i, Suspended, 0.0).legal, "fleet fully down");
        assert!(t.transition_at(i, Suspended, 0.0).legal, "self-loop: still down");
        assert!(t.transition_at(i, Decoding, 0.0).legal, "PD decode half re-queued");
        assert!(t.transition_at(i, Aborted, 0.0).legal);
        assert_eq!(t.stats().violations, 0);
        // A turn boundary crossing a weight-sync suspend parks too.
        let j = t.spawn_at(0.0);
        t.transition_at(j, Prefilling, 0.0);
        t.transition_at(j, EnvStep, 0.0);
        assert!(t.transition_at(j, Suspended, 0.0).legal, "next turn parks mid-sync");
        assert!(t.transition_at(j, Prefilling, 0.0).legal, "resumes on sync done");
        assert_eq!(t.stats().violations, 0);
    }

    #[test]
    fn terminal_phases_reject_exits() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(0.0);
        t.transition_at(i, Aborted, 0.0);
        let e = t.transition_at(i, Prefilling, 0.0);
        assert!(!e.legal);
        assert_eq!(t.stats().violations, 1);
        // The move is still applied (deterministic continue).
        assert_eq!(t.phase(i), Prefilling);
    }

    #[test]
    fn illegal_shortcuts_are_recorded() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(0.0);
        assert!(!t.transition_at(i, Reward, 0.0).legal, "Queued cannot skip to Reward");
        let j = t.spawn_at(0.0);
        t.transition_at(j, Prefilling, 0.0);
        t.transition_at(j, EnvStep, 0.0);
        assert!(!t.transition_at(j, Decoding, 0.0).legal, "EnvStep cannot re-enter Decoding");
        assert_eq!(t.stats().violations, 2);
        assert_eq!(t.stats().spawned, 2);
    }

    #[test]
    fn residency_accumulates_per_phase_visit() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(1.0);
        t.transition_at(i, Prefilling, 3.0); // Queued held 2 s
        t.transition_at(i, Decoding, 8.0); // Prefilling held 5 s
        t.transition_at(i, EnvStep, 8.5); // Decoding held 0.5 s
        t.transition_at(i, Prefilling, 10.0); // next turn
        t.transition_at(i, Aborted, 14.0); // Prefilling held 4 s
        let s = t.stats();
        assert_eq!(s.residency_s(Queued), 2.0);
        assert_eq!(s.residency_s(Prefilling), 9.0);
        assert_eq!(s.residency_s(Decoding), 0.5);
        assert_eq!(s.residency_s(EnvStep), 1.5);
        assert_eq!(s.residency_s(Aborted), 0.0, "terminal: never left");
        // Two Prefilling visits, mean 4.5 s each.
        assert_eq!(s.mean_residency_s(Prefilling), 4.5);
        let mut stats = t.into_stats();
        let h = stats.residency.get_mut(&Prefilling).unwrap();
        assert_eq!(h.len(), 2);
        assert_eq!(h.quantile(1.0), 5.0);
    }

    #[test]
    fn residency_self_loop_books_under_the_phase() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(0.0);
        t.transition_at(i, Suspended, 0.0);
        t.transition_at(i, Suspended, 2.0); // re-parked: still suspended
        t.transition_at(i, Prefilling, 3.0);
        assert_eq!(t.stats().residency_s(Suspended), 3.0);
        assert_eq!(t.stats().residency.get(&Suspended).unwrap().len(), 2);
    }

    #[test]
    fn edges_carry_the_phase_span_start() {
        let mut t = LifecycleTracker::new();
        let i = t.spawn_at(1.0);
        let e = t.transition_at(i, Prefilling, 3.0);
        assert_eq!(e.since_s, 1.0, "Queued entered at spawn time");
        let e = t.transition_at(i, Decoding, 8.0);
        assert_eq!(e.since_s, 3.0);
        // Span duration (now - since_s) equals the residency booked.
        assert_eq!(t.stats().residency_s(Prefilling), 8.0 - 3.0);
    }

    #[test]
    fn abort_legal_from_every_non_terminal_phase() {
        for phase in [Queued, Prefilling, Decoding, EnvStep, Reward, Suspended, Recovering] {
            assert!(phase.can_transition(Aborted), "{phase:?}");
        }
        assert!(!Deposited.can_transition(Aborted));
    }
}
