//! The scheduling-policy plane: one small struct per coordination mode.
//!
//! The pre-refactor driver encoded every baseline as inline
//! `cfg.mode == Mode::X` conditionals scattered through a ~1,200-line
//! event loop; adding a scenario meant editing the monolith.  Each
//! [`Mode`] is now a [`SchedPolicy`] — the complete set of decisions
//! that distinguish the §7.1 baselines:
//!
//! | decision | Sync+ | One-off | AReaL | RollArt |
//! |---|---|---|---|---|
//! | rollout | barrier | continuous | continuous | continuous |
//! | group redundancy (§6.3) | 0 | 0 | 0 | cfg.redundancy |
//! | buffer deposits | per-traj | per-traj | per-traj | group-atomic |
//! | mid-flight staleness abort | — | — | — | α at every turn start |
//! | weight sync | blocking after train | lazy before next batch | lazy | lazy |
//!
//! Everything else — the trajectory lifecycle, fault recovery, elastic
//! scaling, PD phase dispatch — lives in the mode-agnostic
//! [`super::core`] and composes with any policy.

use crate::rl::{Trajectory, Version};
use crate::sim::{Mode, Scenario};
use crate::weights::SyncStrategyKind;

/// Mode-specific scheduling decisions consulted by the driver core.
///
/// Default methods encode the baseline (non-RollArt) behaviour so a new
/// policy only overrides what it changes.
///
/// # Writing your own scheduling policy
///
/// Implement the trait and override only the decisions your mode
/// changes.  A "RollArt but with a hard α=0 freshness gate" variant —
/// continuous rollout, group-atomic deposits, and an admission gate
/// that aborts any trajectory whose start version is not *current*:
///
/// ```
/// use rollart::env::TaskDomain;
/// use rollart::rl::{Trajectory, TrajectoryId, Version};
/// use rollart::sim::driver::SchedPolicy;
///
/// struct FreshOnly;
/// impl SchedPolicy for FreshOnly {
///     fn name(&self) -> &'static str {
///         "fresh-only"
///     }
///     fn continuous_rollout(&self) -> bool {
///         true
///     }
///     fn group_atomic_deposits(&self) -> bool {
///         true
///     }
///     fn admit_turn(&self, traj: &Trajectory, current: Version, _alpha: u64) -> bool {
///         traj.version_started == current
///     }
/// }
///
/// let p = FreshOnly;
/// let traj = Trajectory::new(TrajectoryId(0), TaskDomain::Swe, Version(3));
/// assert!(p.admit_turn(&traj, Version(3), 1));
/// assert!(!p.admit_turn(&traj, Version(4), 1), "one version behind: abort");
/// // Decisions not overridden keep the baseline defaults.
/// assert!(!p.sync_blocking_after_train());
/// ```
///
/// The driver core consults exactly these methods — wiring a new
/// policy in means extending [`policy_for`] (or constructing the
/// driver with it directly); the event loop itself never changes.
pub trait SchedPolicy {
    fn name(&self) -> &'static str;

    /// Continuous rollout (keep the env pool refilled to the target
    /// concurrency) vs barrier iterations (launch one batch, wait).
    fn continuous_rollout(&self) -> bool;

    /// Redundant environments launched per GRPO group (§6.3).
    fn group_redundancy(&self, _cfg: &Scenario) -> usize {
        0
    }

    /// Deposit filled GRPO groups atomically (all members or none)
    /// instead of per-trajectory.
    fn group_atomic_deposits(&self) -> bool {
        false
    }

    /// Admission gate before each generation turn: may `traj` start
    /// another turn at `current`?  Returning false aborts the
    /// trajectory mid-flight (RollArt's per-iteration staleness
    /// enforcement, §6.2 fn.1); baselines let stale tails finish and
    /// rely on buffer eviction.
    fn admit_turn(&self, _traj: &Trajectory, _current: Version, _alpha: u64) -> bool {
        true
    }

    /// Pay the weight sync blocking at the end of every train step
    /// (synchronous training) instead of lazily when the next batch is
    /// ready.
    fn sync_blocking_after_train(&self) -> bool {
        false
    }

    /// May `strategy` disseminate weights under this coordination mode?
    ///
    /// The mapping mirrors each mode's semantics: a mode whose training
    /// barrier *is* the weight sync (Sync+) only admits the fleet-drain
    /// [`SyncStrategyKind::BlockingBroadcast`] — a rolling or lazy plane
    /// would dissolve the very barrier the baseline exists to measure.
    /// Continuous modes (One-off, AReaL, RollArt) admit every strategy:
    /// their trains are decoupled from engine refreshes, and the
    /// α-staleness machinery (admission gate + buffer eviction) bounds
    /// how far a lazily-updated engine can drift.
    fn strategy_legal(&self, strategy: SyncStrategyKind) -> bool {
        !self.sync_blocking_after_train()
            || matches!(strategy, SyncStrategyKind::BlockingBroadcast)
    }
}

/// Sync+ (§7.1): async env interaction and async serverless reward, but
/// synchronous training — one batch per iteration, blocking weight
/// sync at the barrier.
#[derive(Clone, Copy, Debug, Default)]
pub struct SyncPlusPolicy;

impl SchedPolicy for SyncPlusPolicy {
    fn name(&self) -> &'static str {
        "Sync+"
    }

    fn continuous_rollout(&self) -> bool {
        false
    }

    fn sync_blocking_after_train(&self) -> bool {
        true
    }
}

/// One-off asynchrony [32]: rollout k+1 overlaps train k; batch
/// boundaries preserved, staleness fixed at 1 by construction.
#[derive(Clone, Copy, Debug, Default)]
pub struct OneOffPolicy;

impl SchedPolicy for OneOffPolicy {
    fn name(&self) -> &'static str {
        "One-off"
    }

    fn continuous_rollout(&self) -> bool {
        true
    }
}

/// AReaL-style continuous rollout: staleness bounded at trajectory
/// *start* only — stale tails generate to completion and are evicted at
/// `get_batch` (the waste RollArt's mid-flight abort removes).
#[derive(Clone, Copy, Debug, Default)]
pub struct ARealPolicy;

impl SchedPolicy for ARealPolicy {
    fn name(&self) -> &'static str {
        "AReaL"
    }

    fn continuous_rollout(&self) -> bool {
        true
    }
}

/// RollArt: continuous rollout, per-iteration staleness bound with
/// mid-flight aborts, group-atomic deposits, redundant environment
/// rollouts (§6.2, §6.3).
#[derive(Clone, Copy, Debug, Default)]
pub struct RollArtPolicy;

impl SchedPolicy for RollArtPolicy {
    fn name(&self) -> &'static str {
        "RollArt"
    }

    fn continuous_rollout(&self) -> bool {
        true
    }

    fn group_redundancy(&self, cfg: &Scenario) -> usize {
        cfg.redundancy
    }

    fn group_atomic_deposits(&self) -> bool {
        true
    }

    fn admit_turn(&self, traj: &Trajectory, current: Version, alpha: u64) -> bool {
        traj.fresh_at_start(current, alpha)
    }
}

/// The policy implementing `mode`.  `Mode::Sync` runs on the
/// phase-structured [`crate::sim::sync_driver`], not this event loop.
pub fn policy_for(mode: Mode) -> Box<dyn SchedPolicy> {
    match mode {
        Mode::Sync => panic!("use sync_driver for Mode::Sync"),
        Mode::SyncPlus => Box::new(SyncPlusPolicy),
        Mode::OneOff => Box::new(OneOffPolicy),
        Mode::AReaL => Box::new(ARealPolicy),
        Mode::RollArt => Box::new(RollArtPolicy),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::TaskDomain;
    use crate::llm::QWEN3_8B;
    use crate::rl::TrajectoryId;

    #[test]
    fn policy_table_matches_modes() {
        for (mode, name, continuous, atomic, blocking) in [
            (Mode::SyncPlus, "Sync+", false, false, true),
            (Mode::OneOff, "One-off", true, false, false),
            (Mode::AReaL, "AReaL", true, false, false),
            (Mode::RollArt, "RollArt", true, true, false),
        ] {
            let p = policy_for(mode);
            assert_eq!(p.name(), name);
            assert_eq!(p.continuous_rollout(), continuous, "{name}");
            assert_eq!(p.group_atomic_deposits(), atomic, "{name}");
            assert_eq!(p.sync_blocking_after_train(), blocking, "{name}");
        }
    }

    #[test]
    fn only_rollart_uses_redundancy() {
        let mut cfg = Scenario::rollart_default(QWEN3_8B.clone(), 0.05);
        cfg.redundancy = 3;
        assert_eq!(policy_for(Mode::RollArt).group_redundancy(&cfg), 3);
        for mode in [Mode::SyncPlus, Mode::OneOff, Mode::AReaL] {
            assert_eq!(policy_for(mode).group_redundancy(&cfg), 0, "{mode:?}");
        }
    }

    #[test]
    fn only_rollart_aborts_stale_mid_flight() {
        let traj = Trajectory::new(TrajectoryId(0), TaskDomain::Web, Version(0));
        // Version 0 start, current 5, α=1: far outside the window.
        assert!(!policy_for(Mode::RollArt).admit_turn(&traj, Version(5), 1));
        assert!(policy_for(Mode::RollArt).admit_turn(&traj, Version(1), 1));
        for mode in [Mode::SyncPlus, Mode::OneOff, Mode::AReaL] {
            assert!(policy_for(mode).admit_turn(&traj, Version(5), 1), "{mode:?}");
        }
    }

    #[test]
    #[should_panic(expected = "sync_driver")]
    fn sync_mode_panics() {
        policy_for(Mode::Sync);
    }

    #[test]
    fn strategy_legality_follows_the_barrier() {
        use crate::weights::SyncStrategyKind as K;
        let all = [
            K::BlockingBroadcast,
            K::RollingSubset { k: 2 },
            K::LazyPull,
            K::OverlappedBroadcast { chunks: 8 },
            K::Adaptive,
        ];
        // Sync+ trains behind a blocking barrier: only the fleet drain.
        let sp = policy_for(Mode::SyncPlus);
        assert!(sp.strategy_legal(K::BlockingBroadcast));
        assert!(!sp.strategy_legal(K::RollingSubset { k: 2 }));
        assert!(!sp.strategy_legal(K::LazyPull));
        assert!(!sp.strategy_legal(K::OverlappedBroadcast { chunks: 4 }));
        assert!(!sp.strategy_legal(K::Adaptive));
        // Continuous modes admit every strategy.
        for mode in [Mode::OneOff, Mode::AReaL, Mode::RollArt] {
            let p = policy_for(mode);
            for k in all {
                assert!(p.strategy_legal(k), "{mode:?} must admit {}", k.name());
            }
        }
    }
}
