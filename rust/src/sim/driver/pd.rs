//! Prefill-decode disaggregation as a *simulated execution mode*
//! (§6.3, Table 5).
//!
//! [`crate::proxy::pd`] models PD with closed-form pipeline algebra;
//! that is cheap but composes with nothing — no fault injection, no
//! elastic scaling, no per-trajectory staleness.  This module promotes
//! PD to a first-class mode of the DES driver:
//!
//! * a [`PdScenario`] (`xPyD`: x prefill nodes, y decode nodes) slots
//!   into [`crate::sim::Scenario::pd`];
//! * the driver core splits every generation request into a prefill
//!   half pinned to the prefill pool and a decode half pinned to the
//!   decode pool ([`split_request`]), with the KV cache shipped over
//!   the configured [`Link`] in between — as a *contended*
//!   [`SharedLink`] with [`PdScenario::kv_slots`] FIFO transfer slots,
//!   so a high-batch admission wave's simultaneous KV transfers queue
//!   instead of overlapping for free ([`kv_transfer_s`] remains the
//!   uncontended single-transfer estimate);
//! * because the halves flow through the ordinary dispatch/re-queue
//!   machinery, PD composes with everything the driver already does: a
//!   prefill-pool engine crash drains and re-queues its in-flight
//!   prefills, weight-sync suspends both pools, the staleness gate
//!   still aborts mid-flight trajectories.
//!
//! Setting [`PdScenario::disaggregated`] to false builds the equal-GPU
//! *colocated* ablation arm instead: one pool of x+y nodes that
//! interleaves both phases and pays the
//! [`colocation_interference`](crate::proxy::pd::colocation_interference)
//! tax (DistServe / MegaScale-Infer; the reason Table 5's MoE gains
//! exceed the dense ones).
//!
//! [`rollout_makespan`] is a focused DES harness over the same engines
//! and the same request-splitting rules, used to cross-check the
//! analytic Table 5 numbers (see tests and the `table5` bench).

use crate::hw::GpuClass;
use crate::llm::LlmSpec;
use crate::net::{Link, SharedLink, SharedLinkStats, NVLINK_INTRA};
use crate::proxy::pd::colocation_interference;
use crate::proxy::{EngineSim, SimRequest, StepOutcome};
use crate::rl::TrajectoryId;
use crate::simkit::EventQueue;
use std::collections::BTreeMap;

/// One simulated PD deployment.
#[derive(Clone, Debug)]
pub struct PdScenario {
    pub prefill_nodes: usize,
    pub decode_nodes: usize,
    /// GPUs per node (the paper's setup: 8).
    pub gpus_per_node: usize,
    /// Override for the decode pool's GPUs per node (`None`: same as
    /// [`PdScenario::gpus_per_node`]).  The critical-path plane's
    /// what-if validation widens decode with this knob: per the 1/n
    /// width law in [`phase_time`](crate::hw::phase_time), doubling
    /// decode width ≈ halves decode service (modulo the
    /// per-step launch overhead), which re-simulates a virtual
    /// `Speedup::Decode(2.0)`.
    pub decode_gpus_per_node: Option<usize>,
    /// Compute-optimized class hosting prefill.
    pub prefill_class: GpuClass,
    /// Bandwidth-optimized class hosting decode.
    pub decode_class: GpuClass,
    /// Link carrying the KV cache from prefill to decode pool.
    pub kv_link: Link,
    /// Concurrent transfer slots on the KV link (NIC queues / NVLink
    /// channels).  Transfers beyond this queue FIFO — the shared-
    /// bandwidth contention model (see [`SharedLink`]).
    pub kv_slots: usize,
    /// Continuous-batching slots per engine.
    pub max_batch: usize,
    /// True: split phases across the two pools.  False: build the
    /// equal-GPU colocated baseline (one interleaved pool of
    /// `prefill_nodes + decode_nodes` nodes of `prefill_class`, paying
    /// the interference tax).
    pub disaggregated: bool,
    /// Model decode→prefill prefix reuse: after each turn's decode the
    /// freshly decoded tokens' KV ships *back* to the prefill pool (the
    /// next turn's prefill needs the full context resident), as a
    /// reverse-direction transfer on the same shared link
    /// ([`SharedLink::acquire_reverse`]).  The next turn's prefill
    /// waits for the hop when it outlasts the env step.  Off by
    /// default (the forward-only model assumes the prefill pool keeps
    /// its own prefix cache).
    pub prefix_reuse: bool,
}

impl PdScenario {
    /// The paper's `xPyD` configuration: H800 prefill, H20 decode,
    /// 8-GPU nodes, intra-cluster NVLink/NVSwitch KV path.
    pub fn xpyd(prefill_nodes: usize, decode_nodes: usize) -> Self {
        assert!(prefill_nodes > 0 && decode_nodes > 0);
        PdScenario {
            prefill_nodes,
            decode_nodes,
            gpus_per_node: 8,
            decode_gpus_per_node: None,
            prefill_class: GpuClass::H800,
            decode_class: GpuClass::H20,
            kv_link: NVLINK_INTRA.clone(),
            kv_slots: 4,
            max_batch: 128,
            disaggregated: true,
            prefix_reuse: false,
        }
    }

    /// The equal-GPU colocated ablation arm of the same deployment.
    pub fn colocated_baseline(prefill_nodes: usize, decode_nodes: usize) -> Self {
        PdScenario {
            disaggregated: false,
            ..PdScenario::xpyd(prefill_nodes, decode_nodes)
        }
    }

    pub fn name(&self) -> String {
        if self.disaggregated {
            format!("{}P{}D", self.prefill_nodes, self.decode_nodes)
        } else {
            format!("{}N-coloc", self.prefill_nodes + self.decode_nodes)
        }
    }

    /// Interference multiplier the deployment's engines pay (1.0 when
    /// phases are disaggregated).
    pub fn interference(&self, model: &LlmSpec) -> f64 {
        if self.disaggregated {
            1.0
        } else {
            colocation_interference(model)
        }
    }

    /// Total nodes (either arm).
    pub fn nodes(&self) -> usize {
        self.prefill_nodes + self.decode_nodes
    }

    /// GPUs per decode-pool node (the override, else the common width).
    pub fn decode_gpus(&self) -> usize {
        self.decode_gpus_per_node.unwrap_or(self.gpus_per_node)
    }
}

/// Split one generation request into its PD halves.
///
/// * Prefill half: same new/context tokens, zero decode budget — it
///   completes at admission, which is exactly the prefill step.
/// * Decode half: zero new tokens (the KV arrives over the link; its
///   re-materialization cost is the transfer itself plus the admission
///   floor), full context, full decode budget.
pub fn split_request(req: &SimRequest) -> (SimRequest, SimRequest) {
    let prefill = SimRequest {
        decode_budget: 0.0,
        ..req.clone()
    };
    let decode = SimRequest {
        new_tokens: 0.0,
        ctx_tokens: req.ctx_tokens + req.new_tokens,
        ..req.clone()
    };
    (prefill, decode)
}

/// Bytes of KV cache one request ships after prefill.  Under prefix
/// caching only the *new* tokens' KV moves; earlier turns already live
/// on the decode side.
pub fn kv_bytes(model: &LlmSpec, new_tokens: f64) -> f64 {
    new_tokens * model.kv_bytes_per_token()
}

/// Uncontended single-transfer estimate of one request's KV hop (the
/// queueing-free lower bound; the drivers route actual transfers
/// through a [`SharedLink`] built by [`shared_kv_link`]).
pub fn kv_transfer_s(pd: &PdScenario, model: &LlmSpec, new_tokens: f64) -> f64 {
    pd.kv_link.transfer_time(kv_bytes(model, new_tokens))
}

/// The contended KV link of one deployment: the configured [`Link`]
/// behind [`PdScenario::kv_slots`] FIFO transfer slots.
pub fn shared_kv_link(pd: &PdScenario) -> SharedLink {
    SharedLink::new(pd.kv_link.clone(), pd.kv_slots)
}

/// The pool an engine of `class` serves in this deployment — used by
/// the telemetry plane to label engine trace tracks.  The colocated
/// arm runs one interleaved pool.
pub fn pool_label(pd: &PdScenario, class: GpuClass) -> &'static str {
    if !pd.disaggregated {
        "colocated"
    } else if class == pd.prefill_class {
        "prefill"
    } else {
        "decode"
    }
}

/// Build the engine fleet a [`PdScenario`] describes.  Engine ids start
/// at 0; in the disaggregated arm prefill engines come first.
pub fn build_engines(pd: &PdScenario, model: &LlmSpec) -> Vec<EngineSim> {
    let mut engines = Vec::new();
    if pd.disaggregated {
        assert_ne!(
            pd.prefill_class, pd.decode_class,
            "PD pools are told apart by GPU class"
        );
        for i in 0..pd.prefill_nodes {
            engines.push(EngineSim::new(
                i as u64,
                pd.prefill_class,
                pd.gpus_per_node,
                model.clone(),
                pd.max_batch,
            ));
        }
        for i in 0..pd.decode_nodes {
            engines.push(EngineSim::new(
                (pd.prefill_nodes + i) as u64,
                pd.decode_class,
                pd.decode_gpus(),
                model.clone(),
                pd.max_batch,
            ));
        }
    } else {
        let tax = pd.interference(model);
        for i in 0..pd.nodes() {
            let mut e = EngineSim::new(
                i as u64,
                pd.prefill_class,
                pd.gpus_per_node,
                model.clone(),
                pd.max_batch,
            );
            e.set_interference(tax);
            engines.push(e);
        }
    }
    engines
}

#[derive(Debug)]
enum Ev {
    Free {
        engine: usize,
        completed: Vec<(TrajectoryId, f64)>,
    },
    Kv {
        tid: TrajectoryId,
    },
}

/// DES makespan of one batch of identical single-turn requests under a
/// [`PdScenario`] — the Table 5 workload driven through real
/// [`EngineSim`] event loops instead of pipeline algebra.  Used to
/// cross-check [`crate::proxy::pd::PdConfig`]'s closed forms; the full
/// training-loop composition (faults, staleness, weight sync) runs
/// through [`super::core`].
pub fn rollout_makespan(
    model: &LlmSpec,
    pd: &PdScenario,
    batch: usize,
    prompt: f64,
    decode: f64,
) -> f64 {
    rollout_makespan_traced(model, pd, batch, prompt, decode).0
}

/// [`rollout_makespan`] plus the KV link's contention statistics —
/// the table5 bench prints the queue-delay percentiles from these.
pub fn rollout_makespan_traced(
    model: &LlmSpec,
    pd: &PdScenario,
    batch: usize,
    prompt: f64,
    decode: f64,
) -> (f64, SharedLinkStats) {
    assert!(batch > 0);
    let mut kv_link = shared_kv_link(pd);
    let mut engines = build_engines(pd, model);
    let n = engines.len();
    let mut busy = vec![false; n];
    let mut q: EventQueue<Ev> = EventQueue::new();
    // Pending decode halves keyed by trajectory (disaggregated arm).
    let mut decode_half: BTreeMap<TrajectoryId, SimRequest> = BTreeMap::new();

    let req = |i: usize| SimRequest {
        traj: TrajectoryId(i as u64),
        domain: crate::env::TaskDomain::Swe,
        new_tokens: prompt,
        ctx_tokens: 0.0,
        decode_budget: decode,
    };

    let least_loaded = |engines: &[EngineSim], range: std::ops::Range<usize>| -> usize {
        range
            .min_by_key(|&i| engines[i].load())
            .expect("pool is non-empty")
    };

    let prefill_pool = 0..pd.prefill_nodes;
    let decode_pool = pd.prefill_nodes..n;

    for i in 0..batch {
        if pd.disaggregated {
            let (p, d) = split_request(&req(i));
            decode_half.insert(p.traj, d);
            let e = least_loaded(&engines, prefill_pool.clone());
            engines[e].enqueue(p);
        } else {
            let e = least_loaded(&engines, 0..n);
            engines[e].enqueue(req(i));
        }
    }

    let kick = |engines: &mut [EngineSim], busy: &mut [bool], q: &mut EventQueue<Ev>, e: usize| {
        if busy[e] {
            return;
        }
        if let StepOutcome::Busy {
            elapsed, completed, ..
        } = engines[e].step()
        {
            busy[e] = true;
            q.schedule_in(elapsed, Ev::Free { engine: e, completed });
        }
    };

    for e in 0..n {
        kick(&mut engines, &mut busy, &mut q, e);
    }

    let mut done = 0usize;
    let mut finished_at = 0.0;
    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::Free { engine, completed } => {
                busy[engine] = false;
                for (tid, _ctx) in completed {
                    if pd.disaggregated && decode_half.contains_key(&tid) {
                        // Prefill half finished: ship the KV over the
                        // contended link.  A whole admission wave
                        // completes at once, so these transfers queue
                        // on the shared transfer slots.
                        let grant = kv_link.acquire(t.as_secs(), kv_bytes(model, prompt));
                        q.schedule_in(grant.done_s - t.as_secs(), Ev::Kv { tid });
                    } else {
                        done += 1;
                        finished_at = t.as_secs();
                    }
                }
                kick(&mut engines, &mut busy, &mut q, engine);
            }
            Ev::Kv { tid } => {
                let d = decode_half.remove(&tid).expect("decode half pending");
                let e = least_loaded(&engines, decode_pool.clone());
                engines[e].enqueue(d);
                kick(&mut engines, &mut busy, &mut q, e);
            }
        }
    }
    assert_eq!(done, batch, "every request must finish");
    (finished_at, kv_link.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::{QWEN3_30B_A3B, QWEN3_32B};
    use crate::proxy::pd::PdConfig;

    // Table 5 workload: SWE task, batch 128, 32k sequence.
    const BATCH: usize = 128;
    const PROMPT: f64 = 12_000.0;
    const DECODE: f64 = 20_000.0;

    fn des_speedup(model: &LlmSpec, x: usize, y: usize) -> f64 {
        let pd = rollout_makespan(model, &PdScenario::xpyd(x, y), BATCH, PROMPT, DECODE);
        let colo = rollout_makespan(
            model,
            &PdScenario::colocated_baseline(x, y),
            BATCH,
            PROMPT,
            DECODE,
        );
        colo / pd
    }

    fn analytic_speedup(model: &LlmSpec, x: usize, y: usize) -> f64 {
        let cfg = PdConfig::new(x, y, NVLINK_INTRA.clone());
        let pd = cfg.rollout_time(model, BATCH as f64, PROMPT, DECODE);
        let colo =
            PdConfig::colocated_time(model, (x + y) * 8, BATCH as f64, PROMPT, DECODE);
        colo / pd
    }

    #[test]
    fn des_moe_speedup_exceeds_dense() {
        // Table 5's headline ordering, reproduced by the event-driven
        // engines: PD pays off more for MoE (paper 1.21x vs 1.05x at
        // 2P2D).
        let moe = des_speedup(&QWEN3_30B_A3B, 2, 2);
        let dense = des_speedup(&QWEN3_32B, 2, 2);
        assert!(moe > dense, "moe {moe} vs dense {dense}");
        assert!(moe > 1.0, "MoE PD must win outright: {moe}");
    }

    #[test]
    fn des_3p1d_is_worst() {
        // Footnote 2: one decode node bottlenecks 20k-token decodes.
        let t_1p3d = rollout_makespan(
            &QWEN3_30B_A3B,
            &PdScenario::xpyd(1, 3),
            BATCH,
            PROMPT,
            DECODE,
        );
        let t_2p2d = rollout_makespan(
            &QWEN3_30B_A3B,
            &PdScenario::xpyd(2, 2),
            BATCH,
            PROMPT,
            DECODE,
        );
        let t_3p1d = rollout_makespan(
            &QWEN3_30B_A3B,
            &PdScenario::xpyd(3, 1),
            BATCH,
            PROMPT,
            DECODE,
        );
        assert!(t_3p1d > t_1p3d, "{t_3p1d} vs {t_1p3d}");
        assert!(t_3p1d > t_2p2d, "{t_3p1d} vs {t_2p2d}");
    }

    #[test]
    fn des_tracks_the_analytic_model() {
        // The DES and the closed forms model the same deployment with
        // different fidelity (per-request events + per-engine weight
        // sweeps vs pooled pipeline algebra), so exact agreement is not
        // expected.  Two checks: at the balanced 2P2D point the
        // speedups agree within a generous band, and across all
        // configurations the two models agree on *who benefits* — PD
        // pays off more for the MoE than for the dense model.
        for model in [&QWEN3_32B, &QWEN3_30B_A3B] {
            let a = analytic_speedup(model, 2, 2);
            let d = des_speedup(model, 2, 2);
            let ratio = d / a;
            assert!(
                (0.55..1.8).contains(&ratio),
                "{} 2P2D: des {d:.3} vs analytic {a:.3}",
                model.name
            );
        }
        for (x, y) in [(2usize, 2usize), (1, 3)] {
            let a_gap = analytic_speedup(&QWEN3_30B_A3B, x, y)
                - analytic_speedup(&QWEN3_32B, x, y);
            let d_gap =
                des_speedup(&QWEN3_30B_A3B, x, y) - des_speedup(&QWEN3_32B, x, y);
            assert!(a_gap > 0.0, "{x}P{y}D analytic MoE advantage {a_gap}");
            assert!(d_gap > 0.0, "{x}P{y}D des MoE advantage {d_gap}");
        }
    }

    #[test]
    fn uncontended_shared_hop_matches_the_single_transfer_estimate() {
        // With an idle link, the contended model reduces exactly to
        // the classic Link::transfer_time lower bound.
        let pd = PdScenario::xpyd(1, 1);
        let mut link = shared_kv_link(&pd);
        let est = kv_transfer_s(&pd, &QWEN3_32B, 5_000.0);
        let g = link.acquire(2.0, kv_bytes(&QWEN3_32B, 5_000.0));
        assert!((g.done_s - 2.0 - est).abs() < 1e-12, "{g:?} vs {est}");
        assert_eq!(g.queue_delay_s, 0.0);
    }

    #[test]
    fn high_batch_kv_transfers_queue_on_the_shared_link() {
        // A prefill admission wave completes ~max_batch requests at
        // once; their KV transfers burst onto kv_slots FIFO slots, so
        // contention must be visible at the Table 5 batch size.
        let (_, stats) = rollout_makespan_traced(
            &QWEN3_32B,
            &PdScenario::xpyd(2, 2),
            BATCH,
            PROMPT,
            DECODE,
        );
        assert_eq!(stats.transfers, BATCH as u64);
        assert!(stats.queued_transfers > 0, "{stats:?}");
        assert!(stats.queue_delay_max_s > 0.0, "{stats:?}");
        assert!(stats.queue_delay_total_s > 0.0, "{stats:?}");
    }

    #[test]
    fn more_kv_slots_mean_less_queueing() {
        let mut wide = PdScenario::xpyd(2, 2);
        wide.kv_slots = 64;
        let narrow = PdScenario::xpyd(2, 2); // 4 slots
        let (_, sw) = rollout_makespan_traced(&QWEN3_32B, &wide, BATCH, PROMPT, DECODE);
        let (_, sn) = rollout_makespan_traced(&QWEN3_32B, &narrow, BATCH, PROMPT, DECODE);
        assert!(
            sw.queue_delay_total_s < sn.queue_delay_total_s,
            "wide {sw:?} vs narrow {sn:?}"
        );
    }

    #[test]
    fn kv_link_bandwidth_matters() {
        let fast = rollout_makespan(
            &QWEN3_32B,
            &PdScenario::xpyd(1, 3),
            BATCH,
            PROMPT,
            DECODE,
        );
        let mut slow_cfg = PdScenario::xpyd(1, 3);
        slow_cfg.kv_link.effective_bytes_per_s = 1e9;
        let slow = rollout_makespan(&QWEN3_32B, &slow_cfg, BATCH, PROMPT, DECODE);
        assert!(slow > fast, "{slow} vs {fast}");
    }

    #[test]
    fn split_request_halves_are_consistent() {
        let r = SimRequest {
            traj: TrajectoryId(7),
            domain: crate::env::TaskDomain::Swe,
            new_tokens: 600.0,
            ctx_tokens: 1400.0,
            decode_budget: 250.0,
        };
        let (p, d) = split_request(&r);
        assert_eq!(p.traj, r.traj);
        assert_eq!(p.new_tokens, 600.0);
        assert_eq!(p.decode_budget, 0.0, "prefill half completes at admission");
        assert_eq!(d.new_tokens, 0.0);
        assert_eq!(d.ctx_tokens, 2000.0, "decode half sees the full context");
        assert_eq!(d.decode_budget, 250.0);
    }

    #[test]
    fn pool_labels_follow_the_deployment_arm() {
        let pd = PdScenario::xpyd(1, 1);
        assert_eq!(pool_label(&pd, GpuClass::H800), "prefill");
        assert_eq!(pool_label(&pd, GpuClass::H20), "decode");
        let colo = PdScenario::colocated_baseline(1, 1);
        assert_eq!(pool_label(&colo, GpuClass::H800), "colocated");
    }

    #[test]
    fn names_and_construction() {
        assert_eq!(PdScenario::xpyd(2, 2).name(), "2P2D");
        assert_eq!(PdScenario::colocated_baseline(1, 3).name(), "4N-coloc");
        assert_eq!(PdScenario::xpyd(1, 3).nodes(), 4);
        let moe_tax = PdScenario::colocated_baseline(2, 2).interference(&QWEN3_30B_A3B);
        let dense_tax = PdScenario::colocated_baseline(2, 2).interference(&QWEN3_32B);
        assert!(moe_tax > dense_tax);
        assert_eq!(PdScenario::xpyd(2, 2).interference(&QWEN3_30B_A3B), 1.0);
    }
}
