//! The mode-agnostic event-loop core of the trajectory-level driver.
//!
//! One discrete-event loop drives every asynchronous baseline; all
//! mode-specific decisions are delegated to a [`SchedPolicy`]
//! (see [`super::policy`]), every trajectory phase change funnels
//! through the [`LifecycleTracker`] (see [`super::lifecycle`]), and the
//! PD execution mode (see [`super::pd`]) plugs into the same dispatch
//! path — so faults, elasticity, staleness gating and PD compose
//! instead of accreting conditionals.
//!
//! The fault & elasticity plane threads through the same loop: a
//! [`FaultProfile`](crate::fault::FaultProfile) injects engine
//! crashes / env-worker deaths / serverless stragglers, the core
//! recovers at *trajectory* granularity (in-flight requests on a dead
//! engine are drained and re-queued through the proxy; crashed env
//! workers are backfilled into their GRPO group via the §6.3 redundancy
//! machinery), and an optional
//! [`ElasticPolicy`](crate::elastic::ElasticPolicy) controller resizes
//! the generation pool — and, through CpuSlot bindings, the environment
//! pool — via the [`crate::resource`] plane.
//!
//! The weight-dissemination plane (see [`crate::weights`]) threads
//! through it too: every engine carries its own weight [`Version`], and
//! the scenario's [`SyncStrategy`] decides which engines refresh when a
//! freshly trained version publishes.  The legacy fleet drain
//! (`begin_suspend`/`finish_drain`/`SyncDone`) survives as the
//! [`BlockingBroadcast`](crate::weights::BlockingBroadcast) strategy's
//! implementation — byte-for-byte the pre-refactor semantics — while
//! the event strategies (rolling / lazy / overlapped / adaptive) run
//! the **bucketized pull pipeline**: each engine's pull splits into the
//! Mooncake bucket model's sequenced bucket transfers on a contended
//! fan-out [`SharedLink`] ([`crate::weights::bucketized_pull`]), each
//! bucket gated on the trainer→store push producing it, the whole
//! stream hidden behind ongoing decode — the engine suspends only for
//! the cutover (chunked GPU load + per-bucket coordination + KV
//! recompute), so the DES reproduces Table 4's push/pull/exposed
//! decomposition per engine ([`WeightSyncReport::buckets`]).  Elastic
//! scale-ups pay their warm-up weight pull as real bucketized traffic
//! on the same link instead of the analytic `provision_delay_s`.
//! Staleness admission consults the *engines'* versions
//! (`DriverCore::gen_version`) and every turn is recorded at the
//! version of the engine that generated it.

use super::lifecycle::{LifecycleStats, LifecycleTracker, TrajPhase};
use super::pd::{kv_bytes, shared_kv_link, split_request, PdScenario};
use super::policy::{policy_for, SchedPolicy};
use crate::buffer::SampleBuffer;
use crate::coordinator::{EnvAction, EnvManagerSim, GroupOutcome, GroupTracker, IterationCost};
use crate::elastic::{
    AutoScaler, ElasticPolicy, ElasticReport, PdAutoScaler, PdSignals, ScaleDecision,
};
use crate::env::profile::DomainProfile;
use crate::env::TaskDomain;
use crate::envpool::ResetSampler;
use crate::fault::{FaultEvent, FaultReport};
use crate::hw::{phase_time, GpuClass};
use crate::metrics::{Histogram, StepBreakdown};
use crate::mooncake::MooncakeStore;
use crate::net::SharedLink;
use crate::obs::{self, BubbleCause, BubbleReport, EdgeKind, TraceRecorder};
use crate::proxy::{EngineSim, LlmProxy, SimRequest};
use crate::resource::{ResourceClass, ResourceManager, Role};
use crate::rl::{TrajectoryId, Version};
use crate::serverless::{ServerlessConfig, ServerlessPlatform};
use crate::sim::{Mode, RewardDeploy, Scenario, ScenarioResult, StepStats};
use crate::simkit::{EventQueue, SimRng, SimTime};
use crate::trace::{
    Arrivals, DomainSlo, SloPolicy, SloReport, TraceFeed, TraceRecord, TraceReplayStats,
    TraceSource,
};
use crate::weights::{
    bucketized_pull_classed, AdaptDecision, FleetView, SyncStrategy, WeightSyncReport,
};
use std::collections::BTreeMap;

// Hot-path storage note: everything keyed by trajectory slot
// (`TrajectoryId.0` == the `mgrs` index) or by dense group id lives in
// plain `Vec`s — the per-event `BTreeMap` lookups this file used to do
// were the driver's dominant cost after the calendar queue landed
// (docs/ARCHITECTURE.md, "DES performance plane").  `BTreeMap` remains
// only for genuinely sparse, cold keys (`pending_provisions`).

/// Safety horizon: a mis-configured chaos scenario (e.g. a permanent
/// whole-fleet outage with no elastic replacement) must terminate, not
/// spin on fault events forever.  Only checked when faults are active.
const MAX_SIM_S: f64 = 60.0 * 86400.0;

#[derive(Debug)]
enum Ev {
    ResetDone { mgr: usize },
    ResetRetry { mgr: usize },
    EngineFree { engine: usize, epoch: u64, completed: Vec<(TrajectoryId, f64)> },
    EnvStepDone { mgr: usize },
    /// The env worker of `mgr` died mid-trajectory (fault plane).
    EnvCrashed { mgr: usize },
    RewardDone { mgr: usize },
    TrainDone,
    SyncDone,
    /// Stochastic engine failure (MTBF process).
    EngineCrashed { engine: usize },
    /// A crashed engine finished recovering.
    EngineRecovered { engine: usize },
    /// A crashed engine finished rebooting (the analytic
    /// `engine_recovery_s`): admit its weight *reload* on the contended
    /// link now — recovery traffic queues like elastic warm-ups do —
    /// then rejoin via [`Ev::EngineRecovered`].
    RecoveryPull { engine: usize },
    /// Deterministic chaos event `cfg.fault.scheduled[idx]` fires.
    Scheduled { idx: usize },
    /// An elastic scale-up finished warming: an engine of `class`
    /// (`gpus` wide, `max_batch` slots) joins the fleet holding
    /// `binding` in the resource plane.
    EngineProvisioned {
        binding: Option<u64>,
        class: GpuClass,
        gpus: usize,
        max_batch: usize,
    },
    /// An elastic scale-up finished booting: admit its warm-up weight
    /// pull on the contended link *now* (admitting it at decision time
    /// would reserve FIFO slots the link should be serving during the
    /// boot), then join the fleet after the pull + GPU load.
    WarmupPull {
        binding: Option<u64>,
        class: GpuClass,
        gpus: usize,
        max_batch: usize,
    },
    /// A cross-class repurpose finished its warm-up weight pull: engine
    /// `engine` re-homes onto `class` (`gpus` wide, `max_batch` slots)
    /// and rejoins the fleet — same slot, new roofline.
    EngineRepurposed {
        engine: usize,
        class: GpuClass,
        gpus: usize,
        max_batch: usize,
    },
    /// PD mode: `tid`'s KV cache finished its hop to the decode pool.
    KvDone { tid: TrajectoryId },
    /// Weight plane: engine finished its cutover and now serves the
    /// version it committed to (event-driven strategies only).
    WsyncDone { engine: usize, epoch: u64 },
    /// Weight plane: the engine's background bucketized weight stream
    /// delivered; cut over at the next step boundary (event-driven
    /// strategies — the transfer rides behind decode).
    WsyncStreamed { engine: usize, epoch: u64 },
    /// Trace-replay plane: the next open-loop arrival fires — pull one
    /// record from the feed, admit or shed it, schedule the next tick.
    TraceArrival,
}

/// Where one engine is in its per-engine weight sync (event-driven
/// strategies; the blocking baseline never leaves `Idle`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EngineSync {
    Idle,
    /// Bucketized pull streaming behind ongoing decode (the transfer
    /// lands host-side; the engine keeps serving).
    Streaming,
    /// Stream delivered mid-step; cut over at the next step boundary.
    AwaitCutover,
    /// Suspended for the cutover: (chunked) GPU load + per-bucket
    /// coordination + KV recompute.
    Offline,
}

/// Bucketized push schedule of the version currently disseminating:
/// bucket `i` of the trainer→store push lands at
/// `start_s + (i + 1) * per_bucket_s`, and per-engine pulls gate each
/// bucket on it ([`crate::weights::bucketized_pull`]).
#[derive(Clone, Copy, Debug)]
struct PushPlan {
    start_s: f64,
    per_bucket_s: f64,
}

/// Why a trajectory is being aborted — drives the per-reason hooks on
/// the `→ Aborted` lifecycle edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum AbortReason {
    /// Left the α staleness window mid-flight (policy admission gate).
    Stale,
    /// Its GRPO group filled without it (§6.3 redundancy).
    Redundant,
    /// Its environment worker died (fault plane).
    EnvCrash,
}

/// Where one trajectory's split request currently is (PD mode).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PdPhase {
    /// Prefill half queued/active in the prefill pool.
    Prefill,
    /// KV cache riding the inter-pool link.
    Transfer,
    /// Decode half queued/active in the decode pool.
    Decode,
}

#[derive(Clone, Debug)]
struct PdPending {
    phase: PdPhase,
    prefill: SimRequest,
    decode: SimRequest,
    /// End-to-end duration of the turn's KV hop (queue + service +
    /// latency), set when the transfer is admitted.  Booked into
    /// `kv_hop_booked_s` at KvDone — the same event whose dispatch
    /// moves the trajectory out of Prefilling — so the prefill-wait
    /// correction and the residency it corrects land in the same
    /// iteration.
    hop_s: f64,
}

/// PD runtime state: the deployment config, the contended KV link, and
/// each in-flight turn's split request.
struct PdState {
    cfg: PdScenario,
    /// The shared-bandwidth KV link: transfers queue on its FIFO slots
    /// instead of overlapping for free, and per-transfer queue delays
    /// accumulate in its stats (surfaced as
    /// [`crate::sim::ScenarioResult::kv_link`]).  With
    /// `weights.share_kv_link` the weight plane's per-engine pulls ride
    /// (and contend on) the same slots.
    shared: SharedLink,
    /// Slab of in-flight split requests, indexed by trajectory slot
    /// (`TrajectoryId.0` — also the driver's `mgrs` index): a direct
    /// index instead of a per-event tree walk.  `None` = no split
    /// request in flight for that slot.
    pending: Vec<Option<PdPending>>,
}

struct DriverCore<'a> {
    cfg: &'a Scenario,
    policy: Box<dyn SchedPolicy>,
    lifecycle: LifecycleTracker,
    pd: Option<PdState>,
    q: EventQueue<Ev>,
    rng: SimRng,
    mgrs: Vec<EnvManagerSim>,
    proxy: LlmProxy,
    engine_busy: Vec<bool>,
    // ---- fault & elasticity plane -------------------------------
    /// Any fault mechanism enabled this run?
    fault_on: bool,
    fault_report: FaultReport,
    reset_sampler: ResetSampler,
    engine_down: Vec<bool>,
    /// Retired by the elastic controller: stays down forever.
    engine_retired: Vec<bool>,
    /// Bumped on every crash/retire so stale `EngineFree` events (work
    /// that "completed" on a dead engine) are discarded.
    engine_epoch: Vec<u64>,
    /// Trajectories whose completions ride each engine's in-flight
    /// step event.  `EngineSim::step` harvests completions *at kick
    /// time*, so a completed-in-step request no longer exists in the
    /// engine's queues — if the engine dies before its `EngineFree`
    /// fires, the epoch bump would silently drop the turn and wedge the
    /// trajectory.  The take-down path replays these via
    /// [`DriverCore::replay_lost`].
    engine_inflight_done: Vec<Vec<TrajectoryId>>,
    /// Per-engine count of MTBF failures drawn so far (stream index).
    engine_fail_nth: Vec<u64>,
    /// Crash time of currently-down engines (recovery-latency metric);
    /// `None` while up.
    down_since: Vec<Option<f64>>,
    /// Alive-time accounting for utilization under churn.
    engine_up_since: Vec<Option<f64>>,
    engine_alive_s: Vec<f64>,
    scaler: Option<AutoScaler>,
    /// Split per-class controller of an elastic PD run (mutually
    /// exclusive with `scaler`).
    pd_scaler: Option<PdAutoScaler>,
    /// Prefilling-phase residency already charged to past iterations
    /// (the PD controller's prefill-wait signal is the per-iteration
    /// delta).
    charged_prefill_res_s: f64,
    /// KV-link queue delay already charged to past iterations.
    charged_kv_queue_s: f64,
    /// Cumulative KV hop time of turns whose transfer has *delivered*
    /// (booked at KvDone).  A trajectory stays lifecycle-Prefilling
    /// while its KV rides the link, so the per-iteration delta of this
    /// is subtracted from the Prefilling-residency delta to keep the
    /// prefill-bound detector measuring the *engines*, not the hop.
    kv_hop_booked_s: f64,
    /// Portion of `kv_hop_booked_s` already charged to past iterations.
    charged_kv_transfer_s: f64,
    /// Resource-plane view backing the elastic controller's bindings.
    rm: Option<ResourceManager>,
    engine_bindings: Vec<Option<u64>>,
    /// CpuSlot bindings backing the environment pool (elastic runs):
    /// one binding per concurrent environment, released on scale-down.
    env_bindings: Vec<u64>,
    /// Engines still warming up, per GPU class.
    pending_provisions: BTreeMap<GpuClass, usize>,
    /// Environment-pool size target (elastic: scales with the live
    /// generation fleet).
    env_target: usize,
    initial_engines: usize,
    acc_engine_failures: u64,
    acc_requeued: u64,
    // -------------------------------------------------------------
    groups: GroupTracker,
    /// Completed trajectories awaiting their group to fill, indexed by
    /// group id (group ids are dense: `0..next_group`).
    staged: Vec<Vec<crate::rl::Trajectory>>,
    /// Group → task domain (for replacement launches), same dense
    /// group-id index as `staged`.
    group_domain: Vec<TaskDomain>,
    /// Maintained count of non-terminal trajectories (the old
    /// `mgrs.iter().filter(!terminal)` scan ran on every refill /
    /// counter sample and went quadratic with trajectory churn).
    active_count: usize,
    buffer: SampleBuffer,
    store: MooncakeStore,
    serverless: ServerlessPlatform,
    reward_gpu_free_at: Vec<f64>,
    version: Version,
    next_group: u64,
    inflight_resets: usize,
    /// Requests blocked by a suspended proxy or a dead target pool.
    pending_requests: Vec<SimRequest>,
    // ---- weight-dissemination plane -----------------------------
    /// Per-engine weight version: the fleet may disagree under the
    /// rolling / lazy / overlapped strategies; the blocking baseline
    /// keeps it uniform (flipped fleet-wide at `SyncDone`).
    engine_version: Vec<Version>,
    /// Cached [`DriverCore::gen_version`]: the admission gate reads it
    /// on every turn, but its inputs (`engine_version`, `engine_down`,
    /// `version`) only change at rare fleet-mutation events — so it is
    /// recomputed there ([`DriverCore::recompute_gen_version`]) instead
    /// of scanning the fleet per admission.
    gen_version_cache: Version,
    /// The scenario's dissemination discipline (see [`crate::weights`]).
    wstrategy: Box<dyn SyncStrategy>,
    /// Trainer-side fan-out link the per-engine pulls contend on
    /// (bypassed when `weights.share_kv_link` routes them over the PD
    /// KV link).
    wlink: SharedLink,
    /// Per-engine sync progress (event-driven strategies).
    wsync: Vec<EngineSync>,
    /// The version each engine's in-flight sync will flip it to.
    wsync_version: Vec<Version>,
    /// Low-priority pull id of each engine's in-flight background
    /// stream on a preemption-enabled shared link (`u64::MAX`: none).
    /// KV hops may push the stream's queued buckets back after its
    /// `WsyncStreamed` was scheduled; the handler re-checks
    /// [`SharedLink::low_pull_done`] and chases the moved delivery.
    wsync_pull: Vec<u64>,
    /// Wall-clock the open dissemination window started (publish →
    /// last live engine current), if one is converging.
    wdissem_started: Option<f64>,
    /// Push schedule of the latest published version: per-engine pulls
    /// admitted while it is current gate their buckets on it.
    wpush_plan: Option<PushPlan>,
    wreport: WeightSyncReport,
    /// PD prefix-reuse: per-trajectory completion time of the reverse
    /// (decode→prefill) KV hop the next turn's prefill must wait for.
    /// Indexed by trajectory slot; `0.0` is the "nothing pending"
    /// sentinel — `(0.0 - now).max(0.0) == 0.0`, exactly the absent
    /// case, so no `Option` wrapper is needed on the hot path.
    pd_reverse_ready: Vec<f64>,
    // -------------------------------------------------------------
    // trainer state
    trainer_busy: bool,
    trainer_idle_since: f64,
    inflight_train_tokens: f64,
    pending_batch: Option<(usize, f64)>, // (#trajectories, tokens) awaiting sync
    weights_pushed_at: Option<f64>,      // push start of latest trained weights
    suspend_draining: bool,
    /// A `SyncDone` is already in flight: `finish_drain` must not fire
    /// again off a crash/retire event landing inside the exposed-sync
    /// window (it would double-bump the version and double-charge the
    /// exposed cost).
    sync_scheduled: bool,
    train_steps_done: usize,
    last_train_done: f64,
    // barrier-mode iteration control
    iter_launched: bool,
    // stats accumulators (reset per step)
    acc_stale: u64,
    acc_redundant: u64,
    acc_failures: u64,
    acc_staleness: f64,
    acc_exposed_sync: f64,
    acc_recompute: f64,
    acc_train: f64,
    acc_wait: f64,
    reward_busy_s: f64,
    // ---- telemetry plane ----------------------------------------
    /// The run's trace sink.  A disabled recorder drops every span and
    /// counter (one branch per site), so tracing is always compiled in
    /// but free when off; the *bubble* accounting below is always on —
    /// it is pure f64 bookkeeping and must be bit-identical between
    /// traced and untraced runs.
    rec: &'a mut TraceRecorder,
    bubbles: BubbleReport,
    /// Open idle window per engine (`None` while busy or down).
    idle_since: Vec<Option<f64>>,
    /// Cause the open window will book under unless refined at close.
    idle_cause: Vec<BubbleCause>,
    /// When the engine's in-flight step started (trace span start).
    busy_since: Vec<f64>,
    /// When the engine's in-flight cutover began (trace span start).
    cutover_since: Vec<f64>,
    /// Dispatch context: a window closed while this is not `EnvWait`
    /// refines a generic env-wait bubble into the real unblocker
    /// (KV delivery → `KvQueue`; post-resume flush →
    /// `StarvedAdmission`).
    kick_cause: BubbleCause,
    /// When the in-flight train step started (trace span start).
    train_started: f64,
    // ---- trace-replay plane -------------------------------------
    /// Open-loop trace replay (`Scenario::trace`): arrivals replace
    /// closed-loop admission (`refill`) and barrier launches; `None`
    /// runs the classic closed-loop drivers untouched.
    tr: Option<TraceState>,
    /// Causal provenance armed on the event queue (critical-path
    /// plane): the dispatch loop classifies every popped event and
    /// `finish()` turns the log into a [`CritPathReport`]
    /// ([`crate::obs::CritPathReport`]).  Purely observational — the
    /// `ScenarioResult` aside from its `critpath` field is
    /// bit-identical with it off (pinned in `tests/critpath_plane.rs`).
    prov_on: bool,
    // -------------------------------------------------------------
    result: ScenarioResult,
}

/// Outcome of one admitted engine weight pull
/// ([`DriverCore::pull_weights`]): when it lands, how much of that was
/// link queueing, and — for background streams on a preemption-enabled
/// shared link — the low-priority pull id whose live delivery estimate
/// the `WsyncStreamed` handler re-checks.
struct PullTicket {
    done_s: f64,
    queue_s: f64,
    pull: Option<u64>,
}

/// Where the next trace record comes from (trace-replay plane).
///
/// Both feeds produce the *same* record sequence for the same
/// `trace_seed` ([`TraceSource`] is the generator `generate` collects
/// from), so the `ScenarioResult` is bit-identical either way — only
/// the memory profile differs, which is exactly what
/// [`TraceReplayStats::peak_records_buffered`] measures.
enum TraceFeedState {
    /// Constant-memory streaming: at most the record in hand.
    Streamed(TraceSource),
    /// Reference path: the whole trace materialized up front.
    Materialized(std::vec::IntoIter<TraceRecord>),
}

impl TraceFeedState {
    fn next(&mut self) -> Option<TraceRecord> {
        match self {
            TraceFeedState::Streamed(s) => s.next(),
            TraceFeedState::Materialized(it) => it.next(),
        }
    }

    /// Records currently buffered inside the feed (the record in hand
    /// is counted by the caller).
    fn buffered(&self) -> usize {
        match self {
            TraceFeedState::Streamed(_) => 0,
            TraceFeedState::Materialized(it) => it.as_slice().len(),
        }
    }
}

/// Per-domain latency accumulator behind the [`SloReport`].
#[derive(Default)]
struct DomainAcc {
    lat: Histogram,
    total_s: f64,
    completed: u64,
    violations: u64,
}

/// Open-loop trace-replay state (`Scenario::trace`).  Lives outside
/// `ScenarioResult` so the replay bookkeeping (notably
/// `peak_buffered`, which *differs* between feeds by design) cannot
/// perturb the bit-identity pins.
struct TraceState {
    feed: TraceFeedState,
    arrivals: Arrivals,
    slo: SloPolicy,
    /// Stop offering after this many arrivals (`TraceScenario::requests`).
    limit: u64,
    offered: u64,
    admitted: u64,
    shed: u64,
    completed: u64,
    aborted: u64,
    aborted_total_s: f64,
    peak_buffered: u64,
    /// Keyed by [`TaskDomain`] (`Ord` = declaration order, so report
    /// rows come out in `TaskDomain::ALL` order).
    acc: BTreeMap<TaskDomain, DomainAcc>,
}

/// Per-call reward execution sample.
fn reward_exec(cfg: &Scenario, rng: &mut SimRng) -> f64 {
    match &cfg.reward {
        RewardDeploy::DedicatedGpus { exec_s, .. } => exec_s.sample(rng),
        RewardDeploy::Serverless { exec_s } => exec_s.sample(rng),
    }
}

impl<'a> DriverCore<'a> {
    fn new(cfg: &'a Scenario, rec: &'a mut TraceRecorder, prov: bool) -> Self {
        let policy = policy_for(cfg.mode);
        if let Err(e) = cfg.weights.validate() {
            panic!("invalid weights config: {e}");
        }
        assert!(
            policy.strategy_legal(cfg.weights.strategy),
            "mode {:?} does not admit weight strategy {:?} (see SchedPolicy::strategy_legal)",
            cfg.mode,
            cfg.weights.strategy.name()
        );
        // PD mode replaces the configured gen pools with the xPyD
        // deployment (or its colocated ablation arm).
        let engines = match &cfg.pd {
            Some(p) => super::pd::build_engines(p, &cfg.model),
            None => {
                let mut engines = Vec::new();
                let mut eid = 0;
                for pool in &cfg.gen_pools {
                    for _ in 0..pool.engines {
                        engines.push(EngineSim::new(
                            eid,
                            pool.class,
                            pool.gpus_per_engine,
                            cfg.model.clone(),
                            pool.max_batch,
                        ));
                        eid += 1;
                    }
                }
                engines
            }
        };
        let n_engines = engines.len();
        assert!(n_engines > 0, "scenario needs at least one engine");
        let mut proxy = LlmProxy::new(engines);
        proxy.set_route_policy(cfg.route.make());
        if cfg.affinity_routing && cfg.pd.is_none() {
            // R1: prefill-heavy → compute-optimized, decode-heavy →
            // bandwidth-optimized (domain-level declarations).  PD mode
            // routes by *phase* instead (add_to_class), so task-level
            // affinity is moot there.
            for d in TaskDomain::ALL {
                let class = if DomainProfile::of(d).prefill_heavy {
                    GpuClass::H800
                } else {
                    GpuClass::H20
                };
                proxy.set_affinity(d, class);
            }
        }
        let reward_gpus = match &cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, .. } => *gpus,
            RewardDeploy::Serverless { .. } => 0,
        };
        // Elastic runs bind every engine through the resource plane so
        // scale decisions contend for real capacity; each elastic class
        // gets headroom up to its policy's max fleet size.
        assert!(
            cfg.elastic.is_none() || cfg.pd_elastic.is_none(),
            "Scenario::elastic and Scenario::pd_elastic are mutually exclusive"
        );
        let elastic_classes: Vec<ElasticPolicy> = match (&cfg.elastic, &cfg.pd_elastic) {
            (Some(p), None) => vec![p.clone()],
            (None, Some(pp)) => {
                assert!(
                    cfg.pd.as_ref().is_some_and(|p| p.disaggregated),
                    "pd_elastic requires a disaggregated Scenario::pd"
                );
                vec![pp.prefill.clone(), pp.decode.clone()]
            }
            _ => Vec::new(),
        };
        let (mut rm, engine_bindings) = if elastic_classes.is_empty() {
            (None, vec![None; n_engines])
        } else {
            let mut rm = ResourceManager::new();
            for e in proxy.engines() {
                rm.add_pool(ResourceClass::Gpu(e.class), e.gpus);
            }
            for policy in &elastic_classes {
                let have = proxy
                    .engines()
                    .iter()
                    .filter(|e| e.class == policy.class)
                    .count();
                if policy.max_engines > have {
                    rm.add_pool(
                        ResourceClass::Gpu(policy.class),
                        (policy.max_engines - have) * policy.gpus_per_engine,
                    );
                }
            }
            let bindings: Vec<Option<u64>> = proxy
                .engines()
                .iter()
                .map(|e| {
                    rm.bind(Role::ActorGen, &[ResourceClass::Gpu(e.class)], e.gpus)
                        .ok()
                        .map(|b| b.id)
                })
                .collect();
            (Some(rm), bindings)
        };
        let mut scaler = cfg.elastic.as_ref().map(|p| AutoScaler::new(p.clone()));
        let mut pd_scaler = cfg.pd_elastic.as_ref().map(|p| PdAutoScaler::new(p.clone()));
        let env_target = cfg.concurrent_envs.unwrap_or(cfg.batch_size);
        // The environment pool is resource-plane-backed too (elastic
        // runs): one CpuSlot binding per concurrent environment, with
        // headroom for the target's upper clamp, so scale-down can
        // *release* slots instead of merely shrinking a number.
        let mut env_bindings = Vec::new();
        {
            let report = match (scaler.as_mut(), pd_scaler.as_mut()) {
                (Some(s), _) => Some(&mut s.report),
                (None, Some(s)) => Some(&mut s.report),
                (None, None) => None,
            };
            if let (Some(rm), Some(report)) = (rm.as_mut(), report) {
                let base = cfg.concurrent_envs.unwrap_or(cfg.batch_size);
                let lo = cfg.group_size.max(base / 2);
                let hi = (2 * base).max(lo);
                rm.add_pool(ResourceClass::CpuSlot, hi);
                for _ in 0..env_target {
                    if let Ok(b) = rm.bind(Role::Environment, &[ResourceClass::CpuSlot], 1) {
                        env_bindings.push(b.id);
                        report.env_slots_bound += 1;
                    }
                }
            }
        }
        let buffer = {
            // RollArt keeps GRPO groups whole: a stale member evicts
            // its entire group (partial groups would corrupt the
            // advantage baseline).  The AReaL/One-off baselines keep
            // their per-trajectory semantics.
            let mut b = SampleBuffer::new(cfg.alpha, cfg.staleness);
            b.set_group_aware(policy.group_atomic_deposits());
            b
        };
        let mut pd = cfg.pd.as_ref().filter(|p| p.disaggregated).map(|p| PdState {
            cfg: p.clone(),
            shared: shared_kv_link(p),
            pending: Vec::new(),
        });
        let mut wlink = SharedLink::new(cfg.weights.fanout_link(), cfg.weights.fanout_slots);
        if rec.is_enabled() {
            // Keep per-transfer records so finish() can lay the links
            // out as occupancy tracks.  Grants are identical either
            // way, so traced and untraced runs cannot diverge.
            wlink.enable_trace();
            if let Some(pd) = pd.as_mut() {
                pd.shared.enable_trace();
            }
        }
        if cfg.weights.share_kv_link {
            // Bucket-level priorities: when weight streams ride the PD
            // KV link, latency-critical KV hops preempt their *queued*
            // buckets (committed transfers are never cut).
            if let Some(pd) = pd.as_mut() {
                pd.shared.enable_preemption();
            }
        }
        let mut q = EventQueue::new();
        if prov {
            q.enable_provenance();
        }
        let rng = SimRng::new(cfg.seed);
        let tr = cfg.trace.as_ref().map(|t| {
            assert!(t.requests > 0, "Scenario::trace needs at least one request");
            assert!(
                policy.continuous_rollout(),
                "trace replay needs a continuous-rollout mode (open-loop \
                 arrivals cannot drive barrier iteration launches)"
            );
            let feed = match t.feed {
                TraceFeed::Streamed => {
                    TraceFeedState::Streamed(TraceSource::new(&t.families, t.trace_seed))
                }
                TraceFeed::Materialized => TraceFeedState::Materialized(
                    crate::trace::generate(&t.families, t.requests as usize, t.trace_seed)
                        .into_iter(),
                ),
            };
            TraceState {
                feed,
                // Dedicated stream: arrival *times* are a pure function
                // of (scenario seed, process) — independent of the
                // record draws (`trace_seed`) and of every other driver
                // stream (docs/DETERMINISM.md).
                arrivals: Arrivals::new(t.arrivals.clone(), rng.stream("arrival", 0)),
                slo: cfg.slo.clone().unwrap_or_default(),
                limit: t.requests,
                offered: 0,
                admitted: 0,
                shed: 0,
                completed: 0,
                aborted: 0,
                aborted_total_s: 0.0,
                peak_buffered: 0,
                acc: BTreeMap::new(),
            }
        });
        DriverCore {
            cfg,
            policy,
            lifecycle: LifecycleTracker::new(),
            pd,
            q,
            rng,
            mgrs: Vec::new(),
            proxy,
            engine_busy: vec![false; n_engines],
            fault_on: cfg.fault.is_active(),
            fault_report: FaultReport::default(),
            reset_sampler: ResetSampler::new(&cfg.envpool),
            engine_down: vec![false; n_engines],
            engine_retired: vec![false; n_engines],
            engine_epoch: vec![0; n_engines],
            engine_inflight_done: vec![Vec::new(); n_engines],
            engine_fail_nth: vec![0; n_engines],
            down_since: vec![None; n_engines],
            engine_up_since: vec![Some(0.0); n_engines],
            engine_alive_s: vec![0.0; n_engines],
            scaler,
            pd_scaler,
            charged_prefill_res_s: 0.0,
            charged_kv_queue_s: 0.0,
            kv_hop_booked_s: 0.0,
            charged_kv_transfer_s: 0.0,
            rm,
            engine_bindings,
            env_bindings,
            pending_provisions: BTreeMap::new(),
            env_target,
            engine_version: vec![Version(0); n_engines],
            gen_version_cache: Version(0),
            wstrategy: cfg.weights.make_strategy(),
            wlink,
            wsync: vec![EngineSync::Idle; n_engines],
            wsync_version: vec![Version(0); n_engines],
            wsync_pull: vec![u64::MAX; n_engines],
            wdissem_started: None,
            wpush_plan: None,
            wreport: WeightSyncReport::default(),
            pd_reverse_ready: Vec::new(),
            initial_engines: n_engines,
            acc_engine_failures: 0,
            acc_requeued: 0,
            groups: GroupTracker::new(),
            active_count: 0,
            staged: Vec::new(),
            group_domain: Vec::new(),
            buffer,
            // Both weight paths — the blocking drain's analytic sync
            // and the event strategies' bucketized pulls — price
            // transfers with the scenario's one bucket model.
            store: MooncakeStore::new(cfg.weights.mooncake.clone()),
            serverless: ServerlessPlatform::new(ServerlessConfig {
                // tight reclaim: reward bursts are short-lived (Fig 12)
                idle_timeout_s: 15.0,
                ..ServerlessConfig::default()
            }),
            reward_gpu_free_at: vec![0.0; reward_gpus],
            version: Version(0),
            next_group: 0,
            inflight_resets: 0,
            pending_requests: Vec::new(),
            trainer_busy: false,
            trainer_idle_since: 0.0,
            inflight_train_tokens: 0.0,
            pending_batch: None,
            weights_pushed_at: None,
            suspend_draining: false,
            sync_scheduled: false,
            train_steps_done: 0,
            last_train_done: 0.0,
            iter_launched: false,
            acc_stale: 0,
            acc_redundant: 0,
            acc_failures: 0,
            acc_staleness: 0.0,
            acc_exposed_sync: 0.0,
            acc_recompute: 0.0,
            acc_train: 0.0,
            acc_wait: 0.0,
            reward_busy_s: 0.0,
            rec,
            bubbles: BubbleReport::default(),
            // Every engine starts idle awaiting its first dispatch.
            idle_since: vec![Some(0.0); n_engines],
            idle_cause: vec![BubbleCause::EnvWait; n_engines],
            busy_since: vec![0.0; n_engines],
            cutover_since: vec![0.0; n_engines],
            kick_cause: BubbleCause::EnvWait,
            train_started: 0.0,
            tr,
            prov_on: prov,
            result: ScenarioResult::default(),
        }
    }

    fn now(&self) -> f64 {
        self.q.now().as_secs()
    }

    // ---- telemetry plane --------------------------------------------

    /// Trace pid of engine `e` (one viewer "process" per engine).
    fn engine_pid(e: usize) -> u64 {
        obs::PID_ENGINE_BASE + e as u64
    }

    /// Open an idle window on engine `e` (no-op if one is already open
    /// or the engine is down — downtime belongs to the fault plane, not
    /// the bubble decomposition).
    fn idle_open(&mut self, e: usize, cause: BubbleCause) {
        if self.idle_since[e].is_none() && !self.engine_down[e] {
            self.idle_since[e] = Some(self.now());
            self.idle_cause[e] = cause;
        }
    }

    /// Close engine `e`'s open idle window, booking it under its cause.
    /// A window opened as generic `EnvWait` is refined by the dispatch
    /// context that ended it (`kick_cause`): closed by a KV delivery,
    /// the engine was really behind the KV queue; closed by a
    /// post-resume flush, admission starved it.
    fn idle_close(&mut self, e: usize) {
        let Some(t0) = self.idle_since[e].take() else {
            return;
        };
        let now = self.now();
        let mut cause = self.idle_cause[e];
        if cause == BubbleCause::EnvWait && self.kick_cause != BubbleCause::EnvWait {
            cause = self.kick_cause;
        }
        self.bubbles.book(cause, now - t0);
        if self.rec.is_enabled() && now > t0 {
            let name = format!("idle:{}", cause.label());
            self.rec.span(Self::engine_pid(e), 0, &name, "bubble", t0, now - t0);
        }
    }

    /// Re-cause engine `e`'s open idle window at the current instant:
    /// book the elapsed part under the old cause and reopen under
    /// `cause`.  No-op while the engine is busy or down — this is how
    /// `AwaitingWeights` gets bracketed exactly at cutover and drain
    /// boundaries.
    fn idle_split(&mut self, e: usize, cause: BubbleCause) {
        if self.idle_since[e].is_some() {
            self.idle_close(e);
            self.idle_open(e, cause);
        }
    }

    /// Sample the gauge catalog (sim-time-sampled counters; one point
    /// per train step plus the endpoints).
    fn sample_counters(&mut self) {
        if !self.rec.is_enabled() {
            return;
        }
        let now = self.now();
        let busy = self.engine_busy.iter().filter(|b| **b).count() as f64;
        let live = self.engine_down.iter().filter(|d| !**d).count() as f64;
        let lag = (0..self.engine_version.len())
            .filter(|&e| !self.engine_down[e])
            .map(|e| self.version.0.saturating_sub(self.engine_version[e].0))
            .max()
            .unwrap_or(0) as f64;
        let active = self.active() as f64;
        let parked = self.pending_requests.len() as f64;
        let depth = self.q.len() as f64;
        let kv_q = match self.pd.as_ref() {
            Some(pd) => pd.shared.stats.queue_delay_total_s,
            None => 0.0,
        };
        let w_q = self.wlink.stats.queue_delay_total_s;
        self.rec.counter(obs::PID_DRIVER, obs::CTR_ENGINES_BUSY, now, busy);
        self.rec.counter(obs::PID_DRIVER, obs::CTR_ENGINES_LIVE, now, live);
        self.rec.counter(obs::PID_DRIVER, obs::CTR_ACTIVE_TRAJ, now, active);
        self.rec.counter(obs::PID_DRIVER, obs::CTR_PENDING_REQS, now, parked);
        self.rec.counter(obs::PID_DRIVER, obs::CTR_QUEUE_DEPTH, now, depth);
        self.rec.counter(obs::PID_DRIVER, obs::CTR_VERSION_LAG_MAX, now, lag);
        self.rec.counter(obs::PID_KV_LINK, obs::CTR_KV_QUEUE_DELAY, now, kv_q);
        self.rec
            .counter(obs::PID_WEIGHT_LINK, obs::CTR_WLINK_QUEUE_DELAY, now, w_q);
        if let Some(tr) = self.tr.as_ref() {
            let (off, shed) = (tr.offered as f64, tr.shed as f64);
            self.rec.counter(obs::PID_DRIVER, obs::CTR_TRACE_OFFERED, now, off);
            self.rec.counter(obs::PID_DRIVER, obs::CTR_TRACE_SHED, now, shed);
        }
        // Per-GPU-class rows (heterogeneous fleet plane): live/busy
        // engines and token backlog per class, scanned from the fleet
        // because repurposing moves engines between classes mid-run.
        let mut per_class: BTreeMap<GpuClass, (f64, f64, f64)> = BTreeMap::new();
        for (i, e) in self.proxy.engines().iter().enumerate() {
            let row = per_class.entry(e.class).or_insert((0.0, 0.0, 0.0));
            if !self.engine_down[i] {
                row.0 += 1.0;
                row.2 += e.backlog_tokens();
            }
            if self.engine_busy[i] {
                row.1 += 1.0;
            }
        }
        for (class, (live, busy, backlog)) in per_class {
            let name = class.name();
            self.rec.counter(
                obs::PID_DRIVER,
                &format!("{}{name}", obs::CTR_CLASS_LIVE_PREFIX),
                now,
                live,
            );
            self.rec.counter(
                obs::PID_DRIVER,
                &format!("{}{name}", obs::CTR_CLASS_BUSY_PREFIX),
                now,
                busy,
            );
            self.rec.counter(
                obs::PID_DRIVER,
                &format!("{}{name}", obs::CTR_CLASS_BACKLOG_PREFIX),
                now,
                backlog,
            );
        }
    }

    /// Viewer label of engine `e`: index, GPU class, and (PD) the pool
    /// its class serves.
    fn engine_label(&self, e: usize) -> String {
        let eng = &self.proxy.engines()[e];
        match self.pd.as_ref() {
            Some(pd) => format!(
                "engine-{e} ({:?}, {})",
                eng.class,
                super::pd::pool_label(&pd.cfg, eng.class)
            ),
            None => format!("engine-{e} ({:?})", eng.class),
        }
    }

    // ---- lifecycle funnel -------------------------------------------

    /// The single phase-change funnel: every trajectory transition goes
    /// through here, gets validated against the lifecycle table (which
    /// also books the left phase's residency at the current sim time),
    /// and triggers the cross-cutting edge hooks (today: PD-state
    /// cleanup on abort; the per-reason fault/redundancy bookkeeping
    /// hangs off [`DriverCore::abort_mgr`]).
    fn transition(&mut self, mgr: usize, to: TrajPhase) {
        let now = self.now();
        let edge = self.lifecycle.transition_at(mgr, to, now);
        if self.rec.is_enabled() {
            // One span per completed phase visit, computed with the
            // same `(now - entered).max(0)` arithmetic the residency
            // booking uses, so the span timeline and LifecycleStats
            // agree exactly (the fig_phases bench asserts this).
            let dur = (now - edge.since_s).max(0.0);
            self.rec
                .span(obs::PID_TRAJ, mgr as u64, edge.from.label(), "traj", edge.since_s, dur);
        }
        if edge.to == TrajPhase::Aborted {
            if let Some(pd) = self.pd.as_mut() {
                if let Some(entry) = pd.pending.get_mut(mgr).and_then(Option::take) {
                    if entry.phase == PdPhase::Transfer {
                        // Aborted mid-hop: the admitted transfer still
                        // occupies (and completes on) the link, and the
                        // abort edge just booked the trajectory's
                        // Prefilling residency — book the hop too so
                        // the prefill-wait correction is not starved of
                        // its matching subtraction.
                        self.kv_hop_booked_s += entry.hop_s;
                    }
                }
            }
        }
        if self.tr.is_some() {
            self.trace_terminal(mgr, edge.from, edge.to);
        }
    }

    /// The active elastic controller's report (single-pool or PD
    /// split), if any — env-slot and retirement accounting is shared
    /// between the two controller kinds.
    fn elastic_report_mut(&mut self) -> Option<&mut ElasticReport> {
        if let Some(s) = self.scaler.as_mut() {
            return Some(&mut s.report);
        }
        self.pd_scaler.as_mut().map(|s| &mut s.report)
    }

    /// Is any elastic controller active?
    fn elastic_on(&self) -> bool {
        self.scaler.is_some() || self.pd_scaler.is_some()
    }

    // ---- weight-dissemination plane ---------------------------------

    /// The version the fleet can currently generate at: the newest
    /// weights any live engine serves.  Under the blocking baseline
    /// every engine agrees and this equals the pre-refactor global
    /// version at every admission point; under rolling/lazy
    /// dissemination it leads the laggards.  Falls back to the
    /// trainer-side version when the whole fleet is down (chaos).
    ///
    /// Read per admitted turn, so the fleet scan is cached and
    /// recomputed only at the events that can change it (crash, retire,
    /// revive, sync completion, provisioning, trainer version bump) —
    /// every such site calls [`DriverCore::recompute_gen_version`].
    fn gen_version(&self) -> Version {
        debug_assert_eq!(
            self.gen_version_cache,
            (0..self.engine_version.len())
                .filter(|&i| !self.engine_down[i])
                .map(|i| self.engine_version[i])
                .max()
                .unwrap_or(self.version),
            "stale gen_version cache: a fleet mutation missed its recompute"
        );
        self.gen_version_cache
    }

    fn recompute_gen_version(&mut self) {
        self.gen_version_cache = (0..self.engine_version.len())
            .filter(|&i| !self.engine_down[i])
            .map(|i| self.engine_version[i])
            .max()
            .unwrap_or(self.version);
    }

    /// A freshly trained version starts disseminating (event-driven
    /// strategies): open — or re-target — the dissemination window,
    /// record the bucketized push schedule pulls will gate on
    /// (`push_start` is when the trainer began streaming to the store,
    /// i.e. the train-done instant), and ask the strategy for its
    /// first wave.  Engines mid-sync complete to the version they
    /// committed to and are re-picked.
    fn begin_dissemination(&mut self, push_start: f64) {
        let now = self.now();
        self.rec.instant(obs::PID_DRIVER, 0, "publish", "weights", now);
        self.wreport.publishes += 1;
        let bytes = self.cfg.model.weight_bytes();
        let n = self.cfg.weights.mooncake.bucket_count(bytes);
        let push = self.store.push_time(bytes);
        self.wreport.buckets.push_s += push;
        self.wreport.buckets.naive_s += push + self.store.acc_pull_time(bytes);
        self.wpush_plan = Some(PushPlan {
            start_s: push_start,
            per_bucket_s: if n > 0 { push / n as f64 } else { 0.0 },
        });
        if self.wdissem_started.is_none() {
            self.wdissem_started = Some(self.now());
        }
        self.start_waves();
    }

    /// Ask the strategy which engines refresh next and start them.
    /// No-op for the blocking baseline and while no dissemination
    /// window is open.
    fn start_waves(&mut self) {
        if self.wstrategy.blocking() || self.wdissem_started.is_none() {
            return;
        }
        let syncing: Vec<bool> = self.wsync.iter().map(|s| *s != EngineSync::Idle).collect();
        let wave = {
            let fleet = FleetView {
                target: self.version,
                engine_version: &self.engine_version,
                engine_down: &self.engine_down,
                syncing: &syncing,
                alpha: self.cfg.alpha,
            };
            self.wstrategy.next_wave(&fleet)
        };
        for e in wave {
            self.start_engine_sync(e);
        }
        self.check_dissemination_done();
    }

    /// Commit engine `e` to a sync toward the current trainer version:
    /// its bucketized pull starts streaming immediately *behind*
    /// ongoing decode (the buckets land host-side; only the cutover
    /// will suspend the engine).
    fn start_engine_sync(&mut self, e: usize) {
        if self.engine_down[e]
            || self.wsync[e] != EngineSync::Idle
            || self.engine_version[e] >= self.version
        {
            return;
        }
        self.wsync_version[e] = self.version;
        self.wsync[e] = EngineSync::Streaming;
        let now = self.now();
        let ticket = self.pull_weights(now, self.cfg.model.weight_bytes(), true, true);
        self.wsync_pull[e] = ticket.pull.unwrap_or(u64::MAX);
        self.q.schedule_in(
            (ticket.done_s - now).max(0.0),
            Ev::WsyncStreamed {
                engine: e,
                epoch: self.engine_epoch[e],
            },
        );
        // Provenance: the link-queue share of the stream is queueing,
        // not service — what_if must never scale it away.
        self.q.tag_last_queue(ticket.queue_s);
    }

    /// The stream has delivered and the engine is at a step boundary —
    /// suspend only for the cutover (protocol step ⑤).
    fn begin_cutover(&mut self, e: usize) {
        // The engine sits at a step boundary, so it has an open idle
        // window: from here to WsyncDone the bubble is the weight
        // plane's — exactly the `cut` booked into engine_offline_s.
        self.idle_split(e, BubbleCause::AwaitingWeights);
        self.cutover_since[e] = self.now();
        self.wsync[e] = EngineSync::Offline;
        self.proxy.engines_mut()[e].suspend();
        let (cut, exposed) = self.engine_cutover_s(e);
        self.wreport.engine_offline_s += cut;
        self.wreport.buckets.exposed_s += exposed;
        self.wreport.buckets.cutovers += 1;
        self.q.schedule_in(
            cut,
            Ev::WsyncDone {
                engine: e,
                epoch: self.engine_epoch[e],
            },
        );
    }

    /// Admit one **bucketized** weight pull on the configured path: the
    /// dedicated fan-out link, or the PD deployment's KV link when the
    /// scenario makes weight and KV traffic contend
    /// (`weights.share_kv_link`).  The pull is `bucket_count` sequenced
    /// bucket transfers (never reordered within one pull); with `gated`
    /// each bucket additionally waits for the trainer→store push
    /// pipeline to produce it, so the pull trails the push
    /// bucket-by-bucket exactly as `MooncakeStore::sync`'s analytic
    /// pipeline does.  Returns the final bucket's completion time and
    /// books the pull into [`WeightSyncReport::buckets`].
    fn pull_weights(&mut self, now: f64, bytes: f64, gated: bool, background: bool) -> PullTicket {
        let plan = if gated { self.wpush_plan } else { None };
        let ready = move |i: usize| match plan {
            Some(p) => p.start_s + (i + 1) as f64 * p.per_bucket_s,
            None => f64::NEG_INFINITY,
        };
        let mc = self.cfg.weights.mooncake.clone();
        let out = match (self.cfg.weights.share_kv_link, self.pd.as_mut()) {
            (true, Some(pd)) => {
                bucketized_pull_classed(&mut pd.shared, &mc, now, bytes, ready, background)
            }
            _ => bucketized_pull_classed(&mut self.wlink, &mc, now, bytes, ready, background),
        };
        let b = &mut self.wreport.buckets;
        b.engine_pulls += 1;
        b.bucket_transfers += out.buckets.len() as u64;
        b.bytes_pulled += bytes.max(0.0);
        b.acc_pull_s += out.transfer_s;
        b.queue_delay_s += out.queue_delay_s;
        b.max_queue_delay_s = b.max_queue_delay_s.max(out.max_queue_delay_s);
        b.push_gate_s += out.push_gate_s;
        self.wreport.transfers += out.buckets.len() as u64;
        self.wreport.queued_transfers += out.queued;
        self.wreport.link_queue_delay_s += out.queue_delay_s;
        PullTicket {
            done_s: out.done_s,
            queue_s: out.queue_delay_s,
            pull: out.pull,
        }
    }

    /// Cutover of one engine's weight swap.  Returns
    /// `(engine_offline, exposed_swap)`: the offline time adds the KV
    /// recompute of the engine's in-flight contexts on top of the
    /// exposed swap cost — the (chunked) GPU load plus the per-bucket
    /// coordination RPCs, Table 4's exposed residual — which is kept
    /// separate so [`BucketBreakdown::exposed_s`] stays cross-checkable
    /// against the analytic store decomposition.
    fn engine_cutover_s(&self, e: usize) -> (f64, f64) {
        let bytes = self.cfg.model.weight_bytes();
        let chunks = self.wstrategy.chunks().max(1) as f64;
        let load = self.store.gpu_load_time(bytes / chunks);
        let coord = self.cfg.weights.mooncake.bucket_count(bytes) as f64
            * self.cfg.weights.mooncake.per_bucket_latency_s;
        let exposed = load + coord;
        (exposed + self.proxy.engines()[e].recompute_cost_s(), exposed)
    }

    /// Engine `e` finished its pull + cutover: flip its version, bring
    /// it back, and let the strategy launch the next wave.
    fn on_wsync_done(&mut self, e: usize, epoch: u64) {
        if epoch != self.engine_epoch[e] || self.wsync[e] != EngineSync::Offline {
            return; // crashed/retired mid-sync; recovery reloads weights
        }
        self.wsync[e] = EngineSync::Idle;
        self.engine_version[e] = self.wsync_version[e];
        self.recompute_gen_version();
        self.wreport.engine_syncs += 1;
        if self.rec.is_enabled() {
            let t0 = self.cutover_since[e];
            let dur = self.now() - t0;
            self.rec.span(Self::engine_pid(e), 0, "cutover", "weights", t0, dur);
        }
        // The awaiting-weights bubble ends here; whatever idle follows
        // is ordinary env-wait (or refined by the kicks below).
        self.idle_split(e, BubbleCause::EnvWait);
        if !self.proxy.is_suspended() {
            self.proxy.engines_mut()[e].resume();
        }
        self.flush_pending();
        self.kick_engine(e);
        self.start_waves();
    }

    /// Bucketized stream delivered: cut over now if the engine sits at
    /// a step boundary, else at its next `EngineFree`.
    fn on_wsync_streamed(&mut self, e: usize, epoch: u64) {
        if epoch != self.engine_epoch[e] || self.wsync[e] != EngineSync::Streaming {
            return;
        }
        // Bucket-level priorities: KV hops admitted after this stream's
        // grant may have pushed its queued buckets back on the shared
        // link.  Chase the live delivery estimate until it holds still.
        if self.wsync_pull[e] != u64::MAX {
            if let Some(done) = self
                .pd
                .as_ref()
                .and_then(|pd| pd.shared.low_pull_done(self.wsync_pull[e]))
            {
                let now = self.now();
                if done > now + 1e-9 {
                    self.q.schedule_in(done - now, Ev::WsyncStreamed { engine: e, epoch });
                    // The chase is pure pushback delay — all queueing.
                    self.q.tag_last_queue(done - now);
                    return;
                }
            }
            self.wsync_pull[e] = u64::MAX;
        }
        if self.engine_busy[e] {
            self.wsync[e] = EngineSync::AwaitCutover;
        } else {
            self.begin_cutover(e);
        }
    }

    /// Close the dissemination window once every live engine serves the
    /// trainer-side version with no sync in flight.
    fn check_dissemination_done(&mut self) {
        let Some(t0) = self.wdissem_started else {
            return;
        };
        let settled = (0..self.engine_version.len()).all(|e| {
            self.engine_down[e]
                || (self.wsync[e] == EngineSync::Idle && self.engine_version[e] >= self.version)
        });
        if settled {
            self.wdissem_started = None;
            self.wreport.dissemination_s += self.now() - t0;
        }
    }

    // -----------------------------------------------------------------

    /// Active (non-terminal) trajectory count (maintained, not
    /// scanned: spawn sites increment, the terminal edges — abort and
    /// completion — decrement).
    fn active(&self) -> usize {
        debug_assert_eq!(
            self.active_count,
            self.mgrs.iter().filter(|m| !m.is_terminal()).count(),
            "active-trajectory count drifted from the mgr slab"
        );
        self.active_count
    }

    /// Launch one GRPO group (G + redundancy members).
    fn launch_group(&mut self) {
        let g = self.next_group;
        self.next_group += 1;
        let members = self.cfg.group_size + self.policy.group_redundancy(self.cfg);
        self.groups.add_group(g, self.cfg.group_size);
        let domain = *self.rng.choose(&self.cfg.task_mix);
        // Group ids are dense — the per-group tables are plain Vecs.
        debug_assert_eq!(self.group_domain.len() as u64, g);
        self.group_domain.push(domain);
        self.staged.push(Vec::new());
        let profile = DomainProfile::of(domain);
        for _ in 0..members {
            let idx = self.mgrs.len();
            let id = TrajectoryId(idx as u64);
            let shape = profile.sample_trajectory(&mut self.rng);
            let m = EnvManagerSim::new(id, shape, self.gen_version(), g, self.now());
            self.mgrs.push(m);
            self.active_count += 1;
            let li = self.lifecycle.spawn_at(self.now());
            debug_assert_eq!(li, idx);
            self.groups.launch(g, id);
            self.schedule_reset(idx);
        }
    }

    fn schedule_reset(&mut self, mgr: usize) {
        let mut r = self.rng.stream("reset", mgr as u64);
        let o = self.reset_sampler.sample(self.inflight_resets, &mut r);
        self.inflight_resets += 1;
        if o.failed {
            self.acc_failures += 1;
            self.q.schedule_in(o.latency_s, Ev::ResetRetry { mgr });
        } else {
            self.q.schedule_in(o.latency_s, Ev::ResetDone { mgr });
        }
    }

    /// Keep the continuous modes at target concurrency.  The target is
    /// elastic: it tracks the live generation fleet so a grown pool is
    /// fed and a shrunken one is not drowned.
    fn refill(&mut self) {
        if !self.policy.continuous_rollout() || self.tr.is_some() {
            // Trace replay is open-loop: concurrency is whatever the
            // arrival process drives it to (minus shedding), never
            // topped up to a closed-loop target.
            return;
        }
        while self.active() < self.env_target {
            self.launch_group();
        }
    }

    /// Resize the environment-pool target after fleet changes
    /// (elastic runs only; fault-only runs keep the configured target),
    /// and mirror it into the CpuSlot bindings.
    fn update_env_target(&mut self) {
        if !self.elastic_on() {
            return;
        }
        let base = self.cfg.concurrent_envs.unwrap_or(self.cfg.batch_size);
        let live = self.proxy.live_engines().max(1);
        let scaled = base * live / self.initial_engines.max(1);
        let lo = self.cfg.group_size.max(base / 2);
        let hi = (2 * base).max(lo);
        self.env_target = scaled.clamp(lo, hi);
        self.sync_env_slots();
    }

    /// Keep the CpuSlot bindings in lock-step with the env-pool target:
    /// an autoscaler shrink *releases* environment capacity back to the
    /// resource plane instead of merely lowering a number (ROADMAP
    /// follow-up), and a grow binds more — dropped without queueing
    /// when the pool is exhausted, like engine provisioning.
    fn sync_env_slots(&mut self) {
        if self.rm.is_none() {
            return;
        }
        while self.env_bindings.len() > self.env_target {
            let b = self.env_bindings.pop().expect("len checked");
            self.rm.as_mut().expect("checked above").release(b);
            if let Some(r) = self.elastic_report_mut() {
                r.env_slots_released += 1;
            }
        }
        while self.env_bindings.len() < self.env_target {
            let bound = self
                .rm
                .as_mut()
                .expect("checked above")
                .bind(Role::Environment, &[ResourceClass::CpuSlot], 1);
            match bound {
                Ok(b) => {
                    self.env_bindings.push(b.id);
                    if let Some(r) = self.elastic_report_mut() {
                        r.env_slots_bound += 1;
                    }
                }
                Err(_) => break,
            }
        }
    }

    /// Barrier modes: launch one iteration's worth of groups.
    fn launch_iteration(&mut self) {
        debug_assert!(self.tr.is_none(), "trace replay rejects barrier modes");
        let n_groups = (self.cfg.batch_size / self.cfg.group_size).max(1);
        for _ in 0..n_groups {
            self.launch_group();
        }
        self.iter_launched = true;
    }

    // ---- dispatch ----------------------------------------------------

    /// The single dispatch funnel: fresh turns, crash re-queues and
    /// post-resume flushes all come through here.  Parks the request
    /// (Suspended edge) when the proxy is suspended or the target pool
    /// has no live engine.
    fn dispatch(&mut self, req: SimRequest) {
        let mgr = req.traj.0 as usize;
        if self.mgrs[mgr].is_terminal() {
            // A parked or re-queued request whose trajectory has since
            // aborted: drop it instead of burning engine time on a
            // ghost turn whose completion would be discarded anyway.
            return;
        }
        if self.pd.is_some() {
            self.dispatch_pd(req);
            return;
        }
        if self.proxy.is_suspended() || self.proxy.live_engines() == 0 {
            // Suspended for weight sync, or the whole fleet is down
            // (chaos): hold the request; it re-dispatches on resume /
            // recovery / provisioning.
            self.transition(mgr, TrajPhase::Suspended);
            self.pending_requests.push(req);
            return;
        }
        match self.proxy.add(req.clone()) {
            Some(e) => {
                self.transition(mgr, TrajPhase::Prefilling);
                self.kick_engine(e);
            }
            None => {
                // Every live engine is suspended for a weight pull
                // (per-engine suspend replaces the all-or-nothing proxy
                // suspend): hold the request; it re-dispatches when a
                // sync completes.
                self.transition(mgr, TrajPhase::Suspended);
                self.pending_requests.push(req);
            }
        }
    }

    /// PD-mode dispatch: look up (or register) the trajectory's split
    /// request and send the half its phase calls for, pinned to that
    /// phase's pool with no spill (see [`LlmProxy::add_to_class`]).
    fn dispatch_pd(&mut self, req: SimRequest) {
        let tid = req.traj;
        let mgr = tid.0 as usize;
        let (half, class, phase) = {
            let pd = self.pd.as_mut().expect("pd dispatch without pd state");
            if pd.pending.len() <= mgr {
                pd.pending.resize_with(mgr + 1, || None);
            }
            if pd.pending[mgr].is_none() {
                let (prefill, decode) = split_request(&req);
                pd.pending[mgr] = Some(PdPending {
                    phase: PdPhase::Prefill,
                    prefill,
                    decode,
                    hop_s: 0.0,
                });
            }
            let entry = pd.pending[mgr].as_mut().expect("slot filled above");
            match entry.phase {
                PdPhase::Prefill => (
                    entry.prefill.clone(),
                    pd.cfg.prefill_class,
                    TrajPhase::Prefilling,
                ),
                // Riding the link; KvDone advances it.
                PdPhase::Transfer => return,
                PdPhase::Decode => (
                    entry.decode.clone(),
                    pd.cfg.decode_class,
                    TrajPhase::Decoding,
                ),
            }
        };
        if self.proxy.is_suspended() {
            self.transition(mgr, TrajPhase::Suspended);
            self.pending_requests.push(req);
            return;
        }
        match self.proxy.add_to_class(half, class) {
            Some(e) => {
                self.transition(mgr, phase);
                self.kick_engine(e);
            }
            None => {
                // The phase's pool has no live engine: hold — PD halves
                // never spill across pools.
                self.transition(mgr, TrajPhase::Suspended);
                self.pending_requests.push(req);
            }
        }
    }

    fn kick_engine(&mut self, e: usize) {
        if self.engine_busy[e] || self.engine_down[e] || self.proxy.is_suspended() {
            return;
        }
        let outcome = self.proxy.engines_mut()[e].step();
        if let crate::proxy::StepOutcome::Busy {
            elapsed, completed, ..
        } = outcome
        {
            self.engine_busy[e] = true;
            self.idle_close(e);
            self.busy_since[e] = self.now();
            // Reuse the per-engine scratch buffer instead of collecting
            // a fresh Vec on every busy step.
            let buf = &mut self.engine_inflight_done[e];
            buf.clear();
            buf.extend(completed.iter().map(|(t, _)| *t));
            let epoch = self.engine_epoch[e];
            self.q.schedule_in(
                elapsed,
                Ev::EngineFree {
                    engine: e,
                    epoch,
                    completed,
                },
            );
        }
    }

    fn kick_all_engines(&mut self) {
        for e in 0..self.engine_busy.len() {
            self.kick_engine(e);
        }
    }

    fn env_step_latency(&mut self, mgr: usize) -> f64 {
        let domain = self.mgrs[mgr].domain();
        let turn = self.mgrs[mgr].turns_done();
        let mut r = self.rng.stream("envstep", (mgr * 1000 + turn) as u64);
        match &self.cfg.env_step_override {
            Some(d) => d.sample(&mut r),
            None => self.cfg.envpool.sample_step(domain, &mut r),
        }
    }

    fn handle_action(&mut self, mgr: usize, action: EnvAction) {
        match action {
            EnvAction::Generate(req) => {
                // Per-iteration staleness enforcement (§6.2 fn.1),
                // delegated to the policy: RollArt aborts mid-flight
                // trajectories whose start version left the α window
                // instead of letting them generate a stale tail that
                // get_batch would evict anyway (AReaL's behaviour).
                // The gate consults the *engines'* version — the newest
                // weights the fleet can actually generate this turn at
                // — not the trainer-side counter, so rolling / lazy
                // dissemination does not abort trajectories for a
                // version no engine serves yet.
                if !self
                    .policy
                    .admit_turn(&self.mgrs[mgr].traj, self.gen_version(), self.cfg.alpha)
                {
                    self.abort_mgr(mgr, AbortReason::Stale);
                    return;
                }
                self.dispatch(req);
            }
            EnvAction::StepEnv => {
                // PD prefix reuse: the next turn's prefill cannot start
                // until this turn's reverse (decode→prefill) KV hop
                // lands back home — fold any residual transfer time
                // into the env-interaction wait.
                let now = self.now();
                let reverse_gap = match self.pd_reverse_ready.get_mut(mgr) {
                    Some(t) => (std::mem::replace(t, 0.0) - now).max(0.0),
                    None => 0.0,
                };
                // Fault plane: this step may kill its env worker.  The
                // crash is detected after the health-check delay and
                // recovered at trajectory level (group backfill).
                if self.fault_on
                    && self
                        .cfg
                        .fault
                        .env_step_crashes(&self.rng, mgr, self.mgrs[mgr].turns_done())
                {
                    self.q
                        .schedule_in(self.cfg.fault.env_crash_detect_s, Ev::EnvCrashed { mgr });
                    return;
                }
                let lat = self.env_step_latency(mgr).max(reverse_gap);
                self.q.schedule_in(lat, Ev::EnvStepDone { mgr });
            }
            EnvAction::Complete => {
                // The mgr just went `Done` (terminal) — the only place
                // `Complete` is produced.
                self.active_count -= 1;
                self.transition(mgr, TrajPhase::Reward);
                self.dispatch_reward(mgr);
            }
        }
    }

    /// Abort one trajectory.  The common teardown is shared; the
    /// per-reason hooks (group backfill, fault accounting) hang off the
    /// `→ Aborted` lifecycle edge this records.
    fn abort_mgr(&mut self, mgr: usize, reason: AbortReason) {
        let id = self.mgrs[mgr].id;
        let group = self.mgrs[mgr].traj.group;
        if !self.mgrs[mgr].is_terminal() {
            self.active_count -= 1;
        }
        self.mgrs[mgr].abort();
        self.proxy.abort(id);
        self.groups.fail(id);
        self.transition(mgr, TrajPhase::Aborted);
        match reason {
            AbortReason::Stale => {
                self.acc_stale += 1;
                // A stale member leaves its group short: relaunch a
                // replacement at the *current* version so the group can
                // still fill (the paper re-rolls aborted trajectories).
                // Open-loop trace replay never backfills — a shed or
                // aborted request is lost offered load, and a
                // replacement would sample a non-trace shape.
                if self.tr.is_none() && !self.groups.is_filled(group) {
                    self.launch_member(group);
                }
            }
            AbortReason::Redundant => {
                self.acc_redundant += 1;
            }
            AbortReason::EnvCrash => {
                // Trajectory-level recovery: the dead worker's
                // trajectory is abandoned, but its GRPO group is
                // backfilled with a fresh member at the current version
                // (§6.3 redundancy machinery).
                self.fault_report.env_crashes += 1;
                self.acc_failures += 1;
                if self.tr.is_none() && !self.groups.is_filled(group) {
                    self.fault_report.trajectories_relaunched += 1;
                    self.launch_member(group);
                }
            }
        }
        self.refill();
    }

    /// Launch one replacement member into an existing group.
    fn launch_member(&mut self, group: u64) {
        let domain = self.group_domain[group as usize];
        let profile = DomainProfile::of(domain);
        let idx = self.mgrs.len();
        let id = TrajectoryId(idx as u64);
        let shape = profile.sample_trajectory(&mut self.rng);
        let m = EnvManagerSim::new(id, shape, self.gen_version(), group, self.now());
        self.mgrs.push(m);
        self.active_count += 1;
        let li = self.lifecycle.spawn_at(self.now());
        debug_assert_eq!(li, idx);
        self.groups.launch(group, id);
        self.schedule_reset(idx);
    }

    // ---- trace-replay plane -----------------------------------------

    /// Schedule the next open-loop arrival tick, unless the trace's
    /// request budget is exhausted (then the run drains naturally).
    fn schedule_next_arrival(&mut self) {
        let now = self.now();
        let Some(tr) = self.tr.as_mut() else { return };
        if tr.offered >= tr.limit {
            return;
        }
        let gap = tr.arrivals.next_gap(now);
        self.q.schedule_in(gap, Ev::TraceArrival);
    }

    /// One open-loop arrival: pull the next record from the feed,
    /// shed it if the in-flight cap says so, launch it otherwise, and
    /// schedule the next tick.
    fn on_trace_arrival(&mut self) {
        let active = self.active_count;
        let (rec, admitted) = {
            let Some(tr) = self.tr.as_mut() else { return };
            let Some(rec) = tr.feed.next() else { return };
            tr.offered += 1;
            // +1 for the record in hand: a streamed feed buffers
            // nothing else, so its peak pins at 1 — the constant-memory
            // proof the fig_trace bench gates on.
            tr.peak_buffered = tr.peak_buffered.max(tr.feed.buffered() as u64 + 1);
            let shed = tr.slo.shed_above.is_some_and(|cap| active >= cap);
            if shed {
                tr.shed += 1;
            } else {
                tr.admitted += 1;
            }
            (rec, !shed)
        };
        self.schedule_next_arrival();
        if admitted {
            self.launch_trace_record(&rec);
        }
    }

    /// Spawn one admitted trace record.  Each request is its own group
    /// of one — open-loop arrivals carry no GRPO prompt-group
    /// semantics, and a singleton group keeps the deposit machinery
    /// (staging, atomic deposit, lifecycle edges) uniform with the
    /// closed-loop path.
    fn launch_trace_record(&mut self, rec: &TraceRecord) {
        let t = self.cfg.trace.as_ref().expect("trace arrival without Scenario::trace");
        let domain = t.families[rec.family].domain;
        let shape = crate::trace::record_shape(rec, domain);
        let g = self.next_group;
        self.next_group += 1;
        self.groups.add_group(g, 1);
        debug_assert_eq!(self.group_domain.len() as u64, g);
        self.group_domain.push(domain);
        self.staged.push(Vec::new());
        let idx = self.mgrs.len();
        let id = TrajectoryId(idx as u64);
        let m = EnvManagerSim::new(id, shape, self.gen_version(), g, self.now());
        self.mgrs.push(m);
        self.active_count += 1;
        let li = self.lifecycle.spawn_at(self.now());
        debug_assert_eq!(li, idx);
        self.groups.launch(g, id);
        self.schedule_reset(idx);
    }

    /// SLO accounting at the terminal lifecycle edges of a trace
    /// replay.  Latency is arrival → terminal, which equals the sum of
    /// the trajectory's booked phase dwells (the lifecycle tracker's
    /// residency booking telescopes) — `tests/trace_plane.rs` holds the
    /// report to that identity within 1e-9.
    fn trace_terminal(&mut self, mgr: usize, from: TrajPhase, to: TrajPhase) {
        if !to.is_terminal() || from.is_terminal() {
            // Not a terminal entry — or an illegal terminal→terminal
            // edge (the lifecycle tracker records those as violations);
            // either way there is nothing to book twice.
            return;
        }
        let lat = (self.now() - self.mgrs[mgr].traj.started_at).max(0.0);
        let domain = self.mgrs[mgr].domain();
        let Some(tr) = self.tr.as_mut() else { return };
        match to {
            TrajPhase::Deposited => {
                tr.completed += 1;
                let acc = tr.acc.entry(domain).or_default();
                acc.completed += 1;
                acc.lat.record(lat);
                acc.total_s += lat;
                if lat > tr.slo.target_for(domain) {
                    acc.violations += 1;
                }
            }
            TrajPhase::Aborted => {
                tr.aborted += 1;
                tr.aborted_total_s += lat;
            }
            _ => unreachable!("matched above"),
        }
        // Constant-memory replay: the terminal trajectory's token
        // vectors are dead weight (a deposited clone lives in the
        // sample buffer) — drop them so slab memory is bounded by the
        // in-flight set, not the trace length.
        self.mgrs[mgr].release();
    }

    // ---- fault plane ------------------------------------------------

    /// Shared crash/retire path: mark the engine dead, invalidate its
    /// in-flight `EngineFree`, account alive time, and return its
    /// drained requests plus the trajectories whose completions were
    /// riding the invalidated step event (both need re-dispatch).
    fn take_down_engine(&mut self, e: usize) -> (Vec<SimRequest>, Vec<TrajectoryId>) {
        // Close the telemetry windows first: the truncated step (work
        // the crash voided) and any open bubble end here — downtime
        // itself belongs to the fault plane, not the idle
        // decomposition.
        if self.engine_busy[e] && self.rec.is_enabled() {
            let t0 = self.busy_since[e];
            let dur = self.now() - t0;
            self.rec.span(Self::engine_pid(e), 0, "step(lost)", "engine", t0, dur);
        }
        self.idle_close(e);
        self.engine_down[e] = true;
        self.engine_epoch[e] += 1;
        self.engine_busy[e] = false;
        // A sync interrupted by the crash is void (its WsyncDone rides
        // the invalidated epoch); recovery reloads current weights.
        self.wsync[e] = EngineSync::Idle;
        let now = self.now();
        if let Some(up) = self.engine_up_since[e].take() {
            self.engine_alive_s[e] += now - up;
        }
        self.proxy.set_down(e, true);
        self.recompute_gen_version();
        let lost = std::mem::take(&mut self.engine_inflight_done[e]);
        (self.proxy.engines_mut()[e].drain_requests(), lost)
    }

    /// Re-dispatch a dead engine's drained requests: each surviving
    /// trajectory takes the `Recovering` edge and flows back through
    /// the ordinary dispatch funnel (in PD mode that re-pins the half
    /// to its own pool — a prefill never restarts in the decode pool).
    fn requeue_drained(&mut self, reqs: Vec<SimRequest>) {
        for r in reqs {
            let mgr = r.traj.0 as usize;
            if !self.mgrs[mgr].is_terminal() {
                self.transition(mgr, TrajPhase::Recovering);
            }
            self.dispatch(r);
        }
    }

    /// Replay turns whose *completions* died with the engine: they were
    /// harvested out of the engine's queues at step time and existed
    /// only inside the now-invalidated `EngineFree` event, so the drain
    /// cannot see them.  The EnvManager is a pure state machine over a
    /// pre-sampled shape, so regenerating the turn's request is exact.
    fn replay_lost(&mut self, lost: Vec<TrajectoryId>) {
        for tid in lost {
            let mgr = tid.0 as usize;
            if self.mgrs[mgr].is_terminal()
                || self.mgrs[mgr].phase != crate::coordinator::EnvPhase::Generating
            {
                continue;
            }
            self.transition(mgr, TrajPhase::Recovering);
            let req = self.mgrs[mgr].regen_request(self.gen_version());
            self.dispatch(req);
        }
    }

    /// An engine crashed.  Trajectory-level recovery: every request it
    /// held (queued or mid-generation) is re-queued through the proxy
    /// instead of being lost — its trajectory survives, only the
    /// partially decoded turn is replayed.
    fn kill_engine(&mut self, e: usize, auto_recover: bool) {
        if self.engine_down[e] {
            return;
        }
        let (reqs, lost) = self.take_down_engine(e);
        self.fault_report.engine_failures += 1;
        self.acc_engine_failures += 1;
        let recovered = (reqs.len() + lost.len()) as u64;
        self.fault_report.requeued_requests += recovered;
        self.acc_requeued += recovered;
        self.down_since[e] = Some(self.now());
        self.requeue_drained(reqs);
        self.replay_lost(lost);
        if auto_recover {
            // Recovery = node reboot + engine relaunch (the analytic
            // `engine_recovery_s`) followed by a *real* bucketized
            // weight reload on the contended link: a crash storm's
            // reloads queue against in-flight refreshes and elastic
            // warm-ups instead of hiding inside the constant.
            self.q.schedule_in(
                self.cfg.fault.engine_recovery_s,
                Ev::RecoveryPull { engine: e },
            );
        }
        // A crash mid-drain must not wedge the weight-sync barrier:
        // the dead engine's EngineFree will never count down.
        if self.suspend_draining {
            self.finish_drain();
        }
        // Likewise a crash mid-wave must not wedge the event-driven
        // plane: the dead engine frees its wave slot (rolling) and no
        // longer blocks the dissemination window.
        self.start_waves();
        self.check_dissemination_done();
        self.update_env_target();
    }

    /// Rebooted engine's weight reload: pull the current weights as
    /// real bucketized traffic on the contended fan-out (or shared-KV)
    /// link, load them into the GPU, then rejoin the fleet — the same
    /// shape as an elastic warm-up.  The reload books into the generic
    /// transfer/bucket counters plus its own `recovery_pulls` tally,
    /// *never* into `engine_offline_s` (that is the weight plane's
    /// cutover cost and is cross-checked 1:1 against the
    /// awaiting-weights bubble).
    fn on_recovery_pull(&mut self, e: usize) {
        if !self.engine_down[e] || self.engine_retired[e] {
            // Restored early by a PoolRestore (or retired) while the
            // reboot was in flight: nothing to reload.
            return;
        }
        let now = self.now();
        let bytes = self.cfg.model.weight_bytes();
        // No push gate: the store already holds the published version.
        let pull_done = self.pull_weights(now, bytes, false, false).done_s;
        let delay = (pull_done - now).max(0.0) + self.store.gpu_load_time(bytes);
        self.wreport.recovery_pulls += 1;
        self.q.schedule_in(delay, Ev::EngineRecovered { engine: e });
    }

    fn revive_engine(&mut self, e: usize) {
        if !self.engine_down[e] || self.engine_retired[e] {
            return;
        }
        self.engine_down[e] = false;
        self.engine_up_since[e] = Some(self.now());
        self.idle_open(e, BubbleCause::EnvWait);
        self.proxy.set_down(e, false);
        // Recovery reloaded the *current* weights (the reboot's
        // bucketized pull, see on_recovery_pull) and clears any
        // suspend a cancelled per-engine sync left behind.
        self.engine_version[e] = self.version;
        self.wsync[e] = EngineSync::Idle;
        self.recompute_gen_version();
        if !self.proxy.is_suspended() {
            self.proxy.engines_mut()[e].resume();
        }
        if let Some(t0) = self.down_since[e].take() {
            self.fault_report.recoveries += 1;
            self.fault_report.recovery_latency_s += self.now() - t0;
        }
        self.update_env_target();
        self.flush_pending();
        self.kick_engine(e);
    }

    /// Re-dispatch requests held while the fleet was down/suspended.
    fn flush_pending(&mut self) {
        if self.proxy.is_suspended() || self.proxy.live_engines() == 0 {
            return;
        }
        let pending: Vec<SimRequest> = std::mem::take(&mut self.pending_requests);
        if pending.is_empty() {
            return;
        }
        // An idle window closed by one of these dispatches means the
        // engine sat ready while admission held its work back.
        let prev = self.kick_cause;
        self.kick_cause = BubbleCause::StarvedAdmission;
        for req in pending {
            self.dispatch(req);
        }
        self.kick_cause = prev;
    }

    fn live_engines_of(&self, class: GpuClass) -> Vec<usize> {
        (0..self.engine_down.len())
            .filter(|&i| !self.engine_down[i] && self.proxy.engines()[i].class == class)
            .collect()
    }

    /// Scheduled chaos: kill `fraction` of the live engines of `class`.
    fn pool_outage(&mut self, class: GpuClass, fraction: f64) {
        let live = self.live_engines_of(class);
        let k = ((live.len() as f64) * fraction).ceil() as usize;
        // Kill from the back for determinism (highest indices first).
        for &e in live.iter().rev().take(k) {
            self.kill_engine(e, false);
        }
    }

    /// Scheduled chaos: bring every downed engine of `class` back.
    fn pool_restore(&mut self, class: GpuClass) {
        let down: Vec<usize> = (0..self.engine_down.len())
            .filter(|&i| {
                self.engine_down[i]
                    && !self.engine_retired[i]
                    && self.proxy.engines()[i].class == class
            })
            .collect();
        for e in down {
            self.revive_engine(e);
        }
    }

    /// Schedule engine `e`'s next stochastic failure (MTBF process).
    fn schedule_engine_failure(&mut self, e: usize) {
        let nth = self.engine_fail_nth[e];
        if let Some(dt) = self.cfg.fault.next_engine_failure(&self.rng, e, nth) {
            self.engine_fail_nth[e] += 1;
            self.q.schedule_in(dt, Ev::EngineCrashed { engine: e });
        }
    }

    // ---- elasticity plane -------------------------------------------

    /// Count live engines of one class.
    fn live_count_of(&self, class: GpuClass) -> usize {
        self.live_engines_of(class).len()
    }

    /// Act on one controller decision for one class's pool.
    fn apply_scale_decision(&mut self, decision: ScaleDecision, policy: &ElasticPolicy) {
        match decision {
            ScaleDecision::Hold => {}
            ScaleDecision::Up(n) => {
                for _ in 0..n {
                    self.provision_engine(policy);
                }
            }
            ScaleDecision::Down(n) => {
                // Retire the least-loaded live engines of the class:
                // minimal re-queued work.
                let mut candidates = self.live_engines_of(policy.class);
                candidates.sort_by_key(|&i| self.proxy.engines()[i].load());
                let victims: Vec<usize> = candidates.into_iter().take(n).collect();
                for e in victims {
                    self.retire_engine(e);
                }
            }
        }
    }

    /// Feed the controller the just-completed iteration's cost and act
    /// on its decision through the resource plane.
    fn maybe_autoscale(&mut self) {
        if self.pd_scaler.is_some() {
            self.maybe_autoscale_pd();
            return;
        }
        let Some(policy) = self.scaler.as_ref().map(|s| s.policy.clone()) else {
            return;
        };
        let Some(last) = self.result.steps.last() else {
            return;
        };
        let cost = IterationCost {
            get_batch_wait_s: last.breakdown.get_batch_wait_s,
            weight_update_s: last.breakdown.weight_sync_s,
            recompute_s: 0.0,
            train_s: last.breakdown.train_s,
            command_s: 0.0,
        };
        let live = self.live_count_of(policy.class);
        let provisioning = self.pending_provisions.get(&policy.class).copied().unwrap_or(0);
        let decision = self
            .scaler
            .as_mut()
            .expect("checked above")
            .observe(&cost, live, provisioning);
        self.apply_scale_decision(decision, &policy);
    }

    /// PD split controller: measure the iteration's per-class
    /// bottleneck signals and resize the prefill and decode pools
    /// independently.
    fn maybe_autoscale_pd(&mut self) {
        let Some(last) = self.result.steps.last() else {
            return;
        };
        let (p_class, d_class, kv_queue_total) = match self.pd.as_ref() {
            Some(pd) => (
                pd.cfg.prefill_class,
                pd.cfg.decode_class,
                pd.shared.stats.queue_delay_total_s,
            ),
            None => return,
        };
        // Per-iteration deltas of the cumulative signals.
        let prefill_res = self.lifecycle.stats().residency_s(TrajPhase::Prefilling);
        let prefill_res_delta = (prefill_res - self.charged_prefill_res_s).max(0.0);
        self.charged_prefill_res_s = prefill_res;
        let kv_queue = (kv_queue_total - self.charged_kv_queue_s).max(0.0);
        self.charged_kv_queue_s = kv_queue_total;
        let kv_transfer = (self.kv_hop_booked_s - self.charged_kv_transfer_s).max(0.0);
        self.charged_kv_transfer_s = self.kv_hop_booked_s;
        // Prefilling residency includes the KV hop (the lifecycle
        // phase only advances on KvDone): subtract the delivered hops'
        // end-to-end time so a congested link cannot masquerade as
        // prefill-engine pressure and grow the wrong pool.
        let prefill_wait = (prefill_res_delta - kv_transfer).max(0.0);
        // Outstanding decode tokens on the decode pool right now.
        let backlog: f64 = self
            .proxy
            .engines()
            .iter()
            .enumerate()
            .filter(|(i, e)| e.class == d_class && !self.engine_down[*i])
            .map(|(_, e)| e.backlog_tokens())
            .sum();
        let sig = PdSignals {
            get_batch_wait_s: last.breakdown.get_batch_wait_s,
            train_s: last.breakdown.train_s,
            prefill_wait_s: prefill_wait,
            decode_backlog_tokens: backlog,
            kv_queue_delay_s: kv_queue,
        };
        let live_p = self.live_count_of(p_class);
        let live_d = self.live_count_of(d_class);
        let prov_p = self.pending_provisions.get(&p_class).copied().unwrap_or(0);
        let prov_d = self.pending_provisions.get(&d_class).copied().unwrap_or(0);
        let scaler = self.pd_scaler.as_mut().expect("pd autoscale without scaler");
        let (dp, dd) = scaler.observe(&sig, live_p, live_d, prov_p, prov_d);
        // Opposed decisions are a regime shift: matched Up/Down pairs
        // become cross-class repurposes (warm-up pull only, no boot)
        // instead of a retire on one side and a cold provision on the
        // other; the residuals stay ordinary scale decisions.
        let plan = scaler.reconcile(dp, dd);
        let (prefill_policy, decode_policy) = {
            let s = self.pd_scaler.as_ref().expect("checked above");
            (s.policy.prefill.clone(), s.policy.decode.clone())
        };
        for _ in 0..plan.repurpose_prefill_to_decode {
            self.repurpose_one(p_class, &decode_policy);
        }
        for _ in 0..plan.repurpose_decode_to_prefill {
            self.repurpose_one(d_class, &prefill_policy);
        }
        self.apply_scale_decision(plan.prefill, &prefill_policy);
        self.apply_scale_decision(plan.decode, &decode_policy);
    }

    /// Start warming one engine of `policy`'s class: bind capacity
    /// now, join the fleet after the warm-up — runtime boot, then the
    /// warm-up weight pull as *real* bucketized traffic on the
    /// contended fan-out (or shared-KV) link, then the host→GPU load.
    /// A burst of scale-ups therefore queues against in-flight
    /// refreshes instead of paying the analytic `provision_delay_s`
    /// (which is kept in [`crate::elastic`] only as the declarative
    /// reference cost).
    fn provision_engine(&mut self, policy: &ElasticPolicy) {
        let binding = match self.rm.as_mut() {
            Some(rm) => {
                match rm.bind(
                    Role::ActorGen,
                    &[ResourceClass::Gpu(policy.class)],
                    policy.gpus_per_engine,
                ) {
                    Ok(b) => Some(b.id),
                    // Resource plane has no capacity left: the decision
                    // is dropped, not queued (next iteration retries).
                    Err(_) => return,
                }
            }
            None => None,
        };
        let boot = policy.boot_delay_s();
        if let Some(r) = self.elastic_report_mut() {
            r.provision_wait_s += boot;
        }
        *self.pending_provisions.entry(policy.class).or_insert(0) += 1;
        self.q.schedule_in(
            boot,
            Ev::WarmupPull {
                binding,
                class: policy.class,
                gpus: policy.gpus_per_engine,
                max_batch: policy.max_batch,
            },
        );
    }

    /// Boot finished: pull the warm-up weights as real bucketized
    /// traffic on the contended link (queueing against in-flight
    /// refreshes), load them into the GPU, then join the fleet.
    fn on_warmup_pull(
        &mut self,
        binding: Option<u64>,
        class: GpuClass,
        gpus: usize,
        max_batch: usize,
    ) {
        let now = self.now();
        let bytes = self.cfg.model.weight_bytes();
        // No push gate: the store already holds the published version.
        let pull_done = self.pull_weights(now, bytes, false, false).done_s;
        let delay = (pull_done - now).max(0.0) + self.store.gpu_load_time(bytes);
        self.wreport.warmup_pulls += 1;
        if let Some(r) = self.elastic_report_mut() {
            r.provision_wait_s += delay;
        }
        self.q.schedule_in(
            delay,
            Ev::EngineProvisioned {
                binding,
                class,
                gpus,
                max_batch,
            },
        );
    }

    fn on_engine_provisioned(
        &mut self,
        binding: Option<u64>,
        class: GpuClass,
        gpus: usize,
        max_batch: usize,
    ) {
        if let Some(n) = self.pending_provisions.get_mut(&class) {
            *n = n.saturating_sub(1);
        }
        let Some(r) = self.elastic_report_mut() else {
            return;
        };
        r.engines_added += 1;
        let e = self.proxy.add_engine(EngineSim::new(
            self.engine_down.len() as u64,
            class,
            gpus,
            self.cfg.model.clone(),
            max_batch,
        ));
        self.engine_busy.push(false);
        self.engine_down.push(false);
        self.engine_retired.push(false);
        self.engine_epoch.push(0);
        self.engine_inflight_done.push(Vec::new());
        self.engine_fail_nth.push(0);
        self.down_since.push(None);
        self.engine_up_since.push(Some(self.now()));
        self.engine_alive_s.push(0.0);
        self.engine_bindings.push(binding);
        // A provisioned engine's warm-up included the weight pull: it
        // joins the fleet at the current trainer-side version.
        self.engine_version.push(self.version);
        self.wsync.push(EngineSync::Idle);
        self.wsync_version.push(self.version);
        self.wsync_pull.push(u64::MAX);
        self.recompute_gen_version();
        // Telemetry state: the newcomer starts idle awaiting dispatch.
        self.idle_since.push(Some(self.now()));
        self.idle_cause.push(BubbleCause::EnvWait);
        self.busy_since.push(self.now());
        self.cutover_since.push(0.0);
        if self.rec.is_enabled() {
            let label = self.engine_label(e);
            self.rec.process_name(Self::engine_pid(e), &label);
        }
        // The new engine is subject to the same failure process.
        if self.fault_on {
            self.schedule_engine_failure(e);
        }
        self.update_env_target();
        self.flush_pending();
        self.refill();
        self.kick_engine(e);
    }

    /// Elastic scale-down: drain, re-queue, release the binding.
    fn retire_engine(&mut self, e: usize) {
        if self.engine_down[e] {
            return;
        }
        let (reqs, lost) = self.take_down_engine(e);
        self.engine_retired[e] = true;
        if let Some(r) = self.elastic_report_mut() {
            r.engines_retired += 1;
        }
        if let (Some(rm), Some(b)) = (self.rm.as_mut(), self.engine_bindings[e].take()) {
            rm.release(b);
        }
        self.requeue_drained(reqs);
        self.replay_lost(lost);
        if self.suspend_draining {
            self.finish_drain();
        }
        self.start_waves();
        self.check_dissemination_done();
        self.update_env_target();
    }

    /// Repurpose the least-loaded live engine of `from` onto the pool
    /// `to` provisions for (minimal re-queued work, same victim rule as
    /// a scale-down).  No live candidate → the repurpose is dropped
    /// this iteration, like a capacity-starved provision.
    fn repurpose_one(&mut self, from: GpuClass, to: &ElasticPolicy) {
        let mut candidates = self.live_engines_of(from);
        candidates.sort_by_key(|&i| self.proxy.engines()[i].load());
        if let Some(&e) = candidates.first() {
            self.repurpose_engine(e, to);
        }
    }

    /// Re-home engine `e` onto `to`'s class (a matched Up/Down pair
    /// from [`PdAutoScaler::reconcile`]): bind new-class capacity,
    /// drain and take the engine down, release the old binding, and
    /// admit the warm-up weight pull on the contended link *now* — a
    /// repurpose skips the runtime boot a fresh provision pays (the
    /// engine process survives; only its weights are re-laid-out for
    /// the new class), which is exactly why the controller prefers it
    /// over a retire + provision pair under regime shifts.
    fn repurpose_engine(&mut self, e: usize, to: &ElasticPolicy) {
        if self.engine_down[e] {
            return;
        }
        // Bind the new class's capacity before touching the engine: no
        // capacity → the decision is dropped (the engine keeps serving
        // its old pool; next iteration retries), mirroring
        // `provision_engine`'s drop-not-queue rule.
        let new_binding = match self.rm.as_mut() {
            Some(rm) => {
                match rm.bind(
                    Role::ActorGen,
                    &[ResourceClass::Gpu(to.class)],
                    to.gpus_per_engine,
                ) {
                    Ok(b) => Some(b.id),
                    Err(_) => return,
                }
            }
            None => None,
        };
        let (reqs, lost) = self.take_down_engine(e);
        // Conversion window: the retired flag keeps a chaos
        // PoolRestore or a stale RecoveryPull from reviving the engine
        // into its *old* class mid-conversion; EngineRepurposed clears
        // it (the epoch bump in take_down_engine already voided any
        // in-flight EngineFree).
        self.engine_retired[e] = true;
        if let (Some(rm), Some(b)) = (self.rm.as_mut(), self.engine_bindings[e].take()) {
            rm.release(b);
        }
        self.engine_bindings[e] = new_binding;
        self.requeue_drained(reqs);
        self.replay_lost(lost);
        if self.suspend_draining {
            self.finish_drain();
        }
        self.start_waves();
        self.check_dissemination_done();
        self.update_env_target();
        // The warming engine counts toward the target pool's
        // provisioning total so the controller cannot flap past its
        // bounds while conversions are in flight.
        *self.pending_provisions.entry(to.class).or_insert(0) += 1;
        let now = self.now();
        let bytes = self.cfg.model.weight_bytes();
        // No push gate: the store already holds the published version.
        let pull_done = self.pull_weights(now, bytes, false, false).done_s;
        let delay = (pull_done - now).max(0.0) + self.store.gpu_load_time(bytes);
        self.wreport.warmup_pulls += 1;
        if let Some(r) = self.elastic_report_mut() {
            r.provision_wait_s += delay;
        }
        self.q.schedule_in(
            delay,
            Ev::EngineRepurposed {
                engine: e,
                class: to.class,
                gpus: to.gpus_per_engine,
                max_batch: to.max_batch,
            },
        );
    }

    /// The repurposed engine's warm-up pull landed: re-home it onto the
    /// new class (same fleet slot — no parallel-state pushes) and
    /// rejoin the live fleet, mirroring `revive_engine`'s rejoin
    /// sequence plus the class move itself.
    fn on_engine_repurposed(&mut self, e: usize, class: GpuClass, gpus: usize, max_batch: usize) {
        if let Some(n) = self.pending_provisions.get_mut(&class) {
            *n = n.saturating_sub(1);
        }
        self.engine_retired[e] = false;
        self.proxy.reclass_engine(e, class, gpus, max_batch);
        self.engine_down[e] = false;
        self.engine_up_since[e] = Some(self.now());
        self.idle_open(e, BubbleCause::EnvWait);
        self.proxy.set_down(e, false);
        // The pull delivered the current trainer-side version; any
        // per-engine sync the take-down cancelled stays cancelled.
        self.engine_version[e] = self.version;
        self.wsync[e] = EngineSync::Idle;
        self.wsync_version[e] = self.version;
        self.recompute_gen_version();
        if !self.proxy.is_suspended() {
            self.proxy.engines_mut()[e].resume();
        }
        if self.rec.is_enabled() {
            let label = self.engine_label(e);
            self.rec.process_name(Self::engine_pid(e), &label);
        }
        self.update_env_target();
        self.flush_pending();
        self.refill();
        self.kick_engine(e);
    }

    // ---- reward & training ------------------------------------------

    fn dispatch_reward(&mut self, mgr: usize) {
        let mut r = self.rng.stream("rexec", mgr as u64);
        let mut exec = reward_exec(self.cfg, &mut r);
        if self.fault_on && matches!(self.cfg.reward, RewardDeploy::Serverless { .. }) {
            // Serverless stragglers: the invocation lands on a slow
            // sandbox and runs straggler_factor× longer.
            let mult = self.cfg.fault.reward_multiplier(&self.rng, mgr as u64);
            if mult > 1.0 {
                exec *= mult;
                self.fault_report.reward_stragglers += 1;
            }
        }
        match &self.cfg.reward {
            RewardDeploy::Serverless { .. } => {
                let inv = self.serverless.invoke(self.now(), exec, &mut r);
                let delay = (inv.done_s - self.now()).max(0.0);
                self.q.schedule_in(delay, Ev::RewardDone { mgr });
            }
            RewardDeploy::DedicatedGpus { .. } => {
                // FIFO over the dedicated reward servers.
                let now = self.now();
                let slot = self
                    .reward_gpu_free_at
                    .iter_mut()
                    .min_by(|a, b| a.partial_cmp(b).unwrap())
                    .expect("dedicated reward needs ≥1 GPU");
                let start = slot.max(now);
                *slot = start + exec;
                self.reward_busy_s += exec;
                let done = *slot;
                self.q.schedule_in(done - now, Ev::RewardDone { mgr });
            }
        }
    }

    /// Reward scored: group accounting + buffer deposit.
    ///
    /// GRPO needs *complete groups* (the group mean/std is the
    /// advantage baseline), so trajectories are staged until their
    /// group fills and only then deposited — this is exactly why
    /// redundant environment rollouts pay off (§6.3): one straggler
    /// otherwise gates its whole group's availability.
    fn on_reward_done(&mut self, mgr: usize) {
        if self.mgrs[mgr].is_terminal()
            && self.mgrs[mgr].phase == crate::coordinator::EnvPhase::Aborted
        {
            return;
        }
        let id = self.mgrs[mgr].id;
        let group = self.mgrs[mgr].traj.group;
        self.mgrs[mgr].traj.reward = Some(1.0);
        match self.groups.complete(id) {
            GroupOutcome::Surplus => {
                // Completed after its group filled (racing abort): the
                // trajectory is dropped without entering the buffer.
                self.transition(mgr, TrajPhase::Aborted);
            }
            GroupOutcome::Pending => {
                let traj = self.mgrs[mgr].traj.clone();
                self.staged[group as usize].push(traj);
            }
            GroupOutcome::Filled { abort } => {
                let traj = self.mgrs[mgr].traj.clone();
                let mut members = std::mem::take(&mut self.staged[group as usize]);
                members.push(traj);
                // Deposited = handed to the buffer with its whole
                // group; the buffer may still evict stale entries.
                let ids: Vec<usize> = members.iter().map(|t| t.id.0 as usize).collect();
                if self.policy.group_atomic_deposits() {
                    // Atomic group deposit: all members or none (GRPO
                    // groups must never enter the buffer partially).
                    self.buffer.deposit_group(members, self.version);
                } else {
                    // Baseline semantics: per-trajectory deposit, a
                    // stale member is dropped individually (AReaL).
                    for t in members {
                        self.buffer.deposit(t, self.version);
                    }
                }
                for i in ids {
                    self.transition(i, TrajPhase::Deposited);
                }
                for t in abort {
                    let i = t.0 as usize;
                    if !self.mgrs[i].is_terminal() {
                        self.abort_mgr(i, AbortReason::Redundant);
                    }
                }
            }
        }
        self.refill();
        self.try_iteration_boundary();
    }

    /// The scheduling heart: can a train step (and the weight-sync
    /// protocol) start now?
    fn try_iteration_boundary(&mut self) {
        if self.trainer_busy || self.suspend_draining || self.pending_batch.is_some() {
            return;
        }
        let Some(batch) = self.buffer.get_batch(self.cfg.batch_size, self.version) else {
            // Barrier modes relaunch the next iteration only once the
            // batch is consumed; nothing to do here.
            return;
        };
        let tokens: f64 = batch.iter().map(|t| t.total_tokens() as f64).sum();
        let n = batch.len();
        self.acc_staleness = batch
            .iter()
            .map(|t| (self.version.0 - t.min_version().0) as f64)
            .sum::<f64>()
            / n.max(1) as f64;
        self.acc_wait += self.now() - self.trainer_idle_since;

        // Weight sync before this train step (protocol ②–⑤) when the
        // engines run older weights than the trainer produced.  The
        // blocking baseline pays the fleet drain here; the event-driven
        // strategies bump the trainer-side version, hand the fleet to
        // the dissemination plane, and train immediately — the α
        // machinery (admission gate + buffer eviction) bounds how far
        // a lagging engine's output can drift.
        if self.weights_pushed_at.is_some() {
            if self.wstrategy.blocking() {
                self.pending_batch = Some((n, tokens));
                self.begin_suspend();
            } else {
                let push_start = self.weights_pushed_at.take().unwrap_or_else(|| self.now());
                self.version = self.version.next();
                // The bump can only matter to gen_version when the
                // whole fleet is down (its fallback); keep the cache
                // coherent anyway.
                self.recompute_gen_version();
                self.begin_dissemination(push_start);
                self.start_train(tokens);
            }
        } else {
            self.start_train(tokens);
        }
        // One-off / Sync+ barrier: next iteration launches are handled
        // at train start / sync completion respectively.
    }

    fn begin_suspend(&mut self) {
        self.proxy.suspend();
        self.suspend_draining = true;
        // Already-idle engines wait on the drain from this instant;
        // busy ones open their awaiting-weights window at EngineFree.
        for e in 0..self.engine_busy.len() {
            self.idle_split(e, BubbleCause::AwaitingWeights);
        }
        if self.engine_busy.iter().all(|b| !b) {
            self.finish_drain();
        }
        // else: the in-flight EngineFree events trigger finish_drain.
    }

    fn finish_drain(&mut self) {
        if !self.suspend_draining || self.sync_scheduled || self.engine_busy.iter().any(|b| *b) {
            return;
        }
        // Exposed update (③) + KV recompute (⑤).
        let push_start = self.weights_pushed_at.take().unwrap_or(self.now());
        let overlap = self.now() - push_start;
        let bytes = self.cfg.model.weight_bytes();
        let exposed = if self.cfg.async_weight_sync {
            self.store.sync(bytes, overlap).exposed_s
        } else {
            // Blocking veRL-style cross-cluster transfer (Fig 14a).
            self.store.sync(bytes, 0.0).naive_s
        };
        let recompute = self.proxy.recompute_cost_s();
        self.acc_exposed_sync += exposed;
        self.acc_recompute += recompute;
        // Blocking-strategy report: the whole window is trainer-exposed
        // and the whole live fleet sits offline through it.
        let live = (0..self.engine_down.len())
            .filter(|&i| !self.engine_down[i])
            .count();
        self.wreport.publishes += 1;
        self.wreport.engine_syncs += live as u64;
        self.wreport.exposed_stall_s += exposed + recompute;
        self.wreport.dissemination_s += exposed + recompute;
        self.wreport.engine_offline_s += (exposed + recompute) * live as f64;
        self.sync_scheduled = true;
        if self.rec.is_enabled() {
            let now = self.now();
            self.rec
                .span(obs::PID_DRIVER, 0, "fleet-drain", "weights", now, exposed + recompute);
        }
        self.q.schedule_in(exposed + recompute, Ev::SyncDone);
    }

    fn on_sync_done(&mut self) {
        self.sync_scheduled = false;
        self.suspend_draining = false;
        self.version = self.version.next();
        // The fleet drain flips every engine at once — the per-engine
        // version vector stays uniform under the blocking baseline.
        for v in &mut self.engine_version {
            *v = self.version;
        }
        self.recompute_gen_version();
        // The drain is over: idle from here on is ordinary env-wait
        // (the kicks below close most windows at zero length anyway).
        for e in 0..self.engine_busy.len() {
            self.idle_split(e, BubbleCause::EnvWait);
        }
        self.proxy.resume();
        self.flush_pending();
        self.kick_all_engines();
        if let Some((_, tokens)) = self.pending_batch.take() {
            self.start_train(tokens);
        }
    }

    fn start_train(&mut self, tokens: f64) {
        // Per-engine version lag at the moment training consumes its
        // batch: the live counterpart of the α window.
        for e in 0..self.engine_version.len() {
            if self.engine_down[e] {
                continue;
            }
            let lag = self.version.0.saturating_sub(self.engine_version[e].0);
            self.wreport.lag_samples += 1;
            self.wreport.lag_sum += lag;
            self.wreport.lag_max = self.wreport.lag_max.max(lag);
        }
        let cost = self.cfg.model.train_cost(tokens, 8000.0);
        let t = phase_time(&cost, self.cfg.train_class.spec(), self.cfg.train_gpus.max(1))
            * crate::sim::TRAIN_OVERHEAD;
        self.acc_train += t;
        self.trainer_busy = true;
        self.train_started = self.now();
        self.inflight_train_tokens = tokens;
        self.q.schedule_in(t, Ev::TrainDone);
    }

    fn maybe_launch_barrier_iteration(&mut self) {
        if self.policy.continuous_rollout() || self.iter_launched {
            return;
        }
        self.launch_iteration();
    }

    fn on_train_done(&mut self, tokens_trained: f64) {
        self.trainer_busy = false;
        self.trainer_idle_since = self.now();
        self.train_steps_done += 1;
        if self.rec.is_enabled() {
            let t0 = self.train_started;
            let dur = self.now() - t0;
            self.rec.span(obs::PID_DRIVER, 0, "train", "trainer", t0, dur);
        }
        self.sample_counters();
        // Publish new weights to the store (push overlaps rollout).
        self.weights_pushed_at = Some(self.now());

        // Record the completed step.
        let step_time = self.now() - self.last_train_done;
        self.last_train_done = self.now();
        let breakdown = StepBreakdown {
            generation_s: 0.0, // filled from engine stats at the end
            env_reset_s: 0.0,
            env_step_s: 0.0,
            reward_s: 0.0,
            train_s: std::mem::take(&mut self.acc_train),
            weight_sync_s: std::mem::take(&mut self.acc_exposed_sync)
                + std::mem::take(&mut self.acc_recompute),
            get_batch_wait_s: std::mem::take(&mut self.acc_wait),
            other_s: 0.0,
        };
        self.result.steps.push(StepStats {
            step_time_s: step_time,
            breakdown,
            batch_tokens: tokens_trained,
            mean_staleness: std::mem::take(&mut self.acc_staleness),
            stale_aborts: std::mem::take(&mut self.acc_stale),
            redundant_aborts: std::mem::take(&mut self.acc_redundant),
            env_failures: std::mem::take(&mut self.acc_failures),
            engine_failures: std::mem::take(&mut self.acc_engine_failures),
            requeued: std::mem::take(&mut self.acc_requeued),
        });

        // Closed-loop dissemination (AdaptiveSync): feed the
        // iteration's get_batch wait vs the fleet's worst version lag
        // back into the strategy — the same measured-signal feedback
        // the elastic controllers run on, so the decisions replay
        // bit-identically under a fixed seed.
        let (wait_s, train_s) = {
            let last = self.result.steps.last().expect("step just recorded");
            (last.breakdown.get_batch_wait_s, last.breakdown.train_s)
        };
        let max_lag = (0..self.engine_version.len())
            .filter(|&e| !self.engine_down[e])
            .map(|e| self.version.0.saturating_sub(self.engine_version[e].0))
            .max()
            .unwrap_or(0);
        match self.wstrategy.observe_iteration(wait_s, train_s, max_lag, self.cfg.alpha) {
            AdaptDecision::Raise => self.wreport.adapt_raises += 1,
            AdaptDecision::Lower => self.wreport.adapt_drops += 1,
            AdaptDecision::Hold => {}
        }

        // Elastic controller: one decision per completed iteration,
        // fed by the iteration cost just recorded.
        self.maybe_autoscale();

        // Synchronous-training barrier (Sync+): pay the weight sync
        // *now*, blocking; the next iteration launches on SyncDone.
        if self.policy.sync_blocking_after_train() {
            self.iter_launched = false;
            self.begin_suspend();
        }
        self.try_iteration_boundary();
    }

    // ---- event handlers ---------------------------------------------

    fn on_reset_done(&mut self, mgr: usize) {
        self.inflight_resets = self.inflight_resets.saturating_sub(1);
        if !self.mgrs[mgr].is_terminal() {
            let v = self.gen_version();
            let action = self.mgrs[mgr].on_reset_done(v);
            self.handle_action(mgr, action);
        }
    }

    fn on_reset_retry(&mut self, mgr: usize) {
        self.inflight_resets = self.inflight_resets.saturating_sub(1);
        if !self.mgrs[mgr].is_terminal() {
            self.schedule_reset(mgr);
        }
    }

    /// One trajectory's engine work finished.  In PD mode a prefill
    /// half triggers the KV hop; a decode half (or any colocated
    /// completion) finishes the turn.  `gen_v` is the weight version of
    /// the engine the work completed on — the version this turn is
    /// recorded at (per-engine under rolling/lazy dissemination).
    fn on_generation_complete(&mut self, tid: TrajectoryId, gen_v: Version) {
        let mgr = tid.0 as usize;
        if self.mgrs[mgr].is_terminal() {
            if let Some(pd) = self.pd.as_mut() {
                if let Some(slot) = pd.pending.get_mut(mgr) {
                    *slot = None;
                }
            }
            return;
        }
        let now = self.now();
        let mut kv_delay = None;
        if let Some(pd) = self.pd.as_mut() {
            match pd.pending.get(mgr).and_then(|e| e.as_ref()).map(|e| e.phase) {
                Some(PdPhase::Prefill) => {
                    let entry = pd.pending[mgr].as_mut().expect("entry just seen");
                    entry.phase = PdPhase::Transfer;
                    // Ship the KV over the *contended* link: an
                    // admission wave's worth of prefills completes in
                    // one engine step, so these transfers queue on the
                    // shared slots instead of overlapping for free.
                    // KV hops are the latency-critical class — on a
                    // preemption-enabled link (share_kv_link) they cut
                    // ahead of queued background weight buckets.
                    let bytes = kv_bytes(&self.cfg.model, entry.prefill.new_tokens);
                    let grant = pd.shared.acquire_prio(now, bytes);
                    entry.hop_s = grant.done_s - now;
                    // Telemetry: the forward hops' queueing is the
                    // cross-checkable floor of the kv-queue bubble.
                    self.bubbles.kv_queue_booked_s += grant.queue_delay_s;
                    kv_delay = Some((entry.hop_s, grant.queue_delay_s));
                }
                // A completion for a transfer-phase entry cannot arrive
                // (nothing is on an engine); ignore defensively.
                Some(PdPhase::Transfer) => return,
                Some(PdPhase::Decode) => {
                    let entry = pd.pending.get_mut(mgr).and_then(Option::take);
                    // Decode→prefill prefix reuse (ROADMAP follow-up):
                    // the turn's freshly decoded KV ships *back* so the
                    // next turn's prefill sees the full context — a
                    // reverse-direction transfer on the same shared
                    // link, queueing only against other reverse traffic
                    // (full-duplex fabric).
                    if pd.cfg.prefix_reuse {
                        let more_turns = self.mgrs[mgr].turns_done() + 1
                            < self.mgrs[mgr].turns_total();
                        if let Some(entry) = entry {
                            if more_turns && entry.decode.decode_budget > 0.0 {
                                let bytes =
                                    kv_bytes(&self.cfg.model, entry.decode.decode_budget);
                                let grant = pd.shared.acquire_reverse(now, bytes);
                                if self.pd_reverse_ready.len() <= mgr {
                                    self.pd_reverse_ready.resize(mgr + 1, 0.0);
                                }
                                self.pd_reverse_ready[mgr] = grant.done_s;
                            }
                        }
                    }
                }
                None => {}
            }
        }
        if let Some((dt, queue)) = kv_delay {
            // Still Prefilling (lifecycle-wise) until the decode half
            // dispatches on KvDone.
            self.q.schedule_in(dt, Ev::KvDone { tid });
            // Provenance: split the hop into link queueing vs transfer.
            self.q.tag_last_queue(queue);
            return;
        }
        if self.mgrs[mgr].phase == crate::coordinator::EnvPhase::Generating {
            let action = self.mgrs[mgr].on_generation_done(gen_v);
            self.transition(mgr, TrajPhase::EnvStep);
            self.handle_action(mgr, action);
        }
    }

    fn on_engine_free(&mut self, engine: usize, epoch: u64, completed: Vec<(TrajectoryId, f64)>) {
        if epoch != self.engine_epoch[engine] {
            // The engine crashed (or was retired) while this step was
            // in flight: its queued work was drained and its in-step
            // completions replayed at take-down; this event is void.
            return;
        }
        self.engine_busy[engine] = false;
        self.engine_inflight_done[engine].clear();
        if self.rec.is_enabled() {
            let t0 = self.busy_since[engine];
            let dur = self.now() - t0;
            self.rec.span(Self::engine_pid(engine), 0, "step", "engine", t0, dur);
        }
        // The engine goes idle at this boundary; mid-drain the bubble
        // is the weight plane's, otherwise env-wait until refined.
        let cause = if self.suspend_draining {
            BubbleCause::AwaitingWeights
        } else {
            BubbleCause::EnvWait
        };
        self.idle_open(engine, cause);
        // Turns are recorded at the version of the engine that
        // generated them (exact per-engine attribution under rolling /
        // lazy dissemination; uniform under the blocking baseline).
        let gen_v = self.engine_version[engine];
        for (tid, _ctx) in completed {
            self.on_generation_complete(tid, gen_v);
        }
        if self.suspend_draining {
            self.finish_drain();
            return;
        }
        // Weight plane: an engine whose stream delivered mid-step cuts
        // over at this boundary (the completions above may have
        // re-kicked it; if so it stays committed and cuts at the next
        // boundary)...
        if self.wsync[engine] == EngineSync::AwaitCutover && !self.engine_busy[engine] {
            self.begin_cutover(engine);
            return;
        }
        // ...and a lazy engine takes its idle gap: behind the trainer
        // with nothing queued, it starts its bucketized pull now
        // instead of idling (the cutover follows when the stream
        // lands).
        if self.wstrategy.pull_on_idle()
            && self.wsync[engine] == EngineSync::Idle
            && !self.engine_busy[engine]
            && !self.engine_down[engine]
            && self.engine_version[engine] < self.version
            && self.proxy.engines()[engine].load() == 0
        {
            self.start_engine_sync(engine);
            return;
        }
        self.kick_engine(engine);
    }

    fn on_env_step_done(&mut self, mgr: usize) {
        if !self.mgrs[mgr].is_terminal() {
            let v = self.gen_version();
            let now = self.now();
            let action = self.mgrs[mgr].on_env_step_done(v, now);
            self.handle_action(mgr, action);
        }
    }

    fn on_kv_done(&mut self, tid: TrajectoryId) {
        let mgr = tid.0 as usize;
        if self.mgrs[mgr].is_terminal() {
            if let Some(pd) = self.pd.as_mut() {
                if let Some(slot) = pd.pending.get_mut(mgr) {
                    *slot = None;
                }
            }
            return;
        }
        let decode = {
            let Some(pd) = self.pd.as_mut() else { return };
            let Some(entry) = pd.pending.get_mut(mgr).and_then(|e| e.as_mut()) else {
                return;
            };
            entry.phase = PdPhase::Decode;
            // The hop has delivered: book its duration now, the same
            // event whose dispatch moves the trajectory out of
            // Prefilling, so the prefill-wait correction lands in the
            // same iteration as the residency it corrects.
            self.kv_hop_booked_s += entry.hop_s;
            entry.decode.clone()
        };
        // A decode engine whose idle window this dispatch closes was
        // really waiting on the KV link, not the environments.
        let prev = self.kick_cause;
        self.kick_cause = BubbleCause::KvQueue;
        self.dispatch(decode);
        self.kick_cause = prev;
    }

    fn on_scheduled(&mut self, idx: usize) {
        let event = self.cfg.fault.scheduled[idx].event.clone();
        match event {
            FaultEvent::EngineCrash { engine } => {
                if engine < self.engine_down.len() && !self.engine_retired[engine] {
                    self.kill_engine(engine, true);
                }
            }
            FaultEvent::PoolOutage { class, fraction } => {
                self.pool_outage(class, fraction);
            }
            FaultEvent::PoolRestore { class } => {
                self.pool_restore(class);
            }
        }
    }

    // ---- the event loop ---------------------------------------------

    /// Classify the event being dispatched for the causal-provenance
    /// log (critical-path plane): which pipeline edge its wait
    /// represents, and which actor (engine / env manager / trajectory)
    /// it belongs to.  Purely observational — only called when
    /// provenance is armed.
    fn classify(&self, ev: &Ev) -> (EdgeKind, u32) {
        match ev {
            Ev::ResetDone { mgr } | Ev::ResetRetry { mgr } => (EdgeKind::EnvReset, *mgr as u32),
            Ev::EngineFree { engine, .. } => match self.pd.as_ref() {
                // PD mode tells the phases apart by pool class.
                Some(pd) if self.proxy.engines()[*engine].class == pd.cfg.decode_class => {
                    (EdgeKind::Decode, *engine as u32)
                }
                Some(_) => (EdgeKind::Prefill, *engine as u32),
                None => (EdgeKind::Generation, *engine as u32),
            },
            Ev::EnvStepDone { mgr } => (EdgeKind::EnvStep, *mgr as u32),
            Ev::EnvCrashed { mgr } => (EdgeKind::Fault, *mgr as u32),
            Ev::RewardDone { mgr } => (EdgeKind::Reward, *mgr as u32),
            Ev::TrainDone => (EdgeKind::Train, u32::MAX),
            Ev::SyncDone => (EdgeKind::Barrier, u32::MAX),
            Ev::EngineCrashed { engine }
            | Ev::EngineRecovered { engine }
            | Ev::RecoveryPull { engine } => (EdgeKind::Fault, *engine as u32),
            Ev::Scheduled { .. } => (EdgeKind::Fault, u32::MAX),
            Ev::EngineProvisioned { .. } | Ev::WarmupPull { .. } => (EdgeKind::Elastic, u32::MAX),
            Ev::EngineRepurposed { engine, .. } => (EdgeKind::Elastic, *engine as u32),
            Ev::KvDone { tid } => (EdgeKind::KvHop, tid.0 as u32),
            Ev::WsyncDone { engine, .. } => (EdgeKind::Cutover, *engine as u32),
            Ev::WsyncStreamed { engine, .. } => (EdgeKind::WeightStream, *engine as u32),
            Ev::TraceArrival => (EdgeKind::Arrival, u32::MAX),
        }
    }

    /// Prime the queue: chaos schedule, MTBF processes, initial launch.
    fn prime(&mut self) {
        self.trainer_idle_since = 0.0;
        if self.rec.is_enabled() {
            self.rec.process_name(obs::PID_DRIVER, "driver");
            self.rec.process_name(obs::PID_TRAJ, "trajectories");
            if let Some(pd) = self.pd.as_ref() {
                self.rec.process_name(obs::PID_KV_LINK, "kv-link");
                // Transfer tracks are laid out tid = 2·slot + direction
                // (see finish()); name them so Perfetto shows
                // "slot0 fwd" instead of bare numbers.
                for s in 0..pd.shared.slots() {
                    let (f, r) = (2 * s as u64, 2 * s as u64 + 1);
                    self.rec.thread_name(obs::PID_KV_LINK, f, &format!("slot{s} fwd"));
                    self.rec.thread_name(obs::PID_KV_LINK, r, &format!("slot{s} rev"));
                }
            }
            self.rec.process_name(obs::PID_WEIGHT_LINK, "weight-link");
            for s in 0..self.wlink.slots() {
                self.rec
                    .thread_name(obs::PID_WEIGHT_LINK, 2 * s as u64, &format!("slot{s}"));
            }
            for e in 0..self.engine_down.len() {
                let label = self.engine_label(e);
                self.rec.process_name(Self::engine_pid(e), &label);
            }
        }
        self.sample_counters();
        if self.fault_on {
            for (idx, f) in self.cfg.fault.scheduled.iter().enumerate() {
                self.q.schedule(SimTime::secs(f.at_s), Ev::Scheduled { idx });
            }
            for e in 0..self.engine_down.len() {
                self.schedule_engine_failure(e);
            }
        }
        if self.tr.is_some() {
            // Open-loop trace replay: the arrival process drives all
            // admission; the first tick seeds the chain.
            self.schedule_next_arrival();
        } else if self.policy.continuous_rollout() {
            self.refill();
        } else {
            self.launch_iteration();
        }
    }

    fn run(mut self) -> (ScenarioResult, LifecycleStats, TraceReplayStats) {
        self.prime();
        let target_steps = self.cfg.iterations;
        while let Some((t, ev)) = self.q.pop() {
            if self.fault_on && t.as_secs() > MAX_SIM_S {
                break; // chaos deadlock backstop; results are partial
            }
            if self.prov_on {
                let (kind, actor) = self.classify(&ev);
                self.q.classify_current(kind as u8, actor);
            }
            match ev {
                Ev::ResetRetry { mgr } => self.on_reset_retry(mgr),
                Ev::ResetDone { mgr } => self.on_reset_done(mgr),
                Ev::EngineFree {
                    engine,
                    epoch,
                    completed,
                } => self.on_engine_free(engine, epoch, completed),
                Ev::EnvStepDone { mgr } => self.on_env_step_done(mgr),
                Ev::EnvCrashed { mgr } => {
                    if !self.mgrs[mgr].is_terminal() {
                        self.abort_mgr(mgr, AbortReason::EnvCrash);
                    }
                }
                Ev::EngineCrashed { engine } => {
                    if !self.engine_down[engine] && !self.engine_retired[engine] {
                        self.kill_engine(engine, true);
                    }
                    // The failure process continues either way.
                    self.schedule_engine_failure(engine);
                }
                Ev::EngineRecovered { engine } => self.revive_engine(engine),
                Ev::RecoveryPull { engine } => self.on_recovery_pull(engine),
                Ev::Scheduled { idx } => self.on_scheduled(idx),
                Ev::EngineProvisioned {
                    binding,
                    class,
                    gpus,
                    max_batch,
                } => self.on_engine_provisioned(binding, class, gpus, max_batch),
                Ev::WarmupPull {
                    binding,
                    class,
                    gpus,
                    max_batch,
                } => self.on_warmup_pull(binding, class, gpus, max_batch),
                Ev::EngineRepurposed {
                    engine,
                    class,
                    gpus,
                    max_batch,
                } => self.on_engine_repurposed(engine, class, gpus, max_batch),
                Ev::KvDone { tid } => self.on_kv_done(tid),
                Ev::WsyncDone { engine, epoch } => self.on_wsync_done(engine, epoch),
                Ev::WsyncStreamed { engine, epoch } => self.on_wsync_streamed(engine, epoch),
                Ev::TraceArrival => self.on_trace_arrival(),
                Ev::RewardDone { mgr } => self.on_reward_done(mgr),
                Ev::TrainDone => {
                    let tokens = self.inflight_train_tokens;
                    self.on_train_done(tokens);
                    if self.train_steps_done >= target_steps {
                        break;
                    }
                }
                Ev::SyncDone => {
                    self.on_sync_done();
                    if self.policy.sync_blocking_after_train() {
                        self.maybe_launch_barrier_iteration();
                    }
                }
            }
        }
        self.finish()
    }

    /// Final stats.
    fn finish(mut self) -> (ScenarioResult, LifecycleStats, TraceReplayStats) {
        let total = self.now().max(1e-9);
        self.result.total_time_s = total;
        // Close the telemetry plane: truncated busy spans for engines
        // still mid-step, every open idle window booked through run
        // end, a final counter sample, and the links' transfer logs
        // laid out as occupancy tracks (tid = 2·slot + direction, so
        // same-slot transfers — which the link serializes — share a
        // row).
        self.sample_counters();
        for e in 0..self.engine_busy.len() {
            if self.engine_busy[e] && self.rec.is_enabled() {
                let t0 = self.busy_since[e];
                let dur = self.now() - t0;
                self.rec.span(Self::engine_pid(e), 0, "step", "engine", t0, dur);
            }
            self.idle_close(e);
        }
        if self.rec.is_enabled() {
            let kv_log = match self.pd.as_mut() {
                Some(pd) => pd.shared.drain_trace(),
                None => Vec::new(),
            };
            for t in kv_log {
                let tid = 2 * t.slot as u64 + t.reverse as u64;
                let name = if t.reverse { "kv-reverse" } else { "kv-transfer" };
                self.rec
                    .span(obs::PID_KV_LINK, tid, name, "link", t.start_s, t.done_s - t.start_s);
            }
            for t in self.wlink.drain_trace() {
                let tid = 2 * t.slot as u64 + t.reverse as u64;
                self.rec.span(
                    obs::PID_WEIGHT_LINK,
                    tid,
                    "weight-bucket",
                    "link",
                    t.start_s,
                    t.done_s - t.start_s,
                );
            }
        }
        self.result.bubbles = self.bubbles;
        self.result.sim_events = self.q.popped();
        self.result.peak_queue_depth = self.q.max_depth() as u64;
        // Critical-path plane: fold the causal log into per-iteration
        // blame (the report is the only field provenance may touch —
        // everything else must stay byte-identical with it off).
        if self.prov_on {
            if let Some(log) = self.q.take_provenance() {
                self.result.critpath = Some(Box::new(crate::obs::extract_critpath(&log)));
            }
        }
        // A dissemination window still converging at run end (a lazy
        // fleet floating inside its α slack) closes here.
        if let Some(t0) = self.wdissem_started.take() {
            self.wreport.dissemination_s += total - t0;
        }
        self.result.weights = self.wreport;
        let n_engines = self.engine_busy.len() as f64;
        let busy: f64 = self.proxy.engines().iter().map(|e| e.stats.busy_s).sum();
        if self.fault_on || self.elastic_on() {
            // Engines churned: utilization over engine-*alive* seconds,
            // and the fault/elastic reports become part of the result.
            let mut alive: f64 = self.engine_alive_s.iter().sum();
            for up in self.engine_up_since.iter().flatten() {
                alive += total - up;
            }
            self.result.gen_util = (busy / alive.max(1e-9)).min(1.0);
        } else {
            self.result.gen_util = (busy / (total * n_engines)).min(1.0);
        }
        self.result.gen_tokens = self
            .proxy
            .engines()
            .iter()
            .map(|e| e.stats.prefill_tokens + e.stats.decode_tokens)
            .sum();
        self.result.faults = self.fault_report;
        if let Some(s) = &self.scaler {
            self.result.elastic = s.report;
        }
        if let Some(s) = &self.pd_scaler {
            self.result.elastic = s.report;
        }
        if let Some(pd) = &self.pd {
            self.result.kv_link = pd.shared.stats.report();
        }
        self.result.reward_util = match &self.cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, .. } => {
                self.reward_busy_s / (total * (*gpus).max(1) as f64)
            }
            RewardDeploy::Serverless { .. } => self.serverless.utilization(total),
        };
        // Spread generation time into per-step breakdowns (engines are
        // shared across steps; attribute uniformly).
        let steps = self.result.steps.len().max(1) as f64;
        for s in &mut self.result.steps {
            s.breakdown.generation_s = busy / steps;
        }
        // Trace-replay plane: fold the per-domain accumulators into the
        // SloReport.  The feed-side replay stats stay *outside*
        // `ScenarioResult` — `peak_records_buffered` differs between
        // streamed and materialized feeds by design, and folding it in
        // would break the bit-identity pin between the two.
        let mut replay = TraceReplayStats::default();
        if let Some(mut tr) = self.tr.take() {
            let mut domains = Vec::new();
            let mut total_violations = 0;
            for (domain, acc) in tr.acc.iter_mut() {
                total_violations += acc.violations;
                domains.push(DomainSlo {
                    domain: *domain,
                    completed: acc.completed,
                    target_s: tr.slo.target_for(*domain),
                    p50_s: acc.lat.p50(),
                    p99_s: acc.lat.p99(),
                    max_s: acc.lat.max(),
                    total_latency_s: acc.total_s,
                    violations: acc.violations,
                });
            }
            self.result.slo = Some(Box::new(SloReport {
                domains,
                offered: tr.offered,
                admitted: tr.admitted,
                shed: tr.shed,
                completed: tr.completed,
                aborted: tr.aborted,
                aborted_latency_s: tr.aborted_total_s,
                goodput_rps: tr.completed as f64 / total,
                total_violations,
            }));
            replay = TraceReplayStats {
                offered: tr.offered,
                admitted: tr.admitted,
                shed: tr.shed,
                peak_records_buffered: tr.peak_buffered,
            };
        }
        (self.result, self.lifecycle.into_stats(), replay)
    }
}

/// Run a trajectory-level scenario.
pub fn run(cfg: &Scenario) -> ScenarioResult {
    run_traced(cfg).0
}

/// Run a trajectory-level scenario and return the lifecycle statistics
/// alongside the result (invariant checks, diagnostics).
pub fn run_traced(cfg: &Scenario) -> (ScenarioResult, LifecycleStats) {
    let mut rec = TraceRecorder::disabled();
    run_with_trace(cfg, &mut rec)
}

/// Run a trajectory-level scenario recording telemetry into `rec`.
///
/// With an enabled recorder every trajectory phase, engine step, idle
/// bubble, cutover, link transfer and train step lands as a span
/// (export with [`TraceRecorder::to_chrome_json`] and open in
/// chrome://tracing or Perfetto).  The returned `ScenarioResult` is
/// bit-identical to an untraced run of the same scenario — tracing
/// observes the simulation, never steers it (pinned by the
/// `tests/obs_plane.rs` determinism test).
pub fn run_with_trace(
    cfg: &Scenario,
    rec: &mut TraceRecorder,
) -> (ScenarioResult, LifecycleStats) {
    assert_ne!(cfg.mode, Mode::Sync, "use sync_driver for Mode::Sync");
    let (result, lifecycle, _) = DriverCore::new(cfg, rec, false).run();
    (result, lifecycle)
}

/// Run an open-loop trace-replay scenario (`Scenario::trace` must be
/// set) and return the feed-side [`TraceReplayStats`] alongside the
/// usual result.  `peak_records_buffered` is the constant-memory proof
/// the `fig_trace` bench gates on: a streamed feed pins it at 1
/// regardless of `TraceScenario::requests`, a materialized feed
/// buffers the whole trace.  The stats live outside `ScenarioResult`
/// because they *differ* between the two feeds of the same scenario,
/// whose results are otherwise pinned bit-identical
/// (`tests/determinism.rs`).
pub fn run_trace_replay(cfg: &Scenario) -> (ScenarioResult, LifecycleStats, TraceReplayStats) {
    assert!(cfg.trace.is_some(), "run_trace_replay needs Scenario::trace");
    assert_ne!(cfg.mode, Mode::Sync, "use sync_driver for Mode::Sync");
    let mut rec = TraceRecorder::disabled();
    DriverCore::new(cfg, &mut rec, false).run()
}

/// Run a trajectory-level scenario with **causal event provenance**
/// armed: every scheduled event records its parent, the dispatch loop
/// classifies each pop into a pipeline [`EdgeKind`], and the result
/// carries a [`CritPathReport`](crate::obs::CritPathReport)
/// (`result.critpath`) — the per-iteration critical path, its phase
/// blame decomposition, and the inputs the [`crate::obs::what_if`]
/// estimator re-prices.
///
/// Provenance observes, never steers: aside from `critpath` itself the
/// returned `ScenarioResult` is byte-identical to [`run`]'s (pinned in
/// `tests/critpath_plane.rs`).
pub fn run_with_provenance(cfg: &Scenario) -> (ScenarioResult, LifecycleStats) {
    let mut rec = TraceRecorder::disabled();
    run_instrumented(cfg, &mut rec, true)
}

/// Run with both telemetry planes controlled explicitly: spans into
/// `rec`, and causal provenance on the event queue when `provenance`
/// is set.  [`run_with_trace`] and [`run_with_provenance`] are the
/// common special cases; the `perf_baseline` overhead guard uses this
/// to price recorder + provenance together against the untraced hot
/// path.
pub fn run_instrumented(
    cfg: &Scenario,
    rec: &mut TraceRecorder,
    provenance: bool,
) -> (ScenarioResult, LifecycleStats) {
    assert_ne!(cfg.mode, Mode::Sync, "use sync_driver for Mode::Sync");
    let (result, lifecycle, _) = DriverCore::new(cfg, rec, provenance).run();
    (result, lifecycle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn scenario(mode: Mode) -> Scenario {
        let mut s = Scenario::rollart_default(QWEN3_8B.clone(), 0.06);
        s.mode = mode;
        s.batch_size = 16;
        s.group_size = 4;
        s.iterations = 3;
        s
    }

    /// A small PD deployment over the standard test scenario.
    fn pd_scenario(mode: Mode) -> Scenario {
        let mut s = scenario(mode);
        s.pd = Some(PdScenario {
            gpus_per_node: 2,
            max_batch: 8,
            ..PdScenario::xpyd(1, 1)
        });
        s
    }

    #[test]
    fn lifecycle_edges_are_legal_across_modes() {
        for mode in [Mode::SyncPlus, Mode::OneOff, Mode::AReaL, Mode::RollArt] {
            let (r, lc) = run_traced(&scenario(mode));
            assert_eq!(r.steps.len(), 3, "{mode:?}");
            assert_eq!(lc.violations, 0, "{mode:?}: {:?}", lc.edges);
            assert!(lc.spawned > 0);
            assert!(lc.entered(TrajPhase::Deposited) > 0, "{mode:?}");
            // Colocated engines collapse the phase boundary: turns go
            // Prefilling → EnvStep directly.
            assert!(lc.edge(TrajPhase::Prefilling, TrajPhase::EnvStep) > 0, "{mode:?}");
            assert_eq!(lc.edge(TrajPhase::Prefilling, TrajPhase::Decoding), 0);
        }
    }

    #[test]
    fn lifecycle_edges_stay_legal_under_chaos() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        let mut cfg = scenario(Mode::RollArt);
        // A crash storm on the busiest engine (every prefill-heavy
        // domain routes to H800 engine 0) plus env-worker deaths: the
        // run must ride through with only legal lifecycle edges, and
        // the drained requests must take the Recovering edge.
        cfg.fault = FaultProfile {
            env_crash_p: 0.02,
            engine_recovery_s: 3.0,
            scheduled: (1..120)
                .map(|i| ScheduledFault {
                    at_s: 10.0 * i as f64,
                    event: FaultEvent::EngineCrash { engine: 0 },
                })
                .collect(),
            ..FaultProfile::none()
        };
        let (r, lc) = run_traced(&cfg);
        assert_eq!(r.steps.len(), 3);
        assert_eq!(lc.violations, 0, "{:?}", lc.edges);
        assert!(r.faults.engine_failures > 0);
        assert!(lc.entered(TrajPhase::Recovering) > 0, "{:?}", lc.edges);
        assert!(lc.entered(TrajPhase::Aborted) > 0, "env crashes abort");
    }

    #[test]
    fn recovery_reloads_ride_the_contended_link() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            engine_recovery_s: 3.0,
            scheduled: (1..40)
                .map(|i| ScheduledFault {
                    at_s: 25.0 * i as f64,
                    event: FaultEvent::EngineCrash { engine: 0 },
                })
                .collect(),
            ..FaultProfile::none()
        };
        let r = run(&cfg);
        assert!(r.faults.engine_failures > 0, "{:?}", r.faults);
        // Carried-over ROADMAP fix: every auto-recovery reloads its
        // weights as a real bucketized pull on the contended link
        // instead of hiding the reload inside engine_recovery_s.
        assert!(r.weights.recovery_pulls > 0, "{:?}", r.weights);
        // A recovery completes only after its reload: pulls lead (or
        // match) completed recoveries, and each pull booked real
        // bucket transfers.
        assert!(
            r.weights.recovery_pulls >= r.faults.recoveries,
            "pulls {} vs recoveries {}",
            r.weights.recovery_pulls,
            r.faults.recoveries
        );
        assert!(
            r.weights.buckets.engine_pulls >= r.weights.recovery_pulls,
            "{:?}",
            r.weights.buckets
        );
        // The reload lengthens measured recovery latency beyond the
        // analytic reboot constant.
        assert!(r.faults.recoveries > 0);
        assert!(
            r.faults.recovery_latency_s / r.faults.recoveries as f64
                > cfg.fault.engine_recovery_s,
            "mean recovery {} must exceed the bare reboot {}",
            r.faults.recovery_latency_s / r.faults.recoveries as f64,
            cfg.fault.engine_recovery_s
        );
    }

    #[test]
    fn pd_mode_runs_and_is_deterministic() {
        for mode in [Mode::SyncPlus, Mode::AReaL, Mode::RollArt] {
            let (a, lc) = run_traced(&pd_scenario(mode));
            assert_eq!(a.steps.len(), 3, "{mode:?}");
            assert_eq!(lc.violations, 0, "{mode:?}: {:?}", lc.edges);
            // PD observes the prefill→decode boundary on every turn.
            assert!(lc.edge(TrajPhase::Prefilling, TrajPhase::Decoding) > 0, "{mode:?}");
            assert!(lc.edge(TrajPhase::Decoding, TrajPhase::EnvStep) > 0, "{mode:?}");
            let b = run(&pd_scenario(mode));
            assert_eq!(a.mean_step_time(), b.mean_step_time(), "{mode:?}");
        }
    }

    #[test]
    fn pd_fleet_is_built_from_the_pd_config() {
        let (r, _) = run_traced(&pd_scenario(Mode::RollArt));
        assert!(r.gen_tokens > 0.0);
        let cfg = pd_scenario(Mode::RollArt);
        let engines = super::super::pd::build_engines(cfg.pd.as_ref().unwrap(), &cfg.model);
        assert_eq!(engines.len(), 2, "1P1D: one engine per pool");
        assert_eq!(engines[0].class, GpuClass::H800);
        assert_eq!(engines[1].class, GpuClass::H20);
        assert_eq!(engines[0].gpus, 2);
    }

    #[test]
    fn pd_composes_with_prefill_pool_crashes() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        let mut cfg = pd_scenario(Mode::RollArt);
        // Storm the single prefill engine (engine 0): crash every 10 s
        // with quick recovery.  In-flight prefill halves must be
        // drained and re-queued — trajectories survive, iterations all
        // complete.
        cfg.fault = FaultProfile {
            engine_recovery_s: 3.0,
            scheduled: (1..120)
                .map(|i| ScheduledFault {
                    at_s: 10.0 * i as f64,
                    event: FaultEvent::EngineCrash { engine: 0 },
                })
                .collect(),
            ..FaultProfile::none()
        };
        let (r, lc) = run_traced(&cfg);
        assert_eq!(r.steps.len(), 3, "no iteration may be lost to crashes");
        assert!(r.faults.engine_failures > 0, "{:?}", r.faults);
        assert!(
            r.faults.requeued_requests > 0,
            "a prefill-pool crash must re-queue its in-flight work: {:?}",
            r.faults
        );
        assert_eq!(lc.violations, 0, "{:?}", lc.edges);
        // Determinism holds under PD + chaos.
        let (r2, _) = run_traced(&cfg);
        assert_eq!(r.mean_step_time(), r2.mean_step_time());
        assert_eq!(r.faults, r2.faults);
    }

    #[test]
    fn elastic_scale_down_releases_env_slots() {
        use crate::elastic::ElasticPolicy;
        let mut cfg = scenario(Mode::RollArt);
        cfg.iterations = 4;
        let mut policy = ElasticPolicy::new(GpuClass::H800, cfg.model.rollout_tp, 32);
        // Force scale-down every decision: any wait below 1e6× train
        // counts as train-bound.
        policy.scale_up_wait_ratio = 1e7;
        policy.scale_down_wait_ratio = 1e6;
        policy.min_engines = 1;
        policy.step_engines = 2;
        policy.cooldown_steps = 0;
        cfg.elastic = Some(policy);
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 4);
        assert!(r.elastic.scale_downs > 0, "{:?}", r.elastic);
        assert!(r.elastic.engines_retired > 0, "{:?}", r.elastic);
        // The ROADMAP follow-up: shrinking the generation fleet shrinks
        // the environment pool *through the resource plane* — CpuSlot
        // bindings are actually released, not just a target lowered.
        assert!(
            r.elastic.env_slots_bound >= 16,
            "initial env pool must be resource-backed: {:?}",
            r.elastic
        );
        assert!(
            r.elastic.env_slots_released > 0,
            "scale-down must release CpuSlots: {:?}",
            r.elastic
        );
    }

    #[test]
    fn route_policies_run_and_stay_deterministic() {
        use crate::proxy::RouteKind;
        for kind in [
            RouteKind::LeastLoaded,
            RouteKind::DomainFair,
            RouteKind::TokenBacklog,
        ] {
            let mut cfg = scenario(Mode::RollArt);
            cfg.route = kind;
            let a = run(&cfg);
            let b = run(&cfg);
            assert_eq!(a.steps.len(), 3, "{kind:?}");
            assert_eq!(a.mean_step_time(), b.mean_step_time(), "{kind:?}");
        }
    }

    #[test]
    fn pd_run_reports_kv_link_activity() {
        let r = run(&pd_scenario(Mode::RollArt));
        assert!(r.kv_link.transfers > 0, "{:?}", r.kv_link);
        // Non-PD runs never touch the link.
        let plain = run(&scenario(Mode::RollArt));
        assert_eq!(plain.kv_link.transfers, 0);
        assert_eq!(plain.kv_link.queue_delay_total_s, 0.0);
    }

    #[test]
    fn pd_run_records_phase_residency() {
        let (_, lc) = run_traced(&pd_scenario(Mode::RollArt));
        // Every observable phase of the PD chain accumulated residency.
        for phase in [TrajPhase::Prefilling, TrajPhase::Decoding, TrajPhase::EnvStep] {
            assert!(
                lc.residency_s(phase) > 0.0,
                "{phase:?}: {:?}",
                lc.residency_totals
            );
        }
        assert!(lc.mean_residency_s(TrajPhase::Decoding) > 0.0);
    }

    #[test]
    fn pd_pools_scale_independently() {
        use crate::elastic::PdElasticPolicy;
        // 1P2D so the decode pool has shrink slack and the prefill
        // pool sits at its minimum.
        let mut cfg = scenario(Mode::RollArt);
        cfg.iterations = 4;
        cfg.pd = Some(PdScenario {
            gpus_per_node: 2,
            max_batch: 8,
            ..PdScenario::xpyd(1, 2)
        });
        let mut pol = PdElasticPolicy::for_pd(cfg.pd.as_ref().unwrap());
        // Force a decode-bound regime: any backlog trips the decode
        // detector, the prefill detector never fires, and every
        // iteration is rollout-bound.
        pol.decode_backlog_per_engine = -1.0;
        pol.prefill_wait_per_engine_s = f64::INFINITY;
        pol.kv_bound_ratio = f64::INFINITY;
        pol.decode.scale_up_wait_ratio = 1e-6;
        pol.decode.scale_down_wait_ratio = 1e-7;
        pol.decode.cooldown_steps = 0;
        pol.prefill.cooldown_steps = 0;
        cfg.pd_elastic = Some(pol);
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 4);
        // The split controller acted on the decode pool but not the
        // prefill pool: an independent P-vs-D decision.
        assert!(r.elastic.decode_scale_ups > 0, "{:?}", r.elastic);
        assert_eq!(r.elastic.prefill_scale_ups, 0, "{:?}", r.elastic);
        // Determinism holds for the split controller too.
        let again = run(&cfg);
        assert_eq!(r.elastic, again.elastic);
        assert_eq!(r.mean_step_time(), again.mean_step_time());
    }

    #[test]
    #[should_panic(expected = "pd_elastic requires a disaggregated")]
    fn pd_elastic_requires_disaggregated_pd() {
        use crate::elastic::PdElasticPolicy;
        let mut cfg = scenario(Mode::RollArt);
        let pd = PdScenario::xpyd(1, 1);
        cfg.pd_elastic = Some(PdElasticPolicy::for_pd(&pd));
        // No Scenario::pd at all: the driver must refuse.
        run(&cfg);
    }

    // ---- weight-dissemination plane ---------------------------------

    use crate::weights::{SyncStrategyKind, WeightsScenario};

    fn with_strategy(mode: Mode, kind: SyncStrategyKind) -> Scenario {
        let mut cfg = scenario(mode);
        cfg.weights = WeightsScenario::with_strategy(kind);
        cfg
    }

    fn exposed_sync_total(r: &ScenarioResult) -> f64 {
        r.steps.iter().map(|s| s.breakdown.weight_sync_s).sum()
    }

    const EVENT_STRATEGIES: [SyncStrategyKind; 4] = [
        SyncStrategyKind::RollingSubset { k: 1 },
        SyncStrategyKind::LazyPull,
        SyncStrategyKind::OverlappedBroadcast { chunks: 8 },
        SyncStrategyKind::Adaptive,
    ];

    #[test]
    fn blocking_broadcast_is_the_legacy_fleet_drain() {
        // The pin for the pre-refactor numbers: the default knob IS
        // BlockingBroadcast, an explicit construction must be
        // bit-identical, and the run must show the fleet-drain
        // signature — exposed weight_sync_s every post-warm-up
        // iteration, per-engine versions uniform (zero lag at every
        // train start), zero overlap.
        let cfg = scenario(Mode::RollArt);
        let a = run(&cfg);
        let b = run(&with_strategy(Mode::RollArt, SyncStrategyKind::BlockingBroadcast));
        assert_eq!(a, b, "explicit BlockingBroadcast must equal the default");
        assert!(
            a.steps.iter().skip(1).all(|s| s.breakdown.weight_sync_s > 0.0),
            "fleet drain exposes sync every post-warm-up iteration: {:?}",
            a.steps.iter().map(|s| s.breakdown.weight_sync_s).collect::<Vec<_>>()
        );
        assert_eq!(a.weights.lag_max, 0, "{:?}", a.weights);
        assert_eq!(a.weights.overlap_ratio(), 0.0);
        // One publish per post-warm-up train (a final boundary racing
        // the loop exit may add one more).
        assert!(a.weights.publishes >= 2, "{:?}", a.weights);
        assert!(a.weights.exposed_stall_s > 0.0);
        assert!(a.weights.engine_offline_s > a.weights.exposed_stall_s);
        assert_eq!(a.weights.transfers, 0, "the drain is analytic, not per-engine");
    }

    #[test]
    fn event_strategies_cut_exposed_sync_and_run_clean() {
        // The acceptance criterion: RollingSubset / LazyPull (and the
        // overlapped push) strictly reduce exposed sync time at equal α
        // on the RollArt-mode scenario, while completing the same
        // number of iterations with only legal lifecycle edges.
        let blocking = run(&scenario(Mode::RollArt));
        assert!(exposed_sync_total(&blocking) > 0.0);
        for kind in EVENT_STRATEGIES {
            let cfg = with_strategy(Mode::RollArt, kind);
            let (r, lc) = run_traced(&cfg);
            assert_eq!(r.steps.len(), 3, "{kind:?}");
            assert_eq!(lc.violations, 0, "{kind:?}: {:?}", lc.edges);
            assert!(lc.entered(TrajPhase::Deposited) > 0, "{kind:?}");
            assert!(
                exposed_sync_total(&r) < exposed_sync_total(&blocking),
                "{kind:?} must strictly cut exposed sync"
            );
            assert_eq!(
                exposed_sync_total(&r),
                0.0,
                "{kind:?}: the trainer never stalls on dissemination"
            );
            assert!(r.weights.publishes >= 2, "{kind:?}: {:?}", r.weights);
            assert!(r.weights.engine_syncs > 0, "{kind:?}");
            assert!(r.weights.transfers > 0, "{kind:?}: pulls ride the link");
            assert!(r.weights.engine_offline_s > 0.0, "{kind:?}");
            assert!(
                r.weights.overlap_ratio() > 0.99,
                "{kind:?}: {:?}",
                r.weights
            );
            assert!(
                r.weights.lag_max >= 1,
                "{kind:?}: engines must visibly lag the trainer at train start"
            );
            // Bit-deterministic.
            let again = run(&cfg);
            assert_eq!(r, again, "{kind:?}");
        }
    }

    #[test]
    fn per_engine_versions_attribute_turns_and_bound_lag() {
        // Rolling one engine at a time: the fleet disagrees mid-window,
        // yet the α machinery keeps every *trained* batch inside the
        // window — mean staleness stays bounded by α + 1 versions.
        let cfg = with_strategy(Mode::RollArt, SyncStrategyKind::RollingSubset { k: 1 });
        let r = run(&cfg);
        for s in r.steps.iter().skip(1) {
            assert!(
                s.mean_staleness <= (cfg.alpha + 1) as f64 + 1e-9,
                "trained staleness must stay α-bounded: {}",
                s.mean_staleness
            );
        }
        assert!(r.weights.mean_lag() > 0.0, "{:?}", r.weights);
    }

    #[test]
    fn bucketized_pulls_conserve_bytes_and_fill_the_breakdown() {
        // The tentpole invariant at driver level: every per-engine pull
        // moved exactly the model's weight bytes as bucket transfers,
        // and the Table 4 decomposition is populated per publish /
        // pull / cutover.  (The analytic cross-check lives in
        // tests/weights_conformance.rs.)
        let cfg = with_strategy(Mode::RollArt, SyncStrategyKind::RollingSubset { k: 2 });
        let r = run(&cfg);
        let b = &r.weights.buckets;
        let bytes = cfg.model.weight_bytes();
        let n = cfg.weights.mooncake.bucket_count(bytes) as u64;
        assert!(b.engine_pulls > 0, "{b:?}");
        assert_eq!(b.bucket_transfers, b.engine_pulls * n, "whole pulls only");
        assert!(
            (b.bytes_pulled - b.engine_pulls as f64 * bytes).abs() < 1.0,
            "pipelining must conserve bytes: {b:?}"
        );
        assert!(b.push_s > 0.0 && b.acc_pull_s > 0.0 && b.naive_s > b.push_s);
        assert!(b.cutovers > 0 && b.exposed_s > 0.0);
        // The pull stream hides behind decode: exposed swap cost per
        // cutover is far below the per-engine pull it replaces.
        assert!(b.mean_exposed_s() < 0.5 * b.mean_pull_s(), "{b:?}");
    }

    #[test]
    fn provisioned_engines_pay_real_warmup_pulls() {
        use crate::elastic::ElasticPolicy;
        use crate::simkit::dist::Dist;
        // Slow env steps make every iteration rollout-bound, so the
        // eager thresholds below are guaranteed to scale up.
        let mut cfg = with_strategy(Mode::RollArt, SyncStrategyKind::RollingSubset { k: 1 });
        cfg.iterations = 4;
        cfg.env_step_override = Some(Dist::Constant(30.0));
        let mut policy = ElasticPolicy::new(GpuClass::H800, cfg.model.rollout_tp, 32);
        policy.scale_up_wait_ratio = 0.1;
        policy.scale_down_wait_ratio = 0.01;
        policy.cooldown_steps = 0;
        cfg.elastic = Some(policy);
        let r = run(&cfg);
        assert!(r.elastic.scale_ups > 0, "{:?}", r.elastic);
        assert!(
            r.weights.warmup_pulls > 0,
            "scale-ups must book their warm-up pull on the link: {:?}",
            r.weights
        );
        assert!(
            r.weights.warmup_pulls >= r.elastic.engines_added,
            "every provisioned engine paid a pull: {:?} vs {:?}",
            r.weights,
            r.elastic
        );
        // Deterministic with warm-up traffic on the contended link.
        let again = run(&cfg);
        assert_eq!(r, again);
    }

    #[test]
    fn adaptive_sync_closes_the_loop() {
        let mut cfg = with_strategy(Mode::RollArt, SyncStrategyKind::Adaptive);
        cfg.iterations = 5;
        let (r, lc) = run_traced(&cfg);
        assert_eq!(r.steps.len(), 5);
        assert_eq!(lc.violations, 0, "{:?}", lc.edges);
        assert_eq!(exposed_sync_total(&r), 0.0, "adaptive never stalls the trainer");
        assert!(r.weights.engine_syncs > 0);
        // The controller made at least one observation pass (counters
        // may legitimately both be zero on a balanced run, but the
        // run must stay bit-deterministic with whatever it decided).
        let again = run(&cfg);
        assert_eq!(r, again);
        assert_eq!(
            (r.weights.adapt_raises, r.weights.adapt_drops),
            (again.weights.adapt_raises, again.weights.adapt_drops)
        );
    }

    #[test]
    fn overlapped_push_pays_less_engine_offline_than_rolling() {
        // The whole point of chunked streaming: the transfer hides
        // behind decode, engines suspend only for the cutover.
        let rolling = run(&with_strategy(
            Mode::RollArt,
            SyncStrategyKind::RollingSubset { k: 2 },
        ));
        let overlapped = run(&with_strategy(
            Mode::RollArt,
            SyncStrategyKind::OverlappedBroadcast { chunks: 8 },
        ));
        assert!(
            overlapped.weights.engine_offline_s < rolling.weights.engine_offline_s,
            "overlapped {} vs rolling {}",
            overlapped.weights.engine_offline_s,
            rolling.weights.engine_offline_s
        );
    }

    #[test]
    fn weight_pulls_contend_on_the_fanout_link() {
        // Overlapped broadcast streams the whole fleet at once over
        // fanout_slots FIFO slots: the burst must queue.
        let mut cfg = with_strategy(
            Mode::RollArt,
            SyncStrategyKind::OverlappedBroadcast { chunks: 8 },
        );
        cfg.weights.fanout_slots = 1;
        let narrow = run(&cfg);
        assert!(narrow.weights.queued_transfers > 0, "{:?}", narrow.weights);
        assert!(narrow.weights.link_queue_delay_s > 0.0);
        cfg.weights.fanout_slots = 64;
        let wide = run(&cfg);
        assert!(
            wide.weights.link_queue_delay_s < narrow.weights.link_queue_delay_s,
            "wide {:?} vs narrow {:?}",
            wide.weights,
            narrow.weights
        );
    }

    #[test]
    fn strategies_compose_with_pd_and_share_the_kv_link() {
        for kind in EVENT_STRATEGIES {
            let mut cfg = pd_scenario(Mode::RollArt);
            cfg.weights = WeightsScenario::with_strategy(kind);
            let (r, lc) = run_traced(&cfg);
            assert_eq!(r.steps.len(), 3, "{kind:?}");
            assert_eq!(lc.violations, 0, "{kind:?}: {:?}", lc.edges);
            assert!(r.weights.engine_syncs > 0, "{kind:?}");
        }
        // share_kv_link: weight pulls ride the PD KV link and show up
        // in its transfer count on top of the KV hops.
        let mut apart = pd_scenario(Mode::RollArt);
        apart.weights = WeightsScenario::with_strategy(SyncStrategyKind::RollingSubset { k: 1 });
        let r_apart = run(&apart);
        let mut shared = pd_scenario(Mode::RollArt);
        shared.weights =
            WeightsScenario::with_strategy(SyncStrategyKind::RollingSubset { k: 1 });
        shared.weights.share_kv_link = true;
        let r_shared = run(&shared);
        assert!(
            r_shared.kv_link.transfers > r_apart.kv_link.transfers,
            "weight traffic must land on the shared KV link: {:?} vs {:?}",
            r_shared.kv_link,
            r_apart.kv_link
        );
        assert!(r_shared.weights.transfers > 0);
    }

    #[test]
    fn strategies_compose_with_chaos() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        for kind in EVENT_STRATEGIES {
            let mut cfg = with_strategy(Mode::RollArt, kind);
            cfg.fault = FaultProfile {
                env_crash_p: 0.01,
                engine_recovery_s: 3.0,
                scheduled: (1..60)
                    .map(|i| ScheduledFault {
                        at_s: 20.0 * i as f64,
                        event: FaultEvent::EngineCrash { engine: 0 },
                    })
                    .collect(),
                ..FaultProfile::none()
            };
            let (r, lc) = run_traced(&cfg);
            assert_eq!(r.steps.len(), 3, "{kind:?}");
            assert_eq!(lc.violations, 0, "{kind:?}: {:?}", lc.edges);
            let again = run(&cfg);
            assert_eq!(r.mean_step_time(), again.mean_step_time(), "{kind:?}");
        }
    }

    #[test]
    #[should_panic(expected = "does not admit weight strategy")]
    fn barrier_mode_rejects_event_strategies() {
        run(&with_strategy(Mode::SyncPlus, SyncStrategyKind::LazyPull));
    }

    #[test]
    fn pd_prefix_reuse_ships_reverse_kv() {
        let mut cfg = pd_scenario(Mode::RollArt);
        cfg.pd.as_mut().expect("pd set").prefix_reuse = true;
        let (r, lc) = run_traced(&cfg);
        assert_eq!(r.steps.len(), 3);
        assert_eq!(lc.violations, 0, "{:?}", lc.edges);
        assert!(
            r.kv_link.reverse_transfers > 0,
            "multi-turn decodes must ship prefix KV back: {:?}",
            r.kv_link
        );
        // Off by default: no reverse traffic.
        let plain = run(&pd_scenario(Mode::RollArt));
        assert_eq!(plain.kv_link.reverse_transfers, 0);
        // Deterministic with the reverse hops in play.
        let again = run(&cfg);
        assert_eq!(r.mean_step_time(), again.mean_step_time());
    }

    #[test]
    fn train_class_threads_through_the_event_driver() {
        let fast = run(&scenario(Mode::RollArt));
        let mut cfg = scenario(Mode::RollArt);
        cfg.train_class = GpuClass::H20;
        let slow = run(&cfg);
        let t = |r: &ScenarioResult| -> f64 {
            r.steps.iter().map(|s| s.breakdown.train_s).sum()
        };
        assert!(t(&slow) > t(&fast), "{} vs {}", t(&slow), t(&fast));
    }
}
