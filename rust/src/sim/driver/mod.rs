//! The trajectory-level scheduler plane: a thin event-loop core with
//! pluggable policies.
//!
//! This subsystem replaces the old `async_driver` monolith (one
//! ~1,200-line `run()` with per-mode conditionals).  It is split along
//! the paper's own seams:
//!
//! | module | role |
//! |---|---|
//! | [`lifecycle`] | the trajectory state machine (Queued → Prefilling → Decoding → EnvStep → Reward → Deposited, with Suspended/Recovering/Aborted edges) every phase change funnels through |
//! | [`policy`] | [`SchedPolicy`](policy::SchedPolicy): one small struct per [`Mode`](crate::sim::Mode) — admission/staleness gating, redundancy, deposit atomicity, weight-sync discipline |
//! | [`pd`] | prefill-decode disaggregation as a simulated execution mode (xPyD pools, KV hop over a [`Link`](crate::net::Link), optional decode→prefill prefix-reuse reverse hops), composing with faults, elasticity and staleness |
//! | [`core`] | the mode-agnostic DES loop: dispatch, fault recovery, elastic scaling, weight dissemination (per-engine versions driven by a [`crate::weights::SyncStrategy`]), iteration accounting |
//!
//! Routing is equally pluggable on the proxy side — see
//! [`crate::proxy::route`].
//!
//! [`crate::sim::async_driver`] remains as a compatibility shim over
//! [`run`].

pub mod core;
pub mod lifecycle;
pub mod pd;
pub mod policy;

pub use self::core::{
    run, run_instrumented, run_trace_replay, run_traced, run_with_provenance, run_with_trace,
};
pub use lifecycle::{LifecycleStats, LifecycleTracker, TrajPhase};
pub use pd::PdScenario;
pub use policy::{policy_for, SchedPolicy};
