//! Discrete-event simulation drivers: the evaluation harness.
//!
//! Two drivers share the control-plane core:
//!
//! * [`sync_driver`] — the phase-structured *monolithic synchronous*
//!   pipeline (the paper's Sync baseline): batched env interaction,
//!   dedicated reward GPUs, blocking weight sync, blocking training.
//!   Produces the Fig 3 step breakdowns and Fig 6 utilization directly.
//! * [`driver`] — the trajectory-level scheduler plane used by Sync+,
//!   One-off, AReaL and RollArt: a mode-agnostic event-loop core
//!   ([`driver::core`]) with per-mode [`driver::policy`] structs, an
//!   explicit trajectory [`driver::lifecycle`] state machine, and PD
//!   disaggregation as a simulated execution mode ([`driver::pd`]).
//!   [`async_driver`] remains as a compatibility shim over it.
//!
//! Scenario configs mirror the paper's §7.1 setup; each bench in
//! `rust/benches/paper_figures.rs` instantiates one scenario per table
//! or figure row.

pub mod async_driver;
pub mod driver;
pub mod sync_driver;

/// Trainer time over the raw roofline: RL training steps run at low
/// MFU (long sequences with activation recompute, logprob passes,
/// pipeline bubbles, optimizer sync).  8x over roofline ≈ 6% MFU,
/// consistent with Fig 3's measured 84 s train phase for Qwen3-8B
/// batch 128 on 32 H800s.
pub const TRAIN_OVERHEAD: f64 = 8.0;

use crate::buffer::StalenessPolicy;
use crate::elastic::{ElasticPolicy, ElasticReport, PdElasticPolicy};
use crate::env::TaskDomain;
use crate::envpool::EnvPoolConfig;
use crate::fault::{FaultProfile, FaultReport};
use crate::hw::GpuClass;
use crate::llm::LlmSpec;
use crate::metrics::StepBreakdown;
use crate::net::KvLinkReport;
use crate::proxy::RouteKind;
use crate::simkit::dist::Dist;
use crate::weights::{WeightSyncReport, WeightsScenario};

/// Coordination semantics (§7.1's baseline grid).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Monolithic synchronous (Sync): batched env interaction, blocking
    /// reward/train/sync. Runs on [`sync_driver`].
    Sync,
    /// Sync + async env + async serverless reward, but synchronous
    /// training (Sync+).
    SyncPlus,
    /// One-off asynchrony [32]: rollout k+1 overlaps train k; batch
    /// boundaries preserved.
    OneOff,
    /// AReaL-style: continuous rollout, staleness bounded at trajectory
    /// *start* only.
    AReaL,
    /// RollArt: continuous rollout, per-iteration staleness bound,
    /// suspend/resume + KV recompute, hardware-affinity routing.
    RollArt,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Sync => "Sync",
            Mode::SyncPlus => "Sync+",
            Mode::OneOff => "One-off",
            Mode::AReaL => "AReaL",
            Mode::RollArt => "RollArt",
        }
    }
}

/// One engine pool entry: `count` engines of `gpus` × `class`.
#[derive(Clone, Debug)]
pub struct EnginePool {
    pub class: GpuClass,
    pub gpus_per_engine: usize,
    pub engines: usize,
    pub max_batch: usize,
}

/// Reward-stage deployment (R3 ablation, Fig 6/12).
#[derive(Clone, Debug)]
pub enum RewardDeploy {
    /// Dedicated local GPUs; `exec_s` per call, `gpus` servers.
    DedicatedGpus { gpus: usize, exec_s: Dist },
    /// Elastic serverless platform.
    Serverless { exec_s: Dist },
}

/// A full scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub mode: Mode,
    pub model: LlmSpec,
    /// Task domains sampled uniformly (§7.1 uniform task sampling).
    pub task_mix: Vec<TaskDomain>,
    /// Trajectories per training batch (paper: 512; scaled in tests).
    pub batch_size: usize,
    /// Concurrent environments E for the continuous modes (defaults to
    /// the batch size when None, matching the paper's setup; with
    /// E = batch the steady-state step interval equals one trajectory
    /// lifetime, so alpha = 1 lets typical trajectories complete).
    pub concurrent_envs: Option<usize>,
    /// GRPO group size (paper: 8).
    pub group_size: usize,
    /// Redundant environments launched per group (§6.3).
    pub redundancy: usize,
    /// Training pool (compute-optimized GPUs).
    pub train_gpus: usize,
    /// GPU class of the training pool (paper: H800; configurable so
    /// cost-equivalent H20 training ablations are expressible).
    pub train_class: GpuClass,
    /// Generation engine pools.
    pub gen_pools: Vec<EnginePool>,
    /// R1: route prefill-heavy domains to H800, decode-heavy to H20.
    pub affinity_routing: bool,
    /// Asynchronous bound α and eviction policy (continuous modes).
    pub alpha: u64,
    pub staleness: StalenessPolicy,
    pub envpool: EnvPoolConfig,
    /// Override per-turn env.step latency (Fig 11b Gaussian injection).
    pub env_step_override: Option<Dist>,
    pub reward: RewardDeploy,
    /// Cross-cluster weight path: async Mooncake store vs blocking
    /// transfer (Fig 14a).
    pub async_weight_sync: bool,
    /// Iterations to simulate (first iteration discarded as warm-up in
    /// steady-state metrics).
    pub iterations: usize,
    pub seed: u64,
    /// Cluster-level failure injection (engine crashes, env-worker
    /// deaths, serverless stragglers, scheduled chaos).  Inactive by
    /// default; when inactive no fault stream is ever sampled, so
    /// results are bit-identical to a fault-free build.
    pub fault: FaultProfile,
    /// Optional autoscaling controller over the generation pool.
    /// Mutually exclusive with `pd_elastic`.
    pub elastic: Option<ElasticPolicy>,
    /// Prefill-decode disaggregation as a simulated execution mode
    /// (§6.3): when set, the `xPyD` deployment replaces `gen_pools`
    /// and every generation request is split into a prefill half and a
    /// decode half with the KV cache shipped between the pools over a
    /// *contended* shared link.  See [`driver::pd::PdScenario`].
    pub pd: Option<driver::pd::PdScenario>,
    /// Split autoscaling controller for a PD deployment: resizes the
    /// prefill and decode pools *independently* on per-class bottleneck
    /// signals (prefill queue wait / decode token backlog / KV-link
    /// queue delay).  Requires a disaggregated `pd`; mutually exclusive
    /// with `elastic`.
    pub pd_elastic: Option<PdElasticPolicy>,
    /// Dispatch discipline of the generation proxy (R1 affinity
    /// routing by default; see [`crate::proxy::route`]).
    pub route: RouteKind,
    /// Weight-dissemination plane: per-engine weight versions and the
    /// [`SyncStrategy`](crate::weights::SyncStrategy) that refreshes
    /// them (default: the legacy fleet-drain
    /// [`BlockingBroadcast`](crate::weights::BlockingBroadcast)).
    pub weights: WeightsScenario,
    /// Trace-replay plane: when set, closed-loop admission is replaced
    /// by open-loop arrivals drawn from this trace (§8 production
    /// replay; see [`crate::trace::TraceScenario`]).  Event-driver
    /// modes only — the analytic Sync driver ignores it.
    pub trace: Option<crate::trace::TraceScenario>,
    /// Per-domain SLO targets and load-shedding backstop for a trace
    /// replay.  `None` with `trace` set still emits an [`SloReport`]
    /// (infinite targets, no shedding).
    ///
    /// [`SloReport`]: crate::trace::SloReport
    pub slo: Option<crate::trace::SloPolicy>,
}

impl Scenario {
    /// The paper's default mixed-task RollArt scenario, scaled by
    /// `scale` (1.0 = paper size: batch 512, 96 H800 + 32 H20).
    ///
    /// Engines are sized at the model's rollout tensor-parallel degree
    /// (§7.1: TP 1/2/4 for 8B/14B/32B) — one engine replica per TP
    /// group, which is what makes the H20-vs-H800 decode rooflines
    /// visible (an 8-way TP engine for an 8B model would be
    /// launch-overhead-bound and mask the hardware difference).
    pub fn rollart_default(model: LlmSpec, scale: f64) -> Scenario {
        let b = ((512.0 * scale) as usize).max(16);
        let h800_gen = ((64.0 * scale) as usize).max(2);
        let h20_gen = ((32.0 * scale) as usize).max(2);
        let tp = model.rollout_tp;
        let per_engine_batch = 32;
        Scenario {
            mode: Mode::RollArt,
            model: model.clone(),
            task_mix: vec![
                TaskDomain::Swe,
                TaskDomain::Web,
                TaskDomain::Game,
                TaskDomain::MathTool,
                TaskDomain::GameSingle,
            ],
            batch_size: b,
            concurrent_envs: None,
            group_size: 8,
            redundancy: 0,
            train_gpus: ((32.0 * scale) as usize).max(2),
            train_class: GpuClass::H800,
            gen_pools: vec![
                EnginePool {
                    class: GpuClass::H800,
                    gpus_per_engine: tp,
                    engines: (h800_gen / tp).max(1),
                    max_batch: per_engine_batch,
                },
                EnginePool {
                    class: GpuClass::H20,
                    gpus_per_engine: tp,
                    engines: (h20_gen / tp).max(1),
                    max_batch: per_engine_batch,
                },
            ],
            affinity_routing: true,
            alpha: 1,
            staleness: StalenessPolicy::PerTurn,
            envpool: EnvPoolConfig::registry_only(),
            env_step_override: None,
            reward: RewardDeploy::Serverless {
                exec_s: Dist::lognormal_median(1.0, 0.6),
            },
            async_weight_sync: true,
            iterations: 6,
            seed: 17,
            fault: FaultProfile::none(),
            elastic: None,
            pd: None,
            pd_elastic: None,
            route: RouteKind::Affinity,
            weights: WeightsScenario::default(),
            trace: None,
            slo: None,
        }
    }

    pub fn total_gen_gpus(&self) -> usize {
        self.gen_pools
            .iter()
            .map(|p| p.gpus_per_engine * p.engines)
            .sum()
    }
}

/// One training iteration's results.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepStats {
    /// Wall-clock of this iteration (train-step to train-step).
    pub step_time_s: f64,
    pub breakdown: StepBreakdown,
    /// Tokens (prompt + response) in the consumed batch — throughput
    /// numerator (§7.1 Metrics).
    pub batch_tokens: f64,
    /// Mean staleness (versions) of the consumed batch.
    pub mean_staleness: f64,
    /// Trajectories aborted for staleness this iteration.
    pub stale_aborts: u64,
    /// Trajectories aborted as redundant.
    pub redundant_aborts: u64,
    /// Env failures observed (reset timeouts + injected worker
    /// crashes).
    pub env_failures: u64,
    /// Engine crashes observed this iteration (fault plane).
    pub engine_failures: u64,
    /// Generation requests re-queued off dead engines this iteration
    /// (trajectory-level recovery).
    pub requeued: u64,
}

/// Scenario outcome.
///
/// Derives `PartialEq` so the determinism regression test (see
/// `docs/DETERMINISM.md`) can assert that two runs of the same seeded
/// scenario produce *bit-identical* results, field for field.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScenarioResult {
    pub steps: Vec<StepStats>,
    /// Reward-resource utilization over the run (Fig 6/12).
    pub reward_util: f64,
    /// Mean generation-GPU busy fraction.
    pub gen_util: f64,
    pub total_time_s: f64,
    /// Tokens the engines actually processed (prefill + decode),
    /// including work later discarded by aborts and crash replays —
    /// the goodput denominator's "offered work" side.
    pub gen_tokens: f64,
    /// Fault-plane activity over the run.
    pub faults: FaultReport,
    /// Elastic-controller activity over the run (single-pool or PD
    /// split controller; the latter also fills the per-class fields).
    pub elastic: ElasticReport,
    /// KV-link contention of a PD run (zero when `pd` is unset): how
    /// many transfers queued on the shared link and for how long.
    pub kv_link: KvLinkReport,
    /// Weight-dissemination activity: exposed stall, per-engine
    /// version lag, fan-out link contention (see [`crate::weights`]).
    pub weights: WeightSyncReport,
    /// Engine idle time decomposed into named causes by the telemetry
    /// plane (see [`crate::obs::BubbleReport`]).  Always populated by
    /// the event driver, traced or not.
    pub bubbles: crate::obs::BubbleReport,
    /// Events the DES dispatched over the run (event-driver runs only;
    /// the analytic Sync driver leaves it 0).
    pub sim_events: u64,
    /// High-water mark of the pending-event heap.
    pub peak_queue_depth: u64,
    /// Per-iteration critical paths and their blame decomposition,
    /// populated only by [`driver::run_with_provenance`] (the Sync
    /// driver synthesizes one from its analytic breakdown — see
    /// [`sync_driver::run_with_critpath`]).  `None` everywhere else,
    /// so ordinary runs stay byte-identical whether or not the
    /// critical-path plane is compiled against.
    pub critpath: Option<Box<crate::obs::CritPathReport>>,
    /// Multi-tenant SLO outcome of a trace replay, populated whenever
    /// [`Scenario::trace`] is set (`None` otherwise, so non-trace runs
    /// stay byte-identical to builds without the trace plane).
    pub slo: Option<Box<crate::trace::SloReport>>,
}

impl ScenarioResult {
    /// Steady-state mean step time (drops the first iteration).
    pub fn mean_step_time(&self) -> f64 {
        let steps: Vec<&StepStats> = self.steps.iter().skip(1).collect();
        if steps.is_empty() {
            return self.steps.first().map(|s| s.step_time_s).unwrap_or(0.0);
        }
        steps.iter().map(|s| s.step_time_s).sum::<f64>() / steps.len() as f64
    }

    /// Steady-state throughput, tokens/s (§7.1 Metrics).
    pub fn throughput(&self) -> f64 {
        let steps: Vec<&StepStats> = self.steps.iter().skip(1).collect();
        if steps.is_empty() {
            return 0.0;
        }
        let tok: f64 = steps.iter().map(|s| s.batch_tokens).sum();
        let t: f64 = steps.iter().map(|s| s.step_time_s).sum();
        tok / t.max(1e-9)
    }

    /// Goodput (§8 robustness metric): *useful* tokens — tokens that
    /// reached a training batch — per wall-clock second over the whole
    /// run, warm-up included.  Under fault injection this is the number
    /// that degrades: crashes burn wall-clock (recovery, replays) and
    /// tokens (aborted trajectories) without adding trained tokens.
    pub fn goodput(&self) -> f64 {
        let tok: f64 = self.steps.iter().map(|s| s.batch_tokens).sum();
        tok / self.total_time_s.max(1e-9)
    }

    /// Fraction of engine-processed tokens that reached a training
    /// batch (1.0 = nothing wasted on aborts/replays).  0 when the
    /// driver did not record engine token counts.
    pub fn token_efficiency(&self) -> f64 {
        if self.gen_tokens <= 0.0 {
            return 0.0;
        }
        let tok: f64 = self.steps.iter().map(|s| s.batch_tokens).sum();
        (tok / self.gen_tokens).min(1.0)
    }
}
