//! Event-driven driver for the trajectory-level pipelines: Sync+,
//! One-off, AReaL and RollArt (§6, §7.1).
//!
//! One event loop covers all four modes; the [`Mode`] knob selects:
//!
//! | | env interaction | reward | train overlap | staleness |
//! |---|---|---|---|---|
//! | Sync+ | trajectory-level | async serverless | none | — |
//! | One-off | trajectory-level | async | rollout k+1 ∥ train k | 1, at start |
//! | AReaL | continuous | async | continuous | α, at start |
//! | RollArt | continuous | async | continuous | α, per turn |
//!
//! RollArt additionally routes by hardware affinity (R1), runs the
//! suspend → update → resume → recomp protocol at each version bump
//! (§6.2), and launches redundant environments per GRPO group (§6.3).

use super::{Mode, RewardDeploy, Scenario, ScenarioResult, StepStats};
use crate::buffer::SampleBuffer;
use crate::coordinator::{EnvAction, EnvManagerSim, GroupOutcome, GroupTracker};
use crate::env::profile::DomainProfile;
use crate::env::TaskDomain;
use crate::hw::{phase_time, GpuClass};
use crate::metrics::StepBreakdown;
use crate::mooncake::MooncakeStore;
use crate::proxy::{EngineSim, LlmProxy, SimRequest};
use crate::rl::{TrajectoryId, Version};
use crate::serverless::{ServerlessConfig, ServerlessPlatform};
use crate::simkit::{EventQueue, SimRng};

#[derive(Debug)]
enum Ev {
    ResetDone { mgr: usize },
    ResetRetry { mgr: usize },
    EngineFree { engine: usize, completed: Vec<(TrajectoryId, f64)> },
    EnvStepDone { mgr: usize },
    RewardDone { mgr: usize },
    TrainDone,
    SyncDone,
}

struct Driver<'a> {
    cfg: &'a Scenario,
    q: EventQueue<Ev>,
    rng: SimRng,
    mgrs: Vec<EnvManagerSim>,
    proxy: LlmProxy,
    engine_busy: Vec<bool>,
    groups: GroupTracker,
    /// Completed trajectories awaiting their group to fill.
    staged: std::collections::BTreeMap<u64, Vec<crate::rl::Trajectory>>,
    /// Group → task domain (for replacement launches).
    group_domain: std::collections::BTreeMap<u64, crate::env::TaskDomain>,
    buffer: SampleBuffer,
    store: MooncakeStore,
    serverless: ServerlessPlatform,
    reward_gpu_free_at: Vec<f64>,
    version: Version,
    next_group: u64,
    inflight_resets: usize,
    /// Requests blocked by a suspended proxy.
    pending_requests: Vec<SimRequest>,
    // trainer state
    trainer_busy: bool,
    trainer_idle_since: f64,
    inflight_train_tokens: f64,
    pending_batch: Option<(usize, f64)>, // (#trajectories, tokens) awaiting sync
    weights_pushed_at: Option<f64>,      // push start of latest trained weights
    suspend_draining: bool,
    train_steps_done: usize,
    last_train_done: f64,
    // barrier-mode iteration control
    iter_launched: bool,
    // stats accumulators (reset per step)
    acc_stale: u64,
    acc_redundant: u64,
    acc_failures: u64,
    acc_staleness: f64,
    acc_exposed_sync: f64,
    acc_recompute: f64,
    acc_train: f64,
    acc_wait: f64,
    reward_busy_s: f64,
    result: ScenarioResult,
}

/// Per-call reward execution sample.
fn reward_exec(cfg: &Scenario, rng: &mut SimRng) -> f64 {
    match &cfg.reward {
        RewardDeploy::DedicatedGpus { exec_s, .. } => exec_s.sample(rng),
        RewardDeploy::Serverless { exec_s } => exec_s.sample(rng),
    }
}

impl<'a> Driver<'a> {
    fn new(cfg: &'a Scenario) -> Self {
        let mut engines = Vec::new();
        let mut eid = 0;
        for pool in &cfg.gen_pools {
            for _ in 0..pool.engines {
                engines.push(EngineSim::new(
                    eid,
                    pool.class,
                    pool.gpus_per_engine,
                    cfg.model.clone(),
                    pool.max_batch,
                ));
                eid += 1;
            }
        }
        let n_engines = engines.len();
        assert!(n_engines > 0, "scenario needs at least one engine");
        let mut proxy = LlmProxy::new(engines);
        if cfg.affinity_routing {
            // R1: prefill-heavy → compute-optimized, decode-heavy →
            // bandwidth-optimized (domain-level declarations).
            for d in TaskDomain::ALL {
                let class = if DomainProfile::of(d).prefill_heavy {
                    GpuClass::H800
                } else {
                    GpuClass::H20
                };
                proxy.set_affinity(d, class);
            }
        }
        let reward_gpus = match &cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, .. } => *gpus,
            RewardDeploy::Serverless { .. } => 0,
        };
        Driver {
            cfg,
            q: EventQueue::new(),
            rng: SimRng::new(cfg.seed),
            mgrs: Vec::new(),
            proxy,
            engine_busy: vec![false; n_engines],
            groups: GroupTracker::new(),
            staged: std::collections::BTreeMap::new(),
            group_domain: std::collections::BTreeMap::new(),
            buffer: SampleBuffer::new(cfg.alpha, cfg.staleness),
            store: MooncakeStore::default(),
            serverless: ServerlessPlatform::new(ServerlessConfig {
                // tight reclaim: reward bursts are short-lived (Fig 12)
                idle_timeout_s: 15.0,
                ..ServerlessConfig::default()
            }),
            reward_gpu_free_at: vec![0.0; reward_gpus],
            version: Version(0),
            next_group: 0,
            inflight_resets: 0,
            pending_requests: Vec::new(),
            trainer_busy: false,
            trainer_idle_since: 0.0,
            inflight_train_tokens: 0.0,
            pending_batch: None,
            weights_pushed_at: None,
            suspend_draining: false,
            train_steps_done: 0,
            last_train_done: 0.0,
            iter_launched: false,
            acc_stale: 0,
            acc_redundant: 0,
            acc_failures: 0,
            acc_staleness: 0.0,
            acc_exposed_sync: 0.0,
            acc_recompute: 0.0,
            acc_train: 0.0,
            acc_wait: 0.0,
            reward_busy_s: 0.0,
            result: ScenarioResult::default(),
        }
    }

    fn now(&self) -> f64 {
        self.q.now().as_secs()
    }

    fn continuous(&self) -> bool {
        // One-off pipelines rollout continuously too (Fig 2-Right: the
        // next iteration's rollout overlaps training); only Sync+ stops
        // the world between iterations.
        matches!(self.cfg.mode, Mode::OneOff | Mode::AReaL | Mode::RollArt)
    }

    /// Active (non-terminal) trajectory count.
    fn active(&self) -> usize {
        self.mgrs.iter().filter(|m| !m.is_terminal()).count()
    }

    /// Launch one GRPO group (G + redundancy members).
    fn launch_group(&mut self) {
        let g = self.next_group;
        self.next_group += 1;
        let members = self.cfg.group_size
            + if self.cfg.mode == Mode::RollArt {
                self.cfg.redundancy
            } else {
                0
            };
        self.groups.add_group(g, self.cfg.group_size);
        let domain = *self.rng.choose(&self.cfg.task_mix);
        self.group_domain.insert(g, domain);
        let profile = DomainProfile::of(domain);
        for _ in 0..members {
            let idx = self.mgrs.len();
            let id = TrajectoryId(idx as u64);
            let shape = profile.sample_trajectory(&mut self.rng);
            let m = EnvManagerSim::new(id, shape, self.version, g, self.now());
            self.mgrs.push(m);
            self.groups.launch(g, id);
            self.schedule_reset(idx);
        }
    }

    fn schedule_reset(&mut self, mgr: usize) {
        let mut r = self.rng.stream("reset", mgr as u64);
        let o = self
            .cfg
            .envpool
            .sample_reset(self.inflight_resets, &mut r);
        self.inflight_resets += 1;
        if o.failed {
            self.acc_failures += 1;
            self.q
                .schedule_in(o.latency_s, Ev::ResetRetry { mgr });
        } else {
            self.q.schedule_in(o.latency_s, Ev::ResetDone { mgr });
        }
    }

    /// Keep the continuous modes at target concurrency.
    fn refill(&mut self) {
        if !self.continuous() {
            return;
        }
        let target = self.cfg.concurrent_envs.unwrap_or(self.cfg.batch_size);
        while self.active() < target {
            self.launch_group();
        }
    }

    /// Barrier modes: launch one iteration's worth of groups.
    fn launch_iteration(&mut self) {
        let n_groups = (self.cfg.batch_size / self.cfg.group_size).max(1);
        for _ in 0..n_groups {
            self.launch_group();
        }
        self.iter_launched = true;
    }

    fn dispatch(&mut self, req: SimRequest) {
        if self.proxy.is_suspended() {
            self.pending_requests.push(req);
            return;
        }
        if let Some(e) = self.proxy.add(req) {
            self.kick_engine(e);
        }
    }

    fn kick_engine(&mut self, e: usize) {
        if self.engine_busy[e] || self.proxy.is_suspended() {
            return;
        }
        let outcome = self.proxy.engines_mut()[e].step();
        if let crate::proxy::StepOutcome::Busy {
            elapsed, completed, ..
        } = outcome
        {
            self.engine_busy[e] = true;
            self.q
                .schedule_in(elapsed, Ev::EngineFree { engine: e, completed });
        }
    }

    fn kick_all_engines(&mut self) {
        for e in 0..self.engine_busy.len() {
            self.kick_engine(e);
        }
    }

    fn env_step_latency(&mut self, mgr: usize) -> f64 {
        let domain = self.mgrs[mgr].domain();
        let turn = self.mgrs[mgr].turns_done();
        let mut r = self
            .rng
            .stream("envstep", (mgr * 1000 + turn) as u64);
        match &self.cfg.env_step_override {
            Some(d) => d.sample(&mut r),
            None => self.cfg.envpool.sample_step(domain, &mut r),
        }
    }

    fn handle_action(&mut self, mgr: usize, action: EnvAction) {
        match action {
            EnvAction::Generate(req) => {
                // RollArt's per-iteration staleness enforcement (§6.2
                // fn.1): abort mid-flight trajectories whose start
                // version left the α window, instead of letting them
                // generate a stale tail that get_batch would evict
                // anyway (AReaL's behaviour).
                if self.cfg.mode == Mode::RollArt
                    && !self.mgrs[mgr]
                        .traj
                        .fresh_at_start(self.version, self.cfg.alpha)
                {
                    self.abort_mgr(mgr, true);
                    return;
                }
                self.dispatch(req);
            }
            EnvAction::StepEnv => {
                let lat = self.env_step_latency(mgr);
                self.q.schedule_in(lat, Ev::EnvStepDone { mgr });
            }
            EnvAction::Complete => {
                self.dispatch_reward(mgr);
            }
        }
    }

    fn abort_mgr(&mut self, mgr: usize, stale: bool) {
        let id = self.mgrs[mgr].id;
        let group = self.mgrs[mgr].traj.group;
        self.mgrs[mgr].abort();
        self.proxy.abort(id);
        self.groups.fail(id);
        if stale {
            self.acc_stale += 1;
        } else {
            self.acc_redundant += 1;
        }
        // A stale/failed member leaves its group short: relaunch a
        // replacement at the *current* version so the group can still
        // fill (the paper re-rolls aborted trajectories).
        if stale && !self.groups.is_filled(group) {
            self.launch_member(group);
        }
        self.refill();
    }

    /// Launch one replacement member into an existing group.
    fn launch_member(&mut self, group: u64) {
        let domain = self.group_domain[&group];
        let profile = DomainProfile::of(domain);
        let idx = self.mgrs.len();
        let id = TrajectoryId(idx as u64);
        let shape = profile.sample_trajectory(&mut self.rng);
        let m = EnvManagerSim::new(id, shape, self.version, group, self.now());
        self.mgrs.push(m);
        self.groups.launch(group, id);
        self.schedule_reset(idx);
    }

    fn dispatch_reward(&mut self, mgr: usize) {
        let mut r = self.rng.stream("rexec", mgr as u64);
        let exec = reward_exec(self.cfg, &mut r);
        match &self.cfg.reward {
            RewardDeploy::Serverless { .. } => {
                let inv = self.serverless.invoke(self.now(), exec, &mut r);
                let delay = (inv.done_s - self.now()).max(0.0);
                self.q.schedule_in(delay, Ev::RewardDone { mgr });
            }
            RewardDeploy::DedicatedGpus { .. } => {
                // FIFO over the dedicated reward servers.
                let now = self.now();
                let slot = self
                    .reward_gpu_free_at
                    .iter_mut()
                    .min_by(|a, b| a.partial_cmp(b).unwrap())
                    .expect("dedicated reward needs ≥1 GPU");
                let start = slot.max(now);
                *slot = start + exec;
                self.reward_busy_s += exec;
                let done = *slot;
                self.q.schedule_in(done - now, Ev::RewardDone { mgr });
            }
        }
    }

    /// Reward scored: group accounting + buffer deposit.
    ///
    /// GRPO needs *complete groups* (the group mean/std is the
    /// advantage baseline), so trajectories are staged until their
    /// group fills and only then deposited — this is exactly why
    /// redundant environment rollouts pay off (§6.3): one straggler
    /// otherwise gates its whole group's availability.
    fn on_reward_done(&mut self, mgr: usize) {
        if self.mgrs[mgr].is_terminal() && self.mgrs[mgr].phase == crate::coordinator::EnvPhase::Aborted
        {
            return;
        }
        let id = self.mgrs[mgr].id;
        let group = self.mgrs[mgr].traj.group;
        self.mgrs[mgr].traj.reward = Some(1.0);
        match self.groups.complete(id) {
            GroupOutcome::Surplus => {}
            GroupOutcome::Pending => {
                let traj = self.mgrs[mgr].traj.clone();
                self.staged.entry(group).or_default().push(traj);
            }
            GroupOutcome::Filled { abort } => {
                let traj = self.mgrs[mgr].traj.clone();
                let mut members = self.staged.remove(&group).unwrap_or_default();
                members.push(traj);
                for t in members {
                    self.buffer.deposit(t, self.version);
                }
                for t in abort {
                    let i = t.0 as usize;
                    if !self.mgrs[i].is_terminal() {
                        self.abort_mgr(i, false);
                    }
                }
            }
        }
        self.refill();
        self.try_iteration_boundary();
    }

    /// The scheduling heart: can a train step (and the weight-sync
    /// protocol) start now?
    fn try_iteration_boundary(&mut self) {
        if self.trainer_busy || self.suspend_draining || self.pending_batch.is_some() {
            return;
        }
        let Some(batch) = self.buffer.get_batch(self.cfg.batch_size, self.version) else {
            // Barrier modes relaunch the next iteration only once the
            // batch is consumed; nothing to do here.
            return;
        };
        let tokens: f64 = batch.iter().map(|t| t.total_tokens() as f64).sum();
        let n = batch.len();
        self.acc_staleness = batch
            .iter()
            .map(|t| (self.version.0 - t.min_version().0) as f64)
            .sum::<f64>()
            / n.max(1) as f64;
        self.acc_wait += self.now() - self.trainer_idle_since;

        // Weight sync before this train step (protocol ②–⑤) when the
        // engines run older weights than the trainer produced.
        if self.weights_pushed_at.is_some() {
            self.pending_batch = Some((n, tokens));
            self.begin_suspend();
        } else {
            self.start_train(tokens);
        }
        // One-off / Sync+ barrier: next iteration launches are handled
        // at train start / sync completion respectively.
    }

    fn begin_suspend(&mut self) {
        self.proxy.suspend();
        self.suspend_draining = true;
        if self.engine_busy.iter().all(|b| !b) {
            self.finish_drain();
        }
        // else: the in-flight EngineFree events trigger finish_drain.
    }

    fn finish_drain(&mut self) {
        if !self.suspend_draining || self.engine_busy.iter().any(|b| *b) {
            return;
        }
        // Exposed update (③) + KV recompute (⑤).
        let push_start = self.weights_pushed_at.take().unwrap_or(self.now());
        let overlap = self.now() - push_start;
        let bytes = self.cfg.model.weight_bytes();
        let exposed = if self.cfg.async_weight_sync {
            self.store.sync(bytes, overlap).exposed_s
        } else {
            // Blocking veRL-style cross-cluster transfer (Fig 14a).
            self.store.sync(bytes, 0.0).naive_s
        };
        let recompute = self.proxy.recompute_cost_s();
        self.acc_exposed_sync += exposed;
        self.acc_recompute += recompute;
        self.q.schedule_in(exposed + recompute, Ev::SyncDone);
    }

    fn on_sync_done(&mut self) {
        self.suspend_draining = false;
        self.version = self.version.next();
        self.proxy.resume();
        let pending: Vec<SimRequest> = std::mem::take(&mut self.pending_requests);
        for req in pending {
            self.dispatch(req);
        }
        self.kick_all_engines();
        if let Some((_, tokens)) = self.pending_batch.take() {
            self.start_train(tokens);
        }
    }

    fn start_train(&mut self, tokens: f64) {
        let cost = self.cfg.model.train_cost(tokens, 8000.0);
        let t = phase_time(&cost, GpuClass::H800.spec(), self.cfg.train_gpus.max(1))
            * super::TRAIN_OVERHEAD;
        self.acc_train += t;
        self.trainer_busy = true;
        self.inflight_train_tokens = tokens;
        self.q.schedule_in(t, Ev::TrainDone);
    }

    fn maybe_launch_barrier_iteration(&mut self) {
        if self.continuous() || self.iter_launched {
            return;
        }
        self.launch_iteration();
    }

    fn on_train_done(&mut self, tokens_trained: f64) {
        self.trainer_busy = false;
        self.trainer_idle_since = self.now();
        self.train_steps_done += 1;
        // Publish new weights to the store (push overlaps rollout).
        self.weights_pushed_at = Some(self.now());

        // Record the completed step.
        let step_time = self.now() - self.last_train_done;
        self.last_train_done = self.now();
        let breakdown = StepBreakdown {
            generation_s: 0.0, // filled from engine stats at the end
            env_reset_s: 0.0,
            env_step_s: 0.0,
            reward_s: 0.0,
            train_s: std::mem::take(&mut self.acc_train),
            weight_sync_s: std::mem::take(&mut self.acc_exposed_sync)
                + std::mem::take(&mut self.acc_recompute),
            get_batch_wait_s: std::mem::take(&mut self.acc_wait),
            other_s: 0.0,
        };
        self.result.steps.push(StepStats {
            step_time_s: step_time,
            breakdown,
            batch_tokens: tokens_trained,
            mean_staleness: std::mem::take(&mut self.acc_staleness),
            stale_aborts: std::mem::take(&mut self.acc_stale),
            redundant_aborts: std::mem::take(&mut self.acc_redundant),
            env_failures: std::mem::take(&mut self.acc_failures),
        });

        // Sync+ barrier: next iteration only after train completes.
        if self.cfg.mode == Mode::SyncPlus {
            self.iter_launched = false;
            // Pay the weight sync *now*, blocking (synchronous training):
            self.begin_suspend();
            // next iteration launches on SyncDone via pending flag below
        }
        self.try_iteration_boundary();
    }

    fn run(mut self) -> ScenarioResult {
        self.trainer_idle_since = 0.0;
        if self.continuous() {
            self.refill();
        } else {
            self.launch_iteration();
        }

        let target_steps = self.cfg.iterations;
        while let Some((_, ev)) = self.q.pop() {
            match ev {
                Ev::ResetRetry { mgr } => {
                    self.inflight_resets = self.inflight_resets.saturating_sub(1);
                    if !self.mgrs[mgr].is_terminal() {
                        self.schedule_reset(mgr);
                    }
                }
                Ev::ResetDone { mgr } => {
                    self.inflight_resets = self.inflight_resets.saturating_sub(1);
                    if !self.mgrs[mgr].is_terminal() {
                        let v = self.version;
                        let action = self.mgrs[mgr].on_reset_done(v);
                        self.handle_action(mgr, action);
                    }
                }
                Ev::EngineFree { engine, completed } => {
                    self.engine_busy[engine] = false;
                    for (tid, _ctx) in completed {
                        let mgr = tid.0 as usize;
                        if self.mgrs[mgr].is_terminal() {
                            continue;
                        }
                        if self.mgrs[mgr].phase == crate::coordinator::EnvPhase::Generating {
                            let v = self.version;
                            let action = self.mgrs[mgr].on_generation_done(v);
                            self.handle_action(mgr, action);
                        }
                    }
                    if self.suspend_draining {
                        self.finish_drain();
                    } else {
                        self.kick_engine(engine);
                    }
                }
                Ev::EnvStepDone { mgr } => {
                    if !self.mgrs[mgr].is_terminal() {
                        let v = self.version;
                        let now = self.now();
                        let action = self.mgrs[mgr].on_env_step_done(v, now);
                        self.handle_action(mgr, action);
                    }
                }
                Ev::RewardDone { mgr } => {
                    self.on_reward_done(mgr);
                }
                Ev::TrainDone => {
                    let tokens = self.inflight_train_tokens;
                    self.on_train_done(tokens);
                    if self.train_steps_done >= target_steps {
                        break;
                    }
                }
                Ev::SyncDone => {
                    self.on_sync_done();
                    if self.cfg.mode == Mode::SyncPlus {
                        self.maybe_launch_barrier_iteration();
                    }
                }
            }
        }

        // Final stats.
        let total = self.now().max(1e-9);
        self.result.total_time_s = total;
        let n_engines = self.engine_busy.len() as f64;
        let busy: f64 = self
            .proxy
            .engines()
            .iter()
            .map(|e| e.stats.busy_s)
            .sum();
        self.result.gen_util = (busy / (total * n_engines)).min(1.0);
        self.result.reward_util = match &self.cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, .. } => {
                self.reward_busy_s / (total * (*gpus).max(1) as f64)
            }
            RewardDeploy::Serverless { .. } => self.serverless.utilization(total),
        };
        // Spread generation time into per-step breakdowns (engines are
        // shared across steps; attribute uniformly).
        let steps = self.result.steps.len().max(1) as f64;
        for s in &mut self.result.steps {
            s.breakdown.generation_s = busy / steps;
        }
        self.result
    }
}

/// Run a trajectory-level scenario.
pub fn run(cfg: &Scenario) -> ScenarioResult {
    assert_ne!(cfg.mode, Mode::Sync, "use sync_driver for Mode::Sync");
    Driver::new(cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn scenario(mode: Mode) -> Scenario {
        let mut s = Scenario::rollart_default(QWEN3_8B.clone(), 0.06);
        s.mode = mode;
        s.batch_size = 16;
        s.group_size = 4;
        s.iterations = 3;
        s
    }

    #[test]
    fn rollart_runs_to_completion() {
        let r = run(&scenario(Mode::RollArt));
        assert_eq!(r.steps.len(), 3);
        for s in &r.steps {
            assert!(s.step_time_s > 0.0);
            assert!(s.batch_tokens > 0.0, "{s:?}");
        }
        assert!(r.gen_util > 0.0 && r.gen_util <= 1.0);
    }

    #[test]
    fn all_async_modes_run() {
        for mode in [Mode::SyncPlus, Mode::OneOff, Mode::AReaL, Mode::RollArt] {
            let r = run(&scenario(mode));
            assert_eq!(r.steps.len(), 3, "{mode:?}");
        }
    }

    #[test]
    fn deterministic() {
        let a = run(&scenario(Mode::RollArt));
        let b = run(&scenario(Mode::RollArt));
        assert_eq!(a.mean_step_time(), b.mean_step_time());
    }

    #[test]
    fn continuous_overlap_beats_stop_and_go() {
        // At unit-test scale the engine pools are too small for
        // affinity routing to be meaningful (the benches exercise R1
        // at proper scale); this asserts the R4 machinery: continuous
        // bounded-staleness overlap beats the Sync+ barrier.
        let sp = run(&scenario(Mode::SyncPlus));
        let mut cfg = scenario(Mode::RollArt);
        cfg.affinity_routing = false;
        let ra = run(&cfg);
        assert!(
            ra.mean_step_time() < sp.mean_step_time(),
            "RollArt {} vs Sync+ {}",
            ra.mean_step_time(),
            sp.mean_step_time()
        );
    }
}
