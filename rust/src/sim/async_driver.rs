//! Compatibility shim over the decomposed scheduler plane.
//!
//! The event-driven driver for the trajectory-level pipelines (Sync+,
//! One-off, AReaL, RollArt — §6, §7.1) used to live here as one
//! monolithic `run()`.  It now lives in [`crate::sim::driver`]:
//!
//! * [`crate::sim::driver::core`] — the mode-agnostic event loop;
//! * [`crate::sim::driver::policy`] — per-[`Mode`](super::Mode)
//!   scheduling policies (what the `cfg.mode == ...` conditionals used
//!   to encode);
//! * [`crate::sim::driver::lifecycle`] — the trajectory state machine;
//! * [`crate::sim::driver::pd`] — PD disaggregation as a simulated
//!   execution mode.
//!
//! Every pre-refactor entry point and behaviour is preserved; this
//! module simply re-exports [`run`] so existing callers (benches,
//! examples, tests) keep working.  The original driver test suite stays
//! here, pinned against the new core.

pub use super::driver::run;

#[cfg(test)]
mod tests {
    use super::run;
    use crate::llm::QWEN3_8B;
    use crate::sim::{Mode, Scenario};

    fn scenario(mode: Mode) -> Scenario {
        let mut s = Scenario::rollart_default(QWEN3_8B.clone(), 0.06);
        s.mode = mode;
        s.batch_size = 16;
        s.group_size = 4;
        s.iterations = 3;
        s
    }

    #[test]
    fn rollart_runs_to_completion() {
        let r = run(&scenario(Mode::RollArt));
        assert_eq!(r.steps.len(), 3);
        for s in &r.steps {
            assert!(s.step_time_s > 0.0);
            assert!(s.batch_tokens > 0.0, "{s:?}");
        }
        assert!(r.gen_util > 0.0 && r.gen_util <= 1.0);
    }

    #[test]
    fn all_async_modes_run() {
        for mode in [Mode::SyncPlus, Mode::OneOff, Mode::AReaL, Mode::RollArt] {
            let r = run(&scenario(mode));
            assert_eq!(r.steps.len(), 3, "{mode:?}");
        }
    }

    #[test]
    fn deterministic() {
        let a = run(&scenario(Mode::RollArt));
        let b = run(&scenario(Mode::RollArt));
        assert_eq!(a.mean_step_time(), b.mean_step_time());
    }

    #[test]
    fn engine_mtbf_faults_recover_trajectories() {
        use crate::fault::FaultProfile;
        let clean = run(&scenario(Mode::RollArt));
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            engine_recovery_s: 60.0,
            ..FaultProfile::mtbf(400.0)
        };
        let r = run(&cfg);
        // Crashes happened, every iteration still completed, and the
        // re-queue machinery recovered the in-flight work.
        assert_eq!(r.steps.len(), 3, "no iteration may be lost to crashes");
        assert!(r.faults.engine_failures > 0, "{:?}", r.faults);
        assert!(r.faults.recoveries > 0);
        assert!(r.faults.mean_recovery_latency_s() >= 60.0 - 1e-9);
        // Faults burn wall-clock: the run cannot get meaningfully
        // faster (small tolerance for event-reordering noise).
        assert!(
            r.total_time_s >= 0.9 * clean.total_time_s,
            "faults cannot speed the run up: {} vs {}",
            r.total_time_s,
            clean.total_time_s
        );
    }

    #[test]
    fn env_crashes_backfill_their_groups() {
        use crate::fault::FaultProfile;
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            env_crash_p: 0.05,
            ..FaultProfile::none()
        };
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 3);
        assert!(r.faults.env_crashes > 0, "{:?}", r.faults);
        assert!(r.faults.trajectories_relaunched > 0);
    }

    #[test]
    fn fault_runs_are_deterministic() {
        use crate::fault::FaultProfile;
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            env_crash_p: 0.02,
            ..FaultProfile::mtbf(500.0)
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.mean_step_time(), b.mean_step_time());
        assert_eq!(a.faults, b.faults);
    }

    #[test]
    fn scheduled_pool_outage_and_restore_ride_through() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        use crate::hw::GpuClass;
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            scheduled: vec![
                ScheduledFault {
                    at_s: 50.0,
                    event: FaultEvent::PoolOutage {
                        class: GpuClass::H800,
                        fraction: 0.5,
                    },
                },
                ScheduledFault {
                    at_s: 1500.0,
                    event: FaultEvent::PoolRestore {
                        class: GpuClass::H800,
                    },
                },
            ],
            ..FaultProfile::none()
        };
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 3);
        assert!(r.faults.engine_failures > 0);
    }

    #[test]
    fn elastic_controller_grows_a_starved_pool() {
        use crate::elastic::ElasticPolicy;
        use crate::hw::GpuClass;
        let mut cfg = scenario(Mode::RollArt);
        cfg.iterations = 4;
        let mut policy = ElasticPolicy::new(GpuClass::H800, cfg.model.rollout_tp, 32);
        // Hair-trigger scale-up so the tiny test scenario provisions
        // deterministically (any positive get_batch wait counts as
        // rollout-bound).
        policy.scale_up_wait_ratio = 1e-9;
        policy.scale_down_wait_ratio = 1e-12;
        policy.max_engines = 16;
        policy.cooldown_steps = 0;
        cfg.elastic = Some(policy);
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 4);
        assert!(r.elastic.scale_ups > 0, "{:?}", r.elastic);
        assert!(r.elastic.engines_added > 0, "{:?}", r.elastic);
        assert!(r.elastic.provision_wait_s > 0.0);
    }

    #[test]
    fn goodput_and_efficiency_are_sane() {
        let r = run(&scenario(Mode::RollArt));
        assert!(r.goodput() > 0.0);
        let eff = r.token_efficiency();
        assert!(eff > 0.0 && eff <= 1.0, "{eff}");
    }

    #[test]
    fn continuous_overlap_beats_stop_and_go() {
        // At unit-test scale the engine pools are too small for
        // affinity routing to be meaningful (the benches exercise R1
        // at proper scale); this asserts the R4 machinery: continuous
        // bounded-staleness overlap beats the Sync+ barrier.
        let sp = run(&scenario(Mode::SyncPlus));
        let mut cfg = scenario(Mode::RollArt);
        cfg.affinity_routing = false;
        let ra = run(&cfg);
        assert!(
            ra.mean_step_time() < sp.mean_step_time(),
            "RollArt {} vs Sync+ {}",
            ra.mean_step_time(),
            sp.mean_step_time()
        );
    }
}
