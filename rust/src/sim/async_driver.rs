//! Event-driven driver for the trajectory-level pipelines: Sync+,
//! One-off, AReaL and RollArt (§6, §7.1).
//!
//! One event loop covers all four modes; the [`Mode`] knob selects:
//!
//! | | env interaction | reward | train overlap | staleness |
//! |---|---|---|---|---|
//! | Sync+ | trajectory-level | async serverless | none | — |
//! | One-off | trajectory-level | async | rollout k+1 ∥ train k | 1, at start |
//! | AReaL | continuous | async | continuous | α, at start |
//! | RollArt | continuous | async | continuous | α, per turn |
//!
//! RollArt additionally routes by hardware affinity (R1), runs the
//! suspend → update → resume → recomp protocol at each version bump
//! (§6.2), and launches redundant environments per GRPO group (§6.3).
//!
//! The fault & elasticity plane threads through the same loop: a
//! [`FaultProfile`](crate::fault::FaultProfile) injects engine
//! crashes / env-worker deaths / serverless stragglers, the
//! coordinator recovers at *trajectory* granularity (in-flight
//! requests on a dead engine are drained and re-queued through the
//! proxy; crashed env workers are backfilled into their GRPO group via
//! the §6.3 redundancy machinery), and an optional
//! [`ElasticPolicy`](crate::elastic::ElasticPolicy) controller resizes
//! the generation pool through the [`crate::resource`] plane based on
//! the measured `get_batch`-wait vs. train-time balance.

use super::{Mode, RewardDeploy, Scenario, ScenarioResult, StepStats};
use crate::buffer::SampleBuffer;
use crate::coordinator::{EnvAction, EnvManagerSim, GroupOutcome, GroupTracker, IterationCost};
use crate::elastic::{AutoScaler, ScaleDecision};
use crate::env::profile::DomainProfile;
use crate::env::TaskDomain;
use crate::envpool::ResetSampler;
use crate::fault::{FaultEvent, FaultReport};
use crate::hw::{phase_time, GpuClass};
use crate::metrics::StepBreakdown;
use crate::mooncake::MooncakeStore;
use crate::proxy::{EngineSim, LlmProxy, SimRequest};
use crate::resource::{ResourceClass, ResourceManager, Role};
use crate::rl::{TrajectoryId, Version};
use crate::serverless::{ServerlessConfig, ServerlessPlatform};
use crate::simkit::{EventQueue, SimRng, SimTime};

/// Safety horizon: a mis-configured chaos scenario (e.g. a permanent
/// whole-fleet outage with no elastic replacement) must terminate, not
/// spin on fault events forever.  Only checked when faults are active.
const MAX_SIM_S: f64 = 60.0 * 86400.0;

#[derive(Debug)]
enum Ev {
    ResetDone { mgr: usize },
    ResetRetry { mgr: usize },
    EngineFree { engine: usize, epoch: u64, completed: Vec<(TrajectoryId, f64)> },
    EnvStepDone { mgr: usize },
    /// The env worker of `mgr` died mid-trajectory (fault plane).
    EnvCrashed { mgr: usize },
    RewardDone { mgr: usize },
    TrainDone,
    SyncDone,
    /// Stochastic engine failure (MTBF process).
    EngineCrashed { engine: usize },
    /// A crashed engine finished recovering.
    EngineRecovered { engine: usize },
    /// Deterministic chaos event `cfg.fault.scheduled[idx]` fires.
    Scheduled { idx: usize },
    /// An elastic scale-up finished warming: the engine joins the
    /// fleet holding `binding` in the resource plane.
    EngineProvisioned { binding: Option<u64> },
}

struct Driver<'a> {
    cfg: &'a Scenario,
    q: EventQueue<Ev>,
    rng: SimRng,
    mgrs: Vec<EnvManagerSim>,
    proxy: LlmProxy,
    engine_busy: Vec<bool>,
    // ---- fault & elasticity plane -------------------------------
    /// Any fault mechanism enabled this run?
    fault_on: bool,
    fault_report: FaultReport,
    reset_sampler: ResetSampler,
    engine_down: Vec<bool>,
    /// Retired by the elastic controller: stays down forever.
    engine_retired: Vec<bool>,
    /// Bumped on every crash/retire so stale `EngineFree` events (work
    /// that "completed" on a dead engine) are discarded.
    engine_epoch: Vec<u64>,
    /// Per-engine count of MTBF failures drawn so far (stream index).
    engine_fail_nth: Vec<u64>,
    /// Crash time of currently-down engines (recovery-latency metric).
    down_since: std::collections::BTreeMap<usize, f64>,
    /// Alive-time accounting for utilization under churn.
    engine_up_since: Vec<Option<f64>>,
    engine_alive_s: Vec<f64>,
    scaler: Option<AutoScaler>,
    /// Resource-plane view backing the elastic controller's bindings.
    rm: Option<ResourceManager>,
    engine_bindings: Vec<Option<u64>>,
    pending_provisions: usize,
    /// Environment-pool size target (elastic: scales with the live
    /// generation fleet).
    env_target: usize,
    initial_engines: usize,
    acc_engine_failures: u64,
    acc_requeued: u64,
    // -------------------------------------------------------------
    groups: GroupTracker,
    /// Completed trajectories awaiting their group to fill.
    staged: std::collections::BTreeMap<u64, Vec<crate::rl::Trajectory>>,
    /// Group → task domain (for replacement launches).
    group_domain: std::collections::BTreeMap<u64, crate::env::TaskDomain>,
    buffer: SampleBuffer,
    store: MooncakeStore,
    serverless: ServerlessPlatform,
    reward_gpu_free_at: Vec<f64>,
    version: Version,
    next_group: u64,
    inflight_resets: usize,
    /// Requests blocked by a suspended proxy.
    pending_requests: Vec<SimRequest>,
    // trainer state
    trainer_busy: bool,
    trainer_idle_since: f64,
    inflight_train_tokens: f64,
    pending_batch: Option<(usize, f64)>, // (#trajectories, tokens) awaiting sync
    weights_pushed_at: Option<f64>,      // push start of latest trained weights
    suspend_draining: bool,
    train_steps_done: usize,
    last_train_done: f64,
    // barrier-mode iteration control
    iter_launched: bool,
    // stats accumulators (reset per step)
    acc_stale: u64,
    acc_redundant: u64,
    acc_failures: u64,
    acc_staleness: f64,
    acc_exposed_sync: f64,
    acc_recompute: f64,
    acc_train: f64,
    acc_wait: f64,
    reward_busy_s: f64,
    result: ScenarioResult,
}

/// Per-call reward execution sample.
fn reward_exec(cfg: &Scenario, rng: &mut SimRng) -> f64 {
    match &cfg.reward {
        RewardDeploy::DedicatedGpus { exec_s, .. } => exec_s.sample(rng),
        RewardDeploy::Serverless { exec_s } => exec_s.sample(rng),
    }
}

impl<'a> Driver<'a> {
    fn new(cfg: &'a Scenario) -> Self {
        let mut engines = Vec::new();
        let mut eid = 0;
        for pool in &cfg.gen_pools {
            for _ in 0..pool.engines {
                engines.push(EngineSim::new(
                    eid,
                    pool.class,
                    pool.gpus_per_engine,
                    cfg.model.clone(),
                    pool.max_batch,
                ));
                eid += 1;
            }
        }
        let n_engines = engines.len();
        assert!(n_engines > 0, "scenario needs at least one engine");
        let mut proxy = LlmProxy::new(engines);
        if cfg.affinity_routing {
            // R1: prefill-heavy → compute-optimized, decode-heavy →
            // bandwidth-optimized (domain-level declarations).
            for d in TaskDomain::ALL {
                let class = if DomainProfile::of(d).prefill_heavy {
                    GpuClass::H800
                } else {
                    GpuClass::H20
                };
                proxy.set_affinity(d, class);
            }
        }
        let reward_gpus = match &cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, .. } => *gpus,
            RewardDeploy::Serverless { .. } => 0,
        };
        // Elastic runs bind every engine through the resource plane so
        // scale decisions contend for real capacity; the elastic class
        // gets headroom up to the policy's max fleet size.
        let (rm, engine_bindings, scaler) = match &cfg.elastic {
            None => (None, vec![None; n_engines], None),
            Some(policy) => {
                let mut rm = ResourceManager::new();
                for p in &cfg.gen_pools {
                    rm.add_pool(ResourceClass::Gpu(p.class), p.engines * p.gpus_per_engine);
                }
                let have = proxy
                    .engines()
                    .iter()
                    .filter(|e| e.class == policy.class)
                    .count();
                if policy.max_engines > have {
                    rm.add_pool(
                        ResourceClass::Gpu(policy.class),
                        (policy.max_engines - have) * policy.gpus_per_engine,
                    );
                }
                let bindings: Vec<Option<u64>> = proxy
                    .engines()
                    .iter()
                    .map(|e| {
                        rm.bind(Role::ActorGen, &[ResourceClass::Gpu(e.class)], e.gpus)
                            .ok()
                            .map(|b| b.id)
                    })
                    .collect();
                (Some(rm), bindings, Some(AutoScaler::new(policy.clone())))
            }
        };
        let env_target = cfg.concurrent_envs.unwrap_or(cfg.batch_size);
        Driver {
            cfg,
            q: EventQueue::new(),
            rng: SimRng::new(cfg.seed),
            mgrs: Vec::new(),
            proxy,
            engine_busy: vec![false; n_engines],
            fault_on: cfg.fault.is_active(),
            fault_report: FaultReport::default(),
            reset_sampler: ResetSampler::new(&cfg.envpool),
            engine_down: vec![false; n_engines],
            engine_retired: vec![false; n_engines],
            engine_epoch: vec![0; n_engines],
            engine_fail_nth: vec![0; n_engines],
            down_since: std::collections::BTreeMap::new(),
            engine_up_since: vec![Some(0.0); n_engines],
            engine_alive_s: vec![0.0; n_engines],
            scaler,
            rm,
            engine_bindings,
            pending_provisions: 0,
            env_target,
            initial_engines: n_engines,
            acc_engine_failures: 0,
            acc_requeued: 0,
            groups: GroupTracker::new(),
            staged: std::collections::BTreeMap::new(),
            group_domain: std::collections::BTreeMap::new(),
            buffer: {
                // RollArt keeps GRPO groups whole: a stale member
                // evicts its entire group (partial groups would
                // corrupt the advantage baseline).  The AReaL/One-off
                // baselines keep their per-trajectory semantics.
                let mut b = SampleBuffer::new(cfg.alpha, cfg.staleness);
                b.set_group_aware(cfg.mode == Mode::RollArt);
                b
            },
            store: MooncakeStore::default(),
            serverless: ServerlessPlatform::new(ServerlessConfig {
                // tight reclaim: reward bursts are short-lived (Fig 12)
                idle_timeout_s: 15.0,
                ..ServerlessConfig::default()
            }),
            reward_gpu_free_at: vec![0.0; reward_gpus],
            version: Version(0),
            next_group: 0,
            inflight_resets: 0,
            pending_requests: Vec::new(),
            trainer_busy: false,
            trainer_idle_since: 0.0,
            inflight_train_tokens: 0.0,
            pending_batch: None,
            weights_pushed_at: None,
            suspend_draining: false,
            train_steps_done: 0,
            last_train_done: 0.0,
            iter_launched: false,
            acc_stale: 0,
            acc_redundant: 0,
            acc_failures: 0,
            acc_staleness: 0.0,
            acc_exposed_sync: 0.0,
            acc_recompute: 0.0,
            acc_train: 0.0,
            acc_wait: 0.0,
            reward_busy_s: 0.0,
            result: ScenarioResult::default(),
        }
    }

    fn now(&self) -> f64 {
        self.q.now().as_secs()
    }

    fn continuous(&self) -> bool {
        // One-off pipelines rollout continuously too (Fig 2-Right: the
        // next iteration's rollout overlaps training); only Sync+ stops
        // the world between iterations.
        matches!(self.cfg.mode, Mode::OneOff | Mode::AReaL | Mode::RollArt)
    }

    /// Active (non-terminal) trajectory count.
    fn active(&self) -> usize {
        self.mgrs.iter().filter(|m| !m.is_terminal()).count()
    }

    /// Launch one GRPO group (G + redundancy members).
    fn launch_group(&mut self) {
        let g = self.next_group;
        self.next_group += 1;
        let members = self.cfg.group_size
            + if self.cfg.mode == Mode::RollArt {
                self.cfg.redundancy
            } else {
                0
            };
        self.groups.add_group(g, self.cfg.group_size);
        let domain = *self.rng.choose(&self.cfg.task_mix);
        self.group_domain.insert(g, domain);
        let profile = DomainProfile::of(domain);
        for _ in 0..members {
            let idx = self.mgrs.len();
            let id = TrajectoryId(idx as u64);
            let shape = profile.sample_trajectory(&mut self.rng);
            let m = EnvManagerSim::new(id, shape, self.version, g, self.now());
            self.mgrs.push(m);
            self.groups.launch(g, id);
            self.schedule_reset(idx);
        }
    }

    fn schedule_reset(&mut self, mgr: usize) {
        let mut r = self.rng.stream("reset", mgr as u64);
        let o = self.reset_sampler.sample(self.inflight_resets, &mut r);
        self.inflight_resets += 1;
        if o.failed {
            self.acc_failures += 1;
            self.q
                .schedule_in(o.latency_s, Ev::ResetRetry { mgr });
        } else {
            self.q.schedule_in(o.latency_s, Ev::ResetDone { mgr });
        }
    }

    /// Keep the continuous modes at target concurrency.  The target is
    /// elastic: it tracks the live generation fleet so a grown pool is
    /// fed and a shrunken one is not drowned.
    fn refill(&mut self) {
        if !self.continuous() {
            return;
        }
        while self.active() < self.env_target {
            self.launch_group();
        }
    }

    /// Resize the environment-pool target after fleet changes
    /// (elastic runs only; fault-only runs keep the configured target).
    fn update_env_target(&mut self) {
        if self.scaler.is_none() {
            return;
        }
        let base = self.cfg.concurrent_envs.unwrap_or(self.cfg.batch_size);
        let live = self.proxy.live_engines().max(1);
        let scaled = base * live / self.initial_engines.max(1);
        let lo = self.cfg.group_size.max(base / 2);
        let hi = (2 * base).max(lo);
        self.env_target = scaled.clamp(lo, hi);
    }

    /// Barrier modes: launch one iteration's worth of groups.
    fn launch_iteration(&mut self) {
        let n_groups = (self.cfg.batch_size / self.cfg.group_size).max(1);
        for _ in 0..n_groups {
            self.launch_group();
        }
        self.iter_launched = true;
    }

    fn dispatch(&mut self, req: SimRequest) {
        if self.proxy.is_suspended() || self.proxy.live_engines() == 0 {
            // Suspended for weight sync, or the whole fleet is down
            // (chaos): hold the request; it re-dispatches on resume /
            // recovery / provisioning.
            self.pending_requests.push(req);
            return;
        }
        if let Some(e) = self.proxy.add(req) {
            self.kick_engine(e);
        }
    }

    fn kick_engine(&mut self, e: usize) {
        if self.engine_busy[e] || self.engine_down[e] || self.proxy.is_suspended() {
            return;
        }
        let outcome = self.proxy.engines_mut()[e].step();
        if let crate::proxy::StepOutcome::Busy {
            elapsed, completed, ..
        } = outcome
        {
            self.engine_busy[e] = true;
            let epoch = self.engine_epoch[e];
            self.q.schedule_in(
                elapsed,
                Ev::EngineFree {
                    engine: e,
                    epoch,
                    completed,
                },
            );
        }
    }

    fn kick_all_engines(&mut self) {
        for e in 0..self.engine_busy.len() {
            self.kick_engine(e);
        }
    }

    fn env_step_latency(&mut self, mgr: usize) -> f64 {
        let domain = self.mgrs[mgr].domain();
        let turn = self.mgrs[mgr].turns_done();
        let mut r = self
            .rng
            .stream("envstep", (mgr * 1000 + turn) as u64);
        match &self.cfg.env_step_override {
            Some(d) => d.sample(&mut r),
            None => self.cfg.envpool.sample_step(domain, &mut r),
        }
    }

    fn handle_action(&mut self, mgr: usize, action: EnvAction) {
        match action {
            EnvAction::Generate(req) => {
                // RollArt's per-iteration staleness enforcement (§6.2
                // fn.1): abort mid-flight trajectories whose start
                // version left the α window, instead of letting them
                // generate a stale tail that get_batch would evict
                // anyway (AReaL's behaviour).
                if self.cfg.mode == Mode::RollArt
                    && !self.mgrs[mgr]
                        .traj
                        .fresh_at_start(self.version, self.cfg.alpha)
                {
                    self.abort_mgr(mgr, true);
                    return;
                }
                self.dispatch(req);
            }
            EnvAction::StepEnv => {
                // Fault plane: this step may kill its env worker.  The
                // crash is detected after the health-check delay and
                // recovered at trajectory level (group backfill).
                if self.fault_on
                    && self
                        .cfg
                        .fault
                        .env_step_crashes(&self.rng, mgr, self.mgrs[mgr].turns_done())
                {
                    self.q.schedule_in(
                        self.cfg.fault.env_crash_detect_s,
                        Ev::EnvCrashed { mgr },
                    );
                    return;
                }
                let lat = self.env_step_latency(mgr);
                self.q.schedule_in(lat, Ev::EnvStepDone { mgr });
            }
            EnvAction::Complete => {
                self.dispatch_reward(mgr);
            }
        }
    }

    fn abort_mgr(&mut self, mgr: usize, stale: bool) {
        let id = self.mgrs[mgr].id;
        let group = self.mgrs[mgr].traj.group;
        self.mgrs[mgr].abort();
        self.proxy.abort(id);
        self.groups.fail(id);
        if stale {
            self.acc_stale += 1;
        } else {
            self.acc_redundant += 1;
        }
        // A stale/failed member leaves its group short: relaunch a
        // replacement at the *current* version so the group can still
        // fill (the paper re-rolls aborted trajectories).
        if stale && !self.groups.is_filled(group) {
            self.launch_member(group);
        }
        self.refill();
    }

    /// Launch one replacement member into an existing group.
    fn launch_member(&mut self, group: u64) {
        let domain = self.group_domain[&group];
        let profile = DomainProfile::of(domain);
        let idx = self.mgrs.len();
        let id = TrajectoryId(idx as u64);
        let shape = profile.sample_trajectory(&mut self.rng);
        let m = EnvManagerSim::new(id, shape, self.version, group, self.now());
        self.mgrs.push(m);
        self.groups.launch(group, id);
        self.schedule_reset(idx);
    }

    // ---- fault plane ------------------------------------------------

    /// Shared crash/retire path: mark the engine dead, invalidate its
    /// in-flight `EngineFree`, account alive time, and return its
    /// drained requests for re-dispatch.
    fn take_down_engine(&mut self, e: usize) -> Vec<SimRequest> {
        self.engine_down[e] = true;
        self.engine_epoch[e] += 1;
        self.engine_busy[e] = false;
        let now = self.now();
        if let Some(up) = self.engine_up_since[e].take() {
            self.engine_alive_s[e] += now - up;
        }
        self.proxy.engines_mut()[e].set_down(true);
        self.proxy.engines_mut()[e].drain_requests()
    }

    /// An engine crashed.  Trajectory-level recovery: every request it
    /// held (queued or mid-generation) is re-queued through the proxy
    /// instead of being lost — its trajectory survives, only the
    /// partially decoded turn is replayed.
    fn kill_engine(&mut self, e: usize, auto_recover: bool) {
        if self.engine_down[e] {
            return;
        }
        let reqs = self.take_down_engine(e);
        self.fault_report.engine_failures += 1;
        self.acc_engine_failures += 1;
        self.fault_report.requeued_requests += reqs.len() as u64;
        self.acc_requeued += reqs.len() as u64;
        self.down_since.insert(e, self.now());
        for r in reqs {
            self.dispatch(r);
        }
        if auto_recover {
            self.q
                .schedule_in(self.cfg.fault.engine_recovery_s, Ev::EngineRecovered { engine: e });
        }
        // A crash mid-drain must not wedge the weight-sync barrier:
        // the dead engine's EngineFree will never count down.
        if self.suspend_draining {
            self.finish_drain();
        }
    }

    fn revive_engine(&mut self, e: usize) {
        if !self.engine_down[e] || self.engine_retired[e] {
            return;
        }
        self.engine_down[e] = false;
        self.engine_up_since[e] = Some(self.now());
        self.proxy.engines_mut()[e].set_down(false);
        if let Some(t0) = self.down_since.remove(&e) {
            self.fault_report.recoveries += 1;
            self.fault_report.recovery_latency_s += self.now() - t0;
        }
        self.flush_pending();
        self.kick_engine(e);
    }

    /// Re-dispatch requests held while the fleet was down/suspended.
    fn flush_pending(&mut self) {
        if self.proxy.is_suspended() || self.proxy.live_engines() == 0 {
            return;
        }
        let pending: Vec<SimRequest> = std::mem::take(&mut self.pending_requests);
        for req in pending {
            self.dispatch(req);
        }
    }

    fn live_engines_of(&self, class: GpuClass) -> Vec<usize> {
        (0..self.engine_down.len())
            .filter(|&i| !self.engine_down[i] && self.proxy.engines()[i].class == class)
            .collect()
    }

    /// Scheduled chaos: kill `fraction` of the live engines of `class`.
    fn pool_outage(&mut self, class: GpuClass, fraction: f64) {
        let live = self.live_engines_of(class);
        let k = ((live.len() as f64) * fraction).ceil() as usize;
        // Kill from the back for determinism (highest indices first).
        for &e in live.iter().rev().take(k) {
            self.kill_engine(e, false);
        }
    }

    /// Scheduled chaos: bring every downed engine of `class` back.
    fn pool_restore(&mut self, class: GpuClass) {
        let down: Vec<usize> = (0..self.engine_down.len())
            .filter(|&i| {
                self.engine_down[i]
                    && !self.engine_retired[i]
                    && self.proxy.engines()[i].class == class
            })
            .collect();
        for e in down {
            self.revive_engine(e);
        }
    }

    /// Schedule engine `e`'s next stochastic failure (MTBF process).
    fn schedule_engine_failure(&mut self, e: usize) {
        let nth = self.engine_fail_nth[e];
        if let Some(dt) = self.cfg.fault.next_engine_failure(&self.rng, e, nth) {
            self.engine_fail_nth[e] += 1;
            self.q.schedule_in(dt, Ev::EngineCrashed { engine: e });
        }
    }

    // ---- elasticity plane -------------------------------------------

    /// Feed the controller the just-completed iteration's cost and act
    /// on its decision through the resource plane.
    fn maybe_autoscale(&mut self) {
        let Some(scaler) = self.scaler.as_mut() else {
            return;
        };
        let Some(last) = self.result.steps.last() else {
            return;
        };
        let cost = IterationCost {
            get_batch_wait_s: last.breakdown.get_batch_wait_s,
            weight_update_s: last.breakdown.weight_sync_s,
            recompute_s: 0.0,
            train_s: last.breakdown.train_s,
            command_s: 0.0,
        };
        let class = scaler.policy.class;
        let live = self
            .proxy
            .engines()
            .iter()
            .enumerate()
            .filter(|(i, e)| e.class == class && !self.engine_down[*i])
            .count();
        match scaler.observe(&cost, live, self.pending_provisions) {
            ScaleDecision::Hold => {}
            ScaleDecision::Up(n) => {
                for _ in 0..n {
                    self.provision_engine();
                }
            }
            ScaleDecision::Down(n) => {
                // Retire the least-loaded live engines of the class:
                // minimal re-queued work.
                let mut candidates = self.live_engines_of(class);
                candidates.sort_by_key(|&i| self.proxy.engines()[i].load());
                let victims: Vec<usize> = candidates.into_iter().take(n).collect();
                for e in victims {
                    self.retire_engine(e);
                }
            }
        }
    }

    /// Start warming one engine: bind capacity now, join the fleet
    /// after the provision delay (boot + weight pull).
    fn provision_engine(&mut self) {
        let Some(scaler) = self.scaler.as_ref() else {
            return;
        };
        let policy = scaler.policy.clone();
        let binding = match self.rm.as_mut() {
            Some(rm) => {
                match rm.bind(
                    Role::ActorGen,
                    &[ResourceClass::Gpu(policy.class)],
                    policy.gpus_per_engine,
                ) {
                    Ok(b) => Some(b.id),
                    // Resource plane has no capacity left: the decision
                    // is dropped, not queued (next iteration retries).
                    Err(_) => return,
                }
            }
            None => None,
        };
        let delay = policy.provision_delay_s(&self.cfg.model);
        if let Some(s) = self.scaler.as_mut() {
            s.report.provision_wait_s += delay;
        }
        self.pending_provisions += 1;
        self.q
            .schedule_in(delay, Ev::EngineProvisioned { binding });
    }

    fn on_engine_provisioned(&mut self, binding: Option<u64>) {
        self.pending_provisions = self.pending_provisions.saturating_sub(1);
        let Some(scaler) = self.scaler.as_mut() else {
            return;
        };
        let policy = scaler.policy.clone();
        scaler.report.engines_added += 1;
        let e = self.proxy.add_engine(EngineSim::new(
            self.engine_down.len() as u64,
            policy.class,
            policy.gpus_per_engine,
            self.cfg.model.clone(),
            policy.max_batch,
        ));
        self.engine_busy.push(false);
        self.engine_down.push(false);
        self.engine_retired.push(false);
        self.engine_epoch.push(0);
        self.engine_fail_nth.push(0);
        self.engine_up_since.push(Some(self.now()));
        self.engine_alive_s.push(0.0);
        self.engine_bindings.push(binding);
        // The new engine is subject to the same failure process.
        if self.fault_on {
            self.schedule_engine_failure(e);
        }
        self.update_env_target();
        self.flush_pending();
        self.refill();
        self.kick_engine(e);
    }

    /// Elastic scale-down: drain, re-queue, release the binding.
    fn retire_engine(&mut self, e: usize) {
        if self.engine_down[e] {
            return;
        }
        let reqs = self.take_down_engine(e);
        self.engine_retired[e] = true;
        if let Some(s) = self.scaler.as_mut() {
            s.report.engines_retired += 1;
        }
        if let (Some(rm), Some(b)) = (self.rm.as_mut(), self.engine_bindings[e].take()) {
            rm.release(b);
        }
        for r in reqs {
            self.dispatch(r);
        }
        if self.suspend_draining {
            self.finish_drain();
        }
        self.update_env_target();
    }

    // -----------------------------------------------------------------

    fn dispatch_reward(&mut self, mgr: usize) {
        let mut r = self.rng.stream("rexec", mgr as u64);
        let mut exec = reward_exec(self.cfg, &mut r);
        if self.fault_on && matches!(self.cfg.reward, RewardDeploy::Serverless { .. }) {
            // Serverless stragglers: the invocation lands on a slow
            // sandbox and runs straggler_factor× longer.
            let mult = self.cfg.fault.reward_multiplier(&self.rng, mgr as u64);
            if mult > 1.0 {
                exec *= mult;
                self.fault_report.reward_stragglers += 1;
            }
        }
        match &self.cfg.reward {
            RewardDeploy::Serverless { .. } => {
                let inv = self.serverless.invoke(self.now(), exec, &mut r);
                let delay = (inv.done_s - self.now()).max(0.0);
                self.q.schedule_in(delay, Ev::RewardDone { mgr });
            }
            RewardDeploy::DedicatedGpus { .. } => {
                // FIFO over the dedicated reward servers.
                let now = self.now();
                let slot = self
                    .reward_gpu_free_at
                    .iter_mut()
                    .min_by(|a, b| a.partial_cmp(b).unwrap())
                    .expect("dedicated reward needs ≥1 GPU");
                let start = slot.max(now);
                *slot = start + exec;
                self.reward_busy_s += exec;
                let done = *slot;
                self.q.schedule_in(done - now, Ev::RewardDone { mgr });
            }
        }
    }

    /// Reward scored: group accounting + buffer deposit.
    ///
    /// GRPO needs *complete groups* (the group mean/std is the
    /// advantage baseline), so trajectories are staged until their
    /// group fills and only then deposited — this is exactly why
    /// redundant environment rollouts pay off (§6.3): one straggler
    /// otherwise gates its whole group's availability.
    fn on_reward_done(&mut self, mgr: usize) {
        if self.mgrs[mgr].is_terminal() && self.mgrs[mgr].phase == crate::coordinator::EnvPhase::Aborted
        {
            return;
        }
        let id = self.mgrs[mgr].id;
        let group = self.mgrs[mgr].traj.group;
        self.mgrs[mgr].traj.reward = Some(1.0);
        match self.groups.complete(id) {
            GroupOutcome::Surplus => {}
            GroupOutcome::Pending => {
                let traj = self.mgrs[mgr].traj.clone();
                self.staged.entry(group).or_default().push(traj);
            }
            GroupOutcome::Filled { abort } => {
                let traj = self.mgrs[mgr].traj.clone();
                let mut members = self.staged.remove(&group).unwrap_or_default();
                members.push(traj);
                if self.cfg.mode == Mode::RollArt {
                    // Atomic group deposit: all members or none (GRPO
                    // groups must never enter the buffer partially).
                    self.buffer.deposit_group(members, self.version);
                } else {
                    // Baseline semantics: per-trajectory deposit, a
                    // stale member is dropped individually (AReaL).
                    for t in members {
                        self.buffer.deposit(t, self.version);
                    }
                }
                for t in abort {
                    let i = t.0 as usize;
                    if !self.mgrs[i].is_terminal() {
                        self.abort_mgr(i, false);
                    }
                }
            }
        }
        self.refill();
        self.try_iteration_boundary();
    }

    /// The scheduling heart: can a train step (and the weight-sync
    /// protocol) start now?
    fn try_iteration_boundary(&mut self) {
        if self.trainer_busy || self.suspend_draining || self.pending_batch.is_some() {
            return;
        }
        let Some(batch) = self.buffer.get_batch(self.cfg.batch_size, self.version) else {
            // Barrier modes relaunch the next iteration only once the
            // batch is consumed; nothing to do here.
            return;
        };
        let tokens: f64 = batch.iter().map(|t| t.total_tokens() as f64).sum();
        let n = batch.len();
        self.acc_staleness = batch
            .iter()
            .map(|t| (self.version.0 - t.min_version().0) as f64)
            .sum::<f64>()
            / n.max(1) as f64;
        self.acc_wait += self.now() - self.trainer_idle_since;

        // Weight sync before this train step (protocol ②–⑤) when the
        // engines run older weights than the trainer produced.
        if self.weights_pushed_at.is_some() {
            self.pending_batch = Some((n, tokens));
            self.begin_suspend();
        } else {
            self.start_train(tokens);
        }
        // One-off / Sync+ barrier: next iteration launches are handled
        // at train start / sync completion respectively.
    }

    fn begin_suspend(&mut self) {
        self.proxy.suspend();
        self.suspend_draining = true;
        if self.engine_busy.iter().all(|b| !b) {
            self.finish_drain();
        }
        // else: the in-flight EngineFree events trigger finish_drain.
    }

    fn finish_drain(&mut self) {
        if !self.suspend_draining || self.engine_busy.iter().any(|b| *b) {
            return;
        }
        // Exposed update (③) + KV recompute (⑤).
        let push_start = self.weights_pushed_at.take().unwrap_or(self.now());
        let overlap = self.now() - push_start;
        let bytes = self.cfg.model.weight_bytes();
        let exposed = if self.cfg.async_weight_sync {
            self.store.sync(bytes, overlap).exposed_s
        } else {
            // Blocking veRL-style cross-cluster transfer (Fig 14a).
            self.store.sync(bytes, 0.0).naive_s
        };
        let recompute = self.proxy.recompute_cost_s();
        self.acc_exposed_sync += exposed;
        self.acc_recompute += recompute;
        self.q.schedule_in(exposed + recompute, Ev::SyncDone);
    }

    fn on_sync_done(&mut self) {
        self.suspend_draining = false;
        self.version = self.version.next();
        self.proxy.resume();
        let pending: Vec<SimRequest> = std::mem::take(&mut self.pending_requests);
        for req in pending {
            self.dispatch(req);
        }
        self.kick_all_engines();
        if let Some((_, tokens)) = self.pending_batch.take() {
            self.start_train(tokens);
        }
    }

    fn start_train(&mut self, tokens: f64) {
        let cost = self.cfg.model.train_cost(tokens, 8000.0);
        let t = phase_time(&cost, GpuClass::H800.spec(), self.cfg.train_gpus.max(1))
            * super::TRAIN_OVERHEAD;
        self.acc_train += t;
        self.trainer_busy = true;
        self.inflight_train_tokens = tokens;
        self.q.schedule_in(t, Ev::TrainDone);
    }

    fn maybe_launch_barrier_iteration(&mut self) {
        if self.continuous() || self.iter_launched {
            return;
        }
        self.launch_iteration();
    }

    fn on_train_done(&mut self, tokens_trained: f64) {
        self.trainer_busy = false;
        self.trainer_idle_since = self.now();
        self.train_steps_done += 1;
        // Publish new weights to the store (push overlaps rollout).
        self.weights_pushed_at = Some(self.now());

        // Record the completed step.
        let step_time = self.now() - self.last_train_done;
        self.last_train_done = self.now();
        let breakdown = StepBreakdown {
            generation_s: 0.0, // filled from engine stats at the end
            env_reset_s: 0.0,
            env_step_s: 0.0,
            reward_s: 0.0,
            train_s: std::mem::take(&mut self.acc_train),
            weight_sync_s: std::mem::take(&mut self.acc_exposed_sync)
                + std::mem::take(&mut self.acc_recompute),
            get_batch_wait_s: std::mem::take(&mut self.acc_wait),
            other_s: 0.0,
        };
        self.result.steps.push(StepStats {
            step_time_s: step_time,
            breakdown,
            batch_tokens: tokens_trained,
            mean_staleness: std::mem::take(&mut self.acc_staleness),
            stale_aborts: std::mem::take(&mut self.acc_stale),
            redundant_aborts: std::mem::take(&mut self.acc_redundant),
            env_failures: std::mem::take(&mut self.acc_failures),
            engine_failures: std::mem::take(&mut self.acc_engine_failures),
            requeued: std::mem::take(&mut self.acc_requeued),
        });

        // Elastic controller: one decision per completed iteration,
        // fed by the iteration cost just recorded.
        self.maybe_autoscale();

        // Sync+ barrier: next iteration only after train completes.
        if self.cfg.mode == Mode::SyncPlus {
            self.iter_launched = false;
            // Pay the weight sync *now*, blocking (synchronous training):
            self.begin_suspend();
            // next iteration launches on SyncDone via pending flag below
        }
        self.try_iteration_boundary();
    }

    fn run(mut self) -> ScenarioResult {
        self.trainer_idle_since = 0.0;
        if self.fault_on {
            // Deterministic chaos schedule + per-engine MTBF processes.
            for (idx, f) in self.cfg.fault.scheduled.iter().enumerate() {
                self.q.schedule(SimTime::secs(f.at_s), Ev::Scheduled { idx });
            }
            for e in 0..self.engine_down.len() {
                self.schedule_engine_failure(e);
            }
        }
        if self.continuous() {
            self.refill();
        } else {
            self.launch_iteration();
        }

        let target_steps = self.cfg.iterations;
        while let Some((t, ev)) = self.q.pop() {
            if self.fault_on && t.as_secs() > MAX_SIM_S {
                break; // chaos deadlock backstop; results are partial
            }
            match ev {
                Ev::ResetRetry { mgr } => {
                    self.inflight_resets = self.inflight_resets.saturating_sub(1);
                    if !self.mgrs[mgr].is_terminal() {
                        self.schedule_reset(mgr);
                    }
                }
                Ev::ResetDone { mgr } => {
                    self.inflight_resets = self.inflight_resets.saturating_sub(1);
                    if !self.mgrs[mgr].is_terminal() {
                        let v = self.version;
                        let action = self.mgrs[mgr].on_reset_done(v);
                        self.handle_action(mgr, action);
                    }
                }
                Ev::EngineFree { engine, epoch, completed } => {
                    if epoch != self.engine_epoch[engine] {
                        // The engine crashed (or was retired) while
                        // this step was in flight: its work was drained
                        // and re-queued; the completions never
                        // happened.
                        continue;
                    }
                    self.engine_busy[engine] = false;
                    for (tid, _ctx) in completed {
                        let mgr = tid.0 as usize;
                        if self.mgrs[mgr].is_terminal() {
                            continue;
                        }
                        if self.mgrs[mgr].phase == crate::coordinator::EnvPhase::Generating {
                            let v = self.version;
                            let action = self.mgrs[mgr].on_generation_done(v);
                            self.handle_action(mgr, action);
                        }
                    }
                    if self.suspend_draining {
                        self.finish_drain();
                    } else {
                        self.kick_engine(engine);
                    }
                }
                Ev::EnvStepDone { mgr } => {
                    if !self.mgrs[mgr].is_terminal() {
                        let v = self.version;
                        let now = self.now();
                        let action = self.mgrs[mgr].on_env_step_done(v, now);
                        self.handle_action(mgr, action);
                    }
                }
                Ev::EnvCrashed { mgr } => {
                    if self.mgrs[mgr].is_terminal() {
                        continue;
                    }
                    // Trajectory-level recovery: the dead worker's
                    // trajectory is abandoned, but its GRPO group is
                    // backfilled with a fresh member at the current
                    // version (§6.3 redundancy machinery).
                    let id = self.mgrs[mgr].id;
                    let group = self.mgrs[mgr].traj.group;
                    self.mgrs[mgr].abort();
                    self.proxy.abort(id);
                    self.groups.fail(id);
                    self.fault_report.env_crashes += 1;
                    self.acc_failures += 1;
                    if !self.groups.is_filled(group) {
                        self.fault_report.trajectories_relaunched += 1;
                        self.launch_member(group);
                    }
                    self.refill();
                }
                Ev::EngineCrashed { engine } => {
                    if !self.engine_down[engine] && !self.engine_retired[engine] {
                        self.kill_engine(engine, true);
                    }
                    // The failure process continues either way.
                    self.schedule_engine_failure(engine);
                }
                Ev::EngineRecovered { engine } => {
                    self.revive_engine(engine);
                }
                Ev::Scheduled { idx } => {
                    let event = self.cfg.fault.scheduled[idx].event.clone();
                    match event {
                        FaultEvent::EngineCrash { engine } => {
                            if engine < self.engine_down.len() && !self.engine_retired[engine] {
                                self.kill_engine(engine, true);
                            }
                        }
                        FaultEvent::PoolOutage { class, fraction } => {
                            self.pool_outage(class, fraction);
                        }
                        FaultEvent::PoolRestore { class } => {
                            self.pool_restore(class);
                        }
                    }
                }
                Ev::EngineProvisioned { binding } => {
                    self.on_engine_provisioned(binding);
                }
                Ev::RewardDone { mgr } => {
                    self.on_reward_done(mgr);
                }
                Ev::TrainDone => {
                    let tokens = self.inflight_train_tokens;
                    self.on_train_done(tokens);
                    if self.train_steps_done >= target_steps {
                        break;
                    }
                }
                Ev::SyncDone => {
                    self.on_sync_done();
                    if self.cfg.mode == Mode::SyncPlus {
                        self.maybe_launch_barrier_iteration();
                    }
                }
            }
        }

        // Final stats.
        let total = self.now().max(1e-9);
        self.result.total_time_s = total;
        let n_engines = self.engine_busy.len() as f64;
        let busy: f64 = self
            .proxy
            .engines()
            .iter()
            .map(|e| e.stats.busy_s)
            .sum();
        if self.fault_on || self.scaler.is_some() {
            // Engines churned: utilization over engine-*alive* seconds,
            // and the fault/elastic reports become part of the result.
            let mut alive: f64 = self.engine_alive_s.iter().sum();
            for up in self.engine_up_since.iter().flatten() {
                alive += total - up;
            }
            self.result.gen_util = (busy / alive.max(1e-9)).min(1.0);
        } else {
            self.result.gen_util = (busy / (total * n_engines)).min(1.0);
        }
        self.result.gen_tokens = self
            .proxy
            .engines()
            .iter()
            .map(|e| e.stats.prefill_tokens + e.stats.decode_tokens)
            .sum();
        self.result.faults = self.fault_report;
        if let Some(s) = &self.scaler {
            self.result.elastic = s.report;
        }
        self.result.reward_util = match &self.cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, .. } => {
                self.reward_busy_s / (total * (*gpus).max(1) as f64)
            }
            RewardDeploy::Serverless { .. } => self.serverless.utilization(total),
        };
        // Spread generation time into per-step breakdowns (engines are
        // shared across steps; attribute uniformly).
        let steps = self.result.steps.len().max(1) as f64;
        for s in &mut self.result.steps {
            s.breakdown.generation_s = busy / steps;
        }
        self.result
    }
}

/// Run a trajectory-level scenario.
pub fn run(cfg: &Scenario) -> ScenarioResult {
    assert_ne!(cfg.mode, Mode::Sync, "use sync_driver for Mode::Sync");
    Driver::new(cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::QWEN3_8B;

    fn scenario(mode: Mode) -> Scenario {
        let mut s = Scenario::rollart_default(QWEN3_8B.clone(), 0.06);
        s.mode = mode;
        s.batch_size = 16;
        s.group_size = 4;
        s.iterations = 3;
        s
    }

    #[test]
    fn rollart_runs_to_completion() {
        let r = run(&scenario(Mode::RollArt));
        assert_eq!(r.steps.len(), 3);
        for s in &r.steps {
            assert!(s.step_time_s > 0.0);
            assert!(s.batch_tokens > 0.0, "{s:?}");
        }
        assert!(r.gen_util > 0.0 && r.gen_util <= 1.0);
    }

    #[test]
    fn all_async_modes_run() {
        for mode in [Mode::SyncPlus, Mode::OneOff, Mode::AReaL, Mode::RollArt] {
            let r = run(&scenario(mode));
            assert_eq!(r.steps.len(), 3, "{mode:?}");
        }
    }

    #[test]
    fn deterministic() {
        let a = run(&scenario(Mode::RollArt));
        let b = run(&scenario(Mode::RollArt));
        assert_eq!(a.mean_step_time(), b.mean_step_time());
    }

    #[test]
    fn engine_mtbf_faults_recover_trajectories() {
        use crate::fault::FaultProfile;
        let clean = run(&scenario(Mode::RollArt));
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            engine_recovery_s: 60.0,
            ..FaultProfile::mtbf(400.0)
        };
        let r = run(&cfg);
        // Crashes happened, every iteration still completed, and the
        // re-queue machinery recovered the in-flight work.
        assert_eq!(r.steps.len(), 3, "no iteration may be lost to crashes");
        assert!(r.faults.engine_failures > 0, "{:?}", r.faults);
        assert!(r.faults.recoveries > 0);
        assert!(r.faults.mean_recovery_latency_s() >= 60.0 - 1e-9);
        // Faults burn wall-clock: the run cannot get meaningfully
        // faster (small tolerance for event-reordering noise).
        assert!(
            r.total_time_s >= 0.9 * clean.total_time_s,
            "faults cannot speed the run up: {} vs {}",
            r.total_time_s,
            clean.total_time_s
        );
    }

    #[test]
    fn env_crashes_backfill_their_groups() {
        use crate::fault::FaultProfile;
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            env_crash_p: 0.05,
            ..FaultProfile::none()
        };
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 3);
        assert!(r.faults.env_crashes > 0, "{:?}", r.faults);
        assert!(r.faults.trajectories_relaunched > 0);
    }

    #[test]
    fn fault_runs_are_deterministic() {
        use crate::fault::FaultProfile;
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            env_crash_p: 0.02,
            ..FaultProfile::mtbf(500.0)
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.mean_step_time(), b.mean_step_time());
        assert_eq!(a.faults, b.faults);
    }

    #[test]
    fn scheduled_pool_outage_and_restore_ride_through() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        use crate::hw::GpuClass;
        let mut cfg = scenario(Mode::RollArt);
        cfg.fault = FaultProfile {
            scheduled: vec![
                ScheduledFault {
                    at_s: 50.0,
                    event: FaultEvent::PoolOutage {
                        class: GpuClass::H800,
                        fraction: 0.5,
                    },
                },
                ScheduledFault {
                    at_s: 1500.0,
                    event: FaultEvent::PoolRestore {
                        class: GpuClass::H800,
                    },
                },
            ],
            ..FaultProfile::none()
        };
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 3);
        assert!(r.faults.engine_failures > 0);
    }

    #[test]
    fn elastic_controller_grows_a_starved_pool() {
        use crate::elastic::ElasticPolicy;
        use crate::hw::GpuClass;
        let mut cfg = scenario(Mode::RollArt);
        cfg.iterations = 4;
        let mut policy = ElasticPolicy::new(GpuClass::H800, cfg.model.rollout_tp, 32);
        // Hair-trigger scale-up so the tiny test scenario provisions
        // deterministically (any positive get_batch wait counts as
        // rollout-bound).
        policy.scale_up_wait_ratio = 1e-9;
        policy.scale_down_wait_ratio = 1e-12;
        policy.max_engines = 16;
        policy.cooldown_steps = 0;
        cfg.elastic = Some(policy);
        let r = run(&cfg);
        assert_eq!(r.steps.len(), 4);
        assert!(r.elastic.scale_ups > 0, "{:?}", r.elastic);
        assert!(r.elastic.engines_added > 0, "{:?}", r.elastic);
        assert!(r.elastic.provision_wait_s > 0.0);
    }

    #[test]
    fn goodput_and_efficiency_are_sane() {
        let r = run(&scenario(Mode::RollArt));
        assert!(r.goodput() > 0.0);
        let eff = r.token_efficiency();
        assert!(eff > 0.0 && eff <= 1.0, "{eff}");
    }

    #[test]
    fn continuous_overlap_beats_stop_and_go() {
        // At unit-test scale the engine pools are too small for
        // affinity routing to be meaningful (the benches exercise R1
        // at proper scale); this asserts the R4 machinery: continuous
        // bounded-staleness overlap beats the Sync+ barrier.
        let sp = run(&scenario(Mode::SyncPlus));
        let mut cfg = scenario(Mode::RollArt);
        cfg.affinity_routing = false;
        let ra = run(&cfg);
        assert!(
            ra.mean_step_time() < sp.mean_step_time(),
            "RollArt {} vs Sync+ {}",
            ra.mean_step_time(),
            sp.mean_step_time()
        );
    }
}
