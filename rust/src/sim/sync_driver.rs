//! Phase-structured driver for the monolithic synchronous baseline.
//!
//! Models the paper's Sync pipeline (§7.1, Fig 2-Left, Fig 3):
//!
//! 1. `env.reset` for the whole batch — a barrier over the slowest
//!    container (failures burn the detection timeout, then retry);
//! 2. *batched* rollout rounds (Fig 5b): every surviving trajectory
//!    generates, then every environment steps; the round ends at the
//!    slowest member;
//! 3. batched reward on dedicated GPUs after all rollouts finish;
//! 4. blocking weight synchronization;
//! 5. blocking training.
//!
//! Reward/generation utilization and the Fig 3 component breakdown fall
//! out of the phase times directly.
//!
//! Chaos model: scheduled pool outages are paid *in kind* — a downed
//! engine's environment shard is adopted by a survivor and runs there
//! as an additional serialized wave (per-engine queueing), rather than
//! rescaling an aggregate capacity.  Engine crashes and env-worker
//! deaths remain analytic stalls: the monolith has no re-queue path, so
//! the whole barrier waits out each recovery.
//!
//! PD model: with a disaggregated [`Scenario::pd`] the monolith pays
//! the prefill→decode KV hop *analytically* — each rollout round adds
//! the balanced fair-share makespan of the turn's KV transfers over
//! the shared link ([`crate::net::balanced_makespan`], booked under
//! `other_s`).  The pools themselves are not split (the barrier model
//! has no per-phase dispatch); the term exists so sync-vs-async PD
//! comparisons are not biased by a free KV hop on the sync side.
//!
//! Weight-plane model: the monolith's sync is blocking by construction
//! (a barrier pipeline cannot exploit rolling updates), so with the
//! default [`BlockingBroadcast`](crate::weights::BlockingBroadcast)
//! knob it pays the legacy colocated NCCL reshard.  When a scenario
//! configures a non-default dissemination strategy, the monolith pays
//! the *matching analytic term* instead —
//! [`WeightsScenario::analytic_fleet_sync_s`](crate::weights::WeightsScenario::analytic_fleet_sync_s),
//! one full-weight pull per engine over the configured fan-out link —
//! so blocking-vs-event-strategy comparisons are not biased by the
//! baselines paying a different transfer cost model.

use super::{RewardDeploy, Scenario, ScenarioResult, StepStats};
use crate::coordinator::GroupTracker;
use crate::obs::{self, TraceRecorder};
use crate::env::profile::{DomainProfile, TrajectoryShape};
use crate::envpool::ResetSampler;
use crate::fault::{exp_sample, FaultEvent};
use crate::hw::phase_time;
use crate::metrics::StepBreakdown;
use crate::net::{balanced_makespan, NVLINK_INTRA};
use crate::proxy::{EngineSim, SimRequest};
use crate::rl::TrajectoryId;
use crate::simkit::SimRng;
use crate::weights::SyncStrategyKind;

use super::TRAIN_OVERHEAD;

/// Run the synchronous scenario.
pub fn run(cfg: &Scenario) -> ScenarioResult {
    let mut rec = TraceRecorder::disabled();
    run_with_trace(cfg, &mut rec)
}

/// Run the synchronous scenario and attach a synthesized
/// [`CritPathReport`](crate::obs::CritPathReport) (`result.critpath`).
///
/// The monolith has no event queue to record causal provenance from,
/// but a barrier pipeline *is* one causal chain by construction: each
/// iteration's committed [`StepBreakdown`] phases map directly onto
/// critical-path nodes, in barrier order, with `get_batch_wait` booked
/// as queueing on the train edge and the analytic KV-hop/fault terms
/// under `other`.  This keeps `Mode::Sync` a first-class citizen of
/// blame tables and [`what_if`](crate::obs::what_if) rankings alongside
/// the provenance-extracted event-driver reports
/// ([`run_with_provenance`](super::driver::run_with_provenance)).
///
/// Aside from `critpath` the result is byte-identical to [`run`]'s.
pub fn run_with_critpath(cfg: &Scenario) -> ScenarioResult {
    use crate::obs::{synthesize_critpath, EdgeKind, PathNode};
    let mut result = run(cfg);
    let iters: Vec<Vec<PathNode>> = result
        .steps
        .iter()
        .map(|s| {
            let b = &s.breakdown;
            let phases = [
                (EdgeKind::EnvReset, b.env_reset_s, 0.0),
                (EdgeKind::Generation, b.generation_s, 0.0),
                (EdgeKind::EnvStep, b.env_step_s, 0.0),
                (EdgeKind::Reward, b.reward_s, 0.0),
                (EdgeKind::Other, b.other_s, 0.0),
                (EdgeKind::Barrier, b.weight_sync_s, 0.0),
                (EdgeKind::Train, b.train_s, b.get_batch_wait_s),
            ];
            phases
                .iter()
                .filter(|(_, service, queue)| service + queue > 0.0)
                .map(|&(kind, service, queue)| PathNode {
                    kind,
                    actor: u32::MAX,
                    service_s: service,
                    queue_s: queue,
                })
                .collect()
        })
        .collect();
    result.critpath = Some(Box::new(synthesize_critpath(&iters)));
    result
}

/// Run the synchronous scenario, recording its phase timeline into
/// `rec`.
///
/// The monolith is analytic (no event queue), so the trace is a flat
/// per-iteration timeline on [`obs::PID_DRIVER`]: one span per pipeline
/// phase, serialized in the barrier order of the module doc.  Phase
/// durations come straight from the committed
/// [`StepBreakdown`](crate::metrics::StepBreakdown), so the span
/// timeline sums to `total_time_s` exactly.  The `other` span bundles
/// the analytic KV-hop and fault-stall terms; its nominal position at
/// the end of the iteration is a presentation choice (the modeled costs
/// interleave with rollout).
///
/// Passing a disabled recorder is free and bit-identical to [`run`].
pub fn run_with_trace(cfg: &Scenario, rec: &mut TraceRecorder) -> ScenarioResult {
    if rec.is_enabled() {
        rec.process_name(obs::PID_DRIVER, "sync-pipeline");
    }
    let root = SimRng::new(cfg.seed);
    let mut result = ScenarioResult::default();
    let mut reward_busy = 0.0;
    let mut gen_busy = 0.0;
    let mut clock = 0.0;
    let mut reset_sampler = ResetSampler::new(&cfg.envpool);
    // Scheduled single-engine crashes are paid exactly once, in the
    // iteration whose start crosses their timestamp.
    let mut scheduled_crash_done = vec![false; cfg.fault.scheduled.len()];
    // Outage state carried across iterations (per-engine failure
    // accounting: an engine counts as failed once per downtime spell).
    let mut was_down: Vec<bool> = Vec::new();

    // Engine fleet (no affinity in the Sync baseline: whole pool).
    let mut engines: Vec<EngineSim> = Vec::new();
    let mut eid = 0;
    for pool in &cfg.gen_pools {
        for _ in 0..pool.engines {
            engines.push(EngineSim::new(
                eid,
                pool.class,
                pool.gpus_per_engine,
                cfg.model.clone(),
                pool.max_batch,
            ));
            eid += 1;
        }
    }
    assert!(!engines.is_empty());
    was_down.resize(engines.len(), false);

    for iter in 0..cfg.iterations {
        let mut rng = root.stream("iter", iter as u64);
        let mut breakdown = StepBreakdown::default();
        let mut env_failures = 0u64;
        let mut engine_failures = 0u64;

        // ---- scheduled chaos: per-engine outage state ---------------
        // Pool outages that have fired by this iteration's start take
        // concrete engines out of the rollout rotation (killed from the
        // back within their class, mirroring the async driver); the
        // batched rounds then *queue* their work on the survivors
        // instead of rescaling an aggregate capacity — one surviving
        // engine with 4× the requests takes ~4× the round, which is the
        // per-engine queueing model the aggregate rescale lacked.
        let mut engine_live = vec![true; engines.len()];
        if !cfg.fault.scheduled.is_empty() {
            // Apply the chaos schedule in *timestamp* order — the
            // async driver processes it through a time-ordered event
            // queue, and an unsorted profile (restore listed before
            // the outage it clears) must not change the outcome.
            let mut fired: Vec<&crate::fault::ScheduledFault> = cfg
                .fault
                .scheduled
                .iter()
                .filter(|f| f.at_s <= clock)
                .collect();
            fired.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
            let mut outage: std::collections::BTreeMap<crate::hw::GpuClass, f64> =
                std::collections::BTreeMap::new();
            for f in fired {
                match f.event {
                    FaultEvent::PoolOutage { class, fraction } => {
                        let e = outage.entry(class).or_insert(0.0);
                        *e = (*e + fraction).min(1.0);
                    }
                    FaultEvent::PoolRestore { class } => {
                        outage.insert(class, 0.0);
                    }
                    FaultEvent::EngineCrash { .. } => {}
                }
            }
            for (&class, &fraction) in &outage {
                let members: Vec<usize> = engines
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| e.class == class)
                    .map(|(i, _)| i)
                    .collect();
                let k = ((members.len() as f64) * fraction).ceil() as usize;
                for &i in members.iter().rev().take(k) {
                    engine_live[i] = false;
                }
            }
            // The monolith has no replacement machinery: a fully-dead
            // fleet degenerates to one skeleton engine carrying the
            // whole batch rather than a dead stop.
            if engine_live.iter().all(|l| !l) {
                engine_live[0] = true;
            }
            for i in 0..engines.len() {
                if !engine_live[i] && !was_down[i] {
                    engine_failures += 1;
                }
                was_down[i] = !engine_live[i];
            }
        }
        let live_idx: Vec<usize> = (0..engines.len()).filter(|&i| engine_live[i]).collect();

        // ---- sample the batch's trajectory shapes -------------------
        let mut groups = GroupTracker::new();
        let mut shapes: Vec<TrajectoryShape> = Vec::new();
        let n_groups = cfg.batch_size / cfg.group_size;
        for g in 0..n_groups {
            groups.add_group(g as u64, cfg.group_size);
            let domain = *rng.choose(&cfg.task_mix);
            let profile = DomainProfile::of(domain);
            for m in 0..cfg.group_size {
                let id = (g * cfg.group_size + m) as u64;
                shapes.push(profile.sample_trajectory(&mut rng));
                groups.launch(g as u64, TrajectoryId(id));
            }
        }
        let n = shapes.len();

        // ---- phase 1: batched env.reset (barrier at slowest) --------
        let mut reset_max: f64 = 0.0;
        for i in 0..n {
            let mut r = rng.stream("reset", i as u64);
            let mut t = 0.0;
            loop {
                let o = reset_sampler.sample(n, &mut r);
                t += o.latency_s;
                if !o.failed {
                    break;
                }
                env_failures += 1;
            }
            reset_max = reset_max.max(t);
        }
        breakdown.env_reset_s = reset_max;

        // ---- phase 2: batched rollout rounds ------------------------
        let max_turns = shapes.iter().map(|s| s.turns()).max().unwrap_or(0);
        let mut gen_time = 0.0;
        let mut env_time = 0.0;
        let mut kv_time = 0.0;
        // Disaggregated PD arm: the monolith ships every turn's fresh
        // KV between the pools (analytic transfer term; see module doc).
        let pd_link = cfg.pd.as_ref().filter(|p| p.disaggregated);
        let mut ctx: Vec<f64> = shapes.iter().map(|_| 0.0).collect();
        for turn in 0..max_turns {
            // generation: active trajectories spread across engines.
            let mut active = 0;
            let mut kv_transfer_bytes: Vec<f64> = Vec::new();
            for (i, s) in shapes.iter().enumerate() {
                if turn < s.turns() {
                    let (obs, act) = s.per_turn[turn];
                    let new = if turn == 0 {
                        s.initial_prompt_tokens + obs
                    } else {
                        obs
                    };
                    let e = active % engines.len();
                    engines[e].enqueue(SimRequest {
                        traj: TrajectoryId(i as u64),
                        domain: s.domain,
                        new_tokens: new,
                        ctx_tokens: ctx[i],
                        decode_budget: act,
                    });
                    if pd_link.is_some() {
                        kv_transfer_bytes
                            .push(crate::sim::driver::pd::kv_bytes(&cfg.model, new));
                    }
                    ctx[i] += new + act;
                    active += 1;
                }
            }
            if active == 0 {
                break;
            }
            if let Some(p) = pd_link {
                // Each round's freshly prefilled KV crosses the shared
                // link before decode; the batch barrier waits it out.
                kv_time += balanced_makespan(&p.kv_link, p.kv_slots, &kv_transfer_bytes);
            }
            // Batched: the round lasts as long as the slowest engine.
            // Per-engine queueing under outages: each engine's shard of
            // environments runs as one batched wave; a dead engine's
            // shard is adopted by a survivor (round-robin) and runs
            // there as an *additional* wave — monolithic frameworks
            // shard envs statically per engine process, so an adopted
            // shard queues behind the survivor's own work instead of
            // merging into its batch.  The barrier ends at the survivor
            // with the most queued waves.
            let round: f64 = if live_idx.len() == engines.len() {
                engines
                    .iter_mut()
                    .map(|e| e.run_to_idle().0)
                    .fold(0.0, f64::max)
            } else {
                let mut round_time = vec![0.0; engines.len()];
                for &i in &live_idx {
                    round_time[i] = engines[i].run_to_idle().0;
                }
                let dead: Vec<usize> =
                    (0..engines.len()).filter(|&i| !engine_live[i]).collect();
                let mut rr = 0usize;
                for i in dead {
                    let reqs = engines[i].drain_requests();
                    if reqs.is_empty() {
                        continue;
                    }
                    let s = live_idx[rr % live_idx.len()];
                    rr += 1;
                    for r in reqs {
                        engines[s].enqueue(r);
                    }
                    round_time[s] += engines[s].run_to_idle().0;
                }
                round_time.iter().cloned().fold(0.0, f64::max)
            };
            gen_time += round;

            // env round: barrier at the slowest environment step.
            let mut step_max: f64 = 0.0;
            for (i, s) in shapes.iter().enumerate() {
                if turn < s.turns() {
                    let mut r = rng.stream("step", (turn * n + i) as u64);
                    let lat = match &cfg.env_step_override {
                        Some(d) => d.sample(&mut r),
                        None => cfg.envpool.sample_step(s.domain, &mut r),
                    };
                    step_max = step_max.max(lat);
                }
            }
            env_time += step_max;
        }
        breakdown.generation_s = gen_time;
        breakdown.env_step_s = env_time;
        // The KV hop is network time, not GPU busy time: it lengthens
        // the step (other_s) without counting toward gen utilization.
        breakdown.other_s += kv_time;
        gen_busy += gen_time;

        // ---- phase 3: batched reward ---------------------------------
        let reward_time = match &cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, exec_s } => {
                // n calls queued over `gpus` servers.
                let total: f64 = (0..n)
                    .map(|i| exec_s.sample(&mut rng.stream("reward", i as u64)))
                    .sum();
                total / (*gpus as f64)
            }
            RewardDeploy::Serverless { exec_s } => {
                // still batched at the end in Sync, but elastic: the
                // platform fans out, so the phase lasts ~one call.
                let max: f64 = (0..n)
                    .map(|i| exec_s.sample(&mut rng.stream("reward", i as u64)))
                    .fold(0.0, f64::max);
                max
            }
        };
        breakdown.reward_s = reward_time;
        reward_busy += match &cfg.reward {
            RewardDeploy::DedicatedGpus { .. } => reward_time,
            RewardDeploy::Serverless { .. } => 0.0,
        };

        // ---- phase 4: blocking weight sync ---------------------------
        // Colocated monolith: NCCL reshard between training and rollout
        // processes over NVLink (fast but blocking).  A non-default
        // weight plane swaps in the matching analytic fan-out term so
        // the baseline pays the same transfer cost model the async
        // drivers route through the contended link (see module doc).
        let sync_time = if matches!(cfg.weights.strategy, SyncStrategyKind::BlockingBroadcast) {
            NVLINK_INTRA.transfer_time(cfg.model.weight_bytes()) + 2.0
        } else {
            cfg.weights.analytic_fleet_sync_s(&cfg.model, engines.len()) + 2.0
        };
        breakdown.weight_sync_s = sync_time;

        // ---- phase 5: blocking training ------------------------------
        let batch_tokens: f64 = shapes.iter().map(|s| s.total_tokens()).sum();
        let t_cost = cfg.model.train_cost(
            batch_tokens,
            shapes.iter().map(|s| s.final_context()).sum::<f64>() / n as f64,
        );
        let train_time = phase_time(&t_cost, cfg.train_class.spec(), cfg.train_gpus.max(1))
            * TRAIN_OVERHEAD;
        breakdown.train_s = train_time;

        // ---- fault plane (analytic): the monolithic baseline has no
        // recovery machinery, so every fault stalls the whole barrier
        // pipeline.  Pool outages are already paid in kind above — the
        // rollout rounds actually queued on the survivors --------------
        if cfg.fault.is_active() {
            // Same seeding convention as the async driver: the stream
            // is salted, so salt sweeps replay independent patterns.
            let mut fr = cfg.fault.stream(&root, "fault/sync", iter as u64);
            let mut stall = 0.0;
            // Scheduled single-engine crashes pay one recovery stall in
            // the iteration they land in.
            for (fi, f) in cfg.fault.scheduled.iter().enumerate() {
                if f.at_s <= clock
                    && !scheduled_crash_done[fi]
                    && matches!(f.event, FaultEvent::EngineCrash { .. })
                {
                    scheduled_crash_done[fi] = true;
                    engine_failures += 1;
                    stall += cfg.fault.engine_recovery_s
                        + breakdown.generation_s / (max_turns.max(1) as f64);
                }
            }
            // Engine crashes during the rollout phase: the interrupted
            // batched round is redone on the recovered engine, and the
            // whole batch waits out the recovery (no re-queue path).
            // Only live engines draw from the MTBF process.
            if let Some(mtbf) = cfg.fault.engine_mtbf_s {
                let round = breakdown.generation_s / (max_turns.max(1) as f64);
                for _e in 0..live_idx.len() {
                    let mut t = exp_sample(mtbf, &mut fr);
                    while t < breakdown.generation_s {
                        engine_failures += 1;
                        stall += cfg.fault.engine_recovery_s + round;
                        t += exp_sample(mtbf, &mut fr);
                    }
                }
            }
            // Env-worker crashes: detection + container restart, each
            // serialized behind the barrier.
            let mut env_crashes = 0u64;
            if cfg.fault.env_crash_p > 0.0 {
                let total_env_steps: usize = shapes.iter().map(|s| s.turns()).sum();
                for _ in 0..total_env_steps {
                    if fr.chance(cfg.fault.env_crash_p) {
                        env_crashes += 1;
                        stall += cfg.fault.env_crash_detect_s + cfg.envpool.reset_dist().mean();
                    }
                }
            }
            // Serverless reward stragglers stretch the batched reward
            // phase (the barrier ends at the slowest call).
            let mut stragglers = 0u64;
            if cfg.fault.straggler_p > 0.0
                && matches!(cfg.reward, RewardDeploy::Serverless { .. })
            {
                for _ in 0..n {
                    if fr.chance(cfg.fault.straggler_p) {
                        stragglers += 1;
                    }
                }
                if stragglers > 0 {
                    breakdown.reward_s *= cfg.fault.straggler_factor;
                }
            }
            breakdown.other_s += stall;
            env_failures += env_crashes;
            result.faults.engine_failures += engine_failures;
            result.faults.env_crashes += env_crashes;
            result.faults.reward_stragglers += stragglers;
        }

        let step_time = breakdown.total();
        if rec.is_enabled() {
            let mut t = clock;
            let phases = [
                ("env-reset", breakdown.env_reset_s),
                ("rollout", breakdown.generation_s),
                ("env-step", breakdown.env_step_s),
                ("reward", breakdown.reward_s),
                ("weight-sync", breakdown.weight_sync_s),
                ("get-batch-wait", breakdown.get_batch_wait_s),
                ("train", breakdown.train_s),
                ("other", breakdown.other_s),
            ];
            for (name, dur) in phases {
                if dur > 0.0 {
                    rec.span(obs::PID_DRIVER, 0, name, "sync-phase", t, dur);
                }
                t += dur;
            }
        }
        clock += step_time;
        result.steps.push(StepStats {
            step_time_s: step_time,
            breakdown,
            batch_tokens,
            mean_staleness: 0.0,
            stale_aborts: 0,
            redundant_aborts: 0,
            env_failures,
            engine_failures,
            requeued: 0,
        });
    }

    result.total_time_s = clock;
    if clock > 0.0 {
        result.reward_util = match &cfg.reward {
            RewardDeploy::DedicatedGpus { .. } => reward_busy / clock,
            RewardDeploy::Serverless { .. } => 1.0, // elastic: busy only when invoked
        };
        result.gen_util = gen_busy / clock;
    }
    result.gen_tokens = engines
        .iter()
        .map(|e| e.stats.prefill_tokens + e.stats.decode_tokens)
        .sum();
    // Weight-plane report, analytic: the monolith's sync is fully
    // exposed (overlap ratio 0) and the whole fleet sits through it.
    let sync_total: f64 = result.steps.iter().map(|s| s.breakdown.weight_sync_s).sum();
    result.weights = crate::weights::WeightSyncReport {
        publishes: result.steps.len() as u64,
        engine_syncs: (engines.len() * result.steps.len()) as u64,
        exposed_stall_s: sync_total,
        dissemination_s: sync_total,
        engine_offline_s: sync_total * engines.len() as f64,
        ..Default::default()
    };
    // Unified bucket model: on the Mooncake-plane arm (non-default
    // strategy) the monolith reports the same Table 4 decomposition
    // the DES books per engine — push and per-engine pull per publish,
    // everything exposed (a barrier pipeline has no overlap window).
    if !matches!(cfg.weights.strategy, SyncStrategyKind::BlockingBroadcast) {
        let store = crate::mooncake::MooncakeStore::new(cfg.weights.mooncake.clone());
        let bytes = cfg.model.weight_bytes();
        let iters = result.steps.len() as f64;
        let push = store.push_time(bytes);
        let pull = store.acc_pull_time(bytes);
        let pulls = (engines.len() * result.steps.len()) as u64;
        result.weights.buckets = crate::weights::BucketBreakdown {
            push_s: push * iters,
            acc_pull_s: pull * pulls as f64,
            // Every engine sits through the whole barrier each publish,
            // so the per-cutover mean is the full per-publish stall
            // (mirrors engine_offline_s above).
            exposed_s: sync_total * engines.len() as f64,
            naive_s: (push + pull) * iters,
            engine_pulls: pulls,
            cutovers: pulls,
            bucket_transfers: pulls * cfg.weights.mooncake.bucket_count(bytes) as u64,
            bytes_pulled: pulls as f64 * bytes,
            ..Default::default()
        };
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envpool::EnvPoolConfig;
    use crate::llm::QWEN3_8B;
    use crate::sim::{Mode, Scenario};
    use crate::simkit::dist::Dist;

    fn small_sync() -> Scenario {
        let mut s = Scenario::rollart_default(QWEN3_8B.clone(), 0.1);
        s.mode = Mode::Sync;
        s.batch_size = 32;
        s.iterations = 3;
        s.reward = RewardDeploy::DedicatedGpus {
            gpus: 4,
            exec_s: Dist::Constant(2.0),
        };
        s
    }

    #[test]
    fn produces_iterations_with_positive_components() {
        let r = run(&small_sync());
        assert_eq!(r.steps.len(), 3);
        for s in &r.steps {
            assert!(s.step_time_s > 0.0);
            assert!(s.breakdown.generation_s > 0.0);
            assert!(s.breakdown.env_reset_s > 0.0);
            assert!(s.breakdown.train_s > 0.0);
            assert!(s.batch_tokens > 0.0);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = run(&small_sync());
        let b = run(&small_sync());
        assert_eq!(a.mean_step_time(), b.mean_step_time());
        let mut c = small_sync();
        c.seed += 1;
        let d = run(&c);
        assert_ne!(a.mean_step_time(), d.mean_step_time());
    }

    #[test]
    fn dedicated_reward_gpus_underutilized() {
        // Fig 6's effect: reward GPUs busy only during the short
        // batched reward phase → single-digit utilization.
        let r = run(&small_sync());
        assert!(r.reward_util < 0.2, "reward util {}", r.reward_util);
        assert!(r.reward_util > 0.0);
    }

    #[test]
    fn env_failures_inflate_reset_phase() {
        let mut clean = small_sync();
        clean.envpool = EnvPoolConfig {
            reset_failure_p: 0.0,
            ..EnvPoolConfig::registry_only()
        };
        let mut faulty = small_sync();
        faulty.envpool = EnvPoolConfig {
            reset_failure_p: 0.3,
            ..EnvPoolConfig::registry_only()
        };
        let rc = run(&clean);
        let rf = run(&faulty);
        let reset_c: f64 = rc.steps.iter().map(|s| s.breakdown.env_reset_s).sum();
        let reset_f: f64 = rf.steps.iter().map(|s| s.breakdown.env_reset_s).sum();
        assert!(reset_f > reset_c * 1.3, "{reset_f} vs {reset_c}");
        assert!(rf.steps.iter().map(|s| s.env_failures).sum::<u64>() > 0);
    }

    #[test]
    fn engine_faults_stall_the_barrier_pipeline() {
        use crate::fault::FaultProfile;
        let clean = run(&small_sync());
        let mut faulty = small_sync();
        faulty.fault = FaultProfile::mtbf(300.0);
        let rf = run(&faulty);
        assert!(rf.faults.engine_failures > 0, "{:?}", rf.faults);
        assert!(
            rf.mean_step_time() > clean.mean_step_time(),
            "{} vs {}",
            rf.mean_step_time(),
            clean.mean_step_time()
        );
        assert!(rf.goodput() < clean.goodput());
        // With faults disabled the run is untouched — the fault branch
        // draws nothing.
        let again = run(&small_sync());
        assert_eq!(again.mean_step_time(), clean.mean_step_time());
        assert_eq!(again.faults.engine_failures, 0);
    }

    #[test]
    fn scheduled_outage_slows_sync_rollout() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        use crate::hw::GpuClass;
        let clean = run(&small_sync());
        let mut faulty = small_sync();
        // Half of every pool gone from t=0: rounds redistribute over
        // the survivors, roughly doubling the generation phase.
        faulty.fault = FaultProfile {
            scheduled: [GpuClass::H800, GpuClass::H20]
                .into_iter()
                .map(|class| ScheduledFault {
                    at_s: 0.0,
                    event: FaultEvent::PoolOutage {
                        class,
                        fraction: 0.5,
                    },
                })
                .collect(),
            ..FaultProfile::none()
        };
        let rf = run(&faulty);
        let gen_c: f64 = clean.steps.iter().map(|s| s.breakdown.generation_s).sum();
        let gen_f: f64 = rf.steps.iter().map(|s| s.breakdown.generation_s).sum();
        assert!(gen_f > 1.5 * gen_c, "{gen_f} vs {gen_c}");
        assert!(rf.mean_step_time() > clean.mean_step_time());
    }

    #[test]
    fn outage_queueing_scales_with_severity() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        use crate::hw::GpuClass;
        let mk = |fraction: f64| {
            let mut s = small_sync();
            s.fault = FaultProfile {
                scheduled: [GpuClass::H800, GpuClass::H20]
                    .into_iter()
                    .map(|class| ScheduledFault {
                        at_s: 0.0,
                        event: FaultEvent::PoolOutage { class, fraction },
                    })
                    .collect(),
                ..FaultProfile::none()
            };
            s
        };
        let gen = |r: &crate::sim::ScenarioResult| -> f64 {
            r.steps.iter().map(|s| s.breakdown.generation_s).sum()
        };
        let clean = run(&small_sync());
        let light = run(&mk(0.25));
        let heavy = run(&mk(0.75));
        // Per-engine queueing: the survivors' queues grow with outage
        // severity, superlinearly past the point where one engine
        // carries most of the batch.
        assert!(gen(&light) > gen(&clean), "{} vs {}", gen(&light), gen(&clean));
        assert!(
            gen(&heavy) > 1.5 * gen(&light),
            "{} vs {}",
            gen(&heavy),
            gen(&light)
        );
    }

    #[test]
    fn unordered_chaos_schedule_applies_in_time_order() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        use crate::hw::GpuClass;
        let outage = ScheduledFault {
            at_s: 1.0,
            event: FaultEvent::PoolOutage {
                class: GpuClass::H800,
                fraction: 1.0,
            },
        };
        let restore = ScheduledFault {
            at_s: 100.0,
            event: FaultEvent::PoolRestore {
                class: GpuClass::H800,
            },
        };
        let mk = |scheduled: Vec<ScheduledFault>| {
            let mut s = small_sync();
            s.fault = FaultProfile {
                scheduled,
                ..FaultProfile::none()
            };
            run(&s)
        };
        // A restore listed *before* the outage it clears must behave
        // identically to the chronological listing.
        let a = mk(vec![outage.clone(), restore.clone()]);
        let b = mk(vec![restore, outage]);
        assert_eq!(a.mean_step_time(), b.mean_step_time());
        assert_eq!(a.faults.engine_failures, b.faults.engine_failures);
    }

    #[test]
    fn outage_engines_counted_once_per_spell() {
        use crate::fault::{FaultEvent, FaultProfile, ScheduledFault};
        use crate::hw::GpuClass;
        let mut s = small_sync();
        s.fault = FaultProfile {
            scheduled: [GpuClass::H800, GpuClass::H20]
                .into_iter()
                .map(|class| ScheduledFault {
                    at_s: 0.0,
                    event: FaultEvent::PoolOutage {
                        class,
                        fraction: 0.25,
                    },
                })
                .collect(),
            ..FaultProfile::none()
        };
        let r = run(&s);
        // scale 0.1 fleet: 6×H800 + 3×H20; a 25% outage downs
        // ceil(1.5)=2 + ceil(0.75)=1 engines, each counted once even
        // though the outage persists across all iterations.
        assert_eq!(r.faults.engine_failures, 3, "{:?}", r.faults);
    }

    #[test]
    fn pd_arm_pays_the_kv_transfer_term() {
        use crate::sim::driver::pd::PdScenario;
        // The analytic formula itself is pinned in
        // `net::shared::tests::balanced_makespan_formula_is_pinned`;
        // here: the sync driver actually charges it, scaled by link
        // quality, and only on the disaggregated arm.
        let other = |r: &crate::sim::ScenarioResult| -> f64 {
            r.steps.iter().map(|s| s.breakdown.other_s).sum()
        };
        let plain = run(&small_sync());
        assert_eq!(other(&plain), 0.0, "no PD: no transfer term");

        let mut pd = small_sync();
        pd.pd = Some(PdScenario::xpyd(1, 1));
        let r_pd = run(&pd);
        assert!(other(&r_pd) > 0.0, "disaggregated PD ships KV every round");
        assert!(r_pd.mean_step_time() > plain.mean_step_time());

        // An undersized link (1 slot, 0.1 GB/s) inflates the term.
        let mut slow = small_sync();
        let mut p = PdScenario::xpyd(1, 1);
        p.kv_link.effective_bytes_per_s = 1e8;
        p.kv_slots = 1;
        slow.pd = Some(p);
        let r_slow = run(&slow);
        assert!(
            other(&r_slow) > 10.0 * other(&r_pd),
            "{} vs {}",
            other(&r_slow),
            other(&r_pd)
        );

        // The colocated ablation arm ships no KV.
        let mut colo = small_sync();
        colo.pd = Some(PdScenario::colocated_baseline(1, 1));
        let r_colo = run(&colo);
        assert_eq!(other(&r_colo), 0.0);
        // Generation time itself is untouched by the PD term (same
        // engines, same rounds).
        let gen = |r: &crate::sim::ScenarioResult| -> f64 {
            r.steps.iter().map(|s| s.breakdown.generation_s).sum()
        };
        assert_eq!(gen(&plain), gen(&r_pd));
    }

    #[test]
    fn non_default_weight_plane_swaps_the_sync_term() {
        use crate::weights::{SyncStrategyKind, WeightsScenario};
        let legacy = run(&small_sync());
        let mut cfg = small_sync();
        cfg.weights = WeightsScenario::with_strategy(SyncStrategyKind::RollingSubset { k: 2 });
        let r = run(&cfg);
        let sync = |r: &crate::sim::ScenarioResult| r.steps[0].breakdown.weight_sync_s;
        // The analytic fan-out term replaces the legacy NCCL reshard,
        // pinned against the formula the async drivers' link model
        // reduces to for a simultaneous fleet-wide burst.
        let n: usize = cfg.gen_pools.iter().map(|p| p.engines).sum();
        let expect = cfg.weights.analytic_fleet_sync_s(&cfg.model, n) + 2.0;
        assert!((sync(&r) - expect).abs() < 1e-9, "{} vs {expect}", sync(&r));
        assert_ne!(sync(&legacy), sync(&r));
        // The monolith's sync is fully exposed: no overlap, whole fleet
        // offline through it.
        assert_eq!(r.weights.publishes, 3);
        assert!(r.weights.exposed_stall_s > 0.0);
        assert_eq!(r.weights.overlap_ratio(), 0.0);
        assert_eq!(r.weights.engine_syncs, (n * 3) as u64);
        // The Mooncake-plane arm fills the analytic bucket breakdown;
        // the legacy NCCL reshard is not bucketized and leaves it zero.
        let store = crate::mooncake::MooncakeStore::default();
        let bytes = cfg.model.weight_bytes();
        assert!(
            (r.weights.buckets.push_s - 3.0 * store.push_time(bytes)).abs() < 1e-6,
            "{:?}",
            r.weights.buckets
        );
        assert_eq!(r.weights.buckets.engine_pulls, (n * 3) as u64);
        assert!(r.weights.buckets.naive_s > r.weights.buckets.push_s);
        assert_eq!(legacy.weights.buckets, crate::weights::BucketBreakdown::default());
        // The legacy default also fills the report (for the benches).
        assert_eq!(legacy.weights.publishes, 3);
        assert!(legacy.weights.exposed_stall_s > 0.0);
    }

    #[test]
    fn train_class_threads_through_the_monolith() {
        let fast = run(&small_sync());
        let mut cfg = small_sync();
        cfg.train_class = crate::hw::GpuClass::H20;
        let slow = run(&cfg);
        let t = |r: &crate::sim::ScenarioResult| r.steps[0].breakdown.train_s;
        // Training is compute-bound: the bandwidth-optimized class must
        // pay for its thin FLOPs.
        assert!(t(&slow) > t(&fast), "{} vs {}", t(&slow), t(&fast));
    }

    #[test]
    fn trace_timeline_sums_to_total_time() {
        let cfg = small_sync();
        let mut rec = TraceRecorder::enabled();
        let r = run_with_trace(&cfg, &mut rec);
        // One flat timeline on the driver pid; spans sum to the clock
        // exactly (phase durations come from the same breakdown).
        let span_sum: f64 = rec
            .events()
            .iter()
            .filter(|e| e.ph == 'X')
            .map(|e| e.dur_s)
            .sum();
        assert!((span_sum - r.total_time_s).abs() < 1e-9, "{span_sum} vs {}", r.total_time_s);
        // Spans never overlap: each starts at or after the previous end.
        let mut end = 0.0f64;
        for e in rec.events().iter().filter(|e| e.ph == 'X') {
            assert!(e.start_s >= end - 1e-9, "{} starts before {end}", e.name);
            end = e.start_s + e.dur_s;
        }
        // Tracing leaves the result untouched.
        assert_eq!(r, run(&cfg));
    }

    #[test]
    fn generation_not_overwhelmingly_dominant() {
        // Fig 3's point: generation is only ~half the successful step.
        let r = run(&small_sync());
        let s = &r.steps[1];
        let frac = s.breakdown.fraction("generation");
        assert!(frac < 0.9, "generation fraction {frac}");
        assert!(frac > 0.05, "generation fraction {frac}");
    }
}
