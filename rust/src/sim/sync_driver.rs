//! Phase-structured driver for the monolithic synchronous baseline.
//!
//! Models the paper's Sync pipeline (§7.1, Fig 2-Left, Fig 3):
//!
//! 1. `env.reset` for the whole batch — a barrier over the slowest
//!    container (failures burn the detection timeout, then retry);
//! 2. *batched* rollout rounds (Fig 5b): every surviving trajectory
//!    generates, then every environment steps; the round ends at the
//!    slowest member;
//! 3. batched reward on dedicated GPUs after all rollouts finish;
//! 4. blocking weight synchronization;
//! 5. blocking training.
//!
//! Reward/generation utilization and the Fig 3 component breakdown fall
//! out of the phase times directly.

use super::{RewardDeploy, Scenario, ScenarioResult, StepStats};
use crate::coordinator::GroupTracker;
use crate::env::profile::{DomainProfile, TrajectoryShape};
use crate::hw::phase_time;
use crate::metrics::StepBreakdown;
use crate::net::NVLINK_INTRA;
use crate::proxy::{EngineSim, SimRequest};
use crate::rl::TrajectoryId;
use crate::simkit::SimRng;

use super::TRAIN_OVERHEAD;

/// Run the synchronous scenario.
pub fn run(cfg: &Scenario) -> ScenarioResult {
    let root = SimRng::new(cfg.seed);
    let mut result = ScenarioResult::default();
    let mut reward_busy = 0.0;
    let mut gen_busy = 0.0;
    let mut clock = 0.0;

    // Engine fleet (no affinity in the Sync baseline: whole pool).
    let mut engines: Vec<EngineSim> = Vec::new();
    let mut eid = 0;
    for pool in &cfg.gen_pools {
        for _ in 0..pool.engines {
            engines.push(EngineSim::new(
                eid,
                pool.class,
                pool.gpus_per_engine,
                cfg.model.clone(),
                pool.max_batch,
            ));
            eid += 1;
        }
    }
    assert!(!engines.is_empty());

    for iter in 0..cfg.iterations {
        let mut rng = root.stream("iter", iter as u64);
        let mut breakdown = StepBreakdown::default();
        let mut env_failures = 0u64;

        // ---- sample the batch's trajectory shapes -------------------
        let mut groups = GroupTracker::new();
        let mut shapes: Vec<TrajectoryShape> = Vec::new();
        let n_groups = cfg.batch_size / cfg.group_size;
        for g in 0..n_groups {
            groups.add_group(g as u64, cfg.group_size);
            let domain = *rng.choose(&cfg.task_mix);
            let profile = DomainProfile::of(domain);
            for m in 0..cfg.group_size {
                let id = (g * cfg.group_size + m) as u64;
                shapes.push(profile.sample_trajectory(&mut rng));
                groups.launch(g as u64, TrajectoryId(id));
            }
        }
        let n = shapes.len();

        // ---- phase 1: batched env.reset (barrier at slowest) --------
        let mut reset_max: f64 = 0.0;
        for i in 0..n {
            let mut r = rng.stream("reset", i as u64);
            let mut t = 0.0;
            loop {
                let o = cfg.envpool.sample_reset(n, &mut r);
                t += o.latency_s;
                if !o.failed {
                    break;
                }
                env_failures += 1;
            }
            reset_max = reset_max.max(t);
        }
        breakdown.env_reset_s = reset_max;

        // ---- phase 2: batched rollout rounds ------------------------
        let max_turns = shapes.iter().map(|s| s.turns()).max().unwrap_or(0);
        let mut gen_time = 0.0;
        let mut env_time = 0.0;
        let mut ctx: Vec<f64> = shapes.iter().map(|_| 0.0).collect();
        for turn in 0..max_turns {
            // generation: active trajectories spread across engines.
            let mut active = 0;
            for (i, s) in shapes.iter().enumerate() {
                if turn < s.turns() {
                    let (obs, act) = s.per_turn[turn];
                    let new = if turn == 0 {
                        s.initial_prompt_tokens + obs
                    } else {
                        obs
                    };
                    let e = active % engines.len();
                    engines[e].enqueue(SimRequest {
                        traj: TrajectoryId(i as u64),
                        domain: s.domain,
                        new_tokens: new,
                        ctx_tokens: ctx[i],
                        decode_budget: act,
                    });
                    ctx[i] += new + act;
                    active += 1;
                }
            }
            if active == 0 {
                break;
            }
            // batched: the round lasts as long as the slowest engine.
            let round: f64 = engines
                .iter_mut()
                .map(|e| e.run_to_idle().0)
                .fold(0.0, f64::max);
            gen_time += round;

            // env round: barrier at the slowest environment step.
            let mut step_max: f64 = 0.0;
            for (i, s) in shapes.iter().enumerate() {
                if turn < s.turns() {
                    let mut r = rng.stream("step", (turn * n + i) as u64);
                    let lat = match &cfg.env_step_override {
                        Some(d) => d.sample(&mut r),
                        None => cfg.envpool.sample_step(s.domain, &mut r),
                    };
                    step_max = step_max.max(lat);
                }
            }
            env_time += step_max;
        }
        breakdown.generation_s = gen_time;
        breakdown.env_step_s = env_time;
        gen_busy += gen_time;

        // ---- phase 3: batched reward ---------------------------------
        let reward_time = match &cfg.reward {
            RewardDeploy::DedicatedGpus { gpus, exec_s } => {
                // n calls queued over `gpus` servers.
                let total: f64 = (0..n)
                    .map(|i| exec_s.sample(&mut rng.stream("reward", i as u64)))
                    .sum();
                total / (*gpus as f64)
            }
            RewardDeploy::Serverless { exec_s } => {
                // still batched at the end in Sync, but elastic: the
                // platform fans out, so the phase lasts ~one call.
                let max: f64 = (0..n)
                    .map(|i| exec_s.sample(&mut rng.stream("reward", i as u64)))
                    .fold(0.0, f64::max);
                max
            }
        };
        breakdown.reward_s = reward_time;
        reward_busy += match &cfg.reward {
            RewardDeploy::DedicatedGpus { .. } => reward_time,
            RewardDeploy::Serverless { .. } => 0.0,
        };

        // ---- phase 4: blocking weight sync ---------------------------
        // Colocated monolith: NCCL reshard between training and rollout
        // processes over NVLink (fast but blocking).
        let sync_time = NVLINK_INTRA.transfer_time(cfg.model.weight_bytes()) + 2.0;
        breakdown.weight_sync_s = sync_time;

        // ---- phase 5: blocking training ------------------------------
        let batch_tokens: f64 = shapes.iter().map(|s| s.total_tokens()).sum();
        let t_cost = cfg.model.train_cost(
            batch_tokens,
            shapes.iter().map(|s| s.final_context()).sum::<f64>() / n as f64,
        );
        let train_time = phase_time(
            &t_cost,
            crate::hw::GpuClass::H800.spec(),
            cfg.train_gpus.max(1),
        ) * TRAIN_OVERHEAD;
        breakdown.train_s = train_time;

        let step_time = breakdown.total();
        clock += step_time;
        result.steps.push(StepStats {
            step_time_s: step_time,
            breakdown,
            batch_tokens,
            mean_staleness: 0.0,
            stale_aborts: 0,
            redundant_aborts: 0,
            env_failures,
        });
    }

    result.total_time_s = clock;
    if clock > 0.0 {
        result.reward_util = match &cfg.reward {
            RewardDeploy::DedicatedGpus { .. } => reward_busy / clock,
            RewardDeploy::Serverless { .. } => 1.0, // elastic: busy only when invoked
        };
        result.gen_util = gen_busy / clock;
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envpool::EnvPoolConfig;
    use crate::llm::QWEN3_8B;
    use crate::sim::{Mode, Scenario};
    use crate::simkit::dist::Dist;

    fn small_sync() -> Scenario {
        let mut s = Scenario::rollart_default(QWEN3_8B.clone(), 0.1);
        s.mode = Mode::Sync;
        s.batch_size = 32;
        s.iterations = 3;
        s.reward = RewardDeploy::DedicatedGpus {
            gpus: 4,
            exec_s: Dist::Constant(2.0),
        };
        s
    }

    #[test]
    fn produces_iterations_with_positive_components() {
        let r = run(&small_sync());
        assert_eq!(r.steps.len(), 3);
        for s in &r.steps {
            assert!(s.step_time_s > 0.0);
            assert!(s.breakdown.generation_s > 0.0);
            assert!(s.breakdown.env_reset_s > 0.0);
            assert!(s.breakdown.train_s > 0.0);
            assert!(s.batch_tokens > 0.0);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = run(&small_sync());
        let b = run(&small_sync());
        assert_eq!(a.mean_step_time(), b.mean_step_time());
        let mut c = small_sync();
        c.seed += 1;
        let d = run(&c);
        assert_ne!(a.mean_step_time(), d.mean_step_time());
    }

    #[test]
    fn dedicated_reward_gpus_underutilized() {
        // Fig 6's effect: reward GPUs busy only during the short
        // batched reward phase → single-digit utilization.
        let r = run(&small_sync());
        assert!(r.reward_util < 0.2, "reward util {}", r.reward_util);
        assert!(r.reward_util > 0.0);
    }

    #[test]
    fn env_failures_inflate_reset_phase() {
        let mut clean = small_sync();
        clean.envpool = EnvPoolConfig {
            reset_failure_p: 0.0,
            ..EnvPoolConfig::registry_only()
        };
        let mut faulty = small_sync();
        faulty.envpool = EnvPoolConfig {
            reset_failure_p: 0.3,
            ..EnvPoolConfig::registry_only()
        };
        let rc = run(&clean);
        let rf = run(&faulty);
        let reset_c: f64 = rc.steps.iter().map(|s| s.breakdown.env_reset_s).sum();
        let reset_f: f64 = rf.steps.iter().map(|s| s.breakdown.env_reset_s).sum();
        assert!(reset_f > reset_c * 1.3, "{reset_f} vs {reset_c}");
        assert!(rf.steps.iter().map(|s| s.env_failures).sum::<u64>() > 0);
    }

    #[test]
    fn generation_not_overwhelmingly_dominant() {
        // Fig 3's point: generation is only ~half the successful step.
        let r = run(&small_sync());
        let s = &r.steps[1];
        let frac = s.breakdown.fraction("generation");
        assert!(frac < 0.9, "generation fraction {frac}");
        assert!(frac > 0.05, "generation fraction {frac}");
    }
}
