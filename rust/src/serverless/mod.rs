//! Serverless platform (simulated): elastic, scale-to-zero function
//! execution for stateless reward computation (R3).
//!
//! Models the properties the paper's results depend on:
//! * cold starts when no warm instance is available,
//! * autoscaling to the offered concurrency,
//! * scale-to-zero after an idle timeout (reclaiming the GPU budget
//!   that dedicated reward GPUs waste at 6–7.4% utilization, Fig 6/12),
//! * per-call I/O overhead (§7.5: ≤5.2 MB payloads, mean 0.01 s /
//!   max 2.1 s per call).
//!
//! The simulation is event-driven but self-contained: callers ask
//! "when does an invocation issued at `t` complete?" and the platform
//! tracks instance lifecycles internally.

use crate::net::jittered_small_transfer;
use crate::simkit::dist::Dist;
use crate::simkit::SimRng;

#[derive(Clone, Debug)]
pub struct ServerlessConfig {
    /// Cold-start latency (sandbox provision + runtime init).
    pub cold_start_s: f64,
    /// Idle seconds before a warm instance is reclaimed.
    pub idle_timeout_s: f64,
    /// Hard cap on concurrent instances (platform quota).
    pub max_instances: usize,
    /// Per-call network I/O overhead distribution (§7.5).
    pub io_overhead: Dist,
}

impl Default for ServerlessConfig {
    fn default() -> Self {
        ServerlessConfig {
            cold_start_s: 1.5,
            idle_timeout_s: 60.0,
            max_instances: 512,
            // §7.5 serverless reward I/O: mean 0.01 s, max 2.1 s.
            io_overhead: jittered_small_transfer(0.01, 2.1),
        }
    }
}

/// One warm (or provisioning) instance.
#[derive(Clone, Copy, Debug)]
struct Instance {
    /// Instance is busy until this time.
    busy_until: f64,
    /// Last time the instance finished work (for idle reclaim).
    idle_since: f64,
    /// Provisioning time (for instance-lifetime utilization, Fig 12).
    created_at: f64,
    /// Busy seconds accumulated on this instance.
    busy_s: f64,
}

/// The platform: tracks instances and serves invocations.
#[derive(Clone, Debug)]
pub struct ServerlessPlatform {
    cfg: ServerlessConfig,
    instances: Vec<Instance>,
    /// Completed invocation count and accumulated stats.
    pub invocations: u64,
    pub cold_starts: u64,
    pub total_exec_s: f64,
    pub total_io_s: f64,
    /// Lifetime seconds of already-reclaimed instances and their busy
    /// seconds — the basis of instance-level utilization (Fig 12: a
    /// well-packed serverless fleet runs hot, unlike dedicated GPUs).
    reclaimed_lifetime_s: f64,
    reclaimed_busy_s: f64,
}

/// Outcome of a single invocation.
#[derive(Clone, Copy, Debug)]
pub struct Invocation {
    pub start_s: f64,
    pub done_s: f64,
    pub cold_start: bool,
    pub io_s: f64,
}

impl ServerlessPlatform {
    pub fn new(cfg: ServerlessConfig) -> Self {
        ServerlessPlatform {
            cfg,
            instances: Vec::new(),
            invocations: 0,
            cold_starts: 0,
            total_exec_s: 0.0,
            total_io_s: 0.0,
            reclaimed_lifetime_s: 0.0,
            reclaimed_busy_s: 0.0,
        }
    }

    /// Reclaim instances idle past the timeout as of time `t`.
    fn reclaim(&mut self, t: f64) {
        let timeout = self.cfg.idle_timeout_s;
        let mut freed_life = 0.0;
        let mut freed_busy = 0.0;
        self.instances.retain(|i| {
            let keep = i.busy_until > t || t - i.idle_since < timeout;
            if !keep {
                freed_life += (i.idle_since + timeout) - i.created_at;
                freed_busy += i.busy_s;
            }
            keep
        });
        self.reclaimed_lifetime_s += freed_life;
        self.reclaimed_busy_s += freed_busy;
    }

    /// Instance-level utilization so far: busy seconds over provisioned
    /// instance-lifetime seconds (live instances counted up to `t`).
    pub fn utilization(&mut self, t: f64) -> f64 {
        self.reclaim(t);
        let mut life = self.reclaimed_lifetime_s;
        let mut busy = self.reclaimed_busy_s;
        for i in &self.instances {
            life += (t.max(i.created_at)) - i.created_at;
            busy += i.busy_s - (i.busy_until - t).max(0.0);
        }
        if life <= 0.0 {
            0.0
        } else {
            (busy / life).clamp(0.0, 1.0)
        }
    }

    /// Current warm instance count (after reclaim at `t`).
    pub fn warm_instances(&mut self, t: f64) -> usize {
        self.reclaim(t);
        self.instances.len()
    }

    /// Invoke a function at time `t` with execution time `exec_s`.
    /// Returns the completion schedule; the platform autoscales by
    /// provisioning a new instance (cold start) when all warm ones are
    /// busy and the quota allows.
    pub fn invoke(&mut self, t: f64, exec_s: f64, rng: &mut SimRng) -> Invocation {
        self.reclaim(t);
        let io = self.cfg.io_overhead.sample(rng);
        self.invocations += 1;
        self.total_exec_s += exec_s;
        self.total_io_s += io;

        // Prefer the warm instance that frees up soonest.
        let can_scale = self.instances.len() < self.cfg.max_instances;
        let best = self
            .instances
            .iter_mut()
            .min_by(|a, b| a.busy_until.partial_cmp(&b.busy_until).unwrap());

        match best {
            Some(inst) if inst.busy_until <= t || !can_scale => {
                // Warm start (or forced queue when at quota).
                let start = inst.busy_until.max(t) + io;
                let done = start + exec_s;
                inst.busy_until = done;
                inst.idle_since = done;
                inst.busy_s += exec_s;
                Invocation {
                    start_s: start,
                    done_s: done,
                    cold_start: false,
                    io_s: io,
                }
            }
            _ => {
                // Cold start a new instance.
                self.cold_starts += 1;
                let start = t + self.cfg.cold_start_s + io;
                let done = start + exec_s;
                self.instances.push(Instance {
                    busy_until: done,
                    idle_since: done,
                    created_at: t,
                    busy_s: exec_s,
                });
                Invocation {
                    start_s: start,
                    done_s: done,
                    cold_start: true,
                    io_s: io,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn platform() -> (ServerlessPlatform, SimRng) {
        let mut cfg = ServerlessConfig::default();
        cfg.io_overhead = Dist::Constant(0.01);
        (ServerlessPlatform::new(cfg), SimRng::new(0))
    }

    #[test]
    fn first_call_cold_starts() {
        let (mut p, mut rng) = platform();
        let inv = p.invoke(0.0, 1.0, &mut rng);
        assert!(inv.cold_start);
        assert!((inv.done_s - (1.5 + 0.01 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn warm_reuse_after_completion() {
        let (mut p, mut rng) = platform();
        let a = p.invoke(0.0, 1.0, &mut rng);
        let b = p.invoke(a.done_s + 0.1, 1.0, &mut rng);
        assert!(!b.cold_start);
        assert!(b.done_s < a.done_s + 0.1 + 1.5 + 1.0); // no cold start
    }

    #[test]
    fn concurrent_burst_autoscales() {
        let (mut p, mut rng) = platform();
        // 10 simultaneous invocations -> 10 instances
        for _ in 0..10 {
            p.invoke(0.0, 5.0, &mut rng);
        }
        assert_eq!(p.warm_instances(1.0), 10);
        assert_eq!(p.cold_starts, 10);
    }

    #[test]
    fn scale_to_zero_after_idle() {
        let (mut p, mut rng) = platform();
        p.invoke(0.0, 1.0, &mut rng);
        assert_eq!(p.warm_instances(10.0), 1);
        // after idle timeout, reclaimed
        assert_eq!(p.warm_instances(200.0), 0);
        // next call cold-starts again
        let inv = p.invoke(200.0, 1.0, &mut rng);
        assert!(inv.cold_start);
    }

    #[test]
    fn quota_queues_instead_of_scaling() {
        let mut cfg = ServerlessConfig::default();
        cfg.max_instances = 2;
        cfg.io_overhead = Dist::Constant(0.0);
        let mut p = ServerlessPlatform::new(cfg);
        let mut rng = SimRng::new(1);
        let a = p.invoke(0.0, 10.0, &mut rng);
        let b = p.invoke(0.0, 10.0, &mut rng);
        let c = p.invoke(0.0, 10.0, &mut rng); // queued behind a or b
        assert!(a.cold_start && b.cold_start);
        assert!(!c.cold_start);
        assert!(c.start_s >= a.done_s.min(b.done_s));
        assert_eq!(p.warm_instances(1.0), 2);
    }

    #[test]
    fn io_overhead_accumulates() {
        let (mut p, mut rng) = platform();
        for i in 0..5 {
            p.invoke(i as f64 * 10.0, 0.5, &mut rng);
        }
        assert!((p.total_io_s - 0.05).abs() < 1e-9);
        assert_eq!(p.invocations, 5);
    }
}
