//! Generation engine over the AOT artifacts.
//!
//! The artifacts are compiled at a fixed batch width `B`
//! (shapes.py `batch`); the engine exposes turn-level generation for up
//! to `B` prompts at once: one `prefill` call builds the KV caches,
//! then `decode_step` advances every live slot one token per call until
//! all slots emit a stop token or exhaust the budget.  Sampling is
//! temperature softmax with an optional greedy mode, seeded by
//! [`SimRng`] for reproducibility.

use crate::env::tokenizer::{EOS, PAD, SEP};
use crate::runtime::{Params, Runtime};
use crate::simkit::SimRng;
use anyhow::{bail, Result};

/// Sampling configuration.
#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f32,
    pub greedy: bool,
}

impl Default for SampleCfg {
    fn default() -> Self {
        SampleCfg {
            temperature: 0.8,
            greedy: false,
        }
    }
}

/// Fixed-width generation engine.
pub struct GenEngine<'r> {
    rt: &'r Runtime,
    pub sample: SampleCfg,
    rng: SimRng,
    /// Engine steps executed (decode calls), for perf accounting.
    pub decode_calls: u64,
    pub prefill_calls: u64,
}

impl<'r> GenEngine<'r> {
    pub fn new(rt: &'r Runtime, seed: u64) -> Self {
        GenEngine {
            rt,
            sample: SampleCfg::default(),
            rng: SimRng::new(seed),
            decode_calls: 0,
            prefill_calls: 0,
        }
    }

    fn batch(&self) -> usize {
        self.rt.manifest.model.batch
    }

    fn max_seq(&self) -> usize {
        self.rt.manifest.model.max_seq
    }

    fn sample_token(&mut self, logits: &[f32]) -> i32 {
        debug_assert_eq!(logits.len(), self.rt.manifest.model.vocab);
        if self.sample.greedy {
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(EOS);
        }
        let t = self.sample.temperature.max(1e-3);
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> = logits
            .iter()
            .map(|&l| (((l - max) / t) as f64).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut pick = self.rng.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            pick -= w;
            if pick <= 0.0 {
                return i as i32;
            }
        }
        (weights.len() - 1) as i32
    }

    /// Generate one turn's action for up to `batch()` prompts.
    ///
    /// `prompts[i]` is slot i's full prompt (token ids); empty slots
    /// beyond `prompts.len()` are padded internally.  Returns one
    /// generated token sequence per prompt (stop tokens excluded).
    pub fn generate(
        &mut self,
        params: &Params,
        prompts: &[Vec<i32>],
        max_new_tokens: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let b = self.batch();
        let s = self.max_seq();
        if prompts.is_empty() || prompts.len() > b {
            bail!("prompt count {} out of range 1..={b}", prompts.len());
        }
        for (i, p) in prompts.iter().enumerate() {
            if p.is_empty() || p.len() >= s {
                bail!("prompt {i} length {} out of range", p.len());
            }
        }

        // Pack into the fixed-width batch.
        let mut tokens = vec![PAD; b * s];
        let mut lengths = vec![1i32; b]; // dummy slots hold 1 PAD token
        for (i, p) in prompts.iter().enumerate() {
            tokens[i * s..i * s + p.len()].copy_from_slice(p);
            lengths[i] = p.len() as i32;
        }

        let (mut logits, mut cache) = self.rt.prefill(params, &tokens, &lengths)?;
        self.prefill_calls += 1;
        // Perf L3-1: keep parameters device-resident for the decode
        // loop instead of re-uploading ~18 MB per step.
        let dev_params = self.rt.upload_params(params)?;

        let vocab = self.rt.manifest.model.vocab;
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut live: Vec<bool> = (0..b).map(|i| i < prompts.len()).collect();
        let budget = max_new_tokens.min(s - 1);

        for _ in 0..budget {
            // Sample the next token per live slot.
            let mut next = vec![PAD; b];
            for (slot, alive) in live.iter().enumerate().take(b) {
                if !alive {
                    continue;
                }
                let tok = self.sample_token(&logits[slot * vocab..(slot + 1) * vocab]);
                next[slot] = tok;
            }
            // Stop bookkeeping (before feeding: stop tokens are not
            // appended to the action).
            let mut any_live = false;
            for slot in 0..prompts.len() {
                if !live[slot] {
                    continue;
                }
                let tok = next[slot];
                if tok == EOS || tok == SEP || lengths[slot] as usize >= s - 1 {
                    live[slot] = false;
                } else {
                    out[slot].push(tok);
                    any_live = true;
                }
            }
            if !any_live {
                break;
            }
            // Dead slots keep feeding PAD (their outputs are ignored;
            // the cache write at their frozen position is harmless).
            logits = self
                .rt
                .decode_step_device(&dev_params, &mut cache, &next, &mut lengths)?;
            self.decode_calls += 1;
        }
        Ok(out)
    }
}
