//! Real execution harness: the coordinator running against the PJRT
//! runtime and live Rust environments (Python never on this path).
//!
//! Mirrors the control-plane flow of §6 at laptop scale: a
//! [`GenEngine`] plays the inference worker (fixed-width continuous
//! batch over the AOT `prefill`/`decode_step` artifacts), EnvManagers
//! drive real [`crate::env`] environments per trajectory, rewards come
//! from in-process "serverless" handlers, and the trainer consumes
//! GRPO groups through the same [`crate::buffer::SampleBuffer`] +
//! staleness machinery the DES uses.  `examples/e2e_train.rs` runs the
//! full loop and logs the loss/reward curves (EXPERIMENTS.md §E2E).

mod engine;
mod trainer;

pub use engine::GenEngine;
pub use trainer::{train, StepLog, TrainConfig};
