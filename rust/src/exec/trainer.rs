//! The real GRPO training loop: coordinator + PJRT runtime + live envs.
//!
//! One step = collect `groups_per_step` GRPO groups (each group: the
//! same task seed rolled out `batch` times), score them through the
//! in-process serverless reward handler, push them through the
//! [`SampleBuffer`] (the same staleness machinery as the DES), compute
//! old log-probs with the `logprob` artifact, and run fused
//! `train_step` micro-batches.  Returns a per-step log for
//! EXPERIMENTS.md §E2E.

use crate::buffer::{SampleBuffer, StalenessPolicy};
use crate::cluster::ServerlessHandler;
use crate::env::tokenizer::{build_prompt, decode as tok_decode};
use crate::env::{Environment, Observation};
use crate::exec::GenEngine;
use crate::rl::{group_advantages, pack_sample, Trajectory, TrajectoryId, Turn, Version};
use crate::runtime::{Runtime, TrainState};
use anyhow::Result;
use std::time::Instant;

/// Configuration of the real training loop.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// GRPO groups collected per training step (group size = the
    /// engine batch width, shapes.py `batch`).
    pub groups_per_step: usize,
    pub steps: usize,
    pub lr: f32,
    pub max_new_tokens: usize,
    pub max_turns: usize,
    pub temperature: f32,
    pub alpha: u64,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            groups_per_step: 1,
            steps: 50,
            lr: 1e-3,
            max_new_tokens: 8,
            max_turns: 1,
            temperature: 1.0,
            alpha: 1,
            seed: 0,
        }
    }
}

/// One step's log line.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub entropy: f32,
    pub grad_norm: f32,
    pub mean_reward: f64,
    pub trajectories: usize,
    pub action_tokens: usize,
    pub rollout_s: f64,
    pub train_s: f64,
}

/// Roll out one GRPO group: the same task seed, `width` samples.
#[allow(clippy::too_many_arguments)]
fn rollout_group(
    rt: &Runtime,
    engine: &mut GenEngine,
    state: &TrainState,
    make_env: &dyn Fn() -> Box<dyn Environment>,
    task_seed: u64,
    group: u64,
    version: Version,
    cfg: &TrainConfig,
    next_id: &mut u64,
) -> Result<Vec<(Trajectory, f64)>> {
    let width = rt.manifest.model.batch;
    let budget = rt.manifest.model.max_seq - cfg.max_new_tokens - 2;

    let mut envs: Vec<Box<dyn Environment>> = (0..width).map(|_| make_env()).collect();
    let mut histories: Vec<Vec<(String, String)>> = vec![Vec::new(); width];
    let mut obs: Vec<Observation> = envs.iter_mut().map(|e| e.reset(task_seed)).collect();
    let mut trajs: Vec<Trajectory> = (0..width)
        .map(|_| {
            let id = TrajectoryId(*next_id);
            *next_id += 1;
            let mut t = Trajectory::new(id, envs[0].domain(), version);
            t.group = group;
            t
        })
        .collect();
    let mut rewards = vec![0.0f64; width];
    let mut done = vec![false; width];

    for _turn in 0..cfg.max_turns {
        let live: Vec<usize> = (0..width).filter(|&i| !done[i]).collect();
        if live.is_empty() {
            break;
        }
        // Build prompts for live slots (trajectory-level: each slot has
        // its own history/obs).
        let prompts: Vec<Vec<i32>> = live
            .iter()
            .map(|&i| build_prompt(&histories[i], &obs[i].text, budget))
            .collect();
        let actions = engine.generate(&state.params, &prompts, cfg.max_new_tokens)?;

        for (k, &i) in live.iter().enumerate() {
            let action_text = tok_decode(&actions[k]);
            // Record the turn with the *new* prompt tokens this turn
            // contributed (the observation text).
            trajs[i].turns.push(Turn {
                obs_tokens: crate::env::tokenizer::encode(&obs[i].text),
                action_tokens: actions[k].clone(),
                version,
            });
            let next = envs[i].step(&action_text);
            histories[i].push((obs[i].text.clone(), action_text));
            if next.done {
                done[i] = true;
                rewards[i] = next.reward;
            }
            obs[i] = next;
        }
    }

    // Unfinished trajectories get reward 0 (out of budget).
    Ok(trajs.into_iter().zip(rewards).collect())
}

/// Run the full loop; `make_env` builds one environment instance.
pub fn train(
    rt: &Runtime,
    cfg: &TrainConfig,
    make_env: &dyn Fn() -> Box<dyn Environment>,
) -> Result<(Vec<StepLog>, TrainState)> {
    let mut state = rt.init_train_state()?;
    let mut engine = GenEngine::new(rt, cfg.seed ^ 0x5eed);
    engine.sample.temperature = cfg.temperature;
    let mut buffer = SampleBuffer::new(cfg.alpha, StalenessPolicy::PerTurn);
    // The reward stage as a serverless handler (R3's shape: a stateless
    // function behind a URL; in-process here).
    let mut reward_fn: ServerlessHandler<f64, f64> =
        ServerlessHandler::new("fc://local/reward", |r: f64| r);

    let m = rt.manifest.model.clone();
    let mut logs = Vec::new();
    let mut next_id = 0u64;

    for step in 0..cfg.steps {
        let version = Version(step as u64);
        let t0 = Instant::now();

        // ---- rollout: collect groups ---------------------------------
        let mut all: Vec<(Trajectory, f64)> = Vec::new();
        for g in 0..cfg.groups_per_step {
            let task_seed = cfg.seed
                .wrapping_mul(31)
                .wrapping_add((step * cfg.groups_per_step + g) as u64);
            let group = rollout_group(
                rt,
                &mut engine,
                &state,
                make_env,
                task_seed,
                g as u64,
                version,
                cfg,
                &mut next_id,
            )?;
            all.extend(group);
        }
        let rollout_s = t0.elapsed().as_secs_f64();

        // ---- reward + advantages (per group) --------------------------
        let width = m.batch;
        let mut packed = Vec::new();
        let mut reward_sum = 0.0;
        for chunk in all.chunks_mut_helper(width) {
            let rewards: Vec<f64> = chunk.iter().map(|(_, r)| reward_fn.invoke(*r)).collect();
            reward_sum += rewards.iter().sum::<f64>();
            let advs = group_advantages(&rewards);
            for ((traj, r), adv) in chunk.iter_mut().zip(advs) {
                traj.reward = Some(*r);
                buffer.deposit(traj.clone(), version);
                packed.push(pack_sample(traj, adv, m.train_seq));
            }
        }
        let mean_reward = reward_sum / all.len() as f64;

        // ---- drain through the buffer (staleness machinery) -----------
        let batch = buffer
            .get_batch(packed.len().min(buffer.len()), version)
            .unwrap_or_default();
        debug_assert_eq!(batch.len(), packed.len());

        // ---- train micro-batches --------------------------------------
        let t1 = Instant::now();
        let mut loss = 0.0;
        let mut entropy = 0.0;
        let mut grad_norm = 0.0;
        let mut micro = 0;
        let mut action_tokens = 0usize;
        for mb in packed.chunks(m.train_batch) {
            if mb.len() < m.train_batch {
                break; // drop ragged tail (fixed-shape artifact)
            }
            let mut tokens = Vec::with_capacity(m.train_batch * m.train_seq);
            let mut adv = Vec::with_capacity(tokens.capacity());
            let mut mask = Vec::with_capacity(tokens.capacity());
            for s in mb {
                tokens.extend_from_slice(&s.tokens);
                adv.extend_from_slice(&s.adv);
                mask.extend_from_slice(&s.mask);
                action_tokens += s.mask.iter().filter(|&&x| x > 0.0).count();
            }
            // Old log-probs under the *current* (pre-update) weights.
            let old = rt.logprob(&state.params, &tokens)?;
            let metrics = rt.train_step(&mut state, cfg.lr, &tokens, &old, &adv, &mask)?;
            loss += metrics.loss;
            entropy += metrics.entropy;
            grad_norm += metrics.grad_norm;
            micro += 1;
        }
        let train_s = t1.elapsed().as_secs_f64();
        let n = micro.max(1) as f32;

        logs.push(StepLog {
            step,
            loss: loss / n,
            entropy: entropy / n,
            grad_norm: grad_norm / n,
            mean_reward,
            trajectories: all.len(),
            action_tokens,
            rollout_s,
            train_s,
        });
    }
    Ok((logs, state))
}

/// Chunking helper that yields mutable slices (std `chunks_mut` via a
/// tiny extension trait so the call site stays readable).
trait ChunksMutHelper<T> {
    fn chunks_mut_helper(&mut self, size: usize) -> std::slice::ChunksMut<'_, T>;
}

impl<T> ChunksMutHelper<T> for Vec<T> {
    fn chunks_mut_helper(&mut self, size: usize) -> std::slice::ChunksMut<'_, T> {
        self.chunks_mut(size)
    }
}
