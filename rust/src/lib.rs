//! # RollArt — disaggregated multi-task agentic RL training
//!
//! Reproduction of *ROLLART: Disaggregated Multi-Task Agentic RL Training
//! at Scale* as a three-layer Rust + JAX + Pallas stack: this crate is
//! Layer 3 — the paper's coordination contribution (resource / data /
//! control planes) plus every substrate it depends on.  Layers 2 and 1
//! (the agent LLM and its Pallas kernels) are AOT-compiled by
//! `python/compile` into `artifacts/*.hlo.txt` and executed from
//! [`runtime`] via the PJRT C API; Python never runs on the request path.
//!
//! Two harnesses drive the same control-plane core:
//!
//! * [`sim`] — a discrete-event simulator over the [`hw`]/[`net`]/
//!   [`envpool`]/[`serverless`] cost models; regenerates every table and
//!   figure of the paper's evaluation (see `rust/benches/`).
//! * [`exec`] — a real tokio runtime: the PJRT CPU client executes the
//!   AOT transformer while real Rust environments ([`env`]) interact with
//!   it through the same [`proxy::LlmProxy`] / [`coordinator`] machinery
//!   (see `examples/e2e_train.rs`).
//!
//! Module map (DESIGN.md §1 has the paper-section ↔ module table;
//! `docs/ARCHITECTURE.md` is the guided tour of the simulation stack,
//! `docs/DETERMINISM.md` the RNG seeding contract):
//!
//! | plane | modules |
//! |---|---|
//! | resource | [`resource`], [`hw`], [`llm`], [`net`] (incl. the shared-bandwidth [`net::SharedLink`] with bidirectional transfer slots) |
//! | data | [`cluster`], [`serverless`], [`mooncake`], [`runtime`] |
//! | control | [`coordinator`], [`proxy`] (incl. pluggable [`proxy::route`] policies), [`buffer`], [`rl`] |
//! | scheduler | [`sim::driver`]: [`sim::driver::core`] event loop, [`sim::driver::policy`] per-mode policies, [`sim::driver::lifecycle`] trajectory state machine + phase residency, [`sim::driver::pd`] PD execution mode |
//! | weights | [`weights`]: per-engine weight versions + pluggable [`weights::SyncStrategy`] dissemination (blocking / rolling / lazy / overlapped / adaptive), bucketized per-engine pulls ([`weights::bucketized_pull`], Mooncake bucket model) over a contended fan-out link |
//! | fault & elasticity | [`fault`], [`elastic`] (single-pool [`elastic::AutoScaler`] + per-class PD [`elastic::PdAutoScaler`]) |
//! | substrates | [`simkit`], [`env`], [`envpool`], [`metrics`] |
//! | trace replay | [`trace`]: streaming [`trace::TraceSource`] §8 workload generator, [`trace::ArrivalProcess`] open-loop arrivals (Poisson / diurnal / bursty), [`trace::SloPolicy`] admission + per-domain [`trace::SloReport`] on [`sim::ScenarioResult`] (driven by [`sim::driver::run_trace_replay`]) |
//! | telemetry | [`obs`]: [`obs::TraceRecorder`] Chrome-trace span/counter export, [`obs::BubbleReport`] idle-cause attribution, [`obs::critpath`] causal critical-path blame + [`obs::what_if`] estimator over [`simkit::EventQueue`] provenance (see `docs/OBSERVABILITY.md`) |
//! | evaluation | [`sim`] ([`sim::sync_driver`] + the scheduler plane), [`baselines`] |

pub mod baselines;
pub mod buffer;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod elastic;
pub mod env;
pub mod envpool;
pub mod exec;
pub mod fault;
pub mod hw;
pub mod llm;
pub mod metrics;
pub mod mooncake;
pub mod net;
pub mod obs;
pub mod proxy;
pub mod resource;
pub mod rl;
pub mod runtime;
pub mod serverless;
pub mod sim;
pub mod simkit;
pub mod trace;
pub mod util;
pub mod weights;
