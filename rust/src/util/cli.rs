//! Minimal CLI argument parser (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments; used by the launcher binary and the examples.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {v}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a number, got {v}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn key_value_forms() {
        // getopt-style: a bare `--flag` consumes the next token as its
        // value unless it is another flag or absent — so boolean flags
        // mid-line use `--flag=true` or sit last.
        let a = parse(&["--model", "qwen3-8b", "--alpha=2", "run", "--verbose"]);
        assert_eq!(a.get("model"), Some("qwen3-8b"));
        assert_eq!(a.get_usize("alpha", 1), 2);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["run"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("f", 1.5), 1.5);
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "val"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
    }

    #[test]
    #[should_panic(expected = "must be an integer")]
    fn bad_integer_panics() {
        parse(&["--n", "abc"]).get_usize("n", 0);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--x", "-3.5"]);
        assert_eq!(a.get_f64("x", 0.0), -3.5);
    }
}
