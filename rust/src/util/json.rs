//! Minimal JSON parser (offline stand-in for `serde_json`).
//!
//! Parses the machine-generated `artifacts/manifest.json` and the
//! launcher's config files.  Supports the full JSON grammar except
//! exotic number forms; numbers are f64 (the manifest only carries
//! shapes and names, well within f64's integer range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style access with a dotted path.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(v) => v.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the raw bytes through
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn nested_structure() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.at("a.2.b").unwrap().as_str(), Some("c"));
        assert_eq!(j.at("d.e").unwrap().as_bool(), Some(false));
        assert_eq!(j.at("a.0").unwrap().as_f64(), Some(1.0));
        assert!(j.at("missing").is_none());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        let j = Json::parse(r#""é café""#).unwrap();
        assert_eq!(j.as_str(), Some("é café"));
        let j2 = Json::parse("\"héllo\"").unwrap();
        assert_eq!(j2.as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "model": {"vocab": 512, "batch": 8},
            "entries": {
                "prefill": {
                    "file": "prefill.hlo.txt",
                    "inputs": [{"name": "embed", "shape": [512, 256], "dtype": "float32"}]
                }
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.at("model.vocab").unwrap().as_usize(), Some(512));
        let inp = j.at("entries.prefill.inputs.0").unwrap();
        assert_eq!(inp.get("dtype").unwrap().as_str(), Some("float32"));
        let shape: Vec<usize> = inp
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![512, 256]);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
