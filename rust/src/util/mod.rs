//! Small in-tree utilities.
//!
//! This build environment is offline with only the `xla` dependency
//! closure vendored, so helpers that would normally come from crates
//! (tempdir, JSON parsing, CLI parsing) live here instead.

pub mod cli;
pub mod json;
pub mod tempdir;
